//! Gradient-approximation quality: measure the paper's core quantity
//! directly. For snapshots (w_t, w_{t+tau}) sampled from a real training
//! run, compare
//!
//!   ||g(w_t)            - g(w_{t+tau})||   (ASGD's delayed gradient), vs
//!   ||g_dc(w_t)         - g(w_{t+tau})||   (the delay-compensated gradient
//!                                           with Diag(lambda g g^T))
//!
//! This is the microscope view of why DC-ASGD works: Section 3's Taylor
//! argument, evaluated on actual network gradients rather than theory.
//!
//!     cargo run --release --example dc_vs_asgd

use dc_asgd::config::{Algorithm, ExperimentConfig};
use dc_asgd::data::{build_dataset, EpochPartition, ShardCursor};
use dc_asgd::ps::{Hyper, NativeKernel, ParamServer};
use dc_asgd::util::stats::Running;

fn l2(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>().sqrt()
}

fn main() -> anyhow::Result<()> {
    let artifacts = dc_asgd::find_artifacts_dir()
        .expect("artifacts/manifest.json not found — run `make artifacts` first");
    let engine = dc_asgd::runtime::start_engine(&artifacts, "mlp_tiny", false)?;
    let entry = engine.entry().clone();
    let init = entry.load_init(&artifacts)?;
    let cfg = ExperimentConfig::preset_quickstart();
    let train = build_dataset(&cfg.dataset, entry.feature_kind(), entry.classes, true, 2048, 17);

    // drive a short ASGD run manually, measuring approximation error at
    // several points along the trajectory and several delays tau
    let hyper = Hyper { lambda0: 1.0, ms_momentum: 0.0, momentum: 0.0, eps: 1e-7 };
    let ps = ParamServer::new(&init, 1, 1, Algorithm::Asgd, hyper, Box::new(NativeKernel))?;
    let partition = EpochPartition::new(3, train.len(), 1);
    let mut cursor = ShardCursor::new(partition, 0, entry.batch);
    let mut params = vec![0.0f32; entry.n_padded];

    println!("tau | ||g_del - g_true||   dc-c (lam=4)   dc-a (lam0=1)   best improvement");
    println!("----+-------------------------------------------------------------------");
    for tau in [1usize, 2, 4, 8, 16] {
        let mut err_delayed = Running::new();
        let mut err_dc = Running::new();
        let mut err_dca = Running::new();
        for _trial in 0..6 {
            // advance the model a little so we measure mid-training geometry
            for _ in 0..3 {
                ps.pull(0, &mut params);
                let batch = train.make_batch(&cursor.next_indices());
                let (_, g) = engine.train(&params, &batch)?;
                ps.push(0, &g, 0.05);
            }
            ps.pull(0, &mut params);
            let w_t = params.clone();
            let probe = train.make_batch(&cursor.next_indices());
            let (_, g_t) = engine.train(&w_t, &probe)?;
            // simulate tau intervening updates by other workers
            for _ in 0..tau {
                ps.pull(0, &mut params);
                let batch = train.make_batch(&cursor.next_indices());
                let (_, g) = engine.train(&params, &batch)?;
                ps.push(0, &g, 0.05);
            }
            ps.pull(0, &mut params);
            let w_tau = params.clone();
            let (_, g_true) = engine.train(&w_tau, &probe)?;
            // constant-lambda approximation: g + lam*g*g*(w_tau - w_t)
            let lam = 4.0f32;
            let g_dc: Vec<f32> = g_t
                .iter()
                .zip(&w_tau)
                .zip(&w_t)
                .map(|((g, wt), w0)| g + lam * g * g * (wt - w0))
                .collect();
            // adaptive-lambda (Eqn. 14 with ms = g^2): g + lam0*|g|*(w_tau - w_t)
            let lam0 = 1.0f32;
            let g_dca: Vec<f32> = g_t
                .iter()
                .zip(&w_tau)
                .zip(&w_t)
                .map(|((g, wt), w0)| g + lam0 * g.abs() * (wt - w0))
                .collect();
            err_delayed.push(l2(&g_t, &g_true));
            err_dc.push(l2(&g_dc, &g_true));
            err_dca.push(l2(&g_dca, &g_true));
        }
        let best = err_dc.mean().min(err_dca.mean());
        let improvement = 100.0 * (1.0 - best / err_delayed.mean());
        println!(
            "{:>3} | {:>18.6} {:>14.6} {:>15.6} {:>+17.1}%",
            tau,
            err_delayed.mean(),
            err_dc.mean(),
            err_dca.mean(),
            improvement
        );
    }
    println!("\nPositive improvement = the compensated gradient is closer to the");
    println!("true gradient g(w_t+tau) than the delayed gradient ASGD applies.");
    engine.shutdown();
    Ok(())
}
