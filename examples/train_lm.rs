//! End-to-end driver (DESIGN.md §7): train a decoder-only transformer LM
//! with DC-ASGD-a over simulated workers on the synthetic corpus, logging
//! the loss curve. This exercises every layer of the stack on one real
//! workload: Pallas softmax-CE kernel -> JAX fwd/bwd -> AOT HLO -> PJRT
//! engine -> sharded parameter server -> DC update rule -> metrics.
//!
//!     cargo run --release --example train_lm -- [--model lm_medium]
//!         [--steps 300] [--workers 4] [--algo dc-asgd-a] [--compare]
//!
//! `--compare` additionally runs ASGD with the same budget so the delay
//! compensation effect is visible on the loss curve. Output lands in
//! runs/train_lm/.

use dc_asgd::config::{Algorithm, ExperimentConfig};
use dc_asgd::coordinator::Trainer;
use dc_asgd::util::cli::Args;

fn run_one(
    algo: Algorithm,
    model: &str,
    steps: usize,
    workers: usize,
    artifacts: &std::path::Path,
    engine: &dc_asgd::runtime::EngineHandle,
) -> anyhow::Result<()> {
    let mut cfg = ExperimentConfig::preset_lm(model);
    cfg.algorithm = algo;
    cfg.workers = if algo == Algorithm::SequentialSgd { 1 } else { workers };
    cfg.max_steps = steps;
    cfg.eval_every_steps = (steps / 6).max(25);
    cfg.out_dir = "runs/train_lm".into();
    cfg.tag = format!("{}_{}_m{}_s{}", model, algo.name(), cfg.workers, steps);
    cfg.verbose = true;

    eprintln!("== {} | {} | M={} | {} steps ==", model, algo, cfg.workers, steps);
    let t0 = std::time::Instant::now();
    let trainer = Trainer::with_engine(cfg, engine.clone(), artifacts)?;
    let report = trainer.run()?;
    println!(
        "[{}] {} steps in {:.1}s wall | final train loss {:.4} | test loss {:.4} | \
         token error {:.2}% | staleness mean {:.2}",
        algo.name(),
        report.total_steps,
        t0.elapsed().as_secs_f64(),
        report.final_train_loss,
        report.final_test_loss,
        report.final_test_error * 100.0,
        report.staleness_mean,
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let model = args.str_or("model", "lm_medium");
    let steps = args.usize_or("steps", 300)?;
    let workers = args.usize_or("workers", 4)?;
    let algo = Algorithm::parse(&args.str_or("algo", "dc-asgd-a"))?;
    let compare = args.flag("compare");
    args.finish()?;

    let artifacts = dc_asgd::find_artifacts_dir()
        .expect("artifacts/manifest.json not found — run `make artifacts` first");
    let engine = dc_asgd::runtime::start_engine(&artifacts, &model, false)?;

    run_one(algo, &model, steps, workers, &artifacts, &engine)?;
    if compare {
        run_one(Algorithm::Asgd, &model, steps, workers, &artifacts, &engine)?;
    }
    println!("loss curves: runs/train_lm/*.steps.csv (loss vs step/time)");
    engine.shutdown();
    Ok(())
}
