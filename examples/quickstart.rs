//! Quickstart: train the tiny MLP with every algorithm and compare.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Runs in about a minute on one CPU core: sequential SGD, SSGD, ASGD and
//! both DC-ASGD variants on the CIFAR-like synthetic task, M=4 workers,
//! simulated cluster time — the whole paper in miniature.

use dc_asgd::bench::Table;
use dc_asgd::config::{Algorithm, ExperimentConfig};
use dc_asgd::coordinator::Trainer;

fn main() -> anyhow::Result<()> {
    let artifacts = dc_asgd::find_artifacts_dir()
        .expect("artifacts/manifest.json not found — run `make artifacts` first");
    // one engine, reused across runs (PJRT compilation is the slow part)
    let engine = dc_asgd::runtime::start_engine(&artifacts, "mlp_tiny", false)?;

    let algos = [
        Algorithm::SequentialSgd,
        Algorithm::SyncSgd,
        Algorithm::Asgd,
        Algorithm::DcAsgdConst,
        Algorithm::DcAsgdAdaptive,
    ];

    let mut table = Table::new(&["algorithm", "workers", "test error(%)", "sim time(s)", "stale(mean)"]);
    for algo in algos {
        let mut cfg = ExperimentConfig::preset_quickstart();
        cfg.algorithm = algo;
        cfg.workers = if algo == Algorithm::SequentialSgd { 1 } else { 4 };
        cfg.out_dir = "runs/quickstart".into();
        eprintln!("== {algo} (M={}) ==", cfg.workers);
        let report = Trainer::with_engine(cfg.clone(), engine.clone(), &artifacts)?.run()?;
        table.row(&[
            algo.name().into(),
            cfg.workers.to_string(),
            format!("{:.2}", report.final_test_error * 100.0),
            format!("{:.1}", report.total_time),
            format!("{:.2}", report.staleness_mean),
        ]);
    }
    println!("\nCIFAR-like synthetic task, mlp_tiny, 6 epochs:");
    table.print();
    println!("\nExpect: DC-ASGD variants close the gap between ASGD and sequential SGD");
    println!("while keeping ASGD-like simulated wallclock. Metrics in runs/quickstart/.");
    engine.shutdown();
    Ok(())
}
