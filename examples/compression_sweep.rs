//! Gradient compression in one picture: what a codec buys on the wire and
//! what error feedback preserves in the loss.
//!
//! Each worker encodes its gradient (TopK / RandK sparsification or QSGD
//! quantization) with an error-feedback residual before pushing; the
//! scheduler charges uploads at the encoded wire size under the `[comm]`
//! model. Dense ASGD pays full price per push; topk@0.1 ships ~6x fewer
//! bytes and finishes sooner on the same schedule budget.
//!
//!     cargo run --release --example compression_sweep

use dc_asgd::bench::Table;
use dc_asgd::compress::CodecConfig;
use dc_asgd::config::{Algorithm, ExperimentConfig};
use dc_asgd::coordinator::Trainer;
use dc_asgd::sim::CommModel;

fn main() -> anyhow::Result<()> {
    let artifacts = dc_asgd::find_artifacts_dir()
        .expect("artifacts/manifest.json not found — run `make artifacts` first");
    let engine = dc_asgd::runtime::start_engine(&artifacts, "mlp_tiny", false)?;

    let mut table =
        Table::new(&["algo", "codec", "upload(MB)", "wire(MB)", "time(s)", "loss", "err(%)"]);
    for algo in [Algorithm::Asgd, Algorithm::DcAsgdAdaptive] {
        for codec in [
            CodecConfig::None,
            CodecConfig::TopK { ratio: 0.1 },
            CodecConfig::RandK { ratio: 0.1 },
            CodecConfig::Qsgd { bits: 4 },
        ] {
            let mut cfg = ExperimentConfig::preset_quickstart();
            cfg.algorithm = algo;
            cfg.workers = 8;
            cfg.epochs = 4;
            cfg.compress = codec;
            // slow wire: transfer time is a first-order cost here
            cfg.comm.enabled = true;
            cfg.comm.model = CommModel { per_push: 1e-4, per_mb: 0.25 };
            let (report, log) =
                Trainer::with_engine(cfg, engine.clone(), &artifacts)?.run_logged()?;
            let upload =
                report.total_steps * codec.wire_bytes(engine.n_padded()) as u64;
            table.row(&[
                algo.name().into(),
                codec.to_string(),
                format!("{:.2}", upload as f64 / 1e6),
                format!("{:.2}", log.comm_bytes() as f64 / 1e6),
                format!("{:.1}", report.total_time),
                format!("{:.4}", report.final_train_loss),
                format!("{:.2}", report.final_test_error * 100.0),
            ]);
        }
    }
    table.print();
    println!(
        "(uploads are charged at the encoded wire size; model downloads stay dense — \
         see the `[compress]` section in README.md)"
    );
    engine.shutdown();
    Ok(())
}
