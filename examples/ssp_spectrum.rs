//! Stale-synchronous parallel in one picture: sweep the staleness bound s
//! and watch SSGD morph into ASGD.
//!
//! At s = 0 every worker waits for the whole fleet each step (barrier
//! rounds, zero staleness, straggler-bound wallclock); as s grows, workers
//! overlap more (wallclock falls, staleness rises); DC-S3GD applies the
//! paper's delay compensation on the same schedule to claw the accuracy
//! back.
//!
//!     cargo run --release --example ssp_spectrum

use dc_asgd::bench::Table;
use dc_asgd::config::{Algorithm, DelayModel, ExperimentConfig};
use dc_asgd::coordinator::Trainer;

fn main() -> anyhow::Result<()> {
    let artifacts = dc_asgd::find_artifacts_dir()
        .expect("artifacts/manifest.json not found — run `make artifacts` first");
    let engine = dc_asgd::runtime::start_engine(&artifacts, "mlp_tiny", false)?;

    let mut table =
        Table::new(&["algorithm", "s", "error(%)", "time(s)", "stale mean", "wait(s)"]);
    for algo in [Algorithm::Ssp, Algorithm::DcS3gd] {
        for s in [0usize, 1, 4, 16] {
            let mut cfg = ExperimentConfig::preset_quickstart();
            cfg.algorithm = algo;
            cfg.workers = 8;
            cfg.epochs = 4;
            cfg.staleness_bound = s;
            // a straggly fleet makes the barrier<->staleness tradeoff visible
            cfg.delay =
                DelayModel::Heterogeneous { mean: 1.0, speeds: vec![1.0, 1.6], jitter: 0.2 };
            let (report, log) =
                Trainer::with_engine(cfg, engine.clone(), &artifacts)?.run_logged()?;
            table.row(&[
                algo.name().into(),
                s.to_string(),
                format!("{:.2}", report.final_test_error * 100.0),
                format!("{:.1}", report.total_time),
                format!("{:.2}", report.staleness_mean),
                format!("{:.1}", log.wait_total()),
            ]);
        }
    }
    table.print();
    println!("\nExpect: time(s) falls and staleness rises with s;");
    println!("DC-S3GD holds accuracy closer to SSGD at the async end.");
    engine.shutdown();
    Ok(())
}
