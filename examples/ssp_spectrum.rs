//! Stale-synchronous parallel in one picture: sweep the staleness bound s
//! and watch SSGD morph into ASGD.
//!
//! At s = 0 every worker waits for the whole fleet each step (barrier
//! rounds, zero staleness, straggler-bound wallclock); as s grows, workers
//! overlap more (wallclock falls, staleness rises); DC-S3GD applies the
//! paper's delay compensation on the same schedule to claw the accuracy
//! back.
//!
//! The grid is the committed scenarios/ssp_spectrum.toml — the same file
//! the bench runs — expanded and driven through
//! [`dc_asgd::scenario::run_grid`].
//!
//!     cargo run --release --example ssp_spectrum

use dc_asgd::bench::Table;
use dc_asgd::scenario::{find_scenarios_dir, run_grid, Scenario};

fn main() -> anyhow::Result<()> {
    let artifacts = dc_asgd::find_artifacts_dir()
        .expect("artifacts/manifest.json not found — run `make artifacts` first");
    let scenarios = find_scenarios_dir().expect("scenarios/README.md not found");
    let sc = Scenario::load(&scenarios.join("ssp_spectrum.toml"))?;
    let engine = dc_asgd::runtime::start_engine(&artifacts, "mlp_tiny", false)?;

    let runs = run_grid(
        &sc,
        &engine,
        &artifacts,
        |_cfg, _case| Ok(()),
        |_case, _cfg, _report| Vec::new(),
    )?;

    let mut table =
        Table::new(&["algorithm", "s", "error(%)", "time(s)", "stale mean", "wait(s)"]);
    for r in &runs {
        let s = r.config.staleness_bound;
        table.row(&[
            r.config.algorithm.name().into(),
            if s >= usize::MAX / 2 { "inf".to_string() } else { s.to_string() },
            format!("{:.2}", r.report.final_test_error * 100.0),
            format!("{:.1}", r.report.total_time),
            format!("{:.2}", r.report.staleness_mean),
            format!("{:.1}", r.report.wait_total),
        ]);
    }
    table.print();
    println!("\nExpect: time(s) falls and staleness rises with s;");
    println!("DC-S3GD holds accuracy closer to SSGD at the async end.");
    engine.shutdown();
    Ok(())
}
