//! Delay-tolerance study: how does each algorithm degrade as the cluster
//! gets *more asynchronous*?
//!
//! The paper's theory (Thm 5.1 / Cor 5.2) says DC-ASGD tolerates larger
//! delays tau than ASGD. We turn that knob two ways:
//!
//! 1. worker count M (tau scales with M, Fig. 2's M=4 vs M=8 effect),
//! 2. straggler heaviness (Pareto tail alpha): heavier tails produce rare
//!    but huge tau — the regime where delayed gradients hurt most.
//!
//!     cargo run --release --example delay_sweep

use dc_asgd::bench::Table;
use dc_asgd::config::{Algorithm, DelayModel, ExperimentConfig};
use dc_asgd::coordinator::Trainer;

fn main() -> anyhow::Result<()> {
    let artifacts = dc_asgd::find_artifacts_dir()
        .expect("artifacts/manifest.json not found — run `make artifacts` first");
    let engine = dc_asgd::runtime::start_engine(&artifacts, "mlp_tiny", false)?;
    let algos = [Algorithm::Asgd, Algorithm::DcAsgdConst, Algorithm::DcAsgdAdaptive];

    // -- sweep 1: worker count ------------------------------------------------
    let mut t1 = Table::new(&["M", "algorithm", "error(%)", "stale mean", "stale max"]);
    for m in [2usize, 4, 8, 16] {
        for algo in algos {
            let mut cfg = ExperimentConfig::preset_quickstart();
            cfg.algorithm = algo;
            cfg.workers = m;
            cfg.epochs = 6;
            let r = Trainer::with_engine(cfg, engine.clone(), &artifacts)?.run()?;
            t1.row(&[
                m.to_string(),
                algo.name().into(),
                format!("{:.2}", r.final_test_error * 100.0),
                format!("{:.2}", r.staleness_mean),
                r.staleness_max.to_string(),
            ]);
        }
    }
    println!("\n# Degradation with worker count (uniform worker speeds)");
    t1.print();

    // -- sweep 2: straggler tail ---------------------------------------------
    let mut t2 = Table::new(&["pareto alpha", "algorithm", "error(%)", "stale p99"]);
    for alpha in [3.0f64, 2.0, 1.3] {
        for algo in algos {
            let mut cfg = ExperimentConfig::preset_quickstart();
            cfg.algorithm = algo;
            cfg.workers = 8;
            cfg.epochs = 6;
            cfg.delay = DelayModel::Pareto { scale: 1.0, alpha };
            let r = Trainer::with_engine(cfg, engine.clone(), &artifacts)?.run()?;
            t2.row(&[
                format!("{alpha}"),
                algo.name().into(),
                format!("{:.2}", r.final_test_error * 100.0),
                format!("{:.0}", r.staleness_p99),
            ]);
        }
    }
    println!("\n# Degradation with straggler heaviness (M=8, Pareto compute times)");
    t2.print();
    println!("\nExpect: ASGD error grows with M and with tail heaviness;");
    println!("DC-ASGD degrades more slowly (the paper's delay-tolerance claim).");
    engine.shutdown();
    Ok(())
}
