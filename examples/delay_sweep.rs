//! Delay-tolerance study: how does each algorithm degrade as the cluster
//! gets *more asynchronous*?
//!
//! The paper's theory (Thm 5.1 / Cor 5.2) says DC-ASGD tolerates larger
//! delays tau than ASGD. We turn that knob two ways, each a committed
//! scenario file:
//!
//! 1. scenarios/delay_workers.toml — worker count M (tau scales with M,
//!    Fig. 2's M=4 vs M=8 effect),
//! 2. scenarios/delay_tail.toml — straggler heaviness (Pareto tail
//!    alpha): heavier tails produce rare but huge tau — the regime where
//!    delayed gradients hurt most.
//!
//!     cargo run --release --example delay_sweep

use dc_asgd::bench::Table;
use dc_asgd::scenario::{find_scenarios_dir, run_grid, GridRun, Scenario};

fn main() -> anyhow::Result<()> {
    let artifacts = dc_asgd::find_artifacts_dir()
        .expect("artifacts/manifest.json not found — run `make artifacts` first");
    let scenarios = find_scenarios_dir().expect("scenarios/README.md not found");
    let engine = dc_asgd::runtime::start_engine(&artifacts, "mlp_tiny", false)?;
    let grid = |name: &str| -> anyhow::Result<Vec<GridRun>> {
        let sc = Scenario::load(&scenarios.join(format!("{name}.toml")))?;
        run_grid(&sc, &engine, &artifacts, |_c, _| Ok(()), |_, _, _| Vec::new())
    };

    // -- sweep 1: worker count ------------------------------------------------
    let mut t1 = Table::new(&["M", "algorithm", "error(%)", "stale mean", "stale max"]);
    for r in &grid("delay_workers")? {
        t1.row(&[
            r.config.workers.to_string(),
            r.config.algorithm.name().into(),
            format!("{:.2}", r.report.final_test_error * 100.0),
            format!("{:.2}", r.report.staleness_mean),
            r.report.staleness_max.to_string(),
        ]);
    }
    println!("\n# Degradation with worker count (uniform worker speeds)");
    t1.print();

    // -- sweep 2: straggler tail ---------------------------------------------
    let mut t2 = Table::new(&["pareto alpha", "algorithm", "error(%)", "stale p99"]);
    for r in &grid("delay_tail")? {
        let alpha = match r.config.delay {
            dc_asgd::config::DelayModel::Pareto { alpha, .. } => alpha,
            _ => f64::NAN,
        };
        t2.row(&[
            format!("{alpha}"),
            r.config.algorithm.name().into(),
            format!("{:.2}", r.report.final_test_error * 100.0),
            format!("{:.0}", r.report.staleness_p99),
        ]);
    }
    println!("\n# Degradation with straggler heaviness (M=8, Pareto compute times)");
    t2.print();
    println!("\nExpect: ASGD error grows with M and with tail heaviness;");
    println!("DC-ASGD degrades more slowly (the paper's delay-tolerance claim).");
    engine.shutdown();
    Ok(())
}
