//! Fault injection in one picture: the same workload on a healthy fleet
//! and on churny ones (crashes + restarts + post-recovery stragglers).
//!
//! Churn amplifies gradient staleness — a straggling worker holds its
//! snapshot while peers push past it — which is exactly what delay
//! compensation (Eqn. 10) corrects. Expect the ASGD loss to degrade with
//! churn while DC-ASGD-a holds close to its healthy-fleet loss.
//!
//! The grid is the committed scenarios/fault_churn.toml — the same file
//! the bench runs — with the bench's coupling rule applied in the tweak
//! hook (straggle stream scales with the swept crash rate; crash_rate = 0
//! keeps `[faults]` fully off).
//!
//!     cargo run --release --example fault_churn

use dc_asgd::bench::Table;
use dc_asgd::scenario::{find_scenarios_dir, run_grid, Scenario};

fn main() -> anyhow::Result<()> {
    let artifacts = dc_asgd::find_artifacts_dir()
        .expect("artifacts/manifest.json not found — run `make artifacts` first");
    let scenarios = find_scenarios_dir().expect("scenarios/README.md not found");
    let sc = Scenario::load(&scenarios.join("fault_churn.toml"))?;
    let engine = dc_asgd::runtime::start_engine(&artifacts, "mlp_tiny", false)?;

    let runs = run_grid(
        &sc,
        &engine,
        &artifacts,
        |cfg, _case| {
            if cfg.faults.crash_rate == 0.0 {
                cfg.faults = Default::default();
            } else {
                cfg.faults.straggler_rate = cfg.faults.crash_rate;
            }
            Ok(())
        },
        |_case, _cfg, _report| Vec::new(),
    )?;

    let mut table = Table::new(&[
        "algo",
        "churn",
        "loss",
        "err(%)",
        "crashes",
        "restarts",
        "stale(mean)",
        "time(s)",
    ]);
    for r in &runs {
        table.row(&[
            r.config.algorithm.name().into(),
            format!("{}", r.config.faults.crash_rate),
            format!("{:.4}", r.report.final_train_loss),
            format!("{:.2}", r.report.final_test_error * 100.0),
            r.report.faults.crashes.to_string(),
            r.report.faults.restarts.to_string(),
            format!("{:.2}", r.report.staleness_mean),
            format!("{:.1}", r.report.total_time),
        ]);
    }
    table.print();
    println!(
        "(churn = crashes/worker/s AND straggle windows/worker/s; in-flight gradients \
         are dropped on crash, w_bak and the EF residual are re-seeded on rejoin — \
         see the `[faults]` section in README.md)"
    );
    engine.shutdown();
    Ok(())
}
