//! Fault injection in one picture: the same workload on a healthy fleet
//! and on a churny one (crashes + restarts + post-recovery stragglers),
//! for plain ASGD vs DC-ASGD-a.
//!
//! Churn amplifies gradient staleness — a straggling worker holds its
//! snapshot while peers push past it — which is exactly what delay
//! compensation (Eqn. 10) corrects. Expect the ASGD loss to degrade with
//! churn while DC-ASGD-a holds close to its healthy-fleet loss.
//!
//!     cargo run --release --example fault_churn

use dc_asgd::bench::Table;
use dc_asgd::config::{Algorithm, ExperimentConfig};
use dc_asgd::coordinator::Trainer;

fn main() -> anyhow::Result<()> {
    let artifacts = dc_asgd::find_artifacts_dir()
        .expect("artifacts/manifest.json not found — run `make artifacts` first");
    let engine = dc_asgd::runtime::start_engine(&artifacts, "mlp_tiny", false)?;

    let mut table = Table::new(&[
        "algo",
        "churn",
        "loss",
        "err(%)",
        "crashes",
        "restarts",
        "stale(mean)",
        "time(s)",
    ]);
    for algo in [Algorithm::Asgd, Algorithm::DcAsgdAdaptive] {
        for &churn in &[0.0f64, 0.1] {
            let mut cfg = ExperimentConfig::preset_quickstart();
            cfg.algorithm = algo;
            cfg.workers = 8;
            cfg.epochs = 4;
            if churn > 0.0 {
                cfg.faults.enabled = true;
                cfg.faults.crash_rate = churn;
                cfg.faults.restart_mean = 3.0;
                cfg.faults.departure_prob = 0.0; // crashes always restart
                cfg.faults.straggler_rate = churn;
                cfg.faults.straggler_factor = 5.0;
                cfg.faults.straggler_duration = 5.0;
            }
            let report = Trainer::with_engine(cfg, engine.clone(), &artifacts)?.run()?;
            table.row(&[
                algo.name().into(),
                format!("{churn}"),
                format!("{:.4}", report.final_train_loss),
                format!("{:.2}", report.final_test_error * 100.0),
                report.faults.crashes.to_string(),
                report.faults.restarts.to_string(),
                format!("{:.2}", report.staleness_mean),
                format!("{:.1}", report.total_time),
            ]);
        }
    }
    table.print();
    println!(
        "(churn = crashes/worker/s AND straggle windows/worker/s; in-flight gradients \
         are dropped on crash, w_bak and the EF residual are re-seeded on rejoin — \
         see the `[faults]` section in README.md)"
    );
    engine.shutdown();
    Ok(())
}
