"""Layer-2: JAX model definitions (forward/backward) over flat parameters.

Three model families, all ending in the fused Pallas softmax-CE kernel so
the entire loss (and its custom VJP) lowers into the AOT HLO artifact:

* ``mlp``        — residual MLP classifier (the CIFAR-like / ImageNet-like
                   table workloads; stands in for ResNet-20/50 at 1-core
                   scale — same softmax-CE loss, non-convex, residual).
* ``cnn``        — small residual conv net ("resnet-lite") on 32x32x3.
* ``transformer``— decoder-only LM for the end-to-end training driver.

Every model exposes:

    spec()                          -> ParamSpec
    loss_fn(flat, x, y)             -> scalar mean loss
    train_step(flat, x, y)          -> (loss, grads_flat)   [jax.value_and_grad]
    eval_step(flat, x, y)           -> (loss, correct_count)

Python here is build-time only: `aot.py` lowers these to HLO text once.
"""

from __future__ import annotations

from dataclasses import dataclass, asdict

import jax
import jax.numpy as jnp
import numpy as np

from .params import ParamSpec
from .kernels.xent import softmax_xent


# --------------------------------------------------------------------------
# MLP (residual)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class MlpConfig:
    name: str
    input_dim: int
    hidden: tuple
    classes: int
    batch: int
    residual: bool = True

    @property
    def kind(self) -> str:
        return "mlp"


def mlp_spec(cfg: MlpConfig) -> ParamSpec:
    spec = ParamSpec()
    dims = [cfg.input_dim, *cfg.hidden]
    for i in range(len(dims) - 1):
        spec.add(f"w{i}", (dims[i], dims[i + 1]), "he", fan_in=dims[i])
        spec.add(f"b{i}", (dims[i + 1],), "zeros")
    spec.add("w_out", (dims[-1], cfg.classes), "glorot", fan_in=dims[-1])
    spec.add("b_out", (cfg.classes,), "zeros")
    return spec


def mlp_logits(cfg: MlpConfig, p: dict, x):
    h = x
    dims = [cfg.input_dim, *cfg.hidden]
    for i in range(len(dims) - 1):
        z = h @ p[f"w{i}"] + p[f"b{i}"]
        z = jax.nn.relu(z)
        # residual connection when shapes line up (resnet-lite behaviour)
        if cfg.residual and dims[i] == dims[i + 1]:
            h = h + z
        else:
            h = z
    return h @ p["w_out"] + p["b_out"]


# --------------------------------------------------------------------------
# CNN ("resnet-lite")
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class CnnConfig:
    name: str
    image: tuple          # (H, W, C)
    channels: tuple       # conv widths, e.g. (16, 16, 32)
    classes: int
    batch: int

    @property
    def kind(self) -> str:
        return "cnn"

    @property
    def input_dim(self) -> int:
        h, w, c = self.image
        return h * w * c


def cnn_spec(cfg: CnnConfig) -> ParamSpec:
    spec = ParamSpec()
    cin = cfg.image[2]
    for i, cout in enumerate(cfg.channels):
        spec.add(f"k{i}", (3, 3, cin, cout), "he", fan_in=9 * cin)
        spec.add(f"kb{i}", (cout,), "zeros")
        if cin == cout:  # residual block second conv
            spec.add(f"r{i}", (3, 3, cout, cout), "he", fan_in=9 * cout)
            spec.add(f"rb{i}", (cout,), "zeros")
        cin = cout
    h, w, _ = cfg.image
    downs = sum(1 for i in range(1, len(cfg.channels)))  # stride-2 at each widening
    # compute spatial dims after the stride schedule in cnn_logits
    spec.add("w_out", (cfg.channels[-1], cfg.classes), "glorot", fan_in=cfg.channels[-1])
    spec.add("b_out", (cfg.classes,), "zeros")
    del h, w, downs
    return spec


def _conv(x, k, b, stride=1):
    y = jax.lax.conv_general_dilated(
        x, k, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + b


def cnn_logits(cfg: CnnConfig, p: dict, x):
    b = x.shape[0]
    h = x.reshape(b, *cfg.image)
    cin = cfg.image[2]
    for i, cout in enumerate(cfg.channels):
        stride = 2 if (i > 0 and cout != cin) else 1
        z = jax.nn.relu(_conv(h, p[f"k{i}"], p[f"kb{i}"], stride))
        if cin == cout:
            z = jax.nn.relu(z + _conv(h, p[f"r{i}"], p[f"rb{i}"]))
        h = z
        cin = cout
    pooled = jnp.mean(h, axis=(1, 2))          # global average pool
    return pooled @ p["w_out"] + p["b_out"]


# --------------------------------------------------------------------------
# Transformer LM (decoder-only)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class LmConfig:
    name: str
    vocab: int
    d_model: int
    n_heads: int
    n_layers: int
    seq_len: int
    batch: int
    d_ff: int = 0  # 0 -> 4*d_model

    @property
    def kind(self) -> str:
        return "transformer"

    @property
    def ff(self) -> int:
        return self.d_ff or 4 * self.d_model


def lm_spec(cfg: LmConfig) -> ParamSpec:
    spec = ParamSpec()
    d = cfg.d_model
    spec.add("tok_emb", (cfg.vocab, d), "embed")
    spec.add("pos_emb", (cfg.seq_len, d), "embed")
    for i in range(cfg.n_layers):
        spec.add(f"l{i}.ln1_g", (d,), "ones")
        spec.add(f"l{i}.ln1_b", (d,), "zeros")
        spec.add(f"l{i}.wqkv", (d, 3 * d), "glorot", fan_in=d)
        spec.add(f"l{i}.wo", (d, d), "glorot", fan_in=d)
        spec.add(f"l{i}.ln2_g", (d,), "ones")
        spec.add(f"l{i}.ln2_b", (d,), "zeros")
        spec.add(f"l{i}.wff1", (d, cfg.ff), "he", fan_in=d)
        spec.add(f"l{i}.bff1", (cfg.ff,), "zeros")
        spec.add(f"l{i}.wff2", (cfg.ff, d), "glorot", fan_in=cfg.ff)
        spec.add(f"l{i}.bff2", (d,), "zeros")
    spec.add("lnf_g", (d,), "ones")
    spec.add("lnf_b", (d,), "zeros")
    spec.add("w_head", (d, cfg.vocab), "glorot", fan_in=d)
    return spec


def _layernorm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _attn(cfg: LmConfig, p: dict, i: int, h):
    b, t, d = h.shape
    nh, hd = cfg.n_heads, d // cfg.n_heads
    qkv = h @ p[f"l{i}.wqkv"]                          # [b,t,3d]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, t, nh, hd).transpose(0, 2, 1, 3)  # [b,nh,t,hd]
    k = k.reshape(b, t, nh, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, t, nh, hd).transpose(0, 2, 1, 3)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(hd).astype(np.float32)
    mask = jnp.tril(jnp.ones((t, t), bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    att = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, t, d)
    return out @ p[f"l{i}.wo"]


def lm_logits(cfg: LmConfig, p: dict, x):
    """x int32 [B,T] -> logits [B*T, V] (flattened rows for the xent kernel)."""
    h = p["tok_emb"][x] + p["pos_emb"][None, :, :]
    for i in range(cfg.n_layers):
        h = h + _attn(cfg, p, i, _layernorm(h, p[f"l{i}.ln1_g"], p[f"l{i}.ln1_b"]))
        z = _layernorm(h, p[f"l{i}.ln2_g"], p[f"l{i}.ln2_b"])
        z = jax.nn.relu(z @ p[f"l{i}.wff1"] + p[f"l{i}.bff1"]) @ p[f"l{i}.wff2"] + p[f"l{i}.bff2"]
        h = h + z
    h = _layernorm(h, p["lnf_g"], p["lnf_b"])
    logits = h @ p["w_head"]
    return logits.reshape(-1, cfg.vocab)


# --------------------------------------------------------------------------
# Uniform model facade
# --------------------------------------------------------------------------


class Model:
    """Uniform wrapper: spec + loss/train/eval closures over flat params."""

    def __init__(self, cfg):
        self.cfg = cfg
        if cfg.kind == "mlp":
            self.spec = mlp_spec(cfg)
            self._logits = lambda p, x: mlp_logits(cfg, p, x)
        elif cfg.kind == "cnn":
            self.spec = cnn_spec(cfg)
            self._logits = lambda p, x: cnn_logits(cfg, p, x)
        elif cfg.kind == "transformer":
            self.spec = lm_spec(cfg)
            self._logits = lambda p, x: lm_logits(cfg, p, x)
        else:
            raise ValueError(cfg.kind)

    # -- shapes the artifact is specialized to -----------------------------
    def input_shapes(self):
        cfg = self.cfg
        if cfg.kind == "transformer":
            x = ("i32", [cfg.batch, cfg.seq_len])
            y = ("i32", [cfg.batch, cfg.seq_len])
        else:
            x = ("f32", [cfg.batch, cfg.input_dim])
            y = ("i32", [cfg.batch])
        return x, y

    def example_args(self):
        (xd, xs), (yd, ys) = self.input_shapes()
        params = jax.ShapeDtypeStruct((self.spec.n_padded,), jnp.float32)
        x = jax.ShapeDtypeStruct(tuple(xs), jnp.float32 if xd == "f32" else jnp.int32)
        y = jax.ShapeDtypeStruct(tuple(ys), jnp.int32)
        return params, x, y

    # -- loss / train / eval ------------------------------------------------
    def loss_fn(self, flat, x, y):
        p = self.spec.unpack(flat)
        logits = self._logits(p, x)
        labels = y.reshape(-1)
        return jnp.mean(softmax_xent(logits, labels))

    def train_step(self, flat, x, y):
        loss, grads = jax.value_and_grad(self.loss_fn)(flat, x, y)
        return loss, grads

    def eval_step(self, flat, x, y):
        p = self.spec.unpack(flat)
        logits = self._logits(p, x)
        labels = y.reshape(-1)
        loss = jnp.mean(softmax_xent(logits, labels))
        pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        correct = jnp.sum((pred == labels).astype(jnp.float32))
        return loss, correct

    def meta(self) -> dict:
        return {"kind": self.cfg.kind, **asdict(self.cfg)}
