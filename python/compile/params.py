"""Flat parameter-vector packing for Layer-2 models.

The rust coordinator is model-agnostic: every model artifact has the same
signature over a single flat f32[N_padded] parameter vector,

    train_step(params, x, y) -> (loss, grads)      grads: f32[N_padded]
    eval_step(params, x, y)  -> (loss, correct)

so the parameter server stores/updates one contiguous buffer per model and
the DC update kernels tile it uniformly. N is padded up to a multiple of the
update-kernel block so the Pallas grid divides evenly; the tail is unused by
the model (its gradient is exactly zero).

Offsets are static python ints, so `flat[o:o+n].reshape(shape)` stays a
static slice under jit — no dynamic-slice overhead in the lowered HLO.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

# Must match kernels.dc_update.BLOCK: the PS vector length is a multiple of
# the update-kernel tile.
PAD_MULTIPLE = 8192


@dataclass(frozen=True)
class TensorSpec:
    name: str
    shape: tuple
    init: str = "he"      # he | glorot | zeros | embed | ones
    fan_in: int | None = None

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1


@dataclass
class ParamSpec:
    """Ordered collection of named tensors packed into one flat vector."""

    tensors: list = field(default_factory=list)

    def add(self, name: str, shape, init: str = "he", fan_in: int | None = None) -> None:
        if any(t.name == name for t in self.tensors):
            raise ValueError(f"duplicate tensor name {name!r}")
        self.tensors.append(TensorSpec(name, tuple(int(s) for s in shape), init, fan_in))

    @property
    def n_params(self) -> int:
        return sum(t.size for t in self.tensors)

    @property
    def n_padded(self) -> int:
        return int(math.ceil(self.n_params / PAD_MULTIPLE) * PAD_MULTIPLE)

    def offsets(self) -> dict:
        out, o = {}, 0
        for t in self.tensors:
            out[t.name] = o
            o += t.size
        return out

    def unpack(self, flat):
        """flat f32[n_padded] -> dict name -> array(shape). Static slices."""
        out, o = {}, 0
        for t in self.tensors:
            out[t.name] = flat[o : o + t.size].reshape(t.shape)
            o += t.size
        return out

    def init_flat(self, seed: int = 0) -> np.ndarray:
        """Numpy init of the padded flat vector (run once, host side)."""
        rng = np.random.default_rng(seed)
        flat = np.zeros(self.n_padded, dtype=np.float32)
        o = 0
        for t in self.tensors:
            n = t.size
            if t.init == "zeros":
                vals = np.zeros(t.shape, dtype=np.float32)
            elif t.init == "ones":
                vals = np.ones(t.shape, dtype=np.float32)
            elif t.init == "embed":
                vals = rng.normal(0.0, 0.02, size=t.shape).astype(np.float32)
            else:
                fan_in = t.fan_in
                if fan_in is None:
                    fan_in = t.shape[0] if len(t.shape) >= 2 else max(1, n)
                if t.init == "glorot":
                    fan_out = t.shape[-1] if len(t.shape) >= 2 else n
                    std = math.sqrt(2.0 / (fan_in + fan_out))
                else:  # he
                    std = math.sqrt(2.0 / fan_in)
                vals = rng.normal(0.0, std, size=t.shape).astype(np.float32)
            flat[o : o + n] = vals.reshape(-1)
            o += n
        return flat

    def describe(self) -> list:
        """Manifest-friendly listing: [{name, shape, offset, size}...]."""
        offs = self.offsets()
        return [
            {"name": t.name, "shape": list(t.shape), "offset": offs[t.name], "size": t.size}
            for t in self.tensors
        ]


def pad_to(flat, n_padded: int):
    """Pad a flat jnp vector with zeros up to n_padded."""
    n = flat.shape[0]
    if n == n_padded:
        return flat
    return jnp.concatenate([flat, jnp.zeros(n_padded - n, flat.dtype)])
