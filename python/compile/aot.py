"""AOT compile path: lower every Layer-2/Layer-1 computation to HLO text.

Run once via `make artifacts`:

    cd python && python -m compile.aot --out ../artifacts

Outputs, per model M in the registry:
    artifacts/M.train.hlo.txt       train_step(params, x, y) -> (loss, grads)
    artifacts/M.eval.hlo.txt        eval_step(params, x, y)  -> (loss, correct)
    artifacts/M.init.npy-like       initial flat params (raw f32 little-endian)
and, for models flagged `update_artifacts` (the XLA-update ablation path):
    artifacts/M.dc.hlo.txt          dc_update(w,g,wbak,lr,lam) -> w'
    artifacts/M.dca.hlo.txt         dc_update_adaptive(...)    -> (w', ms')
    artifacts/M.sgd.hlo.txt         sgd_update(w,g,lr)         -> w'
plus a single `artifacts/manifest.json` the rust runtime loads.

Interchange format is HLO **text**: the image's xla_extension 0.5.1 rejects
jax>=0.5 serialized protos (64-bit instruction ids); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import CnnConfig, LmConfig, MlpConfig, Model
from .kernels import dc_update as upd

MANIFEST_VERSION = 2

# ---------------------------------------------------------------------------
# Model registry. Sizes are chosen for a single-CPU-core testbed; the table
# workloads (cifar_like / imagenet_like) stand in for ResNet-20/CIFAR-10 and
# ResNet-50/ImageNet per DESIGN.md §5.
# ---------------------------------------------------------------------------

REGISTRY = {
    # fast model for unit/integration tests (python and rust)
    "mlp_tiny": MlpConfig("mlp_tiny", input_dim=64, hidden=(32, 32), classes=4, batch=16),
    # CONVEX case (paper appendix D / Thm 4.1): no hidden layers ->
    # multinomial logistic regression, strongly convex with L2-ish landscape
    "logreg": MlpConfig("logreg", input_dim=256, hidden=(), classes=10, batch=32),
    # Table 1 / Fig 2 / Fig 3 workload (CIFAR-like 32x32x3, 10 classes)
    "mlp_cifar": MlpConfig("mlp_cifar", input_dim=3072, hidden=(256, 256), classes=10, batch=32),
    # Table 2 / Fig 4 workload (ImageNet-like: wider, 100 classes)
    "mlp_imagenet": MlpConfig(
        "mlp_imagenet", input_dim=3072, hidden=(512, 512), classes=100, batch=32
    ),
    # residual conv net, CIFAR-like (kept small: conv on 1 CPU core)
    "cnn_cifar": CnnConfig("cnn_cifar", image=(32, 32, 3), channels=(16, 16, 32), classes=10, batch=16),
    # LM for tests
    "lm_small": LmConfig(
        "lm_small", vocab=512, d_model=128, n_heads=4, n_layers=2, seq_len=64, batch=8
    ),
    # end-to-end driver model (examples/train_lm.rs)
    "lm_medium": LmConfig(
        "lm_medium", vocab=1024, d_model=256, n_heads=8, n_layers=4, seq_len=64, batch=8
    ),
}

# models that additionally get XLA-side update artifacts (ablation A)
UPDATE_ARTIFACTS = ("mlp_tiny", "mlp_cifar")

DEFAULT_MODELS = tuple(REGISTRY)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_fn(fn, example_args):
    return to_hlo_text(jax.jit(fn).lower(*example_args))


def emit_model(name: str, out_dir: str) -> dict:
    cfg = REGISTRY[name]
    model = Model(cfg)
    params, x, y = model.example_args()
    n_padded = model.spec.n_padded

    files = {}

    train_txt = lower_fn(model.train_step, (params, x, y))
    files["train"] = f"{name}.train.hlo.txt"
    with open(os.path.join(out_dir, files["train"]), "w") as f:
        f.write(train_txt)

    eval_txt = lower_fn(model.eval_step, (params, x, y))
    files["eval"] = f"{name}.eval.hlo.txt"
    with open(os.path.join(out_dir, files["eval"]), "w") as f:
        f.write(eval_txt)

    # initial parameters: raw little-endian f32, length n_padded
    init = model.spec.init_flat(seed=17)
    files["init"] = f"{name}.init.f32"
    init.astype("<f4").tofile(os.path.join(out_dir, files["init"]))

    if name in UPDATE_ARTIFACTS:
        vec = jax.ShapeDtypeStruct((n_padded,), jnp.float32)
        s1 = jax.ShapeDtypeStruct((1,), jnp.float32)
        files["dc"] = f"{name}.dc.hlo.txt"
        with open(os.path.join(out_dir, files["dc"]), "w") as f:
            f.write(lower_fn(lambda w, g, wb, lr, lam: (upd.dc_update(w, g, wb, lr, lam),),
                             (vec, vec, vec, s1, s1)))
        files["dca"] = f"{name}.dca.hlo.txt"
        with open(os.path.join(out_dir, files["dca"]), "w") as f:
            f.write(lower_fn(
                lambda w, g, wb, ms, lr, lam0, m, eps: upd.dc_update_adaptive(
                    w, g, wb, ms, lr, lam0, m, eps),
                (vec, vec, vec, vec, s1, s1, s1, s1)))
        files["sgd"] = f"{name}.sgd.hlo.txt"
        with open(os.path.join(out_dir, files["sgd"]), "w") as f:
            f.write(lower_fn(lambda w, g, lr: (upd.sgd_update(w, g, lr),),
                             (vec, vec, s1)))

    (xd, xs), (yd, ys) = model.input_shapes()
    return {
        "name": name,
        "kind": cfg.kind,
        "n_params": model.spec.n_params,
        "n_padded": n_padded,
        "x": {"dtype": xd, "shape": xs},
        "y": {"dtype": yd, "shape": ys},
        "batch": cfg.batch,
        "classes": getattr(cfg, "classes", getattr(cfg, "vocab", 0)),
        "tokens_per_batch": (cfg.batch * cfg.seq_len) if cfg.kind == "transformer" else cfg.batch,
        "files": files,
        "tensors": model.spec.describe(),
        "meta": model.meta(),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", nargs="*", default=list(DEFAULT_MODELS))
    args = ap.parse_args(argv)

    os.makedirs(args.out, exist_ok=True)
    entries = []
    for name in args.models:
        if name not in REGISTRY:
            print(f"unknown model {name!r}; known: {sorted(REGISTRY)}", file=sys.stderr)
            return 2
        print(f"[aot] lowering {name} ...", flush=True)
        entries.append(emit_model(name, args.out))

    manifest = {
        "version": MANIFEST_VERSION,
        "pad_multiple": upd.BLOCK,
        "models": entries,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] wrote {len(entries)} models -> {args.out}/manifest.json")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
