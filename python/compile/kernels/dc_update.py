"""Layer-1 Pallas kernels: the parameter-server update hot-spot.

The DC-ASGD update (paper Eqn. 10) is pure elementwise math over the flat
parameter vector. On a real TPU the kernel is bandwidth-bound: each grid
step streams one `(BLOCK,)` tile of each operand HBM->VMEM, runs the fused
multiply-adds on the VPU, and streams the result back — one pass, no
temporaries, bytes moved = theoretical minimum (3 reads + 1 write for the
constant-lambda rule; 4 reads + 2 writes for the adaptive rule).

TPU adaptation note (paper targeted K40 GPUs): there is no warp/shared-mem
structure to port — the HBM<->VMEM schedule expressed by the BlockSpec *is*
the whole kernel. We pick BLOCK so that all resident tiles fit comfortably
in VMEM (see `vmem_bytes`).

All kernels are lowered with interpret=True: CPU PJRT cannot execute Mosaic
custom-calls, and interpret mode lowers to plain HLO (a fori over the grid
with dynamic-slice windows) that the rust runtime executes natively.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Padding quantum for the flat parameter vector (the PS vector length is a
# multiple of this; see params.PAD_MULTIPLE).
BLOCK = 8192

# Target tile size for lowering: the largest multiple of BLOCK that divides
# n and keeps the adaptive rule's 6 resident tiles (w, g, w_bak, ms, w_out,
# ms_out) within a conservative VMEM budget. 128k f32 = 512 KiB per tile ->
# ~3 MiB resident, comfortably under a TPU core's ~16 MiB VMEM.
#
# Perf note (EXPERIMENTS.md §Perf): block size is ALSO what dominates the
# interpret-mode cost on CPU — each grid step pays a full-output
# dynamic-update-slice, so cost scales with grid *count*, not just bytes.
# Lowering mlp_cifar's 860160-long updates at block=8192 (105 grid steps)
# measured 130-266 ms/update; at block=122880 (7 steps) it drops ~10x.
BLOCK_TARGET = 128 * 1024


def pick_block(n: int, target: int = BLOCK_TARGET) -> int:
    """Largest multiple of BLOCK that divides n and is <= target.

    Falls back to BLOCK (which always divides a padded n); if n itself is
    below the target, uses n (single grid step).
    """
    assert n % BLOCK == 0, f"n={n} not padded to {BLOCK}"
    if n <= target:
        return n
    best = BLOCK
    k = n // BLOCK
    for d in range(1, k + 1):
        if k % d == 0 and d * BLOCK <= target:
            best = max(best, d * BLOCK)
    return best


def vmem_bytes(block: int, n_arrays: int, itemsize: int = 4) -> int:
    """Estimated VMEM residency for a given block size (perf model, §Perf)."""
    return block * n_arrays * itemsize


def _dc_kernel(w_ref, g_ref, wbak_ref, lr_ref, lam_ref, out_ref):
    w = w_ref[...]
    g = g_ref[...]
    delta = w - wbak_ref[...]
    lr = lr_ref[0]
    lam = lam_ref[0]
    # fused: w - lr*(g + lam*g*g*delta)
    out_ref[...] = w - lr * (g + lam * g * g * delta)


def dc_update(w, g, w_bak, lr, lam, *, block: int | None = None):
    """DC-ASGD-c update over flat f32[N] vectors; N must be a multiple of block.

    `lr`/`lam` are f32[1] so the same compiled artifact serves any
    learning-rate schedule / lambda setting at runtime.
    """
    n = w.shape[0]
    block = block or pick_block(n)
    assert n % block == 0, f"n={n} must be padded to a multiple of {block}"
    grid = n // block
    spec = pl.BlockSpec((block,), lambda i: (i,))
    scalar = pl.BlockSpec((1,), lambda i: (0,))
    return pl.pallas_call(
        _dc_kernel,
        grid=(grid,),
        in_specs=[spec, spec, spec, scalar, scalar],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), w.dtype),
        interpret=True,
    )(w, g, w_bak, lr, lam)


def _dc_adaptive_kernel(w_ref, g_ref, wbak_ref, ms_ref, lr_ref, lam0_ref,
                        m_ref, eps_ref, w_out_ref, ms_out_ref):
    w = w_ref[...]
    g = g_ref[...]
    g2 = g * g
    ms_new = m_ref[0] * ms_ref[...] + (1.0 - m_ref[0]) * g2
    lam_t = lam0_ref[0] / jnp.sqrt(ms_new + eps_ref[0])
    out = w - lr_ref[0] * (g + lam_t * g2 * (w - wbak_ref[...]))
    w_out_ref[...] = out
    ms_out_ref[...] = ms_new


def dc_update_adaptive(w, g, w_bak, ms, lr, lam0, m, eps, *, block: int | None = None):
    """DC-ASGD-a update; returns (w_new, ms_new). All vectors f32[N]."""
    n = w.shape[0]
    block = block or pick_block(n)
    assert n % block == 0, f"n={n} must be padded to a multiple of {block}"
    grid = n // block
    spec = pl.BlockSpec((block,), lambda i: (i,))
    scalar = pl.BlockSpec((1,), lambda i: (0,))
    return pl.pallas_call(
        _dc_adaptive_kernel,
        grid=(grid,),
        in_specs=[spec, spec, spec, spec, scalar, scalar, scalar, scalar],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((n,), w.dtype),
            jax.ShapeDtypeStruct((n,), w.dtype),
        ],
        interpret=True,
    )(w, g, w_bak, ms, lr, lam0, m, eps)


def _sgd_kernel(w_ref, g_ref, lr_ref, out_ref):
    out_ref[...] = w_ref[...] - lr_ref[0] * g_ref[...]


def sgd_update(w, g, lr, *, block: int | None = None):
    """Plain SGD update over flat f32[N]; the lambda=0 end of DC-ASGD."""
    n = w.shape[0]
    block = block or pick_block(n)
    assert n % block == 0
    spec = pl.BlockSpec((block,), lambda i: (i,))
    scalar = pl.BlockSpec((1,), lambda i: (0,))
    return pl.pallas_call(
        _sgd_kernel,
        grid=(n // block,),
        in_specs=[spec, spec, scalar],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), w.dtype),
        interpret=True,
    )(w, g, lr)


def _momentum_kernel(w_ref, v_ref, g_ref, lr_ref, mu_ref, w_out_ref, v_out_ref):
    v_new = mu_ref[0] * v_ref[...] + g_ref[...]
    v_out_ref[...] = v_new
    w_out_ref[...] = w_ref[...] - lr_ref[0] * v_new


def momentum_update(w, v, g, lr, mu, *, block: int | None = None):
    """Heavy-ball momentum update; returns (w_new, v_new)."""
    n = w.shape[0]
    block = block or pick_block(n)
    assert n % block == 0
    spec = pl.BlockSpec((block,), lambda i: (i,))
    scalar = pl.BlockSpec((1,), lambda i: (0,))
    return pl.pallas_call(
        _momentum_kernel,
        grid=(n // block,),
        in_specs=[spec, spec, spec, scalar, scalar],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((n,), w.dtype),
            jax.ShapeDtypeStruct((n,), w.dtype),
        ],
        interpret=True,
    )(w, v, g, lr, mu)
