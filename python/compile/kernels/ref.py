"""Pure-jnp oracles for every Pallas kernel in this package.

These are the CORE correctness signal: pytest asserts each Pallas kernel
(interpret=True) against the corresponding function here, and hypothesis
sweeps shapes/dtypes. Keep these boring and obviously-correct.

Notation follows the paper (ICML'17 DC-ASGD):

    w_{t+tau+1} = w_{t+tau} - eta * ( g + lambda * g (.) g (.) (w - w_bak) )

where `w` is the *current* global model, `w_bak` the snapshot the worker
pulled (Algorithm 2), and (.) is the elementwise product.
"""

from __future__ import annotations

import jax.numpy as jnp


def sgd_update_ref(w, g, lr):
    """Plain SGD: w' = w - lr * g."""
    return w - lr * g


def momentum_update_ref(w, v, g, lr, mu):
    """Heavy-ball momentum: v' = mu*v + g ; w' = w - lr*v'."""
    v_new = mu * v + g
    return w - lr * v_new, v_new


def dc_update_ref(w, g, w_bak, lr, lam):
    """DC-ASGD-c (Eqn. 10): constant-lambda delay-compensated update.

    The compensation term lambda * g*g * (w - w_bak) is the first-order
    Taylor correction with Diag(lambda * G) as the Hessian approximator.
    """
    comp = g + lam * g * g * (w - w_bak)
    return w - lr * comp


def dc_update_adaptive_ref(w, g, w_bak, ms, lr, lam0, m, eps=1e-7):
    """DC-ASGD-a (Eqn. 10 + Eqn. 14): lambda normalized by MeanSquare.

    MeanSquare(t) = m * MeanSquare(t-1) + (1-m) * g^2
    lambda_t      = lam0 / sqrt(MeanSquare(t) + eps)       (elementwise)
    """
    ms_new = m * ms + (1.0 - m) * g * g
    lam_t = lam0 / jnp.sqrt(ms_new + eps)
    comp = g + lam_t * g * g * (w - w_bak)
    return w - lr * comp, ms_new


def softmax_xent_ref(logits, labels):
    """Per-row softmax cross-entropy. logits [B,K] f32, labels [B] i32."""
    m = jnp.max(logits, axis=-1, keepdims=True)
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    picked = jnp.take_along_axis(logits, labels[:, None].astype(jnp.int32), axis=-1)[:, 0]
    return lse - picked


def softmax_xent_grad_ref(logits, labels, dloss):
    """d/dlogits of softmax_xent_ref, contracted with dloss [B]."""
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)
    onehot = jnp.asarray(labels[:, None] == jnp.arange(logits.shape[-1])[None, :], logits.dtype)
    return (probs - onehot) * dloss[:, None]
