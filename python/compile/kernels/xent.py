"""Layer-1 Pallas kernel: fused softmax cross-entropy (fwd + bwd).

The model-side hot-spot. One grid step owns a `(BLOCK_B, K)` tile of logits
resident in VMEM and performs max / exp / sum / log / pick in a single pass
(row reductions on the VPU — the TPU analogue of the warp-reduction a GPU
kernel would use). The backward kernel recomputes nothing: it consumes the
softmax probabilities saved as residuals by the forward pass.

Wrapped in `jax.custom_vjp` so the Layer-2 models can differentiate through
it; both branches are Pallas kernels, so the whole loss lowers into the same
HLO artifact the rust runtime executes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per grid step. A (128, K) f32 tile at K=2048 is 1 MiB — comfortably
# VMEM-resident next to its probs output tile.
BLOCK_B = 128


def _xent_fwd_kernel(logits_ref, labels_ref, loss_ref, probs_ref):
    logits = logits_ref[...]              # [Bb, K]
    labels = labels_ref[...]              # [Bb]
    k = logits.shape[-1]
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    s = jnp.sum(e, axis=-1, keepdims=True)
    probs = e / s
    probs_ref[...] = probs
    lse = jnp.log(s[:, 0]) + m[:, 0]
    # pick logits[i, labels[i]] without gather: iota + where-sum
    cols = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    onehot = cols == labels[:, None]
    picked = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    loss_ref[...] = lse - picked
    del k


def _xent_bwd_kernel(probs_ref, labels_ref, dloss_ref, dlogits_ref):
    probs = probs_ref[...]
    labels = labels_ref[...]
    dloss = dloss_ref[...]
    cols = jax.lax.broadcasted_iota(jnp.int32, probs.shape, 1)
    onehot = jnp.where(cols == labels[:, None], 1.0, 0.0).astype(probs.dtype)
    dlogits_ref[...] = (probs - onehot) * dloss[:, None]


def _fwd_call(logits, labels, block_b):
    b, k = logits.shape
    assert b % block_b == 0, f"batch {b} must be a multiple of {block_b}"
    grid = b // block_b
    row = pl.BlockSpec((block_b, k), lambda i: (i, 0))
    vec = pl.BlockSpec((block_b,), lambda i: (i,))
    return pl.pallas_call(
        _xent_fwd_kernel,
        grid=(grid,),
        in_specs=[row, vec],
        out_specs=[vec, row],
        out_shape=[
            jax.ShapeDtypeStruct((b,), logits.dtype),
            jax.ShapeDtypeStruct((b, k), logits.dtype),
        ],
        interpret=True,
    )(logits, labels)


def _bwd_call(probs, labels, dloss, block_b):
    b, k = probs.shape
    grid = b // block_b
    row = pl.BlockSpec((block_b, k), lambda i: (i, 0))
    vec = pl.BlockSpec((block_b,), lambda i: (i,))
    return pl.pallas_call(
        _xent_bwd_kernel,
        grid=(grid,),
        in_specs=[row, vec, vec],
        out_specs=row,
        out_shape=jax.ShapeDtypeStruct((b, k), probs.dtype),
        interpret=True,
    )(probs, labels, dloss)


def _pick_block(b: int) -> int:
    """Largest divisor of b not exceeding BLOCK_B (batch sizes are small)."""
    blk = min(b, BLOCK_B)
    while b % blk != 0:
        blk -= 1
    return blk


@jax.custom_vjp
def softmax_xent(logits, labels):
    """Per-row softmax cross-entropy loss. logits [B,K] f32, labels [B] i32."""
    loss, _ = _fwd_call(logits, labels, _pick_block(logits.shape[0]))
    return loss


def _softmax_xent_fwd(logits, labels):
    loss, probs = _fwd_call(logits, labels, _pick_block(logits.shape[0]))
    return loss, (probs, labels)


def _softmax_xent_bwd(res, dloss):
    probs, labels = res
    dlogits = _bwd_call(probs, labels, dloss, _pick_block(probs.shape[0]))
    return dlogits, None


softmax_xent.defvjp(_softmax_xent_fwd, _softmax_xent_bwd)
