"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes and value regimes; fixed-seed cases pin exact
paper-relevant behaviours (lambda=0 degenerates to ASGD, etc.).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dc_update as K
from compile.kernels import ref as R
from compile.kernels.xent import softmax_xent, _fwd_call, _bwd_call, _pick_block

ATOL = 2e-5
RTOL = 2e-5


def vecs(seed, n, scale=1.0, count=1):
    rng = np.random.default_rng(seed)
    out = [jnp.asarray(rng.normal(0, scale, n).astype(np.float32)) for _ in range(count)]
    return out if count > 1 else out[0]


# ---------------------------------------------------------------- dc_update


class TestDcUpdate:
    def test_matches_ref(self):
        n = 4 * K.BLOCK
        w, g, wb = vecs(0, n, count=3)
        out = K.dc_update(w, g, wb, jnp.array([0.1]), jnp.array([0.04]))
        ref = R.dc_update_ref(w, g, wb, 0.1, 0.04)
        np.testing.assert_allclose(out, ref, atol=ATOL, rtol=RTOL)

    def test_lambda_zero_is_plain_sgd(self):
        """DC-ASGD with lambda=0 must be exactly ASGD's plain update."""
        n = K.BLOCK
        w, g, wb = vecs(1, n, count=3)
        out = K.dc_update(w, g, wb, jnp.array([0.5]), jnp.array([0.0]))
        np.testing.assert_allclose(out, R.sgd_update_ref(w, g, 0.5), atol=ATOL, rtol=RTOL)

    def test_no_delay_no_compensation(self):
        """w == w_bak (tau=0) => compensation term vanishes for any lambda."""
        n = K.BLOCK
        w, g = vecs(2, n, count=2)
        out = K.dc_update(w, g, w, jnp.array([0.3]), jnp.array([2.0]))
        np.testing.assert_allclose(out, R.sgd_update_ref(w, g, 0.3), atol=ATOL, rtol=RTOL)

    @settings(max_examples=20, deadline=None)
    @given(
        blocks=st.integers(1, 4),
        block=st.sampled_from([128, 256, 1024]),
        lr=st.floats(1e-4, 1.0),
        lam=st.floats(0.0, 4.0),
        scale=st.floats(0.01, 10.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_property_sweep(self, blocks, block, lr, lam, scale, seed):
        n = blocks * block
        w, g, wb = vecs(seed, n, scale=scale, count=3)
        out = K.dc_update(w, g, wb, jnp.array([lr], jnp.float32),
                          jnp.array([lam], jnp.float32), block=block)
        ref = R.dc_update_ref(w, g, wb, np.float32(lr), np.float32(lam))
        np.testing.assert_allclose(out, ref, atol=1e-3 * max(1.0, scale**3), rtol=1e-4)

    def test_rejects_unpadded(self):
        with pytest.raises(AssertionError):
            K.dc_update(*vecs(3, K.BLOCK + 1, count=3), jnp.array([0.1]), jnp.array([0.1]))


# ------------------------------------------------------- dc_update_adaptive


class TestDcUpdateAdaptive:
    def test_matches_ref(self):
        n = 2 * K.BLOCK
        w, g, wb = vecs(4, n, count=3)
        ms = jnp.abs(vecs(5, n))
        args = (jnp.array([0.1]), jnp.array([2.0]), jnp.array([0.95]), jnp.array([1e-7]))
        w2, ms2 = K.dc_update_adaptive(w, g, wb, ms, *args)
        rw, rms = R.dc_update_adaptive_ref(w, g, wb, ms, 0.1, 2.0, 0.95)
        np.testing.assert_allclose(w2, rw, atol=ATOL, rtol=RTOL)
        np.testing.assert_allclose(ms2, rms, atol=ATOL, rtol=RTOL)

    def test_meansquare_recursion(self):
        """MeanSquare(t) = m*MeanSquare(t-1) + (1-m)*g^2 (Eqn. 14), iterated."""
        n = K.BLOCK
        w, wb = vecs(6, n, count=2)
        ms = jnp.zeros(n)
        m = 0.9
        for step in range(3):
            g = vecs(100 + step, n)
            _, ms = K.dc_update_adaptive(
                w, g, wb, ms, jnp.array([0.1]), jnp.array([1.0]),
                jnp.array([m], jnp.float32), jnp.array([1e-7]))
        # closed form over the three gradients
        expect = jnp.zeros(n)
        for step in range(3):
            g = vecs(100 + step, n)
            expect = m * expect + (1 - m) * g * g
        np.testing.assert_allclose(ms, expect, atol=ATOL, rtol=RTOL)

    def test_m_zero_is_instant_normalization(self):
        """m=0: lambda_t = lam0/|g| elementwise (the ImageNet setting m=0)."""
        n = K.BLOCK
        w, g, wb = vecs(7, n, count=3)
        w2, ms2 = K.dc_update_adaptive(
            w, g, wb, jnp.ones(n) * 123.0, jnp.array([0.1]), jnp.array([2.0]),
            jnp.array([0.0]), jnp.array([0.0]))
        lam_t = 2.0 / jnp.abs(g)
        ref = w - 0.1 * (g + lam_t * g * g * (w - wb))
        np.testing.assert_allclose(w2, ref, atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(ms2, g * g, atol=ATOL, rtol=RTOL)

    @settings(max_examples=15, deadline=None)
    @given(
        block=st.sampled_from([128, 512]),
        m=st.floats(0.0, 0.999),
        lam0=st.floats(0.0, 4.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_property_sweep(self, block, m, lam0, seed):
        n = 2 * block
        w, g, wb = vecs(seed, n, count=3)
        ms = jnp.abs(vecs(seed + 1, n))
        w2, ms2 = K.dc_update_adaptive(
            w, g, wb, ms, jnp.array([0.05]), jnp.array([lam0], jnp.float32),
            jnp.array([m], jnp.float32), jnp.array([1e-7]), block=block)
        rw, rms = R.dc_update_adaptive_ref(w, g, wb, ms, 0.05,
                                           np.float32(lam0), np.float32(m))
        np.testing.assert_allclose(w2, rw, atol=1e-4, rtol=1e-3)
        np.testing.assert_allclose(ms2, rms, atol=1e-5, rtol=1e-4)


# ----------------------------------------------------------- sgd / momentum


class TestSgdMomentum:
    def test_sgd_matches_ref(self):
        n = 2 * K.BLOCK
        w, g = vecs(8, n, count=2)
        out = K.sgd_update(w, g, jnp.array([0.25]))
        np.testing.assert_allclose(out, R.sgd_update_ref(w, g, 0.25), atol=ATOL, rtol=RTOL)

    def test_momentum_matches_ref(self):
        n = K.BLOCK
        w, v, g = vecs(9, n, count=3)
        w2, v2 = K.momentum_update(w, v, g, jnp.array([0.1]), jnp.array([0.9]))
        rw, rv = R.momentum_update_ref(w, v, g, 0.1, 0.9)
        np.testing.assert_allclose(w2, rw, atol=ATOL, rtol=RTOL)
        np.testing.assert_allclose(v2, rv, atol=ATOL, rtol=RTOL)

    def test_momentum_mu_zero_is_sgd(self):
        n = K.BLOCK
        w, g = vecs(10, n, count=2)
        w2, v2 = K.momentum_update(w, jnp.zeros(n) + 7.0, g, jnp.array([0.1]),
                                   jnp.array([0.0]))
        np.testing.assert_allclose(w2, R.sgd_update_ref(w, g, 0.1), atol=ATOL, rtol=RTOL)
        np.testing.assert_allclose(v2, g, atol=ATOL, rtol=RTOL)


# ------------------------------------------------------------------- xent


class TestXent:
    def test_forward_matches_ref(self):
        rng = np.random.default_rng(11)
        logits = jnp.asarray(rng.normal(0, 3, (256, 17)).astype(np.float32))
        labels = jnp.asarray(rng.integers(0, 17, 256).astype(np.int32))
        np.testing.assert_allclose(
            softmax_xent(logits, labels), R.softmax_xent_ref(logits, labels),
            atol=1e-5, rtol=1e-5)

    def test_grad_matches_ref(self):
        rng = np.random.default_rng(12)
        logits = jnp.asarray(rng.normal(0, 2, (64, 10)).astype(np.float32))
        labels = jnp.asarray(rng.integers(0, 10, 64).astype(np.int32))
        gk = jax.grad(lambda l: softmax_xent(l, labels).mean())(logits)
        gr = jax.grad(lambda l: R.softmax_xent_ref(l, labels).mean())(logits)
        np.testing.assert_allclose(gk, gr, atol=1e-6, rtol=1e-5)

    def test_large_logits_stable(self):
        """Row-max subtraction keeps the kernel finite at |logit|~1e4."""
        logits = jnp.asarray([[1e4, -1e4, 0.0], [5e3, 5e3, 5e3]], jnp.float32)
        labels = jnp.asarray([0, 2], jnp.int32)
        loss = softmax_xent(logits, labels)
        assert np.isfinite(np.asarray(loss)).all()
        # f32 cancellation at |logit|=5e3 costs ~1e-4 absolute; the point of
        # the test is finiteness + correct value, not ulp-accuracy.
        np.testing.assert_allclose(loss[0], 0.0, atol=1e-3)
        np.testing.assert_allclose(loss[1], np.log(3.0), atol=1e-3)

    def test_grad_rows_sum_to_zero(self):
        """softmax-CE gradient rows sum to 0 (probs sum 1, one-hot sums 1)."""
        rng = np.random.default_rng(13)
        logits = jnp.asarray(rng.normal(0, 1, (32, 8)).astype(np.float32))
        labels = jnp.asarray(rng.integers(0, 8, 32).astype(np.int32))
        g = jax.grad(lambda l: softmax_xent(l, labels).sum())(logits)
        np.testing.assert_allclose(jnp.sum(g, axis=-1), jnp.zeros(32), atol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(
        b=st.sampled_from([1, 2, 8, 32, 96, 128, 256]),
        k=st.integers(2, 64),
        scale=st.floats(0.1, 30.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_property_sweep(self, b, k, scale, seed):
        rng = np.random.default_rng(seed)
        logits = jnp.asarray(rng.normal(0, scale, (b, k)).astype(np.float32))
        labels = jnp.asarray(rng.integers(0, k, b).astype(np.int32))
        np.testing.assert_allclose(
            softmax_xent(logits, labels), R.softmax_xent_ref(logits, labels),
            atol=1e-4, rtol=1e-4)

    def test_pick_block_divides(self):
        for b in [1, 2, 7, 128, 129, 384, 1000]:
            blk = _pick_block(b)
            assert b % blk == 0 and 1 <= blk <= 128

    def test_bwd_kernel_direct(self):
        rng = np.random.default_rng(14)
        logits = jnp.asarray(rng.normal(0, 1, (16, 5)).astype(np.float32))
        labels = jnp.asarray(rng.integers(0, 5, 16).astype(np.int32))
        dloss = jnp.asarray(rng.normal(0, 1, 16).astype(np.float32))
        _, probs = _fwd_call(logits, labels, 16)
        dl = _bwd_call(probs, labels, dloss, 16)
        np.testing.assert_allclose(
            dl, R.softmax_xent_grad_ref(logits, labels, dloss), atol=1e-5, rtol=1e-5)


# --------------------------------------------- DC vs true-gradient property


class TestDelayCompensationProperty:
    """The headline claim, in miniature: on a quadratic (where g(w)g(w)^T has
    the right scale), the DC gradient approximates g(w_{t+tau}) strictly
    better than the delayed gradient g(w_t) that ASGD uses."""

    def test_dc_closer_than_delayed_on_logreg(self):
        rng = np.random.default_rng(15)
        d, b = 16, 256
        x = jnp.asarray(rng.normal(0, 1, (b, d)).astype(np.float32))
        yl = jnp.asarray(rng.integers(0, 2, b).astype(np.int32))

        def loss(w):
            logits = jnp.stack([jnp.zeros(b), x @ w], axis=1)
            return R.softmax_xent_ref(logits, yl).mean()

        gfun = jax.grad(loss)
        w_t = jnp.asarray(rng.normal(0, 0.3, d).astype(np.float32))
        delta = jnp.asarray(rng.normal(0, 0.05, d).astype(np.float32))
        w_tau = w_t + delta
        g_true = gfun(w_tau)
        g_delayed = gfun(w_t)
        # paper's approximator: g + lam*g*g*(w_tau - w_t), lam ~ 1
        g_dc = g_delayed + 1.0 * g_delayed * g_delayed * delta
        err_delayed = float(jnp.linalg.norm(g_delayed - g_true))
        err_dc = float(jnp.linalg.norm(g_dc - g_true))
        # With the diagonal outer-product approximator the correction must
        # not hurt; on this well-conditioned task it strictly helps.
        assert err_dc < err_delayed


class TestPickBlock:
    def test_divides_and_bounded(self):
        for k in [1, 3, 7, 105, 128, 231]:
            n = k * K.BLOCK
            blk = K.pick_block(n)
            assert n % blk == 0
            assert blk <= max(K.BLOCK_TARGET, K.BLOCK) or blk == n
            assert blk % K.BLOCK == 0

    def test_small_n_single_grid_step(self):
        assert K.pick_block(K.BLOCK) == K.BLOCK
        assert K.pick_block(8 * K.BLOCK) == 8 * K.BLOCK  # 64k <= target

    def test_mlp_cifar_case(self):
        # 860160 = 105 * 8192; largest divisor <= 128k is 15*8192 = 122880
        assert K.pick_block(860160) == 122880

    def test_rejects_unpadded(self):
        with pytest.raises(AssertionError):
            K.pick_block(K.BLOCK + 1)

    def test_kernel_output_block_invariant(self):
        n = 4 * K.BLOCK
        w, g, wb = vecs(21, n, count=3)
        a = K.dc_update(w, g, wb, jnp.array([0.1]), jnp.array([0.5]), block=K.BLOCK)
        b = K.dc_update(w, g, wb, jnp.array([0.1]), jnp.array([0.5]), block=n)
        np.testing.assert_allclose(a, b, atol=1e-6, rtol=1e-6)
