"""ParamSpec packing invariants."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.params import PAD_MULTIPLE, ParamSpec


def make_spec(shapes):
    spec = ParamSpec()
    for i, s in enumerate(shapes):
        spec.add(f"t{i}", s)
    return spec


class TestParamSpec:
    def test_sizes_and_padding(self):
        spec = make_spec([(3, 4), (7,), ()])
        assert spec.n_params == 12 + 7 + 1
        assert spec.n_padded == PAD_MULTIPLE
        assert spec.n_padded % PAD_MULTIPLE == 0

    def test_offsets_are_contiguous(self):
        spec = make_spec([(2, 2), (5,), (3, 1)])
        offs = spec.offsets()
        assert offs == {"t0": 0, "t1": 4, "t2": 9}

    def test_unpack_roundtrip(self):
        spec = make_spec([(4, 3), (6,)])
        flat = jnp.arange(spec.n_padded, dtype=jnp.float32)
        p = spec.unpack(flat)
        np.testing.assert_array_equal(p["t0"], jnp.arange(12.0).reshape(4, 3))
        np.testing.assert_array_equal(p["t1"], jnp.arange(12.0, 18.0))

    def test_duplicate_name_rejected(self):
        spec = ParamSpec()
        spec.add("w", (2,))
        with pytest.raises(ValueError):
            spec.add("w", (3,))

    def test_init_flat_padding_is_zero(self):
        spec = make_spec([(10, 10)])
        flat = spec.init_flat(seed=3)
        assert flat.shape == (spec.n_padded,)
        assert np.all(flat[spec.n_params:] == 0.0)
        assert flat[: spec.n_params].std() > 0

    def test_init_deterministic(self):
        spec = make_spec([(32, 16)])
        np.testing.assert_array_equal(spec.init_flat(seed=9), spec.init_flat(seed=9))
        assert not np.array_equal(spec.init_flat(seed=9), spec.init_flat(seed=10))

    def test_zeros_ones_init(self):
        spec = ParamSpec()
        spec.add("b", (5,), "zeros")
        spec.add("g", (5,), "ones")
        flat = spec.init_flat()
        np.testing.assert_array_equal(flat[:5], np.zeros(5))
        np.testing.assert_array_equal(flat[5:10], np.ones(5))

    def test_describe_matches_offsets(self):
        spec = make_spec([(2, 3), (4,)])
        desc = spec.describe()
        assert desc[0] == {"name": "t0", "shape": [2, 3], "offset": 0, "size": 6}
        assert desc[1] == {"name": "t1", "shape": [4], "offset": 6, "size": 4}

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.tuples(st.integers(1, 8), st.integers(1, 8)), min_size=1, max_size=6))
    def test_property_total_size(self, shapes):
        spec = make_spec(shapes)
        assert spec.n_params == sum(a * b for a, b in shapes)
        assert 0 <= spec.n_padded - spec.n_params < PAD_MULTIPLE
        flat = jnp.arange(spec.n_padded, dtype=jnp.float32)
        p = spec.unpack(flat)
        # unpacked tensors tile the prefix exactly
        total = np.concatenate([np.asarray(v).reshape(-1) for v in p.values()])
        np.testing.assert_array_equal(total, np.arange(spec.n_params, dtype=np.float32))
