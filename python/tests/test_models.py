"""Layer-2 model shape/gradient checks, plus gradcheck vs finite differences."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import CnnConfig, LmConfig, MlpConfig, Model

TINY_MLP = MlpConfig("t_mlp", input_dim=12, hidden=(8, 8), classes=3, batch=4)
TINY_CNN = CnnConfig("t_cnn", image=(8, 8, 3), channels=(4, 4, 8), classes=3, batch=2)
TINY_LM = LmConfig("t_lm", vocab=32, d_model=16, n_heads=2, n_layers=2, seq_len=8, batch=2)


def batch_for(model, seed=0):
    rng = np.random.default_rng(seed)
    cfg = model.cfg
    if cfg.kind == "transformer":
        x = jnp.asarray(rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq_len)).astype(np.int32))
        y = jnp.asarray(rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq_len)).astype(np.int32))
    else:
        x = jnp.asarray(rng.normal(0, 1, (cfg.batch, cfg.input_dim)).astype(np.float32))
        y = jnp.asarray(rng.integers(0, cfg.classes, cfg.batch).astype(np.int32))
    return x, y


@pytest.mark.parametrize("cfg", [TINY_MLP, TINY_CNN, TINY_LM], ids=lambda c: c.kind)
class TestModelShapes:
    def test_train_step_shapes(self, cfg):
        model = Model(cfg)
        flat = jnp.asarray(model.spec.init_flat(seed=1))
        x, y = batch_for(model)
        loss, grads = model.train_step(flat, x, y)
        assert loss.shape == ()
        assert grads.shape == (model.spec.n_padded,)
        assert np.isfinite(float(loss))
        assert np.isfinite(np.asarray(grads)).all()

    def test_padding_tail_gradient_is_zero(self, cfg):
        model = Model(cfg)
        flat = jnp.asarray(model.spec.init_flat(seed=1))
        x, y = batch_for(model)
        _, grads = model.train_step(flat, x, y)
        tail = np.asarray(grads[model.spec.n_params:])
        np.testing.assert_array_equal(tail, np.zeros_like(tail))

    def test_eval_step(self, cfg):
        model = Model(cfg)
        flat = jnp.asarray(model.spec.init_flat(seed=1))
        x, y = batch_for(model)
        loss, correct = model.eval_step(flat, x, y)
        n_rows = y.size
        assert 0.0 <= float(correct) <= n_rows
        assert float(correct) == int(float(correct))  # a count
        assert np.isfinite(float(loss))

    def test_loss_decreases_under_gd(self, cfg):
        """A few full-batch GD steps must reduce the loss (sanity of bwd)."""
        model = Model(cfg)
        flat = jnp.asarray(model.spec.init_flat(seed=2))
        x, y = batch_for(model)
        step = jax.jit(model.train_step)
        loss0, g = step(flat, x, y)
        lr = 0.1 if cfg.kind != "transformer" else 0.5
        for _ in range(5):
            flat = flat - lr * g
            loss, g = step(flat, x, y)
        assert float(loss) < float(loss0)


class TestGradcheck:
    def test_mlp_grad_vs_finite_difference(self):
        model = Model(TINY_MLP)
        flat = jnp.asarray(model.spec.init_flat(seed=3))
        x, y = batch_for(model, seed=3)
        _, grads = model.train_step(flat, x, y)
        rng = np.random.default_rng(0)
        idx = rng.choice(model.spec.n_params, size=12, replace=False)
        eps = 1e-3
        for i in idx:
            e = np.zeros(model.spec.n_padded, np.float32)
            e[i] = eps
            lp = float(model.loss_fn(flat + e, x, y))
            lm = float(model.loss_fn(flat - e, x, y))
            fd = (lp - lm) / (2 * eps)
            assert abs(fd - float(grads[i])) < 5e-3, f"param {i}: fd={fd} ad={grads[i]}"

    def test_lm_grad_directional_derivative(self):
        """Per-coordinate fd through two attention layers is dominated by
        curvature + f32 noise, so check the *directional* derivative along
        random directions instead (aggregates thousands of coordinates)."""
        model = Model(TINY_LM)
        flat = jnp.asarray(model.spec.init_flat(seed=4))
        x, y = batch_for(model, seed=4)
        _, grads = model.train_step(flat, x, y)
        rng = np.random.default_rng(1)
        eps = 3e-4
        for trial in range(4):
            v = rng.normal(0, 1, model.spec.n_padded).astype(np.float32)
            v[model.spec.n_params:] = 0.0
            v /= np.linalg.norm(v)
            vj = jnp.asarray(v)
            lp = float(model.loss_fn(flat + eps * vj, x, y))
            lm = float(model.loss_fn(flat - eps * vj, x, y))
            fd = (lp - lm) / (2 * eps)
            ad = float(jnp.dot(grads, vj))
            assert abs(fd - ad) < 0.05 * max(1.0, abs(ad)), f"trial {trial}: fd={fd} ad={ad}"


class TestLmDetails:
    def test_causality(self):
        """Changing a future token must not affect earlier-position logits."""
        model = Model(TINY_LM)
        flat = jnp.asarray(model.spec.init_flat(seed=5))
        cfg = TINY_LM
        rng = np.random.default_rng(2)
        x = rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq_len)).astype(np.int32)
        x2 = x.copy()
        x2[:, -1] = (x2[:, -1] + 1) % cfg.vocab
        from compile.model import lm_logits
        p = model.spec.unpack(jnp.asarray(flat))
        l1 = np.asarray(lm_logits(cfg, p, jnp.asarray(x))).reshape(cfg.batch, cfg.seq_len, -1)
        l2 = np.asarray(lm_logits(cfg, p, jnp.asarray(x2))).reshape(cfg.batch, cfg.seq_len, -1)
        np.testing.assert_allclose(l1[:, :-1], l2[:, :-1], atol=1e-5)
        assert np.abs(l1[:, -1] - l2[:, -1]).max() > 1e-6

    def test_initial_loss_near_uniform(self):
        """Fresh init: LM loss ~ log(V) (softmax near-uniform)."""
        model = Model(TINY_LM)
        flat = jnp.asarray(model.spec.init_flat(seed=6))
        x, y = batch_for(model, seed=6)
        loss = float(model.loss_fn(flat, x, y))
        assert abs(loss - np.log(TINY_LM.vocab)) < 0.5


class TestRegistryConfigs:
    def test_registry_specs_build(self):
        from compile.aot import REGISTRY
        for name, cfg in REGISTRY.items():
            model = Model(cfg)
            assert model.spec.n_params > 0
            assert model.spec.n_padded % 8192 == 0, name

    def test_example_args_match_input_shapes(self):
        from compile.aot import REGISTRY
        for cfg in REGISTRY.values():
            model = Model(cfg)
            params, x, y = model.example_args()
            (xd, xs), (yd, ys) = model.input_shapes()
            assert list(x.shape) == xs and list(y.shape) == ys
            assert params.shape == (model.spec.n_padded,)
