//! ASGD / DC-ASGD: the asynchronous loop, in two executions:
//!
//! * [`run_sim`] — discrete-event simulation. Worker finish events pop in
//!   virtual-time order; gradients are computed for real on the snapshot
//!   each worker pulled, so delayed-gradient staleness arises exactly as it
//!   would on a cluster, but deterministically. This is the mode behind the
//!   wallclock figures.
//! * [`run_threads`] — real OS threads racing on the sharded parameter
//!   server (lock contention and interleavings are physical; staleness is
//!   nondeterministic). Used by ablation benches and as a sanity check that
//!   the simulator matches reality in distribution.
//!
//! In both, a worker's cycle is Algorithm 1 verbatim: pull -> compute
//! gradient -> push; the server applies Algorithm 2's update rule.

use super::RunCtx;
use crate::data::{EpochPartition, ShardCursor};
use crate::metrics::StepRecord;
use crate::sim::{DelaySampler, EventQueue};
use anyhow::Result;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

/// Server-side cost per update in simulated seconds, as a fraction of the
/// mean worker compute time. The paper reports the DC compensation is a
/// "lightweight overhead" on the server; we charge it explicitly (and
/// double it for DC rules) so the wallclock comparison is honest.
const SERVER_COST_FRAC: f64 = 0.01;

fn mean_delay(cfg: &crate::config::ExperimentConfig) -> f64 {
    match &cfg.delay {
        crate::config::DelayModel::Constant { mean }
        | crate::config::DelayModel::Uniform { mean, .. }
        | crate::config::DelayModel::Exponential { mean }
        | crate::config::DelayModel::Heterogeneous { mean, .. } => *mean,
        crate::config::DelayModel::Pareto { scale, alpha } => {
            if *alpha > 1.0 {
                scale * alpha / (alpha - 1.0)
            } else {
                *scale
            }
        }
    }
}

pub fn run_sim(ctx: &mut RunCtx) -> Result<()> {
    let m = ctx.cfg.workers;
    let n = ctx.ps.n();
    let partition = EpochPartition::new(ctx.cfg.seed ^ 0x5EED, ctx.train_set.len(), m);
    let mut cursors: Vec<ShardCursor> =
        (0..m).map(|w| ShardCursor::new(partition.clone(), w, ctx.batch_size)).collect();
    let mut delays = DelaySampler::new(ctx.cfg.delay.clone(), m, ctx.cfg.seed);
    let server_cost = SERVER_COST_FRAC
        * mean_delay(&ctx.cfg)
        * if ctx.cfg.algorithm.is_delay_compensated() { 2.0 } else { 1.0 };

    let mut snapshots: Vec<Vec<f32>> = vec![vec![0.0f32; n]; m];
    let mut queue: EventQueue<usize> = EventQueue::new();
    for w in 0..m {
        ctx.ps.pull(w, &mut snapshots[w]);
        queue.schedule_in(delays.sample(w), w);
    }

    let mut step = 0u64;
    let mut samples = 0u64;
    let mut prev_passes = 0.0f64;

    while let Some((t, w)) = queue.pop() {
        let passes = samples as f64 / ctx.train_set.len() as f64;
        if ctx.done(step, passes) {
            break;
        }
        let lr = ctx.lr_at(passes);
        let batch = ctx.train_set.make_batch(&cursors[w].next_indices());
        // the gradient is computed on the (stale) snapshot worker w pulled
        let (loss, grads) = ctx.engine.train(&snapshots[w], &batch)?;
        let outcome = ctx.ps.push(w, &grads, lr);
        samples += ctx.batch_size as u64;
        step += 1;
        let passes_now = samples as f64 / ctx.train_set.len() as f64;
        ctx.metrics.record_step(StepRecord {
            step: step - 1,
            worker: w,
            passes: passes_now,
            time: t,
            loss,
            lr,
            staleness: outcome.staleness,
        });
        if ctx.should_eval(prev_passes, passes_now, step) {
            ctx.run_eval(step, passes_now, t)?;
        }
        prev_passes = passes_now;
        // pull the fresh model and start the next gradient
        ctx.ps.pull(w, &mut snapshots[w]);
        queue.schedule_in(server_cost + delays.sample(w), w);
    }
    Ok(())
}

pub fn run_threads(ctx: &mut RunCtx) -> Result<()> {
    let m = ctx.cfg.workers;
    let n = ctx.ps.n();
    let partition = EpochPartition::new(ctx.cfg.seed ^ 0x5EED, ctx.train_set.len(), m);
    let stop = AtomicBool::new(false);
    let samples = AtomicU64::new(0);
    let steps = AtomicU64::new(0);
    let records: Mutex<Vec<StepRecord>> = Mutex::new(Vec::new());
    let wall_start = std::time::Instant::now();
    let train_len = ctx.train_set.len() as f64;
    let first_err: Mutex<Option<anyhow::Error>> = Mutex::new(None);

    // clone what workers need so `ctx` stays exclusively borrowable for the
    // in-flight evals below
    let cfg = ctx.cfg.clone();
    let batch_size = ctx.batch_size;

    std::thread::scope(|scope| {
        for w in 0..m {
            let ps = ctx.ps.clone();
            let engine = ctx.engine.clone();
            let train_set = ctx.train_set.clone();
            let cfg = cfg.clone();
            let partition = partition.clone();
            let (stop, samples, steps, records, first_err) =
                (&stop, &samples, &steps, &records, &first_err);
            scope.spawn(move || {
                let mut cursor = ShardCursor::new(partition, w, batch_size);
                let mut params = vec![0.0f32; n];
                while !stop.load(Ordering::Relaxed) {
                    ps.pull(w, &mut params);
                    let batch = train_set.make_batch(&cursor.next_indices());
                    let passes = samples.load(Ordering::Relaxed) as f64 / train_len;
                    let lr = cfg.lr.lr_at_epoch(passes.floor().max(0.0) as usize) as f32;
                    match engine.train(&params, &batch) {
                        Ok((loss, grads)) => {
                            let outcome = ps.push(w, &grads, lr);
                            let s = samples.fetch_add(batch_size as u64, Ordering::Relaxed)
                                + batch_size as u64;
                            let step = steps.fetch_add(1, Ordering::Relaxed);
                            let passes_now = s as f64 / train_len;
                            records.lock().unwrap().push(StepRecord {
                                step,
                                worker: w,
                                passes: passes_now,
                                time: wall_start.elapsed().as_secs_f64(),
                                loss,
                                lr,
                                staleness: outcome.staleness,
                            });
                            let done_steps =
                                cfg.max_steps > 0 && step + 1 >= cfg.max_steps as u64;
                            let done_passes = cfg.max_steps == 0
                                && cfg.epochs > 0
                                && passes_now >= cfg.epochs as f64;
                            if done_steps || done_passes {
                                stop.store(true, Ordering::Relaxed);
                            }
                        }
                        Err(e) => {
                            let mut slot = first_err.lock().unwrap();
                            if slot.is_none() {
                                *slot = Some(e);
                            }
                            stop.store(true, Ordering::Relaxed);
                        }
                    }
                }
            });
        }

        // monitor: run inline evals at epoch boundaries while workers race.
        // The engine serializes execution, so evals interleave safely.
        let mut next_eval_passes = cfg.eval_every.max(1) as f64;
        while !stop.load(Ordering::Relaxed) {
            std::thread::sleep(std::time::Duration::from_millis(20));
            let passes = samples.load(Ordering::Relaxed) as f64 / train_len;
            if cfg.eval_every > 0 && passes >= next_eval_passes {
                let step = steps.load(Ordering::Relaxed);
                let time = wall_start.elapsed().as_secs_f64();
                if let Err(e) = ctx.run_eval(step, passes, time) {
                    let mut slot = first_err.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(e);
                    }
                    stop.store(true, Ordering::Relaxed);
                }
                next_eval_passes += cfg.eval_every.max(1) as f64;
            }
        }
    });

    if let Some(e) = first_err.into_inner().unwrap() {
        return Err(e);
    }

    let mut recs = records.into_inner().unwrap();
    recs.sort_by_key(|r| r.step);
    for r in recs {
        ctx.metrics.record_step(r);
    }
    Ok(())
}
