//! ASGD / DC-ASGD / SSP / DC-S3GD: the no-global-barrier protocols, in two
//! executions:
//!
//! * [`run_sim`] — the unified event-driven loop ([`super::driver`]) with
//!   the [`crate::sim::FullyAsync`] protocol (ASGD family) or
//!   [`crate::sim::StalenessBounded`] (SSP family). Worker finish events
//!   pop in virtual-time order; gradients are computed for real on the
//!   snapshot each worker pulled, so delayed-gradient staleness arises
//!   exactly as it would on a cluster, but deterministically. This is the
//!   mode behind the wallclock figures.
//! * [`run_threads`] — real OS threads racing on the sharded parameter
//!   server (lock contention and interleavings are physical; staleness is
//!   nondeterministic). Used by ablation benches and as a sanity check that
//!   the simulator matches reality in distribution. ASGD family only: the
//!   SSP gate needs the scheduler's clock bookkeeping.
//!
//! In both, a worker's cycle is Algorithm 1 verbatim: pull -> compute
//! gradient -> push; the server applies Algorithm 2's update rule.

use super::{FirstError, Progress, RunCtx};
use crate::data::{EpochPartition, ShardCursor};
use crate::metrics::StepRecord;
use anyhow::Result;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

pub fn run_sim(ctx: &mut RunCtx) -> Result<()> {
    super::driver::run(ctx, false)
}

pub fn run_threads(ctx: &mut RunCtx) -> Result<()> {
    let m = ctx.cfg.workers;
    let n = ctx.ps.n();
    let partition = EpochPartition::new(ctx.cfg.seed ^ 0x5EED, ctx.train_set.len(), m);
    let stop = AtomicBool::new(false);
    let samples = AtomicU64::new(0);
    let steps = AtomicU64::new(0);
    let records: Mutex<Vec<StepRecord>> = Mutex::new(Vec::new());
    let wall_start = std::time::Instant::now();
    let train_len = ctx.train_set.len() as f64;
    let first_err = FirstError::new();
    let progress = Progress::new();

    // clone what workers need so `ctx` stays exclusively borrowable for the
    // in-flight evals below
    let cfg = ctx.cfg.clone();
    let batch_size = ctx.batch_size;

    std::thread::scope(|scope| {
        for w in 0..m {
            let ps = ctx.ps.clone();
            let engine = ctx.engine.clone();
            let train_set = ctx.train_set.clone();
            let cfg = cfg.clone();
            let partition = partition.clone();
            let (stop, samples, steps, records, first_err, progress) =
                (&stop, &samples, &steps, &records, &first_err, &progress);
            scope.spawn(move || {
                let mut cursor = ShardCursor::new(partition, w, batch_size);
                let mut params = vec![0.0f32; n];
                while !stop.load(Ordering::Relaxed) {
                    ps.pull(w, &mut params);
                    let batch = train_set.make_batch(&cursor.next_indices());
                    let passes = samples.load(Ordering::Relaxed) as f64 / train_len;
                    let lr = cfg.lr.lr_at_epoch(passes.floor().max(0.0) as usize) as f32;
                    match engine.train(&params, &batch) {
                        Ok((loss, grads)) => {
                            let outcome = ps.push(w, &grads, lr);
                            let s = samples.fetch_add(batch_size as u64, Ordering::Relaxed)
                                + batch_size as u64;
                            let step = steps.fetch_add(1, Ordering::Relaxed);
                            let passes_now = s as f64 / train_len;
                            records.lock().unwrap().push(StepRecord {
                                step,
                                worker: w,
                                passes: passes_now,
                                time: wall_start.elapsed().as_secs_f64(),
                                loss,
                                lr,
                                staleness: outcome.staleness,
                                wait: 0.0, // threads race freely: no gate
                            });
                            let done_steps =
                                cfg.max_steps > 0 && step + 1 >= cfg.max_steps as u64;
                            let done_passes = cfg.max_steps == 0
                                && cfg.epochs > 0
                                && passes_now >= cfg.epochs as f64;
                            if done_steps || done_passes {
                                stop.store(true, Ordering::Relaxed);
                            }
                            // wake the monitor after every push (and after
                            // the stop transition) so it never busy-waits
                            progress.bump();
                        }
                        Err(e) => {
                            first_err.set(e);
                            stop.store(true, Ordering::Relaxed);
                            progress.bump();
                        }
                    }
                }
                // a worker observing stop set by a peer still wakes the
                // monitor so shutdown never waits on a missed signal
                progress.bump();
            });
        }

        // monitor: park on the progress condvar and run inline evals at
        // epoch boundaries while workers race. The engine serializes
        // execution, so evals interleave safely.
        let mut next_eval_passes = cfg.eval_every.max(1) as f64;
        let mut seen = 0u64;
        while !stop.load(Ordering::Relaxed) {
            seen = progress.wait_past(seen, &stop);
            let passes = samples.load(Ordering::Relaxed) as f64 / train_len;
            if cfg.eval_every > 0 && passes >= next_eval_passes && !stop.load(Ordering::Relaxed)
            {
                // tag the eval with the latest recorded step's index (the
                // counter holds completed steps), matching the sim driver
                let step = steps.load(Ordering::Relaxed).saturating_sub(1);
                let time = wall_start.elapsed().as_secs_f64();
                if let Err(e) = ctx.run_eval(step, passes, time) {
                    first_err.set(e);
                    stop.store(true, Ordering::Relaxed);
                    progress.bump();
                }
                next_eval_passes += cfg.eval_every.max(1) as f64;
            }
        }
    });

    if let Some(e) = first_err.take() {
        return Err(e);
    }

    let mut recs = records.into_inner().unwrap();
    recs.sort_by_key(|r| r.step);
    for r in recs {
        ctx.metrics.record_step(r);
    }
    Ok(())
}
