//! The unified event-driven training loop.
//!
//! Every simulated-time protocol — sequential, SSGD/DC-SSGD/hier-SSGD
//! barriers, SSP/DC-S3GD staleness windows, fully-async ASGD/DC-ASGD —
//! runs through
//! this single loop: the [`Scheduler`] decides *who computes when* (and who
//! waits, and — under a `[faults]` plan — who crashes, rejoins, or departs),
//! this driver turns finish events into real gradient computations and
//! parameter-server commits, and the shared [`RunCtx`] helpers handle
//! learning-rate schedules, stopping, evals, and metrics. The per-protocol
//! modules ([`super::sequential`], [`super::sync`], [`super::async_`]) are
//! thin adapters over this loop.
//!
//! ## Pipelined gradient stage
//!
//! Between a worker's pull and its finish event, its gradient depends only
//! on inputs the worker already holds — the snapshot it pulled and its own
//! batch cursor — so the in-flight computations are mutually independent
//! (Mishchenko et al. 2022). The driver exploits that through a
//! [`ComputeStage`]: each pull draws the worker's batch and enqueues the
//! compute on a [`GradPipeline`] over the run's persistent
//! [`ComputePool`]; the first finish event that needs an unevaluated
//! result flushes *every* queued worker concurrently in one pool burst.
//! Commits still happen strictly in the scheduler's event order, results
//! are keyed by worker, and each gradient is a pure function of its
//! per-worker inputs — so lane count cannot change a single produced bit
//! (`runtime.threads = 1` is the pinned serial reference).
//!
//! One subtlety keeps crashed runs bit-identical to the old draw-at-commit
//! loop: a drop-policy crash invalidates an in-flight compute whose batch
//! the serial loop would never have drawn. The stage therefore *retains*
//! the dropped batch and re-uses it for the worker's first compute after
//! rejoining — the cursor advances exactly when a compute can still
//! commit, never for work that died.
//!
//! Concurrency caveat: the PJRT backend executes every Train request on
//! its single engine thread ([`crate::runtime`] module docs), so on that
//! backend a flush currently *pipelines request issue* — all in-flight
//! requests are queued back-to-back and the engine never waits on the
//! driver between gradients — rather than parallelizing XLA execution
//! itself. Engine-free consumers of the stage (the chaos harness's
//! synthetic gradients, future multi-engine backends: the per-worker
//! handle slots are already in place) parallelize fully, as do the pool's
//! other clients (multi-shard applies, `store_w`, barrier folds).
//!
//! ## Worker churn
//!
//! Fault events surface as [`SimEvent`]s and map onto parameter-server
//! state exactly once each:
//!
//! * **Crash** — the scheduler already invalidated (or marked for salvage)
//!   the in-flight compute; the driver discards the pipelined result for a
//!   dropped epoch (a salvage drain keeps it — that finish still commits),
//!   settles a barrier round the membership change may have completed,
//!   then re-pulls for any workers the shrunken gate released.
//! * **Join** — the worker's server-side backup `w_bak(m)` is re-seeded to
//!   the current model ([`crate::ps::ParamServer::reset_worker`]) so DC
//!   compensation never sees a dead incarnation's snapshot, its
//!   error-feedback residual is zeroed (accumulated mass belongs to the
//!   crashed epoch), and it pulls a fresh snapshot.
//!
//! Barrier rounds complete over the **live** membership: the round folds
//! whatever the contributors delivered (sum of k gradients at `k * lr`),
//! so a dead worker never wedges a round. With `[faults]` off none of
//! these paths run and trajectories are bit-identical to pre-fault builds.

use super::RunCtx;
use crate::config::Algorithm;
use crate::data::{Batch, Dataset, EpochPartition, ShardCursor};
use crate::metrics::StepRecord;
use crate::optim::DcSsgdAccumulator;
use crate::runtime::EngineHandle;
use crate::sim::{
    ArrivalProcess, BarrierSync, CommCosts, CommitMode, DelaySampler, FaultPlan, FullyAsync,
    Protocol, ReadMode, Scheduler, ServingClock, ServingConfig, ServingRecorder, SimEvent,
    StalenessBounded, UplinkMeter,
};
use crate::trace::{EventKind, RunTrace, TraceOut};
use crate::util::pool::{ComputePool, GradPipeline};
use anyhow::Result;
use std::sync::{Arc, Mutex};

/// Server-side cost per update in simulated seconds, as a fraction of the
/// mean worker compute time. The paper reports the DC compensation is a
/// "lightweight overhead" on the server; we charge it explicitly (and
/// double it for DC rules) so the wallclock comparison is honest. Barrier
/// protocols fold once per round on the critical path of the slowest
/// worker, so (as before this refactor) they carry no per-push charge.
const SERVER_COST_FRAC: f64 = 0.01;

/// What one gradient computation produces.
type GradResult = Result<(f32, Vec<f32>)>;

/// Map an algorithm to its synchronization [`Protocol`].
pub fn protocol_for(algo: Algorithm, staleness_bound: u64) -> Box<dyn Protocol> {
    match algo {
        Algorithm::SyncSgd | Algorithm::DcSyncSgd | Algorithm::HierSsgd => Box::new(BarrierSync),
        Algorithm::Ssp | Algorithm::DcS3gd => {
            Box::new(StalenessBounded { bound: staleness_bound })
        }
        Algorithm::SequentialSgd
        | Algorithm::Asgd
        | Algorithm::DcAsgdConst
        | Algorithm::DcAsgdAdaptive => Box::new(FullyAsync),
    }
}

/// The driver's pipelined gradient stage (see the module docs): per-worker
/// batches drawn at pull time, gradients evaluated in pool bursts the
/// first time a finish event demands one, results consumed in commit
/// order. Engine handles are pre-cloned per worker behind uncontended
/// mutexes so flush tasks can issue engine requests from any pool lane.
struct ComputeStage {
    pipe: GradPipeline<GradResult>,
    /// The batch each in-flight compute trains on, drawn at enqueue time.
    batches: Vec<Option<Batch>>,
    engines: Vec<Mutex<EngineHandle>>,
}

impl ComputeStage {
    fn new(engine: &EngineHandle, workers: usize, pool: Arc<ComputePool>) -> Self {
        Self {
            pipe: GradPipeline::new(pool, workers),
            batches: vec![None; workers],
            engines: (0..workers).map(|_| Mutex::new(engine.clone())).collect(),
        }
    }

    /// Register worker `w`'s next compute: draw its batch — unless the
    /// pipeline retained the batch of a crash-dropped compute, which the
    /// serial draw-at-commit order never consumed and must see again —
    /// and queue the gradient for the next flush.
    fn enqueue(&mut self, worker: usize, cursor: &mut ShardCursor, ds: &dyn Dataset) {
        if self.pipe.enqueue(worker) {
            self.batches[worker] = Some(ds.make_batch(&cursor.next_indices()));
        } else {
            debug_assert!(self.batches[worker].is_some(), "retained compute without a batch");
        }
    }

    /// Void worker `w`'s in-flight compute (its epoch died under a
    /// drop-policy crash); the pipeline retains its inputs for re-use.
    fn discard(&mut self, worker: usize) {
        self.pipe.discard(worker);
    }

    /// If a take for `worker` would flush the pipeline (its result is not
    /// evaluated yet), the number of queued computes that burst covers.
    fn flush_pending(&self, worker: usize) -> Option<usize> {
        (!self.pipe.is_ready(worker)).then(|| self.pipe.queued_len())
    }

    /// Consume worker `w`'s gradient, flushing every queued compute
    /// concurrently on the pool if `w`'s is not evaluated yet. Barrier
    /// protocols share snapshot slot 0; immediate protocols read the
    /// worker's own slot.
    fn take(&mut self, worker: usize, snapshots: &[Vec<f32>], barrier: bool) -> GradResult {
        let Self { pipe, batches, engines, .. } = self;
        let (batches, engines) = (&*batches, &*engines);
        pipe.take(worker, &|v: usize| {
            let snap = if barrier { 0 } else { v };
            let batch = batches[v].as_ref().expect("in-flight compute without a batch");
            engines[v].lock().unwrap().train(&snapshots[snap], batch)
        })
    }
}

/// Driver-side serving-plane state ([`crate::sim::serving`]): the seeded
/// arrival stream, the deterministic latency clock, the sample recorder,
/// and reusable query/output buffers. Arrivals are processed *between*
/// scheduler events and never enter the scheduler's queue, so the serving
/// workload observes training without perturbing a single schedule bit
/// (pinned by `tests/serving.rs`).
struct ServingState {
    cfg: ServingConfig,
    arr: ArrivalProcess,
    clock: ServingClock,
    rec: ServingRecorder,
    /// Absolute virtual time of the next pending arrival.
    next: f64,
    /// Virtual seconds one training push occupies the apply path for
    /// (what locked reads queue behind).
    push_window: f64,
    queries: Vec<std::ops::Range<usize>>,
    out: Vec<f32>,
}

impl ServingState {
    fn new(cfg: ServingConfig, push_window: f64) -> Self {
        let mut arr = ArrivalProcess::new(cfg);
        let next = arr.next_arrival();
        Self {
            cfg,
            arr,
            clock: ServingClock::default(),
            rec: ServingRecorder::new(),
            next,
            push_window,
            queries: Vec::new(),
            out: Vec::new(),
        }
    }

    /// Serve every arrival at or before virtual time `t`. `step` is the
    /// training frontier (commits so far) the snapshot staleness is
    /// measured against.
    fn drain_until(&mut self, t: f64, ps: &crate::ps::ParamServer, n: usize, step: u64) {
        while self.next <= t {
            let at = self.next;
            let qlen = self.arr.draw_queries(n, &mut self.queries);
            self.out.resize(qlen, 0.0);
            let lat = self.clock.pull_latency(at, self.cfg.read_mode, self.cfg.batch);
            match self.cfg.read_mode {
                ReadMode::Snapshot => {
                    let meta = ps
                        .serving_pull_batch(&self.queries, &mut self.out)
                        .expect("serving enabled: the plane publishes before arrivals");
                    let stale_steps = step.saturating_sub(meta.step);
                    self.rec.on_pull(lat, stale_steps, (at - meta.time).max(0.0));
                }
                ReadMode::Locked => {
                    ps.locked_pull_batch(&self.queries, &mut self.out);
                    // live reads: no snapshot lag by definition
                    self.rec.on_pull(lat, 0, 0.0);
                }
            }
            self.next = self.arr.next_arrival();
        }
    }

    /// A commit produced global step `step` at event time `t`: charge the
    /// push-apply window and publish a fresh snapshot on the cadence.
    fn on_commit(&mut self, ps: &crate::ps::ParamServer, step: u64, t: f64) {
        self.clock.on_push(t, self.push_window);
        if step % self.cfg.publish_every as u64 == 0 {
            ps.publish_snapshot(step, t);
            self.rec.on_publish();
        }
    }
}

/// Inter-sample accumulator for the per-rack `uplink_util_r<i>`
/// time-series columns (bytes crossing each rack uplink per virtual
/// second over the sampling window). `racks == 0` when `[topology]` is
/// off: no columns, CSV byte-identical to pre-uplink builds.
struct UplinkWindow {
    racks: usize,
    last_bytes: Vec<f64>,
    last_t: f64,
}

/// Barrier-round arenas: per-worker gradient slots (each takes ownership of
/// the engine's buffer — a move, not a copy), losses, fill flags, and the
/// round's accumulated gate wait. Allocated once; the round loop adds no
/// allocations of its own.
struct RoundState {
    grads: Vec<Vec<f32>>,
    loss: Vec<f32>,
    filled: Vec<bool>,
    wait: f64,
    /// Rack-reducer scratch for the hierarchical fold (hier-ssgd with
    /// more than one rack); empty otherwise.
    partial: Vec<f32>,
}

/// Fold the barrier round into ONE global step if every *live* worker has
/// contributed (paper §1 / appx H, generalized to elastic membership).
/// Called at every arrival and at every membership change — a crash of the
/// last missing worker completes the round. A dead contributor's completed
/// gradient still folds (its *in-flight* work was already handled by the
/// crash policy). Returns whether a fold happened.
///
/// `racks > 1` selects the hierarchical (hier-ssgd) fold: each rack
/// reducer sums its residents' contributions in worker order, then the
/// root folds one partial per rack in rack order. With `racks == 1` the
/// single "rack" holds the whole fleet and the fold is the plain
/// worker-order sum — the exact instruction sequence of the flat SSGD
/// path, so ssgd/dc-ssgd trajectories are bit-identical to before.
#[allow(clippy::too_many_arguments)]
fn fold_round_if_complete(
    ctx: &mut RunCtx,
    sched: &Scheduler,
    round: &mut RoundState,
    acc: &mut DcSsgdAccumulator,
    avg: &mut [f32],
    dcssgd: bool,
    racks: usize,
    step: &mut u64,
    samples: &mut u64,
    prev_passes: &mut f64,
    train_len: f64,
    lr: f32,
    rec_time: f64,
) -> Result<bool> {
    let m = round.filled.len();
    let contributors = round.filled.iter().filter(|&&f| f).count();
    if contributors == 0 {
        return Ok(false);
    }
    if (0..m).any(|v| sched.is_live(v) && !round.filled[v]) {
        return Ok(false); // a live worker is still computing this round
    }
    let mut loss_sum = 0.0f32;
    if dcssgd {
        for v in 0..m {
            if round.filled[v] {
                loss_sum += round.loss[v];
                acc.push_from(&round.grads[v]);
            }
        }
        ctx.ps.apply_with(|wv| acc.apply(wv, lr));
    } else {
        // Paper §1: each worker *adds* its gradient; the barrier only
        // synchronizes, so one round applies the SUM of the contributed
        // gradients. Rack-major: workers on rack r are {r, r+racks, ...}
        // (the [topology] striping); each rack's residents fold in worker
        // order, rack partials fold in rack order. racks == 1 is the
        // pre-topology flat fold, f32-identical to the pre-fault path
        // when the fleet is whole.
        let RoundState { grads, loss, filled, partial, .. } = round;
        let mut any = false;
        for r in 0..racks {
            let first_rack = !any;
            let mut seen = 0usize;
            let dst: &mut [f32] = if first_rack { &mut *avg } else { &mut partial[..] };
            for v in (r..m).step_by(racks) {
                if !filled[v] {
                    continue;
                }
                loss_sum += loss[v];
                if seen == 0 {
                    dst.copy_from_slice(&grads[v]);
                } else {
                    for (a, g) in dst.iter_mut().zip(&grads[v]) {
                        *a += g;
                    }
                }
                seen += 1;
            }
            if seen > 0 {
                if !first_rack {
                    for (a, p) in avg.iter_mut().zip(partial.iter()) {
                        *a += p;
                    }
                }
                any = true;
            }
        }
        let inv = 1.0 / contributors as f32;
        for a in avg.iter_mut() {
            *a *= inv;
        }
        ctx.ps.apply_aggregated(avg, lr * contributors as f32);
    }
    round.filled.fill(false);
    *samples += (contributors * ctx.batch_size) as u64;
    let passes_now = *samples as f64 / train_len;
    ctx.metrics.record_step(StepRecord {
        step: *step,
        worker: 0,
        passes: passes_now,
        time: rec_time,
        loss: loss_sum / contributors as f32,
        lr,
        staleness: 0, // barrier: no delayed gradients
        wait: round.wait,
    });
    *step += 1;
    round.wait = 0.0;
    if ctx.should_eval(*prev_passes, passes_now, *step) {
        // tag the eval row with the round that produced the model it
        // measures — the same index its StepRecord carries (both commit
        // branches use this convention)
        ctx.run_eval(*step - 1, passes_now, rec_time)?;
    }
    *prev_passes = passes_now;
    Ok(true)
}

/// Pull fresh snapshots for the workers a scheduler event just released
/// and stage their gradients on the pipeline. Barrier protocols share ONE
/// snapshot slot (all released workers compute the same round on the
/// post-fold model); immediate protocols re-pull each released worker's
/// own slot.
#[allow(clippy::too_many_arguments)]
fn pull_and_stage(
    ctx: &RunCtx,
    stage: &mut ComputeStage,
    cursors: &mut [ShardCursor],
    barrier: bool,
    released: &[usize],
    snapshots: &mut [Vec<f32>],
    trace: &mut Option<RunTrace>,
    t: f64,
) {
    if barrier {
        if !released.is_empty() {
            ctx.ps.pull(0, &mut snapshots[0]);
        }
    } else {
        for &v in released {
            ctx.ps.pull(v, &mut snapshots[v]);
        }
    }
    for &v in released {
        stage.enqueue(v, &mut cursors[v], ctx.train_set.as_ref());
        if let Some(tr) = trace.as_mut() {
            tr.buf.emit(EventKind::Pull, t, Some(v), None, None, None);
            tr.buf.emit(EventKind::PipelineEnqueue, t, Some(v), None, None, None);
        }
    }
}

/// Close a telemetry window at a `/trace/sample_every` step boundary: one
/// time-series row plus one `ShardVersion` counter event per PS shard.
/// Appends the declared extension values (per-rack uplink utilization,
/// serving window stats) — both vectors are empty when their sections are
/// off, keeping the CSV byte-identical to pre-extension builds.
fn sample_point(
    tr: &mut RunTrace,
    ctx: &RunCtx,
    sched: &Scheduler,
    serving: Option<&mut ServingState>,
    uw: &mut UplinkWindow,
    step: u64,
    t: f64,
) {
    if step == 0 || step % tr.sample_every as u64 != 0 {
        return;
    }
    let mut extra = Vec::with_capacity(tr.extra_cols.len());
    if uw.racks > 0 {
        let bytes = sched.uplink_bytes().expect("topology installs the uplink meter");
        let dt = t - uw.last_t;
        for r in 0..uw.racks {
            let delta = bytes[r] - uw.last_bytes[r];
            extra.push(if dt > 0.0 { delta / dt } else { 0.0 });
        }
        uw.last_bytes.copy_from_slice(bytes);
        uw.last_t = t;
    }
    if let Some(sv) = serving {
        let (pulls, lat_mean) = sv.rec.take_window();
        let lag = ctx
            .ps
            .store()
            .serving()
            .and_then(|p| p.meta())
            .map(|m| step.saturating_sub(m.step))
            .unwrap_or(0);
        extra.push(pulls as f64);
        extra.push(lat_mean);
        extra.push(lag as f64);
    }
    tr.sample_with(
        step,
        t,
        ctx.metrics.loss_ema().unwrap_or(f64::NAN),
        sched.live_workers(),
        sched.comm_bytes_total(),
        sched.queue_depth(),
        extra,
    );
    let store = ctx.ps.store();
    for s in 0..store.num_shards() {
        tr.buf.emit(
            EventKind::ShardVersion,
            t,
            Some(s),
            None,
            None,
            Some(store.shard_version(s) as f64),
        );
    }
}

/// Run one experiment under the event-driven scheduler. `wall` records
/// host wallclock instead of virtual time (sync threads mode); the
/// schedule itself is always driven by the virtual clock.
pub fn run(ctx: &mut RunCtx, wall: bool) -> Result<()> {
    let m = ctx.cfg.workers;
    let n = ctx.ps.n();
    let algo = ctx.cfg.algorithm;
    let train_len = ctx.train_set.len() as f64;
    let partition = EpochPartition::new(ctx.cfg.seed ^ 0x5EED, ctx.train_set.len(), m);
    let mut cursors: Vec<ShardCursor> =
        (0..m).map(|w| ShardCursor::new(partition.clone(), w, ctx.batch_size)).collect();
    let delays = DelaySampler::new(ctx.cfg.delay.clone(), m, ctx.cfg.seed);
    let server_cost = if algo.is_async() {
        SERVER_COST_FRAC
            * ctx.cfg.delay.mean()
            * if algo.is_delay_compensated() { 2.0 } else { 1.0 }
    } else {
        0.0
    };
    // gradient compression ([compress]): per-worker codec + EF residual
    // live on the RunCtx (so checkpoints capture the residuals); `none`
    // builds nothing and the push path below is exactly the dense code.
    let compressed = !ctx.compressors.is_empty();
    debug_assert!(!compressed || ctx.compressors.len() == m);
    // communication charges ([comm]): when enabled, every gradient upload
    // and model download adds virtual time via sim::CommModel; disabled
    // (the default) keeps the schedule bit-identical to a free network.
    // Uploads cost the *encoded* wire size; model downloads stay dense.
    // Byte accounting rides along either way (it never affects the
    // schedule), so sweeps can report bytes-on-wire.
    let dense_bytes = n * std::mem::size_of::<f32>();
    let push_bytes = ctx.cfg.compress.wire_bytes(n);
    let comm = if ctx.cfg.comm.enabled {
        CommCosts::from_model(&ctx.cfg.comm.model, push_bytes, dense_bytes)
    } else {
        CommCosts::sized(push_bytes, dense_bytes)
    };
    // fault injection ([faults]): the scheduler owns the whole lifecycle
    // (crash/restart/departure/late-join/straggle); with the section off
    // no plan is built and the event stream is pure finishes.
    let faults = FaultPlan::from_config(&ctx.cfg.faults, m, ctx.cfg.seed);
    let mut sched = Scheduler::with_faults(
        protocol_for(algo, ctx.cfg.staleness_bound as u64),
        delays,
        server_cost,
        comm,
        faults,
    );
    // fleet topology ([topology]): per-worker transfer charges derived
    // from rack placement + the two-level link model replace the uniform
    // comm costs; the PS spreads its shards over the logical node fleet.
    // Disabled (the default) builds nothing — schedules stay bit-identical.
    let topo = crate::sim::Topology::from_config(&ctx.cfg.topology, m);
    if let Some(t) = &topo {
        sched.set_worker_comm(t.all_worker_costs(push_bytes, dense_bytes));
        ctx.ps.set_ps_nodes(t.ps_nodes());
        // per-rack uplink byte meter: pure accounting at the comm_bytes
        // sites, surfaced as uplink_util_r<i> time-series columns
        sched.set_uplink_meter(UplinkMeter::new(t, push_bytes, dense_bytes));
    }
    // hier-ssgd folds rack-major; every other barrier folds as one rack
    let racks = if algo == Algorithm::HierSsgd {
        topo.as_ref().map(|t| t.racks()).unwrap_or(1)
    } else {
        1
    };
    // run tracing ([trace]): the scheduler records lifecycle events into
    // its own buffer, the driver records pulls/commits/pipeline activity
    // and periodic telemetry here. All emission sites observe decisions
    // already made, so trace-on runs are bit-identical to trace-off
    // (pinned by tests/trace.rs).
    let mut trace: Option<RunTrace> = if ctx.cfg.trace.enabled {
        sched.enable_trace();
        Some(RunTrace::new(&ctx.cfg.trace))
    } else {
        None
    };
    // serving plane ([serving]): wait-free epoch snapshots published on
    // the commit path + a seeded inference workload drained between
    // scheduler events. Strictly an observer — arrivals never enter the
    // event queue, so training schedules, push traces, and model bits are
    // bitwise identical serving-on vs serving-off (tests/serving.rs).
    let mut serving: Option<ServingState> = if ctx.cfg.serving.enabled {
        ctx.ps.enable_serving();
        // epoch 1 covers the initial model: queries are answerable from t=0
        ctx.ps.publish_snapshot(0, 0.0);
        let push_window =
            if server_cost > 0.0 { server_cost } else { SERVER_COST_FRAC * ctx.cfg.delay.mean() };
        let mut sv = ServingState::new(ctx.cfg.serving, push_window);
        sv.rec.on_publish();
        Some(sv)
    } else {
        None
    };
    // declare the appended time-series columns (none ⇒ CSV unchanged)
    let mut uplink_win = UplinkWindow {
        racks: topo.as_ref().map(|t| t.racks()).unwrap_or(0),
        last_bytes: vec![0.0; topo.as_ref().map(|t| t.racks()).unwrap_or(0)],
        last_t: 0.0,
    };
    if let Some(tr) = trace.as_mut() {
        let mut cols: Vec<String> = Vec::new();
        cols.extend((0..uplink_win.racks).map(|r| format!("uplink_util_r{r}")));
        if serving.is_some() {
            cols.extend(
                ["serving_pulls", "serving_lat_mean", "serving_epoch_lag"].map(String::from),
            );
        }
        tr.set_extra_cols(cols);
    }
    let barrier = sched.commit_mode() == CommitMode::Barrier;
    debug_assert!(
        !barrier || !compressed,
        "barrier protocols fold dense gradients (config validation rejects this)"
    );
    let dcssgd = algo == Algorithm::DcSyncSgd;
    let mut acc = DcSsgdAccumulator::new(n, ctx.cfg.lambda0 as f32);
    let mut avg = vec![0.0f32; n];

    // pipelined gradient stage over the run's persistent compute pool (the
    // same pool the sharded store fans multi-shard applies over)
    let mut stage = ComputeStage::new(&ctx.engine, m, Arc::clone(&ctx.pool));

    // snapshot buffers: barrier rounds share ONE (all workers compute on
    // the same model, and the fold paths never read w_bak), immediate
    // protocols keep one per worker — so SSGD at M=16 still costs a single
    // parameter copy per round, as before this refactor
    let snap = |w: usize| if barrier { 0 } else { w };
    let mut snapshots: Vec<Vec<f32>> = vec![vec![0.0f32; n]; if barrier { 1 } else { m }];
    for w in sched.start() {
        if !barrier || w == 0 {
            ctx.ps.pull(w, &mut snapshots[snap(w)]);
        }
        stage.enqueue(w, &mut cursors[w], ctx.train_set.as_ref());
        if let Some(tr) = trace.as_mut() {
            tr.buf.emit(EventKind::Pull, 0.0, Some(w), None, None, None);
            tr.buf.emit(EventKind::PipelineEnqueue, 0.0, Some(w), None, None, None);
        }
    }

    let wall_start = std::time::Instant::now();
    let mut round = RoundState {
        grads: vec![Vec::new(); if barrier { m } else { 0 }],
        loss: vec![0.0f32; m],
        filled: vec![false; m],
        wait: 0.0,
        partial: vec![0.0f32; if barrier && racks > 1 { n } else { 0 }],
    };
    let mut step = 0u64;
    let mut samples = 0u64;
    let mut prev_passes = 0.0f64;

    while let Some(event) = sched.next_event() {
        // serve every inference arrival up to this event's virtual time —
        // an observer pass over immutable training state, before the event
        // itself mutates the model
        if let Some(sv) = serving.as_mut() {
            let now = match &event {
                SimEvent::Finish { time, .. }
                | SimEvent::Crash { time, .. }
                | SimEvent::Join { time, .. } => *time,
            };
            sv.drain_until(now, &ctx.ps, n, step);
        }
        match event {
            SimEvent::Finish { time: t, worker: w } => {
                let passes = samples as f64 / train_len;
                if ctx.done(step, passes) {
                    break;
                }
                let lr = ctx.lr_at(passes);
                // consume the pipelined gradient: computed on the (possibly
                // stale) snapshot worker w pulled when the protocol last
                // admitted it, against the batch drawn at that pull
                debug_assert!(sched.is_computing(w), "finish for a non-computing worker");
                if let Some(tr) = trace.as_mut() {
                    if let Some(nq) = stage.flush_pending(w) {
                        tr.buf.emit(EventKind::PipelineFlush, t, Some(w), None, None, Some(nq as f64));
                    }
                }
                let (loss, grads) = stage.take(w, &snapshots, barrier)?;
                let rec_time = if wall { wall_start.elapsed().as_secs_f64() } else { t };

                if barrier {
                    // the round's wait is every worker's barrier stall
                    // summed, so wait totals stay comparable with per-push
                    // protocols
                    round.wait += sched.step_wait(w);
                    debug_assert!(!round.filled[w], "worker {w} pushed twice in one round");
                    round.grads[w] = grads;
                    round.loss[w] = loss;
                    round.filled[w] = true;
                    let n_fill = round.filled.iter().filter(|&&f| f).count();
                    let restarted = sched.complete(w);
                    let folded = fold_round_if_complete(
                        ctx,
                        &sched,
                        &mut round,
                        &mut acc,
                        &mut avg,
                        dcssgd,
                        racks,
                        &mut step,
                        &mut samples,
                        &mut prev_passes,
                        train_len,
                        lr,
                        rec_time,
                    )?;
                    if folded {
                        if let Some(sv) = serving.as_mut() {
                            sv.on_commit(&ctx.ps, step, t);
                        }
                        if let Some(tr) = trace.as_mut() {
                            tr.observe_commit(0);
                            tr.buf.emit(
                                EventKind::BarrierRelease,
                                t,
                                None,
                                Some(step - 1),
                                None,
                                Some(n_fill as f64),
                            );
                            sample_point(tr, ctx, &sched, serving.as_mut(), &mut uplink_win, step, t);
                        }
                    }
                    // one shared pull for the whole round (restarted is
                    // either empty mid-round or the full live fleet at the
                    // round boundary)
                    pull_and_stage(
                        ctx,
                        &mut stage,
                        &mut cursors,
                        true,
                        &restarted,
                        &mut snapshots,
                        &mut trace,
                        t,
                    );
                } else {
                    // compressed path: EF-inject + encode, then the server
                    // decodes (or applies sparse shard-locally); DC
                    // compensates the decoded gradient against w_bak
                    // exactly as it would the dense one
                    let outcome = if compressed {
                        let payload = ctx.compressors[w].compress(&grads);
                        ctx.ps.push_encoded(w, payload, lr)
                    } else {
                        ctx.ps.push(w, &grads, lr)
                    };
                    if let Some(tr) = trace.as_mut() {
                        tr.observe_commit(outcome.staleness);
                        tr.buf.emit(
                            EventKind::PushCommit,
                            t,
                            Some(w),
                            Some(step),
                            Some(outcome.staleness),
                            Some(loss as f64),
                        );
                    }
                    samples += ctx.batch_size as u64;
                    let passes_now = samples as f64 / train_len;
                    ctx.metrics.record_step(StepRecord {
                        step,
                        worker: w,
                        passes: passes_now,
                        time: rec_time,
                        loss,
                        lr,
                        staleness: outcome.staleness,
                        wait: sched.step_wait(w),
                    });
                    step += 1;
                    if let Some(sv) = serving.as_mut() {
                        sv.on_commit(&ctx.ps, step, t);
                    }
                    if ctx.should_eval(prev_passes, passes_now, step) {
                        // tag the eval row with the push that triggered it —
                        // the same index its StepRecord carries
                        ctx.run_eval(step - 1, passes_now, rec_time)?;
                    }
                    prev_passes = passes_now;
                    if let Some(tr) = trace.as_mut() {
                        sample_point(tr, ctx, &sched, serving.as_mut(), &mut uplink_win, step, t);
                    }
                    // the protocol decides who re-pulls: always `w` itself
                    // when ungated, plus any peers its completion (or, on a
                    // salvage drain, its death) just released
                    let released = sched.complete(w);
                    pull_and_stage(
                        ctx,
                        &mut stage,
                        &mut cursors,
                        false,
                        &released,
                        &mut snapshots,
                        &mut trace,
                        t,
                    );
                }
            }
            SimEvent::Crash { time: t, worker: cw, released, .. } => {
                // the scheduler already dropped (or marked for salvage) the
                // in-flight compute and shrank the live set; mirror that in
                // the pipeline — a dropped epoch's gradient must never be
                // consumed (a salvage drain stays: its finish still commits)
                if !sched.is_live(cw) {
                    stage.discard(cw);
                }
                // a barrier round missing only the dead worker completes
                // right here
                if barrier {
                    let lr = ctx.lr_at(samples as f64 / train_len);
                    let rec_time = if wall { wall_start.elapsed().as_secs_f64() } else { t };
                    let n_fill = round.filled.iter().filter(|&&f| f).count();
                    let folded = fold_round_if_complete(
                        ctx,
                        &sched,
                        &mut round,
                        &mut acc,
                        &mut avg,
                        dcssgd,
                        racks,
                        &mut step,
                        &mut samples,
                        &mut prev_passes,
                        train_len,
                        lr,
                        rec_time,
                    )?;
                    if folded {
                        if let Some(sv) = serving.as_mut() {
                            sv.on_commit(&ctx.ps, step, t);
                        }
                        if let Some(tr) = trace.as_mut() {
                            tr.observe_commit(0);
                            tr.buf.emit(
                                EventKind::BarrierRelease,
                                t,
                                None,
                                Some(step - 1),
                                None,
                                Some(n_fill as f64),
                            );
                            sample_point(tr, ctx, &sched, serving.as_mut(), &mut uplink_win, step, t);
                        }
                    }
                }
                // released workers pull the (post-fold) model
                pull_and_stage(
                    ctx,
                    &mut stage,
                    &mut cursors,
                    barrier,
                    &released,
                    &mut snapshots,
                    &mut trace,
                    t,
                );
            }
            SimEvent::Join { time: t, worker: w, computing, released } => {
                // rejoin / elastic scale-up: the dead incarnation's state
                // must not leak into the new epoch — refresh w_bak(m) (so
                // DC compensates against a live snapshot) and zero the EF
                // residual
                ctx.ps.reset_worker(w);
                if compressed {
                    ctx.compressors[w].reset();
                }
                // a joiner that started computing right away needs its
                // snapshot (and a staged compute) now; a gate-blocked one
                // (it died ahead of the fleet) is pulled via the released
                // list when admitted
                if computing {
                    ctx.ps.pull(w, &mut snapshots[snap(w)]);
                    stage.enqueue(w, &mut cursors[w], ctx.train_set.as_ref());
                    if let Some(tr) = trace.as_mut() {
                        tr.buf.emit(EventKind::Pull, t, Some(w), None, None, None);
                        tr.buf.emit(EventKind::PipelineEnqueue, t, Some(w), None, None, None);
                    }
                }
                pull_and_stage(
                    ctx,
                    &mut stage,
                    &mut cursors,
                    barrier,
                    &released,
                    &mut snapshots,
                    &mut trace,
                    t,
                );
            }
        }
    }
    ctx.metrics.set_comm_bytes(sched.comm_bytes_total());
    ctx.metrics.set_fault_stats(sched.fault_stats());
    if let Some(sv) = &serving {
        ctx.metrics.set_serving(sv.rec.summary());
    }
    // hand the merged event stream + telemetry rows to the trainer for
    // artifact writing (the scheduler's buffer drains here)
    if let Some(mut tr) = trace {
        let events = crate::trace::merge_events(vec![tr.buf.drain(), sched.drain_trace()]);
        ctx.trace_out = Some(TraceOut {
            events,
            rows: std::mem::take(&mut tr.rows),
            extra_cols: std::mem::take(&mut tr.extra_cols),
            extra_rows: std::mem::take(&mut tr.extra_rows),
        });
    }
    Ok(())
}
