//! The unified event-driven training loop.
//!
//! Every simulated-time protocol — sequential, SSGD/DC-SSGD barriers,
//! SSP/DC-S3GD staleness windows, fully-async ASGD/DC-ASGD — runs through
//! this single loop: the [`Scheduler`] decides *who computes when* (and who
//! waits), this driver turns finish events into real gradient computations
//! and parameter-server commits, and the shared [`RunCtx`] helpers handle
//! learning-rate schedules, stopping, evals, and metrics. The per-protocol
//! modules ([`super::sequential`], [`super::sync`], [`super::async_`]) are
//! thin adapters over this loop.

use super::RunCtx;
use crate::compress::WorkerCompressor;
use crate::config::Algorithm;
use crate::data::{EpochPartition, ShardCursor};
use crate::metrics::StepRecord;
use crate::optim::{average_into, DcSsgdAccumulator};
use crate::sim::{
    BarrierSync, CommCosts, CommitMode, DelaySampler, FullyAsync, Protocol, Scheduler,
    StalenessBounded,
};
use anyhow::Result;

/// Server-side cost per update in simulated seconds, as a fraction of the
/// mean worker compute time. The paper reports the DC compensation is a
/// "lightweight overhead" on the server; we charge it explicitly (and
/// double it for DC rules) so the wallclock comparison is honest. Barrier
/// protocols fold once per round on the critical path of the slowest
/// worker, so (as before this refactor) they carry no per-push charge.
const SERVER_COST_FRAC: f64 = 0.01;

/// Map an algorithm to its synchronization [`Protocol`].
pub fn protocol_for(algo: Algorithm, staleness_bound: u64) -> Box<dyn Protocol> {
    match algo {
        Algorithm::SyncSgd | Algorithm::DcSyncSgd => Box::new(BarrierSync),
        Algorithm::Ssp | Algorithm::DcS3gd => {
            Box::new(StalenessBounded { bound: staleness_bound })
        }
        Algorithm::SequentialSgd
        | Algorithm::Asgd
        | Algorithm::DcAsgdConst
        | Algorithm::DcAsgdAdaptive => Box::new(FullyAsync),
    }
}

/// Run one experiment under the event-driven scheduler. `wall` records
/// host wallclock instead of virtual time (sync threads mode); the
/// schedule itself is always driven by the virtual clock.
pub fn run(ctx: &mut RunCtx, wall: bool) -> Result<()> {
    let m = ctx.cfg.workers;
    let n = ctx.ps.n();
    let algo = ctx.cfg.algorithm;
    let train_len = ctx.train_set.len() as f64;
    let partition = EpochPartition::new(ctx.cfg.seed ^ 0x5EED, ctx.train_set.len(), m);
    let mut cursors: Vec<ShardCursor> =
        (0..m).map(|w| ShardCursor::new(partition.clone(), w, ctx.batch_size)).collect();
    let delays = DelaySampler::new(ctx.cfg.delay.clone(), m, ctx.cfg.seed);
    let server_cost = if algo.is_async() {
        SERVER_COST_FRAC
            * ctx.cfg.delay.mean()
            * if algo.is_delay_compensated() { 2.0 } else { 1.0 }
    } else {
        0.0
    };
    // gradient compression ([compress]): one codec + EF residual + payload
    // arena per worker. `none` builds nothing and the push path below is
    // exactly the pre-compression dense code.
    let mut compressors: Vec<WorkerCompressor> = (0..m)
        .filter_map(|w| WorkerCompressor::new(&ctx.cfg.compress, n, ctx.cfg.seed, w))
        .collect();
    debug_assert!(compressors.is_empty() || compressors.len() == m);
    // communication charges ([comm]): when enabled, every gradient upload
    // and model download adds virtual time via sim::CommModel; disabled
    // (the default) keeps the schedule bit-identical to a free network.
    // Uploads cost the *encoded* wire size; model downloads stay dense.
    // Byte accounting rides along either way (it never affects the
    // schedule), so sweeps can report bytes-on-wire.
    let dense_bytes = n * std::mem::size_of::<f32>();
    let push_bytes = ctx.cfg.compress.wire_bytes(n);
    let comm = if ctx.cfg.comm.enabled {
        CommCosts::from_model(&ctx.cfg.comm.model, push_bytes, dense_bytes)
    } else {
        CommCosts::sized(push_bytes, dense_bytes)
    };
    let mut sched = Scheduler::with_comm(
        protocol_for(algo, ctx.cfg.staleness_bound as u64),
        delays,
        server_cost,
        comm,
    );
    let barrier = sched.commit_mode() == CommitMode::Barrier;
    debug_assert!(
        !barrier || compressors.is_empty(),
        "barrier protocols fold dense gradients (config validation rejects this)"
    );
    let dcssgd = algo == Algorithm::DcSyncSgd;
    let mut acc = DcSsgdAccumulator::new(n, ctx.cfg.lambda0 as f32);
    let mut avg = vec![0.0f32; n];

    // snapshot buffers: barrier rounds share ONE (all workers compute on
    // the same model, and the fold paths never read w_bak), immediate
    // protocols keep one per worker — so SSGD at M=16 still costs a single
    // parameter copy per round, as before this refactor
    let snap = |w: usize| if barrier { 0 } else { w };
    let mut snapshots: Vec<Vec<f32>> = vec![vec![0.0f32; n]; if barrier { 1 } else { m }];
    for w in sched.start() {
        if !barrier || w == 0 {
            ctx.ps.pull(w, &mut snapshots[snap(w)]);
        }
    }

    let wall_start = std::time::Instant::now();
    // barrier round slots, indexed by worker so the fold order is
    // worker-deterministic regardless of arrival order. Each slot takes
    // ownership of the engine's per-step gradient buffer (a move, not a
    // copy); the loss/filled arenas are allocated once, so the driver adds
    // no allocations of its own to the round loop.
    let mut round_grads: Vec<Vec<f32>> = vec![Vec::new(); if barrier { m } else { 0 }];
    let mut round_loss = vec![0.0f32; m];
    let mut round_filled = vec![false; m];
    let mut round_n = 0usize;
    let mut round_wait = 0.0f64;
    let mut step = 0u64;
    let mut samples = 0u64;
    let mut prev_passes = 0.0f64;

    while let Some((t, w)) = sched.next() {
        let passes = samples as f64 / train_len;
        if ctx.done(step, passes) {
            break;
        }
        let lr = ctx.lr_at(passes);
        let batch = ctx.train_set.make_batch(&cursors[w].next_indices());
        // the gradient is computed on the (possibly stale) snapshot worker
        // w pulled when the protocol last admitted it
        let (loss, grads) = ctx.engine.train(&snapshots[snap(w)], &batch)?;
        let rec_time = if wall { wall_start.elapsed().as_secs_f64() } else { t };

        if barrier {
            // the round's wait is every worker's barrier stall summed, so
            // wait totals stay comparable with per-push protocols
            round_wait += sched.step_wait(w);
            debug_assert!(!round_filled[w], "worker {w} pushed twice in one round");
            round_grads[w] = grads;
            round_loss[w] = loss;
            round_filled[w] = true;
            round_n += 1;
            let restarted = sched.complete(w);
            if round_n == m {
                // the round completes when the slowest worker arrives; fold
                // the M gradients into ONE global step (paper §1 / appx H).
                // A malformed round (double-complete, unfilled slot) must
                // panic, not fold a stale arena slot.
                assert!(round_filled.iter().all(|&filled| filled), "incomplete barrier round");
                let mut loss_sum = 0.0f32;
                if dcssgd {
                    for (l, g) in round_loss.iter().zip(&round_grads) {
                        loss_sum += l;
                        acc.push_from(g);
                    }
                    ctx.ps.apply_with(|wv| acc.apply(wv, lr));
                } else {
                    // Paper §1: each worker *adds* its gradient; the barrier
                    // only synchronizes, so one round applies the SUM of the
                    // M gradients — the "enlarged mini-batch" effect Table 1
                    // attributes SSGD's degradation to. Folded in worker
                    // order straight out of the arenas.
                    average_into(&mut avg, &round_grads);
                    for &l in &round_loss {
                        loss_sum += l;
                    }
                    ctx.ps.apply_aggregated(&avg, lr * m as f32);
                }
                round_filled.fill(false);
                round_n = 0;
                samples += (m * ctx.batch_size) as u64;
                let passes_now = samples as f64 / train_len;
                ctx.metrics.record_step(StepRecord {
                    step,
                    worker: 0,
                    passes: passes_now,
                    time: rec_time,
                    loss: loss_sum / m as f32,
                    lr,
                    staleness: 0, // barrier: no delayed gradients
                    wait: round_wait,
                });
                step += 1;
                round_wait = 0.0;
                if ctx.should_eval(prev_passes, passes_now, step) {
                    // tag the eval row with the round that produced the
                    // model it measures — the same index its StepRecord
                    // carries (both branches use this convention)
                    ctx.run_eval(step - 1, passes_now, rec_time)?;
                }
                prev_passes = passes_now;
            }
            // one shared pull for the whole round (restarted is either
            // empty mid-round or all M workers at the round boundary)
            if !restarted.is_empty() {
                ctx.ps.pull(0, &mut snapshots[0]);
            }
        } else {
            // compressed path: EF-inject + encode, then the server decodes
            // (or applies sparse shard-locally); DC compensates the decoded
            // gradient against w_bak exactly as it would the dense one
            let outcome = if compressors.is_empty() {
                ctx.ps.push(w, &grads, lr)
            } else {
                ctx.ps.push_encoded(w, compressors[w].compress(&grads), lr)
            };
            samples += ctx.batch_size as u64;
            let passes_now = samples as f64 / train_len;
            ctx.metrics.record_step(StepRecord {
                step,
                worker: w,
                passes: passes_now,
                time: rec_time,
                loss,
                lr,
                staleness: outcome.staleness,
                wait: sched.step_wait(w),
            });
            step += 1;
            if ctx.should_eval(prev_passes, passes_now, step) {
                // tag the eval row with the push that triggered it — the
                // same index its StepRecord carries (was off by one)
                ctx.run_eval(step - 1, passes_now, rec_time)?;
            }
            prev_passes = passes_now;
            // the protocol decides who re-pulls: always `w` itself when
            // ungated, plus any peers its completion just released
            for v in sched.complete(w) {
                ctx.ps.pull(v, &mut snapshots[v]);
            }
        }
    }
    ctx.metrics.set_comm_bytes(sched.comm_bytes_total());
    Ok(())
}
