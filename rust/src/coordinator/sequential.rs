//! Sequential SGD: the paper's single-worker accuracy reference.
//!
//! A thin adapter over the unified event-driven loop ([`super::driver`]):
//! one worker, never gated, immediate commits. It runs under the virtual
//! clock too (no overlap to simulate), so its wallclock curve lands on the
//! same simulated-seconds axis as the parallel algorithms in Fig. 3.

use super::RunCtx;
use anyhow::Result;

pub fn run(ctx: &mut RunCtx) -> Result<()> {
    debug_assert_eq!(ctx.cfg.workers, 1, "sequential SGD is the M=1 protocol");
    super::driver::run(ctx, false)
}
