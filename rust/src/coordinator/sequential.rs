//! Sequential SGD: the paper's single-worker accuracy reference.
//!
//! Runs under the virtual clock too (one worker, no overlap), so its
//! wallclock curve lands on the same simulated-seconds axis as the parallel
//! algorithms in Fig. 3.

use super::RunCtx;
use crate::data::{EpochPartition, ShardCursor};
use crate::metrics::StepRecord;
use crate::sim::DelaySampler;
use anyhow::Result;

pub fn run(ctx: &mut RunCtx) -> Result<()> {
    let n = ctx.ps.n();
    let mut params = vec![0.0f32; n];
    let partition = EpochPartition::new(ctx.cfg.seed ^ 0x5EED, ctx.train_set.len(), 1);
    let mut cursor = ShardCursor::new(partition, 0, ctx.batch_size);
    let mut delays = DelaySampler::new(ctx.cfg.delay.clone(), 1, ctx.cfg.seed);

    let mut step = 0u64;
    let mut samples = 0u64;
    let mut time = 0.0f64;
    let mut prev_passes = 0.0f64;

    loop {
        let passes = samples as f64 / ctx.train_set.len() as f64;
        if ctx.done(step, passes) {
            break;
        }
        let lr = ctx.lr_at(passes);
        ctx.ps.pull(0, &mut params);
        let batch = ctx.train_set.make_batch(&cursor.next_indices());
        let (loss, grads) = ctx.engine.train(&params, &batch)?;
        let outcome = ctx.ps.push(0, &grads, lr);
        debug_assert_eq!(outcome.staleness, 0);
        time += delays.sample(0);
        samples += ctx.batch_size as u64;
        let passes_now = samples as f64 / ctx.train_set.len() as f64;
        ctx.metrics.record_step(StepRecord {
            step,
            worker: 0,
            passes: passes_now,
            time,
            loss,
            lr,
            staleness: 0,
        });
        step += 1;
        if ctx.should_eval(prev_passes, passes_now, step) {
            ctx.run_eval(step, passes_now, time)?;
        }
        prev_passes = passes_now;
    }
    Ok(())
}
