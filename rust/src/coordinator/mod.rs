//! Training coordinator: wires the engine, datasets, parameter server, and
//! delay models into the paper's training protocols.
//!
//! All simulated-time protocols run through one event-driven loop
//! ([`driver`]) parameterized by a [`crate::sim::Protocol`]; the modules
//! below are thin adapters that pick the protocol:
//!
//! * [`sequential`] — single-worker SGD (the paper's accuracy reference),
//! * [`sync`] — SSGD / DC-SSGD barrier rounds,
//! * [`async_`] — ASGD / DC-ASGD / SSP / DC-S3GD, as a discrete-event
//!   simulation (deterministic virtual wallclock; default) or — ASGD
//!   family only — as real racing threads.

pub mod async_;
pub mod driver;
pub mod sequential;
pub mod sync;

use crate::compress::WorkerCompressor;
use crate::config::{Algorithm, ExecMode, ExperimentConfig, UpdateBackend};
use crate::data::{build_dataset, Dataset};
use crate::eval::evaluate;
use crate::metrics::{EvalRecord, MetricsLog, TrainReport};
use crate::ps::{NativeKernel, ParamServer, UpdateKernel};
use crate::runtime::{start_engine, EngineHandle, XlaUpdateKernel};
use crate::util::pool::ComputePool;
use anyhow::{Context, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// First-error slot shared by racing worker threads and the monitor: the
/// earliest failure wins and is returned from the training run.
pub(crate) struct FirstError(Mutex<Option<anyhow::Error>>);

impl FirstError {
    pub fn new() -> Self {
        Self(Mutex::new(None))
    }

    /// Record `e` unless an earlier error already claimed the slot.
    pub fn set(&self, e: anyhow::Error) {
        let mut slot = self.0.lock().unwrap();
        if slot.is_none() {
            *slot = Some(e);
        }
    }

    pub fn take(self) -> Option<anyhow::Error> {
        self.0.into_inner().unwrap()
    }
}

/// Push-progress signal for the threads-mode monitor: workers bump a
/// counter under a lock and notify; the monitor parks on the condvar
/// instead of busy-sleeping. Notification happens while holding the same
/// mutex the waiter uses, so wakeups cannot be missed.
pub(crate) struct Progress {
    pushes: Mutex<u64>,
    cvar: Condvar,
}

impl Progress {
    pub fn new() -> Self {
        Self { pushes: Mutex::new(0), cvar: Condvar::new() }
    }

    /// Bump the counter and wake the monitor.
    pub fn bump(&self) {
        let mut g = self.pushes.lock().unwrap();
        *g += 1;
        self.cvar.notify_all();
    }

    /// Park until the counter moves past `seen` or `stop` is set; returns
    /// the counter value observed on wakeup.
    pub fn wait_past(&self, seen: u64, stop: &AtomicBool) -> u64 {
        let mut g = self.pushes.lock().unwrap();
        while *g <= seen && !stop.load(Ordering::Relaxed) {
            g = self.cvar.wait(g).unwrap();
        }
        *g
    }
}

/// Everything a training loop needs.
pub struct RunCtx {
    pub cfg: ExperimentConfig,
    pub engine: EngineHandle,
    pub ps: Arc<ParamServer>,
    pub train_set: Arc<dyn Dataset>,
    pub test_set: Arc<dyn Dataset>,
    pub metrics: MetricsLog,
    /// Examples per gradient (the artifact's batch size).
    pub batch_size: usize,
    /// Gradient compression ([compress]): one codec + error-feedback
    /// residual + payload arena per worker; empty when compression is off.
    /// Lives on the context (not the driver loop) so checkpoints can
    /// capture the residuals and resume can re-seed them.
    pub compressors: Vec<WorkerCompressor>,
    /// The run's persistent compute pool (`[runtime] threads`): one set of
    /// worker threads serving both the sharded store's multi-shard applies
    /// and the driver's pipelined gradient stage.
    pub pool: Arc<ComputePool>,
    /// Filled by the event-driven driver when `[trace]` is enabled: the
    /// merged run-trace event stream and telemetry rows, written as
    /// artifacts by [`Trainer::run_logged`]. `None` with tracing off.
    pub trace_out: Option<crate::trace::TraceOut>,
}

impl RunCtx {
    /// Learning rate at the given effective-pass count (epoch-indexed
    /// step-decay schedule, paper §6).
    pub fn lr_at(&self, passes: f64) -> f32 {
        self.cfg.lr.lr_at_epoch(passes.floor().max(0.0) as usize) as f32
    }

    /// Evaluate the current global model and record it.
    pub fn run_eval(&mut self, step: u64, passes: f64, time: f64) -> Result<()> {
        let mut params = vec![0.0f32; self.ps.n()];
        self.ps.snapshot(&mut params);
        let (loss, err) =
            evaluate(&self.engine, &params, self.test_set.as_ref(), self.cfg.eval_batches)?;
        if self.cfg.verbose {
            eprintln!(
                "[eval] step={step} passes={passes:.2} time={time:.1} loss={loss:.4} err={:.2}%",
                err * 100.0
            );
        }
        self.metrics.record_eval(EvalRecord {
            step,
            passes,
            time,
            test_loss: loss,
            test_error: err,
        });
        Ok(())
    }

    /// Should we stop? (passes-based epochs or step cap)
    pub fn done(&self, steps: u64, passes: f64) -> bool {
        if self.cfg.max_steps > 0 && steps >= self.cfg.max_steps as u64 {
            return true;
        }
        self.cfg.epochs > 0 && passes >= self.cfg.epochs as f64 && self.cfg.max_steps == 0
    }

    /// Eval-boundary helper: true when `passes` crossed an eval_every
    /// boundary between prev and now, or a step boundary was hit.
    pub fn should_eval(&self, prev_passes: f64, passes: f64, step: u64) -> bool {
        if self.cfg.eval_every_steps > 0 && step % self.cfg.eval_every_steps as u64 == 0 {
            return true;
        }
        if self.cfg.eval_every == 0 {
            return false;
        }
        let e = self.cfg.eval_every as f64;
        (prev_passes / e).floor() < (passes / e).floor()
    }
}

/// The public entry point: build a [`Trainer`] from a config and `run()` it.
pub struct Trainer {
    ctx: RunCtx,
}

impl Trainer {
    pub fn new(cfg: ExperimentConfig) -> Result<Self> {
        cfg.validate()?;
        let artifacts = crate::find_artifacts_dir()
            .context("artifacts/manifest.json not found — run `make artifacts`")?;
        let with_updates = cfg.update_backend == UpdateBackend::Xla;
        let engine = start_engine(&artifacts, &cfg.model, with_updates)?;
        Self::with_engine(cfg, engine, &artifacts)
    }

    /// Build against an already-started engine (benches reuse one engine
    /// across many runs to amortize PJRT compilation).
    pub fn with_engine(
        cfg: ExperimentConfig,
        engine: EngineHandle,
        artifacts: &std::path::Path,
    ) -> Result<Self> {
        cfg.validate()?;
        let entry = engine.entry().clone();
        let init = entry.load_init(artifacts)?;
        let kernel: Box<dyn UpdateKernel> = match cfg.update_backend {
            UpdateBackend::Native => Box::new(NativeKernel),
            UpdateBackend::Xla => Box::new(XlaUpdateKernel::new(engine.clone())),
        };
        // kernel dispatch for this run: chunked-SIMD vs scalar reference
        // (bit-identical either way — the knob trades wallclock only)
        crate::optim::set_simd_enabled(cfg.runtime.simd);
        // one persistent pool per run (threads = 0 shares the process-wide
        // auto-sized pool): the store's applies and the driver's pipelined
        // gradient stage draw from the same lanes
        let pool = crate::util::pool::pool_for_threads(cfg.runtime.threads);
        let ps =
            Arc::new(ParamServer::from_config_with_pool(&cfg, &init, kernel, Arc::clone(&pool))?);
        // one compressor (codec + EF residual + payload arena) per worker;
        // `none` builds nothing and the push path stays exactly dense.
        // TopK encodes shard-parallel on the run's compute pool.
        let mut compressors: Vec<WorkerCompressor> = (0..cfg.workers)
            .filter_map(|w| {
                WorkerCompressor::with_pool(
                    &cfg.compress,
                    init.len(),
                    cfg.seed,
                    w,
                    Some(Arc::clone(&pool)),
                )
            })
            .collect();
        debug_assert!(compressors.is_empty() || compressors.len() == cfg.workers);
        if !cfg.resume_from.is_empty() {
            let ck = crate::ps::Checkpoint::load(std::path::Path::new(&cfg.resume_from))?;
            anyhow::ensure!(
                ck.model == cfg.model,
                "checkpoint is for model {:?}, config wants {:?}",
                ck.model,
                cfg.model
            );
            ck.restore_into(&ps)?;
            // lossy compression resumes only from checkpoints that carry
            // the per-worker EF residuals (format v2); lossless codecs
            // have no residual state to restore
            crate::ps::check_ef_compat(&ck, &cfg.compress, cfg.workers)?;
            if !cfg.compress.is_lossless() {
                for (w, comp) in compressors.iter_mut().enumerate() {
                    comp.set_residual(&ck.ef[w]);
                }
            }
            log::info!("resumed from {} at version {}", cfg.resume_from, ck.version);
        }
        let train_set: Arc<dyn Dataset> = Arc::from(build_dataset(
            &cfg.dataset,
            entry.feature_kind(),
            entry.classes,
            true,
            cfg.train_size,
            cfg.seed,
        ));
        let test_set: Arc<dyn Dataset> = Arc::from(build_dataset(
            &cfg.dataset,
            entry.feature_kind(),
            entry.classes,
            false,
            cfg.test_size,
            cfg.seed,
        ));
        let metrics = MetricsLog::new(if cfg.train_size > 100_000 { 8 } else { 1 });
        Ok(Self {
            ctx: RunCtx {
                batch_size: entry.batch,
                cfg,
                engine,
                ps,
                train_set,
                test_set,
                metrics,
                compressors,
                pool,
                trace_out: None,
            },
        })
    }

    pub fn ctx(&self) -> &RunCtx {
        &self.ctx
    }

    /// Run to completion; returns the summary report and (optionally)
    /// writes the metrics bundle to `cfg.out_dir`.
    pub fn run(self) -> Result<TrainReport> {
        Ok(self.run_logged()?.0)
    }

    /// Like [`Self::run`], but also hands back the full metrics log so
    /// callers (trajectory tests, the SSP-spectrum bench) can compare step
    /// and eval curves directly instead of re-parsing CSV output.
    pub fn run_logged(mut self) -> Result<(TrainReport, MetricsLog)> {
        let algo = self.ctx.cfg.algorithm;
        // subsystem profiling rides the process-global span registry: arm
        // it per run (and disarm for untraced runs, so a traced run in the
        // same process never leaks spans into a later one)
        let profiling = self.ctx.cfg.trace.enabled && self.ctx.cfg.trace.profile;
        crate::trace::profile::set_enabled(profiling);
        if profiling {
            crate::trace::profile::reset();
        }
        match (algo, self.ctx.cfg.exec_mode) {
            (Algorithm::SequentialSgd, _) => sequential::run(&mut self.ctx)?,
            (Algorithm::SyncSgd | Algorithm::DcSyncSgd | Algorithm::HierSsgd, mode) => {
                sync::run(&mut self.ctx, mode)?
            }
            (_, ExecMode::SimulatedTime) => async_::run_sim(&mut self.ctx)?,
            (_, ExecMode::Threads) => async_::run_threads(&mut self.ctx)?,
        }
        // final eval if none recorded at the very end
        let last_step = self.ctx.metrics.steps.last().map(|r| (r.step, r.passes, r.time));
        if let Some((step, passes, time)) = last_step {
            let need = self.ctx.metrics.evals.last().map(|e| e.step < step).unwrap_or(true);
            if need {
                self.ctx.run_eval(step, passes, time)?;
            }
        }
        let report = self.ctx.metrics.report();
        if !self.ctx.cfg.checkpoint_out.is_empty() {
            let samples = (report.passes * self.ctx.cfg.train_size as f64) as u64;
            let mut ck = crate::ps::Checkpoint::capture(
                &self.ctx.ps,
                &self.ctx.cfg.model,
                self.ctx.cfg.algorithm.name(),
                samples,
            );
            if !self.ctx.cfg.compress.is_lossless() {
                // carry the per-worker EF residuals so a compressed run can
                // resume without dropping accumulated gradient mass
                ck = ck.with_ef(
                    self.ctx.compressors.iter().map(|c| c.residual().to_vec()).collect(),
                );
            }
            ck.save(std::path::Path::new(&self.ctx.cfg.checkpoint_out))?;
            // stamp the capture on the run trace at the final virtual time
            if let Some(out) = self.ctx.trace_out.as_mut() {
                let t = out.events.last().map(|e| e.t).unwrap_or(0.0);
                out.events.push(crate::trace::TraceEvent {
                    kind: crate::trace::EventKind::Checkpoint,
                    t,
                    wall: 0.0,
                    worker: None,
                    epoch: None,
                    tau: None,
                    value: None,
                });
            }
        }
        if profiling {
            crate::trace::profile::set_enabled(false);
        }
        if !self.ctx.cfg.out_dir.is_empty() {
            let name = if self.ctx.cfg.tag.is_empty() {
                format!("{}_{}_m{}", self.ctx.cfg.model, algo.name(), self.ctx.cfg.workers)
            } else {
                self.ctx.cfg.tag.clone()
            };
            let dir = std::path::Path::new(&self.ctx.cfg.out_dir);
            let profile = profiling.then(crate::trace::profile::snapshot_json);
            crate::metrics::write_run_full(
                dir,
                &name,
                &self.ctx.metrics,
                &self.ctx.cfg.to_json(),
                profile,
            )?;
            if let Some(out) = &self.ctx.trace_out {
                std::fs::create_dir_all(dir)?;
                if self.ctx.cfg.trace.events {
                    std::fs::write(
                        dir.join(format!("{name}.trace.jsonl")),
                        crate::trace::events_to_jsonl(&out.events),
                    )?;
                }
                if self.ctx.cfg.trace.chrome_trace {
                    std::fs::write(
                        dir.join(format!("{name}.trace.json")),
                        crate::trace::chrome::render(&out.events).to_string(),
                    )?;
                }
                std::fs::write(
                    dir.join(format!("{name}.timeseries.csv")),
                    crate::trace::rows_to_csv_with(&out.rows, &out.extra_cols, &out.extra_rows),
                )?;
            }
        }
        Ok((report, self.ctx.metrics))
    }
}
