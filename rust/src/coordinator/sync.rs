//! Synchronous SGD (barrier) and DC-SSGD (appendix H).
//!
//! Each round: all M workers compute gradients on the *same* model
//! snapshot; the barrier completes when the slowest finishes; the server
//! folds the M gradients into one step:
//!
//! * **SSGD**: average, one SGD step with the per-worker learning rate
//!   (the effective large batch is M×B),
//! * **DC-SSGD**: sequential delay-compensated fold (Eqn. 110/111),
//!   ordered by ascending gradient norm.
//!
//! Under the virtual clock, round time = max over workers of compute time —
//! which is exactly how the barrier drags SSGD in Fig. 3 when stragglers
//! exist. In threads mode the gradients still evaluate through the single
//! engine (1-core testbed); wall time is measured, not simulated.

use super::RunCtx;
use crate::config::{Algorithm, ExecMode};
use crate::data::{EpochPartition, ShardCursor};
use crate::metrics::StepRecord;
use crate::optim::{average_into, DcSsgdAccumulator};
use crate::sim::DelaySampler;
use anyhow::Result;

pub fn run(ctx: &mut RunCtx, mode: ExecMode) -> Result<()> {
    let m = ctx.cfg.workers;
    let n = ctx.ps.n();
    let partition = EpochPartition::new(ctx.cfg.seed ^ 0x5EED, ctx.train_set.len(), m);
    let mut cursors: Vec<ShardCursor> =
        (0..m).map(|w| ShardCursor::new(partition.clone(), w, ctx.batch_size)).collect();
    let mut delays = DelaySampler::new(ctx.cfg.delay.clone(), m, ctx.cfg.seed);
    let use_wall = mode == ExecMode::Threads;
    let wall_start = std::time::Instant::now();

    let dcssgd = ctx.cfg.algorithm == Algorithm::DcSyncSgd;
    let mut acc = DcSsgdAccumulator::new(n, ctx.cfg.lambda0 as f32);
    let mut params = vec![0.0f32; n];
    let mut avg = vec![0.0f32; n];

    let mut step = 0u64; // global rounds
    let mut samples = 0u64;
    let mut time = 0.0f64;
    let mut prev_passes = 0.0f64;

    loop {
        let passes = samples as f64 / ctx.train_set.len() as f64;
        if ctx.done(step, passes) {
            break;
        }
        let lr = ctx.lr_at(passes);
        // all workers share the same snapshot at the barrier
        ctx.ps.pull(0, &mut params);
        let mut grads: Vec<Vec<f32>> = Vec::with_capacity(m);
        let mut loss_sum = 0.0f32;
        let mut round_time = 0.0f64;
        for w in 0..m {
            let batch = ctx.train_set.make_batch(&cursors[w].next_indices());
            let (loss, g) = ctx.engine.train(&params, &batch)?;
            loss_sum += loss;
            round_time = round_time.max(delays.sample(w)); // barrier: slowest wins
            grads.push(g);
        }
        if dcssgd {
            for g in grads {
                acc.push(g);
            }
            ctx.ps.apply_with(|w| acc.apply(w, lr));
        } else {
            // Paper §1: each worker *adds* its gradient to the global model;
            // the barrier only synchronizes. One round therefore applies the
            // SUM of the M gradients (= average at M*lr), making the
            // effective step M x larger — the "enlarged mini-batch" effect
            // Table 1 attributes SSGD's degradation to.
            let refs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
            average_into(&mut avg, &refs);
            ctx.ps.apply_aggregated(&avg, lr * m as f32);
        }
        time += round_time;
        samples += (m * ctx.batch_size) as u64;
        let passes_now = samples as f64 / ctx.train_set.len() as f64;
        let rec_time = if use_wall { wall_start.elapsed().as_secs_f64() } else { time };
        ctx.metrics.record_step(StepRecord {
            step,
            worker: 0,
            passes: passes_now,
            time: rec_time,
            loss: loss_sum / m as f32,
            lr,
            staleness: 0, // barrier: no delayed gradients
        });
        step += 1;
        if ctx.should_eval(prev_passes, passes_now, step) {
            ctx.run_eval(step, passes_now, rec_time)?;
        }
        prev_passes = passes_now;
    }
    Ok(())
}
