//! Synchronous SGD (barrier), DC-SSGD (appendix H), and hier-SSGD.
//!
//! A thin adapter over the unified event-driven loop ([`super::driver`])
//! with the [`crate::sim::BarrierSync`] protocol: all M workers compute on
//! the same snapshot, the round completes when the slowest finishes, and
//! the server folds the M gradients into one step —
//!
//! * **SSGD**: average, one SGD step at `M * lr` (the effective large
//!   batch is M×B),
//! * **DC-SSGD**: sequential delay-compensated fold (Eqn. 110/111),
//!   ordered by ascending gradient norm,
//! * **hier-SSGD**: the SSGD rule with two-level aggregation over the
//!   `[topology]` rack layout — rack reducers sum their residents, the
//!   root folds one partial per rack. One rack degenerates to plain SSGD
//!   bit-for-bit.
//!
//! Under the virtual clock, round time = max over workers of compute time —
//! which is exactly how the barrier drags SSGD in Fig. 3 when stragglers
//! exist. In threads mode the gradients still evaluate through the single
//! engine (1-core testbed); wall time is measured, not simulated.

use super::RunCtx;
use crate::config::ExecMode;
use anyhow::Result;

pub fn run(ctx: &mut RunCtx, mode: ExecMode) -> Result<()> {
    super::driver::run(ctx, mode == ExecMode::Threads)
}
