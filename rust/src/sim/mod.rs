//! Discrete-event simulation substrate: a virtual clock + event queue.
//!
//! The wallclock figures (Fig. 3/4) are produced by replaying the cluster
//! *schedule* — who computes when, who waits at which barrier — under the
//! delay models in [`delay`]. Gradient values are computed for real (via the
//! PJRT engine); only *time* is simulated, so runs are deterministic and
//! hardware-independent. The schedule itself is produced by the
//! event-driven [`scheduler`]: a per-worker pull → compute → push lifecycle
//! gated by a pluggable synchronization [`Protocol`]. The [`faults`] module
//! adds the unhealthy-fleet regime — seeded crashes, restarts, permanent
//! departures, late joins, and transient straggler slowdowns — driven by
//! the same scheduler with first-class worker lifecycle (off by default;
//! bit-identical schedules when off). The [`serving`] module layers a
//! read-only inference workload (seeded arrival process + virtual-time
//! latency model) over the training schedule without perturbing it.

pub mod delay;
pub mod faults;
pub mod fleet;
pub mod scheduler;
pub mod serving;
pub mod topology;

pub use delay::{CommCosts, CommModel, DelaySampler};
pub use faults::{CrashPolicy, FaultConfig, FaultPlan, FaultStats};
pub use fleet::{BitSet, FleetIndex};
pub use scheduler::{
    BarrierSync, CommitMode, FullyAsync, GateSpec, Protocol, Scheduler, SimEvent, StalenessBounded,
};
pub use serving::{
    ArrivalKind, ArrivalProcess, ReadMode, ServingClock, ServingConfig, ServingRecorder,
    ServingSummary,
};
pub use topology::{Topology, TopologyConfig, UplinkMeter};

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// An event scheduled at a virtual time. Ties break by insertion sequence,
/// making the simulation fully deterministic.
#[derive(Debug)]
struct Scheduled<T> {
    time: f64,
    seq: u64,
    payload: T,
}

impl<T> PartialEq for Scheduled<T> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<T> Eq for Scheduled<T> {}

impl<T> Ord for Scheduled<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}
impl<T> PartialOrd for Scheduled<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Earliest-first event queue with a monotonically advancing clock.
#[derive(Debug)]
pub struct EventQueue<T> {
    heap: BinaryHeap<Scheduled<T>>,
    now: f64,
    seq: u64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        Self { heap: BinaryHeap::new(), now: 0.0, seq: 0 }
    }

    /// Pre-size the heap so a fleet's steady-state event population (one
    /// finish per computing worker plus the fault timeline) never
    /// reallocates mid-run: schedule/pop churn at 10k+ entries stays
    /// amortized O(log n) with zero allocation.
    pub fn with_capacity(cap: usize) -> Self {
        Self { heap: BinaryHeap::with_capacity(cap), now: 0.0, seq: 0 }
    }

    /// Current virtual time (time of the last popped event).
    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `payload` at absolute time `at` (must be >= now).
    pub fn schedule_at(&mut self, at: f64, payload: T) {
        debug_assert!(at >= self.now, "scheduling into the past: {at} < {}", self.now);
        self.heap.push(Scheduled { time: at.max(self.now), seq: self.seq, payload });
        self.seq += 1;
    }

    /// Schedule `payload` `delay` seconds from now.
    pub fn schedule_in(&mut self, delay: f64, payload: T) {
        debug_assert!(delay >= 0.0);
        self.schedule_at(self.now + delay, payload);
    }

    /// Pop the earliest event, advancing the clock to it.
    pub fn pop(&mut self) -> Option<(f64, T)> {
        let ev = self.heap.pop()?;
        self.now = ev.time;
        Some((ev.time, ev.payload))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(3.0, "c");
        q.schedule_at(1.0, "a");
        q.schedule_at(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
        assert_eq!(q.now(), 3.0);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.schedule_at(1.0, 1);
        q.schedule_at(1.0, 2);
        q.schedule_at(1.0, 3);
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.schedule_in(5.0, ());
        q.schedule_in(1.0, ());
        let (t1, _) = q.pop().unwrap();
        let (t2, _) = q.pop().unwrap();
        assert!(t1 <= t2);
        assert_eq!(q.now(), 5.0);
        // scheduling relative to the advanced clock
        q.schedule_in(0.5, ());
        let (t3, _) = q.pop().unwrap();
        assert_eq!(t3, 5.5);
    }

    #[test]
    fn churn_at_ten_thousand_entries_stays_ordered_without_realloc() {
        // fleet-scale churn: keep 10k events in flight, popping one and
        // scheduling one per step. With the pre-sized heap the capacity
        // never grows, and time order + tie order survive the churn.
        let n = 10_000usize;
        let mut q = EventQueue::with_capacity(n + 1);
        let cap0 = q.heap.capacity();
        for i in 0..n {
            q.schedule_at(i as f64 * 0.5, i);
        }
        let mut last_t = -1.0f64;
        for step in 0..50_000usize {
            let (t, _) = q.pop().unwrap();
            assert!(t >= last_t, "time order broke under churn");
            last_t = t;
            q.schedule_in(((step % 97) as f64) * 0.25, n + step);
            assert_eq!(q.len(), n);
        }
        assert_eq!(q.heap.capacity(), cap0, "steady-state churn reallocated the heap");
    }

    #[test]
    fn interleaved_schedule_pop() {
        // a worker loop: each pop schedules the next event
        let mut q = EventQueue::new();
        q.schedule_at(0.5, 0usize);
        let mut count = 0;
        while let Some((_, worker)) = q.pop() {
            count += 1;
            if count < 10 {
                q.schedule_in(0.5 + worker as f64, worker);
            }
        }
        assert_eq!(count, 10);
        assert!((q.now() - 5.0).abs() < 1e-9);
    }
}
