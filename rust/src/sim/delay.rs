//! Worker compute-time models for the cluster simulator.
//!
//! The paper ran on a 4×K40-per-node InfiniBand cluster; we don't have one
//! (DESIGN.md §5), so simulated wallclock comes from these distributions.
//! The *shape* of the wallclock figures depends on the schedule they induce
//! (who finishes when, how stragglers stall the SSGD barrier), not absolute
//! GPU speed.

use crate::config::DelayModel;
use crate::util::rng::Pcg64;

/// Samples per-gradient compute durations (simulated seconds) per worker.
#[derive(Clone, Debug)]
pub struct DelaySampler {
    model: DelayModel,
    rngs: Vec<Pcg64>,
}

impl DelaySampler {
    pub fn new(model: DelayModel, workers: usize, seed: u64) -> Self {
        let mut root = Pcg64::new(seed ^ 0xDE1A_1234);
        let rngs = (0..workers).map(|m| root.fork(m as u64)).collect();
        Self { model, rngs }
    }

    /// Duration of worker `m`'s next gradient computation.
    pub fn sample(&mut self, worker: usize) -> f64 {
        let rng = &mut self.rngs[worker];
        match &self.model {
            DelayModel::Constant { mean } => *mean,
            DelayModel::Uniform { mean, jitter } => {
                rng.uniform(mean * (1.0 - jitter), mean * (1.0 + jitter))
            }
            DelayModel::Exponential { mean } => rng.exponential(*mean),
            DelayModel::Pareto { scale, alpha } => rng.pareto(*scale, *alpha),
            DelayModel::Heterogeneous { mean, speeds, jitter } => {
                let s = speeds[worker % speeds.len()];
                let base = mean * s;
                rng.uniform(base * (1.0 - jitter), base * (1.0 + jitter))
            }
        }
    }

    pub fn workers(&self) -> usize {
        self.rngs.len()
    }
}

/// Communication overhead model: fixed per-push cost plus per-byte cost.
/// The paper reports DC-ASGD has *no extra communication* vs ASGD; the
/// server-side compensation compute is modelled separately in the DES.
/// Consulted by the [`crate::sim::Scheduler`] via [`CommCosts`] when the
/// `[comm]` config section is enabled.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CommModel {
    pub per_push: f64,
    pub per_mb: f64,
}

impl CommModel {
    pub fn infiniband_like() -> Self {
        // ~50us latency, ~5 GB/s effective
        Self { per_push: 50e-6, per_mb: 1.0 / 5000.0 }
    }

    pub fn ethernet_like() -> Self {
        // ~200us latency, ~1.2 GB/s effective (10 GbE after framing)
        Self { per_push: 200e-6, per_mb: 1.0 / 1200.0 }
    }

    pub fn cost(&self, bytes: usize) -> f64 {
        self.per_push + self.per_mb * bytes as f64 / 1e6
    }
}

/// Precomputed per-transfer virtual-time charges the scheduler adds to a
/// worker's turnaround: `push` per gradient upload, `pull` per model
/// download. The zero default reproduces the free-network schedule
/// bit-for-bit (adding 0.0 to a non-negative duration is exact in f64).
///
/// The transfer *sizes* ride along so the scheduler can account total
/// bytes on the wire — with gradient compression the push size is the
/// encoded wire size, not the dense vector ([`crate::compress`]). Sizes
/// are pure accounting: they never influence the schedule (only the
/// pre-multiplied `push`/`pull` charges do), so tracking them keeps the
/// comm-off schedule bit-identical.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CommCosts {
    /// Charge per gradient upload (simulated seconds).
    pub push: f64,
    /// Charge per model download (simulated seconds).
    pub pull: f64,
    /// Bytes per gradient upload (wire accounting only).
    pub push_bytes: usize,
    /// Bytes per model download (wire accounting only).
    pub pull_bytes: usize,
}

impl CommCosts {
    /// Derive the charges from a [`CommModel`] and the transfer sizes.
    pub fn from_model(model: &CommModel, push_bytes: usize, pull_bytes: usize) -> Self {
        Self { push: model.cost(push_bytes), pull: model.cost(pull_bytes), push_bytes, pull_bytes }
    }

    /// Free transfers (zero time charge) that still account their sizes —
    /// the `[comm]`-disabled case, where bytes-on-wire stays reportable.
    pub fn sized(push_bytes: usize, pull_bytes: usize) -> Self {
        Self { push_bytes, pull_bytes, ..Self::default() }
    }

    pub fn is_free(&self) -> bool {
        self.push == 0.0 && self.pull == 0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_constant() {
        let mut s = DelaySampler::new(DelayModel::Constant { mean: 2.5 }, 3, 1);
        for m in 0..3 {
            for _ in 0..5 {
                assert_eq!(s.sample(m), 2.5);
            }
        }
    }

    #[test]
    fn uniform_respects_jitter_bounds() {
        let mut s = DelaySampler::new(DelayModel::Uniform { mean: 1.0, jitter: 0.2 }, 2, 2);
        for _ in 0..500 {
            let d = s.sample(0);
            assert!((0.8..=1.2).contains(&d), "{d}");
        }
    }

    #[test]
    fn heterogeneous_speeds_separate_workers() {
        let model = DelayModel::Heterogeneous {
            mean: 1.0,
            speeds: vec![1.0, 3.0],
            jitter: 0.0,
        };
        let mut s = DelaySampler::new(model, 4, 3);
        assert_eq!(s.sample(0), 1.0);
        assert_eq!(s.sample(1), 3.0);
        assert_eq!(s.sample(2), 1.0); // wraps around speeds
        assert_eq!(s.sample(3), 3.0);
    }

    #[test]
    fn per_worker_streams_deterministic_and_distinct() {
        let model = DelayModel::Exponential { mean: 1.0 };
        let mut a = DelaySampler::new(model.clone(), 2, 9);
        let mut b = DelaySampler::new(model, 2, 9);
        let xs: Vec<f64> = (0..10).map(|_| a.sample(0)).collect();
        let ys: Vec<f64> = (0..10).map(|_| b.sample(0)).collect();
        assert_eq!(xs, ys);
        let zs: Vec<f64> = (0..10).map(|_| b.sample(1)).collect();
        assert_ne!(ys, zs);
    }

    #[test]
    fn pareto_stragglers_exist() {
        let mut s = DelaySampler::new(DelayModel::Pareto { scale: 1.0, alpha: 1.5 }, 1, 5);
        let samples: Vec<f64> = (0..5000).map(|_| s.sample(0)).collect();
        let max = samples.iter().cloned().fold(0.0, f64::max);
        let med = crate::util::stats::percentile(&samples, 50.0);
        assert!(max > 5.0 * med, "expected heavy tail: max={max} med={med}");
        assert!(samples.iter().all(|&d| d >= 1.0));
    }

    #[test]
    fn comm_model_monotone_in_bytes() {
        let c = CommModel::infiniband_like();
        assert!(c.cost(1_000_000) > c.cost(1_000));
        assert!(c.cost(0) > 0.0);
        assert!(CommModel::ethernet_like().cost(1 << 20) > c.cost(1 << 20));
    }

    #[test]
    fn comm_costs_derive_from_model_and_sizes() {
        let model = CommModel { per_push: 1e-4, per_mb: 1e-3 };
        let costs = CommCosts::from_model(&model, 2_000_000, 500_000);
        assert!((costs.push - (1e-4 + 2.0 * 1e-3)).abs() < 1e-12);
        assert!((costs.pull - (1e-4 + 0.5 * 1e-3)).abs() < 1e-12);
        assert_eq!((costs.push_bytes, costs.pull_bytes), (2_000_000, 500_000));
        assert!(!costs.is_free());
        assert!(CommCosts::default().is_free());
    }

    #[test]
    fn sized_costs_are_free_but_account_bytes() {
        let c = CommCosts::sized(1234, 5678);
        assert!(c.is_free());
        assert_eq!((c.push_bytes, c.pull_bytes), (1234, 5678));
    }
}
