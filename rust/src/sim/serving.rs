//! Serving workload: inference pulls against a live training PS.
//!
//! The north star is a store that serves read traffic *while* training
//! pushes land. This module provides the workload half of that story for
//! the discrete-event simulator:
//!
//! * [`ServingConfig`] — the `[serving]` section: publish cadence for the
//!   epoch snapshot plane ([`crate::ps::SnapshotPlane`]), arrival process
//!   shape, batch size, and which read path queries use;
//! * [`ArrivalProcess`] — a seeded arrival-time generator on the virtual
//!   clock (homogeneous Poisson, bursty square-wave, or diurnal sinusoid,
//!   all via Lewis–Shedler thinning against the peak rate), plus the query
//!   ranges each arrival asks for;
//! * [`ServingClock`] — the deterministic virtual-time latency model:
//!   snapshot reads cost pure service time; locked reads additionally
//!   queue behind the store's push-apply windows (each training push
//!   occupies the store for the driver's `server_cost`, and a locked read
//!   arriving inside a busy window waits it out);
//! * [`ServingRecorder`] — per-pull latency + snapshot staleness samples
//!   folded into a [`ServingSummary`] (nearest-rank p50/p99/p999, epoch
//!   lag in steps and virtual seconds) for `TrainReport`/`summary.json`.
//!
//! The workload is strictly an *observer* of training: arrivals are
//! processed between scheduler events and never enter the scheduler's
//! queue, so a serving-enabled run replays the exact training schedule —
//! push traces and final model bits bitwise-identical to serving-off
//! (pinned in `tests/serving.rs`).

use crate::util::rng::Pcg64;
use std::ops::Range;

/// How serving queries read the store.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReadMode {
    /// Wait-free reads from the epoch-published snapshot plane.
    Snapshot,
    /// Per-shard read locks against the live model (the contention
    /// baseline the snapshot plane exists to beat).
    Locked,
}

impl ReadMode {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "snapshot" | "epoch" => ReadMode::Snapshot,
            "locked" | "lock" => ReadMode::Locked,
            other => anyhow::bail!("unknown serving read_mode {other:?} (snapshot|locked)"),
        })
    }
    pub fn name(&self) -> &'static str {
        match self {
            ReadMode::Snapshot => "snapshot",
            ReadMode::Locked => "locked",
        }
    }
}

/// Shape of the arrival process.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArrivalKind {
    /// Homogeneous Poisson at `rate` arrivals per virtual second.
    Poisson,
    /// Square wave: `rate * burst` inside the first quarter of each
    /// `period`, `rate` otherwise.
    Bursty,
    /// Sinusoid sweeping [rate, rate * burst] once per `period`.
    Diurnal,
}

impl ArrivalKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "poisson" => ArrivalKind::Poisson,
            "bursty" | "burst" => ArrivalKind::Bursty,
            "diurnal" => ArrivalKind::Diurnal,
            other => anyhow::bail!("unknown arrival process {other:?} (poisson|bursty|diurnal)"),
        })
    }
    pub fn name(&self) -> &'static str {
        match self {
            ArrivalKind::Poisson => "poisson",
            ArrivalKind::Bursty => "bursty",
            ArrivalKind::Diurnal => "diurnal",
        }
    }
}

/// The `[serving]` section. Off by default and bitwise-inert: with
/// `enabled = false` no snapshot plane is built, no arrivals are drawn,
/// and every existing run is bit-identical to pre-serving builds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ServingConfig {
    pub enabled: bool,
    /// Publish a fresh serving snapshot every this many global training
    /// steps (virtual steps — publication rides the commit path).
    pub publish_every: usize,
    /// Base arrival rate in pulls per virtual second.
    pub rate: f64,
    pub arrival: ArrivalKind,
    /// Peak multiplier for bursty/diurnal shapes (ignored by poisson).
    pub burst: f64,
    /// Cycle length of the bursty/diurnal shapes, virtual seconds.
    pub period: f64,
    /// Queries per arrival (each arrival is one batched pull).
    pub batch: usize,
    pub read_mode: ReadMode,
    /// Seed of the arrival/query stream (independent of the train seed).
    pub seed: u64,
}

impl Default for ServingConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            publish_every: 4,
            rate: 2.0,
            arrival: ArrivalKind::Poisson,
            burst: 4.0,
            period: 8.0,
            batch: 8,
            read_mode: ReadMode::Snapshot,
            seed: 77,
        }
    }
}

/// Elements per query range (clamped to the model size). Fixed so the
/// byte volume per pull is a constant of the config, not of the RNG.
pub const QUERY_LEN: usize = 256;

/// Virtual service time charged per batched pull (amortized batch setup:
/// one epoch acquisition / one lock walk).
pub const SERVE_PER_BATCH: f64 = 1e-4;
/// Additional virtual service time per query inside the batch.
pub const SERVE_PER_QUERY: f64 = 1e-5;

/// Seeded arrival-time + query generator on the virtual clock.
///
/// Non-homogeneous shapes use Lewis–Shedler thinning against the peak
/// rate, so every shape consumes the RNG identically per *candidate* and
/// the stream is a pure function of (config, seed).
#[derive(Clone, Debug)]
pub struct ArrivalProcess {
    cfg: ServingConfig,
    rng: Pcg64,
    /// Absolute virtual time of the last generated arrival.
    t: f64,
}

impl ArrivalProcess {
    pub fn new(cfg: ServingConfig) -> Self {
        Self { cfg, rng: Pcg64::new(cfg.seed ^ 0x5e41_71f6_1e55), t: 0.0 }
    }

    /// Instantaneous rate λ(t) of the configured shape.
    pub fn rate_at(&self, t: f64) -> f64 {
        let c = &self.cfg;
        match c.arrival {
            ArrivalKind::Poisson => c.rate,
            ArrivalKind::Bursty => {
                let phase = t.rem_euclid(c.period);
                if phase < c.period * 0.25 {
                    c.rate * c.burst
                } else {
                    c.rate
                }
            }
            ArrivalKind::Diurnal => {
                let s = (2.0 * std::f64::consts::PI * t / c.period).sin();
                c.rate * (1.0 + (c.burst - 1.0) * 0.5 * (1.0 + s))
            }
        }
    }

    /// Peak rate the thinning loop proposes at.
    fn peak_rate(&self) -> f64 {
        match self.cfg.arrival {
            ArrivalKind::Poisson => self.cfg.rate,
            ArrivalKind::Bursty | ArrivalKind::Diurnal => self.cfg.rate * self.cfg.burst.max(1.0),
        }
    }

    /// Absolute virtual time of the next arrival (strictly increasing).
    pub fn next_arrival(&mut self) -> f64 {
        let peak = self.peak_rate();
        loop {
            self.t += self.rng.exponential(1.0 / peak);
            let accept = self.rate_at(self.t) / peak;
            if self.rng.next_f64() < accept {
                return self.t;
            }
        }
    }

    /// Draw this arrival's query ranges: `batch` contiguous windows of
    /// [`QUERY_LEN`] (clamped to `n`) at seeded offsets. Appends to `out`
    /// after clearing it; returns the packed output length.
    pub fn draw_queries(&mut self, n: usize, out: &mut Vec<Range<usize>>) -> usize {
        out.clear();
        let len = QUERY_LEN.min(n.max(1));
        for _ in 0..self.cfg.batch {
            let start = self.rng.below((n.saturating_sub(len) + 1) as u64) as usize;
            out.push(start..start + len);
        }
        self.cfg.batch * len
    }
}

/// Deterministic virtual-time latency model for serving pulls.
///
/// Training pushes serialize on the store: push `k` finishing at event
/// time `t` occupies the apply path for `server_cost`, starting no earlier
/// than the previous push's window end. Locked reads arriving inside a
/// busy window wait for it to drain (that queueing is exactly the
/// contention the snapshot plane removes); snapshot reads never wait.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServingClock {
    /// Virtual time until which the push-apply path is busy.
    busy_until: f64,
}

impl ServingClock {
    /// Record a training push applying at event time `t` for `cost`.
    pub fn on_push(&mut self, t: f64, cost: f64) {
        let start = self.busy_until.max(t);
        self.busy_until = start + cost;
    }

    /// Latency of a batched pull arriving at `t`: service time plus (in
    /// locked mode only) the wait behind the current push-apply window.
    pub fn pull_latency(&self, t: f64, mode: ReadMode, batch: usize) -> f64 {
        let service = SERVE_PER_BATCH + batch as f64 * SERVE_PER_QUERY;
        match mode {
            ReadMode::Snapshot => service,
            ReadMode::Locked => (self.busy_until - t).max(0.0) + service,
        }
    }
}

/// Summary statistics of a serving run, destined for `TrainReport`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ServingSummary {
    /// Batched pulls served.
    pub pulls: u64,
    /// Snapshot publications (epochs) over the run.
    pub published: u64,
    pub lat_p50: f64,
    pub lat_p99: f64,
    pub lat_p999: f64,
    /// Mean / max snapshot staleness in training steps at pull time.
    pub stale_steps_mean: f64,
    pub stale_steps_max: u64,
    /// Mean / max snapshot staleness in virtual seconds at pull time.
    pub stale_time_mean: f64,
    pub stale_time_max: f64,
}

/// Accumulates per-pull samples and folds them into a [`ServingSummary`].
#[derive(Clone, Debug, Default)]
pub struct ServingRecorder {
    latencies: Vec<f64>,
    published: u64,
    stale_steps_sum: f64,
    stale_steps_max: u64,
    stale_time_sum: f64,
    stale_time_max: f64,
    stale_n: u64,
    /// Pulls and latency-sum inside the current timeseries window.
    win_pulls: u64,
    win_lat_sum: f64,
}

impl ServingRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn on_publish(&mut self) {
        self.published += 1;
    }

    /// Record one served batched pull. `stale_steps`/`stale_time` are the
    /// snapshot's lag behind the training frontier at pull time (both 0
    /// for locked reads, which see the live model).
    pub fn on_pull(&mut self, latency: f64, stale_steps: u64, stale_time: f64) {
        self.latencies.push(latency);
        self.stale_steps_sum += stale_steps as f64;
        self.stale_steps_max = self.stale_steps_max.max(stale_steps);
        self.stale_time_sum += stale_time;
        if stale_time > self.stale_time_max {
            self.stale_time_max = stale_time;
        }
        self.stale_n += 1;
        self.win_pulls += 1;
        self.win_lat_sum += latency;
    }

    pub fn pulls(&self) -> u64 {
        self.latencies.len() as u64
    }

    /// Drain the current timeseries window: (pulls, mean latency).
    pub fn take_window(&mut self) -> (u64, f64) {
        let out = (
            self.win_pulls,
            if self.win_pulls > 0 { self.win_lat_sum / self.win_pulls as f64 } else { 0.0 },
        );
        self.win_pulls = 0;
        self.win_lat_sum = 0.0;
        out
    }

    pub fn summary(&self) -> ServingSummary {
        let mut lat = self.latencies.clone();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = self.stale_n.max(1) as f64;
        ServingSummary {
            pulls: self.latencies.len() as u64,
            published: self.published,
            lat_p50: percentile(&lat, 0.50),
            lat_p99: percentile(&lat, 0.99),
            lat_p999: percentile(&lat, 0.999),
            stale_steps_mean: self.stale_steps_sum / n,
            stale_steps_max: self.stale_steps_max,
            stale_time_mean: self.stale_time_sum / n,
            stale_time_max: self.stale_time_max,
        }
    }
}

/// Nearest-rank percentile of an ascending-sorted slice (the same
/// convention as `metrics::staleness_summary`): rank `ceil(n * q)`,
/// clamped to at least 1. Empty input yields 0.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((sorted.len() as f64 * q).ceil() as usize).max(1);
    sorted[rank.min(sorted.len()) - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(kind: ArrivalKind) -> ServingConfig {
        ServingConfig { enabled: true, arrival: kind, ..ServingConfig::default() }
    }

    #[test]
    fn arrivals_are_seeded_and_strictly_increasing() {
        for kind in [ArrivalKind::Poisson, ArrivalKind::Bursty, ArrivalKind::Diurnal] {
            let mut a = ArrivalProcess::new(cfg(kind));
            let mut b = ArrivalProcess::new(cfg(kind));
            let xs: Vec<f64> = (0..200).map(|_| a.next_arrival()).collect();
            let ys: Vec<f64> = (0..200).map(|_| b.next_arrival()).collect();
            assert_eq!(xs, ys, "{kind:?} not deterministic");
            assert!(xs.windows(2).all(|w| w[1] > w[0]), "{kind:?} not increasing");
            let mut c = ArrivalProcess::new(ServingConfig { seed: 1234, ..cfg(kind) });
            assert_ne!(xs[0], c.next_arrival(), "{kind:?} ignores the seed");
        }
    }

    #[test]
    fn poisson_rate_is_roughly_honored() {
        let mut p =
            ArrivalProcess::new(ServingConfig { rate: 50.0, ..cfg(ArrivalKind::Poisson) });
        let mut count = 0usize;
        loop {
            if p.next_arrival() > 100.0 {
                break;
            }
            count += 1;
        }
        // ~5000 expected; Poisson sd ~71
        assert!((4500..5500).contains(&count), "count={count}");
    }

    #[test]
    fn shaped_rates_stay_inside_their_envelope() {
        let c = ServingConfig { rate: 10.0, burst: 4.0, period: 8.0, ..cfg(ArrivalKind::Diurnal) };
        let p = ArrivalProcess::new(c);
        for i in 0..800 {
            let r = p.rate_at(i as f64 * 0.1);
            assert!((10.0 - 1e-9..=40.0 + 1e-9).contains(&r), "diurnal rate {r}");
        }
        let c = ServingConfig { rate: 10.0, burst: 4.0, period: 8.0, ..cfg(ArrivalKind::Bursty) };
        let p = ArrivalProcess::new(c);
        // burst quarter at the head of each period
        assert_eq!(p.rate_at(0.5), 40.0);
        assert_eq!(p.rate_at(1.99), 40.0);
        assert_eq!(p.rate_at(2.0), 10.0);
        assert_eq!(p.rate_at(7.9), 10.0);
        assert_eq!(p.rate_at(8.3), 40.0);
    }

    #[test]
    fn queries_are_in_bounds_and_deterministic() {
        let mut a = ArrivalProcess::new(cfg(ArrivalKind::Poisson));
        let mut b = ArrivalProcess::new(cfg(ArrivalKind::Poisson));
        let mut qa = Vec::new();
        let mut qb = Vec::new();
        for n in [10_000usize, 300, 17, 1] {
            let len_a = a.draw_queries(n, &mut qa);
            let len_b = b.draw_queries(n, &mut qb);
            assert_eq!(qa, qb);
            assert_eq!(len_a, len_b);
            assert_eq!(qa.len(), 8, "batch default");
            assert_eq!(len_a, qa.iter().map(|q| q.len()).sum::<usize>());
            for q in &qa {
                assert!(q.end <= n && q.len() == QUERY_LEN.min(n));
            }
        }
    }

    #[test]
    fn locked_reads_wait_behind_push_windows_and_snapshot_reads_do_not() {
        let mut clk = ServingClock::default();
        let service = SERVE_PER_BATCH + 8.0 * SERVE_PER_QUERY;
        // idle store: both modes cost pure service time
        assert_eq!(clk.pull_latency(1.0, ReadMode::Locked, 8), service);
        assert_eq!(clk.pull_latency(1.0, ReadMode::Snapshot, 8), service);
        // two pushes land back to back: windows chain serially
        clk.on_push(2.0, 0.5);
        clk.on_push(2.1, 0.5); // starts at 2.5, ends 3.0
        let lat = clk.pull_latency(2.2, ReadMode::Locked, 8);
        assert!((lat - (0.8 + service)).abs() < 1e-12, "lat={lat}");
        assert_eq!(clk.pull_latency(2.2, ReadMode::Snapshot, 8), service);
        // after the windows drain, locked waits vanish
        assert_eq!(clk.pull_latency(3.5, ReadMode::Locked, 8), service);
    }

    #[test]
    fn recorder_percentiles_use_nearest_rank() {
        let sorted: Vec<f64> = (1..=1000).map(|i| i as f64).collect();
        assert_eq!(percentile(&sorted, 0.50), 500.0);
        assert_eq!(percentile(&sorted, 0.99), 990.0);
        assert_eq!(percentile(&sorted, 0.999), 999.0);
        assert_eq!(percentile(&[], 0.99), 0.0);
        assert_eq!(percentile(&[7.0], 0.5), 7.0);

        let mut rec = ServingRecorder::new();
        for i in 1..=100 {
            rec.on_pull(i as f64, (i % 5) as u64, i as f64 * 0.01);
        }
        rec.on_publish();
        rec.on_publish();
        let s = rec.summary();
        assert_eq!(s.pulls, 100);
        assert_eq!(s.published, 2);
        assert_eq!(s.lat_p50, 50.0);
        assert_eq!(s.lat_p99, 99.0);
        assert_eq!(s.lat_p999, 100.0);
        assert_eq!(s.stale_steps_max, 4);
        assert!((s.stale_time_max - 1.0).abs() < 1e-12);
        // timeseries window drains and resets
        let (n, mean) = rec.take_window();
        assert_eq!(n, 100);
        assert!((mean - 50.5).abs() < 1e-9);
        assert_eq!(rec.take_window(), (0, 0.0));
    }

    #[test]
    fn parse_roundtrips() {
        for k in [ArrivalKind::Poisson, ArrivalKind::Bursty, ArrivalKind::Diurnal] {
            assert_eq!(ArrivalKind::parse(k.name()).unwrap(), k);
        }
        for m in [ReadMode::Snapshot, ReadMode::Locked] {
            assert_eq!(ReadMode::parse(m.name()).unwrap(), m);
        }
        assert!(ArrivalKind::parse("warp").is_err());
        assert!(ReadMode::parse("warp").is_err());
    }
}
