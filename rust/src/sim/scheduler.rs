//! Event-driven scheduler: the per-worker lifecycle (pull → compute → push)
//! under a pluggable synchronization [`Protocol`].
//!
//! The scheduler owns *time* (the [`EventQueue`] virtual clock), the
//! per-worker compute-duration streams ([`DelaySampler`]), the per-worker
//! logical clocks (completed local steps), and the wait/gate accounting.
//! It deliberately knows nothing about gradients, models, or the parameter
//! server: the coordinator drives it event-at-a-time —
//!
//! ```text
//! for w in sched.start()          { pull snapshot for w }
//! while let Some((t, w)) = sched.next() {
//!     compute gradient on w's snapshot; commit it (push or barrier fold);
//!     for v in sched.complete(w)  { pull fresh snapshot for v }
//! }
//! ```
//!
//! — which keeps the core testable without any compiled artifacts (see the
//! property tests in `tests/properties.rs`).
//!
//! A [`Protocol`] decides, each time a worker could begin a new compute,
//! whether it may proceed or must wait, and whether finished gradients
//! commit immediately (one global step per push) or fold at a barrier
//! (one global step per round). The paper's sync↔async spectrum becomes a
//! one-parameter family:
//!
//! | protocol                  | gate (clock drift)     | commit    |
//! |---------------------------|------------------------|-----------|
//! | [`FullyAsync`]            | never waits            | immediate |
//! | [`StalenessBounded`] (s)  | `clock - min <= s`     | immediate |
//! | [`BarrierSync`]           | all clocks equal       | barrier   |
//!
//! `StalenessBounded` is stale-synchronous parallel (SSP): with `s = 0`
//! every worker computes exactly once per round on the same snapshot (the
//! SSGD schedule); with `s` at least the largest drift the delay model can
//! produce it never gates and the schedule is bit-identical to ASGD. The
//! clock gate admits a worker only while it is at most `s` steps ahead of
//! the slowest; since an admitted step completes before re-checking, the
//! observed fastest-slowest drift is at most `s + 1`, which in turn bounds
//! the version staleness any push can observe by
//! `(workers - 1) * (2s + 1)` (see [`StalenessBounded::version_bound`]).

use super::delay::DelaySampler;
use super::EventQueue;

/// How finished gradients become global steps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommitMode {
    /// Every finished compute is pushed as its own global step.
    Immediate,
    /// Finished computes are buffered; the round commits as one step when
    /// the last worker arrives.
    Barrier,
}

/// A synchronization protocol: the policy half of the scheduler.
///
/// `clocks[w]` is the number of computes worker `w` has *completed*.
/// `may_start` is consulted every time worker `worker` is idle and could
/// begin another compute; returning `false` leaves it gated until another
/// worker's completion changes the clock vector.
pub trait Protocol: Send {
    fn name(&self) -> &'static str;
    fn commit_mode(&self) -> CommitMode {
        CommitMode::Immediate
    }
    fn may_start(&self, worker: usize, clocks: &[u64]) -> bool;
}

/// ASGD-family schedule: nobody ever waits.
#[derive(Clone, Copy, Debug, Default)]
pub struct FullyAsync;

impl Protocol for FullyAsync {
    fn name(&self) -> &'static str {
        "async"
    }
    fn may_start(&self, _worker: usize, _clocks: &[u64]) -> bool {
        true
    }
}

/// SSGD-family schedule: a full barrier every round; gradients fold into a
/// single aggregated step.
#[derive(Clone, Copy, Debug, Default)]
pub struct BarrierSync;

impl Protocol for BarrierSync {
    fn name(&self) -> &'static str {
        "barrier"
    }
    fn commit_mode(&self) -> CommitMode {
        CommitMode::Barrier
    }
    fn may_start(&self, worker: usize, clocks: &[u64]) -> bool {
        let c = clocks[worker];
        clocks.iter().all(|&k| k == c)
    }
}

/// Stale-synchronous parallel: a worker may run at most `bound` local steps
/// ahead of the slowest worker.
#[derive(Clone, Copy, Debug)]
pub struct StalenessBounded {
    pub bound: u64,
}

impl StalenessBounded {
    /// Upper bound on the version staleness (intervening pushes between a
    /// worker's pull and its push) this gate permits: while a worker is in
    /// flight at clock `c`, every peer's clock lives in `[c - s, c + s + 1]`,
    /// so each peer contributes at most `2s + 1` pushes.
    pub fn version_bound(&self, workers: usize) -> u64 {
        (workers.saturating_sub(1) as u64)
            .saturating_mul(self.bound.saturating_mul(2).saturating_add(1))
    }
}

impl Protocol for StalenessBounded {
    fn name(&self) -> &'static str {
        "ssp"
    }
    fn may_start(&self, worker: usize, clocks: &[u64]) -> bool {
        let min = clocks.iter().copied().min().unwrap_or(0);
        clocks[worker] - min <= self.bound
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum WorkerState {
    Computing,
    /// Finished its last compute; gated by the protocol since the stored
    /// virtual time.
    Blocked,
}

/// The event-driven scheduler core. See the module docs for the driving
/// contract.
pub struct Scheduler {
    protocol: Box<dyn Protocol>,
    queue: EventQueue<usize>,
    delays: DelaySampler,
    clocks: Vec<u64>,
    state: Vec<WorkerState>,
    blocked_since: Vec<f64>,
    /// Gate wait charged to each worker's *current/most recent* compute.
    step_wait: Vec<f64>,
    wait_total: Vec<f64>,
    /// Simulated server-side cost charged before each compute after the
    /// first (the paper's "lightweight overhead" of the update rule).
    server_cost: f64,
    workers: usize,
    started: bool,
}

impl Scheduler {
    pub fn new(protocol: Box<dyn Protocol>, delays: DelaySampler, server_cost: f64) -> Self {
        let workers = delays.workers();
        assert!(workers >= 1);
        Self {
            protocol,
            queue: EventQueue::new(),
            delays,
            clocks: vec![0; workers],
            state: vec![WorkerState::Blocked; workers],
            blocked_since: vec![0.0; workers],
            step_wait: vec![0.0; workers],
            wait_total: vec![0.0; workers],
            server_cost,
            workers,
            started: false,
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }
    pub fn commit_mode(&self) -> CommitMode {
        self.protocol.commit_mode()
    }
    pub fn protocol_name(&self) -> &'static str {
        self.protocol.name()
    }
    /// Current virtual time (time of the last popped finish event).
    pub fn now(&self) -> f64 {
        self.queue.now()
    }
    /// Completed local steps per worker.
    pub fn clocks(&self) -> &[u64] {
        &self.clocks
    }
    /// Total gate-wait accumulated per worker (simulated seconds).
    pub fn wait_totals(&self) -> &[f64] {
        &self.wait_total
    }
    /// Gate wait that preceded `worker`'s current/most recent compute.
    pub fn step_wait(&self, worker: usize) -> f64 {
        self.step_wait[worker]
    }

    /// Launch every worker at t = 0 (no protocol can gate clock-0 starts).
    /// Returns the workers that must pull a snapshot, in worker order. The
    /// first compute carries no server cost, matching a cold cluster start.
    pub fn start(&mut self) -> Vec<usize> {
        assert!(!self.started, "scheduler already started");
        self.started = true;
        for w in 0..self.workers {
            self.state[w] = WorkerState::Computing;
            let d = self.delays.sample(w);
            self.queue.schedule_in(d, w);
        }
        (0..self.workers).collect()
    }

    /// Pop the next finish event: `(time, worker)` whose compute is done.
    pub fn next(&mut self) -> Option<(f64, usize)> {
        self.queue.pop()
    }

    /// Mark `worker`'s compute complete (after the caller committed or
    /// buffered its gradient) and restart every worker the protocol now
    /// admits. Returns the restarted workers in worker order; the caller
    /// must pull a fresh snapshot for each before its next finish event.
    pub fn complete(&mut self, worker: usize) -> Vec<usize> {
        debug_assert_eq!(self.state[worker], WorkerState::Computing);
        let now = self.queue.now();
        self.clocks[worker] += 1;
        self.state[worker] = WorkerState::Blocked;
        self.blocked_since[worker] = now;
        let mut restarted = Vec::new();
        for v in 0..self.workers {
            if self.state[v] == WorkerState::Blocked && self.protocol.may_start(v, &self.clocks) {
                let waited = now - self.blocked_since[v];
                self.step_wait[v] = waited;
                self.wait_total[v] += waited;
                self.state[v] = WorkerState::Computing;
                let d = self.delays.sample(v);
                self.queue.schedule_in(self.server_cost + d, v);
                restarted.push(v);
            }
        }
        restarted
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DelayModel;

    fn sampler(workers: usize, seed: u64) -> DelaySampler {
        DelaySampler::new(DelayModel::Uniform { mean: 1.0, jitter: 0.4 }, workers, seed)
    }

    /// Drive the scheduler with a synthetic push counter, returning the
    /// observed per-push version staleness and the max clock drift.
    fn drive(protocol: Box<dyn Protocol>, workers: usize, steps: usize, seed: u64) -> (Vec<u64>, u64) {
        let mut sched = Scheduler::new(protocol, sampler(workers, seed), 0.01);
        let mut version = 0u64;
        let mut pulled_at = vec![0u64; workers];
        for w in sched.start() {
            pulled_at[w] = version;
        }
        let mut staleness = Vec::new();
        let mut max_drift = 0u64;
        for _ in 0..steps {
            let (_, w) = sched.next().expect("scheduler ran dry");
            staleness.push(version - pulled_at[w]);
            version += 1;
            for v in sched.complete(w) {
                pulled_at[v] = version;
            }
            let min = sched.clocks().iter().min().unwrap();
            let max = sched.clocks().iter().max().unwrap();
            max_drift = max_drift.max(max - min);
        }
        (staleness, max_drift)
    }

    #[test]
    fn fully_async_never_waits() {
        let mut sched = Scheduler::new(Box::new(FullyAsync), sampler(4, 7), 0.0);
        sched.start();
        for _ in 0..100 {
            let (_, w) = sched.next().unwrap();
            let restarted = sched.complete(w);
            assert_eq!(restarted, vec![w], "only the finishing worker restarts");
            assert_eq!(sched.step_wait(w), 0.0);
        }
        assert!(sched.wait_totals().iter().all(|&t| t == 0.0));
    }

    #[test]
    fn barrier_restarts_everyone_at_round_end() {
        let m = 3;
        let mut sched = Scheduler::new(Box::new(BarrierSync), sampler(m, 9), 0.0);
        sched.start();
        for round in 0..10u64 {
            let mut restarted_total = 0;
            for arrival in 0..m {
                let (_, w) = sched.next().unwrap();
                let restarted = sched.complete(w);
                if arrival + 1 < m {
                    assert!(restarted.is_empty(), "round {round}: early arrival restarted");
                } else {
                    restarted_total = restarted.len();
                }
            }
            assert_eq!(restarted_total, m, "round {round}: barrier must release all");
            assert!(sched.clocks().iter().all(|&c| c == round + 1));
        }
    }

    #[test]
    fn ssp_bound_zero_is_round_structured() {
        // s = 0: every worker computes exactly once per round.
        let (_, drift) = drive(Box::new(StalenessBounded { bound: 0 }), 4, 60, 11);
        assert!(drift <= 1, "drift {drift} > 1 under s=0");
    }

    #[test]
    fn ssp_clock_drift_never_exceeds_bound_plus_inflight() {
        for s in [0u64, 1, 3] {
            let (_, drift) = drive(Box::new(StalenessBounded { bound: s }), 5, 200, 13 + s);
            assert!(drift <= s + 1, "drift {drift} > s+1 for s={s}");
        }
    }

    #[test]
    fn ssp_version_staleness_respects_derived_bound() {
        for s in [0u64, 1, 2, 4] {
            let m = 4;
            let proto = StalenessBounded { bound: s };
            let cap = proto.version_bound(m);
            let (stale, _) = drive(Box::new(proto), m, 300, 17 + s);
            let max = stale.iter().copied().max().unwrap();
            assert!(max <= cap, "staleness {max} > bound {cap} for s={s}");
        }
    }

    #[test]
    fn ssp_large_bound_matches_fully_async_schedule() {
        let (a, _) = drive(Box::new(FullyAsync), 4, 150, 21);
        let (b, _) = drive(Box::new(StalenessBounded { bound: 1 << 40 }), 4, 150, 21);
        assert_eq!(a, b, "ungated SSP must reproduce the async schedule");
    }

    #[test]
    fn wait_accounting_accumulates_under_barrier() {
        let mut sched = Scheduler::new(Box::new(BarrierSync), sampler(4, 23), 0.0);
        sched.start();
        for _ in 0..40 {
            let (_, w) = sched.next().unwrap();
            sched.complete(w);
        }
        // with jittered delays somebody must have waited at the barrier
        let total: f64 = sched.wait_totals().iter().sum();
        assert!(total > 0.0, "no barrier wait recorded");
    }

    #[test]
    fn single_worker_degenerates_to_sequential() {
        let mut sched =
            Scheduler::new(Box::new(StalenessBounded { bound: 0 }), sampler(1, 29), 0.0);
        assert_eq!(sched.start(), vec![0]);
        let mut last = 0.0;
        for _ in 0..20 {
            let (t, w) = sched.next().unwrap();
            assert_eq!(w, 0);
            assert!(t >= last);
            last = t;
            assert_eq!(sched.complete(0), vec![0]);
        }
        assert_eq!(sched.clocks(), &[20]);
    }
}
