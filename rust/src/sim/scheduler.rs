//! Event-driven scheduler: the per-worker lifecycle (pull → compute → push)
//! under a pluggable synchronization [`Protocol`].
//!
//! The scheduler owns *time* (the [`EventQueue`] virtual clock), the
//! per-worker compute-duration streams ([`DelaySampler`]), the per-worker
//! logical clocks (completed local steps), and the wait/gate accounting.
//! It deliberately knows nothing about gradients, models, or the parameter
//! server: the coordinator drives it event-at-a-time —
//!
//! ```text
//! for w in sched.start()          { pull snapshot for w }
//! while let Some((t, w)) = sched.next() {
//!     compute gradient on w's snapshot; commit it (push or barrier fold);
//!     for v in sched.complete(w)  { pull fresh snapshot for v }
//! }
//! ```
//!
//! — which keeps the core testable without any compiled artifacts (see the
//! property tests in `tests/properties.rs`).
//!
//! A [`Protocol`] decides, each time a worker could begin a new compute,
//! whether it may proceed or must wait, and whether finished gradients
//! commit immediately (one global step per push) or fold at a barrier
//! (one global step per round). The paper's sync↔async spectrum becomes a
//! one-parameter family:
//!
//! | protocol                  | gate (clock drift)     | commit    |
//! |---------------------------|------------------------|-----------|
//! | [`FullyAsync`]            | never waits            | immediate |
//! | [`StalenessBounded`] (s)  | `clock - min <= s`     | immediate |
//! | [`BarrierSync`]           | all clocks equal       | barrier   |
//!
//! `StalenessBounded` is stale-synchronous parallel (SSP): with `s = 0`
//! every worker computes exactly once per round on the same snapshot (the
//! SSGD schedule); with `s` at least the largest drift the delay model can
//! produce it never gates and the schedule is bit-identical to ASGD. The
//! clock gate admits a worker only while it is at most `s` steps ahead of
//! the slowest; since an admitted step completes before re-checking, the
//! observed fastest-slowest drift is at most `s + 1`, which in turn bounds
//! the version staleness any push can observe by
//! `(workers - 1) * (2s + 1)` (see [`StalenessBounded::version_bound`]).

use super::delay::{CommCosts, DelaySampler};
use super::EventQueue;

/// How finished gradients become global steps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommitMode {
    /// Every finished compute is pushed as its own global step.
    Immediate,
    /// Finished computes are buffered; the round commits as one step when
    /// the last worker arrives.
    Barrier,
}

/// A synchronization protocol: the policy half of the scheduler.
///
/// `clocks[w]` is the number of computes worker `w` has *completed*.
/// `may_start` is consulted every time worker `worker` is idle and could
/// begin another compute; returning `false` leaves it gated until another
/// worker's completion changes the clock vector.
pub trait Protocol: Send {
    fn name(&self) -> &'static str;
    fn commit_mode(&self) -> CommitMode {
        CommitMode::Immediate
    }
    fn may_start(&self, worker: usize, clocks: &[u64]) -> bool;
}

/// ASGD-family schedule: nobody ever waits.
#[derive(Clone, Copy, Debug, Default)]
pub struct FullyAsync;

impl Protocol for FullyAsync {
    fn name(&self) -> &'static str {
        "async"
    }
    fn may_start(&self, _worker: usize, _clocks: &[u64]) -> bool {
        true
    }
}

/// SSGD-family schedule: a full barrier every round; gradients fold into a
/// single aggregated step.
#[derive(Clone, Copy, Debug, Default)]
pub struct BarrierSync;

impl Protocol for BarrierSync {
    fn name(&self) -> &'static str {
        "barrier"
    }
    fn commit_mode(&self) -> CommitMode {
        CommitMode::Barrier
    }
    fn may_start(&self, worker: usize, clocks: &[u64]) -> bool {
        let c = clocks[worker];
        clocks.iter().all(|&k| k == c)
    }
}

/// Stale-synchronous parallel: a worker may run at most `bound` local steps
/// ahead of the slowest worker.
#[derive(Clone, Copy, Debug)]
pub struct StalenessBounded {
    pub bound: u64,
}

impl StalenessBounded {
    /// Upper bound on the version staleness (intervening pushes between a
    /// worker's pull and its push) this gate permits: while a worker is in
    /// flight at clock `c`, every peer's clock lives in `[c - s, c + s + 1]`,
    /// so each peer contributes at most `2s + 1` pushes.
    pub fn version_bound(&self, workers: usize) -> u64 {
        (workers.saturating_sub(1) as u64)
            .saturating_mul(self.bound.saturating_mul(2).saturating_add(1))
    }
}

impl Protocol for StalenessBounded {
    fn name(&self) -> &'static str {
        "ssp"
    }
    fn may_start(&self, worker: usize, clocks: &[u64]) -> bool {
        let min = clocks.iter().copied().min().unwrap_or(0);
        clocks[worker] - min <= self.bound
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum WorkerState {
    Computing,
    /// Finished its last compute; gated by the protocol since the stored
    /// virtual time.
    Blocked,
}

/// The event-driven scheduler core. See the module docs for the driving
/// contract.
pub struct Scheduler {
    protocol: Box<dyn Protocol>,
    queue: EventQueue<usize>,
    delays: DelaySampler,
    clocks: Vec<u64>,
    state: Vec<WorkerState>,
    blocked_since: Vec<f64>,
    /// Gate wait charged to each worker's *current/most recent* compute.
    step_wait: Vec<f64>,
    wait_total: Vec<f64>,
    /// Simulated server-side cost charged before each compute after the
    /// first (the paper's "lightweight overhead" of the update rule).
    server_cost: f64,
    /// Per-transfer communication charges ([`CommCosts`]); zero by default,
    /// in which case the schedule is bit-identical to a free network.
    comm: CommCosts,
    /// Total communication time charged so far (diagnostic).
    comm_total: f64,
    /// Total bytes shipped over the modelled wire (uploads + downloads);
    /// tracked even when the time charges are zero so compression sweeps
    /// can report bytes-on-wire without enabling `[comm]`.
    comm_bytes: u64,
    workers: usize,
    started: bool,
}

impl Scheduler {
    pub fn new(protocol: Box<dyn Protocol>, delays: DelaySampler, server_cost: f64) -> Self {
        Self::with_comm(protocol, delays, server_cost, CommCosts::default())
    }

    /// Build a scheduler that charges communication time: each worker's
    /// first compute is preceded by one model download (`comm.pull`), and
    /// every subsequent turnaround is charged one gradient upload plus one
    /// model download (`comm.push + comm.pull`) on top of the server cost.
    /// With `CommCosts::default()` (both zero) the produced schedule is
    /// bit-for-bit the pre-comm one: `x + 0.0 == x` for every non-negative
    /// f64 duration.
    pub fn with_comm(
        protocol: Box<dyn Protocol>,
        delays: DelaySampler,
        server_cost: f64,
        comm: CommCosts,
    ) -> Self {
        let workers = delays.workers();
        assert!(workers >= 1);
        assert!(comm.push >= 0.0 && comm.pull >= 0.0, "comm costs must be non-negative");
        Self {
            protocol,
            queue: EventQueue::new(),
            delays,
            clocks: vec![0; workers],
            state: vec![WorkerState::Blocked; workers],
            blocked_since: vec![0.0; workers],
            step_wait: vec![0.0; workers],
            wait_total: vec![0.0; workers],
            server_cost,
            comm,
            comm_total: 0.0,
            comm_bytes: 0,
            workers,
            started: false,
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }
    pub fn commit_mode(&self) -> CommitMode {
        self.protocol.commit_mode()
    }
    pub fn protocol_name(&self) -> &'static str {
        self.protocol.name()
    }
    /// Current virtual time (time of the last popped finish event).
    pub fn now(&self) -> f64 {
        self.queue.now()
    }
    /// Completed local steps per worker.
    pub fn clocks(&self) -> &[u64] {
        &self.clocks
    }
    /// Total gate-wait accumulated per worker (simulated seconds).
    pub fn wait_totals(&self) -> &[f64] {
        &self.wait_total
    }
    /// Gate wait that preceded `worker`'s current/most recent compute.
    pub fn step_wait(&self, worker: usize) -> f64 {
        self.step_wait[worker]
    }
    /// Total communication time charged to the virtual clock so far
    /// (0.0 unless built via [`Self::with_comm`] with nonzero costs).
    pub fn comm_time_total(&self) -> f64 {
        self.comm_total
    }
    /// Total bytes shipped over the modelled wire so far: one encoded
    /// gradient upload per completed compute (counted even if the worker
    /// is then gated) plus one dense model download per (re)start.
    pub fn comm_bytes_total(&self) -> u64 {
        self.comm_bytes
    }

    /// Launch every worker at t = 0 (no protocol can gate clock-0 starts).
    /// Returns the workers that must pull a snapshot, in worker order. The
    /// first compute carries no server cost, matching a cold cluster start.
    pub fn start(&mut self) -> Vec<usize> {
        assert!(!self.started, "scheduler already started");
        self.started = true;
        for w in 0..self.workers {
            self.state[w] = WorkerState::Computing;
            let d = self.delays.sample(w);
            // initial model download precedes the first compute
            self.queue.schedule_in(self.comm.pull + d, w);
            self.comm_total += self.comm.pull;
            self.comm_bytes += self.comm.pull_bytes as u64;
        }
        (0..self.workers).collect()
    }

    /// Pop the next finish event: `(time, worker)` whose compute is done.
    pub fn next(&mut self) -> Option<(f64, usize)> {
        self.queue.pop()
    }

    /// Mark `worker`'s compute complete (after the caller committed or
    /// buffered its gradient) and restart every worker the protocol now
    /// admits. Returns the restarted workers in worker order; the caller
    /// must pull a fresh snapshot for each before its next finish event.
    pub fn complete(&mut self, worker: usize) -> Vec<usize> {
        debug_assert_eq!(self.state[worker], WorkerState::Computing);
        let now = self.queue.now();
        // the completing worker's gradient is uploaded (committed by the
        // caller) regardless of whether the protocol gates its restart —
        // count the upload bytes here so the counter is exact even for
        // workers still blocked when the run ends. The TIME charge stays
        // on the restart path (it delays the *next* turnaround).
        self.comm_bytes += self.comm.push_bytes as u64;
        self.clocks[worker] += 1;
        self.state[worker] = WorkerState::Blocked;
        self.blocked_since[worker] = now;
        let mut restarted = Vec::new();
        for v in 0..self.workers {
            if self.state[v] == WorkerState::Blocked && self.protocol.may_start(v, &self.clocks) {
                let waited = now - self.blocked_since[v];
                self.step_wait[v] = waited;
                self.wait_total[v] += waited;
                self.state[v] = WorkerState::Computing;
                let d = self.delays.sample(v);
                // turnaround = server update cost + gradient upload for the
                // push that just committed + fresh model download
                self.queue.schedule_in(self.server_cost + self.comm.push + self.comm.pull + d, v);
                self.comm_total += self.comm.push + self.comm.pull;
                self.comm_bytes += self.comm.pull_bytes as u64;
                restarted.push(v);
            }
        }
        restarted
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DelayModel;

    fn sampler(workers: usize, seed: u64) -> DelaySampler {
        DelaySampler::new(DelayModel::Uniform { mean: 1.0, jitter: 0.4 }, workers, seed)
    }

    /// Drive the scheduler with a synthetic push counter, returning the
    /// observed per-push version staleness and the max clock drift.
    fn drive(protocol: Box<dyn Protocol>, workers: usize, steps: usize, seed: u64) -> (Vec<u64>, u64) {
        let mut sched = Scheduler::new(protocol, sampler(workers, seed), 0.01);
        let mut version = 0u64;
        let mut pulled_at = vec![0u64; workers];
        for w in sched.start() {
            pulled_at[w] = version;
        }
        let mut staleness = Vec::new();
        let mut max_drift = 0u64;
        for _ in 0..steps {
            let (_, w) = sched.next().expect("scheduler ran dry");
            staleness.push(version - pulled_at[w]);
            version += 1;
            for v in sched.complete(w) {
                pulled_at[v] = version;
            }
            let min = sched.clocks().iter().min().unwrap();
            let max = sched.clocks().iter().max().unwrap();
            max_drift = max_drift.max(max - min);
        }
        (staleness, max_drift)
    }

    #[test]
    fn fully_async_never_waits() {
        let mut sched = Scheduler::new(Box::new(FullyAsync), sampler(4, 7), 0.0);
        sched.start();
        for _ in 0..100 {
            let (_, w) = sched.next().unwrap();
            let restarted = sched.complete(w);
            assert_eq!(restarted, vec![w], "only the finishing worker restarts");
            assert_eq!(sched.step_wait(w), 0.0);
        }
        assert!(sched.wait_totals().iter().all(|&t| t == 0.0));
    }

    #[test]
    fn barrier_restarts_everyone_at_round_end() {
        let m = 3;
        let mut sched = Scheduler::new(Box::new(BarrierSync), sampler(m, 9), 0.0);
        sched.start();
        for round in 0..10u64 {
            let mut restarted_total = 0;
            for arrival in 0..m {
                let (_, w) = sched.next().unwrap();
                let restarted = sched.complete(w);
                if arrival + 1 < m {
                    assert!(restarted.is_empty(), "round {round}: early arrival restarted");
                } else {
                    restarted_total = restarted.len();
                }
            }
            assert_eq!(restarted_total, m, "round {round}: barrier must release all");
            assert!(sched.clocks().iter().all(|&c| c == round + 1));
        }
    }

    #[test]
    fn ssp_bound_zero_is_round_structured() {
        // s = 0: every worker computes exactly once per round.
        let (_, drift) = drive(Box::new(StalenessBounded { bound: 0 }), 4, 60, 11);
        assert!(drift <= 1, "drift {drift} > 1 under s=0");
    }

    #[test]
    fn ssp_clock_drift_never_exceeds_bound_plus_inflight() {
        for s in [0u64, 1, 3] {
            let (_, drift) = drive(Box::new(StalenessBounded { bound: s }), 5, 200, 13 + s);
            assert!(drift <= s + 1, "drift {drift} > s+1 for s={s}");
        }
    }

    #[test]
    fn ssp_version_staleness_respects_derived_bound() {
        for s in [0u64, 1, 2, 4] {
            let m = 4;
            let proto = StalenessBounded { bound: s };
            let cap = proto.version_bound(m);
            let (stale, _) = drive(Box::new(proto), m, 300, 17 + s);
            let max = stale.iter().copied().max().unwrap();
            assert!(max <= cap, "staleness {max} > bound {cap} for s={s}");
        }
    }

    #[test]
    fn ssp_large_bound_matches_fully_async_schedule() {
        let (a, _) = drive(Box::new(FullyAsync), 4, 150, 21);
        let (b, _) = drive(Box::new(StalenessBounded { bound: 1 << 40 }), 4, 150, 21);
        assert_eq!(a, b, "ungated SSP must reproduce the async schedule");
    }

    #[test]
    fn wait_accounting_accumulates_under_barrier() {
        let mut sched = Scheduler::new(Box::new(BarrierSync), sampler(4, 23), 0.0);
        sched.start();
        for _ in 0..40 {
            let (_, w) = sched.next().unwrap();
            sched.complete(w);
        }
        // with jittered delays somebody must have waited at the barrier
        let total: f64 = sched.wait_totals().iter().sum();
        assert!(total > 0.0, "no barrier wait recorded");
    }

    #[test]
    fn comm_disabled_reproduces_pre_comm_schedule_bitwise() {
        // Regression for the dead-CommModel fix: the default (comm off)
        // schedule must be bit-identical to the pre-comm recurrence
        //   first finish:  t_w = d_w
        //   next finishes: t_w += server_cost + d_w   (FullyAsync)
        // replayed here by hand against the same DelaySampler stream.
        let (workers, seed, server_cost) = (4usize, 77u64, 0.01f64);
        let mut sched = Scheduler::new(Box::new(FullyAsync), sampler(workers, seed), server_cost);
        sched.start();

        let mut manual = sampler(workers, seed);
        let mut times: Vec<f64> = (0..workers).map(|w| manual.sample(w)).collect();
        for _ in 0..200 {
            let (t, w) = sched.next().unwrap();
            // manual replay: earliest finish wins; ties cannot occur with
            // continuous uniform delays
            let exp_w =
                (0..workers).min_by(|&a, &b| times[a].partial_cmp(&times[b]).unwrap()).unwrap();
            assert_eq!(w, exp_w);
            assert_eq!(t.to_bits(), times[w].to_bits(), "schedule diverged");
            sched.complete(w);
            times[w] += server_cost + manual.sample(w);
        }
        assert_eq!(sched.comm_time_total(), 0.0);
    }

    #[test]
    fn comm_costs_charge_push_and_pull_per_turnaround() {
        use crate::sim::CommCosts;
        let delays = DelaySampler::new(DelayModel::Constant { mean: 1.0 }, 1, 5);
        let comm = CommCosts { push: 0.25, pull: 0.5, ..CommCosts::default() };
        let mut sched = Scheduler::with_comm(Box::new(FullyAsync), delays, 0.0, comm);
        sched.start();
        // first finish: pull + compute = 0.5 + 1.0
        let (t0, _) = sched.next().unwrap();
        assert!((t0 - 1.5).abs() < 1e-12);
        sched.complete(0);
        // each turnaround adds push + pull + compute = 0.25 + 0.5 + 1.0
        let (t1, _) = sched.next().unwrap();
        assert!((t1 - 3.25).abs() < 1e-12);
        sched.complete(0);
        let (t2, _) = sched.next().unwrap();
        assert!((t2 - 5.0).abs() < 1e-12);
        // charged: initial pull + 2 turnarounds of (push + pull)
        assert!((sched.comm_time_total() - (0.5 + 2.0 * 0.75)).abs() < 1e-12);
    }

    #[test]
    fn comm_slows_every_protocol_uniformly() {
        use crate::sim::CommCosts;
        for proto in ["async", "barrier", "ssp"] {
            let mk = |comm: CommCosts| -> f64 {
                let p: Box<dyn Protocol> = match proto {
                    "async" => Box::new(FullyAsync),
                    "barrier" => Box::new(BarrierSync),
                    _ => Box::new(StalenessBounded { bound: 1 }),
                };
                let mut sched = Scheduler::with_comm(p, sampler(3, 31), 0.01, comm);
                sched.start();
                let mut last = 0.0;
                for _ in 0..60 {
                    let (t, w) = sched.next().unwrap();
                    last = t;
                    sched.complete(w);
                }
                last
            };
            let free = mk(CommCosts::default());
            let charged = mk(CommCosts { push: 0.05, pull: 0.05, ..CommCosts::default() });
            assert!(charged > free, "{proto}: comm charge did not extend the schedule");
        }
    }

    #[test]
    fn byte_accounting_tracks_transfers_without_touching_the_schedule() {
        use crate::sim::CommCosts;
        // two schedulers, identical streams: one free, one free-but-sized.
        // The schedules must be bit-identical (sizes are pure accounting)
        // while the sized one reports exact bytes on the wire.
        let (workers, seed) = (3usize, 41u64);
        let mut free = Scheduler::new(Box::new(FullyAsync), sampler(workers, seed), 0.01);
        let mut sized = Scheduler::with_comm(
            Box::new(FullyAsync),
            sampler(workers, seed),
            0.01,
            CommCosts::sized(100, 1000),
        );
        free.start();
        sized.start();
        let mut completes = 0u64;
        let mut restarts = 0u64;
        for _ in 0..60 {
            let (ta, wa) = free.next().unwrap();
            let (tb, wb) = sized.next().unwrap();
            assert_eq!(wa, wb);
            assert_eq!(ta.to_bits(), tb.to_bits(), "sizes perturbed the schedule");
            free.complete(wa);
            completes += 1;
            restarts += sized.complete(wb).len() as u64;
        }
        assert_eq!(sized.comm_time_total(), 0.0);
        assert_eq!(free.comm_bytes_total(), 0);
        // one dense download per (re)start + one encoded upload per
        // completed compute (counted even if the worker were gated)
        assert_eq!(
            sized.comm_bytes_total(),
            (workers as u64 + restarts) * 1000 + completes * 100
        );
    }

    #[test]
    fn upload_bytes_counted_even_for_gated_workers() {
        use crate::sim::CommCosts;
        // SSP s=0: early finishers block at the gate, but their pushed
        // gradients were committed — the byte counter must include them.
        let workers = 3;
        let mut sched = Scheduler::with_comm(
            Box::new(StalenessBounded { bound: 0 }),
            sampler(workers, 57),
            0.0,
            CommCosts::sized(10, 0),
        );
        sched.start();
        // complete two workers: both stay gated (round incomplete), yet
        // both uploads count
        for _ in 0..2 {
            let (_, w) = sched.next().unwrap();
            assert!(sched.complete(w).is_empty(), "s=0 must gate early finishers");
        }
        assert_eq!(sched.comm_bytes_total(), 20);
    }

    #[test]
    fn single_worker_degenerates_to_sequential() {
        let mut sched =
            Scheduler::new(Box::new(StalenessBounded { bound: 0 }), sampler(1, 29), 0.0);
        assert_eq!(sched.start(), vec![0]);
        let mut last = 0.0;
        for _ in 0..20 {
            let (t, w) = sched.next().unwrap();
            assert_eq!(w, 0);
            assert!(t >= last);
            last = t;
            assert_eq!(sched.complete(0), vec![0]);
        }
        assert_eq!(sched.clocks(), &[20]);
    }
}
