//! Event-driven scheduler: the per-worker lifecycle (pull → compute → push)
//! under a pluggable synchronization [`Protocol`], with first-class worker
//! faults and elastic membership ([`crate::sim::faults`]).
//!
//! The scheduler owns *time* (the [`EventQueue`] virtual clock), the
//! per-worker compute-duration streams ([`DelaySampler`]), the per-worker
//! logical clocks (completed local steps), the wait/gate accounting, and —
//! when a [`FaultPlan`] is installed — the fleet membership: who is alive,
//! who crashed, who is restarting, who joined late. It deliberately knows
//! nothing about gradients, models, or the parameter server: the
//! coordinator drives it event-at-a-time —
//!
//! ```text
//! for w in sched.start()             { pull snapshot for w }
//! while let Some(ev) = sched.next_event() {
//!     match ev {
//!         Finish { worker, .. } => { compute + commit; for v in sched.complete(worker) { pull v } }
//!         Crash  { released, .. } => { settle any barrier round; for v in released { pull v } }
//!         Join   { worker, released, .. } => { re-seed worker state; pull worker; pull released }
//!     }
//! }
//! ```
//!
//! — which keeps the core testable without any compiled artifacts (see the
//! property tests in `tests/properties.rs` and the chaos harness in
//! `tests/chaos.rs`).
//!
//! A [`Protocol`] decides, each time a worker could begin a new compute,
//! whether it may proceed or must wait, and whether finished gradients
//! commit immediately (one global step per push) or fold at a barrier
//! (one global step per round). The paper's sync↔async spectrum becomes a
//! one-parameter family:
//!
//! | protocol                  | gate (clock drift)     | commit    |
//! |---------------------------|------------------------|-----------|
//! | [`FullyAsync`]            | never waits            | immediate |
//! | [`StalenessBounded`] (s)  | `clock - min <= s`     | immediate |
//! | [`BarrierSync`]           | all clocks equal       | barrier   |
//!
//! `StalenessBounded` is stale-synchronous parallel (SSP): with `s = 0`
//! every worker computes exactly once per round on the same snapshot (the
//! SSGD schedule); with `s` at least the largest drift the delay model can
//! produce it never gates and the schedule is bit-identical to ASGD. The
//! clock gate admits a worker only while it is at most `s` steps ahead of
//! the slowest; since an admitted step completes before re-checking, the
//! observed fastest-slowest drift is at most `s + 1`, which in turn bounds
//! the version staleness any push can observe by
//! `(workers - 1) * (2s + 1)` (see [`StalenessBounded::version_bound`]).
//!
//! ## Worker lifecycle under faults
//!
//! Every gate evaluates over the **live** membership only, so a dead
//! worker can never wedge a barrier round or pin the SSP minimum. Finish
//! events carry the epoch they were scheduled under; a crash under
//! [`CrashPolicy::Drop`] bumps the worker's epoch, so the in-flight finish
//! is recognized as stale and silently discarded — a push from a crashed
//! epoch can never commit. Under [`CrashPolicy::Salvage`] the in-flight
//! compute is delivered and committed first (graceful drain), then the
//! worker goes down. A restarting or late-joining worker that lags the
//! fleet adopts the slowest live peer's clock and starts immediately (so
//! it neither trips the SSP gate for its peers nor wedges a barrier round
//! that is waiting on it); one that died *ahead* of the slowest live peer
//! re-enters through the protocol gate instead — clocks never regress, so
//! completed work is never redone. Either way it downloads a fresh model
//! and re-arms its crash stream. Without a fault plan none of these paths
//! execute and
//! the produced schedule is bit-identical to pre-fault builds (pinned by
//! tests here and in `tests/chaos.rs`).
//!
//! ## Gate engines: indexed fast path vs. scan reference
//!
//! Each built-in protocol declares a [`GateSpec`] — the incremental form
//! of its gate — and the scheduler maintains a [`FleetIndex`] (live-clock
//! multiset + membership/blocked bitsets, see [`crate::sim::fleet`]) so a
//! release touches O(M/64 + released) state instead of scanning all M
//! workers per blocked worker (O(M²) per event at fleet scale). Custom
//! protocols (and [`Scheduler::force_scan_gates`]) fall back to the
//! original O(M) `may_start` scan, retained verbatim as the semantic
//! reference: both engines produce bit-identical schedules on every
//! built-in protocol (pinned here and by the chaos harness).

use super::delay::{CommCosts, DelaySampler};
use super::faults::{CrashPolicy, FaultPlan, FaultStats};
use super::fleet::FleetIndex;
use super::topology::UplinkMeter;
use super::EventQueue;
use crate::trace::profile::{span, Subsystem};
use crate::trace::{EventBuf, EventKind, TraceEvent};

/// How finished gradients become global steps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommitMode {
    /// Every finished compute is pushed as its own global step.
    Immediate,
    /// Finished computes are buffered; the round commits as one step when
    /// the last worker arrives.
    Barrier,
}

/// The incremental form of a protocol's gate, declared via
/// [`Protocol::gate_spec`]. Lets the scheduler release blocked workers
/// from the [`FleetIndex`] in O(log M)/O(1) instead of scanning the
/// fleet; `Scan` is the always-correct fallback that consults
/// [`Protocol::may_start`] per worker.
///
/// A spec must agree with `may_start` over every reachable state — the
/// three built-ins are pinned bitwise against the scan reference by the
/// scheduler tests and the chaos harness.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GateSpec {
    /// The gate never blocks ([`FullyAsync`]): release everything.
    Always,
    /// Admit only when all live clocks are equal ([`BarrierSync`]):
    /// one distinct-count check, then release everything.
    AllEqual,
    /// Admit while `clocks[w] <= min_live + bound` saturating
    /// ([`StalenessBounded`]): one multiset-min lookup, then a
    /// word-skipping pass over the blocked set.
    MaxDrift(u64),
    /// No incremental form: fall back to the O(M) `may_start` scan.
    Scan,
}

/// A synchronization protocol: the policy half of the scheduler.
///
/// `clocks[w]` is the number of computes worker `w` has *completed*;
/// `alive[w]` says whether worker `w` is currently part of the fleet
/// (always all-true without a fault plan). `may_start` is consulted every
/// time worker `worker` is idle and could begin another compute; returning
/// `false` leaves it gated until another worker's completion — or a
/// membership change — updates the clock vector. Gates must ignore dead
/// workers' clocks: a crashed straggler would otherwise pin the minimum
/// forever and wedge the fleet.
pub trait Protocol: Send {
    fn name(&self) -> &'static str;
    fn commit_mode(&self) -> CommitMode {
        CommitMode::Immediate
    }
    fn may_start(&self, worker: usize, clocks: &[u64], alive: &[bool]) -> bool;
    /// The gate's incremental form; defaulting to [`GateSpec::Scan`]
    /// keeps every custom protocol on the reference scan path.
    fn gate_spec(&self) -> GateSpec {
        GateSpec::Scan
    }
}

/// ASGD-family schedule: nobody ever waits.
#[derive(Clone, Copy, Debug, Default)]
pub struct FullyAsync;

impl Protocol for FullyAsync {
    fn name(&self) -> &'static str {
        "async"
    }
    fn may_start(&self, _worker: usize, _clocks: &[u64], _alive: &[bool]) -> bool {
        true
    }
    fn gate_spec(&self) -> GateSpec {
        GateSpec::Always
    }
}

/// SSGD-family schedule: a full barrier every round; gradients fold into a
/// single aggregated step. The barrier spans the *live* membership: a dead
/// worker neither blocks the round nor is waited for.
#[derive(Clone, Copy, Debug, Default)]
pub struct BarrierSync;

impl Protocol for BarrierSync {
    fn name(&self) -> &'static str {
        "barrier"
    }
    fn commit_mode(&self) -> CommitMode {
        CommitMode::Barrier
    }
    fn may_start(&self, worker: usize, clocks: &[u64], alive: &[bool]) -> bool {
        let c = clocks[worker];
        clocks.iter().zip(alive).all(|(&k, &a)| !a || k == c)
    }
    fn gate_spec(&self) -> GateSpec {
        GateSpec::AllEqual
    }
}

/// Stale-synchronous parallel: a worker may run at most `bound` local steps
/// ahead of the slowest **live** worker.
#[derive(Clone, Copy, Debug)]
pub struct StalenessBounded {
    pub bound: u64,
}

impl StalenessBounded {
    /// Upper bound on the version staleness (intervening pushes between a
    /// worker's pull and its push) this gate permits: while a worker is in
    /// flight at clock `c`, every peer's clock lives in `[c - s, c + s + 1]`,
    /// so each peer contributes at most `2s + 1` pushes.
    pub fn version_bound(&self, workers: usize) -> u64 {
        (workers.saturating_sub(1) as u64)
            .saturating_mul(self.bound.saturating_mul(2).saturating_add(1))
    }
}

impl Protocol for StalenessBounded {
    fn name(&self) -> &'static str {
        "ssp"
    }
    fn may_start(&self, worker: usize, clocks: &[u64], alive: &[bool]) -> bool {
        let min = clocks
            .iter()
            .zip(alive)
            .filter(|&(_, &a)| a)
            .map(|(&k, _)| k)
            .min()
            .unwrap_or(0);
        // saturating: the trait contract permits querying a worker whose
        // clock is below the live minimum (dead, or mid-join before clock
        // adoption) — such a worker is behind the fleet, never gated
        clocks[worker].saturating_sub(min) <= self.bound
    }
    fn gate_spec(&self) -> GateSpec {
        GateSpec::MaxDrift(self.bound)
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum WorkerState {
    Computing,
    /// Finished its last compute; gated by the protocol since the stored
    /// virtual time.
    Blocked,
    /// Crashed / departed / not yet joined: not part of the live fleet.
    Dead,
}

/// Internal queue payload: worker finishes plus the fault timeline.
#[derive(Clone, Copy, Debug)]
enum Ev {
    /// Worker's compute finishes. `epoch` pins it to the lifecycle epoch it
    /// was scheduled under: a crash bumps the epoch, so stale finishes from
    /// a dead incarnation are dropped on pop.
    Finish { worker: usize, epoch: u32 },
    Crash { worker: usize },
    Join { worker: usize },
    Straggle { worker: usize },
}

/// What the scheduler hands the coordinator per popped event.
#[derive(Clone, Debug, PartialEq)]
pub enum SimEvent {
    /// `worker`'s compute finished at `time`: compute its gradient on the
    /// snapshot it pulled, commit it, then call
    /// [`Scheduler::complete`] and pull for every returned worker.
    Finish { time: f64, worker: usize },
    /// `worker` crashed. Its in-flight gradient (if any) was dropped or
    /// marked for salvage per [`CrashPolicy`]; `released` lists workers the
    /// membership change just un-gated — the caller must settle any barrier
    /// round over the shrunken fleet **before** pulling for them.
    Crash { time: f64, worker: usize, permanent: bool, released: Vec<usize> },
    /// `worker` (re)joined the fleet. The caller must re-seed its
    /// server-side state (`w_bak`, error-feedback residual) and pull it a
    /// fresh snapshot. `computing` says whether it started a compute right
    /// away (fresh/lagging joiner) or re-entered through the protocol gate
    /// (it died *ahead* of the slowest live peer — e.g. blocked at a
    /// barrier with its contribution already buffered — and will appear in
    /// a later `released` list instead).
    Join { time: f64, worker: usize, computing: bool, released: Vec<usize> },
}

/// The event-driven scheduler core. See the module docs for the driving
/// contract.
pub struct Scheduler {
    protocol: Box<dyn Protocol>,
    queue: EventQueue<Ev>,
    delays: DelaySampler,
    clocks: Vec<u64>,
    state: Vec<WorkerState>,
    blocked_since: Vec<f64>,
    /// Gate wait charged to each worker's *current/most recent* compute.
    step_wait: Vec<f64>,
    wait_total: Vec<f64>,
    /// Simulated server-side cost charged before each compute after the
    /// first (the paper's "lightweight overhead" of the update rule).
    server_cost: f64,
    /// Per-transfer communication charges ([`CommCosts`]); zero by default,
    /// in which case the schedule is bit-identical to a free network.
    comm: CommCosts,
    /// Per-worker charge overrides (topology-aware comm: a worker's costs
    /// depend on its rack's links to the PS nodes). `None` — the default —
    /// charges every worker the shared `comm`, bit-identical to
    /// pre-topology builds.
    comm_w: Option<Vec<CommCosts>>,
    /// Total communication time charged so far (diagnostic).
    comm_total: f64,
    /// Total bytes shipped over the modelled wire (uploads + downloads);
    /// tracked even when the time charges are zero so compression sweeps
    /// can report bytes-on-wire without enabling `[comm]`.
    comm_bytes: u64,
    /// Per-rack uplink byte meter ([`UplinkMeter`]); `None` — the default —
    /// skips the accounting entirely. Pure observability: the meter is
    /// charged at exactly the `comm_bytes` sites and never reads back into
    /// the schedule.
    uplink: Option<UplinkMeter>,
    workers: usize,
    started: bool,
    /// The active gate engine: the protocol's declared [`GateSpec`], or
    /// `Scan` when forced ([`Self::force_scan_gates`]).
    gate: GateSpec,
    /// Incremental fleet index (live-clock multiset + membership/blocked
    /// bitsets); maintained on every transition, read by the indexed gate
    /// fast paths and the O(1) membership accessors.
    index: FleetIndex,
    // ---- fault / membership state (inert without a plan) ----------------
    faults: Option<FaultPlan>,
    /// Live-fleet membership; all-true without a fault plan.
    alive: Vec<bool>,
    /// Lifecycle epoch per worker; finish events from older epochs are
    /// stale and dropped.
    epoch: Vec<u32>,
    /// Salvage drain: crashed mid-compute, dies at its own finish.
    dying: Vec<bool>,
    /// Restart decision captured at crash time for a draining worker
    /// (`Some(None)` = permanent departure at finish).
    pending_restart: Vec<Option<Option<f64>>>,
    /// Permanently departed: straggle chains stop rescheduling.
    departed: Vec<bool>,
    /// First join of a late joiner (vs a post-crash restart).
    late_join_pending: Vec<bool>,
    /// Open straggle window: sampled compute times are multiplied by
    /// `slow_factor` while `now < slow_until`.
    slow_until: Vec<f64>,
    slow_factor: Vec<f64>,
    stats: FaultStats,
    /// Structured event buffer (`[trace]`). `None` (the default) keeps
    /// every emission site a single branch; emissions only record
    /// decisions already made, so the schedule is bitwise unaffected.
    trace: Option<EventBuf>,
}

impl Scheduler {
    pub fn new(protocol: Box<dyn Protocol>, delays: DelaySampler, server_cost: f64) -> Self {
        Self::with_comm(protocol, delays, server_cost, CommCosts::default())
    }

    /// Build a scheduler that charges communication time: each worker's
    /// first compute is preceded by one model download (`comm.pull`), and
    /// every subsequent turnaround is charged one gradient upload plus one
    /// model download (`comm.push + comm.pull`) on top of the server cost.
    /// With `CommCosts::default()` (both zero) the produced schedule is
    /// bit-for-bit the pre-comm one: `x + 0.0 == x` for every non-negative
    /// f64 duration.
    pub fn with_comm(
        protocol: Box<dyn Protocol>,
        delays: DelaySampler,
        server_cost: f64,
        comm: CommCosts,
    ) -> Self {
        Self::with_faults(protocol, delays, server_cost, comm, None)
    }

    /// Build a scheduler with an optional fault plan. With `None` this is
    /// exactly [`Self::with_comm`]: no fault code path executes and the
    /// schedule is bit-identical to a fault-free build (pinned by tests).
    pub fn with_faults(
        protocol: Box<dyn Protocol>,
        delays: DelaySampler,
        server_cost: f64,
        comm: CommCosts,
        faults: Option<FaultPlan>,
    ) -> Self {
        let workers = delays.workers();
        assert!(workers >= 1);
        assert!(comm.push >= 0.0 && comm.pull >= 0.0, "comm costs must be non-negative");
        if let Some(p) = &faults {
            assert_eq!(p.workers(), workers, "fault plan sized for a different fleet");
        }
        let alive: Vec<bool> = (0..workers)
            .map(|w| faults.as_ref().map_or(true, |p| p.join_time(w).is_none()))
            .collect();
        assert!(alive.iter().any(|&a| a), "at least one worker must be present at t = 0");
        let gate = protocol.gate_spec();
        let index = FleetIndex::new(&alive);
        Self {
            protocol,
            // steady state holds ≤ 1 finish + crash + straggle per worker
            queue: EventQueue::with_capacity(workers.saturating_mul(3).saturating_add(1)),
            delays,
            clocks: vec![0; workers],
            state: vec![WorkerState::Dead; workers],
            blocked_since: vec![0.0; workers],
            step_wait: vec![0.0; workers],
            wait_total: vec![0.0; workers],
            server_cost,
            comm,
            comm_w: None,
            comm_total: 0.0,
            comm_bytes: 0,
            uplink: None,
            workers,
            started: false,
            gate,
            index,
            faults,
            alive,
            epoch: vec![0; workers],
            dying: vec![false; workers],
            pending_restart: vec![None; workers],
            departed: vec![false; workers],
            late_join_pending: vec![false; workers],
            slow_until: vec![0.0; workers],
            slow_factor: vec![1.0; workers],
            stats: FaultStats::default(),
            trace: None,
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }
    pub fn commit_mode(&self) -> CommitMode {
        self.protocol.commit_mode()
    }
    pub fn protocol_name(&self) -> &'static str {
        self.protocol.name()
    }
    /// Current virtual time (time of the last popped event).
    pub fn now(&self) -> f64 {
        self.queue.now()
    }
    /// Completed local steps per worker.
    pub fn clocks(&self) -> &[u64] {
        &self.clocks
    }
    /// Total gate-wait accumulated per worker (simulated seconds).
    pub fn wait_totals(&self) -> &[f64] {
        &self.wait_total
    }
    /// Gate wait that preceded `worker`'s current/most recent compute.
    pub fn step_wait(&self, worker: usize) -> f64 {
        self.step_wait[worker]
    }
    /// Total communication time charged to the virtual clock so far
    /// (0.0 unless built via [`Self::with_comm`] with nonzero costs).
    pub fn comm_time_total(&self) -> f64 {
        self.comm_total
    }
    /// Total bytes shipped over the modelled wire so far: one encoded
    /// gradient upload per completed compute (counted even if the worker
    /// is then gated) plus one dense model download per (re)start.
    pub fn comm_bytes_total(&self) -> u64 {
        self.comm_bytes
    }
    /// Is worker `w` currently part of the live fleet? (A salvage-draining
    /// worker counts as live until its final finish commits.)
    pub fn is_live(&self, worker: usize) -> bool {
        self.alive[worker]
    }
    /// Is worker `w` currently computing (a finish event is in flight for
    /// it)? Between its pull and its finish the worker's gradient depends
    /// only on inputs it already holds, so the set of computing workers is
    /// exactly what the pipelined driver may evaluate concurrently
    /// ([`crate::util::pool::GradPipeline`]).
    pub fn is_computing(&self, worker: usize) -> bool {
        self.state[worker] == WorkerState::Computing
    }
    /// The computing workers, in worker order (see [`Self::is_computing`]).
    pub fn computing_workers(&self) -> Vec<usize> {
        (0..self.workers).filter(|&w| self.state[w] == WorkerState::Computing).collect()
    }
    /// Size of the live fleet right now (O(1): bitset popcount, not a
    /// membership scan).
    pub fn live_workers(&self) -> usize {
        self.index.live_count()
    }
    /// Route every gate decision through the reference O(M)
    /// [`Protocol::may_start`] scan instead of the incremental
    /// [`FleetIndex`] fast paths. The two engines are bitwise-equivalent
    /// on the built-in protocols (pinned by the chaos harness); the scan
    /// is retained as the semantic reference and for custom protocols.
    pub fn force_scan_gates(&mut self) {
        self.gate = GateSpec::Scan;
    }
    /// Whether gate decisions currently go through the O(M) scan (a
    /// custom protocol, or [`Self::force_scan_gates`]).
    pub fn uses_scan_gates(&self) -> bool {
        self.gate == GateSpec::Scan
    }
    /// Install per-worker communication charges (topology-aware comm,
    /// [`crate::sim::Topology`]): worker `w`'s transfers are charged
    /// `comm[w]` instead of the shared costs. Must be called before
    /// [`Self::start`]. Passing the shared costs for every worker is
    /// bit-identical to not calling this at all.
    pub fn set_worker_comm(&mut self, comm: Vec<CommCosts>) {
        assert!(!self.started, "set_worker_comm after start");
        assert_eq!(comm.len(), self.workers, "per-worker comm sized for a different fleet");
        for c in &comm {
            assert!(c.push >= 0.0 && c.pull >= 0.0, "comm costs must be non-negative");
        }
        self.comm_w = Some(comm);
    }
    /// Install a per-rack uplink byte meter ([`crate::sim::Topology`]
    /// observability). Must be called before [`Self::start`]. Never
    /// perturbs the schedule: the meter is write-only accounting.
    pub fn set_uplink_meter(&mut self, meter: UplinkMeter) {
        assert!(!self.started, "set_uplink_meter after start");
        assert_eq!(meter.workers(), self.workers, "uplink meter sized for a different fleet");
        self.uplink = Some(meter);
    }
    /// Cumulative uplink bytes per rack (`None` without a meter).
    pub fn uplink_bytes(&self) -> Option<&[f64]> {
        self.uplink.as_ref().map(UplinkMeter::bytes)
    }
    /// Whether a fault plan is installed.
    pub fn has_faults(&self) -> bool {
        self.faults.is_some()
    }
    /// Lifecycle counters (all zero without fault activity).
    pub fn fault_stats(&self) -> FaultStats {
        self.stats
    }
    /// Install a trace event buffer ([`crate::trace`]): lifecycle events
    /// (gate waits, crashes, joins, departures, straggles) are recorded
    /// from here on. Emission counts reconcile 1:1 with [`FaultStats`]
    /// (pinned by `tests/trace.rs`).
    pub fn enable_trace(&mut self) {
        self.trace = Some(EventBuf::new());
    }
    /// Drain buffered trace events (empty when tracing is off).
    pub fn drain_trace(&mut self) -> Vec<TraceEvent> {
        self.trace.as_mut().map(EventBuf::drain).unwrap_or_default()
    }
    /// Pending events in the virtual-time queue (telemetry sample).
    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Record a lifecycle event at the current virtual time (no-op with
    /// tracing off).
    fn emit(&mut self, kind: EventKind, t: f64, worker: usize, value: Option<f64>) {
        let epoch = self.epoch[worker] as u64;
        if let Some(buf) = &mut self.trace {
            buf.emit(kind, t, Some(worker), Some(epoch), None, value);
        }
    }

    /// Launch every t=0 worker (no protocol can gate clock-0 starts) and
    /// arm the fault timeline: late joiners get their join events, present
    /// workers their crash streams, everyone their straggle chains.
    /// Returns the workers that must pull a snapshot, in worker order. The
    /// first compute carries no server cost, matching a cold cluster start.
    pub fn start(&mut self) -> Vec<usize> {
        assert!(!self.started, "scheduler already started");
        self.started = true;
        let mut pulls = Vec::new();
        for w in 0..self.workers {
            if !self.alive[w] {
                // late joiner: schedule its arrival instead of a compute
                let at = self
                    .faults
                    .as_ref()
                    .and_then(|p| p.join_time(w))
                    .expect("dead-at-start worker without a join time");
                self.late_join_pending[w] = true;
                self.queue.schedule_at(at, Ev::Join { worker: w });
                continue;
            }
            self.state[w] = WorkerState::Computing;
            let d = self.sample_delay(w);
            let comm = self.comm_of(w);
            // initial model download precedes the first compute
            self.queue.schedule_in(comm.pull + d, Ev::Finish { worker: w, epoch: self.epoch[w] });
            self.comm_total += comm.pull;
            self.comm_bytes += comm.pull_bytes as u64;
            if let Some(m) = &mut self.uplink {
                m.on_pull(w);
            }
            if let Some(tc) = self.faults.as_mut().and_then(|p| p.next_crash_in(w)) {
                self.queue.schedule_in(tc, Ev::Crash { worker: w });
            }
            pulls.push(w);
        }
        // straggle chains cover every worker; a window opening while the
        // worker is down just slows its first computes after rejoining
        for w in 0..self.workers {
            if let Some(ts) = self.faults.as_mut().and_then(|p| p.next_straggle_in(w)) {
                self.queue.schedule_in(ts, Ev::Straggle { worker: w });
            }
        }
        pulls
    }

    /// Pop the next *finish* event: `(time, worker)` whose compute is done.
    /// Fault events are processed internally and skipped; callers that must
    /// react to membership changes (the coordinator driver, the chaos
    /// harness) should drive [`Self::next_event`] instead. Without a fault
    /// plan the two are equivalent.
    pub fn next(&mut self) -> Option<(f64, usize)> {
        while let Some(ev) = self.next_event() {
            if let SimEvent::Finish { time, worker } = ev {
                return Some((time, worker));
            }
        }
        None
    }

    /// Pop the next observable event (finish / crash / join), advancing the
    /// virtual clock. Stale finishes from crashed epochs and internal
    /// straggle-window events are consumed silently. Returns `None` when
    /// the timeline is exhausted — which, under faults, means the whole
    /// fleet has permanently departed.
    pub fn next_event(&mut self) -> Option<SimEvent> {
        loop {
            let (t, ev) = self.queue.pop()?;
            match ev {
                Ev::Finish { worker, epoch } => {
                    if epoch != self.epoch[worker] {
                        continue; // finish from a crashed epoch: never commits
                    }
                    return Some(SimEvent::Finish { time: t, worker });
                }
                Ev::Crash { worker } => {
                    if let Some(e) = self.process_crash(t, worker) {
                        return Some(e);
                    }
                }
                Ev::Join { worker } => {
                    if !self.alive[worker] {
                        return Some(self.process_join(t, worker));
                    }
                }
                Ev::Straggle { worker } => self.process_straggle(worker),
            }
        }
    }

    /// Mark `worker`'s compute complete (after the caller committed or
    /// buffered its gradient) and restart every worker the protocol now
    /// admits. Returns the restarted workers in worker order; the caller
    /// must pull a fresh snapshot for each before its next finish event.
    /// A salvage-draining worker dies here — its committed push was its
    /// last act — and the gates recompute over the survivors.
    pub fn complete(&mut self, worker: usize) -> Vec<usize> {
        debug_assert_eq!(self.state[worker], WorkerState::Computing);
        let now = self.queue.now();
        // the completing worker's gradient is uploaded (committed by the
        // caller) regardless of whether the protocol gates its restart —
        // count the upload bytes here so the counter is exact even for
        // workers still blocked when the run ends. The TIME charge stays
        // on the restart path (it delays the *next* turnaround).
        self.comm_bytes += self.comm_of(worker).push_bytes as u64;
        if let Some(m) = &mut self.uplink {
            m.on_push(worker);
        }
        self.index.advance_clock(self.clocks[worker]);
        self.clocks[worker] += 1;
        if self.dying[worker] {
            self.stats.salvaged_inflight += 1;
            self.emit(EventKind::InflightSalvaged, now, worker, None);
            let restart = self.pending_restart[worker].take().unwrap_or(None);
            return self.kill(worker, restart);
        }
        self.state[worker] = WorkerState::Blocked;
        self.blocked_since[worker] = now;
        self.index.set_blocked(worker);
        self.release_gated()
    }

    /// Test/diagnostic hook: schedule a crash for `worker` at absolute
    /// virtual time `at`. On a scheduler without a fault plan the crash is
    /// a permanent departure under [`CrashPolicy::Drop`].
    pub fn inject_crash_at(&mut self, at: f64, worker: usize) {
        assert!(worker < self.workers);
        self.queue.schedule_at(at, Ev::Crash { worker });
    }

    /// Test/diagnostic hook: schedule a (re)join for `worker` at absolute
    /// virtual time `at`. Ignored if the worker is alive when it fires.
    pub fn inject_join_at(&mut self, at: f64, worker: usize) {
        assert!(worker < self.workers);
        self.queue.schedule_at(at, Ev::Join { worker });
    }

    // ---- internal lifecycle mechanics -----------------------------------

    /// Worker `w`'s per-transfer charges: its topology-derived override
    /// when installed, the shared costs otherwise.
    #[inline]
    fn comm_of(&self, worker: usize) -> CommCosts {
        match &self.comm_w {
            Some(v) => v[worker],
            None => self.comm,
        }
    }

    /// Sample worker `w`'s next compute duration, stretched by an open
    /// straggle window. Outside a window no arithmetic touches the sample,
    /// so fault-free schedules stay bit-identical.
    fn sample_delay(&mut self, worker: usize) -> f64 {
        let now = self.queue.now();
        let d = self.delays.sample(worker);
        if now < self.slow_until[worker] {
            d * self.slow_factor[worker]
        } else {
            d
        }
    }

    /// Restart every blocked live worker the protocol now admits (called
    /// after any clock or membership change). Returns them in worker order.
    ///
    /// The admissible set is decided up front from the pre-release state —
    /// sound because restarting a worker changes neither clocks nor
    /// membership, the only inputs a gate may read — then each admitted
    /// worker restarts in ascending worker order, reproducing the scan
    /// loop's sampling and event-sequence order exactly.
    fn release_gated(&mut self) -> Vec<usize> {
        let _p = span(Subsystem::GateRelease);
        let admitted = match self.gate {
            GateSpec::Scan => self.admitted_scan(),
            // nothing gates: every blocked worker (blocked ⊆ live) goes
            GateSpec::Always => self.index.blocked().ones().collect(),
            // all-equal holds iff the live multiset has one distinct
            // clock; a blocked worker is live, so its clock is that one
            GateSpec::AllEqual => {
                if self.index.distinct_clocks() > 1 {
                    Vec::new()
                } else {
                    self.index.blocked().ones().collect()
                }
            }
            // `clocks[v].saturating_sub(min) <= s  ⟺  clocks[v] <= min ⊕ s`
            // (⊕ saturating): one multiset-min lookup, then a word-skip
            // pass over the blocked set
            GateSpec::MaxDrift(bound) => match self.index.min_clock() {
                None => Vec::new(),
                Some(min) => {
                    let cap = min.saturating_add(bound);
                    let clocks = &self.clocks;
                    self.index.blocked().ones().filter(|&v| clocks[v] <= cap).collect()
                }
            },
        };
        for &v in &admitted {
            self.restart_worker(v);
        }
        admitted
    }

    /// The reference gate engine: the original O(M) scan consulting
    /// [`Protocol::may_start`] per blocked worker. Kept verbatim as the
    /// semantics the indexed fast paths are equivalence-pinned against,
    /// and as the fallback for custom protocols ([`GateSpec::Scan`]).
    fn admitted_scan(&self) -> Vec<usize> {
        (0..self.workers)
            .filter(|&v| {
                self.state[v] == WorkerState::Blocked
                    && self.alive[v]
                    && self.protocol.may_start(v, &self.clocks, &self.alive)
            })
            .collect()
    }

    /// Admit blocked worker `v`: account its gate wait, emit the wait
    /// span, and schedule its next compute. One body shared by the
    /// indexed fast paths and the scan reference, so both engines produce
    /// identical sample/event/trace streams.
    fn restart_worker(&mut self, v: usize) {
        let now = self.queue.now();
        let waited = now - self.blocked_since[v];
        self.step_wait[v] = waited;
        self.wait_total[v] += waited;
        // emit the gate-wait span only once its extent is known:
        // a zero wait (e.g. FullyAsync) produces no span at all,
        // and Begin/End always pair up (merge_events re-sorts the
        // back-dated Begin into virtual-time order)
        if waited > 0.0 {
            let epoch = Some(self.epoch[v] as u64);
            if let Some(buf) = &mut self.trace {
                buf.emit(EventKind::GateWaitBegin, now - waited, Some(v), epoch, None, None);
                buf.emit(EventKind::GateWaitEnd, now, Some(v), epoch, None, Some(waited));
            }
        }
        self.state[v] = WorkerState::Computing;
        self.index.clear_blocked(v);
        let d = self.sample_delay(v);
        let comm = self.comm_of(v);
        // turnaround = server update cost + gradient upload for the
        // push that just committed + fresh model download
        self.queue.schedule_in(
            self.server_cost + comm.push + comm.pull + d,
            Ev::Finish { worker: v, epoch: self.epoch[v] },
        );
        self.comm_total += comm.push + comm.pull;
        self.comm_bytes += comm.pull_bytes as u64;
        if let Some(m) = &mut self.uplink {
            m.on_pull(v);
        }
    }

    /// Take `worker` out of the fleet; schedule its rejoin (or record the
    /// departure) and recompute the gates over the survivors.
    fn kill(&mut self, worker: usize, restart: Option<f64>) -> Vec<usize> {
        let _p = span(Subsystem::Membership);
        self.index.leave(worker, self.clocks[worker]);
        self.alive[worker] = false;
        self.state[worker] = WorkerState::Dead;
        self.dying[worker] = false;
        match restart {
            Some(d) => self.queue.schedule_in(d, Ev::Join { worker }),
            None => {
                self.stats.departures += 1;
                self.departed[worker] = true;
                self.emit(EventKind::Depart, self.queue.now(), worker, None);
            }
        }
        self.release_gated()
    }

    fn process_crash(&mut self, time: f64, worker: usize) -> Option<SimEvent> {
        if !self.alive[worker] || self.dying[worker] {
            return None; // crash aimed at an already-down worker
        }
        self.stats.crashes += 1;
        let restart = self.faults.as_mut().and_then(|p| p.restart_delay(worker));
        let policy = self.faults.as_ref().map_or(CrashPolicy::Drop, |p| p.policy());
        let will_restart = if restart.is_some() { 1.0 } else { 0.0 };
        self.emit(EventKind::Crash, time, worker, Some(will_restart));
        let computing = self.state[worker] == WorkerState::Computing;
        let released = if computing && policy == CrashPolicy::Salvage {
            // graceful drain: the in-flight compute will finish and commit;
            // the worker dies at its own finish event (see `complete`)
            self.dying[worker] = true;
            self.pending_restart[worker] = Some(restart);
            Vec::new()
        } else {
            if computing {
                // kill -9: the in-flight finish now belongs to a dead epoch
                self.epoch[worker] = self.epoch[worker].wrapping_add(1);
                self.stats.dropped_inflight += 1;
                self.emit(EventKind::InflightDropped, time, worker, None);
            }
            self.kill(worker, restart)
        };
        Some(SimEvent::Crash { time, worker, permanent: restart.is_none(), released })
    }

    fn process_join(&mut self, time: f64, worker: usize) -> SimEvent {
        let _p = span(Subsystem::Membership);
        if self.late_join_pending[worker] {
            self.late_join_pending[worker] = false;
            self.stats.late_joins += 1;
            self.emit(EventKind::Join, time, worker, None);
        } else {
            self.stats.restarts += 1;
            self.emit(EventKind::Restart, time, worker, None);
        }
        self.alive[worker] = true;
        self.departed[worker] = false;
        // a new epoch: nothing scheduled before this join can ever commit
        self.epoch[worker] = self.epoch[worker].wrapping_add(1);
        self.blocked_since[worker] = time;
        self.step_wait[worker] = 0.0;
        // slowest live peer, from the clock multiset (O(log M)). The
        // joiner is not in the index yet (removed at `kill`, or never
        // inserted for a late joiner), so this is the min over its peers —
        // exactly the scan `filter(v != worker && alive[v])` computed.
        let min_live = self.index.min_clock();
        // Clocks never regress. A fresh or lagging joiner adopts the
        // slowest live peer's clock and starts computing the fleet's
        // current round immediately (the SSP gate would admit the minimum
        // anyway, and a barrier round that is waiting on the joiner must
        // not be wedged by the all-equal gate). A worker that died AHEAD
        // of the slowest live peer — it crashed after completing work the
        // fleet hasn't caught up to, e.g. blocked at a barrier with its
        // contribution already buffered — must NOT redo that work:
        // regressing its clock would double-contribute to the open round,
        // so it re-enters through the protocol gate instead and shows up
        // in a later `released` list.
        let computing = min_live.map_or(true, |m0| self.clocks[worker] <= m0);
        if computing {
            if let Some(m0) = min_live {
                self.clocks[worker] = m0;
            }
            self.state[worker] = WorkerState::Computing;
            self.index.join(worker, self.clocks[worker]);
            // fresh model download precedes the first compute of the epoch
            let d = self.sample_delay(worker);
            let comm = self.comm_of(worker);
            self.queue
                .schedule_in(comm.pull + d, Ev::Finish { worker, epoch: self.epoch[worker] });
            self.comm_total += comm.pull;
            self.comm_bytes += comm.pull_bytes as u64;
            if let Some(m) = &mut self.uplink {
                m.on_pull(worker);
            }
        } else {
            self.state[worker] = WorkerState::Blocked;
            self.index.join(worker, self.clocks[worker]);
            self.index.set_blocked(worker);
        }
        // re-arm the crash stream for the reborn worker
        if let Some(tc) = self.faults.as_mut().and_then(|p| p.next_crash_in(worker)) {
            self.queue.schedule_in(tc, Ev::Crash { worker });
        }
        let released = self.release_gated();
        SimEvent::Join { time, worker, computing, released }
    }

    fn process_straggle(&mut self, worker: usize) {
        if self.departed[worker] {
            return; // the chain dies with a departed worker
        }
        let now = self.queue.now();
        if let Some(p) = self.faults.as_mut() {
            let (factor, dur) = p.straggle_window(worker);
            self.slow_factor[worker] = factor;
            self.slow_until[worker] = now + dur;
            self.stats.straggle_events += 1;
            if let Some(buf) = &mut self.trace {
                buf.emit(
                    EventKind::Straggle,
                    now,
                    Some(worker),
                    Some(self.epoch[worker] as u64),
                    None,
                    Some(factor),
                );
            }
            if let Some(tn) = p.next_straggle_in(worker) {
                self.queue.schedule_in(tn, Ev::Straggle { worker });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DelayModel;
    use crate::sim::faults::FaultConfig;

    fn sampler(workers: usize, seed: u64) -> DelaySampler {
        DelaySampler::new(DelayModel::Uniform { mean: 1.0, jitter: 0.4 }, workers, seed)
    }

    /// Drive the scheduler with a synthetic push counter, returning the
    /// observed per-push version staleness and the max clock drift.
    fn drive(protocol: Box<dyn Protocol>, workers: usize, steps: usize, seed: u64) -> (Vec<u64>, u64) {
        let mut sched = Scheduler::new(protocol, sampler(workers, seed), 0.01);
        let mut version = 0u64;
        let mut pulled_at = vec![0u64; workers];
        for w in sched.start() {
            pulled_at[w] = version;
        }
        let mut staleness = Vec::new();
        let mut max_drift = 0u64;
        for _ in 0..steps {
            let (_, w) = sched.next().expect("scheduler ran dry");
            staleness.push(version - pulled_at[w]);
            version += 1;
            for v in sched.complete(w) {
                pulled_at[v] = version;
            }
            let min = sched.clocks().iter().min().unwrap();
            let max = sched.clocks().iter().max().unwrap();
            max_drift = max_drift.max(max - min);
        }
        (staleness, max_drift)
    }

    #[test]
    fn fully_async_never_waits() {
        let mut sched = Scheduler::new(Box::new(FullyAsync), sampler(4, 7), 0.0);
        sched.start();
        for _ in 0..100 {
            let (_, w) = sched.next().unwrap();
            let restarted = sched.complete(w);
            assert_eq!(restarted, vec![w], "only the finishing worker restarts");
            assert_eq!(sched.step_wait(w), 0.0);
        }
        assert!(sched.wait_totals().iter().all(|&t| t == 0.0));
    }

    #[test]
    fn barrier_restarts_everyone_at_round_end() {
        let m = 3;
        let mut sched = Scheduler::new(Box::new(BarrierSync), sampler(m, 9), 0.0);
        sched.start();
        for round in 0..10u64 {
            let mut restarted_total = 0;
            for arrival in 0..m {
                let (_, w) = sched.next().unwrap();
                let restarted = sched.complete(w);
                if arrival + 1 < m {
                    assert!(restarted.is_empty(), "round {round}: early arrival restarted");
                } else {
                    restarted_total = restarted.len();
                }
            }
            assert_eq!(restarted_total, m, "round {round}: barrier must release all");
            assert!(sched.clocks().iter().all(|&c| c == round + 1));
        }
    }

    #[test]
    fn ssp_bound_zero_is_round_structured() {
        // s = 0: every worker computes exactly once per round.
        let (_, drift) = drive(Box::new(StalenessBounded { bound: 0 }), 4, 60, 11);
        assert!(drift <= 1, "drift {drift} > 1 under s=0");
    }

    #[test]
    fn ssp_clock_drift_never_exceeds_bound_plus_inflight() {
        for s in [0u64, 1, 3] {
            let (_, drift) = drive(Box::new(StalenessBounded { bound: s }), 5, 200, 13 + s);
            assert!(drift <= s + 1, "drift {drift} > s+1 for s={s}");
        }
    }

    #[test]
    fn ssp_version_staleness_respects_derived_bound() {
        for s in [0u64, 1, 2, 4] {
            let m = 4;
            let proto = StalenessBounded { bound: s };
            let cap = proto.version_bound(m);
            let (stale, _) = drive(Box::new(proto), m, 300, 17 + s);
            let max = stale.iter().copied().max().unwrap();
            assert!(max <= cap, "staleness {max} > bound {cap} for s={s}");
        }
    }

    #[test]
    fn ssp_large_bound_matches_fully_async_schedule() {
        let (a, _) = drive(Box::new(FullyAsync), 4, 150, 21);
        let (b, _) = drive(Box::new(StalenessBounded { bound: 1 << 40 }), 4, 150, 21);
        assert_eq!(a, b, "ungated SSP must reproduce the async schedule");
    }

    #[test]
    fn computing_set_tracks_the_worker_lifecycle() {
        // FullyAsync: exactly the finishing worker leaves and re-enters the
        // computing set around each event; everyone else stays in flight.
        let m = 4;
        let mut sched = Scheduler::new(Box::new(FullyAsync), sampler(m, 3), 0.0);
        let started = sched.start();
        assert_eq!(sched.computing_workers(), started);
        for _ in 0..50 {
            let (_, w) = sched.next().unwrap();
            assert!(sched.is_computing(w), "finishing worker must still be computing");
            sched.complete(w);
            assert_eq!(sched.computing_workers().len(), m, "async never gates");
        }
        // SSP s=0 gates early finishers: the computing set shrinks until
        // the round completes
        let mut sched =
            Scheduler::new(Box::new(StalenessBounded { bound: 0 }), sampler(3, 5), 0.0);
        sched.start();
        let (_, w) = sched.next().unwrap();
        sched.complete(w);
        assert!(!sched.is_computing(w), "gated worker must leave the computing set");
        assert_eq!(sched.computing_workers().len(), 2);
    }

    #[test]
    fn wait_accounting_accumulates_under_barrier() {
        let mut sched = Scheduler::new(Box::new(BarrierSync), sampler(4, 23), 0.0);
        sched.start();
        for _ in 0..40 {
            let (_, w) = sched.next().unwrap();
            sched.complete(w);
        }
        // with jittered delays somebody must have waited at the barrier
        let total: f64 = sched.wait_totals().iter().sum();
        assert!(total > 0.0, "no barrier wait recorded");
    }

    #[test]
    fn comm_disabled_reproduces_pre_comm_schedule_bitwise() {
        // Regression for the dead-CommModel fix: the default (comm off)
        // schedule must be bit-identical to the pre-comm recurrence
        //   first finish:  t_w = d_w
        //   next finishes: t_w += server_cost + d_w   (FullyAsync)
        // replayed here by hand against the same DelaySampler stream.
        let (workers, seed, server_cost) = (4usize, 77u64, 0.01f64);
        let mut sched = Scheduler::new(Box::new(FullyAsync), sampler(workers, seed), server_cost);
        sched.start();

        let mut manual = sampler(workers, seed);
        let mut times: Vec<f64> = (0..workers).map(|w| manual.sample(w)).collect();
        for _ in 0..200 {
            let (t, w) = sched.next().unwrap();
            // manual replay: earliest finish wins; ties cannot occur with
            // continuous uniform delays
            let exp_w =
                (0..workers).min_by(|&a, &b| times[a].partial_cmp(&times[b]).unwrap()).unwrap();
            assert_eq!(w, exp_w);
            assert_eq!(t.to_bits(), times[w].to_bits(), "schedule diverged");
            sched.complete(w);
            times[w] += server_cost + manual.sample(w);
        }
        assert_eq!(sched.comm_time_total(), 0.0);
    }

    #[test]
    fn comm_costs_charge_push_and_pull_per_turnaround() {
        use crate::sim::CommCosts;
        let delays = DelaySampler::new(DelayModel::Constant { mean: 1.0 }, 1, 5);
        let comm = CommCosts { push: 0.25, pull: 0.5, ..CommCosts::default() };
        let mut sched = Scheduler::with_comm(Box::new(FullyAsync), delays, 0.0, comm);
        sched.start();
        // first finish: pull + compute = 0.5 + 1.0
        let (t0, _) = sched.next().unwrap();
        assert!((t0 - 1.5).abs() < 1e-12);
        sched.complete(0);
        // each turnaround adds push + pull + compute = 0.25 + 0.5 + 1.0
        let (t1, _) = sched.next().unwrap();
        assert!((t1 - 3.25).abs() < 1e-12);
        sched.complete(0);
        let (t2, _) = sched.next().unwrap();
        assert!((t2 - 5.0).abs() < 1e-12);
        // charged: initial pull + 2 turnarounds of (push + pull)
        assert!((sched.comm_time_total() - (0.5 + 2.0 * 0.75)).abs() < 1e-12);
    }

    #[test]
    fn comm_slows_every_protocol_uniformly() {
        use crate::sim::CommCosts;
        for proto in ["async", "barrier", "ssp"] {
            let mk = |comm: CommCosts| -> f64 {
                let p: Box<dyn Protocol> = match proto {
                    "async" => Box::new(FullyAsync),
                    "barrier" => Box::new(BarrierSync),
                    _ => Box::new(StalenessBounded { bound: 1 }),
                };
                let mut sched = Scheduler::with_comm(p, sampler(3, 31), 0.01, comm);
                sched.start();
                let mut last = 0.0;
                for _ in 0..60 {
                    let (t, w) = sched.next().unwrap();
                    last = t;
                    sched.complete(w);
                }
                last
            };
            let free = mk(CommCosts::default());
            let charged = mk(CommCosts { push: 0.05, pull: 0.05, ..CommCosts::default() });
            assert!(charged > free, "{proto}: comm charge did not extend the schedule");
        }
    }

    #[test]
    fn byte_accounting_tracks_transfers_without_touching_the_schedule() {
        use crate::sim::CommCosts;
        // two schedulers, identical streams: one free, one free-but-sized.
        // The schedules must be bit-identical (sizes are pure accounting)
        // while the sized one reports exact bytes on the wire.
        let (workers, seed) = (3usize, 41u64);
        let mut free = Scheduler::new(Box::new(FullyAsync), sampler(workers, seed), 0.01);
        let mut sized = Scheduler::with_comm(
            Box::new(FullyAsync),
            sampler(workers, seed),
            0.01,
            CommCosts::sized(100, 1000),
        );
        free.start();
        sized.start();
        let mut completes = 0u64;
        let mut restarts = 0u64;
        for _ in 0..60 {
            let (ta, wa) = free.next().unwrap();
            let (tb, wb) = sized.next().unwrap();
            assert_eq!(wa, wb);
            assert_eq!(ta.to_bits(), tb.to_bits(), "sizes perturbed the schedule");
            free.complete(wa);
            completes += 1;
            restarts += sized.complete(wb).len() as u64;
        }
        assert_eq!(sized.comm_time_total(), 0.0);
        assert_eq!(free.comm_bytes_total(), 0);
        // one dense download per (re)start + one encoded upload per
        // completed compute (counted even if the worker were gated)
        assert_eq!(
            sized.comm_bytes_total(),
            (workers as u64 + restarts) * 1000 + completes * 100
        );
    }

    #[test]
    fn uplink_meter_reconciles_with_comm_bytes_and_never_perturbs() {
        use crate::sim::topology::{Topology, TopologyConfig, UplinkMeter};
        use crate::sim::CommCosts;
        // 2 racks × 4 PS nodes, 4 workers: every rack hosts half the
        // shards, so exactly half of every transfer crosses an uplink.
        let (workers, seed, pb, db) = (4usize, 63u64, 1000usize, 4000usize);
        let cfg = TopologyConfig {
            enabled: true,
            racks: 2,
            ps_nodes: 4,
            ..TopologyConfig::default()
        };
        let topo = Topology::from_config(&cfg, workers).unwrap();
        let mut plain = Scheduler::with_comm(
            Box::new(FullyAsync),
            sampler(workers, seed),
            0.01,
            CommCosts::sized(pb, db),
        );
        let mut metered = Scheduler::with_comm(
            Box::new(FullyAsync),
            sampler(workers, seed),
            0.01,
            CommCosts::sized(pb, db),
        );
        metered.set_uplink_meter(UplinkMeter::new(&topo, pb, db));
        plain.start();
        metered.start();
        for _ in 0..60 {
            let (ta, wa) = plain.next().unwrap();
            let (tb, wb) = metered.next().unwrap();
            assert_eq!(wa, wb);
            assert_eq!(ta.to_bits(), tb.to_bits(), "uplink meter perturbed the schedule");
            plain.complete(wa);
            metered.complete(wb);
        }
        let per_rack = metered.uplink_bytes().expect("meter installed");
        assert_eq!(per_rack.len(), 2);
        assert!(per_rack.iter().all(|&b| b > 0.0));
        // half of every counted byte crosses an uplink in this layout, and
        // the two counters are charged at the same sites — exact agreement
        let uplink_total: f64 = per_rack.iter().sum();
        let comm_total = metered.comm_bytes_total() as f64;
        assert_eq!(comm_total, plain.comm_bytes_total() as f64);
        assert!(
            (uplink_total - comm_total / 2.0).abs() < 1e-6,
            "uplink {uplink_total} vs comm/2 {}",
            comm_total / 2.0
        );
        assert!(plain.uplink_bytes().is_none());
    }

    #[test]
    fn upload_bytes_counted_even_for_gated_workers() {
        use crate::sim::CommCosts;
        // SSP s=0: early finishers block at the gate, but their pushed
        // gradients were committed — the byte counter must include them.
        let workers = 3;
        let mut sched = Scheduler::with_comm(
            Box::new(StalenessBounded { bound: 0 }),
            sampler(workers, 57),
            0.0,
            CommCosts::sized(10, 0),
        );
        sched.start();
        // complete two workers: both stay gated (round incomplete), yet
        // both uploads count
        for _ in 0..2 {
            let (_, w) = sched.next().unwrap();
            assert!(sched.complete(w).is_empty(), "s=0 must gate early finishers");
        }
        assert_eq!(sched.comm_bytes_total(), 20);
    }

    #[test]
    fn single_worker_degenerates_to_sequential() {
        let mut sched =
            Scheduler::new(Box::new(StalenessBounded { bound: 0 }), sampler(1, 29), 0.0);
        assert_eq!(sched.start(), vec![0]);
        let mut last = 0.0;
        for _ in 0..20 {
            let (t, w) = sched.next().unwrap();
            assert_eq!(w, 0);
            assert!(t >= last);
            last = t;
            assert_eq!(sched.complete(0), vec![0]);
        }
        assert_eq!(sched.clocks(), &[20]);
    }

    // ---- fault / membership lifecycle -----------------------------------

    /// A fault plan with every stream disabled (useful as an enabled-but-
    /// inert [faults] section).
    fn inert_plan(workers: usize) -> FaultPlan {
        let cfg = FaultConfig {
            enabled: true,
            crash_rate: 0.0,
            straggler_rate: 0.0,
            late_join: 0,
            ..FaultConfig::default()
        };
        FaultPlan::from_config(&cfg, workers, 1).unwrap()
    }

    #[test]
    fn inert_fault_plan_is_bitwise_identical_to_no_plan() {
        // The PR-3 pin: an installed-but-inert [faults] section must not
        // perturb a single bit of the schedule.
        let (workers, seed) = (4usize, 91u64);
        let mut plain = Scheduler::new(Box::new(FullyAsync), sampler(workers, seed), 0.01);
        let mut faulty = Scheduler::with_faults(
            Box::new(FullyAsync),
            sampler(workers, seed),
            0.01,
            CommCosts::default(),
            Some(inert_plan(workers)),
        );
        assert_eq!(plain.start(), faulty.start());
        for _ in 0..300 {
            let (ta, wa) = plain.next().unwrap();
            let (tb, wb) = faulty.next().unwrap();
            assert_eq!(wa, wb);
            assert_eq!(ta.to_bits(), tb.to_bits(), "inert plan perturbed the schedule");
            assert_eq!(plain.complete(wa), faulty.complete(wb));
        }
        assert_eq!(faulty.fault_stats(), FaultStats::default());
    }

    #[test]
    fn drop_crash_discards_inflight_and_departs() {
        // single worker, constant 1s computes: crash at t=0.5 mid-compute
        // with no plan => permanent departure, in-flight finish dropped
        let delays = DelaySampler::new(DelayModel::Constant { mean: 1.0 }, 1, 3);
        let mut sched = Scheduler::new(Box::new(FullyAsync), delays, 0.0);
        sched.inject_crash_at(0.5, 0);
        sched.start();
        match sched.next_event().unwrap() {
            SimEvent::Crash { time, worker, permanent, released } => {
                assert_eq!((worker, permanent), (0, true));
                assert!((time - 0.5).abs() < 1e-12);
                assert!(released.is_empty());
            }
            other => panic!("expected crash, got {other:?}"),
        }
        assert_eq!(sched.next_event(), None, "dead fleet must end the timeline");
        assert_eq!(sched.live_workers(), 0);
        let stats = sched.fault_stats();
        assert_eq!((stats.crashes, stats.dropped_inflight, stats.departures), (1, 1, 1));
        assert_eq!(sched.clocks(), &[0], "dropped compute must not advance the clock");
    }

    #[test]
    fn injected_join_revives_a_crashed_worker() {
        let delays = DelaySampler::new(DelayModel::Constant { mean: 1.0 }, 2, 3);
        let mut sched = Scheduler::new(Box::new(FullyAsync), delays, 0.0);
        sched.inject_crash_at(0.5, 1);
        sched.inject_join_at(2.25, 1);
        sched.start();
        let mut finishes_w1 = 0;
        let mut joined_at = f64::NAN;
        for _ in 0..20 {
            match sched.next_event().unwrap() {
                SimEvent::Finish { time, worker } => {
                    if worker == 1 {
                        finishes_w1 += 1;
                        assert!(
                            time >= 2.25,
                            "worker 1 finished at {time} before rejoining at 2.25"
                        );
                    }
                    sched.complete(worker);
                }
                SimEvent::Crash { worker, .. } => assert_eq!(worker, 1),
                SimEvent::Join { time, worker, .. } => {
                    assert_eq!(worker, 1);
                    joined_at = time;
                }
            }
        }
        assert!((joined_at - 2.25).abs() < 1e-12);
        assert!(finishes_w1 > 0, "rejoined worker never computed");
        assert_eq!(sched.fault_stats().restarts, 1);
        assert_eq!(sched.live_workers(), 2);
    }

    #[test]
    fn barrier_round_survives_a_dead_worker() {
        // 3 workers under BarrierSync; worker 2 departs mid-run. The
        // remaining two must keep completing rounds (no wedge).
        let delays = DelaySampler::new(DelayModel::Constant { mean: 1.0 }, 3, 3);
        let mut sched = Scheduler::new(Box::new(BarrierSync), delays, 0.0);
        sched.inject_crash_at(2.5, 2); // mid third-compute... (rounds at t=1,2,3..)
        sched.start();
        let mut completes = 0u64;
        for _ in 0..40 {
            match sched.next_event() {
                Some(SimEvent::Finish { worker, .. }) => {
                    completes += 1;
                    sched.complete(worker);
                }
                Some(SimEvent::Crash { worker, released, .. }) => {
                    assert_eq!(worker, 2);
                    // constant delays: at t=2.5 all three were computing
                    // round 3, so nobody was blocked to release
                    assert!(released.is_empty());
                }
                Some(SimEvent::Join { .. }) => unreachable!("no joins injected"),
                None => break,
            }
        }
        assert_eq!(sched.live_workers(), 2);
        // the two survivors keep producing rounds: barrier drift stays <= 1
        // (the drive may stop mid-round) and clocks run well past the crash
        assert!(completes > 20, "barrier wedged after the crash: {completes} completes");
        let (c0, c1) = (sched.clocks()[0], sched.clocks()[1]);
        assert!(c0.abs_diff(c1) <= 1, "barrier drift broke: {c0} vs {c1}");
        assert!(c0.min(c1) > 8);
    }

    #[test]
    fn ssp_gate_recomputes_over_live_membership() {
        // 2 workers, worker 1 is 4x slower; s = 1 gates worker 0 hard.
        // After worker 1 departs, worker 0 must run free (min over live).
        let model = DelayModel::Heterogeneous { mean: 1.0, speeds: vec![1.0, 4.0], jitter: 0.0 };
        let delays = DelaySampler::new(model, 2, 3);
        let mut sched = Scheduler::new(Box::new(StalenessBounded { bound: 1 }), delays, 0.0);
        sched.inject_crash_at(9.9, 1);
        sched.start();
        let mut after_crash = 0u64;
        let mut crashed = false;
        for _ in 0..60 {
            match sched.next_event() {
                Some(SimEvent::Finish { worker, .. }) => {
                    if crashed {
                        assert_eq!(worker, 0, "dead worker produced a finish");
                        after_crash += 1;
                    }
                    sched.complete(worker);
                }
                Some(SimEvent::Crash { worker, released, .. }) => {
                    assert_eq!(worker, 1);
                    crashed = true;
                    // if worker 0 was gated on the dead straggler it must be
                    // released right here
                    for &v in &released {
                        assert_eq!(v, 0);
                    }
                }
                Some(SimEvent::Join { .. }) => unreachable!(),
                None => break,
            }
        }
        assert!(crashed);
        assert!(after_crash > 20, "survivor stayed gated on a dead straggler: {after_crash}");
    }

    #[test]
    fn salvage_policy_delivers_inflight_then_kills() {
        // Salvage needs a plan (policy lives there): crash_rate high enough
        // to fire during the first 1s compute of a single worker.
        let cfg = FaultConfig {
            enabled: true,
            crash_rate: 2.0, // mean time-to-crash 0.5s
            departure_prob: 1.0,
            straggler_rate: 0.0,
            policy: CrashPolicy::Salvage,
            ..FaultConfig::default()
        };
        let plan = FaultPlan::from_config(&cfg, 1, 5).unwrap();
        let delays = DelaySampler::new(DelayModel::Constant { mean: 1.0 }, 1, 3);
        let mut sched = Scheduler::with_faults(
            Box::new(FullyAsync),
            delays,
            0.0,
            CommCosts::default(),
            Some(plan),
        );
        sched.start();
        // drive until the (salvaged) departure; the crash may land mid-
        // compute (salvage) or between computes; retry over events
        let mut salvage_seen = false;
        for _ in 0..200 {
            match sched.next_event() {
                Some(SimEvent::Finish { worker, .. }) => {
                    sched.complete(worker);
                }
                Some(SimEvent::Crash { .. }) => {}
                Some(SimEvent::Join { .. }) => unreachable!("departure_prob = 1"),
                None => {
                    salvage_seen = sched.fault_stats().salvaged_inflight > 0
                        || sched.fault_stats().crashes > 0;
                    break;
                }
            }
        }
        assert!(salvage_seen, "no crash ever fired");
        let stats = sched.fault_stats();
        assert_eq!(stats.dropped_inflight, 0, "salvage policy must never drop in-flight work");
        assert_eq!(stats.departures, 1);
        if stats.salvaged_inflight > 0 {
            // the salvaged compute advanced the clock before death
            assert!(sched.clocks()[0] > 0);
        }
    }

    #[test]
    fn late_joiners_start_dead_and_join_on_time() {
        let cfg = FaultConfig {
            enabled: true,
            crash_rate: 0.0,
            straggler_rate: 0.0,
            late_join: 1,
            late_join_by: 3.0,
            ..FaultConfig::default()
        };
        let plan = FaultPlan::from_config(&cfg, 3, 11).unwrap();
        let join_t = plan.join_time(2).unwrap();
        let delays = DelaySampler::new(DelayModel::Constant { mean: 1.0 }, 3, 3);
        let mut sched = Scheduler::with_faults(
            Box::new(FullyAsync),
            delays,
            0.0,
            CommCosts::default(),
            Some(plan),
        );
        assert_eq!(sched.start(), vec![0, 1], "late joiner must not pull at t = 0");
        assert_eq!(sched.live_workers(), 2);
        let mut joined = false;
        for _ in 0..30 {
            match sched.next_event().unwrap() {
                SimEvent::Finish { time, worker } => {
                    if worker == 2 {
                        assert!(joined, "joiner finished before joining");
                        assert!(time > join_t);
                    }
                    sched.complete(worker);
                }
                SimEvent::Join { time, worker, .. } => {
                    assert_eq!(worker, 2);
                    assert!((time - join_t).abs() < 1e-12);
                    joined = true;
                }
                SimEvent::Crash { .. } => unreachable!("crash rate 0"),
            }
        }
        assert!(joined);
        assert_eq!(sched.live_workers(), 3);
        assert_eq!(sched.fault_stats().late_joins, 1);
    }

    #[test]
    fn straggle_windows_stretch_compute_times() {
        // one worker, constant 1s computes, a straggle stream that opens
        // long 8x windows almost immediately: mean turnaround must exceed
        // the fault-free 1s by a wide margin
        let cfg = FaultConfig {
            enabled: true,
            crash_rate: 0.0,
            straggler_rate: 1.0,
            straggler_factor: 8.0,
            straggler_duration: 50.0,
            ..FaultConfig::default()
        };
        let plan = FaultPlan::from_config(&cfg, 1, 13).unwrap();
        let delays = DelaySampler::new(DelayModel::Constant { mean: 1.0 }, 1, 3);
        let mut sched = Scheduler::with_faults(
            Box::new(FullyAsync),
            delays,
            0.0,
            CommCosts::default(),
            Some(plan),
        );
        sched.start();
        let mut last = 0.0;
        for _ in 0..30 {
            let (t, w) = sched.next().unwrap();
            last = t;
            sched.complete(w);
        }
        assert!(sched.fault_stats().straggle_events > 0);
        assert!(
            last > 30.0 * 1.5,
            "30 slowed computes took only {last}s — straggle window inert"
        );
    }

    #[test]
    fn rejoining_ahead_of_the_fleet_waits_for_the_gate() {
        // Regression: worker 0 finishes barrier round 1 (blocked, its
        // contribution buffered), crashes while blocked, and rejoins while
        // the slow worker 2 is still computing round 1. Its clock must NOT
        // regress to the live minimum — that would make it recompute and
        // double-contribute to the open round — so it re-enters through
        // the gate and is released with everyone at the round boundary.
        let model =
            DelayModel::Heterogeneous { mean: 1.0, speeds: vec![1.0, 1.0, 3.0], jitter: 0.0 };
        let delays = DelaySampler::new(model, 3, 3);
        let mut sched = Scheduler::new(Box::new(BarrierSync), delays, 0.0);
        sched.inject_crash_at(1.5, 0); // blocked since t=1, contribution buffered
        sched.inject_join_at(2.0, 0); // rejoins while worker 2 computes until t=3
        sched.start();
        let mut filled = vec![false; 3];
        let mut folds = 0u64;
        for _ in 0..40 {
            match sched.next_event() {
                Some(SimEvent::Finish { worker, .. }) => {
                    assert!(
                        !filled[worker],
                        "worker {worker} contributed twice to one barrier round"
                    );
                    filled[worker] = true;
                    sched.complete(worker);
                }
                Some(SimEvent::Crash { .. }) => {}
                Some(SimEvent::Join { worker, computing, .. }) => {
                    assert_eq!(worker, 0);
                    assert!(
                        !computing,
                        "ahead-of-fleet rejoiner must wait for the gate, not recompute"
                    );
                    assert_eq!(sched.clocks()[0], 1, "rejoiner's clock regressed");
                }
                None => break,
            }
            // settle the round exactly like the driver does
            if filled.iter().any(|&f| f)
                && (0..3).all(|v| !sched.is_live(v) || filled[v])
            {
                filled.fill(false);
                folds += 1;
            }
        }
        assert!(folds >= 5, "barrier wedged after an ahead-of-fleet rejoin: {folds} folds");
        assert_eq!(sched.fault_stats().restarts, 1);
        assert_eq!(sched.live_workers(), 3);
    }

    #[test]
    fn ssp_gate_tolerates_below_min_clock_queries() {
        // Regression (u64 underflow): the Protocol contract permits
        // querying a worker whose clock is below the live minimum — a
        // dead straggler, or a joiner mid-adoption. `clocks[w] - min`
        // panicked in debug and admitted ~u64::MAX drift in release;
        // saturating_sub makes the behind-the-fleet query admit.
        let gate = StalenessBounded { bound: 2 };
        let clocks = [7u64, 0, 10];
        let alive = [true, false, true];
        // worker 1 is dead at clock 0, live min is 7: 0 - 7 underflows
        assert!(gate.may_start(1, &clocks, &alive), "behind-the-fleet query must admit");
        assert!(gate.may_start(0, &clocks, &alive));
        assert!(!gate.may_start(2, &clocks, &alive), "drift 3 exceeds bound 2");
    }

    #[test]
    fn indexed_and_scan_gate_engines_are_bitwise_identical() {
        // Drive the indexed fast path and the forced O(M) scan reference
        // through an eventful lifecycle (crashes, rejoins, gated releases)
        // and require identical event streams to the bit.
        let protos: Vec<fn() -> Box<dyn Protocol>> = vec![
            || Box::new(FullyAsync),
            || Box::new(BarrierSync),
            || Box::new(StalenessBounded { bound: 0 }),
            || Box::new(StalenessBounded { bound: 2 }),
        ];
        for mk in protos {
            for seed in [3u64, 41, 97] {
                let build = |scan: bool| {
                    let mut s = Scheduler::new(mk(), sampler(5, seed), 0.01);
                    if scan {
                        s.force_scan_gates();
                    }
                    s.inject_crash_at(2.5, 1);
                    s.inject_join_at(6.0, 1);
                    s.inject_crash_at(9.0, 3);
                    s.inject_join_at(12.5, 3);
                    s
                };
                let mut fast = build(false);
                let mut scan = build(true);
                assert!(!fast.uses_scan_gates() && scan.uses_scan_gates());
                assert_eq!(fast.start(), scan.start());
                for _ in 0..200 {
                    let (ea, eb) = (fast.next_event(), scan.next_event());
                    match (&ea, &eb) {
                        (
                            Some(SimEvent::Finish { time: ta, worker: wa }),
                            Some(SimEvent::Finish { time: tb, worker: wb }),
                        ) => {
                            assert_eq!(wa, wb);
                            assert_eq!(ta.to_bits(), tb.to_bits(), "schedule diverged");
                            assert_eq!(fast.complete(*wa), scan.complete(*wb));
                        }
                        _ => assert_eq!(ea, eb, "event streams diverged"),
                    }
                    if ea.is_none() {
                        break;
                    }
                }
                assert_eq!(fast.clocks(), scan.clocks());
                assert_eq!(fast.fault_stats(), scan.fault_stats());
                assert_eq!(fast.live_workers(), scan.live_workers());
            }
        }
    }

    #[test]
    fn uniform_per_worker_comm_is_bitwise_identical_to_shared_comm() {
        use crate::sim::CommCosts;
        let comm = CommCosts { push: 0.05, pull: 0.1, push_bytes: 64, pull_bytes: 256 };
        let mut shared = Scheduler::with_comm(Box::new(StalenessBounded { bound: 1 }), sampler(4, 19), 0.01, comm);
        let mut per_worker =
            Scheduler::with_comm(Box::new(StalenessBounded { bound: 1 }), sampler(4, 19), 0.01, comm);
        per_worker.set_worker_comm(vec![comm; 4]);
        assert_eq!(shared.start(), per_worker.start());
        for _ in 0..120 {
            let (ta, wa) = shared.next().unwrap();
            let (tb, wb) = per_worker.next().unwrap();
            assert_eq!(wa, wb);
            assert_eq!(ta.to_bits(), tb.to_bits(), "uniform override perturbed the schedule");
            assert_eq!(shared.complete(wa), per_worker.complete(wb));
        }
        assert_eq!(shared.comm_bytes_total(), per_worker.comm_bytes_total());
        assert_eq!(shared.comm_time_total().to_bits(), per_worker.comm_time_total().to_bits());
    }

    #[test]
    fn per_worker_comm_charges_each_worker_its_own_link() {
        use crate::sim::CommCosts;
        // two workers, constant 1s computes; worker 1 sits behind a 10x
        // more expensive (cross-rack) link, so its finishes lag worker 0's
        let delays = DelaySampler::new(DelayModel::Constant { mean: 1.0 }, 2, 5);
        let mut sched = Scheduler::new(Box::new(FullyAsync), delays, 0.0);
        sched.set_worker_comm(vec![
            CommCosts { push: 0.01, pull: 0.02, push_bytes: 10, pull_bytes: 20 },
            CommCosts { push: 0.1, pull: 0.2, push_bytes: 10, pull_bytes: 20 },
        ]);
        sched.start();
        // first finishes: pull + compute
        let (t0, w0) = sched.next().unwrap();
        assert_eq!(w0, 0);
        assert!((t0 - 1.02).abs() < 1e-12);
        sched.complete(0);
        let (t1, w1) = sched.next().unwrap();
        assert_eq!(w1, 1);
        assert!((t1 - 1.2).abs() < 1e-12);
        sched.complete(1);
        // per-worker time accounting: w0 pull + turnaround, w1 pull + turnaround
        let expect = 0.02 + (0.01 + 0.02) + 0.2 + (0.1 + 0.2);
        assert!((sched.comm_time_total() - expect).abs() < 1e-12);
    }

    #[test]
    fn rejoiner_adopts_the_slowest_live_clock() {
        let delays = DelaySampler::new(DelayModel::Constant { mean: 1.0 }, 3, 3);
        let mut sched = Scheduler::new(Box::new(FullyAsync), delays, 0.0);
        sched.inject_crash_at(0.5, 2);
        sched.inject_join_at(10.5, 2);
        sched.start();
        loop {
            match sched.next_event().unwrap() {
                SimEvent::Finish { worker, .. } => {
                    sched.complete(worker);
                }
                SimEvent::Crash { .. } => {}
                SimEvent::Join { worker, .. } => {
                    assert_eq!(worker, 2);
                    break;
                }
            }
        }
        let min_live = sched.clocks()[0].min(sched.clocks()[1]);
        assert_eq!(
            sched.clocks()[2],
            min_live,
            "joiner must adopt the slowest live clock, got {:?}",
            sched.clocks()
        );
    }
}
