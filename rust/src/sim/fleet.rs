//! Fleet-scale membership and gate index: the O(log M)/O(1) structures
//! that let the [`scheduler`](super::scheduler) stop scanning all M
//! workers on every event.
//!
//! At M ≈ 8 the original O(M) scans (`release_gated` consulting
//! `Protocol::may_start` per blocked worker → O(M²) per event) were
//! invisible; at the paper's fleet scale (thousands of workers behind
//! racks of parameter servers) they dominate the host-time profile. The
//! [`FleetIndex`] keeps three incremental views the gate fast paths read
//! instead of the fleet vectors:
//!
//! - a **live-clock multiset** (`BTreeMap<u64, u32>`): the SSP minimum is
//!   the first key (O(log M)), the barrier's all-equal test is
//!   `len() == 1` (O(1)), and a completed step moves one count between
//!   adjacent keys (O(log M));
//! - a **membership bitset**: the live mask as one bit per worker, with
//!   an O(1) popcount replacing the O(M) `live_workers` scan;
//! - a **blocked bitset**: the gate-waiting set, iterated in ascending
//!   worker order with word-skipping, so a release touches
//!   O(M/64 + released) words instead of all M workers.
//!
//! The index is pure bookkeeping over decisions the scheduler already
//! makes — it never samples, never touches the virtual clock — so the
//! indexed gate engine is bitwise-identical to the retained O(M) scan
//! reference (pinned by the scheduler tests and the chaos harness).

use std::collections::BTreeMap;

/// Compact bitset over worker ids with word-skipping ascending iteration.
#[derive(Clone, Debug)]
pub struct BitSet {
    words: Vec<u64>,
    len: usize,
    count: usize,
}

impl BitSet {
    pub fn new(len: usize) -> Self {
        Self { words: vec![0; len.div_ceil(64)], len, count: 0 }
    }

    /// Capacity in bits (worker slots), not the number of set bits.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Number of set bits (maintained incrementally; O(1)).
    pub fn count(&self) -> usize {
        self.count
    }

    pub fn contains(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i >> 6] >> (i & 63)) & 1 == 1
    }

    /// Set bit `i`; returns whether it was newly set.
    pub fn insert(&mut self, i: usize) -> bool {
        debug_assert!(i < self.len);
        let (word, mask) = (&mut self.words[i >> 6], 1u64 << (i & 63));
        let fresh = *word & mask == 0;
        *word |= mask;
        self.count += fresh as usize;
        fresh
    }

    /// Clear bit `i`; returns whether it was set.
    pub fn remove(&mut self, i: usize) -> bool {
        debug_assert!(i < self.len);
        let (word, mask) = (&mut self.words[i >> 6], 1u64 << (i & 63));
        let was = *word & mask != 0;
        *word &= !mask;
        self.count -= was as usize;
        was
    }

    /// Iterate set bits in ascending order, skipping zero words.
    pub fn ones(&self) -> Ones<'_> {
        Ones { words: &self.words, word: 0, base: 0 }
    }
}

/// Ascending iterator over a [`BitSet`]'s set bits.
pub struct Ones<'a> {
    words: &'a [u64],
    word: u64,
    /// Bit offset of the word *after* the one currently in `word`.
    base: usize,
}

impl Iterator for Ones<'_> {
    type Item = usize;

    fn next(&mut self) -> Option<usize> {
        while self.word == 0 {
            let (&w, rest) = self.words.split_first()?;
            self.words = rest;
            self.word = w;
            self.base += 64;
        }
        let bit = self.word.trailing_zeros() as usize;
        self.word &= self.word - 1;
        Some(self.base - 64 + bit)
    }
}

/// Incremental index over the live fleet (see the module docs). The
/// scheduler maintains it at every membership/clock transition and the
/// indexed gate fast paths read it; the O(M) scan reference ignores it.
#[derive(Clone, Debug)]
pub struct FleetIndex {
    /// Live-clock multiset: clock value → number of live workers at it.
    clock_counts: BTreeMap<u64, u32>,
    /// Live membership mask (mirrors the scheduler's `alive` vector).
    live: BitSet,
    /// Gate-waiting workers; always a subset of `live`.
    blocked: BitSet,
}

impl FleetIndex {
    /// Build from the t=0 membership; every live worker starts at clock 0.
    pub fn new(alive: &[bool]) -> Self {
        let mut live = BitSet::new(alive.len());
        let mut clock_counts = BTreeMap::new();
        for (w, &a) in alive.iter().enumerate() {
            if a {
                live.insert(w);
                *clock_counts.entry(0).or_insert(0) += 1;
            }
        }
        Self { clock_counts, live, blocked: BitSet::new(alive.len()) }
    }

    /// Size of the live fleet (O(1), replaces the membership scan).
    pub fn live_count(&self) -> usize {
        self.live.count()
    }

    pub fn is_live(&self, w: usize) -> bool {
        self.live.contains(w)
    }

    /// The gate-waiting set, for word-skipping release iteration.
    pub fn blocked(&self) -> &BitSet {
        &self.blocked
    }

    /// Smallest live clock; `None` for an empty fleet. O(log M).
    pub fn min_clock(&self) -> Option<u64> {
        self.clock_counts.first_key_value().map(|(&c, _)| c)
    }

    /// Number of distinct clock values across the live fleet: `1` means
    /// the barrier's all-equal condition holds. O(1).
    pub fn distinct_clocks(&self) -> usize {
        self.clock_counts.len()
    }

    pub fn set_blocked(&mut self, w: usize) {
        self.blocked.insert(w);
    }

    pub fn clear_blocked(&mut self, w: usize) {
        self.blocked.remove(w);
    }

    /// A live worker completed a step: move one count from `old` to
    /// `old + 1` in the multiset.
    pub fn advance_clock(&mut self, old: u64) {
        self.remove_clock(old);
        *self.clock_counts.entry(old + 1).or_insert(0) += 1;
    }

    /// Worker `w` (re)enters the live fleet at `clock`.
    pub fn join(&mut self, w: usize, clock: u64) {
        if self.live.insert(w) {
            *self.clock_counts.entry(clock).or_insert(0) += 1;
        }
    }

    /// Worker `w` (at `clock`) leaves the live fleet; it can no longer be
    /// blocked at a gate.
    pub fn leave(&mut self, w: usize, clock: u64) {
        if self.live.remove(w) {
            self.remove_clock(clock);
        }
        self.blocked.remove(w);
    }

    fn remove_clock(&mut self, c: u64) {
        match self.clock_counts.get_mut(&c) {
            Some(n) if *n > 1 => *n -= 1,
            Some(_) => {
                self.clock_counts.remove(&c);
            }
            None => debug_assert!(false, "clock {c} missing from the live multiset"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bitset_insert_remove_contains_count() {
        let mut b = BitSet::new(130);
        assert_eq!(b.len(), 130);
        assert!(b.is_empty());
        assert!(b.insert(0));
        assert!(b.insert(63));
        assert!(b.insert(64));
        assert!(b.insert(129));
        assert!(!b.insert(64), "double insert must report not-fresh");
        assert_eq!(b.count(), 4);
        assert!(b.contains(63) && b.contains(64) && !b.contains(65));
        assert!(b.remove(63));
        assert!(!b.remove(63), "double remove must report not-set");
        assert_eq!(b.count(), 3);
    }

    #[test]
    fn bitset_ones_iterates_ascending_and_skips_empty_words() {
        let mut b = BitSet::new(1000);
        let set = [0usize, 1, 63, 64, 127, 500, 999];
        for &i in &set {
            b.insert(i);
        }
        let got: Vec<usize> = b.ones().collect();
        assert_eq!(got, set);
        assert_eq!(BitSet::new(0).ones().count(), 0);
        assert_eq!(BitSet::new(64).ones().count(), 0);
    }

    #[test]
    fn clock_multiset_tracks_min_and_distinct() {
        let mut idx = FleetIndex::new(&[true, true, true, false]);
        assert_eq!(idx.live_count(), 3);
        assert_eq!(idx.min_clock(), Some(0));
        assert_eq!(idx.distinct_clocks(), 1);
        // two workers advance to clock 1
        idx.advance_clock(0);
        idx.advance_clock(0);
        assert_eq!(idx.min_clock(), Some(0));
        assert_eq!(idx.distinct_clocks(), 2);
        // the straggler catches up: all-equal again
        idx.advance_clock(0);
        assert_eq!(idx.min_clock(), Some(1));
        assert_eq!(idx.distinct_clocks(), 1);
    }

    #[test]
    fn join_and_leave_maintain_membership_and_clocks() {
        let mut idx = FleetIndex::new(&[true, true]);
        idx.advance_clock(0); // one worker at clock 1
        idx.set_blocked(1);
        // worker 1 (at clock 0, blocked) crashes
        idx.leave(1, 0);
        assert_eq!(idx.live_count(), 1);
        assert!(!idx.is_live(1));
        assert_eq!(idx.blocked().count(), 0, "a dead worker cannot stay blocked");
        assert_eq!(idx.min_clock(), Some(1));
        // it rejoins adopting the live minimum
        idx.join(1, 1);
        assert_eq!(idx.live_count(), 2);
        assert_eq!(idx.distinct_clocks(), 1);
        // empty fleet has no minimum
        idx.leave(0, 1);
        idx.leave(1, 1);
        assert_eq!(idx.min_clock(), None);
        assert_eq!(idx.distinct_clocks(), 0);
    }
}
