//! Fleet topology: racks, logical PS nodes, and the topology-aware comm
//! model.
//!
//! The flat [`CommModel`](super::delay::CommModel) charges every worker
//! the same per-transfer cost — fine for one logical PS, wrong for the
//! paper's regime of thousands of workers behind racks of parameter
//! servers, where a worker's cost depends on *which links* its bytes
//! cross. This module adds that structure:
//!
//! * workers and PS nodes are striped over `racks` racks (`id % racks`,
//!   matching the shard striping in [`crate::ps::shard`]);
//! * the model's shards are placed across `ps_nodes` logical PS nodes,
//!   so a push fans out `1/ps_nodes` of its bytes to each node — over
//!   the **rack-local** link when the node shares the worker's rack, the
//!   **cross-rack** link otherwise;
//! * each rack's cross-rack uplink is a shared resource: its per-byte
//!   cost is scaled by the number of workers resident in the rack
//!   (static fair-share bandwidth sharing);
//! * with `hierarchical` two-level aggregation, workers push whole
//!   gradients rack-locally to their rack reducer, which ships **one**
//!   combined gradient across the uplink — so the cross-rack cost is
//!   amortized `1/workers_in_rack` per worker instead of multiplied.
//!
//! All of it compiles down to one static [`CommCosts`] per worker,
//! installed via [`Scheduler::set_worker_comm`](super::Scheduler::set_worker_comm):
//! the schedule stays a deterministic function of `(config, seed)`, and
//! with the section disabled no per-worker costs are installed at all —
//! bit-identical to pre-topology builds.
//!
//! With the defaults (`ps_nodes = 1`, `racks = 1`, flat) every transfer
//! is rack-local and the per-worker costs collapse to exactly
//! `CommCosts::from_model(rack_model, ..)` — the `[comm]` section's
//! single-PS math.

use super::delay::{CommCosts, CommModel};
use anyhow::bail;

/// The `[topology]` config section. Off by default; following the
/// `[comm]`/`[faults]` convention, setting any parameter auto-enables it
/// while an explicit `enabled = false` always wins.
#[derive(Clone, Debug, PartialEq)]
pub struct TopologyConfig {
    pub enabled: bool,
    /// Logical PS nodes the model's shards are placed across.
    pub ps_nodes: usize,
    /// Racks the workers and PS nodes are striped over (`id % racks`).
    pub racks: usize,
    /// Rack-local link (worker ↔ same-rack PS node / rack reducer).
    pub rack_model: CommModel,
    /// Cross-rack link (worker ↔ other-rack PS node, reducer ↔ root).
    pub cross_model: CommModel,
    /// Two-level aggregation: rack reducers fold locally, one combined
    /// gradient crosses the uplink per rack per round.
    pub hierarchical: bool,
}

impl Default for TopologyConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            ps_nodes: 1,
            racks: 1,
            rack_model: CommModel::infiniband_like(),
            cross_model: CommModel::ethernet_like(),
            hierarchical: false,
        }
    }
}

impl TopologyConfig {
    /// Validate the knobs against a fleet of `workers` workers.
    pub fn validate(&self, workers: usize) -> anyhow::Result<()> {
        if !self.enabled {
            return Ok(());
        }
        if self.ps_nodes == 0 {
            bail!("topology.ps_nodes must be >= 1");
        }
        if self.racks == 0 {
            bail!("topology.racks must be >= 1");
        }
        if self.racks > workers {
            bail!(
                "topology.racks = {} exceeds the {} workers: every rack must hold \
                 at least one worker",
                self.racks,
                workers
            );
        }
        for (name, m) in [("rack", &self.rack_model), ("cross", &self.cross_model)] {
            if !(m.per_push >= 0.0 && m.per_push.is_finite()) {
                bail!("topology.{name}_per_push must be finite and >= 0");
            }
            if !(m.per_mb >= 0.0 && m.per_mb.is_finite()) {
                bail!("topology.{name}_per_mb must be finite and >= 0");
            }
        }
        Ok(())
    }
}

/// The placed topology: static rack/node layout plus the per-worker cost
/// derivation. Built once per run; `None` when the section is disabled,
/// so callers wire it straight through (mirroring [`super::FaultPlan`]).
#[derive(Clone, Debug)]
pub struct Topology {
    workers: usize,
    ps_nodes: usize,
    racks: usize,
    rack: CommModel,
    cross: CommModel,
    hierarchical: bool,
}

impl Topology {
    pub fn from_config(cfg: &TopologyConfig, workers: usize) -> Option<Topology> {
        if !cfg.enabled {
            return None;
        }
        Some(Topology {
            workers,
            ps_nodes: cfg.ps_nodes.max(1),
            racks: cfg.racks.max(1).min(workers.max(1)),
            rack: cfg.rack_model,
            cross: cfg.cross_model,
            hierarchical: cfg.hierarchical,
        })
    }

    pub fn workers(&self) -> usize {
        self.workers
    }
    pub fn ps_nodes(&self) -> usize {
        self.ps_nodes
    }
    pub fn racks(&self) -> usize {
        self.racks
    }
    pub fn hierarchical(&self) -> bool {
        self.hierarchical
    }

    /// The rack worker `w` lives in (striped).
    pub fn worker_rack(&self, worker: usize) -> usize {
        worker % self.racks
    }

    /// The rack PS node `node` lives in (striped, same rule as workers).
    pub fn node_rack(&self, node: usize) -> usize {
        node % self.racks
    }

    /// Workers resident in rack `r` (the uplink fair-share divisor);
    /// >= 1 for every rack because `racks <= workers`.
    pub fn workers_in_rack(&self, r: usize) -> usize {
        debug_assert!(r < self.racks);
        self.workers / self.racks + usize::from(r < self.workers % self.racks)
    }

    /// One directed transfer of `bytes` from worker `w`'s rack to the PS
    /// nodes under the flat (direct fan-out) model: `1/ps_nodes` of the
    /// bytes to each node, rack-local or shared-uplink cross-rack.
    fn flat_cost(&self, worker: usize, bytes: usize) -> f64 {
        let wr = self.worker_rack(worker);
        let share = self.workers_in_rack(wr) as f64;
        // same multiply/divide association as CommModel::cost so the
        // single-node single-rack case is bitwise the flat [comm] charge
        let per_node_bytes = bytes as f64 / self.ps_nodes as f64;
        let mut t = 0.0;
        // node ranks repeat rack assignments with period `racks`: group
        // the fan-out by rack residency instead of iterating every node
        let local_nodes = {
            let full = self.ps_nodes / self.racks;
            full + usize::from(wr < self.ps_nodes % self.racks)
        };
        let cross_nodes = self.ps_nodes - local_nodes;
        t += local_nodes as f64 * (self.rack.per_push + self.rack.per_mb * per_node_bytes / 1e6);
        t += cross_nodes as f64
            * (self.cross.per_push + self.cross.per_mb * share * per_node_bytes / 1e6);
        t
    }

    /// One directed transfer of `bytes` under hierarchical two-level
    /// aggregation: whole gradient rack-locally to the reducer, plus the
    /// rack's single cross-uplink transfer amortized over its workers.
    fn hier_cost(&self, worker: usize, bytes: usize) -> f64 {
        let wr = self.worker_rack(worker);
        let pop = self.workers_in_rack(wr) as f64;
        let local = self.rack.cost(bytes);
        // a single-rack fleet IS the root's rack: no uplink at all
        let uplink = if self.racks > 1 { self.cross.cost(bytes) / pop } else { 0.0 };
        local + uplink
    }

    /// Bytes of one `bytes`-sized transfer from worker `w` that cross its
    /// rack's uplink. Under flat fan-out that is the fraction of shards
    /// hosted on other-rack PS nodes; under hierarchical aggregation the
    /// rack ships one combined gradient, amortized `1/workers_in_rack`
    /// per contributing worker. A single-rack fleet has no uplink.
    pub fn uplink_bytes(&self, worker: usize, bytes: usize) -> f64 {
        if self.racks <= 1 {
            return 0.0;
        }
        let wr = self.worker_rack(worker);
        if self.hierarchical {
            bytes as f64 / self.workers_in_rack(wr) as f64
        } else {
            let local_nodes =
                self.ps_nodes / self.racks + usize::from(wr < self.ps_nodes % self.racks);
            let cross_nodes = self.ps_nodes - local_nodes;
            bytes as f64 * cross_nodes as f64 / self.ps_nodes as f64
        }
    }

    /// Worker `w`'s per-transfer charges for `push_bytes`-sized uploads
    /// and `pull_bytes`-sized downloads. Uploads and downloads cross the
    /// same links, so both directions use the same per-byte math.
    pub fn worker_costs(&self, worker: usize, push_bytes: usize, pull_bytes: usize) -> CommCosts {
        let (push, pull) = if self.hierarchical {
            (self.hier_cost(worker, push_bytes), self.hier_cost(worker, pull_bytes))
        } else {
            (self.flat_cost(worker, push_bytes), self.flat_cost(worker, pull_bytes))
        };
        CommCosts { push, pull, push_bytes, pull_bytes }
    }

    /// The whole fleet's charges, in worker order — the vector handed to
    /// [`Scheduler::set_worker_comm`](super::Scheduler::set_worker_comm).
    pub fn all_worker_costs(&self, push_bytes: usize, pull_bytes: usize) -> Vec<CommCosts> {
        (0..self.workers).map(|w| self.worker_costs(w, push_bytes, pull_bytes)).collect()
    }
}

/// Per-rack uplink byte meter: the static per-worker uplink charges
/// ([`Topology::uplink_bytes`]) accumulated per rack by the scheduler at
/// the same four sites as `comm_bytes_total` (initial pull, per-push
/// upload, per-turnaround pull, rejoin pull). Pure accounting — installing
/// one never touches the schedule, mirroring the byte counter itself.
#[derive(Clone, Debug)]
pub struct UplinkMeter {
    /// Worker → rack (striped, frozen at build).
    rack_of: Vec<usize>,
    /// Worker → uplink bytes charged per push / per pull.
    push_uplink: Vec<f64>,
    pull_uplink: Vec<f64>,
    /// Cumulative uplink bytes per rack.
    bytes: Vec<f64>,
}

impl UplinkMeter {
    pub fn new(topo: &Topology, push_bytes: usize, pull_bytes: usize) -> Self {
        let workers = topo.workers();
        Self {
            rack_of: (0..workers).map(|w| topo.worker_rack(w)).collect(),
            push_uplink: (0..workers).map(|w| topo.uplink_bytes(w, push_bytes)).collect(),
            pull_uplink: (0..workers).map(|w| topo.uplink_bytes(w, pull_bytes)).collect(),
            bytes: vec![0.0; topo.racks()],
        }
    }

    pub fn workers(&self) -> usize {
        self.rack_of.len()
    }
    pub fn racks(&self) -> usize {
        self.bytes.len()
    }
    /// Charge one gradient upload from `worker` to its rack's uplink.
    pub fn on_push(&mut self, worker: usize) {
        self.bytes[self.rack_of[worker]] += self.push_uplink[worker];
    }
    /// Charge one model download to `worker` to its rack's uplink.
    pub fn on_pull(&mut self, worker: usize) {
        self.bytes[self.rack_of[worker]] += self.pull_uplink[worker];
    }
    /// Cumulative uplink bytes per rack.
    pub fn bytes(&self) -> &[f64] {
        &self.bytes
    }
    /// Cumulative uplink bytes fleet-wide (≤ `comm_bytes_total`: the
    /// uplink share of each transfer never exceeds the transfer).
    pub fn total(&self) -> f64 {
        self.bytes.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn enabled() -> TopologyConfig {
        TopologyConfig { enabled: true, ..TopologyConfig::default() }
    }

    #[test]
    fn disabled_config_builds_no_topology() {
        assert!(Topology::from_config(&TopologyConfig::default(), 4).is_none());
        assert!(Topology::from_config(&enabled(), 4).is_some());
    }

    #[test]
    fn validation_rejects_bad_knobs() {
        enabled().validate(4).unwrap();
        // disabled sections validate regardless of garbage values
        TopologyConfig { ps_nodes: 0, ..TopologyConfig::default() }.validate(4).unwrap();
        assert!(TopologyConfig { ps_nodes: 0, ..enabled() }.validate(4).is_err());
        assert!(TopologyConfig { racks: 0, ..enabled() }.validate(4).is_err());
        assert!(TopologyConfig { racks: 5, ..enabled() }.validate(4).is_err());
        let bad_model = CommModel { per_push: -1.0, per_mb: 0.1 };
        assert!(TopologyConfig { rack_model: bad_model, ..enabled() }.validate(4).is_err());
        assert!(TopologyConfig { cross_model: bad_model, ..enabled() }.validate(4).is_err());
        let nan = CommModel { per_push: 0.0, per_mb: f64::NAN };
        assert!(TopologyConfig { rack_model: nan, ..enabled() }.validate(4).is_err());
    }

    #[test]
    fn default_single_node_single_rack_matches_flat_comm_model() {
        // ps_nodes = 1, racks = 1: the per-worker costs must collapse to
        // the [comm] section's CommCosts::from_model with the rack link.
        let topo = Topology::from_config(&enabled(), 4).unwrap();
        let (pb, db) = (123_456, 4_000_000);
        let flat = CommCosts::from_model(&CommModel::infiniband_like(), pb, db);
        for w in 0..4 {
            let c = topo.worker_costs(w, pb, db);
            assert_eq!(c.push.to_bits(), flat.push.to_bits());
            assert_eq!(c.pull.to_bits(), flat.pull.to_bits());
            assert_eq!((c.push_bytes, c.pull_bytes), (pb, db));
        }
    }

    #[test]
    fn rack_striping_and_population() {
        let cfg = TopologyConfig { racks: 3, ps_nodes: 4, ..enabled() };
        let topo = Topology::from_config(&cfg, 8).unwrap();
        assert_eq!(topo.worker_rack(0), 0);
        assert_eq!(topo.worker_rack(5), 2);
        assert_eq!(topo.node_rack(3), 0);
        // 8 workers over 3 racks: populations 3, 3, 2
        assert_eq!(
            (0..3).map(|r| topo.workers_in_rack(r)).collect::<Vec<_>>(),
            vec![3, 3, 2]
        );
        assert_eq!((0..3).map(|r| topo.workers_in_rack(r)).sum::<usize>(), 8);
    }

    #[test]
    fn cross_rack_workers_pay_more_than_rack_local_ones() {
        // 2 racks, 1 PS node (lives in rack 0): even-indexed workers are
        // rack-local, odd ones cross the (shared, slower) uplink.
        let cfg = TopologyConfig { racks: 2, ps_nodes: 1, ..enabled() };
        let topo = Topology::from_config(&cfg, 4).unwrap();
        let local = topo.worker_costs(0, 1 << 20, 1 << 22);
        let cross = topo.worker_costs(1, 1 << 20, 1 << 22);
        assert!(cross.push > local.push, "cross-rack push must cost more");
        assert!(cross.pull > local.pull, "cross-rack pull must cost more");
        // the uplink is shared by the rack's 2 residents: the cross cost
        // exceeds even the unshared cross-link price
        let unshared = CommModel::ethernet_like().cost(1 << 20);
        assert!(cross.push > unshared);
    }

    #[test]
    fn more_ps_nodes_spread_bytes_but_add_latency() {
        // single rack: every node is rack-local. Doubling nodes halves
        // per-node bytes but doubles the per_push latency terms.
        let one = Topology::from_config(&TopologyConfig { ps_nodes: 1, ..enabled() }, 4).unwrap();
        let four = Topology::from_config(&TopologyConfig { ps_nodes: 4, ..enabled() }, 4).unwrap();
        let c1 = one.worker_costs(0, 8_000_000, 0);
        let c4 = four.worker_costs(0, 8_000_000, 0);
        let m = CommModel::infiniband_like();
        // same total bytes over the same link class: byte cost identical,
        // latency term scales with the fan-out
        let expect4 = 4.0 * m.per_push + m.per_mb * 8.0;
        assert!((c4.push - expect4).abs() < 1e-12);
        assert!((c1.push - (m.per_push + m.per_mb * 8.0)).abs() < 1e-12);
        assert!(c4.push > c1.push);
    }

    #[test]
    fn hierarchical_amortizes_the_uplink_across_the_rack() {
        // 2 racks × 8 workers each, big gradients: flat fan-out makes every
        // cross-rack worker pay the shared uplink in full (scaled by the 8
        // residents), while hierarchical ships ONE combined gradient per
        // rack — per-worker cross cost divided by 8, not multiplied.
        let flat_cfg = TopologyConfig { racks: 2, ps_nodes: 2, ..enabled() };
        let hier_cfg = TopologyConfig { hierarchical: true, ..flat_cfg.clone() };
        let flat = Topology::from_config(&flat_cfg, 16).unwrap();
        let hier = Topology::from_config(&hier_cfg, 16).unwrap();
        let bytes = 16_000_000;
        for w in 0..16 {
            let f = flat.worker_costs(w, bytes, bytes);
            let h = hier.worker_costs(w, bytes, bytes);
            assert!(
                h.push < f.push,
                "worker {w}: hierarchical push {} not under flat {}",
                h.push,
                f.push
            );
        }
        // single rack: no uplink at all, pure rack-local cost
        let single = Topology::from_config(
            &TopologyConfig { hierarchical: true, ..enabled() },
            4,
        )
        .unwrap();
        let c = single.worker_costs(0, bytes, bytes);
        assert_eq!(c.push.to_bits(), CommModel::infiniband_like().cost(bytes).to_bits());
    }

    #[test]
    fn uplink_bytes_partition_the_transfer() {
        // single rack: no uplink, whatever the node count.
        let one = Topology::from_config(&TopologyConfig { ps_nodes: 4, ..enabled() }, 4).unwrap();
        assert_eq!(one.uplink_bytes(0, 1 << 20), 0.0);

        // flat, 2 racks × 4 nodes: every rack hosts 2 of the 4 nodes, so
        // exactly half of each worker's bytes cross its uplink.
        let cfg = TopologyConfig { racks: 2, ps_nodes: 4, ..enabled() };
        let flat = Topology::from_config(&cfg, 8).unwrap();
        for w in 0..8 {
            assert_eq!(flat.uplink_bytes(w, 1_000_000), 500_000.0);
        }

        // flat, 2 racks × 1 node (rack 0): rack-0 workers are all-local,
        // rack-1 workers cross in full.
        let lone = Topology::from_config(
            &TopologyConfig { racks: 2, ps_nodes: 1, ..enabled() },
            4,
        )
        .unwrap();
        assert_eq!(lone.uplink_bytes(0, 1_000_000), 0.0);
        assert_eq!(lone.uplink_bytes(1, 1_000_000), 1_000_000.0);

        // hierarchical: one combined gradient per rack, amortized over the
        // residents — per-rack totals sum back to exactly `bytes`.
        let hier = Topology::from_config(
            &TopologyConfig { hierarchical: true, racks: 3, ps_nodes: 3, ..enabled() },
            8,
        )
        .unwrap();
        for r in 0..3 {
            let rack_total: f64 = (0..8)
                .filter(|&w| hier.worker_rack(w) == r)
                .map(|w| hier.uplink_bytes(w, 700_000))
                .sum();
            assert!((rack_total - 700_000.0).abs() < 1e-6, "rack {r}: {rack_total}");
        }
    }

    #[test]
    fn all_worker_costs_is_worker_ordered_and_deterministic() {
        let cfg = TopologyConfig { racks: 3, ps_nodes: 5, hierarchical: false, ..enabled() };
        let topo = Topology::from_config(&cfg, 9).unwrap();
        let all = topo.all_worker_costs(1000, 2000);
        assert_eq!(all.len(), 9);
        for (w, c) in all.iter().enumerate() {
            let again = topo.worker_costs(w, 1000, 2000);
            assert_eq!(c.push.to_bits(), again.push.to_bits());
            assert_eq!(c.pull.to_bits(), again.pull.to_bits());
            // same-rack workers see identical costs (striping symmetry)
            let peer = topo.worker_costs((w + 3) % 9, 1000, 2000);
            if topo.worker_rack(w) == topo.worker_rack((w + 3) % 9)
                && topo.workers_in_rack(topo.worker_rack(w))
                    == topo.workers_in_rack(topo.worker_rack((w + 3) % 9))
            {
                assert_eq!(c.push.to_bits(), peer.push.to_bits());
            }
        }
    }
}
