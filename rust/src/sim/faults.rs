//! Fault injection & elastic worker membership for the discrete-event core.
//!
//! DC-ASGD's value proposition is robustness to *delayed* gradients, and the
//! regime where delay actually explodes in production is not a healthy fleet
//! with mild jitter — it is worker crashes, restarts, permanent departures,
//! late joins, and post-recovery slowdowns (the "arbitrary delays" regime of
//! Mishchenko et al. and Zhou et al., see PAPERS.md). This module gives the
//! simulator that regime:
//!
//! * [`FaultConfig`] — the `[faults]` config section (off by default; with
//!   it off the scheduler is bit-identical to a fault-free build).
//! * [`FaultPlan`] — a seeded, per-worker stream of fault decisions: when
//!   the next crash lands (Poisson), how long a restart takes (exponential,
//!   or never — permanent departure), when transient straggler windows open
//!   and how much they slow the worker, and which workers join late.
//! * [`CrashPolicy`] — what happens to the gradient a worker was computing
//!   when it crashed: [`CrashPolicy::Drop`] discards it (kill -9), while
//!   [`CrashPolicy::Salvage`] lets the in-flight compute finish and commit
//!   before the worker goes down (graceful drain).
//! * [`FaultStats`] — counters the scheduler maintains and the metrics
//!   pipeline surfaces (`crashes`, `restarts`, `departures`, `late_joins`,
//!   `dropped_inflight`, `salvaged_inflight`, `straggle_events`).
//!
//! The plan only makes *decisions*; the [`crate::sim::Scheduler`] owns the
//! lifecycle mechanics (epoch-tagged finish events so a crashed epoch can
//! never commit, live-membership-aware protocol gates so a dead worker
//! never wedges a barrier or an SSP window, restart/join scheduling). All
//! randomness flows through per-worker forked [`Pcg64`] streams, so fault
//! timelines are bit-reproducible for a given `(config, workers, seed)` and
//! decorrelated across workers.

use crate::util::rng::Pcg64;
use anyhow::bail;

/// What to do with the gradient a worker was computing when it crashed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CrashPolicy {
    /// The in-flight compute is lost (kill -9): its finish event is
    /// invalidated and counted as `dropped_inflight`.
    Drop,
    /// The in-flight compute finishes and commits, then the worker goes
    /// down (graceful drain); counted as `salvaged_inflight`.
    Salvage,
}

impl CrashPolicy {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "drop" => CrashPolicy::Drop,
            "salvage" | "drain" => CrashPolicy::Salvage,
            other => bail!("unknown crash policy {other:?} (drop|salvage)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            CrashPolicy::Drop => "drop",
            CrashPolicy::Salvage => "salvage",
        }
    }
}

/// The `[faults]` config section. Defaults model a mildly unreliable fleet
/// but stay **inert** until `enabled` is set (or, like `[comm]`, until any
/// parameter is given explicitly); with faults off the scheduler takes no
/// fault code path and schedules stay bit-identical to pre-fault builds.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultConfig {
    pub enabled: bool,
    /// Expected crashes per worker per simulated second (Poisson rate).
    pub crash_rate: f64,
    /// Mean restart delay in simulated seconds (exponential).
    pub restart_mean: f64,
    /// Probability that a crash is a permanent departure (never restarts).
    pub departure_prob: f64,
    /// Expected transient-slowdown windows per worker per simulated second.
    pub straggler_rate: f64,
    /// Compute-time multiplier while a straggle window is open (>= 1).
    pub straggler_factor: f64,
    /// Mean straggle-window length in simulated seconds (exponential).
    pub straggler_duration: f64,
    /// Number of workers absent at t = 0 that join later (elastic
    /// scale-up). The highest-indexed workers are the late joiners.
    pub late_join: usize,
    /// Late joiners arrive uniformly within (0, late_join_by].
    pub late_join_by: f64,
    /// In-flight gradient policy on crash.
    pub policy: CrashPolicy,
    /// Fault-stream seed; 0 derives it from the experiment seed.
    pub seed: u64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            crash_rate: 0.02,
            restart_mean: 5.0,
            departure_prob: 0.1,
            straggler_rate: 0.0,
            straggler_factor: 4.0,
            straggler_duration: 5.0,
            late_join: 0,
            late_join_by: 10.0,
            policy: CrashPolicy::Drop,
            seed: 0,
        }
    }
}

impl FaultConfig {
    /// Validate the knobs against a fleet of `workers` workers.
    pub fn validate(&self, workers: usize) -> anyhow::Result<()> {
        if !self.enabled {
            return Ok(());
        }
        if !(self.crash_rate >= 0.0 && self.crash_rate.is_finite()) {
            bail!("faults.crash_rate must be finite and >= 0");
        }
        if !(self.restart_mean > 0.0 && self.restart_mean.is_finite()) {
            bail!("faults.restart_mean must be finite and > 0");
        }
        if !(0.0..=1.0).contains(&self.departure_prob) {
            bail!("faults.departure_prob must be in [0, 1]");
        }
        if !(self.straggler_rate >= 0.0 && self.straggler_rate.is_finite()) {
            bail!("faults.straggler_rate must be finite and >= 0");
        }
        if self.straggler_rate > 0.0 && self.straggler_factor < 1.0 {
            bail!("faults.straggler_factor must be >= 1 (it multiplies compute time)");
        }
        if self.straggler_rate > 0.0
            && !(self.straggler_duration > 0.0 && self.straggler_duration.is_finite())
        {
            bail!("faults.straggler_duration must be finite and > 0");
        }
        if self.late_join >= workers {
            bail!(
                "faults.late_join = {} but only {} workers exist: at least one worker \
                 must be present at t = 0",
                self.late_join,
                workers
            );
        }
        if self.late_join > 0 && !(self.late_join_by > 0.0 && self.late_join_by.is_finite()) {
            bail!("faults.late_join_by must be finite and > 0");
        }
        Ok(())
    }
}

/// Lifecycle counters maintained by the scheduler while a fault plan is
/// active; surfaced through [`crate::metrics::TrainReport`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Crash events that hit a live worker.
    pub crashes: u64,
    /// Rejoins after a crash (excludes late joins).
    pub restarts: u64,
    /// Crashes that became permanent departures.
    pub departures: u64,
    /// Workers that joined an already-running fleet (elastic scale-up).
    pub late_joins: u64,
    /// In-flight computes invalidated by a [`CrashPolicy::Drop`] crash.
    pub dropped_inflight: u64,
    /// In-flight computes delivered before death ([`CrashPolicy::Salvage`]).
    pub salvaged_inflight: u64,
    /// Transient straggle windows opened.
    pub straggle_events: u64,
}

/// A seeded stream of per-worker fault decisions, consumed lazily by the
/// scheduler (no horizon needed: the next crash / straggle window is
/// sampled when the previous one resolves, so plans extend to arbitrarily
/// long runs while staying bit-reproducible).
#[derive(Debug)]
pub struct FaultPlan {
    crash_rate: f64,
    restart_mean: f64,
    departure_prob: f64,
    straggler_rate: f64,
    straggler_factor: f64,
    straggler_duration: f64,
    policy: CrashPolicy,
    /// Late joiners' arrival times (None = present at t = 0).
    join_at: Vec<Option<f64>>,
    rngs: Vec<Pcg64>,
}

impl FaultPlan {
    /// Build the plan for a fleet; `None` when the section is disabled, so
    /// callers pass it straight to [`crate::sim::Scheduler::with_faults`].
    /// `run_seed` feeds the fault streams when `cfg.seed == 0`.
    pub fn from_config(cfg: &FaultConfig, workers: usize, run_seed: u64) -> Option<FaultPlan> {
        if !cfg.enabled {
            return None;
        }
        let seed = if cfg.seed != 0 { cfg.seed } else { run_seed ^ 0xFA_17_5EED };
        let mut root = Pcg64::new(seed ^ 0xC4A5_4EE5);
        let mut rngs: Vec<Pcg64> = (0..workers).map(|m| root.fork(m as u64)).collect();
        // the highest-indexed workers join late (deterministic choice:
        // worker 0 is always present at t = 0 when the config validates)
        let first_late = workers - cfg.late_join.min(workers.saturating_sub(1));
        let join_at = (0..workers)
            .map(|m| {
                if m >= first_late {
                    // (0, by]: strictly after t = 0 so "late" means late
                    let u = 1.0 - rngs[m].next_f64();
                    Some(u * cfg.late_join_by)
                } else {
                    None
                }
            })
            .collect();
        Some(FaultPlan {
            crash_rate: cfg.crash_rate,
            restart_mean: cfg.restart_mean,
            departure_prob: cfg.departure_prob,
            straggler_rate: cfg.straggler_rate,
            straggler_factor: cfg.straggler_factor,
            straggler_duration: cfg.straggler_duration,
            policy: cfg.policy,
            join_at,
            rngs,
        })
    }

    pub fn workers(&self) -> usize {
        self.rngs.len()
    }

    pub fn policy(&self) -> CrashPolicy {
        self.policy
    }

    /// When worker `m` joins the fleet (None = present at t = 0).
    pub fn join_time(&self, worker: usize) -> Option<f64> {
        self.join_at[worker]
    }

    /// Time until worker `m`'s next crash, sampled at (re)activation.
    /// `None` when crashes are disabled (rate 0).
    pub fn next_crash_in(&mut self, worker: usize) -> Option<f64> {
        if self.crash_rate <= 0.0 {
            return None;
        }
        Some(self.rngs[worker].exponential(1.0 / self.crash_rate))
    }

    /// Restart delay for worker `m`'s current crash; `None` means the
    /// crash is a permanent departure.
    pub fn restart_delay(&mut self, worker: usize) -> Option<f64> {
        let rng = &mut self.rngs[worker];
        if rng.next_f64() < self.departure_prob {
            None
        } else {
            Some(rng.exponential(self.restart_mean))
        }
    }

    /// Time until worker `m`'s next straggle window opens; `None` when
    /// straggling is disabled (rate 0).
    pub fn next_straggle_in(&mut self, worker: usize) -> Option<f64> {
        if self.straggler_rate <= 0.0 {
            return None;
        }
        Some(self.rngs[worker].exponential(1.0 / self.straggler_rate))
    }

    /// `(slowdown factor, window length)` for a straggle window that just
    /// opened on worker `m`.
    pub fn straggle_window(&mut self, worker: usize) -> (f64, f64) {
        let dur = self.rngs[worker].exponential(self.straggler_duration);
        (self.straggler_factor, dur)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn enabled() -> FaultConfig {
        FaultConfig { enabled: true, ..FaultConfig::default() }
    }

    #[test]
    fn disabled_config_builds_no_plan() {
        assert!(FaultPlan::from_config(&FaultConfig::default(), 4, 1).is_none());
        assert!(FaultPlan::from_config(&enabled(), 4, 1).is_some());
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in [CrashPolicy::Drop, CrashPolicy::Salvage] {
            assert_eq!(CrashPolicy::parse(p.name()).unwrap(), p);
        }
        assert_eq!(CrashPolicy::parse("drain").unwrap(), CrashPolicy::Salvage);
        assert!(CrashPolicy::parse("explode").is_err());
    }

    #[test]
    fn validation_rejects_bad_knobs() {
        let ok = enabled();
        ok.validate(4).unwrap();
        // disabled sections validate regardless of garbage values
        let mut off = FaultConfig { crash_rate: -1.0, ..FaultConfig::default() };
        off.validate(4).unwrap();
        off.enabled = true;
        assert!(off.validate(4).is_err());

        let bad = FaultConfig { restart_mean: 0.0, ..enabled() };
        assert!(bad.validate(4).is_err());
        let bad = FaultConfig { departure_prob: 1.5, ..enabled() };
        assert!(bad.validate(4).is_err());
        let bad =
            FaultConfig { straggler_rate: 0.1, straggler_factor: 0.5, ..enabled() };
        assert!(bad.validate(4).is_err());
        let bad =
            FaultConfig { straggler_rate: 0.1, straggler_duration: 0.0, ..enabled() };
        assert!(bad.validate(4).is_err());
        let bad = FaultConfig { late_join: 4, ..enabled() };
        assert!(bad.validate(4).is_err(), "the whole fleet cannot join late");
        let bad = FaultConfig { late_join: 1, late_join_by: 0.0, ..enabled() };
        assert!(bad.validate(4).is_err());
    }

    #[test]
    fn streams_are_seed_deterministic_and_per_worker_distinct() {
        let cfg = FaultConfig { crash_rate: 0.1, straggler_rate: 0.05, ..enabled() };
        let mut a = FaultPlan::from_config(&cfg, 3, 7).unwrap();
        let mut b = FaultPlan::from_config(&cfg, 3, 7).unwrap();
        let mut c = FaultPlan::from_config(&cfg, 3, 8).unwrap();
        let mut diverged = false;
        for w in 0..3 {
            for _ in 0..20 {
                let (x, y, z) =
                    (a.next_crash_in(w).unwrap(), b.next_crash_in(w).unwrap(), c.next_crash_in(w).unwrap());
                assert_eq!(x.to_bits(), y.to_bits(), "same seed diverged");
                diverged |= x.to_bits() != z.to_bits();
            }
        }
        assert!(diverged, "different run seeds never diverged");
        // workers draw distinct streams
        let mut d = FaultPlan::from_config(&cfg, 2, 9).unwrap();
        let xs: Vec<u64> = (0..10).map(|_| d.next_crash_in(0).unwrap().to_bits()).collect();
        let ys: Vec<u64> = (0..10).map(|_| d.next_crash_in(1).unwrap().to_bits()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn explicit_fault_seed_overrides_run_seed() {
        let cfg = FaultConfig { crash_rate: 0.1, seed: 42, ..enabled() };
        let mut a = FaultPlan::from_config(&cfg, 2, 1).unwrap();
        let mut b = FaultPlan::from_config(&cfg, 2, 2).unwrap();
        for w in 0..2 {
            assert_eq!(
                a.next_crash_in(w).unwrap().to_bits(),
                b.next_crash_in(w).unwrap().to_bits(),
                "pinned fault seed must decouple the plan from the run seed"
            );
        }
    }

    #[test]
    fn late_joiners_are_the_top_indices_with_positive_times() {
        let cfg = FaultConfig { late_join: 2, late_join_by: 7.0, ..enabled() };
        let plan = FaultPlan::from_config(&cfg, 5, 3).unwrap();
        for w in 0..3 {
            assert_eq!(plan.join_time(w), None, "worker {w} must start at t = 0");
        }
        for w in 3..5 {
            let t = plan.join_time(w).expect("late joiner has a join time");
            assert!(t > 0.0 && t <= 7.0, "join time {t} outside (0, 7]");
        }
    }

    #[test]
    fn zero_rates_disable_their_streams() {
        let cfg = FaultConfig { crash_rate: 0.0, straggler_rate: 0.0, ..enabled() };
        let mut plan = FaultPlan::from_config(&cfg, 2, 1).unwrap();
        assert!(plan.next_crash_in(0).is_none());
        assert!(plan.next_straggle_in(0).is_none());
    }

    #[test]
    fn departure_prob_extremes() {
        let cfg = FaultConfig { departure_prob: 1.0, ..enabled() };
        let mut plan = FaultPlan::from_config(&cfg, 1, 1).unwrap();
        for _ in 0..10 {
            assert!(plan.restart_delay(0).is_none(), "prob 1 must always depart");
        }
        let cfg = FaultConfig { departure_prob: 0.0, ..enabled() };
        let mut plan = FaultPlan::from_config(&cfg, 1, 1).unwrap();
        for _ in 0..10 {
            let d = plan.restart_delay(0).expect("prob 0 must always restart");
            assert!(d >= 0.0);
        }
    }

    #[test]
    fn straggle_windows_scale_with_config() {
        let cfg = FaultConfig {
            straggler_rate: 0.5,
            straggler_factor: 3.5,
            straggler_duration: 2.0,
            ..enabled()
        };
        let mut plan = FaultPlan::from_config(&cfg, 1, 1).unwrap();
        let mut total = 0.0;
        for _ in 0..2000 {
            let (f, d) = plan.straggle_window(0);
            assert_eq!(f, 3.5);
            assert!(d >= 0.0);
            total += d;
        }
        let mean = total / 2000.0;
        assert!((mean - 2.0).abs() < 0.2, "empirical window mean {mean} far from 2.0");
    }
}
