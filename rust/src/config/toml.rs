//! TOML-subset parser for experiment config files.
//!
//! Supported grammar (everything the framework's configs use):
//!   * `[section]` and `[section.sub]` headers
//!   * `key = value` with string / integer / float / bool / array values
//!   * `#` comments, blank lines
//!
//! Values land in a flat `section.key -> Value` map; [`super::ExperimentConfig`]
//! performs the typed extraction + validation.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config parse error on line {}: {}", self.line, self.msg)
    }
}
impl std::error::Error for TomlError {}

/// Parsed document: flat `"section.key"` (or bare `"key"`) → value map.
/// `order` records document (insertion) order of the flattened keys, which
/// scenario sweep axes rely on for a deterministic grid nesting.
#[derive(Debug, Clone, Default)]
pub struct Doc {
    pub entries: BTreeMap<String, Value>,
    pub order: Vec<String>,
}

impl Doc {
    pub fn parse(src: &str) -> Result<Doc, TomlError> {
        let mut entries = BTreeMap::new();
        let mut order = Vec::new();
        let mut section = String::new();
        for (lineno, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let err = |msg: &str| TomlError { line: lineno + 1, msg: msg.to_string() };
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or_else(|| err("unterminated section"))?;
                let name = name.trim();
                if name.is_empty() {
                    return Err(err("empty section name"));
                }
                section = name.to_string();
                continue;
            }
            let (key, val) = line.split_once('=').ok_or_else(|| err("expected key = value"))?;
            // keys may be quoted ("/train/lr" = 0.5 — the JSON-pointer
            // style scenario overrides use this)
            let key = key.trim();
            let key = key
                .strip_prefix('"')
                .and_then(|k| k.strip_suffix('"'))
                .unwrap_or(key)
                .trim();
            if key.is_empty() {
                return Err(err("empty key"));
            }
            let value = parse_value(val.trim()).map_err(|m| err(&m))?;
            let full = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
            if entries.insert(full.clone(), value).is_some() {
                return Err(err(&format!("duplicate key {full}")));
            }
            order.push(full);
        }
        Ok(Doc { entries, order })
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.entries.keys()
    }

    /// Keys in document order (the BTreeMap iteration order is sorted;
    /// sweep-axis nesting wants the order the file declares).
    pub fn ordered_keys(&self) -> impl Iterator<Item = &String> {
        self.order.iter()
    }
}

fn strip_comment(line: &str) -> &str {
    // a `#` inside a quoted string does not start a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("missing value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or("unterminated string")?;
        if inner.contains('"') {
            return Err("embedded quote in string".into());
        }
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest.strip_suffix(']').ok_or("unterminated array")?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let items = inner
            .split(',')
            .map(|p| parse_value(p.trim()))
            .collect::<Result<Vec<_>, _>>()?;
        return Ok(Value::Array(items));
    }
    // number: int if it parses as i64 and has no float syntax
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(i) = s.parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    s.parse::<f64>().map(Value::Float).map_err(|_| format!("bad value {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = Doc::parse(
            r#"
            # experiment
            seed = 42
            [train]
            lr = 0.5            # initial
            algo = "dc-asgd-a"
            verbose = true
            decay_epochs = [80, 120]
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("seed").unwrap().as_i64(), Some(42));
        assert_eq!(doc.get("train.lr").unwrap().as_f64(), Some(0.5));
        assert_eq!(doc.get("train.algo").unwrap().as_str(), Some("dc-asgd-a"));
        assert_eq!(doc.get("train.verbose").unwrap().as_bool(), Some(true));
        let arr = match doc.get("train.decay_epochs").unwrap() {
            Value::Array(a) => a,
            _ => panic!(),
        };
        assert_eq!(arr[0].as_i64(), Some(80));
    }

    #[test]
    fn int_vs_float() {
        let doc = Doc::parse("a = 3\nb = 3.0\nc = 1e-3").unwrap();
        assert_eq!(doc.get("a").unwrap(), &Value::Int(3));
        assert_eq!(doc.get("b").unwrap(), &Value::Float(3.0));
        assert_eq!(doc.get("c").unwrap().as_f64(), Some(1e-3));
        // ints coerce to f64 on demand
        assert_eq!(doc.get("a").unwrap().as_f64(), Some(3.0));
        assert_eq!(doc.get("b").unwrap().as_i64(), None);
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = Doc::parse(r##"tag = "exp#7" # trailing"##).unwrap();
        assert_eq!(doc.get("tag").unwrap().as_str(), Some("exp#7"));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = Doc::parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(err.line, 2);
        let err = Doc::parse("[unterminated\n").unwrap_err();
        assert_eq!(err.line, 1);
        let err = Doc::parse("a = 1\na = 2").unwrap_err();
        assert!(err.msg.contains("duplicate"));
    }

    #[test]
    fn nested_section_names() {
        let doc = Doc::parse("[sim.delay]\nmodel = \"pareto\"").unwrap();
        assert_eq!(doc.get("sim.delay.model").unwrap().as_str(), Some("pareto"));
    }

    #[test]
    fn negative_numbers() {
        let doc = Doc::parse("a = -7\nb = -0.25").unwrap();
        assert_eq!(doc.get("a").unwrap().as_i64(), Some(-7));
        assert_eq!(doc.get("b").unwrap().as_f64(), Some(-0.25));
        assert_eq!(doc.get("a").unwrap().as_usize(), None);
    }

    #[test]
    fn quoted_keys_and_document_order() {
        let doc = Doc::parse(
            "[overrides]\n\"/train/lr\" = 0.5\n/workers = 8\nplain = 1",
        )
        .unwrap();
        assert_eq!(doc.get("overrides./train/lr").unwrap().as_f64(), Some(0.5));
        assert_eq!(doc.get("overrides./workers").unwrap().as_i64(), Some(8));
        let order: Vec<&String> = doc.ordered_keys().collect();
        assert_eq!(order[0], "overrides./train/lr");
        assert_eq!(order[1], "overrides./workers");
        assert_eq!(order[2], "overrides.plain");
    }

    #[test]
    fn empty_and_mixed_arrays() {
        let doc = Doc::parse("a = []\nb = [1, 2.5, \"x\"]").unwrap();
        assert_eq!(doc.get("a").unwrap(), &Value::Array(vec![]));
        match doc.get("b").unwrap() {
            Value::Array(items) => {
                assert_eq!(items.len(), 3);
                assert_eq!(items[2].as_str(), Some("x"));
            }
            _ => panic!(),
        }
    }
}
