//! The knob manifest: every experiment knob declared once, with a stable
//! id, type, bounds, default, and help text — plus the cross-knob rejection
//! rules. This is the single source of truth that the TOML loader, the CLI
//! overlay, the scenario expander, and `dcasgd validate` all derive from.
//!
//! A knob has two spellings of the same stable id:
//!
//! * JSON-pointer style: `/train/lr` (scenario `[overrides]` / `[sweep]`)
//! * dotted TOML style:  `train.lr`  (config files, `[section] key = v`)
//!
//! [`find`] accepts either. Apply order is *manifest order*, not document
//! order: [`apply_doc`] sorts the document's keys by their manifest index
//! before applying, so order-sensitive pairs (codec before ratio, delay
//! model before its parameters, explicit `enabled` after the auto-enabling
//! parameter knobs) behave identically however the file is arranged.
//!
//! Validation is split the same way the old hand-rolled checks were:
//!
//! * per-knob [`Bounds`] (range + finiteness), checked through the knob's
//!   getter so model-dependent knobs (e.g. `sim.delay.jitter`) are only
//!   checked when applicable;
//! * cross-knob [`Rule`]s, each carrying the *pinned* message fragment and
//!   a canonical TOML example that must trip it — [`rejection_cases`]
//!   enumerates bounds violations + rules + parse-level rejections, so the
//!   rejected-combination matrix test iterates the manifest instead of a
//!   hand-maintained list.

use super::toml::{Doc, Value};
use super::{Algorithm, CommConfig, DatasetKind, DelayModel, ExecMode, ExperimentConfig, UpdateBackend};
use crate::compress::CodecConfig;
use crate::util::cli::Args;
use anyhow::bail;
use std::sync::OnceLock;

/// Knob value type (drives CLI parsing and the `knobs` table).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Ty {
    Str,
    Bool,
    USize,
    U64,
    F64,
    /// Closed vocabulary; the setter owns the (pinned) rejection message.
    Enum(&'static [&'static str]),
    USizeList,
    F64List,
}

impl Ty {
    pub fn name(&self) -> &'static str {
        match self {
            Ty::Str => "string",
            Ty::Bool => "bool",
            Ty::USize => "usize",
            Ty::U64 => "u64",
            Ty::F64 => "f64",
            Ty::Enum(_) => "enum",
            Ty::USizeList => "[usize]",
            Ty::F64List => "[f64]",
        }
    }
}

/// Numeric range constraint with its pinned rejection message. Non-finite
/// values never pass.
#[derive(Clone, Copy, Debug)]
pub struct Bounds {
    pub lo: f64,
    pub lo_excl: bool,
    pub hi: f64,
    pub hi_excl: bool,
    pub msg: &'static str,
}

impl Bounds {
    pub fn admits(&self, x: f64) -> bool {
        x.is_finite()
            && (if self.lo_excl { x > self.lo } else { x >= self.lo })
            && (if self.hi_excl { x < self.hi } else { x <= self.hi })
    }

    /// A value violating the bounds, for the generated rejection matrix.
    /// Prefers the high edge (stays a valid non-negative literal for usize
    /// knobs); unbounded-above knobs violate the low edge.
    pub fn violation(&self) -> f64 {
        if self.hi.is_finite() {
            if self.hi_excl {
                self.hi
            } else {
                self.hi + 1.0
            }
        } else if self.lo_excl {
            self.lo
        } else {
            self.lo - 1.0
        }
    }

    /// Human-readable interval, for the `knobs` table.
    pub fn describe(&self) -> String {
        let lo_b = if self.lo_excl { '(' } else { '[' };
        let hi_b = if self.hi_excl { ')' } else { ']' };
        let side = |x: f64| {
            if x == f64::INFINITY {
                "inf".to_string()
            } else if x == f64::NEG_INFINITY {
                "-inf".to_string()
            } else {
                format!("{x}")
            }
        };
        format!("{lo_b}{}, {}{hi_b}", side(self.lo), side(self.hi))
    }
}

/// One declared knob. `get` returns `None` when the knob does not apply to
/// the current config (e.g. `sim.delay.scale` on a non-Pareto model), which
/// also skips its bounds check. `set` applies a parsed TOML value.
pub struct Knob {
    /// JSON-pointer-style stable id (`/train/lr`).
    pub id: &'static str,
    /// Dotted TOML key (`train.lr`).
    pub toml_key: &'static str,
    /// CLI flag (`--lr`), when one exists.
    pub cli: Option<&'static str>,
    pub ty: Ty,
    pub bounds: Option<Bounds>,
    /// Default value, as the `knobs` table prints it.
    pub default: &'static str,
    pub help: &'static str,
    /// TOML prefix that makes a generated bounds-violation example land on
    /// this knob (e.g. selecting the pareto model before `sim.delay.scale`).
    pub ctx: &'static str,
    pub get: fn(&ExperimentConfig) -> Option<Value>,
    pub set: fn(&mut ExperimentConfig, &Value) -> anyhow::Result<()>,
}

/// One cross-knob rejection rule: the check, its pinned message fragment,
/// and a canonical TOML example that must trip it.
pub struct Rule {
    pub id: &'static str,
    /// Pinned fragment the rejection message must contain.
    pub needle: &'static str,
    /// TOML document that must be rejected with `needle`.
    pub example: &'static str,
    pub check: fn(&ExperimentConfig) -> anyhow::Result<()>,
}

/// Parse-level rejections (bad vocabulary / bad types / unknown keys):
/// `(toml, pinned message fragment)`. These fail before a config exists, so
/// they are cases rather than `Rule`s.
pub const PARSE_CASES: &[(&str, &str)] = &[
    ("algorithm = \"bogus\"", "unknown algorithm"),
    ("dataset = \"bogus\"", "unknown dataset"),
    ("exec_mode = \"warp\"", "unknown exec_mode"),
    ("update_backend = \"tpu\"", "unknown update_backend"),
    ("[sim.delay]\nmodel = \"warp\"", "unknown delay model"),
    ("[comm]\nmodel = \"warp\"", "unknown comm model"),
    ("[faults]\npolicy = \"explode\"", "unknown crash policy"),
    ("[compress]\ncodec = \"warp\"", "unknown codec"),
    ("preset = \"bogus\"", "unknown preset"),
    ("bogus_knob = 1", "unknown config key"),
    ("workers = \"many\"", "must be a non-negative integer"),
    ("[train]\nlr = \"fast\"", "must be a number"),
    ("[sim.delay]\nmodel = \"constant\"\njitter = 0.5", "applies to the uniform/heterogeneous delay models"),
    ("[sim.delay]\nmodel = \"uniform\"\nscale = 2.0", "applies to the pareto delay model"),
    ("[sim.delay]\nmodel = \"uniform\"\nspeeds = [1.0, 2.0]", "applies to the heterogeneous delay model"),
    ("[compress]\nratio = 0.5", "requires a topk/randk codec"),
    ("[compress]\nbits = 4", "requires the qsgd codec"),
    ("[serving]\narrival = \"warp\"", "unknown arrival process"),
    ("[serving]\nread_mode = \"warp\"", "unknown serving read_mode"),
];

// ------------------------------------------------------------ typed helpers

fn want_f64(key: &str, v: &Value) -> anyhow::Result<f64> {
    v.as_f64().ok_or_else(|| anyhow::anyhow!("{key} must be a number"))
}

fn want_usize(key: &str, v: &Value) -> anyhow::Result<usize> {
    v.as_usize().ok_or_else(|| anyhow::anyhow!("{key} must be a non-negative integer"))
}

fn want_str<'v>(key: &str, v: &'v Value) -> anyhow::Result<&'v str> {
    v.as_str().ok_or_else(|| anyhow::anyhow!("{key} must be a string"))
}

fn want_bool(key: &str, v: &Value) -> anyhow::Result<bool> {
    v.as_bool().ok_or_else(|| anyhow::anyhow!("{key} must be a boolean"))
}

const UNBOUNDED: f64 = f64::INFINITY;

fn bounds(lo: f64, lo_excl: bool, hi: f64, hi_excl: bool, msg: &'static str) -> Option<Bounds> {
    Some(Bounds { lo, lo_excl, hi, hi_excl, msg })
}

// --------------------------------------------------------------- the knobs

/// The manifest, in apply order. Declaration order is load-bearing:
/// `*.enabled` knobs come after the parameter knobs of their section (so an
/// explicit `enabled` always has the last word over auto-enabling
/// parameters), `compress.codec` before its parameter knobs, and
/// `sim.delay.model` before the model parameters.
pub fn knobs() -> &'static [Knob] {
    static KNOBS: OnceLock<Vec<Knob>> = OnceLock::new();
    KNOBS.get_or_init(build_knobs)
}

#[allow(clippy::too_many_lines)]
fn build_knobs() -> Vec<Knob> {
    vec![
        Knob {
            id: "/model",
            toml_key: "model",
            cli: Some("model"),
            ty: Ty::Str,
            bounds: None,
            default: "mlp_cifar",
            help: "AOT artifact/model name from the manifest",
            ctx: "",
            get: |c| Some(Value::Str(c.model.clone())),
            set: |c, v| {
                c.model = want_str("model", v)?.to_string();
                Ok(())
            },
        },
        Knob {
            id: "/dataset",
            toml_key: "dataset",
            cli: None,
            ty: Ty::Enum(&["cifar-like", "imagenet-like", "lm-corpus"]),
            bounds: None,
            default: "cifar-like",
            help: "synthetic dataset family",
            ctx: "",
            get: |c| Some(Value::Str(c.dataset.name().to_string())),
            set: |c, v| {
                c.dataset = DatasetKind::parse(want_str("dataset", v)?)?;
                Ok(())
            },
        },
        Knob {
            id: "/algorithm",
            toml_key: "algorithm",
            cli: Some("algo"),
            ty: Ty::Enum(&["sgd", "ssgd", "dc-ssgd", "asgd", "dc-asgd-c", "dc-asgd-a", "ssp", "dc-s3gd", "hier-ssgd"]),
            bounds: None,
            default: "asgd",
            help: "update rule / parallelization protocol",
            ctx: "",
            get: |c| Some(Value::Str(c.algorithm.name().to_string())),
            set: |c, v| {
                c.algorithm = Algorithm::parse(want_str("algorithm", v)?)?;
                Ok(())
            },
        },
        Knob {
            id: "/workers",
            toml_key: "workers",
            cli: Some("workers"),
            ty: Ty::USize,
            bounds: bounds(1.0, false, UNBOUNDED, false, "workers must be >= 1"),
            default: "4",
            help: "number of local workers M",
            ctx: "",
            get: |c| Some(Value::Int(c.workers as i64)),
            set: |c, v| {
                c.workers = want_usize("workers", v)?;
                Ok(())
            },
        },
        Knob {
            id: "/epochs",
            toml_key: "epochs",
            cli: Some("epochs"),
            ty: Ty::USize,
            bounds: None,
            default: "10",
            help: "training epochs (0 = step-capped via max_steps)",
            ctx: "",
            get: |c| Some(Value::Int(c.epochs as i64)),
            set: |c, v| {
                c.epochs = want_usize("epochs", v)?;
                Ok(())
            },
        },
        Knob {
            id: "/max_steps",
            toml_key: "max_steps",
            cli: Some("max-steps"),
            ty: Ty::USize,
            bounds: None,
            default: "0",
            help: "hard cap on global update steps (0 = no cap)",
            ctx: "",
            get: |c| Some(Value::Int(c.max_steps as i64)),
            set: |c, v| {
                c.max_steps = want_usize("max_steps", v)?;
                Ok(())
            },
        },
        Knob {
            id: "/data/train_size",
            toml_key: "data.train_size",
            cli: Some("train-size"),
            ty: Ty::USize,
            bounds: bounds(1.0, false, UNBOUNDED, false, "train/test sizes must be positive"),
            default: "4096",
            help: "training-set size",
            ctx: "",
            get: |c| Some(Value::Int(c.train_size as i64)),
            set: |c, v| {
                c.train_size = want_usize("data.train_size", v)?;
                Ok(())
            },
        },
        Knob {
            id: "/data/test_size",
            toml_key: "data.test_size",
            cli: Some("test-size"),
            ty: Ty::USize,
            bounds: bounds(1.0, false, UNBOUNDED, false, "train/test sizes must be positive"),
            default: "1024",
            help: "test-set size",
            ctx: "",
            get: |c| Some(Value::Int(c.test_size as i64)),
            set: |c, v| {
                c.test_size = want_usize("data.test_size", v)?;
                Ok(())
            },
        },
        Knob {
            id: "/train/lr",
            toml_key: "train.lr",
            cli: Some("lr"),
            ty: Ty::F64,
            bounds: bounds(0.0, true, UNBOUNDED, false, "lr must be positive"),
            default: "0.1",
            help: "base learning rate",
            ctx: "",
            get: |c| Some(Value::Float(c.lr.base)),
            set: |c, v| {
                c.lr.base = want_f64("train.lr", v)?;
                Ok(())
            },
        },
        Knob {
            id: "/train/decay_epochs",
            toml_key: "train.decay_epochs",
            cli: None,
            ty: Ty::USizeList,
            bounds: None,
            default: "[]",
            help: "epochs at which lr decays by decay_factor",
            ctx: "",
            get: |c| {
                Some(Value::Array(c.lr.decay_epochs.iter().map(|&e| Value::Int(e as i64)).collect()))
            },
            set: |c, v| {
                let items = match v {
                    Value::Array(a) => a,
                    _ => bail!("train.decay_epochs must be an array"),
                };
                c.lr.decay_epochs = items
                    .iter()
                    .map(|v| {
                        v.as_usize()
                            .ok_or_else(|| anyhow::anyhow!("decay_epochs entries must be integers"))
                    })
                    .collect::<anyhow::Result<_>>()?;
                Ok(())
            },
        },
        Knob {
            id: "/train/decay_factor",
            toml_key: "train.decay_factor",
            cli: None,
            ty: Ty::F64,
            bounds: None,
            default: "0.1",
            help: "lr multiplier at each decay epoch",
            ctx: "",
            get: |c| Some(Value::Float(c.lr.decay_factor)),
            set: |c, v| {
                c.lr.decay_factor = want_f64("train.decay_factor", v)?;
                Ok(())
            },
        },
        Knob {
            id: "/train/lambda0",
            toml_key: "train.lambda0",
            cli: Some("lambda0"),
            ty: Ty::F64,
            bounds: bounds(0.0, false, UNBOUNDED, false, "lambda0 must be >= 0"),
            default: "0.04",
            help: "delay-compensation strength lambda_0",
            ctx: "",
            get: |c| Some(Value::Float(c.lambda0)),
            set: |c, v| {
                c.lambda0 = want_f64("train.lambda0", v)?;
                Ok(())
            },
        },
        Knob {
            id: "/train/ms_momentum",
            toml_key: "train.ms_momentum",
            cli: Some("ms-momentum"),
            ty: Ty::F64,
            bounds: bounds(0.0, false, 1.0, true, "ms_momentum must be in [0, 1)"),
            default: "0.95",
            help: "MeanSquare moving-average constant m (DC-ASGD-a)",
            ctx: "",
            get: |c| Some(Value::Float(c.ms_momentum)),
            set: |c, v| {
                c.ms_momentum = want_f64("train.ms_momentum", v)?;
                Ok(())
            },
        },
        Knob {
            id: "/train/momentum",
            toml_key: "train.momentum",
            cli: Some("momentum"),
            ty: Ty::F64,
            bounds: bounds(0.0, false, 1.0, true, "momentum must be in [0, 1)"),
            default: "0",
            help: "classical momentum mu (0 = plain SGD)",
            ctx: "",
            get: |c| Some(Value::Float(c.momentum)),
            set: |c, v| {
                c.momentum = want_f64("train.momentum", v)?;
                Ok(())
            },
        },
        Knob {
            id: "/staleness_bound",
            toml_key: "staleness_bound",
            cli: Some("staleness-bound"),
            ty: Ty::USize,
            bounds: None,
            default: "4",
            help: "SSP staleness bound s (SSP / DC-S3GD)",
            ctx: "",
            get: |c| Some(Value::Int(c.staleness_bound as i64)),
            set: |c, v| {
                c.staleness_bound = want_usize("staleness_bound", v)?;
                Ok(())
            },
        },
        Knob {
            id: "/seed",
            toml_key: "seed",
            cli: Some("seed"),
            ty: Ty::U64,
            bounds: None,
            default: "17",
            help: "experiment seed (data, init, schedules)",
            ctx: "",
            get: |c| Some(Value::Int(c.seed as i64)),
            set: |c, v| {
                c.seed = v.as_i64().ok_or_else(|| anyhow::anyhow!("seed must be an integer"))? as u64;
                Ok(())
            },
        },
        Knob {
            id: "/exec_mode",
            toml_key: "exec_mode",
            cli: Some("mode"),
            ty: Ty::Enum(&["sim", "threads"]),
            bounds: None,
            default: "sim",
            help: "event-driven simulator vs real OS threads",
            ctx: "",
            get: |c| {
                Some(Value::Str(
                    match c.exec_mode {
                        ExecMode::SimulatedTime => "sim",
                        ExecMode::Threads => "threads",
                    }
                    .to_string(),
                ))
            },
            set: |c, v| {
                c.exec_mode = match want_str("exec_mode", v)? {
                    "threads" => ExecMode::Threads,
                    "sim" | "simulated" => ExecMode::SimulatedTime,
                    other => bail!("unknown exec_mode {other:?}"),
                };
                Ok(())
            },
        },
        Knob {
            id: "/update_backend",
            toml_key: "update_backend",
            cli: Some("backend"),
            ty: Ty::Enum(&["native", "xla"]),
            bounds: None,
            default: "native",
            help: "update kernels: native rust loops or AOT XLA artifact",
            ctx: "",
            get: |c| {
                Some(Value::Str(
                    match c.update_backend {
                        UpdateBackend::Native => "native",
                        UpdateBackend::Xla => "xla",
                    }
                    .to_string(),
                ))
            },
            set: |c, v| {
                c.update_backend = match want_str("update_backend", v)? {
                    "native" => UpdateBackend::Native,
                    "xla" => UpdateBackend::Xla,
                    other => bail!("unknown update_backend {other:?}"),
                };
                Ok(())
            },
        },
        Knob {
            id: "/shards",
            toml_key: "shards",
            cli: Some("shards"),
            ty: Ty::USize,
            bounds: bounds(1.0, false, UNBOUNDED, false, "shards must be >= 1"),
            default: "1",
            help: "parameter-store lock shards",
            ctx: "",
            get: |c| Some(Value::Int(c.shards as i64)),
            set: |c, v| {
                c.shards = want_usize("shards", v)?;
                Ok(())
            },
        },
        Knob {
            id: "/runtime/threads",
            toml_key: "runtime.threads",
            cli: Some("threads"),
            ty: Ty::USize,
            bounds: bounds(0.0, false, 1024.0, false, "runtime.threads must be <= 1024 (0 = auto)"),
            default: "0",
            help: "compute-pool lanes (0 = auto, 1 = serial)",
            ctx: "",
            get: |c| Some(Value::Int(c.runtime.threads as i64)),
            set: |c, v| {
                c.runtime.threads = want_usize("runtime.threads", v)?;
                Ok(())
            },
        },
        Knob {
            id: "/runtime/simd",
            toml_key: "runtime.simd",
            cli: Some("simd"),
            ty: Ty::Bool,
            bounds: None,
            default: "true",
            help: "chunked-SIMD kernels (false = scalar reference)",
            ctx: "",
            get: |c| Some(Value::Bool(c.runtime.simd)),
            set: |c, v| {
                c.runtime.simd = want_bool("runtime.simd", v)?;
                Ok(())
            },
        },
        // delay model before its parameters: the model switch keeps the
        // current mean/jitter, then explicit parameter knobs refine it
        Knob {
            id: "/sim/delay/model",
            toml_key: "sim.delay.model",
            cli: Some("delay-model"),
            ty: Ty::Enum(&["constant", "uniform", "exponential", "pareto", "heterogeneous"]),
            bounds: None,
            default: "uniform",
            help: "worker compute-time distribution",
            ctx: "",
            get: |c| Some(Value::Str(c.delay.name().to_string())),
            set: |c, v| {
                let mean = match &c.delay {
                    DelayModel::Pareto { scale, .. } => *scale,
                    m => m.mean(),
                };
                let jitter = match &c.delay {
                    DelayModel::Uniform { jitter, .. }
                    | DelayModel::Heterogeneous { jitter, .. } => *jitter,
                    _ => 0.3,
                };
                c.delay = match want_str("sim.delay.model", v)? {
                    "constant" => DelayModel::Constant { mean },
                    "uniform" => DelayModel::Uniform { mean, jitter },
                    "exponential" => DelayModel::Exponential { mean },
                    "pareto" => DelayModel::Pareto { scale: mean, alpha: 2.5 },
                    "heterogeneous" => {
                        let speeds = match &c.delay {
                            DelayModel::Heterogeneous { speeds, .. } => speeds.clone(),
                            _ => vec![1.0],
                        };
                        DelayModel::Heterogeneous { mean, speeds, jitter }
                    }
                    other => bail!("unknown delay model {other:?}"),
                };
                Ok(())
            },
        },
        Knob {
            id: "/sim/delay/mean",
            toml_key: "sim.delay.mean",
            cli: Some("delay-mean"),
            ty: Ty::F64,
            bounds: bounds(0.0, true, UNBOUNDED, false, "delay mean must be positive"),
            default: "1.0",
            help: "mean compute time (pareto: sets the scale)",
            ctx: "",
            get: |c| match &c.delay {
                DelayModel::Constant { mean }
                | DelayModel::Uniform { mean, .. }
                | DelayModel::Exponential { mean }
                | DelayModel::Heterogeneous { mean, .. } => Some(Value::Float(*mean)),
                DelayModel::Pareto { .. } => None,
            },
            set: |c, v| {
                let x = want_f64("sim.delay.mean", v)?;
                match &mut c.delay {
                    DelayModel::Constant { mean }
                    | DelayModel::Uniform { mean, .. }
                    | DelayModel::Exponential { mean }
                    | DelayModel::Heterogeneous { mean, .. } => *mean = x,
                    DelayModel::Pareto { scale, .. } => *scale = x,
                }
                Ok(())
            },
        },
        Knob {
            id: "/sim/delay/jitter",
            toml_key: "sim.delay.jitter",
            cli: Some("delay-jitter"),
            ty: Ty::F64,
            bounds: bounds(0.0, false, 1.0, true, "jitter must be in [0, 1)"),
            default: "0.3",
            help: "uniform/heterogeneous spread around the mean",
            ctx: "",
            get: |c| match &c.delay {
                DelayModel::Uniform { jitter, .. } | DelayModel::Heterogeneous { jitter, .. } => {
                    Some(Value::Float(*jitter))
                }
                _ => None,
            },
            set: |c, v| {
                let x = want_f64("sim.delay.jitter", v)?;
                match &mut c.delay {
                    DelayModel::Uniform { jitter, .. }
                    | DelayModel::Heterogeneous { jitter, .. } => *jitter = x,
                    _ => bail!(
                        "sim.delay.jitter applies to the uniform/heterogeneous delay models, \
                         not {}",
                        c.delay.name()
                    ),
                }
                Ok(())
            },
        },
        Knob {
            id: "/sim/delay/scale",
            toml_key: "sim.delay.scale",
            cli: Some("delay-scale"),
            ty: Ty::F64,
            bounds: bounds(0.0, true, UNBOUNDED, false, "pareto scale/alpha must be positive"),
            default: "1.0",
            help: "pareto scale (typical compute time)",
            ctx: "sim.delay.model = \"pareto\"\n",
            get: |c| match &c.delay {
                DelayModel::Pareto { scale, .. } => Some(Value::Float(*scale)),
                _ => None,
            },
            set: |c, v| {
                let x = want_f64("sim.delay.scale", v)?;
                match &mut c.delay {
                    DelayModel::Pareto { scale, .. } => *scale = x,
                    _ => bail!(
                        "sim.delay.scale applies to the pareto delay model, not {}",
                        c.delay.name()
                    ),
                }
                Ok(())
            },
        },
        Knob {
            id: "/sim/delay/alpha",
            toml_key: "sim.delay.alpha",
            cli: Some("delay-alpha"),
            ty: Ty::F64,
            bounds: bounds(0.0, true, UNBOUNDED, false, "pareto scale/alpha must be positive"),
            default: "2.5",
            help: "pareto tail index (lower = heavier stragglers)",
            ctx: "sim.delay.model = \"pareto\"\n",
            get: |c| match &c.delay {
                DelayModel::Pareto { alpha, .. } => Some(Value::Float(*alpha)),
                _ => None,
            },
            set: |c, v| {
                let x = want_f64("sim.delay.alpha", v)?;
                match &mut c.delay {
                    DelayModel::Pareto { alpha, .. } => *alpha = x,
                    _ => bail!(
                        "sim.delay.alpha applies to the pareto delay model, not {}",
                        c.delay.name()
                    ),
                }
                Ok(())
            },
        },
        Knob {
            id: "/sim/delay/speeds",
            toml_key: "sim.delay.speeds",
            cli: None,
            ty: Ty::F64List,
            bounds: None,
            default: "[1.0]",
            help: "heterogeneous per-worker speed multipliers",
            ctx: "",
            get: |c| match &c.delay {
                DelayModel::Heterogeneous { speeds, .. } => {
                    Some(Value::Array(speeds.iter().map(|&s| Value::Float(s)).collect()))
                }
                _ => None,
            },
            set: |c, v| {
                let items = match v {
                    Value::Array(a) => a,
                    _ => bail!("sim.delay.speeds must be an array"),
                };
                let parsed = items
                    .iter()
                    .map(|v| v.as_f64().ok_or_else(|| anyhow::anyhow!("speeds must be numbers")))
                    .collect::<anyhow::Result<Vec<_>>>()?;
                match &mut c.delay {
                    DelayModel::Heterogeneous { speeds, .. } => *speeds = parsed,
                    _ => bail!(
                        "sim.delay.speeds applies to the heterogeneous delay model, not {}",
                        c.delay.name()
                    ),
                }
                Ok(())
            },
        },
        // [comm]: presets and cost parameters auto-enable; explicit
        // `enabled` is declared after them so it always has the last word
        Knob {
            id: "/comm/model",
            toml_key: "comm.model",
            cli: None,
            ty: Ty::Enum(&["off", "infiniband", "ethernet"]),
            bounds: None,
            default: "off",
            help: "communication-cost preset (selects + enables)",
            ctx: "",
            get: |c| {
                Some(Value::Str(
                    if !c.comm.enabled { "off" } else { "custom" }.to_string(),
                ))
            },
            set: |c, v| {
                c.comm = match want_str("comm.model", v)? {
                    "off" | "none" => CommConfig::default(),
                    "infiniband" => {
                        CommConfig::from_model(crate::sim::CommModel::infiniband_like(), true)
                    }
                    "ethernet" => {
                        CommConfig::from_model(crate::sim::CommModel::ethernet_like(), true)
                    }
                    other => bail!("unknown comm model {other:?} (off|infiniband|ethernet)"),
                };
                Ok(())
            },
        },
        Knob {
            id: "/comm/per_push",
            toml_key: "comm.per_push",
            cli: Some("comm-per-push"),
            ty: Ty::F64,
            bounds: bounds(0.0, false, UNBOUNDED, false, "comm per_push/per_mb must be finite and >= 0"),
            default: "per sim::CommModel::infiniband_like",
            help: "seconds charged per push/pull (enables [comm])",
            ctx: "",
            get: |c| Some(Value::Float(c.comm.model.per_push)),
            set: |c, v| {
                c.comm.model.per_push = want_f64("comm.per_push", v)?;
                c.comm.enabled = true;
                Ok(())
            },
        },
        Knob {
            id: "/comm/per_mb",
            toml_key: "comm.per_mb",
            cli: Some("comm-per-mb"),
            ty: Ty::F64,
            bounds: bounds(0.0, false, UNBOUNDED, false, "comm per_push/per_mb must be finite and >= 0"),
            default: "per sim::CommModel::infiniband_like",
            help: "seconds charged per MB on the wire (enables [comm])",
            ctx: "",
            get: |c| Some(Value::Float(c.comm.model.per_mb)),
            set: |c, v| {
                c.comm.model.per_mb = want_f64("comm.per_mb", v)?;
                c.comm.enabled = true;
                Ok(())
            },
        },
        Knob {
            id: "/comm/enabled",
            toml_key: "comm.enabled",
            cli: None,
            ty: Ty::Bool,
            bounds: None,
            default: "false",
            help: "charge transfer time in the DES (explicit key wins)",
            ctx: "",
            get: |c| Some(Value::Bool(c.comm.enabled)),
            set: |c, v| {
                c.comm.enabled = want_bool("comm.enabled", v)?;
                Ok(())
            },
        },
        // [topology]: racks + multi-PS placement; same auto-enable
        // convention as [comm], explicit `enabled` declared last
        Knob {
            id: "/topology/ps_nodes",
            toml_key: "topology.ps_nodes",
            cli: Some("ps-nodes"),
            ty: Ty::USize,
            bounds: bounds(1.0, false, 1024.0, false, "topology.ps_nodes must be in [1, 1024]"),
            default: "1",
            help: "logical PS nodes shards are placed across (enables [topology])",
            ctx: "",
            get: |c| Some(Value::Int(c.topology.ps_nodes as i64)),
            set: |c, v| {
                c.topology.ps_nodes = want_usize("topology.ps_nodes", v)?;
                c.topology.enabled = true;
                Ok(())
            },
        },
        Knob {
            id: "/topology/racks",
            toml_key: "topology.racks",
            cli: Some("racks"),
            ty: Ty::USize,
            bounds: bounds(1.0, false, 256.0, false, "topology.racks must be in [1, 256]"),
            default: "1",
            help: "racks workers/PS nodes stripe over (enables [topology])",
            ctx: "",
            get: |c| Some(Value::Int(c.topology.racks as i64)),
            set: |c, v| {
                c.topology.racks = want_usize("topology.racks", v)?;
                c.topology.enabled = true;
                Ok(())
            },
        },
        Knob {
            id: "/topology/rack_per_push",
            toml_key: "topology.rack_per_push",
            cli: Some("rack-per-push"),
            ty: Ty::F64,
            bounds: bounds(0.0, false, UNBOUNDED, false, "topology link costs must be finite and >= 0"),
            default: "per sim::CommModel::infiniband_like",
            help: "rack-local link: seconds per transfer (enables [topology])",
            ctx: "",
            get: |c| Some(Value::Float(c.topology.rack_model.per_push)),
            set: |c, v| {
                c.topology.rack_model.per_push = want_f64("topology.rack_per_push", v)?;
                c.topology.enabled = true;
                Ok(())
            },
        },
        Knob {
            id: "/topology/rack_per_mb",
            toml_key: "topology.rack_per_mb",
            cli: Some("rack-per-mb"),
            ty: Ty::F64,
            bounds: bounds(0.0, false, UNBOUNDED, false, "topology link costs must be finite and >= 0"),
            default: "per sim::CommModel::infiniband_like",
            help: "rack-local link: seconds per MB (enables [topology])",
            ctx: "",
            get: |c| Some(Value::Float(c.topology.rack_model.per_mb)),
            set: |c, v| {
                c.topology.rack_model.per_mb = want_f64("topology.rack_per_mb", v)?;
                c.topology.enabled = true;
                Ok(())
            },
        },
        Knob {
            id: "/topology/cross_per_push",
            toml_key: "topology.cross_per_push",
            cli: Some("cross-per-push"),
            ty: Ty::F64,
            bounds: bounds(0.0, false, UNBOUNDED, false, "topology link costs must be finite and >= 0"),
            default: "per sim::CommModel::ethernet_like",
            help: "cross-rack uplink: seconds per transfer (enables [topology])",
            ctx: "",
            get: |c| Some(Value::Float(c.topology.cross_model.per_push)),
            set: |c, v| {
                c.topology.cross_model.per_push = want_f64("topology.cross_per_push", v)?;
                c.topology.enabled = true;
                Ok(())
            },
        },
        Knob {
            id: "/topology/cross_per_mb",
            toml_key: "topology.cross_per_mb",
            cli: Some("cross-per-mb"),
            ty: Ty::F64,
            bounds: bounds(0.0, false, UNBOUNDED, false, "topology link costs must be finite and >= 0"),
            default: "per sim::CommModel::ethernet_like",
            help: "cross-rack uplink: seconds per MB (enables [topology])",
            ctx: "",
            get: |c| Some(Value::Float(c.topology.cross_model.per_mb)),
            set: |c, v| {
                c.topology.cross_model.per_mb = want_f64("topology.cross_per_mb", v)?;
                c.topology.enabled = true;
                Ok(())
            },
        },
        Knob {
            id: "/topology/hierarchical",
            toml_key: "topology.hierarchical",
            cli: Some("hierarchical"),
            ty: Ty::Bool,
            bounds: None,
            default: "false",
            help: "two-level rack-reducer aggregation (enables [topology])",
            ctx: "",
            get: |c| Some(Value::Bool(c.topology.hierarchical)),
            set: |c, v| {
                c.topology.hierarchical = want_bool("topology.hierarchical", v)?;
                c.topology.enabled = true;
                Ok(())
            },
        },
        Knob {
            id: "/topology/enabled",
            toml_key: "topology.enabled",
            cli: None,
            ty: Ty::Bool,
            bounds: None,
            default: "false",
            help: "topology-aware comm + PS placement (explicit key wins)",
            ctx: "",
            get: |c| Some(Value::Bool(c.topology.enabled)),
            set: |c, v| {
                c.topology.enabled = want_bool("topology.enabled", v)?;
                Ok(())
            },
        },
        // [faults]: same auto-enable convention as [comm]
        Knob {
            id: "/faults/crash_rate",
            toml_key: "faults.crash_rate",
            cli: Some("fault-crash-rate"),
            ty: Ty::F64,
            bounds: None,
            default: "0.02",
            help: "Poisson crashes per worker per sim second (enables [faults])",
            ctx: "",
            get: |c| Some(Value::Float(c.faults.crash_rate)),
            set: |c, v| {
                c.faults.crash_rate = want_f64("faults.crash_rate", v)?;
                c.faults.enabled = true;
                Ok(())
            },
        },
        Knob {
            id: "/faults/restart_mean",
            toml_key: "faults.restart_mean",
            cli: Some("fault-restart-mean"),
            ty: Ty::F64,
            bounds: None,
            default: "5.0",
            help: "mean restart delay in sim seconds (enables [faults])",
            ctx: "",
            get: |c| Some(Value::Float(c.faults.restart_mean)),
            set: |c, v| {
                c.faults.restart_mean = want_f64("faults.restart_mean", v)?;
                c.faults.enabled = true;
                Ok(())
            },
        },
        Knob {
            id: "/faults/departure_prob",
            toml_key: "faults.departure_prob",
            cli: Some("fault-departure-prob"),
            ty: Ty::F64,
            bounds: None,
            default: "0.1",
            help: "P(crash is a permanent departure) (enables [faults])",
            ctx: "",
            get: |c| Some(Value::Float(c.faults.departure_prob)),
            set: |c, v| {
                c.faults.departure_prob = want_f64("faults.departure_prob", v)?;
                c.faults.enabled = true;
                Ok(())
            },
        },
        Knob {
            id: "/faults/straggler_rate",
            toml_key: "faults.straggler_rate",
            cli: Some("fault-straggler-rate"),
            ty: Ty::F64,
            bounds: None,
            default: "0",
            help: "straggle windows per worker per sim second (enables [faults])",
            ctx: "",
            get: |c| Some(Value::Float(c.faults.straggler_rate)),
            set: |c, v| {
                c.faults.straggler_rate = want_f64("faults.straggler_rate", v)?;
                c.faults.enabled = true;
                Ok(())
            },
        },
        Knob {
            id: "/faults/straggler_factor",
            toml_key: "faults.straggler_factor",
            cli: Some("fault-straggler-factor"),
            ty: Ty::F64,
            bounds: None,
            default: "4.0",
            help: "compute-time multiplier inside a straggle window",
            ctx: "",
            get: |c| Some(Value::Float(c.faults.straggler_factor)),
            set: |c, v| {
                c.faults.straggler_factor = want_f64("faults.straggler_factor", v)?;
                c.faults.enabled = true;
                Ok(())
            },
        },
        Knob {
            id: "/faults/straggler_duration",
            toml_key: "faults.straggler_duration",
            cli: Some("fault-straggler-duration"),
            ty: Ty::F64,
            bounds: None,
            default: "5.0",
            help: "mean straggle-window length in sim seconds",
            ctx: "",
            get: |c| Some(Value::Float(c.faults.straggler_duration)),
            set: |c, v| {
                c.faults.straggler_duration = want_f64("faults.straggler_duration", v)?;
                c.faults.enabled = true;
                Ok(())
            },
        },
        Knob {
            id: "/faults/late_join",
            toml_key: "faults.late_join",
            cli: Some("fault-late-join"),
            ty: Ty::USize,
            bounds: None,
            default: "0",
            help: "workers absent at t = 0 that join later (enables [faults])",
            ctx: "",
            get: |c| Some(Value::Int(c.faults.late_join as i64)),
            set: |c, v| {
                c.faults.late_join = want_usize("faults.late_join", v)?;
                c.faults.enabled = true;
                Ok(())
            },
        },
        Knob {
            id: "/faults/late_join_by",
            toml_key: "faults.late_join_by",
            cli: Some("fault-late-join-by"),
            ty: Ty::F64,
            bounds: None,
            default: "10.0",
            help: "late joiners arrive uniformly within (0, late_join_by]",
            ctx: "",
            get: |c| Some(Value::Float(c.faults.late_join_by)),
            set: |c, v| {
                c.faults.late_join_by = want_f64("faults.late_join_by", v)?;
                c.faults.enabled = true;
                Ok(())
            },
        },
        Knob {
            id: "/faults/policy",
            toml_key: "faults.policy",
            cli: Some("fault-policy"),
            ty: Ty::Enum(&["drop", "salvage"]),
            bounds: None,
            default: "drop",
            help: "in-flight gradient on crash (enables [faults])",
            ctx: "",
            get: |c| Some(Value::Str(c.faults.policy.name().to_string())),
            set: |c, v| {
                c.faults.policy = crate::sim::CrashPolicy::parse(want_str("faults.policy", v)?)?;
                c.faults.enabled = true;
                Ok(())
            },
        },
        Knob {
            id: "/faults/seed",
            toml_key: "faults.seed",
            cli: Some("fault-seed"),
            ty: Ty::U64,
            bounds: None,
            default: "0",
            help: "fault-stream seed (0 = derive from /seed)",
            ctx: "",
            get: |c| Some(Value::Int(c.faults.seed as i64)),
            set: |c, v| {
                c.faults.seed = v
                    .as_i64()
                    .ok_or_else(|| anyhow::anyhow!("faults.seed must be an integer"))?
                    as u64;
                c.faults.enabled = true;
                Ok(())
            },
        },
        Knob {
            id: "/faults/enabled",
            toml_key: "faults.enabled",
            cli: None,
            ty: Ty::Bool,
            bounds: None,
            default: "false",
            help: "inject faults into the DES (explicit key wins)",
            ctx: "",
            get: |c| Some(Value::Bool(c.faults.enabled)),
            set: |c, v| {
                c.faults.enabled = want_bool("faults.enabled", v)?;
                Ok(())
            },
        },
        // [compress]: codec before its parameter knobs; a codec switch
        // keeps a tuned ratio/bits (matching the old --compress semantics),
        // and "topk@0.25"-style compound specs serve single-axis sweeps
        Knob {
            id: "/compress/codec",
            toml_key: "compress.codec",
            cli: Some("compress"),
            ty: Ty::Enum(&["none", "topk", "randk", "qsgd"]),
            bounds: None,
            default: "none",
            help: "gradient codec (accepts name@param, e.g. topk@0.25, qsgd@4)",
            ctx: "",
            get: |c| Some(Value::Str(c.compress.name().to_string())),
            set: |c, v| {
                let spec = want_str("compress.codec", v)?;
                let (name, param) = match spec.split_once('@') {
                    Some((n, p)) => {
                        let x: f64 = p.parse().map_err(|_| {
                            anyhow::anyhow!("bad codec parameter in {spec:?} (name@param)")
                        })?;
                        (n, Some(x))
                    }
                    None => (spec, None),
                };
                let cur_ratio = match c.compress {
                    CodecConfig::TopK { ratio } | CodecConfig::RandK { ratio } => ratio,
                    _ => 0.1,
                };
                let cur_bits = match c.compress {
                    CodecConfig::Qsgd { bits } => bits,
                    _ => 8,
                };
                let (ratio, bits) = match (name, param) {
                    (_, None) => (cur_ratio, cur_bits),
                    ("topk" | "top-k" | "randk" | "rand-k", Some(x)) => (x, cur_bits),
                    ("qsgd", Some(x)) => {
                        let b = (x as i64).try_into().map_err(|_| {
                            anyhow::anyhow!("bad qsgd bit width in {spec:?}")
                        })?;
                        (cur_ratio, b)
                    }
                    (other, Some(_)) => bail!("codec {other:?} takes no @param"),
                };
                c.compress = CodecConfig::parse(name, ratio, bits)?;
                Ok(())
            },
        },
        Knob {
            id: "/compress/ratio",
            toml_key: "compress.ratio",
            cli: Some("topk-ratio"),
            ty: Ty::F64,
            bounds: None,
            default: "0.1",
            help: "topk/randk kept fraction",
            ctx: "",
            get: |c| match c.compress {
                CodecConfig::TopK { ratio } | CodecConfig::RandK { ratio } => {
                    Some(Value::Float(ratio))
                }
                _ => None,
            },
            set: |c, v| {
                let x = want_f64("compress.ratio", v)?;
                match &mut c.compress {
                    CodecConfig::TopK { ratio } | CodecConfig::RandK { ratio } => *ratio = x,
                    _ => bail!("compress.ratio requires a topk/randk codec"),
                }
                Ok(())
            },
        },
        Knob {
            id: "/compress/bits",
            toml_key: "compress.bits",
            cli: Some("quant-bits"),
            ty: Ty::USize,
            bounds: None,
            default: "8",
            help: "qsgd bits per element (32 = exact)",
            ctx: "",
            get: |c| match c.compress {
                CodecConfig::Qsgd { bits } => Some(Value::Int(bits as i64)),
                _ => None,
            },
            set: |c, v| {
                let b = want_usize("compress.bits", v)?;
                let b = u32::try_from(b)
                    .map_err(|_| anyhow::anyhow!("compress.bits {b} out of range"))?;
                match &mut c.compress {
                    CodecConfig::Qsgd { bits } => *bits = b,
                    _ => bail!("compress.bits requires the qsgd codec"),
                }
                Ok(())
            },
        },
        // [trace]: parameter knobs auto-enable the section; the explicit
        // `enabled` knob is declared last so it always has the final word
        Knob {
            id: "/trace/sample_every",
            toml_key: "trace.sample_every",
            cli: Some("trace-sample-every"),
            ty: Ty::USize,
            bounds: bounds(1.0, false, UNBOUNDED, false, "trace.sample_every must be >= 1"),
            default: "10",
            help: "time-series sampling cadence in steps (enables [trace])",
            ctx: "",
            get: |c| Some(Value::Int(c.trace.sample_every as i64)),
            set: |c, v| {
                c.trace.sample_every = want_usize("trace.sample_every", v)?;
                c.trace.enabled = true;
                Ok(())
            },
        },
        Knob {
            id: "/trace/events",
            toml_key: "trace.events",
            cli: Some("trace-events"),
            ty: Ty::Bool,
            bounds: None,
            default: "true",
            help: "emit structured event JSONL (enables [trace])",
            ctx: "",
            get: |c| Some(Value::Bool(c.trace.events)),
            set: |c, v| {
                c.trace.events = want_bool("trace.events", v)?;
                c.trace.enabled = true;
                Ok(())
            },
        },
        Knob {
            id: "/trace/profile",
            toml_key: "trace.profile",
            cli: Some("trace-profile"),
            ty: Ty::Bool,
            bounds: None,
            default: "true",
            help: "collect subsystem span histograms (enables [trace])",
            ctx: "",
            get: |c| Some(Value::Bool(c.trace.profile)),
            set: |c, v| {
                c.trace.profile = want_bool("trace.profile", v)?;
                c.trace.enabled = true;
                Ok(())
            },
        },
        Knob {
            id: "/trace/chrome_trace",
            toml_key: "trace.chrome_trace",
            cli: Some("trace-chrome"),
            ty: Ty::Bool,
            bounds: None,
            default: "true",
            help: "also write Chrome trace-event JSON (enables [trace])",
            ctx: "",
            get: |c| Some(Value::Bool(c.trace.chrome_trace)),
            set: |c, v| {
                c.trace.chrome_trace = want_bool("trace.chrome_trace", v)?;
                c.trace.enabled = true;
                Ok(())
            },
        },
        Knob {
            id: "/trace/enabled",
            toml_key: "trace.enabled",
            cli: None,
            ty: Ty::Bool,
            bounds: None,
            default: "false",
            help: "run-trace observability layer (explicit key wins)",
            ctx: "",
            get: |c| Some(Value::Bool(c.trace.enabled)),
            set: |c, v| {
                c.trace.enabled = want_bool("trace.enabled", v)?;
                Ok(())
            },
        },
        // [serving]: parameter knobs auto-enable the section; the explicit
        // `enabled` knob is declared last so it always has the final word
        Knob {
            id: "/serving/publish_every",
            toml_key: "serving.publish_every",
            cli: Some("serving-publish-every"),
            ty: Ty::USize,
            bounds: bounds(1.0, false, UNBOUNDED, false, "serving.publish_every must be >= 1"),
            default: "4",
            help: "snapshot publication cadence in global steps (enables [serving])",
            ctx: "",
            get: |c| Some(Value::Int(c.serving.publish_every as i64)),
            set: |c, v| {
                c.serving.publish_every = want_usize("serving.publish_every", v)?;
                c.serving.enabled = true;
                Ok(())
            },
        },
        Knob {
            id: "/serving/rate",
            toml_key: "serving.rate",
            cli: Some("serving-rate"),
            ty: Ty::F64,
            bounds: bounds(0.0, true, 1e9, false, "serving.rate must be finite and > 0"),
            default: "2.0",
            help: "base arrival rate, pulls per virtual second (enables [serving])",
            ctx: "",
            get: |c| Some(Value::Float(c.serving.rate)),
            set: |c, v| {
                c.serving.rate = want_f64("serving.rate", v)?;
                c.serving.enabled = true;
                Ok(())
            },
        },
        Knob {
            id: "/serving/arrival",
            toml_key: "serving.arrival",
            cli: Some("serving-arrival"),
            ty: Ty::Enum(&["poisson", "bursty", "diurnal"]),
            bounds: None,
            default: "poisson",
            help: "arrival process shape (enables [serving])",
            ctx: "",
            get: |c| Some(Value::Str(c.serving.arrival.name().to_string())),
            set: |c, v| {
                c.serving.arrival =
                    crate::sim::ArrivalKind::parse(want_str("serving.arrival", v)?)?;
                c.serving.enabled = true;
                Ok(())
            },
        },
        Knob {
            id: "/serving/burst",
            toml_key: "serving.burst",
            cli: Some("serving-burst"),
            ty: Ty::F64,
            bounds: bounds(1.0, false, 1e6, false, "serving.burst must be in [1, 1e6]"),
            default: "4.0",
            help: "peak rate multiplier for bursty/diurnal shapes (enables [serving])",
            ctx: "",
            get: |c| Some(Value::Float(c.serving.burst)),
            set: |c, v| {
                c.serving.burst = want_f64("serving.burst", v)?;
                c.serving.enabled = true;
                Ok(())
            },
        },
        Knob {
            id: "/serving/period",
            toml_key: "serving.period",
            cli: Some("serving-period"),
            ty: Ty::F64,
            bounds: bounds(0.0, true, UNBOUNDED, false, "serving.period must be finite and > 0"),
            default: "8.0",
            help: "bursty/diurnal cycle length, virtual seconds (enables [serving])",
            ctx: "",
            get: |c| Some(Value::Float(c.serving.period)),
            set: |c, v| {
                c.serving.period = want_f64("serving.period", v)?;
                c.serving.enabled = true;
                Ok(())
            },
        },
        Knob {
            id: "/serving/batch",
            toml_key: "serving.batch",
            cli: Some("serving-batch"),
            ty: Ty::USize,
            bounds: bounds(1.0, false, 4096.0, false, "serving.batch must be in [1, 4096]"),
            default: "8",
            help: "queries per batched pull (enables [serving])",
            ctx: "",
            get: |c| Some(Value::Int(c.serving.batch as i64)),
            set: |c, v| {
                c.serving.batch = want_usize("serving.batch", v)?;
                c.serving.enabled = true;
                Ok(())
            },
        },
        Knob {
            id: "/serving/read_mode",
            toml_key: "serving.read_mode",
            cli: Some("serving-read-mode"),
            ty: Ty::Enum(&["snapshot", "locked"]),
            bounds: None,
            default: "snapshot",
            help: "epoch-snapshot reads vs locked-read baseline (enables [serving])",
            ctx: "",
            get: |c| Some(Value::Str(c.serving.read_mode.name().to_string())),
            set: |c, v| {
                c.serving.read_mode =
                    crate::sim::ReadMode::parse(want_str("serving.read_mode", v)?)?;
                c.serving.enabled = true;
                Ok(())
            },
        },
        Knob {
            id: "/serving/seed",
            toml_key: "serving.seed",
            cli: Some("serving-seed"),
            ty: Ty::U64,
            bounds: None,
            default: "77",
            help: "arrival/query stream seed, independent of /seed (enables [serving])",
            ctx: "",
            get: |c| Some(Value::Int(c.serving.seed as i64)),
            set: |c, v| {
                c.serving.seed = v
                    .as_i64()
                    .ok_or_else(|| anyhow::anyhow!("serving.seed must be an integer"))?
                    as u64;
                c.serving.enabled = true;
                Ok(())
            },
        },
        Knob {
            id: "/serving/enabled",
            toml_key: "serving.enabled",
            cli: None,
            ty: Ty::Bool,
            bounds: None,
            default: "false",
            help: "serving workload against the live PS (explicit key wins)",
            ctx: "",
            get: |c| Some(Value::Bool(c.serving.enabled)),
            set: |c, v| {
                c.serving.enabled = want_bool("serving.enabled", v)?;
                Ok(())
            },
        },
        Knob {
            id: "/eval/every",
            toml_key: "eval.every",
            cli: None,
            ty: Ty::USize,
            bounds: None,
            default: "1",
            help: "evaluate every N effective epochs",
            ctx: "",
            get: |c| Some(Value::Int(c.eval_every as i64)),
            set: |c, v| {
                c.eval_every = want_usize("eval.every", v)?;
                Ok(())
            },
        },
        Knob {
            id: "/eval/every_steps",
            toml_key: "eval.every_steps",
            cli: None,
            ty: Ty::USize,
            bounds: None,
            default: "0",
            help: "also evaluate every N global steps (0 = off)",
            ctx: "",
            get: |c| Some(Value::Int(c.eval_every_steps as i64)),
            set: |c, v| {
                c.eval_every_steps = want_usize("eval.every_steps", v)?;
                Ok(())
            },
        },
        Knob {
            id: "/eval/batches",
            toml_key: "eval.batches",
            cli: None,
            ty: Ty::USize,
            bounds: None,
            default: "0",
            help: "cap on evaluation batches (0 = full test set)",
            ctx: "",
            get: |c| Some(Value::Int(c.eval_batches as i64)),
            set: |c, v| {
                c.eval_batches = want_usize("eval.batches", v)?;
                Ok(())
            },
        },
        Knob {
            id: "/out_dir",
            toml_key: "out_dir",
            cli: Some("out"),
            ty: Ty::Str,
            bounds: None,
            default: "\"\"",
            help: "metrics output dir (empty = don't write)",
            ctx: "",
            get: |c| Some(Value::Str(c.out_dir.clone())),
            set: |c, v| {
                c.out_dir = want_str("out_dir", v)?.to_string();
                Ok(())
            },
        },
        Knob {
            id: "/checkpoint_out",
            toml_key: "checkpoint_out",
            cli: Some("save-checkpoint"),
            ty: Ty::Str,
            bounds: None,
            default: "\"\"",
            help: "save a final PS checkpoint here (empty = don't)",
            ctx: "",
            get: |c| Some(Value::Str(c.checkpoint_out.clone())),
            set: |c, v| {
                c.checkpoint_out = want_str("checkpoint_out", v)?.to_string();
                Ok(())
            },
        },
        Knob {
            id: "/resume_from",
            toml_key: "resume_from",
            cli: Some("resume"),
            ty: Ty::Str,
            bounds: None,
            default: "\"\"",
            help: "resume from a checkpoint file (empty = fresh init)",
            ctx: "",
            get: |c| Some(Value::Str(c.resume_from.clone())),
            set: |c, v| {
                c.resume_from = want_str("resume_from", v)?.to_string();
                Ok(())
            },
        },
        Knob {
            id: "/tag",
            toml_key: "tag",
            cli: Some("tag"),
            ty: Ty::Str,
            bounds: None,
            default: "\"\"",
            help: "extra label for metrics files",
            ctx: "",
            get: |c| Some(Value::Str(c.tag.clone())),
            set: |c, v| {
                c.tag = want_str("tag", v)?.to_string();
                Ok(())
            },
        },
        Knob {
            id: "/verbose",
            toml_key: "verbose",
            cli: Some("verbose"),
            ty: Ty::Bool,
            bounds: None,
            default: "false",
            help: "per-eval progress lines",
            ctx: "",
            get: |c| Some(Value::Bool(c.verbose)),
            set: |c, v| {
                c.verbose = want_bool("verbose", v)?;
                Ok(())
            },
        },
    ]
}

// --------------------------------------------------------------- the rules

/// Cross-knob rejection rules, each with its pinned message fragment and a
/// canonical TOML example. [`check`] runs them in order after the bounds.
pub fn rules() -> &'static [Rule] {
    static RULES: OnceLock<Vec<Rule>> = OnceLock::new();
    RULES.get_or_init(build_rules)
}

fn build_rules() -> Vec<Rule> {
    let faults_domain: fn(&ExperimentConfig) -> anyhow::Result<()> =
        |c| c.faults.validate(c.workers);
    let codec_domain: fn(&ExperimentConfig) -> anyhow::Result<()> = |c| c.compress.validate();
    let compress_barrier: fn(&ExperimentConfig) -> anyhow::Result<()> = |c| {
        if !c.compress.is_none()
            && matches!(
                c.algorithm,
                Algorithm::SyncSgd | Algorithm::DcSyncSgd | Algorithm::HierSsgd
            )
        {
            bail!(
                "{} folds dense gradients at the barrier: compression requires an \
                 immediate-commit protocol (asgd/dc-asgd-*/ssp/dc-s3gd/sgd)",
                c.algorithm.name()
            );
        }
        Ok(())
    };
    let ssp_threads: fn(&ExperimentConfig) -> anyhow::Result<()> = |c| {
        if c.algorithm.is_staleness_bounded() && c.exec_mode == ExecMode::Threads {
            bail!(
                "{} runs under the event-driven scheduler: set exec_mode = sim",
                c.algorithm.name()
            );
        }
        Ok(())
    };
    vec![
        Rule {
            id: "seq-workers",
            needle: "sequential SGD requires workers = 1",
            example: "algorithm = \"sgd\"\nworkers = 4",
            check: |c| {
                if c.algorithm == Algorithm::SequentialSgd && c.workers != 1 {
                    bail!("sequential SGD requires workers = 1 (got {})", c.workers);
                }
                Ok(())
            },
        },
        Rule {
            id: "step-budget",
            needle: "one of epochs / max_steps must be positive",
            example: "epochs = 0",
            check: |c| {
                if c.epochs == 0 && c.max_steps == 0 {
                    bail!("one of epochs / max_steps must be positive");
                }
                Ok(())
            },
        },
        Rule {
            id: "ssp-threads",
            needle: "event-driven scheduler",
            example: "algorithm = \"ssp\"\nexec_mode = \"threads\"",
            check: ssp_threads,
        },
        Rule {
            id: "dc-s3gd-threads",
            needle: "event-driven scheduler",
            example: "algorithm = \"dc-s3gd\"\nexec_mode = \"threads\"",
            check: ssp_threads,
        },
        Rule {
            id: "comm-threads",
            needle: "event-driven scheduler",
            example: "exec_mode = \"threads\"\n[comm]\nenabled = true",
            check: |c| {
                if c.comm.enabled && c.exec_mode == ExecMode::Threads {
                    bail!(
                        "comm cost model runs under the event-driven scheduler: \
                         set exec_mode = sim"
                    );
                }
                Ok(())
            },
        },
        Rule {
            id: "faults-threads",
            needle: "fault injection runs under the event-driven scheduler",
            example: "exec_mode = \"threads\"\n[faults]\nenabled = true",
            check: |c| {
                if c.faults.enabled && c.exec_mode == ExecMode::Threads {
                    bail!(
                        "fault injection runs under the event-driven scheduler: \
                         set exec_mode = sim"
                    );
                }
                Ok(())
            },
        },
        Rule {
            id: "trace-threads",
            needle: "event-driven scheduler",
            example: "exec_mode = \"threads\"\n[trace]\nenabled = true",
            check: |c| {
                if c.trace.enabled && c.exec_mode == ExecMode::Threads {
                    bail!(
                        "run tracing records virtual time under the event-driven \
                         scheduler: set exec_mode = sim"
                    );
                }
                Ok(())
            },
        },
        Rule {
            id: "serving-threads",
            needle: "serving workload runs under the event-driven scheduler",
            example: "exec_mode = \"threads\"\n[serving]\nenabled = true",
            check: |c| {
                if c.serving.enabled && c.exec_mode == ExecMode::Threads {
                    bail!(
                        "serving workload runs under the event-driven scheduler: \
                         set exec_mode = sim"
                    );
                }
                Ok(())
            },
        },
        Rule {
            id: "serving-sequential",
            needle: "serving workload rides the event-driven cluster loop",
            example: "algorithm = \"sgd\"\nworkers = 1\n[serving]\nenabled = true",
            check: |c| {
                if c.serving.enabled && c.algorithm == Algorithm::SequentialSgd {
                    bail!(
                        "serving workload rides the event-driven cluster loop: \
                         sequential SGD runs outside it — use a cluster \
                         algorithm (asgd, dc-asgd-*, ssp, dc-s3gd, ssgd)"
                    );
                }
                Ok(())
            },
        },
        Rule {
            id: "compress-barrier-ssgd",
            needle: "folds dense gradients",
            example: "algorithm = \"ssgd\"\n[compress]\ncodec = \"topk\"",
            check: compress_barrier,
        },
        Rule {
            id: "compress-barrier-dc-ssgd",
            needle: "folds dense gradients",
            example: "algorithm = \"dc-ssgd\"\n[compress]\ncodec = \"qsgd\"",
            check: compress_barrier,
        },
        Rule {
            id: "compress-barrier-hier-ssgd",
            needle: "folds dense gradients",
            example: "algorithm = \"hier-ssgd\"\n[compress]\ncodec = \"topk\"",
            check: compress_barrier,
        },
        Rule {
            id: "hier-ssgd-threads",
            needle: "event-driven scheduler",
            example: "algorithm = \"hier-ssgd\"\nexec_mode = \"threads\"",
            check: |c| {
                if c.algorithm == Algorithm::HierSsgd && c.exec_mode == ExecMode::Threads {
                    bail!(
                        "hier-ssgd folds rack partials under the event-driven \
                         scheduler: set exec_mode = sim"
                    );
                }
                Ok(())
            },
        },
        Rule {
            id: "topology-threads",
            needle: "event-driven scheduler",
            example: "exec_mode = \"threads\"\n[topology]\nenabled = true",
            check: |c| {
                if c.topology.enabled && c.exec_mode == ExecMode::Threads {
                    bail!(
                        "fleet topology runs under the event-driven scheduler: \
                         set exec_mode = sim"
                    );
                }
                Ok(())
            },
        },
        Rule {
            id: "topology-comm-overlap",
            needle: "at most one of [comm] and [topology]",
            example: "[comm]\nenabled = true\n[topology]\nenabled = true",
            check: |c| {
                if c.topology.enabled && c.comm.enabled {
                    bail!(
                        "enable at most one of [comm] and [topology]: the topology \
                         model derives per-worker transfer charges itself"
                    );
                }
                Ok(())
            },
        },
        Rule {
            id: "topology-hier-barrier",
            needle: "hierarchical aggregation folds at a barrier",
            example: "algorithm = \"asgd\"\nworkers = 4\n[topology]\nracks = 2\nhierarchical = true",
            check: |c| {
                if c.topology.enabled
                    && c.topology.hierarchical
                    && !matches!(
                        c.algorithm,
                        Algorithm::SyncSgd | Algorithm::DcSyncSgd | Algorithm::HierSsgd
                    )
                {
                    bail!(
                        "hierarchical aggregation folds at a barrier: it requires a \
                         barrier-commit algorithm (ssgd/dc-ssgd/hier-ssgd), not {}",
                        c.algorithm.name()
                    );
                }
                Ok(())
            },
        },
        Rule {
            id: "topology-racks-fleet",
            needle: "every rack must hold at least one worker",
            example: "workers = 2\n[topology]\nracks = 4",
            check: |c| c.topology.validate(c.workers),
        },
        Rule {
            id: "compress-momentum",
            needle: "momentum does not compose",
            example: "[train]\nmomentum = 0.9\n[compress]\ncodec = \"topk\"",
            check: |c| {
                if !c.compress.is_none() && c.momentum > 0.0 {
                    bail!("momentum does not compose with gradient compression");
                }
                Ok(())
            },
        },
        Rule {
            id: "compress-xla",
            needle: "native update backend",
            example: "update_backend = \"xla\"\nshards = 1\n[compress]\ncodec = \"topk\"",
            check: |c| {
                if !c.compress.is_none() && c.update_backend == UpdateBackend::Xla {
                    bail!("compression requires the native update backend");
                }
                Ok(())
            },
        },
        Rule {
            id: "compress-threads",
            needle: "event-driven scheduler",
            example: "exec_mode = \"threads\"\n[compress]\ncodec = \"topk\"",
            check: |c| {
                if !c.compress.is_none() && c.exec_mode == ExecMode::Threads {
                    bail!(
                        "compression runs under the event-driven scheduler: set exec_mode = sim"
                    );
                }
                Ok(())
            },
        },
        Rule {
            id: "faults-crash-rate",
            needle: "crash_rate must be finite and >= 0",
            example: "[faults]\ncrash_rate = -0.1",
            check: faults_domain,
        },
        Rule {
            id: "faults-restart-mean",
            needle: "restart_mean must be finite and > 0",
            example: "[faults]\nrestart_mean = 0.0",
            check: faults_domain,
        },
        Rule {
            id: "faults-departure-prob",
            needle: "departure_prob must be in [0, 1]",
            example: "[faults]\ndeparture_prob = 1.5",
            check: faults_domain,
        },
        Rule {
            id: "faults-straggler-rate",
            needle: "straggler_rate must be finite and >= 0",
            example: "[faults]\nstraggler_rate = -0.1",
            check: faults_domain,
        },
        Rule {
            id: "faults-straggler-factor",
            needle: "straggler_factor must be >= 1",
            example: "[faults]\nstraggler_rate = 0.1\nstraggler_factor = 0.5",
            check: faults_domain,
        },
        Rule {
            id: "faults-straggler-duration",
            needle: "straggler_duration must be finite and > 0",
            example: "[faults]\nstraggler_rate = 0.1\nstraggler_duration = 0.0",
            check: faults_domain,
        },
        Rule {
            id: "faults-late-join",
            needle: "at least one worker must be present at t = 0",
            example: "workers = 4\n[faults]\nlate_join = 4",
            check: faults_domain,
        },
        Rule {
            id: "faults-late-join-by",
            needle: "late_join_by must be finite and > 0",
            example: "workers = 4\n[faults]\nlate_join = 1\nlate_join_by = 0.0",
            check: faults_domain,
        },
        Rule {
            id: "compress-ratio-domain",
            needle: "ratio must be in (0, 1]",
            example: "[compress]\ncodec = \"topk\"\nratio = 0.0",
            check: codec_domain,
        },
        Rule {
            id: "compress-bits-domain",
            needle: "qsgd bits must be in [3, 16]",
            example: "[compress]\ncodec = \"qsgd\"\nbits = 2",
            check: codec_domain,
        },
    ]
}

// ---------------------------------------------------------------- plumbing

/// Normalize a key: `/train/lr` (pointer) and `train.lr` (dotted) are the
/// same knob.
fn normalize(key: &str) -> String {
    match key.strip_prefix('/') {
        Some(rest) => rest.replace('/', "."),
        None => key.to_string(),
    }
}

/// Look up a knob by pointer id or dotted TOML key.
pub fn find(key: &str) -> Option<&'static Knob> {
    find_indexed(key).map(|(_, k)| k)
}

/// Like [`find`], also returning the knob's manifest index (apply order).
pub fn find_indexed(key: &str) -> Option<(usize, &'static Knob)> {
    let norm = normalize(key);
    knobs().iter().enumerate().find(|(_, k)| k.toml_key == norm)
}

/// Apply every entry of a parsed TOML document (except `preset`, which the
/// caller resolves into the base config first). Unknown keys are rejected;
/// entries apply in manifest order regardless of document order.
pub fn apply_doc(cfg: &mut ExperimentConfig, doc: &Doc) -> anyhow::Result<()> {
    let mut hits: Vec<(usize, &Knob, &Value)> = Vec::new();
    for key in doc.keys() {
        if key == "preset" {
            continue;
        }
        let val = doc.get(key).expect("key from doc.keys()");
        match find_indexed(key) {
            Some((i, k)) => hits.push((i, k, val)),
            None => bail!("unknown config key {key:?} (see `dcasgd knobs` for the manifest)"),
        }
    }
    hits.sort_by_key(|(i, _, _)| *i);
    for (_, k, v) in hits {
        (k.set)(cfg, v)?;
    }
    Ok(())
}

/// Apply `(key, value)` pairs (scenario overrides / sweep cells), in
/// manifest order. Keys may use either spelling.
pub fn apply_pairs(cfg: &mut ExperimentConfig, pairs: &[(String, Value)]) -> anyhow::Result<()> {
    let mut hits: Vec<(usize, &Knob, &Value)> = Vec::new();
    for (key, val) in pairs {
        match find_indexed(key) {
            Some((i, k)) => hits.push((i, k, val)),
            None => bail!("unknown config key {key:?} (see `dcasgd knobs` for the manifest)"),
        }
    }
    hits.sort_by_key(|(i, _, _)| *i);
    for (_, k, v) in hits {
        (k.set)(cfg, v)?;
    }
    Ok(())
}

/// Parse a CLI string into a knob's value type.
fn parse_cli_value(k: &Knob, flag: &str, raw: &str) -> anyhow::Result<Value> {
    let invalid = |expect: &str| anyhow::anyhow!("invalid value for --{flag}: {raw:?} ({expect})");
    Ok(match k.ty {
        Ty::Str | Ty::Enum(_) => Value::Str(raw.to_string()),
        Ty::Bool => match raw {
            "true" | "1" => Value::Bool(true),
            "false" | "0" => Value::Bool(false),
            _ => return Err(invalid("true|false")),
        },
        Ty::F64 => Value::Float(raw.parse::<f64>().map_err(|_| invalid("float"))?),
        Ty::USize => Value::Int(raw.parse::<usize>().map_err(|_| invalid("usize"))? as i64),
        // u64 -> i64 round-trips through two's complement losslessly
        Ty::U64 => Value::Int(raw.parse::<u64>().map_err(|_| invalid("u64"))? as i64),
        Ty::USizeList => Value::Array(
            raw.split(',')
                .map(|p| {
                    p.trim()
                        .parse::<usize>()
                        .map(|v| Value::Int(v as i64))
                        .map_err(|_| invalid("comma-separated usize list"))
                })
                .collect::<anyhow::Result<_>>()?,
        ),
        Ty::F64List => Value::Array(
            raw.split(',')
                .map(|p| {
                    p.trim()
                        .parse::<f64>()
                        .map(Value::Float)
                        .map_err(|_| invalid("comma-separated float list"))
                })
                .collect::<anyhow::Result<_>>()?,
        ),
    })
}

/// Overlay CLI flags onto a config: every knob with a `cli` name, plus the
/// historical special cases (`--comm` / `--faults` bare enables, the
/// sequential-SGD worker fixup, compress codec/ratio/bits inheritance, and
/// `--verbose` being sticky-OR with the config file).
pub fn overlay_cli(cfg: &mut ExperimentConfig, args: &Args) -> anyhow::Result<()> {
    for k in knobs() {
        let Some(flag) = k.cli else { continue };
        // handled below with their historical interplay semantics
        if matches!(flag, "compress" | "topk-ratio" | "quant-bits" | "verbose") {
            continue;
        }
        let Some(raw) = args.str_opt(flag) else { continue };
        let val = parse_cli_value(k, flag, &raw)?;
        (k.set)(cfg, &val)?;
        // `--workers N` on a sequential-SGD base means "go parallel"
        if flag == "workers" && cfg.algorithm == Algorithm::SequentialSgd && cfg.workers > 1 {
            cfg.algorithm = Algorithm::Asgd;
        }
    }
    if cfg.algorithm == Algorithm::SequentialSgd {
        cfg.workers = 1;
    }
    if args.flag("comm") {
        cfg.comm.enabled = true;
    }
    if args.flag("faults") {
        cfg.faults.enabled = true;
    }
    if args.flag("trace") {
        cfg.trace.enabled = true;
    }
    // gradient compression: --compress picks the codec; the knob flags
    // refine whichever codec is selected (CLI, scenario, or config file)
    let topk_ratio = args
        .str_opt("topk-ratio")
        .map(|r| r.parse::<f64>().map_err(|_| anyhow::anyhow!("invalid value for --topk-ratio: {r:?} (float)")))
        .transpose()?;
    let quant_bits = args
        .str_opt("quant-bits")
        .map(|b| -> anyhow::Result<u32> {
            b.parse::<usize>()
                .ok()
                .and_then(|v| u32::try_from(v).ok())
                .ok_or_else(|| anyhow::anyhow!("--quant-bits {b} out of range"))
        })
        .transpose()?;
    if let Some(c) = args.str_opt("compress") {
        let cur_ratio = match cfg.compress {
            CodecConfig::TopK { ratio } | CodecConfig::RandK { ratio } => ratio,
            _ => 0.1,
        };
        let cur_bits = match cfg.compress {
            CodecConfig::Qsgd { bits } => bits,
            _ => 8,
        };
        cfg.compress = CodecConfig::parse(
            &c,
            topk_ratio.unwrap_or(cur_ratio),
            quant_bits.unwrap_or(cur_bits),
        )?;
    } else {
        if let Some(r) = topk_ratio {
            if let CodecConfig::TopK { ratio } | CodecConfig::RandK { ratio } = &mut cfg.compress {
                *ratio = r;
            }
        }
        if let Some(b) = quant_bits {
            if let CodecConfig::Qsgd { bits } = &mut cfg.compress {
                *bits = b;
            }
        }
    }
    cfg.verbose = cfg.verbose || args.flag("verbose");
    Ok(())
}

/// Full pre-flight validation: per-knob bounds (through the getters, so
/// model-dependent knobs are only checked when applicable), then the
/// cross-knob rules. This *is* `ExperimentConfig::validate`.
pub fn check(cfg: &ExperimentConfig) -> anyhow::Result<()> {
    for k in knobs() {
        let (Some(b), Some(v)) = (&k.bounds, (k.get)(cfg)) else { continue };
        let x = v.as_f64().unwrap_or(f64::NAN);
        if !b.admits(x) {
            bail!("{}", b.msg);
        }
    }
    for r in rules() {
        (r.check)(cfg)?;
    }
    Ok(())
}

/// One entry of the generated rejected-combination matrix.
pub struct RejectionCase {
    /// TOML document that must be rejected.
    pub toml: String,
    /// Pinned fragment the rejection message must contain.
    pub needle: &'static str,
}

/// The full rejected-combination matrix, generated from the manifest:
/// one bounds violation per bounded knob, every rule's canonical example,
/// and the parse-level cases. The matrix test iterates this, so a new knob
/// or rule is covered automatically.
pub fn rejection_cases() -> Vec<RejectionCase> {
    let mut out = Vec::new();
    for k in knobs() {
        let Some(b) = &k.bounds else { continue };
        let v = b.violation();
        let lit = match k.ty {
            Ty::USize | Ty::U64 => format!("{}", v as i64),
            _ => format!("{v:?}"),
        };
        out.push(RejectionCase {
            toml: format!("{}{} = {}", k.ctx, k.toml_key, lit),
            needle: b.msg,
        });
    }
    for r in rules() {
        out.push(RejectionCase { toml: r.example.to_string(), needle: r.needle });
    }
    for (toml, needle) in PARSE_CASES {
        out.push(RejectionCase { toml: toml.to_string(), needle });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_ids_are_unique_and_consistent() {
        let mut seen = std::collections::BTreeSet::new();
        let mut cli_seen = std::collections::BTreeSet::new();
        for k in knobs() {
            assert!(k.id.starts_with('/'), "{} must be a pointer id", k.id);
            assert_eq!(normalize(k.id), k.toml_key, "{}: id/toml_key mismatch", k.id);
            assert!(seen.insert(k.id), "duplicate knob id {}", k.id);
            if let Some(cli) = k.cli {
                assert!(cli_seen.insert(cli), "duplicate CLI flag --{cli}");
            }
        }
    }

    #[test]
    fn find_accepts_both_spellings() {
        assert!(find("/train/lr").is_some());
        assert!(find("train.lr").is_some());
        assert_eq!(find("/train/lr").unwrap().toml_key, find("train.lr").unwrap().toml_key);
        assert!(find("/no/such/knob").is_none());
    }

    #[test]
    fn getters_round_trip_defaults() {
        // every knob that applies to the default config must read back a
        // value whose bounds admit it
        let cfg = ExperimentConfig::default();
        for k in knobs() {
            if let (Some(b), Some(v)) = (&k.bounds, (k.get)(&cfg)) {
                let x = v.as_f64().unwrap();
                assert!(b.admits(x), "{}: default {x} violates its own bounds", k.id);
            }
        }
    }

    #[test]
    fn set_get_round_trip() {
        let mut cfg = ExperimentConfig::default();
        let k = find("/train/lr").unwrap();
        (k.set)(&mut cfg, &Value::Float(0.25)).unwrap();
        assert_eq!((k.get)(&cfg), Some(Value::Float(0.25)));
        let k = find("/workers").unwrap();
        (k.set)(&mut cfg, &Value::Int(8)).unwrap();
        assert_eq!((k.get)(&cfg), Some(Value::Int(8)));
    }

    #[test]
    fn apply_order_is_manifest_order_not_document_order() {
        // enabled=false written BEFORE the auto-enabling parameter must
        // still win (manifest declares `enabled` last in its section)
        let doc = Doc::parse("[comm]\nenabled = false\nper_push = 2e-4").unwrap();
        let mut cfg = ExperimentConfig::default();
        apply_doc(&mut cfg, &doc).unwrap();
        assert!(!cfg.comm.enabled);
        assert_eq!(cfg.comm.model.per_push, 2e-4);

        // ratio before codec also works: codec applies first
        let doc = Doc::parse("[compress]\nratio = 0.25\ncodec = \"topk\"").unwrap();
        let mut cfg = ExperimentConfig::default();
        apply_doc(&mut cfg, &doc).unwrap();
        assert_eq!(cfg.compress, CodecConfig::TopK { ratio: 0.25 });
    }

    #[test]
    fn compound_codec_specs() {
        let mut cfg = ExperimentConfig::default();
        let k = find("/compress/codec").unwrap();
        (k.set)(&mut cfg, &Value::Str("topk@0.25".into())).unwrap();
        assert_eq!(cfg.compress, CodecConfig::TopK { ratio: 0.25 });
        (k.set)(&mut cfg, &Value::Str("qsgd@4".into())).unwrap();
        assert_eq!(cfg.compress, CodecConfig::Qsgd { bits: 4 });
        // a plain codec switch inherits the tuned parameter
        (k.set)(&mut cfg, &Value::Str("qsgd".into())).unwrap();
        assert_eq!(cfg.compress, CodecConfig::Qsgd { bits: 4 });
        assert!((k.set)(&mut cfg, &Value::Str("none@1".into())).is_err());
    }

    #[test]
    fn every_rejection_case_rejects_with_its_needle() {
        // the real matrix test lives in config::tests; this one pins that
        // the generator itself is self-consistent
        for case in rejection_cases() {
            let err = ExperimentConfig::from_toml(&case.toml)
                .expect_err(&format!("must reject: {}", case.toml))
                .to_string();
            assert!(
                err.contains(case.needle),
                "{:?}: error {err:?} lacks {:?}",
                case.toml,
                case.needle
            );
        }
    }

    #[test]
    fn unknown_keys_are_rejected() {
        let err = ExperimentConfig::from_toml("bogus = 1").unwrap_err().to_string();
        assert!(err.contains("unknown config key"), "{err}");
        let err = ExperimentConfig::from_toml("[train]\nbogus = 1").unwrap_err().to_string();
        assert!(err.contains("train.bogus"), "{err}");
    }
}
