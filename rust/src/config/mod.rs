//! Typed experiment configuration + validation + presets.
//!
//! Config files are TOML-subset (see [`toml`]); every knob also has a CLI
//! override in `main.rs`. Presets encode the paper's experimental setups
//! scaled to this testbed (DESIGN.md §5/§6).

pub mod manifest;
pub mod toml;

use crate::util::json::Json;
use anyhow::{bail, Context};
use std::fmt;
use std::path::Path;

/// Which update rule the parameter server applies (paper §4/§6 + appendix H).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algorithm {
    /// Single-worker sequential SGD (the paper's accuracy reference).
    SequentialSgd,
    /// Synchronous SGD: barrier, average of M gradients (Dean et al.).
    SyncSgd,
    /// Delay-compensated synchronous SGD (appendix H).
    DcSyncSgd,
    /// Plain asynchronous SGD (delayed gradients applied as-is).
    Asgd,
    /// DC-ASGD-c: constant lambda (Eqn. 10).
    DcAsgdConst,
    /// DC-ASGD-a: adaptive lambda via MeanSquare (Eqn. 14).
    DcAsgdAdaptive,
    /// Stale-synchronous parallel SGD: workers may drift at most
    /// `staleness_bound` local steps apart (s=0 degenerates to the SSGD
    /// round structure, s large to ASGD).
    Ssp,
    /// Delay-compensated SSP (DC-S3GD, Rigazzi et al. 2019): the SSP
    /// schedule with the constant-lambda DC update against w_bak.
    DcS3gd,
    /// Hierarchical synchronous SGD: the SSGD barrier schedule with
    /// two-level aggregation — rack reducers fold their residents'
    /// gradients, the root folds one partial per rack (`[topology]`).
    /// With one rack it degenerates to plain SSGD bit-for-bit.
    HierSsgd,
}

impl Algorithm {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "sgd" | "sequential" | "seq" => Algorithm::SequentialSgd,
            "ssgd" | "sync" => Algorithm::SyncSgd,
            "dc-ssgd" | "dcssgd" | "dc-sync" => Algorithm::DcSyncSgd,
            "asgd" | "async" => Algorithm::Asgd,
            "dc-asgd-c" | "dcasgd-c" | "dc-c" => Algorithm::DcAsgdConst,
            "dc-asgd-a" | "dcasgd-a" | "dc-a" => Algorithm::DcAsgdAdaptive,
            "ssp" | "s3gd" => Algorithm::Ssp,
            "dc-s3gd" | "dcs3gd" | "dc-ssp" => Algorithm::DcS3gd,
            "hier-ssgd" | "hierssgd" | "hier" => Algorithm::HierSsgd,
            other => bail!(
                "unknown algorithm {other:?} (sgd|ssgd|dc-ssgd|asgd|dc-asgd-c|dc-asgd-a|ssp|dc-s3gd|hier-ssgd)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::SequentialSgd => "sgd",
            Algorithm::SyncSgd => "ssgd",
            Algorithm::DcSyncSgd => "dc-ssgd",
            Algorithm::Asgd => "asgd",
            Algorithm::DcAsgdConst => "dc-asgd-c",
            Algorithm::DcAsgdAdaptive => "dc-asgd-a",
            Algorithm::Ssp => "ssp",
            Algorithm::DcS3gd => "dc-s3gd",
            Algorithm::HierSsgd => "hier-ssgd",
        }
    }

    /// Does the rule use delay compensation?
    pub fn is_delay_compensated(&self) -> bool {
        matches!(
            self,
            Algorithm::DcAsgdConst
                | Algorithm::DcAsgdAdaptive
                | Algorithm::DcSyncSgd
                | Algorithm::DcS3gd
        )
    }

    /// Is the parallelization asynchronous (no global barrier)? SSP counts:
    /// workers proceed independently inside the staleness window.
    pub fn is_async(&self) -> bool {
        matches!(
            self,
            Algorithm::Asgd
                | Algorithm::DcAsgdConst
                | Algorithm::DcAsgdAdaptive
                | Algorithm::Ssp
                | Algorithm::DcS3gd
        )
    }

    /// Is the schedule gated by the staleness bound (SSP family)?
    pub fn is_staleness_bounded(&self) -> bool {
        matches!(self, Algorithm::Ssp | Algorithm::DcS3gd)
    }
}

impl fmt::Display for Algorithm {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Worker compute-time model for the discrete-event simulator (sim/delay.rs).
#[derive(Clone, Debug, PartialEq)]
pub enum DelayModel {
    /// Every gradient takes exactly `mean` simulated seconds.
    Constant { mean: f64 },
    /// Uniform in [mean*(1-jitter), mean*(1+jitter)].
    Uniform { mean: f64, jitter: f64 },
    /// Exponential with the given mean (memoryless workers).
    Exponential { mean: f64 },
    /// Pareto-tailed: mostly ~scale, occasional heavy stragglers.
    Pareto { scale: f64, alpha: f64 },
    /// Heterogeneous fleet: worker m's mean is `mean * speed[m % speeds.len()]`.
    Heterogeneous { mean: f64, speeds: Vec<f64>, jitter: f64 },
}

impl DelayModel {
    /// Mean compute duration of the model (simulated seconds). The Pareto
    /// mean is `scale * alpha / (alpha - 1)` for `alpha > 1` and is clamped
    /// to `scale` for heavy tails without a finite mean.
    pub fn mean(&self) -> f64 {
        match self {
            DelayModel::Constant { mean }
            | DelayModel::Uniform { mean, .. }
            | DelayModel::Exponential { mean }
            | DelayModel::Heterogeneous { mean, .. } => *mean,
            DelayModel::Pareto { scale, alpha } => {
                if *alpha > 1.0 {
                    scale * alpha / (alpha - 1.0)
                } else {
                    *scale
                }
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DelayModel::Constant { .. } => "constant",
            DelayModel::Uniform { .. } => "uniform",
            DelayModel::Exponential { .. } => "exponential",
            DelayModel::Pareto { .. } => "pareto",
            DelayModel::Heterogeneous { .. } => "heterogeneous",
        }
    }
}

/// Step-decay learning-rate schedule (paper: /10 at epochs 80 and 120).
#[derive(Clone, Debug, PartialEq)]
pub struct LrSchedule {
    pub base: f64,
    /// (epoch, multiplier) breakpoints, applied cumulatively in order.
    pub decay_epochs: Vec<usize>,
    pub decay_factor: f64,
}

impl LrSchedule {
    pub fn constant(base: f64) -> Self {
        Self { base, decay_epochs: vec![], decay_factor: 1.0 }
    }

    /// Learning rate for a (0-based) epoch index.
    pub fn lr_at_epoch(&self, epoch: usize) -> f64 {
        let drops = self.decay_epochs.iter().filter(|&&e| epoch >= e).count();
        self.base * self.decay_factor.powi(drops as i32)
    }
}

/// Communication-cost model for the DES (`[comm]` section). When enabled,
/// the scheduler charges `per_push + per_mb * MB` simulated seconds for
/// every gradient upload and model download, so the sync-vs-async wallclock
/// comparison pays for transfers instead of assuming a free network.
/// Disabled by default: trajectories are bit-identical to earlier builds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CommConfig {
    pub enabled: bool,
    /// Cost parameters; the canonical preset constants live on
    /// [`crate::sim::CommModel`] itself, never duplicated here.
    pub model: crate::sim::CommModel,
}

impl CommConfig {
    pub fn from_model(model: crate::sim::CommModel, enabled: bool) -> Self {
        Self { enabled, model }
    }
}

impl Default for CommConfig {
    fn default() -> Self {
        // InfiniBand-like parameters, inert until `enabled` is set
        Self::from_model(crate::sim::CommModel::infiniband_like(), false)
    }
}

/// Run-trace observability layer (`[trace]` section). When enabled, the
/// scheduler and driver emit structured events (JSONL + Chrome trace-event
/// JSON), subsystem profilers collect span histograms, and the driver
/// snapshots time-series telemetry every `sample_every` steps. Off by
/// default and bitwise-inert: trace-on vs trace-off runs produce identical
/// `TrainReport`s and checkpoint bytes (pinned by `tests/trace.rs`) —
/// tracing observes, never perturbs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceConfig {
    pub enabled: bool,
    /// Time-series sampling cadence in global steps.
    pub sample_every: usize,
    /// Emit structured scheduler/driver events (`*.trace.jsonl`).
    pub events: bool,
    /// Collect per-subsystem span histograms into the summary JSON.
    pub profile: bool,
    /// Also write Chrome trace-event format (`*.trace.json`, Perfetto).
    pub chrome_trace: bool,
}

impl Default for TraceConfig {
    fn default() -> Self {
        Self { enabled: false, sample_every: 10, events: true, profile: true, chrome_trace: true }
    }
}

/// How the server applies updates: pure-rust loops (fast path) or the
/// AOT-compiled XLA/Pallas update artifact (ablation A).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpdateBackend {
    Native,
    Xla,
}

/// Host compute-runtime knobs (`[runtime]`): lane count of the persistent
/// [`crate::util::pool::ComputePool`] that serves multi-shard applies,
/// `store_w`, and the driver's pipelined gradient stage. `0` = auto
/// (available parallelism, the default), `1` = fully serial (no pool
/// threads — the inline reference path), `n` = a dedicated `n`-lane pool.
/// The knob trades wallclock only: every setting produces bit-identical
/// schedules and trajectories (pinned by the chaos harness and the store
/// lane-invariance tests).
///
/// `simd` dispatches the chunked-SIMD update kernels and the fused /
/// streaming codec fast paths ([`crate::optim::set_simd_enabled`]);
/// `false` pins the scalar reference loops. Both sides are bit-identical
/// (the kernel property suite pins it), so this too trades wallclock only
/// — it exists for A/B measurement and the serial reference lane in CI.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RuntimeConfig {
    pub threads: usize,
    pub simd: bool,
}

impl Default for RuntimeConfig {
    fn default() -> Self {
        Self { threads: 0, simd: true }
    }
}

/// Execution mode for parallel algorithms.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Real OS threads racing on the parameter server.
    Threads,
    /// Discrete-event simulation with a virtual clock (deterministic; used
    /// for the wallclock figures).
    SimulatedTime,
}

/// Synthetic dataset selection (DESIGN.md §5).
#[derive(Clone, Debug, PartialEq)]
pub enum DatasetKind {
    CifarLike,
    ImagenetLike,
    LmCorpus,
}

impl DatasetKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "cifar" | "cifar-like" | "cifar_like" => DatasetKind::CifarLike,
            "imagenet" | "imagenet-like" | "imagenet_like" => DatasetKind::ImagenetLike,
            "lm" | "lm-corpus" | "lm_corpus" => DatasetKind::LmCorpus,
            other => bail!("unknown dataset {other:?}"),
        })
    }
    pub fn name(&self) -> &'static str {
        match self {
            DatasetKind::CifarLike => "cifar-like",
            DatasetKind::ImagenetLike => "imagenet-like",
            DatasetKind::LmCorpus => "lm-corpus",
        }
    }
}

/// Full experiment description.
#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentConfig {
    /// Artifact/model name from the AOT manifest (e.g. "mlp_cifar").
    pub model: String,
    pub dataset: DatasetKind,
    pub algorithm: Algorithm,
    /// Number of local workers M (paper: 1, 4, 8, 16).
    pub workers: usize,
    pub epochs: usize,
    /// Optional hard cap on global update steps (0 = no cap).
    pub max_steps: usize,
    pub train_size: usize,
    pub test_size: usize,
    pub lr: LrSchedule,
    /// lambda_0: DC compensation strength.
    pub lambda0: f64,
    /// SSP staleness bound s (SSP / DC-S3GD only): maximum number of local
    /// steps the fastest worker may run ahead of the slowest. s=0 gives the
    /// SSGD round structure; a large s reproduces ASGD.
    pub staleness_bound: usize,
    /// MeanSquare moving-average constant m (DC-ASGD-a).
    pub ms_momentum: f64,
    /// Classical momentum mu (0 = plain SGD; the paper's momentum variants).
    pub momentum: f64,
    pub seed: u64,
    pub exec_mode: ExecMode,
    pub delay: DelayModel,
    /// Communication-cost model (`[comm]`; off by default).
    pub comm: CommConfig,
    /// Fleet topology: racks + multi-PS placement with a topology-aware
    /// comm model (`[topology]`; off by default — bitwise-inert).
    pub topology: crate::sim::TopologyConfig,
    /// Fault injection & elastic membership (`[faults]`; off by default —
    /// schedules and trajectories are bit-identical with it off).
    pub faults: crate::sim::FaultConfig,
    /// Gradient compression codec (`[compress]`; `none` by default —
    /// pinned bit-identical to the uncompressed path).
    pub compress: crate::compress::CodecConfig,
    pub update_backend: UpdateBackend,
    /// Host compute runtime (`[runtime]`; `threads = 0` auto-sizes).
    pub runtime: RuntimeConfig,
    /// Run-trace observability (`[trace]`; off by default — bitwise-inert).
    pub trace: TraceConfig,
    /// Serving workload: inference pulls against the live PS (`[serving]`;
    /// off by default — the training schedule is bitwise-inert to it).
    pub serving: crate::sim::ServingConfig,
    /// Parameter-store lock shards.
    pub shards: usize,
    /// Evaluate on the test set every `eval_every` effective epochs.
    pub eval_every: usize,
    /// Also evaluate every N global steps (0 = disabled); used by
    /// step-capped runs like the LM driver.
    pub eval_every_steps: usize,
    /// Cap on evaluation batches per eval (0 = full test set).
    pub eval_batches: usize,
    /// Where to write metrics CSV/JSON (empty = don't write).
    pub out_dir: String,
    /// Save a final parameter-server checkpoint here (empty = don't).
    pub checkpoint_out: String,
    /// Resume from a checkpoint file before training (empty = fresh init).
    pub resume_from: String,
    /// Extra label for metrics files.
    pub tag: String,
    pub verbose: bool,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            model: "mlp_cifar".into(),
            dataset: DatasetKind::CifarLike,
            algorithm: Algorithm::Asgd,
            workers: 4,
            epochs: 10,
            max_steps: 0,
            train_size: 4096,
            test_size: 1024,
            lr: LrSchedule { base: 0.1, decay_epochs: vec![], decay_factor: 0.1 },
            lambda0: 0.04,
            staleness_bound: 4,
            ms_momentum: 0.95,
            momentum: 0.0,
            seed: 17,
            exec_mode: ExecMode::SimulatedTime,
            delay: DelayModel::Uniform { mean: 1.0, jitter: 0.3 },
            comm: CommConfig::default(),
            topology: crate::sim::TopologyConfig::default(),
            faults: crate::sim::FaultConfig::default(),
            compress: crate::compress::CodecConfig::None,
            update_backend: UpdateBackend::Native,
            runtime: RuntimeConfig::default(),
            trace: TraceConfig::default(),
            serving: crate::sim::ServingConfig::default(),
            shards: 1,
            eval_every: 1,
            eval_every_steps: 0,
            eval_batches: 0,
            out_dir: String::new(),
            checkpoint_out: String::new(),
            resume_from: String::new(),
            tag: String::new(),
            verbose: false,
        }
    }
}

impl ExperimentConfig {
    // ---------------------------------------------------------------- presets

    /// Tiny fast preset used by examples/quickstart and integration tests.
    pub fn preset_quickstart() -> Self {
        Self {
            model: "mlp_tiny".into(),
            dataset: DatasetKind::CifarLike,
            workers: 4,
            epochs: 6,
            train_size: 1024,
            test_size: 512,
            lr: LrSchedule::constant(0.5),
            lambda0: 2.0,
            ..Self::default()
        }
    }

    /// Table 1 / Fig 2 / Fig 3 setup (CIFAR-like; paper: ResNet-20, 160
    /// epochs, batch 128, lr 0.5 decayed at 80/120, lambda0 0.04 / 2.0).
    pub fn preset_cifar() -> Self {
        Self {
            model: "mlp_cifar".into(),
            dataset: DatasetKind::CifarLike,
            workers: 4,
            epochs: 40,
            train_size: 16_384,
            test_size: 4_096,
            // lr/lambda calibrated on the synthetic task (EXPERIMENTS.md):
            // the high-lr regime is where delayed gradients bite, as in the
            // paper's eta=0.5 CIFAR setting.
            lr: LrSchedule { base: 0.5, decay_epochs: vec![20, 30], decay_factor: 0.1 },
            lambda0: 4.0,
            ms_momentum: 0.95,
            ..Self::default()
        }
    }

    /// Table 2 / Fig 4 setup (ImageNet-like; paper: ResNet-50, M=16,
    /// lr 0.1 decayed every 30 epochs, lambda0 2, m=0).
    pub fn preset_imagenet() -> Self {
        Self {
            model: "mlp_imagenet".into(),
            dataset: DatasetKind::ImagenetLike,
            workers: 16,
            epochs: 24,
            train_size: 32_768,
            test_size: 8_192,
            lr: LrSchedule { base: 0.4, decay_epochs: vec![12, 18], decay_factor: 0.1 },
            lambda0: 4.0,
            ms_momentum: 0.0,
            ..Self::default()
        }
    }

    /// End-to-end LM training (examples/train_lm.rs).
    pub fn preset_lm(model: &str) -> Self {
        Self {
            model: model.into(),
            dataset: DatasetKind::LmCorpus,
            workers: 4,
            epochs: 1,
            max_steps: 300,
            train_size: 8_192, // sequences
            test_size: 512,
            // transformer-scale lr: larger models diverge above ~0.1 on
            // this corpus (see EXPERIMENTS.md e2e notes)
            lr: LrSchedule::constant(0.05),
            lambda0: 2.0,
            ms_momentum: 0.95,
            eval_every: 1,
            eval_every_steps: 50,
            eval_batches: 8,
            ..Self::default()
        }
    }

    // ------------------------------------------------------------ validation

    /// Pre-flight validation: per-knob bounds + the cross-knob rejection
    /// rules, all declared once in [`manifest`]. Every message is pinned by
    /// the manifest-driven rejected-combination matrix test.
    pub fn validate(&self) -> anyhow::Result<()> {
        manifest::check(self)
    }

    // --------------------------------------------------------- file loading

    pub fn from_file(path: &Path) -> anyhow::Result<Self> {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_toml(&src)
    }

    /// Resolve a `preset` name into the base config it denotes (`None` =
    /// plain defaults). The single place preset names are interpreted.
    pub fn base_for_preset(name: Option<&str>) -> anyhow::Result<Self> {
        Ok(match name {
            None => Self::default(),
            Some("quickstart") => Self::preset_quickstart(),
            Some("cifar") => Self::preset_cifar(),
            Some("imagenet") => Self::preset_imagenet(),
            Some("lm") => Self::preset_lm("lm_medium"),
            Some(other) => bail!("unknown preset {other:?}"),
        })
    }

    pub fn from_toml(src: &str) -> anyhow::Result<Self> {
        let doc = toml::Doc::parse(src)?;
        Self::from_doc(&doc)
    }

    /// Build a config from a parsed document: resolve `preset` into the
    /// base, apply every other key through the knob manifest (unknown keys
    /// are rejected; entries apply in manifest order), then validate.
    pub fn from_doc(doc: &toml::Doc) -> anyhow::Result<Self> {
        let mut cfg = Self::base_for_preset(doc.get("preset").and_then(|v| v.as_str()))?;
        manifest::apply_doc(&mut cfg, doc)?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// JSON summary for metrics files.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", self.model.as_str().into()),
            ("dataset", self.dataset.name().into()),
            ("algorithm", self.algorithm.name().into()),
            ("workers", self.workers.into()),
            ("epochs", self.epochs.into()),
            ("max_steps", self.max_steps.into()),
            ("train_size", self.train_size.into()),
            ("test_size", self.test_size.into()),
            ("lr", self.lr.base.into()),
            ("lambda0", self.lambda0.into()),
            ("staleness_bound", self.staleness_bound.into()),
            ("ms_momentum", self.ms_momentum.into()),
            ("momentum", self.momentum.into()),
            ("seed", (self.seed as i64).into()),
            ("delay_model", self.delay.name().into()),
            ("comm_enabled", self.comm.enabled.into()),
            ("comm_per_push", self.comm.model.per_push.into()),
            ("comm_per_mb", self.comm.model.per_mb.into()),
            ("topology_enabled", self.topology.enabled.into()),
            ("topology_ps_nodes", self.topology.ps_nodes.into()),
            ("topology_racks", self.topology.racks.into()),
            ("topology_hierarchical", self.topology.hierarchical.into()),
            ("faults_enabled", self.faults.enabled.into()),
            ("fault_crash_rate", self.faults.crash_rate.into()),
            ("fault_restart_mean", self.faults.restart_mean.into()),
            ("fault_departure_prob", self.faults.departure_prob.into()),
            ("fault_straggler_rate", self.faults.straggler_rate.into()),
            ("fault_late_join", self.faults.late_join.into()),
            ("fault_policy", self.faults.policy.name().into()),
            ("compress", self.compress.name().into()),
            (
                "compress_ratio",
                match self.compress {
                    crate::compress::CodecConfig::TopK { ratio }
                    | crate::compress::CodecConfig::RandK { ratio } => ratio.into(),
                    _ => 0.0.into(),
                },
            ),
            (
                "compress_bits",
                match self.compress {
                    crate::compress::CodecConfig::Qsgd { bits } => (bits as i64).into(),
                    _ => 0i64.into(),
                },
            ),
            ("shards", self.shards.into()),
            ("runtime_threads", self.runtime.threads.into()),
            ("runtime_simd", self.runtime.simd.into()),
            ("trace_enabled", self.trace.enabled.into()),
            ("trace_sample_every", self.trace.sample_every.into()),
            ("serving_enabled", self.serving.enabled.into()),
            ("serving_publish_every", self.serving.publish_every.into()),
            ("serving_rate", self.serving.rate.into()),
            ("serving_arrival", self.serving.arrival.name().into()),
            ("serving_batch", self.serving.batch.into()),
            ("serving_read_mode", self.serving.read_mode.name().into()),
            ("tag", self.tag.as_str().into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algorithm_parse_roundtrip() {
        for a in [
            Algorithm::SequentialSgd,
            Algorithm::SyncSgd,
            Algorithm::DcSyncSgd,
            Algorithm::Asgd,
            Algorithm::DcAsgdConst,
            Algorithm::DcAsgdAdaptive,
            Algorithm::Ssp,
            Algorithm::DcS3gd,
            Algorithm::HierSsgd,
        ] {
            assert_eq!(Algorithm::parse(a.name()).unwrap(), a);
        }
        assert!(Algorithm::parse("nope").is_err());
    }

    #[test]
    fn algorithm_classification() {
        assert!(Algorithm::DcAsgdConst.is_delay_compensated());
        assert!(Algorithm::DcSyncSgd.is_delay_compensated());
        assert!(Algorithm::DcS3gd.is_delay_compensated());
        assert!(!Algorithm::Asgd.is_delay_compensated());
        assert!(!Algorithm::Ssp.is_delay_compensated());
        assert!(Algorithm::Asgd.is_async());
        assert!(Algorithm::Ssp.is_async());
        assert!(Algorithm::DcS3gd.is_async());
        assert!(!Algorithm::SyncSgd.is_async());
        assert!(!Algorithm::SequentialSgd.is_async());
        assert!(Algorithm::Ssp.is_staleness_bounded());
        assert!(Algorithm::DcS3gd.is_staleness_bounded());
        assert!(!Algorithm::Asgd.is_staleness_bounded());
        // hierarchical SSGD is a barrier algorithm, plain fold
        assert!(!Algorithm::HierSsgd.is_async());
        assert!(!Algorithm::HierSsgd.is_delay_compensated());
        assert!(!Algorithm::HierSsgd.is_staleness_bounded());
    }

    #[test]
    fn delay_model_means() {
        assert_eq!(DelayModel::Constant { mean: 2.0 }.mean(), 2.0);
        assert_eq!(DelayModel::Uniform { mean: 1.5, jitter: 0.3 }.mean(), 1.5);
        assert_eq!(DelayModel::Exponential { mean: 0.7 }.mean(), 0.7);
        let p = DelayModel::Pareto { scale: 1.0, alpha: 2.0 };
        assert!((p.mean() - 2.0).abs() < 1e-12);
        // heavy tail without a finite mean clamps to scale
        assert_eq!(DelayModel::Pareto { scale: 1.0, alpha: 0.9 }.mean(), 1.0);
    }

    #[test]
    fn lr_schedule_step_decay() {
        let lr = LrSchedule { base: 0.5, decay_epochs: vec![80, 120], decay_factor: 0.1 };
        assert_eq!(lr.lr_at_epoch(0), 0.5);
        assert_eq!(lr.lr_at_epoch(79), 0.5);
        assert!((lr.lr_at_epoch(80) - 0.05).abs() < 1e-12);
        assert!((lr.lr_at_epoch(119) - 0.05).abs() < 1e-12);
        assert!((lr.lr_at_epoch(120) - 0.005).abs() < 1e-12);
        assert!((lr.lr_at_epoch(1000) - 0.005).abs() < 1e-12);
    }

    #[test]
    fn validation_catches_bad_configs() {
        let mut cfg = ExperimentConfig::default();
        cfg.workers = 0;
        assert!(cfg.validate().is_err());

        let mut cfg = ExperimentConfig::default();
        cfg.algorithm = Algorithm::SequentialSgd;
        cfg.workers = 4;
        assert!(cfg.validate().is_err());

        let mut cfg = ExperimentConfig::default();
        cfg.lr.base = -1.0;
        assert!(cfg.validate().is_err());

        let mut cfg = ExperimentConfig::default();
        cfg.delay = DelayModel::Uniform { mean: 1.0, jitter: 1.5 };
        assert!(cfg.validate().is_err());

        assert!(ExperimentConfig::default().validate().is_ok());
    }

    #[test]
    fn from_toml_full() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            model = "mlp_cifar"
            dataset = "cifar-like"
            algorithm = "dc-asgd-a"
            workers = 8
            epochs = 3
            seed = 99
            [train]
            lr = 0.5
            decay_epochs = [2]
            decay_factor = 0.1
            lambda0 = 2.0
            ms_momentum = 0.95
            [data]
            train_size = 2048
            test_size = 256
            [sim.delay]
            model = "pareto"
            scale = 0.8
            alpha = 2.0
            "#,
        )
        .unwrap();
        assert_eq!(cfg.algorithm, Algorithm::DcAsgdAdaptive);
        assert_eq!(cfg.workers, 8);
        assert_eq!(cfg.seed, 99);
        assert_eq!(cfg.lr.decay_epochs, vec![2]);
        assert_eq!(cfg.delay, DelayModel::Pareto { scale: 0.8, alpha: 2.0 });
        assert_eq!(cfg.train_size, 2048);
    }

    #[test]
    fn from_toml_preset_plus_override() {
        let cfg = ExperimentConfig::from_toml(
            "preset = \"cifar\"\nworkers = 8\n[train]\nlambda0 = 2.0",
        )
        .unwrap();
        assert_eq!(cfg.model, "mlp_cifar");
        assert_eq!(cfg.workers, 8);
        assert_eq!(cfg.lambda0, 2.0);
    }

    #[test]
    fn from_toml_rejects_invalid() {
        assert!(ExperimentConfig::from_toml("workers = 0").is_err());
        assert!(ExperimentConfig::from_toml("algorithm = \"bogus\"").is_err());
        assert!(ExperimentConfig::from_toml("preset = \"bogus\"").is_err());
        assert!(ExperimentConfig::from_toml("[sim.delay]\nmodel = \"warp\"").is_err());
        // SSP protocols run only under the event-driven scheduler
        assert!(ExperimentConfig::from_toml("algorithm = \"ssp\"\nexec_mode = \"threads\"").is_err());
    }

    #[test]
    fn from_toml_ssp_knobs() {
        let cfg = ExperimentConfig::from_toml(
            "algorithm = \"dc-s3gd\"\nstaleness_bound = 2\nworkers = 8",
        )
        .unwrap();
        assert_eq!(cfg.algorithm, Algorithm::DcS3gd);
        assert_eq!(cfg.staleness_bound, 2);
        let json = cfg.to_json().to_string();
        assert!(json.contains("\"staleness_bound\""));
    }

    #[test]
    fn from_toml_comm_section() {
        // default: off, inert
        let cfg = ExperimentConfig::from_toml("workers = 2").unwrap();
        assert!(!cfg.comm.enabled);

        // enable with custom parameters
        let cfg = ExperimentConfig::from_toml(
            "[comm]\nenabled = true\nper_push = 1e-4\nper_mb = 5e-4",
        )
        .unwrap();
        assert!(cfg.comm.enabled);
        assert_eq!(cfg.comm.model.per_push, 1e-4);
        assert_eq!(cfg.comm.model.per_mb, 5e-4);

        // setting a cost parameter activates the model (same semantics as
        // the --comm-per-* CLI flags) ...
        let cfg = ExperimentConfig::from_toml("[comm]\nper_push = 2e-4").unwrap();
        assert!(cfg.comm.enabled);
        // ... but an explicit `enabled` key always wins
        let cfg =
            ExperimentConfig::from_toml("[comm]\nper_push = 2e-4\nenabled = false").unwrap();
        assert!(!cfg.comm.enabled);
        assert_eq!(cfg.comm.model.per_push, 2e-4);

        // presets pull their constants straight from sim::CommModel
        let cfg = ExperimentConfig::from_toml("[comm]\nmodel = \"ethernet\"").unwrap();
        assert!(cfg.comm.enabled);
        assert_eq!(cfg.comm.model, crate::sim::CommModel::ethernet_like());
        let cfg = ExperimentConfig::from_toml("[comm]\nmodel = \"off\"").unwrap();
        assert!(!cfg.comm.enabled);

        // rejected: unknown preset, negative cost, threads-mode comm (only
        // the event-driven scheduler consults the comm model)
        assert!(ExperimentConfig::from_toml("[comm]\nmodel = \"warp\"").is_err());
        assert!(ExperimentConfig::from_toml("[comm]\nper_push = -1.0").is_err());
        assert!(ExperimentConfig::from_toml(
            "exec_mode = \"threads\"\n[comm]\nenabled = true"
        )
        .is_err());

        let json = ExperimentConfig::default().to_json().to_string();
        assert!(json.contains("\"comm_enabled\""));
    }

    #[test]
    fn from_toml_compress_section() {
        use crate::compress::CodecConfig;
        // default: none (pinned bit-identical to the uncompressed path)
        let cfg = ExperimentConfig::from_toml("workers = 2").unwrap();
        assert_eq!(cfg.compress, CodecConfig::None);

        let cfg = ExperimentConfig::from_toml(
            "[compress]\ncodec = \"topk\"\nratio = 0.25",
        )
        .unwrap();
        assert_eq!(cfg.compress, CodecConfig::TopK { ratio: 0.25 });

        let cfg = ExperimentConfig::from_toml("[compress]\ncodec = \"qsgd\"\nbits = 4").unwrap();
        assert_eq!(cfg.compress, CodecConfig::Qsgd { bits: 4 });

        let cfg = ExperimentConfig::from_toml("[compress]\ncodec = \"randk\"").unwrap();
        assert_eq!(cfg.compress, CodecConfig::RandK { ratio: 0.1 }, "default ratio");

        // rejected: bad codec, bad params, and non-composing configs
        assert!(ExperimentConfig::from_toml("[compress]\ncodec = \"warp\"").is_err());
        assert!(
            ExperimentConfig::from_toml("[compress]\ncodec = \"topk\"\nratio = 0.0").is_err()
        );
        assert!(ExperimentConfig::from_toml("[compress]\ncodec = \"qsgd\"\nbits = 1").is_err());
        assert!(ExperimentConfig::from_toml(
            "algorithm = \"ssgd\"\n[compress]\ncodec = \"topk\""
        )
        .is_err());
        assert!(ExperimentConfig::from_toml(
            "[train]\nmomentum = 0.9\n[compress]\ncodec = \"topk\""
        )
        .is_err());
        assert!(ExperimentConfig::from_toml(
            "update_backend = \"xla\"\nshards = 1\n[compress]\ncodec = \"topk\""
        )
        .is_err());
        assert!(ExperimentConfig::from_toml(
            "exec_mode = \"threads\"\n[compress]\ncodec = \"topk\""
        )
        .is_err());
        // resume + compression is legal at the config level since v2
        // checkpoints round-trip the EF residuals; EF-less checkpoints are
        // rejected at load time (ps::checkpoint::check_ef_compat)
        let cfg = ExperimentConfig::from_toml(
            "resume_from = \"ck.bin\"\n[compress]\ncodec = \"topk\"",
        )
        .unwrap();
        assert_eq!(cfg.compress, CodecConfig::TopK { ratio: 0.1 });
        assert_eq!(cfg.resume_from, "ck.bin");

        let json = cfg.to_json().to_string();
        assert!(json.contains("\"compress\""));
        assert!(json.contains("randk"));
    }

    #[test]
    fn from_toml_faults_section() {
        use crate::sim::CrashPolicy;
        // default: off, inert
        let cfg = ExperimentConfig::from_toml("workers = 2").unwrap();
        assert!(!cfg.faults.enabled);

        // enable with custom parameters
        let cfg = ExperimentConfig::from_toml(
            "[faults]\nenabled = true\ncrash_rate = 0.05\nrestart_mean = 2.0\n\
             departure_prob = 0.2\npolicy = \"salvage\"\nseed = 9",
        )
        .unwrap();
        assert!(cfg.faults.enabled);
        assert_eq!(cfg.faults.crash_rate, 0.05);
        assert_eq!(cfg.faults.restart_mean, 2.0);
        assert_eq!(cfg.faults.departure_prob, 0.2);
        assert_eq!(cfg.faults.policy, CrashPolicy::Salvage);
        assert_eq!(cfg.faults.seed, 9);

        // setting any parameter activates the section (same semantics as
        // the --fault-* CLI flags) ...
        let cfg = ExperimentConfig::from_toml("[faults]\ncrash_rate = 0.1").unwrap();
        assert!(cfg.faults.enabled);
        // ... but an explicit `enabled` key always wins
        let cfg =
            ExperimentConfig::from_toml("[faults]\ncrash_rate = 0.1\nenabled = false").unwrap();
        assert!(!cfg.faults.enabled);
        assert_eq!(cfg.faults.crash_rate, 0.1);

        // late join + stragglers
        let cfg = ExperimentConfig::from_toml(
            "workers = 4\n[faults]\nlate_join = 2\nlate_join_by = 5.0\n\
             straggler_rate = 0.02\nstraggler_factor = 3.0\nstraggler_duration = 4.0",
        )
        .unwrap();
        assert_eq!(cfg.faults.late_join, 2);
        assert_eq!(cfg.faults.straggler_factor, 3.0);

        let json = cfg.to_json().to_string();
        assert!(json.contains("\"faults_enabled\""));
        assert!(json.contains("\"fault_policy\""));
    }

    #[test]
    fn from_toml_topology_section() {
        // default: off, inert
        let cfg = ExperimentConfig::from_toml("workers = 2").unwrap();
        assert!(!cfg.topology.enabled);
        assert_eq!(cfg.topology, crate::sim::TopologyConfig::default());

        // enable with custom parameters
        let cfg = ExperimentConfig::from_toml(
            "workers = 8\n[topology]\nenabled = true\nps_nodes = 4\nracks = 2\n\
             rack_per_push = 1e-5\nrack_per_mb = 1e-4\ncross_per_push = 3e-4\n\
             cross_per_mb = 1e-3",
        )
        .unwrap();
        assert!(cfg.topology.enabled);
        assert_eq!(cfg.topology.ps_nodes, 4);
        assert_eq!(cfg.topology.racks, 2);
        assert_eq!(cfg.topology.rack_model.per_push, 1e-5);
        assert_eq!(cfg.topology.cross_model.per_mb, 1e-3);
        assert!(!cfg.topology.hierarchical);

        // setting a parameter activates the section (same semantics as
        // [comm]/[faults]) ...
        let cfg = ExperimentConfig::from_toml("workers = 8\n[topology]\nracks = 2").unwrap();
        assert!(cfg.topology.enabled);
        assert_eq!(cfg.topology.racks, 2);
        // ... but an explicit `enabled` key always wins
        let cfg = ExperimentConfig::from_toml(
            "workers = 8\n[topology]\nracks = 2\nenabled = false",
        )
        .unwrap();
        assert!(!cfg.topology.enabled);
        assert_eq!(cfg.topology.racks, 2);

        // hierarchical aggregation needs the barrier fold
        let cfg = ExperimentConfig::from_toml(
            "algorithm = \"hier-ssgd\"\nworkers = 8\n[topology]\nracks = 2\nhierarchical = true",
        )
        .unwrap();
        assert!(cfg.topology.hierarchical);

        // rejected: bounds, threads-mode topology, topology+comm overlap,
        // hierarchical under an async fold, racks exceeding the fleet
        assert!(ExperimentConfig::from_toml("[topology]\nps_nodes = 0").is_err());
        assert!(ExperimentConfig::from_toml("[topology]\nracks = 0").is_err());
        assert!(ExperimentConfig::from_toml("[topology]\nrack_per_push = -1.0").is_err());
        assert!(ExperimentConfig::from_toml(
            "exec_mode = \"threads\"\n[topology]\nenabled = true"
        )
        .is_err());
        assert!(ExperimentConfig::from_toml(
            "[comm]\nenabled = true\n[topology]\nenabled = true"
        )
        .is_err());
        assert!(ExperimentConfig::from_toml(
            "algorithm = \"asgd\"\nworkers = 4\n[topology]\nracks = 2\nhierarchical = true"
        )
        .is_err());
        assert!(ExperimentConfig::from_toml("workers = 4\n[topology]\nracks = 8").is_err());

        let json = ExperimentConfig::default().to_json().to_string();
        assert!(json.contains("\"topology_enabled\""));
        assert!(json.contains("\"topology_ps_nodes\""));
    }

    #[test]
    fn from_toml_trace_section() {
        // default: off, inert
        let cfg = ExperimentConfig::from_toml("workers = 2").unwrap();
        assert!(!cfg.trace.enabled);
        assert_eq!(cfg.trace, TraceConfig::default());

        // enable with custom parameters
        let cfg = ExperimentConfig::from_toml(
            "[trace]\nenabled = true\nsample_every = 5\nchrome_trace = false",
        )
        .unwrap();
        assert!(cfg.trace.enabled);
        assert_eq!(cfg.trace.sample_every, 5);
        assert!(cfg.trace.events);
        assert!(cfg.trace.profile);
        assert!(!cfg.trace.chrome_trace);

        // setting a parameter activates the section (same semantics as the
        // [comm]/[faults] sections) ...
        let cfg = ExperimentConfig::from_toml("[trace]\nsample_every = 25").unwrap();
        assert!(cfg.trace.enabled);
        assert_eq!(cfg.trace.sample_every, 25);
        // ... but an explicit `enabled` key always wins
        let cfg =
            ExperimentConfig::from_toml("[trace]\nsample_every = 25\nenabled = false").unwrap();
        assert!(!cfg.trace.enabled);
        assert_eq!(cfg.trace.sample_every, 25);

        // rejected: zero cadence, threads-mode tracing (events carry
        // virtual time, so only the event-driven scheduler emits them)
        assert!(ExperimentConfig::from_toml("[trace]\nsample_every = 0").is_err());
        assert!(ExperimentConfig::from_toml(
            "exec_mode = \"threads\"\n[trace]\nenabled = true"
        )
        .is_err());

        let json = cfg.to_json().to_string();
        assert!(json.contains("\"trace_enabled\""));
        assert!(json.contains("\"trace_sample_every\""));
    }

    #[test]
    fn from_toml_serving_section() {
        use crate::sim::{ArrivalKind, ReadMode, ServingConfig};
        // default: off, inert
        let cfg = ExperimentConfig::from_toml("workers = 2").unwrap();
        assert!(!cfg.serving.enabled);
        assert_eq!(cfg.serving, ServingConfig::default());

        // enable with custom parameters
        let cfg = ExperimentConfig::from_toml(
            "[serving]\nenabled = true\npublish_every = 2\nrate = 16.0\n\
             arrival = \"bursty\"\nburst = 8.0\nperiod = 4.0\nbatch = 32\n\
             read_mode = \"locked\"\nseed = 5",
        )
        .unwrap();
        assert!(cfg.serving.enabled);
        assert_eq!(cfg.serving.publish_every, 2);
        assert_eq!(cfg.serving.rate, 16.0);
        assert_eq!(cfg.serving.arrival, ArrivalKind::Bursty);
        assert_eq!(cfg.serving.burst, 8.0);
        assert_eq!(cfg.serving.period, 4.0);
        assert_eq!(cfg.serving.batch, 32);
        assert_eq!(cfg.serving.read_mode, ReadMode::Locked);
        assert_eq!(cfg.serving.seed, 5);

        // setting a parameter activates the section (same semantics as the
        // [comm]/[faults]/[trace] sections) ...
        let cfg = ExperimentConfig::from_toml("[serving]\nrate = 4.0").unwrap();
        assert!(cfg.serving.enabled);
        assert_eq!(cfg.serving.rate, 4.0);
        // ... but an explicit `enabled` key always wins
        let cfg =
            ExperimentConfig::from_toml("[serving]\nrate = 4.0\nenabled = false").unwrap();
        assert!(!cfg.serving.enabled);
        assert_eq!(cfg.serving.rate, 4.0);

        // rejected: bounds, bad enums, threads-mode serving (arrivals live
        // on the virtual clock)
        assert!(ExperimentConfig::from_toml("[serving]\npublish_every = 0").is_err());
        assert!(ExperimentConfig::from_toml("[serving]\nrate = 0.0").is_err());
        assert!(ExperimentConfig::from_toml("[serving]\nburst = 0.5").is_err());
        assert!(ExperimentConfig::from_toml("[serving]\nperiod = 0.0").is_err());
        assert!(ExperimentConfig::from_toml("[serving]\nbatch = 0").is_err());
        assert!(ExperimentConfig::from_toml("[serving]\narrival = \"warp\"").is_err());
        assert!(ExperimentConfig::from_toml("[serving]\nread_mode = \"warp\"").is_err());
        assert!(ExperimentConfig::from_toml(
            "exec_mode = \"threads\"\n[serving]\nenabled = true"
        )
        .is_err());

        let json = cfg.to_json().to_string();
        assert!(json.contains("\"serving_enabled\""));
        assert!(json.contains("\"serving_publish_every\""));
        assert!(json.contains("\"serving_read_mode\""));
    }

    /// Exhaustive rejected-combination matrix: every illegal combination
    /// must fail with its *specific* message, so a refactor can't silently
    /// swap one rejection for another (or let a combination slip through).
    /// The matrix is generated from the manifest (one bounds violation per
    /// bounded knob + every rule's canonical example + the parse-level
    /// cases), so a newly declared knob or rule is covered automatically.
    #[test]
    fn rejected_combination_matrix() {
        let cases = manifest::rejection_cases();
        // the historical floor: the hand-written matrix had 28 entries;
        // the generated one must never silently shrink below it
        assert!(cases.len() >= 28, "matrix shrank to {} cases", cases.len());
        for case in &cases {
            let err = ExperimentConfig::from_toml(&case.toml)
                .expect_err(&format!("config must be rejected: {}", case.toml))
                .to_string();
            assert!(
                err.contains(case.needle),
                "{:?}: error {err:?} lacks {:?}",
                case.toml,
                case.needle
            );
        }
        // pinned messages the matrix must keep covering, whatever the
        // manifest declares them on (guards against a needle being edited
        // away during a refactor)
        for needle in [
            "folds dense gradients",
            "momentum does not compose",
            "native update backend",
            "event-driven scheduler",
            "fault injection runs under the event-driven scheduler",
            "crash_rate must be finite and >= 0",
            "restart_mean must be finite and > 0",
            "departure_prob must be in [0, 1]",
            "straggler_factor must be >= 1",
            "straggler_duration must be finite and > 0",
            "at least one worker must be present at t = 0",
            "late_join_by must be finite and > 0",
            "unknown crash policy",
            "unknown codec",
            "ratio must be in (0, 1]",
            "qsgd bits must be in [3, 16]",
            "workers must be >= 1",
            "sequential SGD requires workers = 1",
            "one of epochs / max_steps must be positive",
            "lr must be positive",
            "shards must be >= 1",
            "jitter must be in [0, 1)",
            "comm per_push/per_mb must be finite",
            "serving workload runs under the event-driven scheduler",
        ] {
            assert!(
                cases.iter().any(|c| c.needle.contains(needle) || needle.contains(c.needle)),
                "pinned needle {needle:?} no longer covered by the matrix"
            );
        }
    }

    #[test]
    fn from_toml_runtime_section() {
        // default: auto (0), SIMD kernels on
        let cfg = ExperimentConfig::from_toml("workers = 2").unwrap();
        assert_eq!(cfg.runtime, RuntimeConfig { threads: 0, simd: true });
        // explicit lane counts
        let cfg = ExperimentConfig::from_toml("[runtime]\nthreads = 1").unwrap();
        assert_eq!(cfg.runtime.threads, 1);
        let cfg = ExperimentConfig::from_toml("[runtime]\nthreads = 6").unwrap();
        assert_eq!(cfg.runtime.threads, 6);
        // scalar reference lane
        let cfg = ExperimentConfig::from_toml("[runtime]\nsimd = false").unwrap();
        assert!(!cfg.runtime.simd);
        assert_eq!(cfg.runtime.threads, 0);
        // absurd lane counts are rejected
        assert!(ExperimentConfig::from_toml("[runtime]\nthreads = 4096").is_err());
        let cfg = ExperimentConfig::from_toml("[runtime]\nthreads = 6").unwrap();
        let json = cfg.to_json().to_string();
        assert!(json.contains("\"runtime_threads\""));
        assert!(json.contains("\"runtime_simd\":true"));
    }

    #[test]
    fn presets_validate() {
        ExperimentConfig::preset_quickstart().validate().unwrap();
        ExperimentConfig::preset_cifar().validate().unwrap();
        ExperimentConfig::preset_imagenet().validate().unwrap();
        ExperimentConfig::preset_lm("lm_small").validate().unwrap();
    }

    #[test]
    fn json_summary_contains_key_fields() {
        let j = ExperimentConfig::preset_cifar().to_json().to_string();
        assert!(j.contains("\"algorithm\""));
        assert!(j.contains("mlp_cifar"));
    }
}
