//! Scenario files: declarative experiment grids over the knob manifest.
//!
//! A scenario is a TOML file with three sections:
//!
//! ```toml
//! [scenario]
//! name = "ssp_spectrum"                   # required; should match the file stem
//! description = "sweep the staleness bound"
//! preset = "quickstart"                   # base config: a preset ...
//! # config = "base.toml"                  # ... XOR a config file (path
//! #                                       #     relative to the scenario file)
//! # skip_invalid = true                   # drop (and record) grid cells the
//! #                                       #     manifest rejects, instead of failing
//!
//! [overrides]                             # applied on top of the base, every case
//! "/workers" = 8
//! "/epochs" = 6
//!
//! [sweep]                                 # one axis per knob; full cross product
//! "/algorithm" = ["ssp", "dc-s3gd"]
//! "/staleness_bound" = [0, 1, 4, 16]
//! ```
//!
//! Knob keys accept both spellings from the manifest: JSON-pointer
//! (`"/train/lr"`) and dotted (`train.lr`). Axes nest in **document order**
//! with the first axis outermost, so the grid order is stable and
//! plot-friendly. Every case is a full [`ExperimentConfig`] built as
//! base → overrides → sweep cell, validated through [`manifest::check`] —
//! exactly the same code path as a TOML or CLI run, which is what makes a
//! `--scenario` run bitwise identical to the equivalent hand-rolled one.
//!
//! [`run_grid`] is the shared bench/example driver: expand, run each case
//! against a shared engine, and emit one JSONL row per case into
//! `runs/bench/<name>.jsonl` (scenario + cell values + the full
//! [`TrainReport`] fields, plus caller extras). `dcasgd validate` pre-flights
//! scenario files (and plain config files) through [`validate_file`].

use crate::config::manifest;
use crate::config::toml::{Doc, Value};
use crate::config::ExperimentConfig;
use crate::metrics::TrainReport;
use crate::util::json::Json;
use anyhow::{bail, Context};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Hard cap on the number of cases one scenario may expand to.
pub const MAX_CASES: usize = 4096;

/// One sweep axis: a knob id and the values it takes.
#[derive(Clone, Debug)]
pub struct Axis {
    /// Knob key as written in the file (pointer or dotted spelling).
    pub key: String,
    pub values: Vec<Value>,
}

/// A parsed scenario file (not yet expanded).
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: String,
    pub description: String,
    /// Base config: a named preset ...
    pub preset: Option<String>,
    /// ... XOR a TOML config file, relative to `dir`.
    pub config: Option<String>,
    /// Drop (and record) grid cells the manifest rejects instead of failing.
    pub skip_invalid: bool,
    /// `(knob key, value)` pairs applied to the base for every case.
    pub overrides: Vec<(String, Value)>,
    /// Sweep axes in document order; the first axis is outermost.
    pub axes: Vec<Axis>,
    /// Directory the scenario was loaded from (resolves `config`).
    pub dir: PathBuf,
}

/// One expanded grid cell: a fully built, validated config.
#[derive(Clone, Debug)]
pub struct Case {
    /// Grid position (stable even when other cells are skipped).
    pub index: usize,
    /// Human label, e.g. `algorithm=ssp staleness_bound=4`.
    pub label: String,
    /// The sweep cell that produced this case, one entry per axis.
    pub cells: Vec<(String, Value)>,
    pub config: ExperimentConfig,
}

/// Result of expanding a scenario into its run grid.
#[derive(Clone, Debug)]
pub struct Expansion {
    pub cases: Vec<Case>,
    /// `(label, rejection)` for cells dropped under `skip_invalid`.
    pub skipped: Vec<(String, String)>,
}

impl Scenario {
    /// Load and parse a scenario file.
    pub fn load(path: &Path) -> anyhow::Result<Scenario> {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading scenario {}", path.display()))?;
        let dir = path.parent().unwrap_or_else(|| Path::new(".")).to_path_buf();
        Self::parse(&src, &dir).with_context(|| format!("scenario {}", path.display()))
    }

    /// Parse scenario TOML; `dir` resolves a relative `config` base path.
    pub fn parse(src: &str, dir: &Path) -> anyhow::Result<Scenario> {
        let doc = Doc::parse(src).map_err(anyhow::Error::from)?;
        Self::from_doc(&doc, dir)
    }

    pub fn from_doc(doc: &Doc, dir: &Path) -> anyhow::Result<Scenario> {
        let mut name = None;
        let mut description = String::new();
        let mut preset = None;
        let mut config = None;
        let mut skip_invalid = false;
        let mut overrides: Vec<(String, Value)> = Vec::new();
        let mut axes: Vec<Axis> = Vec::new();
        // reject two spellings (or duplicates) of the same knob per section
        let mut override_knobs = BTreeMap::new();
        let mut axis_knobs = BTreeMap::new();

        for key in doc.ordered_keys() {
            let val = doc.get(key).expect("key from ordered_keys");
            if let Some(field) = key.strip_prefix("scenario.") {
                match field {
                    "name" => {
                        name = Some(want_str(key, val)?.to_string());
                    }
                    "description" => description = want_str(key, val)?.to_string(),
                    "preset" => preset = Some(want_str(key, val)?.to_string()),
                    "config" => config = Some(want_str(key, val)?.to_string()),
                    "skip_invalid" => {
                        skip_invalid = val
                            .as_bool()
                            .ok_or_else(|| anyhow::anyhow!("{key} must be a boolean"))?;
                    }
                    other => bail!(
                        "unknown [scenario] field {other:?} \
                         (name|description|preset|config|skip_invalid)"
                    ),
                }
            } else if let Some(knob) = key.strip_prefix("overrides.") {
                let (idx, k) = find_knob(knob, "[overrides]")?;
                if let Some(prev) = override_knobs.insert(idx, knob.to_string()) {
                    bail!("[overrides] lists knob {} twice ({prev:?} and {knob:?})", k.id);
                }
                overrides.push((knob.to_string(), val.clone()));
            } else if let Some(knob) = key.strip_prefix("sweep.") {
                let (idx, k) = find_knob(knob, "[sweep]")?;
                if let Some(prev) = axis_knobs.insert(idx, knob.to_string()) {
                    bail!("[sweep] lists knob {} twice ({prev:?} and {knob:?})", k.id);
                }
                let values = match val {
                    Value::Array(items) if !items.is_empty() => items.clone(),
                    Value::Array(_) => bail!("[sweep] axis {knob:?} is empty"),
                    _ => bail!("[sweep] axis {knob:?} must be an array of values"),
                };
                axes.push(Axis { key: knob.to_string(), values });
            } else {
                bail!(
                    "scenario files contain only [scenario], [overrides], and [sweep] \
                     sections (found {key:?})"
                );
            }
        }

        let Some(name) = name else { bail!("missing required [scenario] name") };
        if preset.is_some() && config.is_some() {
            bail!("scenario {name:?} declares both preset and config — pick one base");
        }
        let total: usize = axes.iter().map(|a| a.values.len()).product();
        if total > MAX_CASES {
            bail!("scenario {name:?} expands to {total} cases (cap {MAX_CASES})");
        }
        Ok(Scenario {
            name,
            description,
            preset,
            config,
            skip_invalid,
            overrides,
            axes,
            dir: dir.to_path_buf(),
        })
    }

    /// The base config: preset/config file + `[overrides]`, *not* yet
    /// validated — a sweep cell may complete it; cases validate in
    /// [`Scenario::expand`].
    pub fn base(&self) -> anyhow::Result<ExperimentConfig> {
        let mut cfg = match &self.config {
            Some(rel) => {
                let path = self.dir.join(rel);
                let src = std::fs::read_to_string(&path)
                    .with_context(|| format!("scenario base config {}", path.display()))?;
                let doc = Doc::parse(&src).map_err(anyhow::Error::from)?;
                let mut cfg = ExperimentConfig::base_for_preset(
                    doc.get("preset").and_then(|v| v.as_str()),
                )?;
                manifest::apply_doc(&mut cfg, &doc)?;
                cfg
            }
            None => ExperimentConfig::base_for_preset(self.preset.as_deref())?,
        };
        manifest::apply_pairs(&mut cfg, &self.overrides)
            .with_context(|| format!("scenario {:?} [overrides]", self.name))?;
        Ok(cfg)
    }

    /// Expand the sweep axes into the full run grid (first axis outermost).
    /// Every case is validated; invalid cells fail the expansion unless
    /// `skip_invalid` is set, in which case they are recorded in `skipped`.
    pub fn expand(&self) -> anyhow::Result<Expansion> {
        let base = self.base()?;
        let total: usize = self.axes.iter().map(|a| a.values.len()).product();
        let mut cases = Vec::with_capacity(total);
        let mut skipped = Vec::new();
        for i in 0..total {
            let mut cells = Vec::with_capacity(self.axes.len());
            let mut stride = total;
            for ax in &self.axes {
                stride /= ax.values.len();
                let idx = (i / stride) % ax.values.len();
                cells.push((ax.key.clone(), ax.values[idx].clone()));
            }
            let label = if cells.is_empty() {
                self.name.clone()
            } else {
                cells
                    .iter()
                    .map(|(k, v)| format!("{}={}", short_key(k), fmt_value(v)))
                    .collect::<Vec<_>>()
                    .join(" ")
            };
            let mut cfg = base.clone();
            let built =
                manifest::apply_pairs(&mut cfg, &cells).and_then(|()| cfg.validate());
            match built {
                Ok(()) => cases.push(Case { index: i, label, cells, config: cfg }),
                Err(e) if self.skip_invalid => skipped.push((label, format!("{e:#}"))),
                Err(e) => {
                    return Err(e.context(format!(
                        "scenario {:?} case {i} ({label})",
                        self.name
                    )))
                }
            }
        }
        if cases.is_empty() {
            bail!(
                "scenario {:?}: every case was rejected ({} skipped)",
                self.name,
                skipped.len()
            );
        }
        Ok(Expansion { cases, skipped })
    }
}

fn want_str<'v>(key: &str, v: &'v Value) -> anyhow::Result<&'v str> {
    v.as_str().ok_or_else(|| anyhow::anyhow!("{key} must be a string"))
}

fn find_knob(key: &str, section: &str) -> anyhow::Result<(usize, &'static manifest::Knob)> {
    manifest::find_indexed(key).ok_or_else(|| {
        anyhow::anyhow!("unknown knob {key:?} in {section} (see `dcasgd knobs` for the manifest)")
    })
}

/// Last path segment of a knob key (`/sim/delay/model` → `model`): the
/// short column name used in case labels and JSONL rows.
pub fn short_key(key: &str) -> String {
    let norm = key.trim_start_matches('/').replace('/', ".");
    norm.rsplit('.').next().unwrap_or(&norm).to_string()
}

/// Display form of a TOML value for case labels.
pub fn fmt_value(v: &Value) -> String {
    match v {
        Value::Str(s) => s.clone(),
        Value::Int(i) => i.to_string(),
        Value::Float(f) => format!("{f}"),
        Value::Bool(b) => b.to_string(),
        Value::Array(items) => {
            format!("[{}]", items.iter().map(fmt_value).collect::<Vec<_>>().join(","))
        }
    }
}

/// JSON form of a TOML value for JSONL rows.
pub fn value_to_json(v: &Value) -> Json {
    match v {
        Value::Str(s) => Json::Str(s.clone()),
        Value::Int(i) => Json::Num(*i as f64),
        Value::Float(f) => Json::Num(*f),
        Value::Bool(b) => Json::Bool(*b),
        Value::Array(items) => Json::Arr(items.iter().map(value_to_json).collect()),
    }
}

// --------------------------------------------------------------- locating

/// Locate the committed `scenarios/` corpus: `$DCASGD_SCENARIOS`, else walk
/// up from the current directory looking for `scenarios/README.md` (the
/// same discipline as [`crate::find_artifacts_dir`]).
pub fn find_scenarios_dir() -> Option<PathBuf> {
    if let Ok(p) = std::env::var("DCASGD_SCENARIOS") {
        let p = PathBuf::from(p);
        if p.is_dir() {
            return Some(p);
        }
    }
    let mut dir = std::env::current_dir().ok()?;
    loop {
        let cand = dir.join("scenarios");
        if cand.join("README.md").exists() {
            return Some(cand);
        }
        if !dir.pop() {
            return None;
        }
    }
}

// ------------------------------------------------------ pre-flight checks

/// `dcasgd validate` result for one file.
#[derive(Clone, Debug)]
pub struct FileReport {
    pub path: PathBuf,
    /// One-line description of what validated (`scenario x: N cases`).
    pub summary: String,
    pub errors: Vec<String>,
    pub warnings: Vec<String>,
}

impl FileReport {
    /// Clean under the given strictness (`--strict` promotes warnings).
    pub fn ok(&self, strict: bool) -> bool {
        self.errors.is_empty() && (!strict || self.warnings.is_empty())
    }
}

/// Pre-flight one TOML file: a scenario (any `[scenario]` section) expands
/// and validates every case; anything else validates as a plain config.
pub fn validate_file(path: &Path) -> FileReport {
    let mut rep = FileReport {
        path: path.to_path_buf(),
        summary: String::new(),
        errors: Vec::new(),
        warnings: Vec::new(),
    };
    let src = match std::fs::read_to_string(path) {
        Ok(s) => s,
        Err(e) => {
            rep.errors.push(format!("unreadable: {e}"));
            return rep;
        }
    };
    let doc = match Doc::parse(&src) {
        Ok(d) => d,
        Err(e) => {
            rep.errors.push(e.to_string());
            return rep;
        }
    };
    if doc.keys().any(|k| k.starts_with("scenario.")) {
        let dir = path.parent().unwrap_or_else(|| Path::new("."));
        let sc = match Scenario::from_doc(&doc, dir) {
            Ok(sc) => sc,
            Err(e) => {
                rep.errors.push(format!("{e:#}"));
                return rep;
            }
        };
        let stem = path.file_stem().and_then(|s| s.to_str()).unwrap_or("");
        if sc.name != stem {
            rep.warnings
                .push(format!("scenario name {:?} != file stem {stem:?}", sc.name));
        }
        match sc.expand() {
            Ok(ex) => {
                rep.summary = format!(
                    "scenario {:?}: {} case(s){}",
                    sc.name,
                    ex.cases.len(),
                    if ex.skipped.is_empty() {
                        String::new()
                    } else {
                        format!(", {} skipped", ex.skipped.len())
                    }
                );
                if sc.skip_invalid && ex.skipped.is_empty() {
                    rep.warnings.push(
                        "skip_invalid = true but no case was skipped (drop the flag?)"
                            .to_string(),
                    );
                }
            }
            Err(e) => rep.errors.push(format!("{e:#}")),
        }
    } else {
        match ExperimentConfig::from_toml(&src) {
            Ok(_) => rep.summary = "config".to_string(),
            Err(e) => rep.errors.push(format!("{e:#}")),
        }
    }
    rep
}

/// Expand `validate` arguments into the `.toml` files to check: files pass
/// through, directories contribute their `*.toml` entries (sorted).
pub fn collect_toml_files(paths: &[&str]) -> anyhow::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    for p in paths {
        let path = Path::new(p);
        if path.is_dir() {
            let mut entries: Vec<PathBuf> = std::fs::read_dir(path)
                .with_context(|| format!("listing {p}"))?
                .filter_map(|e| e.ok().map(|e| e.path()))
                .filter(|p| p.extension().and_then(|e| e.to_str()) == Some("toml"))
                .collect();
            entries.sort();
            out.extend(entries);
        } else if path.is_file() {
            out.push(path.to_path_buf());
        } else {
            bail!("no such file or directory: {p}");
        }
    }
    if out.is_empty() {
        bail!("no .toml files to validate under {paths:?}");
    }
    Ok(out)
}

// -------------------------------------------------------- the grid driver

/// One completed grid case: the cell, its config, and the run's report.
pub struct GridRun {
    pub index: usize,
    pub label: String,
    pub cells: Vec<(String, Value)>,
    pub config: ExperimentConfig,
    pub report: TrainReport,
}

/// Run a scenario's whole grid against a shared engine and write one JSONL
/// row per case to `runs/bench/<name>.jsonl` — the shared sweep driver for
/// benches and examples.
///
/// * `tweak` adjusts each case config before the run (scale knobs, coupled
///   parameters the grid cannot express); the config is re-validated after.
/// * `extra` contributes additional JSONL fields per completed case.
///
/// Rows carry `scenario`, `case`, `case_index`, each sweep cell under its
/// [`short_key`], every [`TrainReport::to_json`] field, then the extras.
pub fn run_grid<T, X>(
    sc: &Scenario,
    engine: &crate::runtime::EngineHandle,
    artifacts: &Path,
    mut tweak: T,
    mut extra: X,
) -> anyhow::Result<Vec<GridRun>>
where
    T: FnMut(&mut ExperimentConfig, &Case) -> anyhow::Result<()>,
    X: FnMut(&Case, &ExperimentConfig, &TrainReport) -> Vec<(String, Json)>,
{
    use std::io::Write;
    let ex = sc.expand()?;
    for (label, why) in &ex.skipped {
        eprintln!("[skip] {label}: {why}");
    }
    let path = crate::bench::bench_out_dir().join(format!("{}.jsonl", sc.name));
    let mut out = std::io::BufWriter::new(
        std::fs::File::create(&path).with_context(|| format!("creating {}", path.display()))?,
    );
    let mut runs = Vec::with_capacity(ex.cases.len());
    for case in ex.cases {
        let mut cfg = case.config.clone();
        tweak(&mut cfg, &case)?;
        cfg.validate()
            .with_context(|| format!("case {} after tweak", case.label))?;
        let t0 = std::time::Instant::now();
        let report = crate::coordinator::Trainer::with_engine(
            cfg.clone(),
            engine.clone(),
            artifacts,
        )
        .and_then(|t| t.run())
        .with_context(|| format!("case {} failed", case.label))?;
        eprintln!(
            "[case] {}: err={:.2}% loss={:.4} time(sim)={:.1} wall={:.1}s",
            case.label,
            report.final_test_error * 100.0,
            report.final_train_loss,
            report.total_time,
            t0.elapsed().as_secs_f64()
        );
        let mut row = match report.to_json() {
            Json::Obj(m) => m,
            _ => BTreeMap::new(),
        };
        row.insert("scenario".to_string(), Json::Str(sc.name.clone()));
        row.insert("case".to_string(), Json::Str(case.label.clone()));
        row.insert("case_index".to_string(), Json::Num(case.index as f64));
        for (key, v) in &case.cells {
            row.insert(short_key(key), value_to_json(v));
        }
        for (k, v) in extra(&case, &cfg, &report) {
            row.insert(k, v);
        }
        writeln!(out, "{}", Json::Obj(row)).context("jsonl write")?;
        runs.push(GridRun {
            index: case.index,
            label: case.label,
            cells: case.cells,
            config: cfg,
            report,
        });
    }
    drop(out);
    eprintln!("rows: {}", path.display());
    Ok(runs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Algorithm;

    fn sc(src: &str) -> Scenario {
        Scenario::parse(src, Path::new(".")).unwrap()
    }

    #[test]
    fn parses_and_expands_a_grid_first_axis_outermost() {
        let s = sc(r#"
            [scenario]
            name = "demo"
            description = "two axes"
            preset = "quickstart"
            [overrides]
            "/workers" = 8
            [sweep]
            "/algorithm" = ["asgd", "dc-asgd-a"]
            "/train/lambda0" = [0.25, 1.0, 4.0]
        "#);
        assert_eq!(s.axes.len(), 2);
        let ex = s.expand().unwrap();
        assert_eq!(ex.cases.len(), 6);
        assert!(ex.skipped.is_empty());
        // first axis outermost: algorithm changes every 3 cases
        for (i, case) in ex.cases.iter().enumerate() {
            assert_eq!(case.index, i);
            let want_algo =
                if i < 3 { Algorithm::Asgd } else { Algorithm::DcAsgdAdaptive };
            assert_eq!(case.config.algorithm, want_algo, "case {i}");
            assert_eq!(case.config.workers, 8);
            let lam = [0.25, 1.0, 4.0][i % 3];
            assert_eq!(case.config.lambda0, lam);
        }
        assert_eq!(ex.cases[0].label, "algorithm=asgd lambda0=0.25");
    }

    #[test]
    fn overrides_accept_both_spellings_and_axes_beat_overrides() {
        let s = sc(r#"
            [scenario]
            name = "demo"
            [overrides]
            workers = 4
            "/train/lambda0" = 9.0
            [sweep]
            "/train/lambda0" = [1.0, 2.0]
        "#);
        let ex = s.expand().unwrap();
        assert_eq!(ex.cases.len(), 2);
        assert_eq!(ex.cases[0].config.workers, 4);
        // the swept knob wins over its override
        assert_eq!(ex.cases[0].config.lambda0, 1.0);
        assert_eq!(ex.cases[1].config.lambda0, 2.0);
    }

    #[test]
    fn skip_invalid_records_rejections_with_pinned_messages() {
        let s = sc(r#"
            [scenario]
            name = "demo"
            skip_invalid = true
            [overrides]
            "/compress/codec" = "topk@0.1"
            [sweep]
            "/algorithm" = ["asgd", "ssgd"]
        "#);
        let ex = s.expand().unwrap();
        assert_eq!(ex.cases.len(), 1);
        assert_eq!(ex.cases[0].config.algorithm, Algorithm::Asgd);
        assert_eq!(ex.skipped.len(), 1);
        assert!(ex.skipped[0].1.contains("folds dense gradients"), "{}", ex.skipped[0].1);
        // without the flag, the same grid is an error carrying the case label
        let strict = sc(r#"
            [scenario]
            name = "demo"
            [overrides]
            "/compress/codec" = "topk@0.1"
            [sweep]
            "/algorithm" = ["asgd", "ssgd"]
        "#);
        let err = format!("{:#}", strict.expand().unwrap_err());
        assert!(err.contains("algorithm=ssgd"), "{err}");
    }

    #[test]
    fn empty_sweep_means_one_case() {
        let s = sc("[scenario]\nname = \"solo\"\n[overrides]\n\"/epochs\" = 1");
        let ex = s.expand().unwrap();
        assert_eq!(ex.cases.len(), 1);
        assert_eq!(ex.cases[0].label, "solo");
        assert_eq!(ex.cases[0].config.epochs, 1);
    }

    #[test]
    fn bad_files_are_rejected_with_useful_messages() {
        let cases: &[(&str, &str)] = &[
            ("[overrides]\n\"/workers\" = 4", "missing required [scenario] name"),
            (
                "[scenario]\nname = \"x\"\npreset = \"cifar\"\nconfig = \"b.toml\"",
                "both preset and config",
            ),
            ("[scenario]\nname = \"x\"\n[overrides]\n\"/bogus\" = 1", "unknown knob"),
            ("[scenario]\nname = \"x\"\n[sweep]\n\"/workers\" = 4", "must be an array"),
            ("[scenario]\nname = \"x\"\n[sweep]\n\"/workers\" = []", "is empty"),
            ("[scenario]\nname = \"x\"\nbogus = 1", "unknown [scenario] field"),
            ("[scenario]\nname = \"x\"\n[other]\nkey = 1", "only [scenario]"),
            (
                "[scenario]\nname = \"x\"\n[sweep]\n\"/workers\" = [1]\nworkers = [2]",
                "twice",
            ),
            ("[scenario]\nname = \"x\"\npreset = \"bogus\"", "unknown preset"),
        ];
        for (src, needle) in cases {
            let err = Scenario::parse(src, Path::new("."))
                .map(|s| s.expand().map(|_| ()))
                .and_then(|r| r)
                .expect_err(&format!("must reject: {src}"));
            let msg = format!("{err:#}");
            assert!(msg.contains(needle), "{src:?}: {msg:?} lacks {needle:?}");
        }
    }

    #[test]
    fn config_file_base_resolves_relative_to_scenario_dir() {
        let dir = std::env::temp_dir().join(format!("dcasgd_sc_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("base.toml"), "workers = 6\n[train]\nlambda0 = 1.0\n")
            .unwrap();
        let src = r#"
            [scenario]
            name = "filebase"
            config = "base.toml"
            [overrides]
            "/train/lambda0" = 2.0
        "#;
        let s = Scenario::parse(src, &dir).unwrap();
        let ex = s.expand().unwrap();
        assert_eq!(ex.cases[0].config.workers, 6);
        // scenario override beats the TOML base
        assert_eq!(ex.cases[0].config.lambda0, 2.0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn validate_file_reports_scenarios_and_configs() {
        let dir = std::env::temp_dir().join(format!("dcasgd_vf_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let ok = dir.join("grid.toml");
        std::fs::write(
            &ok,
            "[scenario]\nname = \"grid\"\n[sweep]\n\"/workers\" = [2, 4]\n",
        )
        .unwrap();
        let rep = validate_file(&ok);
        assert!(rep.ok(true), "{:?} {:?}", rep.errors, rep.warnings);
        assert!(rep.summary.contains("2 case(s)"), "{}", rep.summary);

        // name/stem mismatch is a warning: strict rejects, lenient accepts
        let misnamed = dir.join("other.toml");
        std::fs::write(
            &misnamed,
            "[scenario]\nname = \"grid\"\n[sweep]\n\"/workers\" = [2]\n",
        )
        .unwrap();
        let rep = validate_file(&misnamed);
        assert!(rep.ok(false) && !rep.ok(true));

        // a plain config validates through the manifest path
        let cfg = dir.join("plain.toml");
        std::fs::write(&cfg, "workers = 4\n").unwrap();
        assert!(validate_file(&cfg).ok(true));
        let bad = dir.join("bad.toml");
        std::fs::write(&bad, "workers = 0\n").unwrap();
        let rep = validate_file(&bad);
        assert!(!rep.ok(false));
        assert!(rep.errors[0].contains("workers must be >= 1"), "{:?}", rep.errors);

        let files = collect_toml_files(&[dir.to_str().unwrap()]).unwrap();
        assert_eq!(files.len(), 4);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn short_keys_and_value_formatting() {
        assert_eq!(short_key("/sim/delay/model"), "model");
        assert_eq!(short_key("train.lambda0"), "lambda0");
        assert_eq!(short_key("/workers"), "workers");
        assert_eq!(fmt_value(&Value::Str("topk@0.1".into())), "topk@0.1");
        assert_eq!(fmt_value(&Value::Float(0.25)), "0.25");
        assert_eq!(
            fmt_value(&Value::Array(vec![Value::Int(1), Value::Int(2)])),
            "[1,2]"
        );
        assert_eq!(value_to_json(&Value::Bool(true)), Json::Bool(true));
    }
}
