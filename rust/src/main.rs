//! `dcasgd` — launcher CLI for the DC-ASGD training framework.
//!
//! Subcommands:
//!   train   run one experiment (preset/config file + flag overrides)
//!   sweep   run an algorithm x workers grid and print a paper-style table
//!   info    list AOT artifacts and their shapes
//!
//! Examples:
//!   dcasgd train --preset quickstart --algo dc-asgd-a --workers 8
//!   dcasgd train --config configs/cifar.toml --algo asgd
//!   dcasgd sweep --preset cifar --algos asgd,dc-asgd-a --workers 4,8
//!   dcasgd info

use dc_asgd::bench::Table;
use dc_asgd::config::{Algorithm, ExecMode, ExperimentConfig, UpdateBackend};
use dc_asgd::coordinator::Trainer;
use dc_asgd::runtime::Manifest;
use dc_asgd::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let code = match args.subcommand() {
        Some("train") => cmd_train(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("eval") => cmd_eval(&args),
        Some("info") => cmd_info(&args),
        Some(other) => {
            eprintln!("unknown subcommand {other:?}");
            usage();
            2
        }
        None => {
            usage();
            2
        }
    };
    std::process::exit(code);
}

fn usage() {
    eprintln!(
        "usage: dcasgd <train|sweep|eval|info> [options]\n\
         common options:\n\
           --preset quickstart|cifar|imagenet|lm   base config\n\
           --config PATH                           TOML config file\n\
           --algo sgd|ssgd|dc-ssgd|asgd|dc-asgd-c|dc-asgd-a|ssp|dc-s3gd\n\
           --workers N          --epochs N         --max-steps N\n\
           --lr F               --lambda0 F        --ms-momentum F\n\
           --momentum F         --seed N           --shards N\n\
           --staleness-bound N  (SSP/DC-S3GD: max local-step drift)\n\
           --mode sim|threads   --backend native|xla\n\
           --threads N          (compute-pool lanes; 0 = auto, 1 = serial)\n\
           --simd true|false    (chunked-SIMD kernels; false = scalar reference)\n\
           --train-size N       --test-size N      --out DIR\n\
           --comm               (charge push/pull transfer time in the DES)\n\
           --comm-per-push F    --comm-per-mb F    (seconds, seconds/MB)\n\
           --compress none|topk|randk|qsgd         gradient codec (+ error feedback)\n\
           --topk-ratio F       (topk/randk kept fraction, default 0.1)\n\
           --quant-bits N       (qsgd bits per element, default 8; 32 = exact)\n\
           --faults             (inject worker crashes/restarts into the DES)\n\
           --fault-crash-rate F --fault-restart-mean F --fault-departure-prob F\n\
           --fault-straggler-rate F --fault-straggler-factor F --fault-straggler-duration F\n\
           --fault-late-join N  --fault-late-join-by F\n\
           --fault-policy drop|salvage             in-flight gradient on crash\n\
           --fault-seed N       (0 = derive from --seed)\n\
           --tag NAME           --verbose\n\
         sweep options:\n\
           --algos a,b,c        --workers-list 1,4,8"
    );
}

fn build_config(args: &Args) -> anyhow::Result<ExperimentConfig> {
    let mut cfg = if let Some(path) = args.str_opt("config") {
        ExperimentConfig::from_file(std::path::Path::new(&path))?
    } else {
        match args.str_or("preset", "quickstart").as_str() {
            "quickstart" => ExperimentConfig::preset_quickstart(),
            "cifar" => ExperimentConfig::preset_cifar(),
            "imagenet" => ExperimentConfig::preset_imagenet(),
            "lm" => ExperimentConfig::preset_lm("lm_medium"),
            other => anyhow::bail!("unknown preset {other:?}"),
        }
    };
    if let Some(a) = args.str_opt("algo") {
        cfg.algorithm = Algorithm::parse(&a)?;
    }
    if let Some(m) = args.str_opt("model") {
        cfg.model = m;
    }
    if let Some(w) = args.usize_opt("workers")? {
        cfg.workers = w;
        if cfg.algorithm == Algorithm::SequentialSgd && w > 1 {
            cfg.algorithm = Algorithm::Asgd;
        }
    }
    if cfg.algorithm == Algorithm::SequentialSgd {
        cfg.workers = 1;
    }
    if let Some(e) = args.usize_opt("epochs")? {
        cfg.epochs = e;
    }
    if let Some(s) = args.usize_opt("max-steps")? {
        cfg.max_steps = s;
    }
    if let Some(v) = args.f64_opt("lr")? {
        cfg.lr.base = v;
    }
    if let Some(v) = args.f64_opt("lambda0")? {
        cfg.lambda0 = v;
    }
    if let Some(v) = args.usize_opt("staleness-bound")? {
        cfg.staleness_bound = v;
    }
    if let Some(v) = args.f64_opt("ms-momentum")? {
        cfg.ms_momentum = v;
    }
    if let Some(v) = args.f64_opt("momentum")? {
        cfg.momentum = v;
    }
    if let Some(v) = args.usize_opt("seed")? {
        cfg.seed = v as u64;
    }
    if let Some(v) = args.usize_opt("shards")? {
        cfg.shards = v;
    }
    if let Some(v) = args.usize_opt("threads")? {
        cfg.runtime.threads = v;
    }
    if let Some(v) = args.str_opt("simd") {
        cfg.runtime.simd = !(v == "false" || v == "0");
    }
    if let Some(v) = args.usize_opt("train-size")? {
        cfg.train_size = v;
    }
    if let Some(v) = args.usize_opt("test-size")? {
        cfg.test_size = v;
    }
    if let Some(v) = args.str_opt("mode") {
        cfg.exec_mode = match v.as_str() {
            "sim" => ExecMode::SimulatedTime,
            "threads" => ExecMode::Threads,
            other => anyhow::bail!("unknown mode {other:?}"),
        };
    }
    if let Some(v) = args.str_opt("backend") {
        cfg.update_backend = match v.as_str() {
            "native" => UpdateBackend::Native,
            "xla" => UpdateBackend::Xla,
            other => anyhow::bail!("unknown backend {other:?}"),
        };
    }
    if args.flag("comm") {
        cfg.comm.enabled = true;
    }
    if let Some(v) = args.f64_opt("comm-per-push")? {
        cfg.comm.model.per_push = v;
        cfg.comm.enabled = true;
    }
    if let Some(v) = args.f64_opt("comm-per-mb")? {
        cfg.comm.model.per_mb = v;
        cfg.comm.enabled = true;
    }
    // fault injection: --faults enables the defaults; any --fault-* knob
    // both sets its value and enables the section (like --comm-per-*)
    if args.flag("faults") {
        cfg.faults.enabled = true;
    }
    if let Some(v) = args.f64_opt("fault-crash-rate")? {
        cfg.faults.crash_rate = v;
        cfg.faults.enabled = true;
    }
    if let Some(v) = args.f64_opt("fault-restart-mean")? {
        cfg.faults.restart_mean = v;
        cfg.faults.enabled = true;
    }
    if let Some(v) = args.f64_opt("fault-departure-prob")? {
        cfg.faults.departure_prob = v;
        cfg.faults.enabled = true;
    }
    if let Some(v) = args.f64_opt("fault-straggler-rate")? {
        cfg.faults.straggler_rate = v;
        cfg.faults.enabled = true;
    }
    if let Some(v) = args.f64_opt("fault-straggler-factor")? {
        cfg.faults.straggler_factor = v;
        cfg.faults.enabled = true;
    }
    if let Some(v) = args.f64_opt("fault-straggler-duration")? {
        cfg.faults.straggler_duration = v;
        cfg.faults.enabled = true;
    }
    if let Some(v) = args.usize_opt("fault-late-join")? {
        cfg.faults.late_join = v;
        cfg.faults.enabled = true;
    }
    if let Some(v) = args.f64_opt("fault-late-join-by")? {
        cfg.faults.late_join_by = v;
        cfg.faults.enabled = true;
    }
    if let Some(v) = args.str_opt("fault-policy") {
        cfg.faults.policy = dc_asgd::sim::CrashPolicy::parse(&v)?;
        cfg.faults.enabled = true;
    }
    if let Some(v) = args.usize_opt("fault-seed")? {
        cfg.faults.seed = v as u64;
        cfg.faults.enabled = true;
    }
    // gradient compression: --compress picks the codec; the knob flags
    // refine whichever codec is selected (here or in the config file)
    let topk_ratio = args.f64_opt("topk-ratio")?;
    // checked conversion: a wrapping `as u32` could alias an out-of-range
    // value onto a valid bit width before validation sees it
    let quant_bits = match args.usize_opt("quant-bits")? {
        Some(b) => Some(
            u32::try_from(b).map_err(|_| anyhow::anyhow!("--quant-bits {b} out of range"))?,
        ),
        None => None,
    };
    use dc_asgd::compress::CodecConfig;
    if let Some(c) = args.str_opt("compress") {
        // knob fallbacks inherit from whatever the config file selected,
        // so `--config exp.toml --compress randk` keeps a tuned ratio
        // instead of silently reverting to the built-in defaults
        let cur_ratio = match cfg.compress {
            CodecConfig::TopK { ratio } | CodecConfig::RandK { ratio } => ratio,
            _ => 0.1,
        };
        let cur_bits = match cfg.compress {
            CodecConfig::Qsgd { bits } => bits,
            _ => 8,
        };
        cfg.compress = CodecConfig::parse(
            &c,
            topk_ratio.unwrap_or(cur_ratio),
            quant_bits.unwrap_or(cur_bits),
        )?;
    } else {
        if let Some(r) = topk_ratio {
            if let CodecConfig::TopK { ratio } | CodecConfig::RandK { ratio } = &mut cfg.compress
            {
                *ratio = r;
            }
        }
        if let Some(b) = quant_bits {
            if let CodecConfig::Qsgd { bits } = &mut cfg.compress {
                *bits = b;
            }
        }
    }
    if let Some(v) = args.str_opt("out") {
        cfg.out_dir = v;
    }
    if let Some(v) = args.str_opt("save-checkpoint") {
        cfg.checkpoint_out = v;
    }
    if let Some(v) = args.str_opt("resume") {
        cfg.resume_from = v;
    }
    if let Some(v) = args.str_opt("tag") {
        cfg.tag = v;
    }
    cfg.verbose = cfg.verbose || args.flag("verbose");
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_train(args: &Args) -> i32 {
    let cfg = match build_config(args).and_then(|c| {
        args.finish()?;
        Ok(c)
    }) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    eprintln!(
        "training {} with {} (M={}, {} mode, backend {:?})",
        cfg.model,
        cfg.algorithm,
        cfg.workers,
        match cfg.exec_mode {
            ExecMode::SimulatedTime => "simulated-time",
            ExecMode::Threads => "threaded",
        },
        cfg.update_backend,
    );
    match Trainer::new(cfg).and_then(|t| t.run()) {
        Ok(report) => {
            println!(
                "steps={} passes={:.2} time={:.1}s wall={:.1}s\n\
                 final train loss {:.4} | test loss {:.4} | test error {:.2}% (best {:.2}%)\n\
                 staleness mean {:.2} p99 {:.0} max {}",
                report.total_steps,
                report.passes,
                report.total_time,
                report.wall_secs,
                report.final_train_loss,
                report.final_test_loss,
                report.final_test_error * 100.0,
                report.best_test_error * 100.0,
                report.staleness_mean,
                report.staleness_p99,
                report.staleness_max,
            );
            if report.faults != dc_asgd::sim::FaultStats::default() {
                let f = report.faults;
                println!(
                    "faults: crashes={} restarts={} departures={} late_joins={} \
                     dropped={} salvaged={} straggles={}",
                    f.crashes,
                    f.restarts,
                    f.departures,
                    f.late_joins,
                    f.dropped_inflight,
                    f.salvaged_inflight,
                    f.straggle_events,
                );
            }
            0
        }
        Err(e) => {
            eprintln!("training failed: {e:#}");
            1
        }
    }
}

fn cmd_sweep(args: &Args) -> i32 {
    let base = match build_config(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    let algos = args.str_or("algos", "asgd,ssgd,dc-asgd-c,dc-asgd-a");
    let workers = match args.usize_list_or("workers-list", &[base.workers]) {
        Ok(w) => w,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    if let Err(e) = args.finish() {
        eprintln!("error: {e}");
        return 2;
    }
    let mut table = Table::new(&["# workers", "algorithm", "error(%)", "time(s)", "stale(mean)"]);
    for &m in &workers {
        for algo_name in algos.split(',') {
            let algo = match Algorithm::parse(algo_name.trim()) {
                Ok(a) => a,
                Err(e) => {
                    eprintln!("error: {e}");
                    return 2;
                }
            };
            let mut cfg = base.clone();
            cfg.algorithm = algo;
            cfg.workers = if algo == Algorithm::SequentialSgd { 1 } else { m };
            eprintln!("[sweep] {} M={} ...", algo, cfg.workers);
            match Trainer::new(cfg).and_then(|t| t.run()) {
                Ok(r) => table.row(&[
                    m.to_string(),
                    algo.name().into(),
                    format!("{:.2}", r.final_test_error * 100.0),
                    format!("{:.1}", r.total_time),
                    format!("{:.2}", r.staleness_mean),
                ]),
                Err(e) => {
                    eprintln!("sweep case failed: {e:#}");
                    return 1;
                }
            }
        }
    }
    table.print();
    0
}

fn cmd_eval(args: &Args) -> i32 {
    // evaluate a checkpointed model on the test split of its dataset
    let run = || -> anyhow::Result<()> {
        let path = args.str_req("checkpoint")?;
        let cfg = build_config(args)?;
        args.finish()?;
        let ck = dc_asgd::ps::Checkpoint::load(std::path::Path::new(&path))?;
        let artifacts = dc_asgd::find_artifacts_dir()
            .ok_or_else(|| anyhow::anyhow!("artifacts/manifest.json not found"))?;
        let engine = dc_asgd::runtime::start_engine(&artifacts, &ck.model, false)?;
        let entry = engine.entry().clone();
        anyhow::ensure!(
            ck.w.len() == entry.n_padded,
            "checkpoint n={} != artifact n_padded={}",
            ck.w.len(),
            entry.n_padded
        );
        let test = dc_asgd::data::build_dataset(
            &cfg.dataset,
            entry.feature_kind(),
            entry.classes,
            false,
            cfg.test_size,
            cfg.seed,
        );
        let (loss, err) = dc_asgd::eval::evaluate(&engine, &ck.w, test.as_ref(), cfg.eval_batches)?;
        println!(
            "checkpoint {path}: model={} algo={} version={} samples={}\n\
             test loss {loss:.4} | test error {:.2}%",
            ck.model,
            ck.algorithm,
            ck.version,
            ck.samples,
            err * 100.0
        );
        engine.shutdown();
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

fn cmd_info(args: &Args) -> i32 {
    if let Err(e) = args.finish() {
        eprintln!("error: {e}");
        return 2;
    }
    let dir = match dc_asgd::find_artifacts_dir() {
        Some(d) => d,
        None => {
            eprintln!("artifacts/manifest.json not found — run `make artifacts`");
            return 1;
        }
    };
    match Manifest::load(&dir) {
        Ok(m) => {
            println!("artifacts: {} (manifest v{})", dir.display(), m.version);
            let mut t = Table::new(&["model", "kind", "params", "padded", "batch", "x shape", "updates"]);
            for e in &m.models {
                t.row(&[
                    e.name.clone(),
                    e.kind.clone(),
                    e.n_params.to_string(),
                    e.n_padded.to_string(),
                    e.batch.to_string(),
                    format!("{:?}", e.x_shape),
                    if e.files.contains_key("dc") { "yes".into() } else { "-".into() },
                ]);
            }
            t.print();
            0
        }
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}
