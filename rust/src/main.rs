//! `dcasgd` — launcher CLI for the DC-ASGD training framework.
//!
//! Subcommands:
//!   train     run one experiment (preset/config/scenario + flag overrides)
//!   sweep     run an algorithm x workers grid and print a paper-style table
//!   validate  pre-flight scenario/config files against the knob manifest
//!   knobs     print the knob manifest (ids, bounds, defaults, rules)
//!   info      list AOT artifacts and their shapes
//!   report    digest the written artifacts of a run directory
//!
//! Examples:
//!   dcasgd train --preset quickstart --algo dc-asgd-a --workers 8
//!   dcasgd train --scenario scenarios/fig5_lambda.toml --case 3
//!   dcasgd sweep --preset cifar --algos asgd,dc-asgd-a --workers 4,8
//!   dcasgd validate scenarios/ --strict
//!   dcasgd report runs/
//!
//! Precedence: CLI flags > scenario overrides/sweep cell > TOML/preset base
//! > built-in defaults — every layer goes through the same manifest setters.

use dc_asgd::bench::Table;
use dc_asgd::config::{manifest, Algorithm, ExecMode, ExperimentConfig};
use dc_asgd::coordinator::Trainer;
use dc_asgd::runtime::Manifest;
use dc_asgd::scenario::{collect_toml_files, validate_file, Scenario};
use dc_asgd::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let code = match args.subcommand() {
        Some("train") => cmd_train(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("eval") => cmd_eval(&args),
        Some("validate") => cmd_validate(&args),
        Some("knobs") => cmd_knobs(&args),
        Some("info") => cmd_info(&args),
        Some("report") => cmd_report(&args),
        Some(other) => {
            eprintln!("unknown subcommand {other:?}");
            usage();
            2
        }
        None => {
            usage();
            2
        }
    };
    std::process::exit(code);
}

fn usage() {
    eprintln!(
        "usage: dcasgd <train|sweep|eval|validate|knobs|info|report> [options]\n\
         common options:\n\
           --preset quickstart|cifar|imagenet|lm   base config\n\
           --config PATH                           TOML config file\n\
           --scenario PATH      --case N           run one expanded scenario case\n\
           --algo sgd|ssgd|dc-ssgd|asgd|dc-asgd-c|dc-asgd-a|ssp|dc-s3gd\n\
           --workers N          --epochs N         --max-steps N\n\
           --lr F               --lambda0 F        --ms-momentum F\n\
           --momentum F         --seed N           --shards N\n\
           --staleness-bound N  (SSP/DC-S3GD: max local-step drift)\n\
           --mode sim|threads   --backend native|xla\n\
           --threads N          (compute-pool lanes; 0 = auto, 1 = serial)\n\
           --simd true|false    (chunked-SIMD kernels; false = scalar reference)\n\
           --train-size N       --test-size N      --out DIR\n\
           --comm               (charge push/pull transfer time in the DES)\n\
           --comm-per-push F    --comm-per-mb F    (seconds, seconds/MB)\n\
           --compress none|topk|randk|qsgd         gradient codec (+ error feedback)\n\
           --topk-ratio F       (topk/randk kept fraction, default 0.1)\n\
           --quant-bits N       (qsgd bits per element, default 8; 32 = exact)\n\
           --faults             (inject worker crashes/restarts into the DES)\n\
           --fault-crash-rate F --fault-restart-mean F --fault-departure-prob F\n\
           --fault-straggler-rate F --fault-straggler-factor F --fault-straggler-duration F\n\
           --fault-late-join N  --fault-late-join-by F\n\
           --fault-policy drop|salvage             in-flight gradient on crash\n\
           --fault-seed N       (0 = derive from --seed)\n\
           --trace              (record run-trace artifacts: events, profile, telemetry)\n\
           --trace-sample-every N  telemetry cadence in steps (default 10)\n\
           --trace-events true|false  --trace-profile true|false  --trace-chrome true|false\n\
           --tag NAME           --verbose\n\
         sweep options:\n\
           --algos a,b,c        --workers-list 1,4,8\n\
         validate: dcasgd validate [PATH ...] [--strict]\n\
           pre-flights scenario/config TOML (default: the scenarios/ corpus);\n\
           --strict also fails on warnings (CI mode)\n\
         report: dcasgd report RUN_DIR\n\
           digest the written run artifacts (summary, profile, trace, telemetry)\n\
         knobs: print the full knob manifest and cross-knob rules"
    );
}

/// Resolve the base config (scenario case XOR config file XOR preset),
/// overlay CLI flags through the knob manifest, validate. Precedence:
/// CLI > scenario override/cell > TOML/preset base > default.
fn build_config(args: &Args) -> anyhow::Result<ExperimentConfig> {
    let mut cfg = if let Some(path) = args.str_opt("scenario") {
        if args.str_opt("config").is_some() || args.str_opt("preset").is_some() {
            anyhow::bail!("--scenario already carries a base config; drop --config/--preset");
        }
        let sc = Scenario::load(std::path::Path::new(&path))?;
        let ex = sc.expand()?;
        let want = args.usize_opt("case")?.unwrap_or(0);
        let case = ex.cases.iter().find(|c| c.index == want).ok_or_else(|| {
            anyhow::anyhow!(
                "scenario {:?} has no runnable case {want} ({} of its grid cells run; \
                 `dcasgd validate {path}` lists the expansion)",
                sc.name,
                ex.cases.len()
            )
        })?;
        eprintln!("[scenario] {} case {}: {}", sc.name, case.index, case.label);
        case.config.clone()
    } else {
        if args.usize_opt("case")?.is_some() {
            anyhow::bail!("--case requires --scenario");
        }
        if let Some(path) = args.str_opt("config") {
            ExperimentConfig::from_file(std::path::Path::new(&path))?
        } else {
            ExperimentConfig::base_for_preset(Some(&args.str_or("preset", "quickstart")))?
        }
    };
    manifest::overlay_cli(&mut cfg, args)?;
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_validate(args: &Args) -> i32 {
    let strict = args.flag("strict");
    if let Err(e) = args.finish() {
        eprintln!("error: {e}");
        return 2;
    }
    let given: Vec<&str> = args.positional()[1..].iter().map(|s| s.as_str()).collect();
    let corpus;
    let paths: Vec<&str> = if given.is_empty() {
        match dc_asgd::scenario::find_scenarios_dir() {
            Some(d) => {
                corpus = d;
                vec![corpus.to_str().unwrap_or("scenarios")]
            }
            None => {
                eprintln!("error: no paths given and no scenarios/ corpus found");
                return 2;
            }
        }
    } else {
        given
    };
    let files = match collect_toml_files(&paths) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 2;
        }
    };
    let mut failed = 0usize;
    for f in &files {
        let rep = validate_file(f);
        let status = if !rep.errors.is_empty() {
            "FAIL"
        } else if !rep.warnings.is_empty() {
            "warn"
        } else {
            "ok"
        };
        println!("{status:>4}  {}  {}", rep.path.display(), rep.summary);
        for w in &rep.warnings {
            println!("      warning: {w}");
        }
        for e in &rep.errors {
            println!("      error: {e}");
        }
        if !rep.ok(strict) {
            failed += 1;
        }
    }
    if failed > 0 {
        eprintln!(
            "{failed}/{} file(s) failed{}",
            files.len(),
            if strict { " (strict)" } else { "" }
        );
        1
    } else {
        0
    }
}

fn cmd_knobs(args: &Args) -> i32 {
    if let Err(e) = args.finish() {
        eprintln!("error: {e}");
        return 2;
    }
    let mut t = Table::new(&["id", "type", "bounds", "default", "cli", "help"]);
    for k in manifest::knobs() {
        t.row(&[
            k.id.to_string(),
            k.ty.name().to_string(),
            k.bounds.map(|b| b.describe()).unwrap_or_else(|| "-".into()),
            k.default.to_string(),
            k.cli.map(|c| format!("--{c}")).unwrap_or_else(|| "-".into()),
            k.help.to_string(),
        ]);
    }
    t.print();
    println!("\ncross-knob rules (rejection message fragments are pinned):");
    for r in manifest::rules() {
        println!("  {:<28} {}", r.id, r.needle);
    }
    0
}

fn cmd_train(args: &Args) -> i32 {
    let cfg = match build_config(args).and_then(|c| {
        args.finish()?;
        Ok(c)
    }) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    eprintln!(
        "training {} with {} (M={}, {} mode, backend {:?})",
        cfg.model,
        cfg.algorithm,
        cfg.workers,
        match cfg.exec_mode {
            ExecMode::SimulatedTime => "simulated-time",
            ExecMode::Threads => "threaded",
        },
        cfg.update_backend,
    );
    match Trainer::new(cfg).and_then(|t| t.run()) {
        Ok(report) => {
            println!(
                "steps={} passes={:.2} time={:.1}s wall={:.1}s\n\
                 final train loss {:.4} | test loss {:.4} | test error {:.2}% (best {:.2}%)\n\
                 staleness mean {:.2} p99 {:.0} max {}",
                report.total_steps,
                report.passes,
                report.total_time,
                report.wall_secs,
                report.final_train_loss,
                report.final_test_loss,
                report.final_test_error * 100.0,
                report.best_test_error * 100.0,
                report.staleness_mean,
                report.staleness_p99,
                report.staleness_max,
            );
            if report.faults != dc_asgd::sim::FaultStats::default() {
                let f = report.faults;
                println!(
                    "faults: crashes={} restarts={} departures={} late_joins={} \
                     dropped={} salvaged={} straggles={}",
                    f.crashes,
                    f.restarts,
                    f.departures,
                    f.late_joins,
                    f.dropped_inflight,
                    f.salvaged_inflight,
                    f.straggle_events,
                );
            }
            0
        }
        Err(e) => {
            eprintln!("training failed: {e:#}");
            1
        }
    }
}

fn cmd_sweep(args: &Args) -> i32 {
    let base = match build_config(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    let algos = args.str_or("algos", "asgd,ssgd,dc-asgd-c,dc-asgd-a");
    let workers = match args.usize_list_or("workers-list", &[base.workers]) {
        Ok(w) => w,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    if let Err(e) = args.finish() {
        eprintln!("error: {e}");
        return 2;
    }
    let mut table = Table::new(&["# workers", "algorithm", "error(%)", "time(s)", "stale(mean)"]);
    for &m in &workers {
        for algo_name in algos.split(',') {
            let algo = match Algorithm::parse(algo_name.trim()) {
                Ok(a) => a,
                Err(e) => {
                    eprintln!("error: {e}");
                    return 2;
                }
            };
            let mut cfg = base.clone();
            cfg.algorithm = algo;
            cfg.workers = if algo == Algorithm::SequentialSgd { 1 } else { m };
            eprintln!("[sweep] {} M={} ...", algo, cfg.workers);
            match Trainer::new(cfg).and_then(|t| t.run()) {
                Ok(r) => table.row(&[
                    m.to_string(),
                    algo.name().into(),
                    format!("{:.2}", r.final_test_error * 100.0),
                    format!("{:.1}", r.total_time),
                    format!("{:.2}", r.staleness_mean),
                ]),
                Err(e) => {
                    eprintln!("sweep case failed: {e:#}");
                    return 1;
                }
            }
        }
    }
    table.print();
    0
}

fn cmd_eval(args: &Args) -> i32 {
    // evaluate a checkpointed model on the test split of its dataset
    let run = || -> anyhow::Result<()> {
        let path = args.str_req("checkpoint")?;
        let cfg = build_config(args)?;
        args.finish()?;
        let ck = dc_asgd::ps::Checkpoint::load(std::path::Path::new(&path))?;
        let artifacts = dc_asgd::find_artifacts_dir()
            .ok_or_else(|| anyhow::anyhow!("artifacts/manifest.json not found"))?;
        let engine = dc_asgd::runtime::start_engine(&artifacts, &ck.model, false)?;
        let entry = engine.entry().clone();
        anyhow::ensure!(
            ck.w.len() == entry.n_padded,
            "checkpoint n={} != artifact n_padded={}",
            ck.w.len(),
            entry.n_padded
        );
        let test = dc_asgd::data::build_dataset(
            &cfg.dataset,
            entry.feature_kind(),
            entry.classes,
            false,
            cfg.test_size,
            cfg.seed,
        );
        let (loss, err) = dc_asgd::eval::evaluate(&engine, &ck.w, test.as_ref(), cfg.eval_batches)?;
        println!(
            "checkpoint {path}: model={} algo={} version={} samples={}\n\
             test loss {loss:.4} | test error {:.2}%",
            ck.model,
            ck.algorithm,
            ck.version,
            ck.samples,
            err * 100.0
        );
        engine.shutdown();
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

fn cmd_report(args: &Args) -> i32 {
    if let Err(e) = args.finish() {
        eprintln!("error: {e}");
        return 2;
    }
    let pos = args.positional();
    let dir = match pos.get(1) {
        Some(d) => std::path::PathBuf::from(d),
        None => {
            eprintln!("usage: dcasgd report RUN_DIR");
            return 2;
        }
    };
    match dc_asgd::trace::report::render_digest(&dir) {
        Ok(digest) => {
            print!("{digest}");
            0
        }
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

fn cmd_info(args: &Args) -> i32 {
    if let Err(e) = args.finish() {
        eprintln!("error: {e}");
        return 2;
    }
    let dir = match dc_asgd::find_artifacts_dir() {
        Some(d) => d,
        None => {
            eprintln!("artifacts/manifest.json not found — run `make artifacts`");
            return 1;
        }
    };
    match Manifest::load(&dir) {
        Ok(m) => {
            println!("artifacts: {} (manifest v{})", dir.display(), m.version);
            let mut t = Table::new(&["model", "kind", "params", "padded", "batch", "x shape", "updates"]);
            for e in &m.models {
                t.row(&[
                    e.name.clone(),
                    e.kind.clone(),
                    e.n_params.to_string(),
                    e.n_padded.to_string(),
                    e.batch.to_string(),
                    format!("{:?}", e.x_shape),
                    if e.files.contains_key("dc") { "yes".into() } else { "-".into() },
                ]);
            }
            t.print();
            0
        }
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}
