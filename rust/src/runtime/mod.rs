//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`), so a dedicated
//! **engine thread** owns the client and every compiled executable, serving
//! requests over channels. Worker threads (and the DES) hold a cloneable
//! [`EngineHandle`]. On this 1-core testbed serializing XLA execution costs
//! nothing; the coordinator's concurrency is about *ordering*, which the
//! delay models control.
//!
//! The PJRT path is gated behind the `pjrt` cargo feature: without it the
//! crate (and the whole pure-rust simulation/PS/test surface) builds with
//! no XLA dependency, and [`start_engine`] fails with a clear message.
//! Integration tests that need the engine skip when the artifact directory
//! is absent, so `cargo test` stays green on a fresh checkout either way.

pub mod artifact;
#[cfg(feature = "pjrt")]
pub mod literal;

pub use artifact::{Manifest, ModelEntry};

use crate::data::Batch;
use anyhow::{anyhow, Context, Result};
use std::path::PathBuf;
use std::sync::mpsc::{channel, Sender};

/// Request protocol for the engine thread.
// without `pjrt` the stub engine never destructures requests; the handle
// side still constructs them, so silence the per-field dead-code lint
#[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
enum Req {
    /// train_step(params, x, y) -> (loss, grads)
    Train { params: Vec<f32>, batch: Batch, resp: Sender<Result<(f32, Vec<f32>)>> },
    /// eval_step(params, x, y) -> (loss, correct_count)
    Eval { params: Vec<f32>, batch: Batch, resp: Sender<Result<(f32, f32)>> },
    /// dc update artifact: returns new w
    UpdateDc {
        w: Vec<f32>,
        g: Vec<f32>,
        bak: Vec<f32>,
        lr: f32,
        lam: f32,
        resp: Sender<Result<Vec<f32>>>,
    },
    /// adaptive dc update artifact: returns (new w, new ms)
    UpdateDca {
        w: Vec<f32>,
        g: Vec<f32>,
        bak: Vec<f32>,
        ms: Vec<f32>,
        lr: f32,
        lam0: f32,
        m: f32,
        eps: f32,
        resp: Sender<Result<(Vec<f32>, Vec<f32>)>>,
    },
    /// sgd update artifact: returns new w
    UpdateSgd { w: Vec<f32>, g: Vec<f32>, lr: f32, resp: Sender<Result<Vec<f32>>> },
    Shutdown,
}

/// Cloneable handle to the engine thread.
#[derive(Clone)]
pub struct EngineHandle {
    tx: Sender<Req>,
    entry: ModelEntry,
}

/// Spawn the engine thread for one model and block until its executables
/// are compiled. `with_updates` additionally compiles the update artifacts
/// (only emitted for some models — see python/compile/aot.py).
pub fn start_engine(
    artifacts_dir: &std::path::Path,
    model: &str,
    with_updates: bool,
) -> Result<EngineHandle> {
    let manifest = Manifest::load(artifacts_dir)?;
    let entry = manifest
        .model(model)
        .ok_or_else(|| anyhow!("model {model:?} not in manifest ({})", manifest.names().join(", ")))?
        .clone();
    if with_updates && !entry.files.contains_key("dc") {
        anyhow::bail!(
            "model {model:?} has no update artifacts; regenerate with UPDATE_ARTIFACTS or use the native backend"
        );
    }
    let dir: PathBuf = artifacts_dir.to_path_buf();
    let (tx, rx) = channel::<Req>();
    let (ready_tx, ready_rx) = channel::<Result<()>>();
    let thread_entry = entry.clone();
    std::thread::Builder::new()
        .name(format!("pjrt-engine-{model}"))
        .spawn(move || engine_main(dir, thread_entry, with_updates, rx, ready_tx))
        .context("spawning engine thread")?;
    ready_rx.recv().context("engine thread died during startup")??;
    Ok(EngineHandle { tx, entry })
}

impl EngineHandle {
    pub fn entry(&self) -> &ModelEntry {
        &self.entry
    }

    pub fn n_padded(&self) -> usize {
        self.entry.n_padded
    }

    /// Compute (loss, grads) for a batch at the given parameters.
    pub fn train(&self, params: &[f32], batch: &Batch) -> Result<(f32, Vec<f32>)> {
        let (resp, rx) = channel();
        self.tx
            .send(Req::Train { params: params.to_vec(), batch: batch.clone(), resp })
            .map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow!("engine thread dropped response"))?
    }

    /// Compute (loss, correct_count) for a batch.
    pub fn eval(&self, params: &[f32], batch: &Batch) -> Result<(f32, f32)> {
        let (resp, rx) = channel();
        self.tx
            .send(Req::Eval { params: params.to_vec(), batch: batch.clone(), resp })
            .map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow!("engine thread dropped response"))?
    }

    pub fn update_dc(&self, w: &[f32], g: &[f32], bak: &[f32], lr: f32, lam: f32) -> Result<Vec<f32>> {
        let (resp, rx) = channel();
        self.tx
            .send(Req::UpdateDc {
                w: w.to_vec(),
                g: g.to_vec(),
                bak: bak.to_vec(),
                lr,
                lam,
                resp,
            })
            .map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow!("engine thread dropped response"))?
    }

    #[allow(clippy::too_many_arguments)]
    pub fn update_dca(
        &self,
        w: &[f32],
        g: &[f32],
        bak: &[f32],
        ms: &[f32],
        lr: f32,
        lam0: f32,
        m: f32,
        eps: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let (resp, rx) = channel();
        self.tx
            .send(Req::UpdateDca {
                w: w.to_vec(),
                g: g.to_vec(),
                bak: bak.to_vec(),
                ms: ms.to_vec(),
                lr,
                lam0,
                m,
                eps,
                resp,
            })
            .map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow!("engine thread dropped response"))?
    }

    pub fn update_sgd(&self, w: &[f32], g: &[f32], lr: f32) -> Result<Vec<f32>> {
        let (resp, rx) = channel();
        self.tx
            .send(Req::UpdateSgd { w: w.to_vec(), g: g.to_vec(), lr, resp })
            .map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow!("engine thread dropped response"))?
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Req::Shutdown);
    }
}

/// [`crate::ps::UpdateKernel`] backed by the XLA/Pallas update artifacts
/// (ablation A: XLA vs native server hot path).
pub struct XlaUpdateKernel {
    handle: EngineHandle,
}

impl XlaUpdateKernel {
    pub fn new(handle: EngineHandle) -> Self {
        Self { handle }
    }
}

impl crate::ps::UpdateKernel for XlaUpdateKernel {
    fn sgd(&self, w: &mut [f32], g: &[f32], lr: f32) {
        let new = self.handle.update_sgd(w, g, lr).expect("xla sgd update");
        w.copy_from_slice(&new);
    }
    fn dc(&self, w: &mut [f32], g: &[f32], w_bak: &[f32], lr: f32, lam: f32) {
        let new = self.handle.update_dc(w, g, w_bak, lr, lam).expect("xla dc update");
        w.copy_from_slice(&new);
    }
    fn dca(
        &self,
        w: &mut [f32],
        g: &[f32],
        w_bak: &[f32],
        ms: &mut [f32],
        lr: f32,
        lam0: f32,
        m: f32,
        eps: f32,
    ) {
        let (new_w, new_ms) =
            self.handle.update_dca(w, g, w_bak, ms, lr, lam0, m, eps).expect("xla dca update");
        w.copy_from_slice(&new_w);
        ms.copy_from_slice(&new_ms);
    }
    fn requires_whole_vector(&self) -> bool {
        true
    }
    fn name(&self) -> &'static str {
        "xla"
    }
}

// ---------------------------------------------------------------------------
// engine thread body
// ---------------------------------------------------------------------------

/// Without the `pjrt` feature there is nothing to execute artifacts with:
/// report a clear startup error instead of failing to link against XLA.
#[cfg(not(feature = "pjrt"))]
fn engine_main(
    _dir: PathBuf,
    entry: ModelEntry,
    _with_updates: bool,
    _rx: std::sync::mpsc::Receiver<Req>,
    ready: Sender<Result<()>>,
) {
    let _ = ready.send(Err(anyhow!(
        "model {:?} needs the PJRT engine, but this binary was built without \
         the `pjrt` cargo feature — rebuild with `--features pjrt`",
        entry.name
    )));
}

#[cfg(feature = "pjrt")]
struct Executables {
    train: xla::PjRtLoadedExecutable,
    eval: xla::PjRtLoadedExecutable,
    dc: Option<xla::PjRtLoadedExecutable>,
    dca: Option<xla::PjRtLoadedExecutable>,
    sgd: Option<xla::PjRtLoadedExecutable>,
}

#[cfg(feature = "pjrt")]
fn compile(
    client: &xla::PjRtClient,
    dir: &std::path::Path,
    file: &str,
) -> Result<xla::PjRtLoadedExecutable> {
    let path = dir.join(file);
    let proto = xla::HloModuleProto::from_text_file(&path)
        .map_err(|e| anyhow!("parsing HLO {}: {e}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client.compile(&comp).map_err(|e| anyhow!("compiling {}: {e}", path.display()))
}

#[cfg(feature = "pjrt")]
fn engine_main(
    dir: PathBuf,
    entry: ModelEntry,
    with_updates: bool,
    rx: std::sync::mpsc::Receiver<Req>,
    ready: Sender<Result<()>>,
) {
    let setup = (|| -> Result<(xla::PjRtClient, Executables)> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PjRtClient::cpu: {e}"))?;
        let train = compile(&client, &dir, &entry.files["train"])?;
        let eval = compile(&client, &dir, &entry.files["eval"])?;
        let (dc, dca, sgd) = if with_updates {
            (
                Some(compile(&client, &dir, &entry.files["dc"])?),
                Some(compile(&client, &dir, &entry.files["dca"])?),
                Some(compile(&client, &dir, &entry.files["sgd"])?),
            )
        } else {
            (None, None, None)
        };
        Ok((client, Executables { train, eval, dc, dca, sgd }))
    })();

    let exes = match setup {
        Ok((_client, exes)) => {
            let _ = ready.send(Ok(()));
            exes
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    while let Ok(req) = rx.recv() {
        match req {
            Req::Shutdown => break,
            Req::Train { params, batch, resp } => {
                let _ = resp.send(run_train(&exes.train, &entry, &params, &batch));
            }
            Req::Eval { params, batch, resp } => {
                let _ = resp.send(run_eval(&exes.eval, &entry, &params, &batch));
            }
            Req::UpdateDc { w, g, bak, lr, lam, resp } => {
                let _ = resp.send(run_update_dc(exes.dc.as_ref(), &w, &g, &bak, lr, lam));
            }
            Req::UpdateDca { w, g, bak, ms, lr, lam0, m, eps, resp } => {
                let _ = resp.send(run_update_dca(
                    exes.dca.as_ref(),
                    &w,
                    &g,
                    &bak,
                    &ms,
                    lr,
                    lam0,
                    m,
                    eps,
                ));
            }
            Req::UpdateSgd { w, g, lr, resp } => {
                let _ = resp.send(run_update_sgd(exes.sgd.as_ref(), &w, &g, lr));
            }
        }
    }
}

#[cfg(feature = "pjrt")]
fn run_train(
    exe: &xla::PjRtLoadedExecutable,
    entry: &ModelEntry,
    params: &[f32],
    batch: &Batch,
) -> Result<(f32, Vec<f32>)> {
    let args = literal::model_args(entry, params, batch)?;
    let mut out = literal::execute_tuple(exe, &args)?;
    if out.len() != 2 {
        anyhow::bail!("train artifact returned {} outputs, expected 2", out.len());
    }
    let grads = out.pop().unwrap().to_vec::<f32>().map_err(|e| anyhow!("grads: {e}"))?;
    let loss = out.pop().unwrap().get_first_element::<f32>().map_err(|e| anyhow!("loss: {e}"))?;
    Ok((loss, grads))
}

#[cfg(feature = "pjrt")]
fn run_eval(
    exe: &xla::PjRtLoadedExecutable,
    entry: &ModelEntry,
    params: &[f32],
    batch: &Batch,
) -> Result<(f32, f32)> {
    let args = literal::model_args(entry, params, batch)?;
    let mut out = literal::execute_tuple(exe, &args)?;
    if out.len() != 2 {
        anyhow::bail!("eval artifact returned {} outputs, expected 2", out.len());
    }
    let correct = out.pop().unwrap().get_first_element::<f32>().map_err(|e| anyhow!("correct: {e}"))?;
    let loss = out.pop().unwrap().get_first_element::<f32>().map_err(|e| anyhow!("loss: {e}"))?;
    Ok((loss, correct))
}

#[cfg(feature = "pjrt")]
fn run_update_dc(
    exe: Option<&xla::PjRtLoadedExecutable>,
    w: &[f32],
    g: &[f32],
    bak: &[f32],
    lr: f32,
    lam: f32,
) -> Result<Vec<f32>> {
    let exe = exe.ok_or_else(|| anyhow!("dc update artifact not loaded"))?;
    let args = vec![
        literal::f32_vec(w),
        literal::f32_vec(g),
        literal::f32_vec(bak),
        literal::f32_vec(&[lr]),
        literal::f32_vec(&[lam]),
    ];
    let mut out = literal::execute_tuple(exe, &args)?;
    out.pop().unwrap().to_vec::<f32>().map_err(|e| anyhow!("dc out: {e}"))
}

#[cfg(feature = "pjrt")]
#[allow(clippy::too_many_arguments)]
fn run_update_dca(
    exe: Option<&xla::PjRtLoadedExecutable>,
    w: &[f32],
    g: &[f32],
    bak: &[f32],
    ms: &[f32],
    lr: f32,
    lam0: f32,
    m: f32,
    eps: f32,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let exe = exe.ok_or_else(|| anyhow!("dca update artifact not loaded"))?;
    let args = vec![
        literal::f32_vec(w),
        literal::f32_vec(g),
        literal::f32_vec(bak),
        literal::f32_vec(ms),
        literal::f32_vec(&[lr]),
        literal::f32_vec(&[lam0]),
        literal::f32_vec(&[m]),
        literal::f32_vec(&[eps]),
    ];
    let mut out = literal::execute_tuple(exe, &args)?;
    if out.len() != 2 {
        anyhow::bail!("dca artifact returned {} outputs, expected 2", out.len());
    }
    let new_ms = out.pop().unwrap().to_vec::<f32>().map_err(|e| anyhow!("ms out: {e}"))?;
    let new_w = out.pop().unwrap().to_vec::<f32>().map_err(|e| anyhow!("w out: {e}"))?;
    Ok((new_w, new_ms))
}

#[cfg(feature = "pjrt")]
fn run_update_sgd(
    exe: Option<&xla::PjRtLoadedExecutable>,
    w: &[f32],
    g: &[f32],
    lr: f32,
) -> Result<Vec<f32>> {
    let exe = exe.ok_or_else(|| anyhow!("sgd update artifact not loaded"))?;
    let args = vec![literal::f32_vec(w), literal::f32_vec(g), literal::f32_vec(&[lr])];
    let mut out = literal::execute_tuple(exe, &args)?;
    out.pop().unwrap().to_vec::<f32>().map_err(|e| anyhow!("sgd out: {e}"))
}
