//! `xla::Literal` construction/extraction helpers.

use super::ModelEntry;
use crate::data::Batch;
use anyhow::{anyhow, bail, Result};

/// Flat f32 slice -> rank-1 literal.
pub fn f32_vec(xs: &[f32]) -> xla::Literal {
    xla::Literal::vec1(xs)
}

/// Flat f32 slice -> rank-2 literal [rows, cols].
pub fn f32_mat(xs: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    if xs.len() != rows * cols {
        bail!("f32_mat: {} values for {rows}x{cols}", xs.len());
    }
    xla::Literal::vec1(xs)
        .reshape(&[rows as i64, cols as i64])
        .map_err(|e| anyhow!("reshape: {e}"))
}

/// Flat i32 slice -> rank-2 literal [rows, cols].
pub fn i32_mat(xs: &[i32], rows: usize, cols: usize) -> Result<xla::Literal> {
    if xs.len() != rows * cols {
        bail!("i32_mat: {} values for {rows}x{cols}", xs.len());
    }
    xla::Literal::vec1(xs)
        .reshape(&[rows as i64, cols as i64])
        .map_err(|e| anyhow!("reshape: {e}"))
}

/// i32 slice -> rank-1 literal.
pub fn i32_vec(xs: &[i32]) -> xla::Literal {
    xla::Literal::vec1(xs)
}

/// Build the (params, x, y) argument list for a model artifact from a batch.
pub fn model_args(entry: &ModelEntry, params: &[f32], batch: &Batch) -> Result<Vec<xla::Literal>> {
    if params.len() != entry.n_padded {
        bail!("params length {} != n_padded {}", params.len(), entry.n_padded);
    }
    if batch.rows != entry.batch {
        bail!("batch rows {} != artifact batch {}", batch.rows, entry.batch);
    }
    let p = f32_vec(params);
    let x = if entry.x_dtype == "i32" {
        i32_mat(&batch.x_i32, entry.x_shape[0], entry.x_shape[1])?
    } else {
        f32_mat(&batch.x_f32, entry.x_shape[0], entry.x_shape[1])?
    };
    let y = match entry.y_shape.len() {
        1 => {
            if batch.y_i32.len() != entry.y_shape[0] {
                bail!("labels {} != y shape {:?}", batch.y_i32.len(), entry.y_shape);
            }
            i32_vec(&batch.y_i32)
        }
        2 => i32_mat(&batch.y_i32, entry.y_shape[0], entry.y_shape[1])?,
        _ => bail!("unsupported y rank {:?}", entry.y_shape),
    };
    Ok(vec![p, x, y])
}

/// Execute and unpack the jax `return_tuple=True` convention: one output
/// buffer holding a tuple literal; returns its elements.
///
/// NOTE: we deliberately avoid `PjRtLoadedExecutable::execute` (the
/// literal-input overload). Its C shim (`xla_rs.cc: execute`) `release()`s
/// the device buffers it creates for the inputs and never frees them —
/// ~one full parameter vector leaked per training step (measured
/// ~3.8 MB/call, OOM after a few thousand steps). Instead we build the
/// input buffers on the rust side, where `PjRtBuffer` has a correct `Drop`,
/// and go through `execute_b`.
pub fn execute_tuple(
    exe: &xla::PjRtLoadedExecutable,
    args: &[xla::Literal],
) -> Result<Vec<xla::Literal>> {
    let client = exe.client();
    let mut arg_bufs = Vec::with_capacity(args.len());
    for lit in args {
        arg_bufs.push(
            client
                .buffer_from_host_literal(None, lit)
                .map_err(|e| anyhow!("host->device transfer: {e}"))?,
        );
    }
    let bufs = exe.execute_b::<xla::PjRtBuffer>(&arg_bufs).map_err(|e| anyhow!("execute: {e}"))?;
    drop(arg_bufs); // input device buffers freed here (see note above)
    let lit = bufs
        .first()
        .and_then(|replica| replica.first())
        .ok_or_else(|| anyhow!("no output buffer"))?
        .to_literal_sync()
        .map_err(|e| anyhow!("to_literal: {e}"))?;
    lit.to_tuple().map_err(|e| anyhow!("to_tuple: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mat_builders_validate_shape() {
        assert!(f32_mat(&[1.0, 2.0, 3.0], 2, 2).is_err());
        assert!(f32_mat(&[1.0, 2.0, 3.0, 4.0], 2, 2).is_ok());
        assert!(i32_mat(&[1, 2], 1, 2).is_ok());
        assert!(i32_mat(&[1, 2], 2, 2).is_err());
    }

    #[test]
    fn literal_roundtrip() {
        let xs = [1.5f32, -2.0, 0.0, 7.25];
        let lit = f32_vec(&xs);
        assert_eq!(lit.to_vec::<f32>().unwrap(), xs);
        let m = f32_mat(&xs, 2, 2).unwrap();
        assert_eq!(m.to_vec::<f32>().unwrap(), xs);
        let ys = [3i32, -1, 9];
        assert_eq!(i32_vec(&ys).to_vec::<i32>().unwrap(), ys);
    }
}
