//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime. See MANIFEST_VERSION there; bump in lockstep.

use crate::data::FeatureKind;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Manifest version this runtime understands.
pub const SUPPORTED_VERSION: i64 = 2;

/// One named tensor inside the flat parameter vector.
#[derive(Clone, Debug)]
pub struct TensorInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

/// One AOT-compiled model.
#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub name: String,
    pub kind: String,
    pub n_params: usize,
    pub n_padded: usize,
    pub x_dtype: String,
    pub x_shape: Vec<usize>,
    pub y_shape: Vec<usize>,
    pub batch: usize,
    pub classes: usize,
    /// Label rows per batch (batch for classifiers, batch*seq for LMs).
    pub tokens_per_batch: usize,
    pub files: BTreeMap<String, String>,
    pub tensors: Vec<TensorInfo>,
}

impl ModelEntry {
    /// Feature layout expected by the dataset builder.
    pub fn feature_kind(&self) -> FeatureKind {
        if self.x_dtype == "i32" {
            FeatureKind::Tokens { seq_len: self.x_shape[1] }
        } else {
            FeatureKind::Dense { dim: self.x_shape[1] }
        }
    }

    pub fn label_width(&self) -> usize {
        self.tokens_per_batch / self.batch
    }

    /// Load the initial flat parameter vector emitted by aot.py.
    pub fn load_init(&self, dir: &Path) -> Result<Vec<f32>> {
        let file = self
            .files
            .get("init")
            .ok_or_else(|| anyhow!("model {} has no init file", self.name))?;
        let bytes = std::fs::read(dir.join(file))
            .with_context(|| format!("reading {}", dir.join(file).display()))?;
        if bytes.len() != self.n_padded * 4 {
            bail!(
                "init file {} has {} bytes, expected {} (n_padded={})",
                file,
                bytes.len(),
                self.n_padded * 4,
                self.n_padded
            );
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// The parsed manifest.json.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub version: i64,
    pub pad_multiple: usize,
    pub models: Vec<ModelEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        Self::parse(&src)
    }

    pub fn parse(src: &str) -> Result<Self> {
        let root = Json::parse(src).map_err(|e| anyhow!("manifest.json: {e}"))?;
        let version = root.get("version").as_i64().unwrap_or(0);
        if version != SUPPORTED_VERSION {
            bail!("manifest version {version} unsupported (runtime expects {SUPPORTED_VERSION}); re-run `make artifacts`");
        }
        let pad_multiple = root
            .get("pad_multiple")
            .as_usize()
            .ok_or_else(|| anyhow!("manifest missing pad_multiple"))?;
        let mut models = Vec::new();
        for m in root.get("models").as_arr().unwrap_or(&[]) {
            models.push(parse_model(m)?);
        }
        if models.is_empty() {
            bail!("manifest lists no models");
        }
        Ok(Self { version, pad_multiple, models })
    }

    pub fn model(&self, name: &str) -> Option<&ModelEntry> {
        self.models.iter().find(|m| m.name == name)
    }

    pub fn names(&self) -> Vec<String> {
        self.models.iter().map(|m| m.name.clone()).collect()
    }
}

fn shape_of(v: &Json, key: &str) -> Result<Vec<usize>> {
    v.get(key)
        .get("shape")
        .as_arr()
        .ok_or_else(|| anyhow!("model missing {key}.shape"))?
        .iter()
        .map(|s| s.as_usize().ok_or_else(|| anyhow!("bad {key}.shape entry")))
        .collect()
}

fn parse_model(m: &Json) -> Result<ModelEntry> {
    let name = m
        .get("name")
        .as_str()
        .ok_or_else(|| anyhow!("model entry missing name"))?
        .to_string();
    let files = m
        .get("files")
        .as_obj()
        .ok_or_else(|| anyhow!("model {name} missing files"))?
        .iter()
        .map(|(k, v)| {
            v.as_str()
                .map(|s| (k.clone(), s.to_string()))
                .ok_or_else(|| anyhow!("model {name}: file entry {k} not a string"))
        })
        .collect::<Result<BTreeMap<_, _>>>()?;
    for required in ["train", "eval", "init"] {
        if !files.contains_key(required) {
            bail!("model {name} missing required artifact {required:?}");
        }
    }
    let tensors = m
        .get("tensors")
        .as_arr()
        .unwrap_or(&[])
        .iter()
        .map(|t| -> Result<TensorInfo> {
            Ok(TensorInfo {
                name: t.get("name").as_str().unwrap_or("?").to_string(),
                shape: t
                    .get("shape")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|s| s.as_usize())
                    .collect(),
                offset: t.get("offset").as_usize().unwrap_or(0),
                size: t.get("size").as_usize().unwrap_or(0),
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let n_params = m.get("n_params").as_usize().ok_or_else(|| anyhow!("{name}: n_params"))?;
    let n_padded = m.get("n_padded").as_usize().ok_or_else(|| anyhow!("{name}: n_padded"))?;
    if n_padded < n_params {
        bail!("model {name}: n_padded < n_params");
    }
    let batch = m.get("batch").as_usize().ok_or_else(|| anyhow!("{name}: batch"))?;
    let entry = ModelEntry {
        kind: m.get("kind").as_str().unwrap_or("?").to_string(),
        n_params,
        n_padded,
        x_dtype: m.get("x").get("dtype").as_str().unwrap_or("f32").to_string(),
        x_shape: shape_of(m, "x")?,
        y_shape: shape_of(m, "y")?,
        batch,
        classes: m.get("classes").as_usize().unwrap_or(0),
        tokens_per_batch: m.get("tokens_per_batch").as_usize().unwrap_or(batch),
        files,
        tensors,
        name,
    };
    if entry.x_shape.len() != 2 || entry.x_shape[0] != entry.batch {
        bail!("model {}: unexpected x shape {:?}", entry.name, entry.x_shape);
    }
    Ok(entry)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "version": 2, "pad_multiple": 8192,
        "models": [{
            "name": "mlp_tiny", "kind": "mlp",
            "n_params": 3268, "n_padded": 8192,
            "x": {"dtype": "f32", "shape": [16, 64]},
            "y": {"dtype": "i32", "shape": [16]},
            "batch": 16, "classes": 4, "tokens_per_batch": 16,
            "files": {"train": "t.hlo.txt", "eval": "e.hlo.txt", "init": "i.f32"},
            "tensors": [{"name": "w0", "shape": [64, 32], "offset": 0, "size": 2048}]
        }, {
            "name": "lm", "kind": "transformer",
            "n_params": 100, "n_padded": 8192,
            "x": {"dtype": "i32", "shape": [8, 64]},
            "y": {"dtype": "i32", "shape": [8, 64]},
            "batch": 8, "classes": 512, "tokens_per_batch": 512,
            "files": {"train": "t", "eval": "e", "init": "i"},
            "tensors": []
        }]
    }"#;

    #[test]
    fn parses_manifest() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.version, 2);
        assert_eq!(m.models.len(), 2);
        let e = m.model("mlp_tiny").unwrap();
        assert_eq!(e.n_padded, 8192);
        assert_eq!(e.feature_kind(), FeatureKind::Dense { dim: 64 });
        assert_eq!(e.label_width(), 1);
        assert_eq!(e.tensors[0].size, 2048);
        let lm = m.model("lm").unwrap();
        assert_eq!(lm.feature_kind(), FeatureKind::Tokens { seq_len: 64 });
        assert_eq!(lm.label_width(), 64);
        assert!(m.model("nope").is_none());
    }

    #[test]
    fn rejects_wrong_version() {
        let bad = SAMPLE.replacen("\"version\": 2", "\"version\": 1", 1);
        let err = Manifest::parse(&bad).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn rejects_missing_required_file() {
        let bad = SAMPLE.replacen("\"train\": \"t.hlo.txt\", ", "", 1);
        let err = Manifest::parse(&bad).unwrap_err().to_string();
        assert!(err.contains("train"), "{err}");
    }

    #[test]
    fn rejects_inconsistent_padding() {
        let bad = SAMPLE.replacen("\"n_padded\": 8192", "\"n_padded\": 100", 1);
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn load_init_checks_length() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let e = m.model("mlp_tiny").unwrap();
        let dir = std::env::temp_dir().join(format!("dcasgd_art_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("i.f32"), vec![0u8; 8192 * 4]).unwrap();
        let init = e.load_init(&dir).unwrap();
        assert_eq!(init.len(), 8192);
        std::fs::write(dir.join("i.f32"), vec![0u8; 16]).unwrap();
        assert!(e.load_init(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
