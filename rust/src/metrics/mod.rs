//! Training metrics: per-step records, eval records, CSV/JSON output, and
//! the summary report returned by the trainer.

use crate::sim::FaultStats;
use crate::util::json::Json;
use std::io::Write;
use std::path::Path;
use std::time::Instant;

/// One global model update.
#[derive(Clone, Copy, Debug)]
pub struct StepRecord {
    pub step: u64,
    pub worker: usize,
    /// Effective passes over the training set at this point.
    pub passes: f64,
    /// Simulated seconds (DES mode) or wall seconds (thread mode).
    pub time: f64,
    pub loss: f32,
    pub lr: f32,
    /// Delay tau observed by this update (global steps since the worker's
    /// pull).
    pub staleness: u64,
    /// Gate/barrier wait charged to this step (simulated seconds; 0 for
    /// ungated protocols and in threads mode). Barrier rounds record the
    /// SUM of all workers' stalls so totals compare across protocols.
    pub wait: f64,
}

/// One test-set evaluation.
#[derive(Clone, Copy, Debug)]
pub struct EvalRecord {
    pub step: u64,
    pub passes: f64,
    pub time: f64,
    pub test_loss: f32,
    /// Classification error in [0,1].
    pub test_error: f32,
}

/// Internal cap on tracked staleness values; anything above folds into the
/// last bucket (query-time caps fold further down from here).
const STALE_TRACK_CAP: usize = 1024;

/// Smoothing constant of the downsampling-proof running loss EMA: each
/// step contributes 2%, so the EMA spans roughly the last ~50 updates —
/// matching the window the old tail-average used at `keep_every = 1`.
const LOSS_EMA_BETA: f64 = 0.98;

/// Collected metrics of one training run.
#[derive(Debug)]
pub struct MetricsLog {
    pub steps: Vec<StepRecord>,
    pub evals: Vec<EvalRecord>,
    started: Instant,
    /// Downsample step records: keep one in `keep_every` (loss curves don't
    /// need every update at scale). Eval records are always kept.
    keep_every: u64,
    /// Gate-wait total over ALL steps, accumulated before downsampling so
    /// `keep_every` never skews it.
    wait_accum: f64,
    /// Staleness counts over ALL steps (index = tau, tail folded at
    /// [`STALE_TRACK_CAP`]), likewise downsampling-proof.
    stale_counts: Vec<u64>,
    /// Exact running maximum staleness (the folded tail would otherwise
    /// clamp heavy-tail outliers to the cap).
    stale_max: u64,
    /// Exact count of recorded steps, accumulated before downsampling —
    /// `steps.last().step + 1` undercounts whenever `keep_every > 1`
    /// drops the final records.
    step_count: u64,
    /// Downsampling-proof running loss EMA (see [`LOSS_EMA_BETA`]); NaN
    /// until the first step lands.
    loss_ema: f64,
    /// Total modelled bytes on the wire (encoded gradient uploads + dense
    /// model downloads), reported by the scheduler at end of run. Zero in
    /// threads mode (no wire model there).
    comm_bytes: u64,
    /// Worker lifecycle counters (crashes / restarts / membership churn),
    /// reported by the scheduler at end of run; all zero without a
    /// `[faults]` section.
    fault_stats: FaultStats,
    /// Serving-plane summary (pull latency percentiles + snapshot
    /// staleness), set once by the driver; `None` without a `[serving]`
    /// section, in which case no serving keys appear in the summary JSON.
    serving: Option<crate::sim::ServingSummary>,
}

impl Default for MetricsLog {
    fn default() -> Self {
        Self::new(1)
    }
}

impl MetricsLog {
    pub fn new(keep_every: u64) -> Self {
        Self {
            steps: Vec::new(),
            evals: Vec::new(),
            started: Instant::now(),
            keep_every: keep_every.max(1),
            wait_accum: 0.0,
            stale_counts: Vec::new(),
            stale_max: 0,
            step_count: 0,
            loss_ema: f64::NAN,
            comm_bytes: 0,
            fault_stats: FaultStats::default(),
            serving: None,
        }
    }

    /// Record the run's total bytes-on-wire (set once by the driver from
    /// [`crate::sim::Scheduler::comm_bytes_total`]).
    pub fn set_comm_bytes(&mut self, bytes: u64) {
        self.comm_bytes = bytes;
    }

    pub fn comm_bytes(&self) -> u64 {
        self.comm_bytes
    }

    /// Record the run's worker-lifecycle counters (set once by the driver
    /// from [`crate::sim::Scheduler::fault_stats`]).
    pub fn set_fault_stats(&mut self, stats: FaultStats) {
        self.fault_stats = stats;
    }

    pub fn fault_stats(&self) -> FaultStats {
        self.fault_stats
    }

    /// Record the run's serving-plane summary (set once by the driver from
    /// [`crate::sim::ServingRecorder::summary`]; never set with `[serving]`
    /// off).
    pub fn set_serving(&mut self, s: crate::sim::ServingSummary) {
        self.serving = Some(s);
    }

    pub fn serving(&self) -> Option<crate::sim::ServingSummary> {
        self.serving
    }

    pub fn record_step(&mut self, r: StepRecord) {
        // wait/staleness/count/loss aggregates must cover every step, not
        // the downsampled curve, or keep_every silently skews them
        self.step_count += 1;
        self.wait_accum += r.wait;
        self.loss_ema = if self.loss_ema.is_nan() {
            r.loss as f64
        } else {
            self.loss_ema * LOSS_EMA_BETA + r.loss as f64 * (1.0 - LOSS_EMA_BETA)
        };
        self.stale_max = self.stale_max.max(r.staleness);
        let tau = (r.staleness as usize).min(STALE_TRACK_CAP);
        if tau >= self.stale_counts.len() {
            self.stale_counts.resize(tau + 1, 0);
        }
        self.stale_counts[tau] += 1;
        if r.step % self.keep_every == 0 {
            self.steps.push(r);
        }
    }

    /// Exact number of recorded steps (immune to `keep_every`).
    pub fn step_count(&self) -> u64 {
        self.step_count
    }

    /// Downsampling-proof running loss EMA; `None` before the first step.
    pub fn loss_ema(&self) -> Option<f64> {
        if self.loss_ema.is_nan() {
            None
        } else {
            Some(self.loss_ema)
        }
    }

    pub fn record_eval(&mut self, r: EvalRecord) {
        self.evals.push(r);
    }

    pub fn wall_secs(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Mean training loss over the last `k` recorded steps.
    pub fn recent_loss(&self, k: usize) -> Option<f32> {
        if self.steps.is_empty() {
            return None;
        }
        let tail = &self.steps[self.steps.len().saturating_sub(k)..];
        Some(tail.iter().map(|r| r.loss).sum::<f32>() / tail.len() as f32)
    }

    /// (mean, p99, max) of observed staleness over EVERY step, computed
    /// from the downsampling-proof counts so `keep_every` cannot drop a
    /// spike (p99 is nearest-rank over the folded counts; max is exact).
    pub fn staleness_summary(&self) -> (f64, f64, u64) {
        let n: u64 = self.stale_counts.iter().sum();
        if n == 0 {
            return (0.0, 0.0, 0);
        }
        let mut sum = 0.0f64;
        for (tau, &c) in self.stale_counts.iter().enumerate() {
            if c > 0 {
                sum += tau as f64 * c as f64;
            }
        }
        let rank = ((n as f64) * 0.99).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        let mut p99 = 0.0f64;
        for (tau, &c) in self.stale_counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                p99 = tau as f64;
                break;
            }
        }
        (sum / n as f64, p99, self.stale_max)
    }

    /// Histogram of observed staleness over EVERY step (not just the
    /// downsampled curve): `hist[tau]` counts steps that observed delay
    /// `tau`. Values above `cap` fold into the last bucket so a single
    /// outlier cannot blow up the vector.
    pub fn staleness_histogram(&self, cap: usize) -> Vec<u64> {
        let top = self
            .stale_counts
            .iter()
            .rposition(|&c| c > 0)
            .map(|t| t.min(cap))
            .unwrap_or(0);
        let mut hist = vec![0u64; top + 1];
        for (tau, &c) in self.stale_counts.iter().enumerate() {
            hist[tau.min(cap).min(top)] += c;
        }
        hist
    }

    /// Total simulated seconds workers spent gated (barrier or staleness
    /// bound) across EVERY step, immune to `keep_every` downsampling.
    pub fn wait_total(&self) -> f64 {
        self.wait_accum
    }

    // ------------------------------------------------------------- output

    pub fn write_steps_csv(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(f, "step,worker,passes,time,loss,lr,staleness,wait")?;
        for r in &self.steps {
            writeln!(
                f,
                "{},{},{:.6},{:.6},{:.6},{:.6},{},{:.6}",
                r.step, r.worker, r.passes, r.time, r.loss, r.lr, r.staleness, r.wait
            )?;
        }
        Ok(())
    }

    pub fn write_evals_csv(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(f, "step,passes,time,test_loss,test_error")?;
        for r in &self.evals {
            writeln!(
                f,
                "{},{:.6},{:.6},{:.6},{:.6}",
                r.step, r.passes, r.time, r.test_loss, r.test_error
            )?;
        }
        Ok(())
    }

    pub fn report(&self) -> TrainReport {
        let (stale_mean, stale_p99, stale_max) = self.staleness_summary();
        let wait_total = self.wait_total();
        let last = self.evals.last();
        let best = self
            .evals
            .iter()
            .map(|e| e.test_error)
            .fold(f32::INFINITY, f32::min);
        TrainReport {
            total_steps: self.step_count,
            final_test_error: last.map(|e| e.test_error).unwrap_or(f32::NAN),
            final_test_loss: last.map(|e| e.test_loss).unwrap_or(f32::NAN),
            best_test_error: if best.is_finite() { best } else { f32::NAN },
            final_train_loss: self.loss_ema().map(|l| l as f32).unwrap_or(f32::NAN),
            total_time: self
                .evals
                .last()
                .map(|e| e.time)
                .or_else(|| self.steps.last().map(|r| r.time))
                .unwrap_or(0.0),
            wall_secs: self.wall_secs(),
            passes: self.steps.last().map(|r| r.passes).unwrap_or(0.0),
            staleness_mean: stale_mean,
            staleness_p99: stale_p99,
            staleness_max: stale_max,
            wait_total,
            comm_bytes: self.comm_bytes,
            faults: self.fault_stats,
            staleness_hist: self.staleness_histogram(64),
            serving: self.serving,
        }
    }
}

/// Summary of a completed run (what benches tabulate).
#[derive(Clone, Debug)]
pub struct TrainReport {
    /// Exact number of global update steps, counted before `keep_every`
    /// downsampling (deriving it from the last *kept* record's index
    /// undercounted whenever the tail was dropped).
    pub total_steps: u64,
    pub final_test_error: f32,
    pub final_test_loss: f32,
    pub best_test_error: f32,
    /// Running EMA of the training loss over ALL steps (2% per update,
    /// ~50-step window), accumulated before downsampling. Earlier builds
    /// averaged the last 50 *kept* records, which under `keep_every > 1`
    /// silently widened the window by the downsampling factor.
    pub final_train_loss: f32,
    /// Simulated (or wall) seconds at the end of training.
    pub total_time: f64,
    /// Host wall-clock seconds the run actually took.
    pub wall_secs: f64,
    pub passes: f64,
    pub staleness_mean: f64,
    pub staleness_p99: f64,
    pub staleness_max: u64,
    /// Total simulated seconds lost to protocol gates (barrier / SSP).
    pub wait_total: f64,
    /// Total modelled bytes on the wire (encoded uploads + dense
    /// downloads; 0 in threads mode).
    pub comm_bytes: u64,
    /// Worker lifecycle counters (all zero without a `[faults]` section).
    pub faults: FaultStats,
    /// `staleness_hist[tau]` = steps that observed delay tau (tail folded
    /// into the last bucket).
    pub staleness_hist: Vec<u64>,
    /// Serving-plane summary; `None` (no serving keys in the JSON) with
    /// `[serving]` off, so serving-disabled summaries stay byte-identical
    /// to pre-serving builds.
    pub serving: Option<crate::sim::ServingSummary>,
}

impl TrainReport {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("total_steps", (self.total_steps as i64).into()),
            ("final_test_error", (self.final_test_error as f64).into()),
            ("final_test_loss", (self.final_test_loss as f64).into()),
            ("best_test_error", (self.best_test_error as f64).into()),
            ("final_train_loss", (self.final_train_loss as f64).into()),
            ("total_time", self.total_time.into()),
            ("wall_secs", self.wall_secs.into()),
            ("passes", self.passes.into()),
            ("staleness_mean", self.staleness_mean.into()),
            ("staleness_p99", self.staleness_p99.into()),
            ("staleness_max", (self.staleness_max as i64).into()),
            ("wait_total", self.wait_total.into()),
            ("comm_bytes", (self.comm_bytes as i64).into()),
            ("crashes", (self.faults.crashes as i64).into()),
            ("restarts", (self.faults.restarts as i64).into()),
            ("departures", (self.faults.departures as i64).into()),
            ("late_joins", (self.faults.late_joins as i64).into()),
            ("dropped_inflight", (self.faults.dropped_inflight as i64).into()),
            ("salvaged_inflight", (self.faults.salvaged_inflight as i64).into()),
            ("straggle_events", (self.faults.straggle_events as i64).into()),
            (
                "staleness_hist",
                Json::arr(self.staleness_hist.iter().map(|&c| Json::from(c as i64))),
            ),
        ];
        if let Some(s) = &self.serving {
            fields.push((
                "serving",
                Json::obj(vec![
                    ("pulls", (s.pulls as i64).into()),
                    ("published", (s.published as i64).into()),
                    ("lat_p50", s.lat_p50.into()),
                    ("lat_p99", s.lat_p99.into()),
                    ("lat_p999", s.lat_p999.into()),
                    ("stale_steps_mean", s.stale_steps_mean.into()),
                    ("stale_steps_max", (s.stale_steps_max as i64).into()),
                    ("stale_time_mean", s.stale_time_mean.into()),
                    ("stale_time_max", s.stale_time_max.into()),
                ]),
            ));
        }
        Json::obj(fields)
    }
}

/// Summary-JSON format version, so downstream tooling (`dcasgd report`)
/// can detect drift instead of guessing. Bump on breaking shape changes.
/// v2 added `schema_version` itself and the optional per-subsystem
/// `profile` block.
pub const SUMMARY_SCHEMA_VERSION: i64 = 2;

/// Write a metrics bundle (steps CSV, evals CSV, summary JSON) under
/// `dir` with the given run name.
pub fn write_run(
    dir: &Path,
    name: &str,
    log: &MetricsLog,
    config_json: &Json,
) -> std::io::Result<()> {
    write_run_full(dir, name, log, config_json, None)
}

/// [`write_run`] plus an optional per-subsystem profile block (from
/// [`crate::trace::profile::snapshot_json`]) in the summary JSON.
pub fn write_run_full(
    dir: &Path,
    name: &str,
    log: &MetricsLog,
    config_json: &Json,
    profile: Option<Json>,
) -> std::io::Result<()> {
    std::fs::create_dir_all(dir)?;
    log.write_steps_csv(&dir.join(format!("{name}.steps.csv")))?;
    log.write_evals_csv(&dir.join(format!("{name}.evals.csv")))?;
    let mut fields = vec![
        ("schema_version", SUMMARY_SCHEMA_VERSION.into()),
        ("config", config_json.clone()),
        ("report", log.report().to_json()),
    ];
    if let Some(p) = profile {
        fields.push(("profile", p));
    }
    let summary = Json::obj(fields);
    std::fs::write(dir.join(format!("{name}.summary.json")), summary.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_log() -> MetricsLog {
        let mut log = MetricsLog::new(1);
        for i in 0..10u64 {
            log.record_step(StepRecord {
                step: i,
                worker: (i % 3) as usize,
                passes: i as f64 * 0.1,
                time: i as f64,
                loss: 2.0 - i as f32 * 0.1,
                lr: 0.1,
                staleness: i % 4,
                wait: 0.25,
            });
        }
        log.record_eval(EvalRecord { step: 5, passes: 0.5, time: 5.0, test_loss: 1.5, test_error: 0.30 });
        log.record_eval(EvalRecord { step: 9, passes: 0.9, time: 9.0, test_loss: 1.2, test_error: 0.25 });
        log
    }

    #[test]
    fn report_fields() {
        let log = sample_log();
        let r = log.report();
        assert_eq!(r.total_steps, 10);
        assert_eq!(r.final_test_error, 0.25);
        assert_eq!(r.best_test_error, 0.25);
        assert_eq!(r.passes, 0.9);
        assert!(r.staleness_mean > 0.0);
        assert!(r.staleness_max <= 3);
        assert!((r.wait_total - 10.0 * 0.25).abs() < 1e-9);
        // staleness pattern i % 4 over 10 steps: tau 0,1 appear 3x; 2,3 2x
        assert_eq!(r.staleness_hist, vec![3, 3, 2, 2]);
    }

    #[test]
    fn staleness_histogram_folds_tail() {
        let mut log = MetricsLog::new(1);
        for &tau in &[0u64, 1, 1, 500] {
            log.record_step(StepRecord {
                step: tau,
                worker: 0,
                passes: 0.0,
                time: 0.0,
                loss: 0.0,
                lr: 0.0,
                staleness: tau,
                wait: 0.0,
            });
        }
        let hist = log.staleness_histogram(8);
        assert_eq!(hist.len(), 9);
        assert_eq!(hist[0], 1);
        assert_eq!(hist[1], 2);
        assert_eq!(hist[8], 1, "tau=500 folds into the cap bucket");
    }

    #[test]
    fn recent_loss_averages_tail() {
        let log = sample_log();
        let l = log.recent_loss(2).unwrap();
        assert!((l - (1.2 + 1.1) / 2.0).abs() < 1e-5);
    }

    #[test]
    fn keep_every_downsamples() {
        let mut log = MetricsLog::new(4);
        for i in 0..20u64 {
            log.record_step(StepRecord {
                step: i,
                worker: 0,
                passes: 0.0,
                time: 0.0,
                loss: 0.0,
                lr: 0.0,
                staleness: 1,
                wait: 0.5,
            });
        }
        assert_eq!(log.steps.len(), 5); // steps 0,4,8,12,16
        // aggregates must cover all 20 steps, not the kept 5
        assert!((log.wait_total() - 20.0 * 0.5).abs() < 1e-9);
        assert_eq!(log.staleness_histogram(8), vec![0, 20]);
        // the exact counter: `steps.last().step + 1` would report 17 here
        assert_eq!(log.step_count(), 20);
        assert_eq!(log.report().total_steps, 20);
    }

    #[test]
    fn loss_ema_is_downsampling_proof() {
        // identical step streams through keep_every 1 and 4 must agree on
        // the EMA bit-for-bit (it accumulates before the downsample filter)
        let mut full = MetricsLog::new(1);
        let mut sampled = MetricsLog::new(4);
        for i in 0..40u64 {
            let r = StepRecord {
                step: i,
                worker: 0,
                passes: 0.0,
                time: 0.0,
                loss: 3.0 - i as f32 * 0.05,
                lr: 0.1,
                staleness: 0,
                wait: 0.0,
            };
            full.record_step(r);
            sampled.record_step(r);
        }
        let (a, b) = (full.loss_ema().unwrap(), sampled.loss_ema().unwrap());
        assert_eq!(a.to_bits(), b.to_bits());
        assert_eq!(
            full.report().final_train_loss.to_bits(),
            sampled.report().final_train_loss.to_bits()
        );
        // a constant loss stream converges to exactly that loss
        let mut flat = MetricsLog::new(1);
        for i in 0..10u64 {
            flat.record_step(StepRecord {
                step: i,
                worker: 0,
                passes: 0.0,
                time: 0.0,
                loss: 1.25,
                lr: 0.1,
                staleness: 0,
                wait: 0.0,
            });
        }
        assert!((flat.report().final_train_loss - 1.25).abs() < 1e-6);
        // and an empty log has no EMA
        assert!(MetricsLog::new(1).loss_ema().is_none());
    }

    #[test]
    fn csv_and_summary_written() {
        let log = sample_log();
        let dir = std::env::temp_dir().join(format!("dcasgd_metrics_{}", std::process::id()));
        write_run(&dir, "t", &log, &Json::obj(vec![("algo", "asgd".into())])).unwrap();
        let steps = std::fs::read_to_string(dir.join("t.steps.csv")).unwrap();
        assert!(steps.starts_with("step,worker,"));
        assert_eq!(steps.lines().count(), 11);
        let summary = std::fs::read_to_string(dir.join("t.summary.json")).unwrap();
        let json = Json::parse(&summary).unwrap();
        assert_eq!(json.get("report").get("total_steps").as_i64(), Some(10));
        assert_eq!(json.get("schema_version").as_i64(), Some(SUMMARY_SCHEMA_VERSION));
        // no profile block unless one is passed
        assert_eq!(json.get("profile"), &Json::Null);
        let profile = Json::arr(vec![Json::obj(vec![("subsystem", "shard_lock".into())])]);
        write_run_full(&dir, "tp", &log, &Json::obj(vec![]), Some(profile)).unwrap();
        let summary = std::fs::read_to_string(dir.join("tp.summary.json")).unwrap();
        let json = Json::parse(&summary).unwrap();
        assert!(json.get("profile").as_arr().is_some());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_log_report_is_sane() {
        let log = MetricsLog::new(1);
        let r = log.report();
        assert_eq!(r.total_steps, 0);
        assert!(r.final_test_error.is_nan());
        assert_eq!(r.faults, FaultStats::default());
    }

    #[test]
    fn fault_stats_flow_into_the_report_json() {
        let mut log = sample_log();
        let stats = FaultStats {
            crashes: 3,
            restarts: 2,
            departures: 1,
            late_joins: 1,
            dropped_inflight: 2,
            salvaged_inflight: 1,
            straggle_events: 4,
        };
        log.set_fault_stats(stats);
        let r = log.report();
        assert_eq!(r.faults, stats);
        let json = r.to_json().to_string();
        for key in ["\"crashes\"", "\"restarts\"", "\"departures\"", "\"late_joins\""] {
            assert!(json.contains(key), "report json lacks {key}");
        }
        let parsed = Json::parse(&json).unwrap();
        assert_eq!(parsed.get("crashes").as_i64(), Some(3));
        assert_eq!(parsed.get("dropped_inflight").as_i64(), Some(2));
    }

    #[test]
    fn serving_summary_is_additive_and_absent_by_default() {
        // without set_serving the JSON has no serving key at all, so
        // serving-off summaries stay byte-identical to pre-serving builds
        let log = sample_log();
        let json = log.report().to_json().to_string();
        assert!(!json.contains("\"serving\""), "{json}");

        let mut log = sample_log();
        log.set_serving(crate::sim::ServingSummary {
            pulls: 40,
            published: 5,
            lat_p50: 1e-4,
            lat_p99: 2e-4,
            lat_p999: 3e-4,
            stale_steps_mean: 1.5,
            stale_steps_max: 4,
            stale_time_mean: 0.01,
            stale_time_max: 0.05,
        });
        let r = log.report();
        assert_eq!(r.serving.unwrap().pulls, 40);
        let parsed = Json::parse(&r.to_json().to_string()).unwrap();
        let s = parsed.get("serving");
        assert_eq!(s.get("pulls").as_i64(), Some(40));
        assert_eq!(s.get("published").as_i64(), Some(5));
        assert_eq!(s.get("stale_steps_max").as_i64(), Some(4));
        assert!(s.get("lat_p99").as_f64().is_some());
    }

    #[test]
    fn staleness_histogram_edge_cases() {
        // empty log: a single zero bucket, nothing to fold
        let log = MetricsLog::new(1);
        assert_eq!(log.staleness_histogram(8), vec![0]);
        // cap 0 folds EVERYTHING into one bucket
        let mut log = MetricsLog::new(1);
        for &tau in &[0u64, 3, 700] {
            log.record_step(StepRecord {
                step: tau,
                worker: 0,
                passes: 0.0,
                time: 0.0,
                loss: 0.0,
                lr: 0.0,
                staleness: tau,
                wait: 0.0,
            });
        }
        assert_eq!(log.staleness_histogram(0), vec![3]);
        // exact max is preserved even though the tracked tail folds
        let (_, _, max) = log.staleness_summary();
        assert_eq!(max, 700);
    }
}
