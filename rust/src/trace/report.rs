//! `dcasgd report <run-dir>`: render a human-readable digest from the
//! artifacts a traced run writes (`*.summary.json`, `*.timeseries.csv`,
//! `*.trace.jsonl`, `*.trace.json`).
//!
//! The digest is derived purely from files on disk — no artifacts, no
//! model, no replay — so it works on any machine the run dir was copied
//! to. Unknown summary schema versions are flagged instead of guessed at
//! (`schema_version` landed in v2 for exactly this).

use crate::metrics::SUMMARY_SCHEMA_VERSION;
use crate::util::json::Json;
use anyhow::{bail, Context};
use std::fmt::Write as _;
use std::path::Path;

const SPARK: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];

/// Render `values` as a fixed-width unicode sparkline.
pub fn sparkline(values: &[f64], width: usize) -> String {
    if values.is_empty() || width == 0 {
        return String::new();
    }
    // downsample by bucket-mean to at most `width` columns
    let cols: Vec<f64> = if values.len() <= width {
        values.to_vec()
    } else {
        (0..width)
            .map(|i| {
                let lo = i * values.len() / width;
                let hi = ((i + 1) * values.len() / width).max(lo + 1);
                values[lo..hi].iter().sum::<f64>() / (hi - lo) as f64
            })
            .collect()
    };
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for &v in &cols {
        if v.is_finite() {
            lo = lo.min(v);
            hi = hi.max(v);
        }
    }
    if !lo.is_finite() {
        return String::new();
    }
    let span = (hi - lo).max(f64::MIN_POSITIVE);
    cols.iter()
        .map(|&v| {
            if !v.is_finite() {
                return ' ';
            }
            let idx = ((v - lo) / span * (SPARK.len() - 1) as f64).round() as usize;
            SPARK[idx.min(SPARK.len() - 1)]
        })
        .collect()
}

fn human_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.1}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

/// One parsed timeseries column set (only the digest's columns).
struct Timeseries {
    loss_ema: Vec<f64>,
    stale_mean: Vec<f64>,
    rows: usize,
}

fn parse_timeseries(src: &str) -> Option<Timeseries> {
    let mut lines = src.lines();
    let header: Vec<&str> = lines.next()?.split(',').collect();
    let col = |name: &str| header.iter().position(|h| *h == name);
    let (li, si) = (col("loss_ema")?, col("stale_mean")?);
    let mut ts = Timeseries { loss_ema: Vec::new(), stale_mean: Vec::new(), rows: 0 };
    for line in lines {
        let cells: Vec<&str> = line.split(',').collect();
        if cells.len() != header.len() {
            continue;
        }
        ts.rows += 1;
        ts.loss_ema.push(cells[li].parse().unwrap_or(f64::NAN));
        ts.stale_mean.push(cells[si].parse().unwrap_or(f64::NAN));
    }
    Some(ts)
}

fn digest_profile(out: &mut String, profile: &Json) {
    let Some(rows) = profile.as_arr() else { return };
    let total: f64 = rows
        .iter()
        .map(|r| r.get("total_ns").as_f64().unwrap_or(0.0))
        .sum();
    let mut spans: Vec<(&Json, f64)> = rows
        .iter()
        .map(|r| (r, r.get("total_ns").as_f64().unwrap_or(0.0)))
        .collect();
    let _ = writeln!(out, "  phase breakdown (profiled spans):");
    let _ = writeln!(
        out,
        "    {:<14} {:>10} {:>10} {:>10} {:>10} {:>7}",
        "subsystem", "count", "total", "mean", "max", "share"
    );
    for r in rows {
        let count = r.get("count").as_f64().unwrap_or(0.0);
        let tot = r.get("total_ns").as_f64().unwrap_or(0.0);
        let _ = writeln!(
            out,
            "    {:<14} {:>10} {:>10} {:>10} {:>10} {:>6.1}%",
            r.get("subsystem").as_str().unwrap_or("?"),
            count as u64,
            human_ns(tot),
            human_ns(r.get("mean_ns").as_f64().unwrap_or(0.0)),
            human_ns(r.get("max_ns").as_f64().unwrap_or(0.0)),
            if total > 0.0 { tot / total * 100.0 } else { 0.0 },
        );
    }
    // top-k slowest spans (by single-span max duration)
    spans.sort_by(|a, b| {
        let (ma, mb) = (
            a.0.get("max_ns").as_f64().unwrap_or(0.0),
            b.0.get("max_ns").as_f64().unwrap_or(0.0),
        );
        mb.partial_cmp(&ma).unwrap_or(std::cmp::Ordering::Equal)
    });
    let _ = writeln!(out, "  slowest spans:");
    for (i, (r, _)) in spans.iter().take(3).enumerate() {
        let _ = writeln!(
            out,
            "    {}. {:<14} max {}",
            i + 1,
            r.get("subsystem").as_str().unwrap_or("?"),
            human_ns(r.get("max_ns").as_f64().unwrap_or(0.0)),
        );
    }
}

fn digest_one(out: &mut String, dir: &Path, base: &str) -> anyhow::Result<()> {
    let summary_path = dir.join(format!("{base}.summary.json"));
    let src = std::fs::read_to_string(&summary_path)
        .with_context(|| format!("reading {}", summary_path.display()))?;
    let summary = Json::parse(&src)
        .map_err(|e| anyhow::anyhow!("parsing {}: {e}", summary_path.display()))?;

    let _ = writeln!(out, "run: {base}");
    match summary.get("schema_version").as_i64() {
        Some(v) if v == SUMMARY_SCHEMA_VERSION => {}
        Some(v) => {
            let _ = writeln!(
                out,
                "  ! schema_version {v} (this build reads v{SUMMARY_SCHEMA_VERSION}); \
                 fields may be missing"
            );
        }
        None => {
            let _ = writeln!(out, "  ! pre-v2 summary (no schema_version)");
        }
    }

    let rep = summary.get("report");
    let cfg = summary.get("config");
    if let (Some(algo), Some(workers)) =
        (cfg.get("algorithm").as_str(), cfg.get("workers").as_i64())
    {
        let _ = writeln!(out, "  config: {algo}, {workers} workers");
    }
    let _ = writeln!(
        out,
        "  steps: {}  sim time: {:.2}s  wall: {:.2}s",
        rep.get("total_steps").as_i64().unwrap_or(0),
        rep.get("total_time").as_f64().unwrap_or(0.0),
        rep.get("wall_secs").as_f64().unwrap_or(0.0),
    );
    let _ = writeln!(
        out,
        "  train loss (EMA): {:.4}  test error: {:.4}",
        rep.get("final_train_loss").as_f64().unwrap_or(f64::NAN),
        rep.get("final_test_error").as_f64().unwrap_or(f64::NAN),
    );
    let _ = writeln!(
        out,
        "  staleness: mean {:.2}  p99 {:.0}  max {}   gate wait: {:.2}s   comm: {} bytes",
        rep.get("staleness_mean").as_f64().unwrap_or(0.0),
        rep.get("staleness_p99").as_f64().unwrap_or(0.0),
        rep.get("staleness_max").as_i64().unwrap_or(0),
        rep.get("wait_total").as_f64().unwrap_or(0.0),
        rep.get("comm_bytes").as_i64().unwrap_or(0),
    );
    let crashes = rep.get("crashes").as_i64().unwrap_or(0);
    if crashes > 0 || rep.get("late_joins").as_i64().unwrap_or(0) > 0 {
        let _ = writeln!(
            out,
            "  faults: {} crashes, {} restarts, {} departures, {} late joins, \
             {} dropped / {} salvaged in-flight, {} straggles",
            crashes,
            rep.get("restarts").as_i64().unwrap_or(0),
            rep.get("departures").as_i64().unwrap_or(0),
            rep.get("late_joins").as_i64().unwrap_or(0),
            rep.get("dropped_inflight").as_i64().unwrap_or(0),
            rep.get("salvaged_inflight").as_i64().unwrap_or(0),
            rep.get("straggle_events").as_i64().unwrap_or(0),
        );
    }

    if summary.get("profile").as_arr().is_some() {
        digest_profile(out, summary.get("profile"));
    }

    if let Ok(csv) = std::fs::read_to_string(dir.join(format!("{base}.timeseries.csv"))) {
        if let Some(ts) = parse_timeseries(&csv) {
            let _ = writeln!(out, "  timeseries: {} samples", ts.rows);
            let _ = writeln!(out, "    staleness over time: {}", sparkline(&ts.stale_mean, 60));
            let _ = writeln!(out, "    loss EMA over time:  {}", sparkline(&ts.loss_ema, 60));
        }
    }

    if let Ok(jsonl) = std::fs::read_to_string(dir.join(format!("{base}.trace.jsonl"))) {
        let _ = writeln!(out, "  events: {} (trace.jsonl)", jsonl.lines().count());
    }
    let chrome_path = dir.join(format!("{base}.trace.json"));
    if let Ok(chrome) = std::fs::read_to_string(&chrome_path) {
        let doc = Json::parse(&chrome)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", chrome_path.display()))?;
        let n = doc.get("traceEvents").as_arr().map(|a| a.len()).unwrap_or(0);
        let _ = writeln!(out, "  chrome trace: {n} records (load {base}.trace.json in Perfetto)");
    }
    Ok(())
}

/// Render the digest for every run (`*.summary.json`) found in `dir`.
pub fn render_digest(dir: &Path) -> anyhow::Result<String> {
    let mut bases: Vec<String> = std::fs::read_dir(dir)
        .with_context(|| format!("reading run dir {}", dir.display()))?
        .filter_map(|e| e.ok())
        .filter_map(|e| {
            e.file_name()
                .to_str()?
                .strip_suffix(".summary.json")
                .map(str::to_string)
        })
        .collect();
    if bases.is_empty() {
        bail!("no *.summary.json found in {}", dir.display());
    }
    bases.sort();
    let mut out = String::new();
    for base in &bases {
        digest_one(&mut out, dir, base)?;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparkline_shapes() {
        assert_eq!(sparkline(&[], 10), "");
        let s = sparkline(&[0.0, 1.0, 2.0, 3.0], 10);
        assert_eq!(s.chars().count(), 4);
        assert!(s.starts_with('▁') && s.ends_with('█'), "{s}");
        // downsampling to a fixed width
        let long: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        assert_eq!(sparkline(&long, 60).chars().count(), 60);
        // a flat series renders at the low band without dividing by zero
        let flat = sparkline(&[2.0, 2.0, 2.0], 10);
        assert_eq!(flat.chars().count(), 3);
    }

    #[test]
    fn digest_renders_from_written_artifacts() {
        let dir = std::env::temp_dir().join(format!("dcasgd_report_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // minimal summary + timeseries, as write_run_full lays them out
        let summary = Json::obj(vec![
            ("schema_version", SUMMARY_SCHEMA_VERSION.into()),
            ("config", Json::obj(vec![("algorithm", "asgd".into()), ("workers", 4i64.into())])),
            (
                "report",
                Json::obj(vec![
                    ("total_steps", 100i64.into()),
                    ("total_time", 12.5.into()),
                    ("wall_secs", 0.2.into()),
                    ("final_train_loss", 0.7.into()),
                    ("final_test_error", 0.25.into()),
                    ("staleness_mean", 1.5.into()),
                    ("staleness_p99", 4.0.into()),
                    ("staleness_max", 6i64.into()),
                    ("wait_total", 0.0.into()),
                    ("comm_bytes", 0i64.into()),
                    ("crashes", 2i64.into()),
                    ("restarts", 1i64.into()),
                    ("departures", 1i64.into()),
                    ("late_joins", 0i64.into()),
                    ("dropped_inflight", 1i64.into()),
                    ("salvaged_inflight", 0i64.into()),
                    ("straggle_events", 0i64.into()),
                ]),
            ),
            (
                "profile",
                Json::arr(vec![Json::obj(vec![
                    ("subsystem", "shard_lock".into()),
                    ("count", 10i64.into()),
                    ("total_ns", 5000i64.into()),
                    ("mean_ns", 500.0.into()),
                    ("max_ns", 900i64.into()),
                ])]),
            ),
        ]);
        std::fs::write(dir.join("run.summary.json"), summary.to_string()).unwrap();
        std::fs::write(
            dir.join("run.timeseries.csv"),
            format!("{}\n10,1.0,0.1,1.5,4,10,1.2,3,100,2\n", crate::trace::TIMESERIES_HEADER),
        )
        .unwrap();
        let digest = render_digest(&dir).unwrap();
        assert!(digest.contains("run: run"), "{digest}");
        assert!(digest.contains("steps: 100"), "{digest}");
        assert!(digest.contains("shard_lock"), "{digest}");
        assert!(digest.contains("2 crashes"), "{digest}");
        assert!(digest.contains("staleness over time"), "{digest}");
        // empty dir errors
        let empty = dir.join("empty");
        std::fs::create_dir_all(&empty).unwrap();
        assert!(render_digest(&empty).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
