//! Subsystem profiling: RAII scoped timers folded into per-subsystem
//! histograms.
//!
//! The registry is a process-global table of atomics (count / total /
//! max / log2-bucket histogram per subsystem), gated by one relaxed
//! `AtomicBool`. Disabled (the default), a span costs a single relaxed
//! load and a branch — no clock read, no allocation — which the hotpath
//! bench pins as unmeasurable. Enabled, each span is two monotonic clock
//! reads plus a handful of relaxed atomic adds; still zero allocation in
//! steady state.
//!
//! Spans never touch the training math, so profiling on/off is bitwise
//! inert by construction (tracing observes, never perturbs).

use crate::util::json::Json;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering::Relaxed};
use std::time::Instant;

/// The instrumented subsystems, in registry order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Subsystem {
    /// PS shard write-lock acquisition (`ps::shard`).
    ShardLock,
    /// One claimed job execution on the compute pool (`util::pool`).
    PoolJob,
    /// Gradient codec encode (`compress::WorkerCompressor`).
    CodecEncode,
    /// Wire payload decode (`compress::WirePayload`).
    CodecDecode,
    /// Fused decode→compensate→apply shard slice (`ps`).
    FusedApply,
    /// One protocol-gate release pass (`sim::scheduler::release_gated`):
    /// the indexed fast path or the O(M) scan reference.
    GateRelease,
    /// One fleet-membership transition (crash kill / rejoin), including
    /// the live-clock multiset and bitset maintenance (`sim::fleet`).
    Membership,
}

pub const SUBSYSTEMS: [Subsystem; 7] = [
    Subsystem::ShardLock,
    Subsystem::PoolJob,
    Subsystem::CodecEncode,
    Subsystem::CodecDecode,
    Subsystem::FusedApply,
    Subsystem::GateRelease,
    Subsystem::Membership,
];

impl Subsystem {
    pub fn name(&self) -> &'static str {
        match self {
            Subsystem::ShardLock => "shard_lock",
            Subsystem::PoolJob => "pool_job",
            Subsystem::CodecEncode => "codec_encode",
            Subsystem::CodecDecode => "codec_decode",
            Subsystem::FusedApply => "fused_apply",
            Subsystem::GateRelease => "gate_release",
            Subsystem::Membership => "membership",
        }
    }

    fn index(&self) -> usize {
        *self as usize
    }
}

/// Log2 duration buckets: bucket i counts spans with
/// `2^i <= ns < 2^(i+1)` (bucket 0 also holds sub-nanosecond spans).
pub const BUCKETS: usize = 40;

struct Cell {
    count: AtomicU64,
    total_ns: AtomicU64,
    max_ns: AtomicU64,
    hist: [AtomicU64; BUCKETS],
}

impl Cell {
    const fn new() -> Self {
        #[allow(clippy::declare_interior_mutable_const)]
        const Z: AtomicU64 = AtomicU64::new(0);
        Self { count: Z, total_ns: Z, max_ns: Z, hist: [Z; BUCKETS] }
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static CELLS: [Cell; SUBSYSTEMS.len()] = [
    Cell::new(),
    Cell::new(),
    Cell::new(),
    Cell::new(),
    Cell::new(),
    Cell::new(),
    Cell::new(),
];

/// Turn span collection on/off (per run; the trainer resets + enables).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Relaxed);
}

pub fn is_enabled() -> bool {
    ENABLED.load(Relaxed)
}

/// Zero every counter (start of a profiled run).
pub fn reset() {
    for cell in &CELLS {
        cell.count.store(0, Relaxed);
        cell.total_ns.store(0, Relaxed);
        cell.max_ns.store(0, Relaxed);
        for b in &cell.hist {
            b.store(0, Relaxed);
        }
    }
}

fn record(sub: usize, ns: u64) {
    let cell = &CELLS[sub];
    cell.count.fetch_add(1, Relaxed);
    cell.total_ns.fetch_add(ns, Relaxed);
    cell.max_ns.fetch_max(ns, Relaxed);
    let bucket = (64 - ns.leading_zeros() as usize).saturating_sub(1).min(BUCKETS - 1);
    cell.hist[bucket].fetch_add(1, Relaxed);
}

/// RAII span: records its subsystem's histogram on drop.
pub struct Span {
    sub: usize,
    start: Instant,
}

impl Drop for Span {
    fn drop(&mut self) {
        let ns = self.start.elapsed().as_nanos() as u64;
        record(self.sub, ns);
    }
}

/// Open a profiling span; `None` (free) when profiling is off.
#[inline]
pub fn span(sub: Subsystem) -> Option<Span> {
    if !ENABLED.load(Relaxed) {
        return None;
    }
    Some(Span { sub: sub.index(), start: Instant::now() })
}

/// Aggregated statistics for one subsystem.
#[derive(Clone, Debug)]
pub struct SubsystemStats {
    pub name: &'static str,
    pub count: u64,
    pub total_ns: u64,
    pub max_ns: u64,
    /// Non-empty log2 buckets as `(bucket_index, count)`.
    pub hist: Vec<(usize, u64)>,
}

impl SubsystemStats {
    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total_ns as f64 / self.count as f64
        }
    }
}

/// Read every subsystem's counters (subsystems with zero spans included).
pub fn snapshot() -> Vec<SubsystemStats> {
    SUBSYSTEMS
        .iter()
        .map(|s| {
            let cell = &CELLS[s.index()];
            let hist = cell
                .hist
                .iter()
                .enumerate()
                .filter_map(|(i, b)| {
                    let n = b.load(Relaxed);
                    (n > 0).then_some((i, n))
                })
                .collect();
            SubsystemStats {
                name: s.name(),
                count: cell.count.load(Relaxed),
                total_ns: cell.total_ns.load(Relaxed),
                max_ns: cell.max_ns.load(Relaxed),
                hist,
            }
        })
        .collect()
}

/// The summary-JSON profile block: one object per subsystem.
pub fn snapshot_json() -> Json {
    Json::Arr(
        snapshot()
            .into_iter()
            .map(|s| {
                Json::obj(vec![
                    ("subsystem", s.name.into()),
                    ("count", (s.count as i64).into()),
                    ("total_ns", (s.total_ns as i64).into()),
                    ("mean_ns", s.mean_ns().into()),
                    ("max_ns", (s.max_ns as i64).into()),
                    (
                        "hist_log2",
                        Json::Arr(
                            s.hist
                                .iter()
                                .map(|&(b, n)| {
                                    Json::Arr(vec![(b as i64).into(), (n as i64).into()])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    // one test: the registry is process-global, so splitting these into
    // separate #[test]s would race under the parallel test runner
    #[test]
    fn span_gating_and_histogram() {
        // disabled: span() is None and nothing is recorded
        set_enabled(false);
        reset();
        {
            let s = span(Subsystem::ShardLock);
            assert!(s.is_none());
        }
        assert_eq!(snapshot()[Subsystem::ShardLock as usize].count, 0);

        // enabled: one span lands in exactly one histogram bucket
        set_enabled(true);
        {
            let _s = span(Subsystem::CodecEncode);
            std::hint::black_box(());
        }
        set_enabled(false);
        let snap = snapshot();
        let enc = &snap[Subsystem::CodecEncode as usize];
        assert_eq!(enc.name, "codec_encode");
        assert_eq!(enc.count, 1);
        assert_eq!(enc.hist.iter().map(|(_, n)| n).sum::<u64>(), 1);
        assert!(enc.max_ns >= enc.total_ns / enc.count.max(1));
        let j = snapshot_json().to_string();
        assert!(j.contains("\"subsystem\":\"codec_encode\""), "{j}");
        reset();
    }
}
