//! Chrome trace-event serialization: renders a merged event stream as a
//! `trace.json` loadable in Perfetto (<https://ui.perfetto.dev>) or
//! `chrome://tracing`.
//!
//! Track layout:
//!
//! * pid 1 `workers` — one thread per worker. Gate waits render as
//!   balanced `B`/`E` span pairs; commits, crashes, joins, departures,
//!   straggles, and pipeline events render as instants (`i`).
//! * pid 2 `ps` — one counter track (`C`) per parameter-server shard,
//!   fed by the periodic `ShardVersion` samples.
//! * pid 3 `driver` — worker-less events (barrier folds, checkpoints).
//!
//! Timestamps are **virtual time** in microseconds (the DES clock), so
//! the rendered timeline is the simulated schedule, not host wall time.
//! Output invariants (pinned by the golden test in `tests/trace.rs`):
//! every `B` has a matching `E` (open spans are closed at the final
//! timestamp) and events are sorted by non-decreasing `ts`.

use super::{EventKind, TraceEvent};
use crate::util::json::Json;

const PID_WORKERS: i64 = 1;
const PID_PS: i64 = 2;
const PID_DRIVER: i64 = 3;

fn us(t: f64) -> f64 {
    t * 1e6
}

struct ChromeEv {
    ts: f64,
    json: Json,
}

#[allow(clippy::too_many_arguments)]
fn ev(
    name: &str,
    ph: &str,
    ts: f64,
    pid: i64,
    tid: i64,
    scope: Option<&str>,
    args: Vec<(&str, Json)>,
) -> ChromeEv {
    let mut fields: Vec<(&str, Json)> = vec![
        ("name", name.into()),
        ("ph", ph.into()),
        ("ts", ts.into()),
        ("pid", pid.into()),
        ("tid", tid.into()),
        ("cat", "dcasgd".into()),
    ];
    if let Some(s) = scope {
        fields.push(("s", s.into()));
    }
    if !args.is_empty() {
        fields.push(("args", Json::obj(args)));
    }
    ChromeEv { ts, json: Json::obj(fields) }
}

fn meta(name: &str, pid: i64, tid: Option<i64>, label: String) -> ChromeEv {
    let mut fields: Vec<(&str, Json)> = vec![
        ("name", name.into()),
        ("ph", "M".into()),
        ("ts", 0.0.into()),
        ("pid", pid.into()),
    ];
    if let Some(t) = tid {
        fields.push(("tid", t.into()));
    }
    fields.push(("args", Json::obj(vec![("name", Json::Str(label))])));
    ChromeEv { ts: 0.0, json: Json::obj(fields) }
}

/// Render the merged event stream as a Chrome trace-event document.
pub fn render(events: &[TraceEvent]) -> Json {
    let mut out: Vec<ChromeEv> = Vec::with_capacity(events.len() + 16);
    let mut workers_seen: Vec<usize> = Vec::new();
    let mut shards_seen: Vec<usize> = Vec::new();
    // workers with an open gate-wait span (Perfetto requires balanced B/E)
    let mut open_wait: Vec<usize> = Vec::new();
    let mut max_ts: f64 = 0.0;

    for e in events {
        let ts = us(e.t);
        max_ts = max_ts.max(ts);
        if let Some(w) = e.worker {
            if e.kind != EventKind::ShardVersion && !workers_seen.contains(&w) {
                workers_seen.push(w);
            }
        }
        let tid = e.worker.unwrap_or(0) as i64;
        let mut args: Vec<(&str, Json)> = Vec::new();
        if let Some(tau) = e.tau {
            args.push(("tau", (tau as i64).into()));
        }
        if let Some(ep) = e.epoch {
            args.push(("epoch", (ep as i64).into()));
        }
        if let Some(v) = e.value {
            args.push(("value", v.into()));
        }
        match e.kind {
            EventKind::GateWaitBegin => {
                let w = e.worker.unwrap_or(0);
                // a second Begin without an End would unbalance the track
                if !open_wait.contains(&w) {
                    open_wait.push(w);
                    out.push(ev("gate_wait", "B", ts, PID_WORKERS, tid, None, args));
                }
            }
            EventKind::GateWaitEnd => {
                let w = e.worker.unwrap_or(0);
                if let Some(i) = open_wait.iter().position(|&ow| ow == w) {
                    open_wait.swap_remove(i);
                    out.push(ev("gate_wait", "E", ts, PID_WORKERS, tid, None, args));
                }
            }
            EventKind::ShardVersion => {
                let shard = e.worker.unwrap_or(0);
                if !shards_seen.contains(&shard) {
                    shards_seen.push(shard);
                }
                out.push(ev(
                    "shard_version",
                    "C",
                    ts,
                    PID_PS,
                    shard as i64,
                    None,
                    vec![("version", e.value.unwrap_or(0.0).into())],
                ));
            }
            EventKind::BarrierRelease | EventKind::Checkpoint => {
                out.push(ev(e.kind.name(), "i", ts, PID_DRIVER, 0, Some("p"), args));
            }
            _ => {
                out.push(ev(e.kind.name(), "i", ts, PID_WORKERS, tid, Some("t"), args));
            }
        }
    }

    // close any still-open gate waits so every B has its E
    for &w in &open_wait {
        out.push(ev("gate_wait", "E", max_ts, PID_WORKERS, w as i64, None, vec![]));
    }

    // metadata first (ts 0), then events in timestamp order
    let mut all: Vec<ChromeEv> = Vec::with_capacity(out.len() + 8);
    all.push(meta("process_name", PID_WORKERS, None, "workers".into()));
    all.push(meta("process_name", PID_PS, None, "ps".into()));
    all.push(meta("process_name", PID_DRIVER, None, "driver".into()));
    workers_seen.sort_unstable();
    for w in workers_seen {
        all.push(meta("thread_name", PID_WORKERS, Some(w as i64), format!("worker {w}")));
    }
    shards_seen.sort_unstable();
    for s in shards_seen {
        all.push(meta("thread_name", PID_PS, Some(s as i64), format!("shard {s}")));
    }
    all.extend(out);
    all.sort_by(|a, b| a.ts.partial_cmp(&b.ts).unwrap_or(std::cmp::Ordering::Equal));

    Json::obj(vec![
        ("traceEvents", Json::Arr(all.into_iter().map(|e| e.json).collect())),
        ("displayTimeUnit", "ms".into()),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(kind: EventKind, t: f64, worker: Option<usize>, value: Option<f64>) -> TraceEvent {
        TraceEvent { kind, t, wall: 0.0, worker, epoch: None, tau: None, value }
    }

    #[test]
    fn spans_balance_and_timestamps_are_monotone() {
        let events = vec![
            mk(EventKind::Pull, 0.0, Some(0), None),
            mk(EventKind::GateWaitBegin, 1.0, Some(0), None),
            mk(EventKind::GateWaitEnd, 1.5, Some(0), Some(0.5)),
            mk(EventKind::PushCommit, 1.5, Some(0), None),
            // worker 1 never gets released: render() must close the span
            mk(EventKind::GateWaitBegin, 2.0, Some(1), None),
            mk(EventKind::ShardVersion, 2.5, Some(0), Some(7.0)),
        ];
        let doc = render(&events);
        let s = doc.to_string();
        let parsed = Json::parse(&s).unwrap();
        let evs = parsed.get("traceEvents").as_arr().unwrap();
        let mut last_ts = f64::NEG_INFINITY;
        let mut depth = 0i64;
        for e in evs {
            let ts = e.get("ts").as_f64().unwrap();
            assert!(ts >= last_ts, "timestamps must be non-decreasing");
            last_ts = ts;
            match e.get("ph").as_str() {
                Some("B") => depth += 1,
                Some("E") => {
                    depth -= 1;
                    assert!(depth >= 0, "E without matching B");
                }
                _ => {}
            }
        }
        assert_eq!(depth, 0, "unbalanced B/E pairs");
        assert!(s.contains("\"shard_version\""));
        assert!(s.contains("\"displayTimeUnit\""));
    }
}
