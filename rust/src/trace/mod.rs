//! Run-trace observability: structured event tracing, subsystem profiling,
//! and time-series telemetry (`[trace]` section).
//!
//! The layer is off by default and **bitwise-inert**: enabling it changes
//! no schedule decision, no RNG draw, and no floating-point operation, so
//! trace-on and trace-off runs produce identical `TrainReport`s and
//! checkpoint bytes (pinned by `tests/trace.rs`). Tracing observes, never
//! perturbs.
//!
//! Three data planes, all buffered per producer with no locks on the hot
//! path:
//!
//! * **Events** ([`TraceEvent`]): typed records from the scheduler (gate
//!   waits, crashes, joins, departures, straggles) and the driver (pulls,
//!   push commits, barrier releases, pipeline enqueue/flush, checkpoints),
//!   each carrying virtual time, wall time, worker id, epoch, and τ.
//!   Written as JSONL (`*.trace.jsonl`) and Chrome trace-event format
//!   (`*.trace.json`, loadable in Perfetto / `chrome://tracing` — see
//!   [`chrome`]).
//! * **Profiling** ([`profile`]): RAII span guards around PS shard-lock
//!   acquisition, pool job execution, codec encode/decode, and fused-apply
//!   slices; u64 monotonic-clock deltas folded into per-subsystem
//!   histograms (atomics only, zero steady-state allocation) surfaced in
//!   the summary JSON.
//! * **Time series** ([`TimeseriesRow`]): every `/trace/sample_every`
//!   steps the driver snapshots loss EMA, live-worker count, staleness
//!   deltas, comm-bytes rate, and event-queue depth into
//!   `*.timeseries.csv`.
//!
//! `dcasgd report <run-dir>` ([`report`]) renders a human-readable digest
//! from the written artifacts.

pub mod chrome;
pub mod profile;
pub mod report;

use crate::util::json::Json;
use std::time::Instant;

/// What happened. The scheduler-side kinds reconcile 1:1 with
/// [`crate::sim::FaultStats`] counters (pinned by `tests/trace.rs`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// Driver staged a model pull for a worker.
    Pull,
    /// A gradient was committed to the PS (τ in `tau`, global step in
    /// `epoch`).
    PushCommit,
    /// Worker finished compute and is waiting on its protocol gate.
    GateWaitBegin,
    /// Worker's gate released (`value` = simulated seconds waited).
    GateWaitEnd,
    /// A synchronous round folded at the barrier (`value` = fold size).
    BarrierRelease,
    /// Worker crashed (`value` = 1.0 if it will restart, 0.0 if the crash
    /// is permanent under the departure draw).
    Crash,
    /// A crashed worker's in-flight gradient was discarded (drop policy).
    InflightDropped,
    /// A crashed worker's in-flight gradient landed anyway (salvage).
    InflightSalvaged,
    /// Worker rejoined after a crash.
    Restart,
    /// A cold worker joined late (elastic membership).
    Join,
    /// Worker left permanently.
    Depart,
    /// A straggle window began (`value` = slowdown factor).
    Straggle,
    /// Driver enqueued a gradient evaluation into the pipeline.
    PipelineEnqueue,
    /// The pipeline flushed (a commit arrived before its evaluation).
    PipelineFlush,
    /// A checkpoint was captured.
    Checkpoint,
    /// PS shard version counter sample (`worker` = shard index,
    /// `value` = version); rendered as a Perfetto counter track.
    ShardVersion,
}

impl EventKind {
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Pull => "pull",
            EventKind::PushCommit => "push_commit",
            EventKind::GateWaitBegin => "gate_wait_begin",
            EventKind::GateWaitEnd => "gate_wait_end",
            EventKind::BarrierRelease => "barrier_release",
            EventKind::Crash => "crash",
            EventKind::InflightDropped => "inflight_dropped",
            EventKind::InflightSalvaged => "inflight_salvaged",
            EventKind::Restart => "restart",
            EventKind::Join => "join",
            EventKind::Depart => "depart",
            EventKind::Straggle => "straggle",
            EventKind::PipelineEnqueue => "pipeline_enqueue",
            EventKind::PipelineFlush => "pipeline_flush",
            EventKind::Checkpoint => "checkpoint",
            EventKind::ShardVersion => "shard_version",
        }
    }
}

/// One structured trace record.
#[derive(Clone, Copy, Debug)]
pub struct TraceEvent {
    pub kind: EventKind,
    /// Virtual (simulated) seconds.
    pub t: f64,
    /// Wall-clock seconds since the producer's buffer was created.
    pub wall: f64,
    pub worker: Option<usize>,
    /// Context-dependent counter: global step for `PushCommit`, the
    /// worker's membership epoch for fault events.
    pub epoch: Option<u64>,
    /// Staleness τ, where the event carries one.
    pub tau: Option<u64>,
    /// Kind-specific payload (see [`EventKind`] docs).
    pub value: Option<f64>,
}

impl TraceEvent {
    pub fn to_json(&self) -> Json {
        let mut fields: Vec<(&str, Json)> = vec![
            ("kind", self.kind.name().into()),
            ("t", self.t.into()),
            ("wall", self.wall.into()),
        ];
        if let Some(w) = self.worker {
            fields.push(("worker", (w as i64).into()));
        }
        if let Some(e) = self.epoch {
            fields.push(("epoch", (e as i64).into()));
        }
        if let Some(tau) = self.tau {
            fields.push(("tau", (tau as i64).into()));
        }
        if let Some(v) = self.value {
            fields.push(("value", v.into()));
        }
        Json::obj(fields)
    }
}

/// Per-producer event buffer: a plain `Vec` push per event, no locks, no
/// cross-thread sharing (the DES and the driver are each single-producer).
#[derive(Debug)]
pub struct EventBuf {
    start: Instant,
    events: Vec<TraceEvent>,
}

impl EventBuf {
    pub fn new() -> Self {
        Self { start: Instant::now(), events: Vec::with_capacity(1024) }
    }

    /// Wall-clock seconds since this buffer was created.
    pub fn wall(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn push(&mut self, mut ev: TraceEvent) {
        ev.wall = self.wall();
        self.events.push(ev);
    }

    /// Convenience emit without pre-filling the wall stamp.
    #[allow(clippy::too_many_arguments)]
    pub fn emit(
        &mut self,
        kind: EventKind,
        t: f64,
        worker: Option<usize>,
        epoch: Option<u64>,
        tau: Option<u64>,
        value: Option<f64>,
    ) {
        self.push(TraceEvent { kind, t, wall: 0.0, worker, epoch, tau, value });
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn drain(&mut self) -> Vec<TraceEvent> {
        std::mem::take(&mut self.events)
    }
}

impl Default for EventBuf {
    fn default() -> Self {
        Self::new()
    }
}

/// One periodic telemetry sample (a `*.timeseries.csv` row).
#[derive(Clone, Copy, Debug)]
pub struct TimeseriesRow {
    /// Global step at the sample point.
    pub step: u64,
    /// Virtual (simulated) seconds.
    pub t: f64,
    /// Wall seconds since the run started.
    pub wall: f64,
    /// Downsampling-proof running loss EMA (see `MetricsLog::loss_ema`).
    pub loss_ema: f64,
    pub live_workers: usize,
    /// Number of commits since the previous sample.
    pub stale_n: u64,
    /// Mean τ over the window.
    pub stale_mean: f64,
    /// Max τ over the window.
    pub stale_max: u64,
    /// Comm bytes transferred since the previous sample.
    pub comm_bytes_delta: u64,
    /// Scheduler event-queue depth at the sample point.
    pub queue_depth: usize,
}

pub const TIMESERIES_HEADER: &str =
    "step,time,wall_secs,loss_ema,live_workers,stale_n,stale_mean,stale_max,comm_bytes_delta,queue_depth";

impl TimeseriesRow {
    pub fn to_csv(&self) -> String {
        format!(
            "{},{:.6},{:.6},{:.6},{},{},{:.4},{},{},{}",
            self.step,
            self.t,
            self.wall,
            self.loss_ema,
            self.live_workers,
            self.stale_n,
            self.stale_mean,
            self.stale_max,
            self.comm_bytes_delta,
            self.queue_depth
        )
    }
}

/// Driver-side trace state for one run: the driver's own event buffer,
/// the collected time-series rows, and the inter-sample accumulators.
///
/// Optional subsystems (topology uplink meters, the serving plane) append
/// extra CSV columns via [`Self::set_extra_cols`] +
/// [`Self::sample_with`]; with none declared the emitted CSV is
/// byte-identical to pre-extension builds.
#[derive(Debug)]
pub struct RunTrace {
    pub events: bool,
    pub chrome: bool,
    pub sample_every: usize,
    pub buf: EventBuf,
    pub rows: Vec<TimeseriesRow>,
    /// Names of appended telemetry columns (empty = base schema only).
    pub extra_cols: Vec<String>,
    /// One appended-value vector per row, `extra_cols.len()` wide.
    pub extra_rows: Vec<Vec<f64>>,
    // window accumulators (reset at each sample)
    win_stale_n: u64,
    win_stale_sum: u64,
    win_stale_max: u64,
    last_comm_bytes: u64,
}

impl RunTrace {
    pub fn new(cfg: &crate::config::TraceConfig) -> Self {
        Self {
            events: cfg.events,
            chrome: cfg.chrome_trace,
            sample_every: cfg.sample_every.max(1),
            buf: EventBuf::new(),
            rows: Vec::new(),
            extra_cols: Vec::new(),
            extra_rows: Vec::new(),
            win_stale_n: 0,
            win_stale_sum: 0,
            win_stale_max: 0,
            last_comm_bytes: 0,
        }
    }

    /// Declare appended telemetry columns. Call once, before the first
    /// sample; every subsequent [`Self::sample_with`] must supply exactly
    /// one value per declared column.
    pub fn set_extra_cols(&mut self, cols: Vec<String>) {
        debug_assert!(self.rows.is_empty(), "extra columns declared after sampling began");
        self.extra_cols = cols;
    }

    /// Fold one committed step's τ into the current sampling window.
    pub fn observe_commit(&mut self, tau: u64) {
        self.win_stale_n += 1;
        self.win_stale_sum += tau;
        self.win_stale_max = self.win_stale_max.max(tau);
    }

    /// Close the current window into a row (base schema only).
    #[allow(clippy::too_many_arguments)]
    pub fn sample(
        &mut self,
        step: u64,
        t: f64,
        loss_ema: f64,
        live_workers: usize,
        comm_bytes_total: u64,
        queue_depth: usize,
    ) {
        self.sample_with(step, t, loss_ema, live_workers, comm_bytes_total, queue_depth, Vec::new());
    }

    /// Close the current window into a row, appending `extra` values for
    /// the declared extension columns (pass an empty vec with none).
    #[allow(clippy::too_many_arguments)]
    pub fn sample_with(
        &mut self,
        step: u64,
        t: f64,
        loss_ema: f64,
        live_workers: usize,
        comm_bytes_total: u64,
        queue_depth: usize,
        extra: Vec<f64>,
    ) {
        debug_assert_eq!(extra.len(), self.extra_cols.len(), "extra values vs declared columns");
        self.extra_rows.push(extra);
        let stale_mean = if self.win_stale_n > 0 {
            self.win_stale_sum as f64 / self.win_stale_n as f64
        } else {
            0.0
        };
        self.rows.push(TimeseriesRow {
            step,
            t,
            wall: self.buf.wall(),
            loss_ema,
            live_workers,
            stale_n: self.win_stale_n,
            stale_mean,
            stale_max: self.win_stale_max,
            comm_bytes_delta: comm_bytes_total.saturating_sub(self.last_comm_bytes),
            queue_depth,
        });
        self.win_stale_n = 0;
        self.win_stale_sum = 0;
        self.win_stale_max = 0;
        self.last_comm_bytes = comm_bytes_total;
    }
}

/// What a traced run hands back to the trainer for artifact writing: the
/// merged (driver + scheduler) event stream, the time-series rows, and
/// any appended extension columns.
#[derive(Debug, Default)]
pub struct TraceOut {
    pub events: Vec<TraceEvent>,
    pub rows: Vec<TimeseriesRow>,
    pub extra_cols: Vec<String>,
    pub extra_rows: Vec<Vec<f64>>,
}

/// Merge event streams (driver + scheduler) into virtual-time order.
/// The sort is stable, so same-timestamp events keep producer order.
pub fn merge_events(mut streams: Vec<Vec<TraceEvent>>) -> Vec<TraceEvent> {
    let mut all: Vec<TraceEvent> = streams.iter_mut().flat_map(std::mem::take).collect();
    all.sort_by(|a, b| a.t.partial_cmp(&b.t).unwrap_or(std::cmp::Ordering::Equal));
    all
}

/// Serialize events as JSON Lines (one record per line).
pub fn events_to_jsonl(events: &[TraceEvent]) -> String {
    let mut out = String::with_capacity(events.len() * 96);
    for ev in events {
        out.push_str(&ev.to_json().to_string());
        out.push('\n');
    }
    out
}

/// Serialize time-series rows as CSV (header + one row per sample).
pub fn rows_to_csv(rows: &[TimeseriesRow]) -> String {
    rows_to_csv_with(rows, &[], &[])
}

/// Serialize time-series rows as CSV with appended extension columns.
/// With `extra_cols` empty the output is byte-identical to
/// [`rows_to_csv`], so runs without extensions keep their pinned CSVs.
pub fn rows_to_csv_with(
    rows: &[TimeseriesRow],
    extra_cols: &[String],
    extra_rows: &[Vec<f64>],
) -> String {
    debug_assert!(extra_cols.is_empty() || extra_rows.len() == rows.len());
    let mut out = String::with_capacity(rows.len() * (64 + extra_cols.len() * 12) + 96);
    out.push_str(TIMESERIES_HEADER);
    for c in extra_cols {
        out.push(',');
        out.push_str(c);
    }
    out.push('\n');
    for (i, r) in rows.iter().enumerate() {
        out.push_str(&r.to_csv());
        if !extra_cols.is_empty() {
            for v in &extra_rows[i] {
                out.push_str(&format!(",{v:.6}"));
            }
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_json_has_kind_and_time() {
        let mut buf = EventBuf::new();
        buf.emit(EventKind::PushCommit, 1.25, Some(3), Some(7), Some(2), None);
        let evs = buf.drain();
        assert_eq!(evs.len(), 1);
        let j = evs[0].to_json().to_string();
        assert!(j.contains("\"kind\":\"push_commit\""), "{j}");
        assert!(j.contains("\"worker\":3"), "{j}");
        assert!(j.contains("\"tau\":2"), "{j}");
        assert!(evs[0].wall >= 0.0);
    }

    #[test]
    fn merge_orders_by_virtual_time() {
        let mk = |t: f64, kind| TraceEvent {
            kind,
            t,
            wall: 0.0,
            worker: None,
            epoch: None,
            tau: None,
            value: None,
        };
        let a = vec![mk(0.5, EventKind::Pull), mk(2.0, EventKind::PushCommit)];
        let b = vec![mk(1.0, EventKind::Crash)];
        let merged = merge_events(vec![a, b]);
        let ts: Vec<f64> = merged.iter().map(|e| e.t).collect();
        assert_eq!(ts, vec![0.5, 1.0, 2.0]);
    }

    #[test]
    fn timeseries_window_accumulates_and_resets() {
        let cfg = crate::config::TraceConfig { enabled: true, ..Default::default() };
        let mut rt = RunTrace::new(&cfg);
        rt.observe_commit(2);
        rt.observe_commit(4);
        rt.sample(10, 1.0, 0.5, 4, 1000, 3);
        rt.observe_commit(0);
        rt.sample(20, 2.0, 0.4, 3, 1500, 2);
        assert_eq!(rt.rows.len(), 2);
        assert_eq!(rt.rows[0].stale_n, 2);
        assert!((rt.rows[0].stale_mean - 3.0).abs() < 1e-12);
        assert_eq!(rt.rows[0].stale_max, 4);
        assert_eq!(rt.rows[0].comm_bytes_delta, 1000);
        assert_eq!(rt.rows[1].stale_n, 1);
        assert_eq!(rt.rows[1].stale_max, 0);
        assert_eq!(rt.rows[1].comm_bytes_delta, 500);
        let csv = rows_to_csv(&rt.rows);
        assert!(csv.starts_with(TIMESERIES_HEADER));
        assert_eq!(csv.lines().count(), 3);
    }

    #[test]
    fn extension_columns_append_and_absence_is_byte_identical() {
        let cfg = crate::config::TraceConfig { enabled: true, ..Default::default() };
        let mut rt = RunTrace::new(&cfg);
        rt.set_extra_cols(vec!["uplink_util_r0".into(), "serving_pulls".into()]);
        rt.sample_with(10, 1.0, 0.5, 4, 1000, 3, vec![0.25, 7.0]);
        rt.sample_with(20, 2.0, 0.4, 4, 1500, 2, vec![0.5, 0.0]);
        let csv = rows_to_csv_with(&rt.rows, &rt.extra_cols, &rt.extra_rows);
        let mut lines = csv.lines();
        let header = lines.next().unwrap();
        assert!(header.ends_with(",uplink_util_r0,serving_pulls"), "{header}");
        assert!(header.starts_with(TIMESERIES_HEADER));
        let row0 = lines.next().unwrap();
        assert!(row0.ends_with(",0.250000,7.000000"), "{row0}");

        // no extensions declared: the CSV must be byte-identical to the
        // base serializer (existing runs keep their pinned artifacts)
        let mut base = RunTrace::new(&cfg);
        base.sample(10, 1.0, 0.5, 4, 1000, 3);
        assert_eq!(
            rows_to_csv_with(&base.rows, &base.extra_cols, &base.extra_rows),
            rows_to_csv(&base.rows)
        );
    }
}
