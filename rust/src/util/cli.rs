//! Tiny CLI argument parser (no clap in the offline crate set).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, repeated keys,
//! and positional arguments. Typed accessors record which keys were touched
//! so `finish()` can reject typos.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    opts: BTreeMap<String, Vec<String>>,
    positional: Vec<String>,
    used: std::cell::RefCell<Vec<String>>,
}

#[derive(Debug, thiserror::Error)]
pub enum CliError {
    #[error("missing required option --{0}")]
    Missing(String),
    #[error("invalid value for --{key}: {value:?} ({expect})")]
    Invalid { key: String, value: String, expect: &'static str },
    #[error("unknown option(s): {0}")]
    Unknown(String),
}

impl Args {
    /// Parse a raw argv (without the program name).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Self {
        let mut opts: BTreeMap<String, Vec<String>> = BTreeMap::new();
        let mut positional = Vec::new();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(rest) = arg.strip_prefix("--") {
                if rest.is_empty() {
                    // `--` terminates option parsing
                    positional.extend(it.by_ref());
                    break;
                }
                if let Some((k, v)) = rest.split_once('=') {
                    opts.entry(k.to_string()).or_default().push(v.to_string());
                } else {
                    // value-style if next token is not an option, else boolean
                    let take_value = matches!(it.peek(), Some(n) if !n.starts_with("--"));
                    if take_value {
                        let v = it.next().unwrap();
                        opts.entry(rest.to_string()).or_default().push(v);
                    } else {
                        opts.entry(rest.to_string()).or_default().push("true".into());
                    }
                }
            } else {
                positional.push(arg);
            }
        }
        Self { opts, positional, used: std::cell::RefCell::new(Vec::new()) }
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.used.borrow_mut().push(key.to_string());
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// First positional argument (subcommand convention).
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn has(&self, key: &str) -> bool {
        self.mark(key);
        self.opts.contains_key(key)
    }

    pub fn str_opt(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.opts.get(key).and_then(|v| v.last().cloned())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or_else(|| default.to_string())
    }

    pub fn str_req(&self, key: &str) -> Result<String, CliError> {
        self.str_opt(key).ok_or_else(|| CliError::Missing(key.into()))
    }

    pub fn flag(&self, key: &str) -> bool {
        self.mark(key);
        match self.opts.get(key).and_then(|v| v.last()) {
            Some(v) => v != "false" && v != "0",
            None => false,
        }
    }

    pub fn usize_opt(&self, key: &str) -> Result<Option<usize>, CliError> {
        match self.str_opt(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| CliError::Invalid { key: key.into(), value: v, expect: "usize" }),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize, CliError> {
        Ok(self.usize_opt(key)?.unwrap_or(default))
    }

    pub fn f64_opt(&self, key: &str) -> Result<Option<f64>, CliError> {
        match self.str_opt(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| CliError::Invalid { key: key.into(), value: v, expect: "float" }),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64, CliError> {
        Ok(self.f64_opt(key)?.unwrap_or(default))
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64, CliError> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::Invalid { key: key.into(), value: v, expect: "u64" }),
        }
    }

    /// Comma-separated list (`--workers 1,4,8`).
    pub fn usize_list_or(&self, key: &str, default: &[usize]) -> Result<Vec<usize>, CliError> {
        match self.str_opt(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim().parse().map_err(|_| CliError::Invalid {
                        key: key.into(),
                        value: v.clone(),
                        expect: "comma-separated usize list",
                    })
                })
                .collect(),
        }
    }

    pub fn f64_list_or(&self, key: &str, default: &[f64]) -> Result<Vec<f64>, CliError> {
        match self.str_opt(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim().parse().map_err(|_| CliError::Invalid {
                        key: key.into(),
                        value: v.clone(),
                        expect: "comma-separated float list",
                    })
                })
                .collect(),
        }
    }

    /// Reject options that were provided but never queried (typo guard).
    pub fn finish(&self) -> Result<(), CliError> {
        let used = self.used.borrow();
        let unknown: Vec<&String> =
            self.opts.keys().filter(|k| !used.contains(k)).collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(CliError::Unknown(
                unknown.iter().map(|s| format!("--{s}")).collect::<Vec<_>>().join(", "),
            ))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_key_value_styles() {
        let a = parse("train --lr 0.5 --lambda=0.04 --verbose --workers 8");
        assert_eq!(a.subcommand(), Some("train"));
        assert_eq!(a.f64_or("lr", 0.0).unwrap(), 0.5);
        assert_eq!(a.f64_or("lambda", 0.0).unwrap(), 0.04);
        assert!(a.flag("verbose"));
        assert_eq!(a.usize_or("workers", 1).unwrap(), 8);
    }

    #[test]
    fn defaults_and_missing() {
        let a = parse("run");
        assert_eq!(a.usize_or("steps", 100).unwrap(), 100);
        assert_eq!(a.str_or("algo", "asgd"), "asgd");
        assert!(!a.flag("quiet"));
        assert!(a.str_req("config").is_err());
    }

    #[test]
    fn repeated_keys_take_last() {
        let a = parse("--lr 0.1 --lr 0.2");
        assert_eq!(a.f64_or("lr", 0.0).unwrap(), 0.2);
    }

    #[test]
    fn lists() {
        let a = parse("--workers 1,4,8 --lambdas 0.1,2.0");
        assert_eq!(a.usize_list_or("workers", &[]).unwrap(), vec![1, 4, 8]);
        assert_eq!(a.f64_list_or("lambdas", &[]).unwrap(), vec![0.1, 2.0]);
        let b = parse("");
        assert_eq!(b.usize_list_or("workers", &[2]).unwrap(), vec![2]);
    }

    #[test]
    fn invalid_values_error() {
        let a = parse("--lr abc");
        assert!(matches!(a.f64_or("lr", 0.0), Err(CliError::Invalid { .. })));
        let b = parse("--n -3");
        // `-3` is treated as the value of --n and fails usize parse
        assert!(b.usize_or("n", 0).is_err());
    }

    #[test]
    fn unknown_option_guard() {
        let a = parse("--known 1 --typo 2");
        let _ = a.usize_or("known", 0).unwrap();
        let err = a.finish().unwrap_err();
        assert!(format!("{err}").contains("--typo"));
    }

    #[test]
    fn double_dash_stops_parsing() {
        let a = parse("cmd -- --not-an-option");
        assert_eq!(a.positional(), &["cmd", "--not-an-option"]);
    }

    #[test]
    fn bool_flag_followed_by_option() {
        let a = parse("--verbose --lr 0.1");
        assert!(a.flag("verbose"));
        assert_eq!(a.f64_or("lr", 0.0).unwrap(), 0.1);
    }
}
