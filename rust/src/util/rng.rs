//! Deterministic PRNG + distributions.
//!
//! `Pcg64` (PCG-XSL-RR 128/64) for the heavy lifting and `SplitMix64` for
//! seeding / cheap streams. Distributions cover everything the framework
//! needs: uniform, normal (Box–Muller), exponential, Pareto (straggler
//! tails), and Zipf (LM token frequencies).
//!
//! Every component that needs randomness takes an explicit seed so entire
//! training runs are bit-reproducible (`ExperimentConfig::seed`).

/// SplitMix64: tiny, solid 64-bit generator; used to expand seeds.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSL-RR 128/64: main generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Pcg64 {
    pub fn new(seed: u64) -> Self {
        // expand the 64-bit seed into state+stream with SplitMix64
        let mut sm = SplitMix64::new(seed);
        let state = ((sm.next_u64() as u128) << 64) | sm.next_u64() as u128;
        let inc = (((sm.next_u64() as u128) << 64) | sm.next_u64() as u128) | 1;
        let mut rng = Self { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(state);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Derive an independent stream (for per-worker / per-shard RNGs).
    pub fn fork(&mut self, tag: u64) -> Self {
        Self::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast here).
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = loop {
            let u = self.next_f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.next_f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + std * z
    }

    /// Exponential with the given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = loop {
            let u = self.next_f64();
            if u > 1e-300 {
                break u;
            }
        };
        -mean * u.ln()
    }

    /// Pareto (type I) with scale `x_m` and shape `alpha` (heavy tail for
    /// straggler modelling; alpha <= 1 has infinite mean — we allow it).
    pub fn pareto(&mut self, x_m: f64, alpha: f64) -> f64 {
        let u = loop {
            let u = self.next_f64();
            if u > 1e-300 {
                break u;
            }
        };
        x_m / u.powf(1.0 / alpha)
    }

    /// Zipf over {0, .., n-1} with exponent `s`, via inverse-CDF on a
    /// precomputed table (see [`ZipfTable`] for the fast path).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        // Fisher–Yates
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (k << n; rejection).
    pub fn choose_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 3 > n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            return all;
        }
        let mut out = Vec::with_capacity(k);
        while out.len() < k {
            let c = self.below(n as u64) as usize;
            if !out.contains(&c) {
                out.push(c);
            }
        }
        out
    }
}

/// Precomputed Zipf sampler: P(i) ∝ 1/(i+1)^s over {0..n-1}.
#[derive(Clone, Debug)]
pub struct ZipfTable {
    cdf: Vec<f64>,
}

impl ZipfTable {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for i in 0..n {
            acc += 1.0 / ((i + 1) as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Self { cdf }
    }

    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let u = rng.next_f64();
        // binary search for the first cdf entry >= u
        match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg64::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn fork_is_independent() {
        let mut a = Pcg64::new(7);
        let mut f1 = a.fork(1);
        let mut f2 = a.fork(2);
        let x: Vec<u64> = (0..8).map(|_| f1.next_u64()).collect();
        let y: Vec<u64> = (0..8).map(|_| f2.next_u64()).collect();
        assert_ne!(x, y);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Pcg64::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = rng.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_ish() {
        let mut rng = Pcg64::new(2);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[rng.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(3);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = rng.normal(2.0, 3.0);
            s1 += v;
            s2 += v * v;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!((mean - 2.0).abs() < 0.05, "mean={mean}");
        assert!((var - 9.0).abs() < 0.3, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Pcg64::new(4);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| rng.exponential(2.5)).sum::<f64>() / n as f64;
        assert!((mean - 2.5).abs() < 0.05, "mean={mean}");
        assert!((0..100).all(|_| rng.exponential(1.0) >= 0.0));
    }

    #[test]
    fn pareto_scale_bound() {
        let mut rng = Pcg64::new(5);
        for _ in 0..1_000 {
            assert!(rng.pareto(1.5, 2.0) >= 1.5);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(6);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn choose_distinct_properties() {
        let mut rng = Pcg64::new(7);
        for (n, k) in [(10, 3), (10, 10), (100, 50), (5, 0)] {
            let c = rng.choose_distinct(n, k);
            assert_eq!(c.len(), k);
            let mut s = c.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), k, "distinct");
            assert!(c.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn zipf_is_monotone_decreasing() {
        let mut rng = Pcg64::new(8);
        let z = ZipfTable::new(50, 1.1);
        let mut counts = vec![0usize; 50];
        for _ in 0..50_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[5]);
        assert!(counts[1] > counts[20]);
        assert!(counts.iter().sum::<usize>() == 50_000);
    }
}
