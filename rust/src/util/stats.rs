//! Descriptive statistics used by metrics and the bench harness.

/// Online mean/variance (Welford) with min/max tracking.
#[derive(Clone, Debug, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile over a copy of the samples (nearest-rank on sorted data with
/// linear interpolation).
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    assert!(!samples.is_empty());
    assert!((0.0..=100.0).contains(&p));
    let mut s = samples.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = p / 100.0 * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        let w = rank - lo as f64;
        s[lo] * (1.0 - w) + s[hi] * w
    }
}

pub fn mean(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    samples.iter().sum::<f64>() / samples.len() as f64
}

/// Exponential moving average smoother (used for loss curves).
#[derive(Clone, Debug)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Self { alpha, value: None }
    }
    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }
    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// Ordinary least squares fit y = a + b*x; returns (a, b).
pub fn linreg(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
    }
    let b = if sxx == 0.0 { 0.0 } else { sxy / sxx };
    (my - b * mx, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut r = Running::new();
        for &x in &xs {
            r.push(x);
        }
        assert_eq!(r.count(), 5);
        assert!((r.mean() - 4.0).abs() < 1e-12);
        let var = xs.iter().map(|x| (x - 4.0f64).powi(2)).sum::<f64>() / 4.0;
        assert!((r.var() - var).abs() < 1e-12);
        assert_eq!(r.min(), 1.0);
        assert_eq!(r.max(), 10.0);
    }

    #[test]
    fn percentile_interpolates() {
        let s = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&s, 0.0), 1.0);
        assert_eq!(percentile(&s, 100.0), 4.0);
        assert!((percentile(&s, 50.0) - 2.5).abs() < 1e-12);
        assert!((percentile(&s, 25.0) - 1.75).abs() < 1e-12);
    }

    #[test]
    fn percentile_is_sort_stable_on_unsorted_input() {
        let s = [5.0, 1.0, 3.0];
        assert_eq!(percentile(&s, 50.0), 3.0);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        assert_eq!(e.push(10.0), 10.0); // first sample passes through
        let mut v = 0.0;
        for _ in 0..50 {
            v = e.push(2.0);
        }
        assert!((v - 2.0).abs() < 1e-9);
    }

    #[test]
    fn linreg_recovers_line() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.0 * x).collect();
        let (a, b) = linreg(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
    }

    // -- edge cases: these helpers back the bench harness and the chaos
    // metrics, so their corner behaviour must be pinned ------------------

    #[test]
    fn running_empty_and_single_sample() {
        let r = Running::new();
        assert_eq!(r.count(), 0);
        assert_eq!(r.mean(), 0.0);
        assert_eq!(r.var(), 0.0);
        assert_eq!(r.min(), f64::INFINITY);
        assert_eq!(r.max(), f64::NEG_INFINITY);
        let mut r = Running::new();
        r.push(4.2);
        assert_eq!(r.count(), 1);
        assert_eq!(r.mean(), 4.2);
        assert_eq!(r.var(), 0.0, "n < 2 must report zero variance, not NaN");
        assert_eq!((r.min(), r.max()), (4.2, 4.2));
    }

    #[test]
    fn running_handles_constant_streams_without_negative_variance() {
        let mut r = Running::new();
        for _ in 0..1000 {
            r.push(0.1 + 0.2); // deliberately non-representable sum
        }
        assert!(r.var() >= 0.0, "catastrophic cancellation produced var {}", r.var());
        assert!(r.std() >= 0.0);
    }

    #[test]
    fn percentile_single_element_and_exact_ranks() {
        assert_eq!(percentile(&[7.5], 0.0), 7.5);
        assert_eq!(percentile(&[7.5], 50.0), 7.5);
        assert_eq!(percentile(&[7.5], 100.0), 7.5);
        // two elements: p50 interpolates the midpoint exactly
        assert_eq!(percentile(&[1.0, 3.0], 50.0), 2.0);
    }

    #[test]
    #[should_panic]
    fn percentile_rejects_empty_samples() {
        let _ = percentile(&[], 50.0);
    }

    #[test]
    #[should_panic]
    fn percentile_rejects_out_of_range_p() {
        let _ = percentile(&[1.0], 101.0);
    }

    #[test]
    fn mean_of_empty_is_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
    }

    #[test]
    fn ema_alpha_extremes() {
        // alpha = 0: after the first sample the value never moves
        let mut e = Ema::new(0.0);
        assert_eq!(e.push(5.0), 5.0);
        assert_eq!(e.push(100.0), 5.0);
        assert_eq!(e.get(), Some(5.0));
        // alpha = 1: tracks the latest sample exactly
        let mut e = Ema::new(1.0);
        e.push(5.0);
        assert_eq!(e.push(-3.0), -3.0);
        // fresh smoother reports nothing
        assert_eq!(Ema::new(0.5).get(), None);
    }

    #[test]
    #[should_panic]
    fn ema_rejects_alpha_above_one() {
        let _ = Ema::new(1.5);
    }

    #[test]
    fn linreg_degenerate_inputs() {
        // vertical stack (all x equal): slope defined as 0, intercept = mean y
        let (a, b) = linreg(&[2.0, 2.0, 2.0], &[1.0, 2.0, 3.0]);
        assert_eq!(b, 0.0);
        assert!((a - 2.0).abs() < 1e-12);
        // two points: exact fit
        let (a, b) = linreg(&[0.0, 1.0], &[1.0, 3.0]);
        assert!((a - 1.0).abs() < 1e-12);
        assert!((b - 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn linreg_rejects_single_point() {
        let _ = linreg(&[1.0], &[1.0]);
    }
}
