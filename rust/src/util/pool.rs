//! Persistent compute pool + pipelined gradient stage (the host-side
//! compute runtime).
//!
//! Before this module existed every multi-shard apply paid a fresh
//! `thread::scope` spawn/join (tens of microseconds of kernel round-trips
//! per call) and the coordinator computed one gradient at a time. The two
//! pieces here remove both costs without changing a single produced bit:
//!
//! * [`ComputePool`] — a fixed set of worker threads created **once per
//!   run**. Jobs are index ranges `0..tasks`; idle workers claim indices
//!   from a shared atomic counter (dynamic chunking: a slow lane never
//!   stalls the others), and `run` returns only after every claimed index
//!   has finished, so tasks may borrow the caller's stack. Task bodies must
//!   write disjoint data per index; under that contract any claim order
//!   produces bit-identical results, which is why the sharded store and the
//!   driver can use the pool freely inside determinism-pinned paths.
//! * [`GradPipeline`] — the deferred-compute stage the coordinator driver
//!   uses to evaluate the gradients of *all* in-flight workers concurrently
//!   (Mishchenko et al. 2022: in-flight computations are mutually
//!   independent by construction). Work is enqueued per worker as soon as
//!   its inputs exist (at pull time) and flushed in one pool burst the
//!   first time a result is demanded; results are keyed by worker, so the
//!   commit order — and therefore every downstream bit — is untouched.
//!
//! `ComputePool::new(1)` spawns nothing and runs every task inline on the
//! caller, which is the `runtime.threads = 1` serial reference the
//! regression tests pin multi-lane runs against.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};
use std::thread::JoinHandle;

/// Lock a mutex, ignoring poisoning: pool state stays structurally valid
/// across a propagated task panic (the panic flag carries the failure).
fn lock_ignore_poison<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Default lane count for auto-sized pools: available parallelism, capped
/// the same way the pre-pool scoped fan-out was.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1).min(8)
}

/// The process-wide shared pool (auto-sized, built on first use). Stores
/// and drivers that were not handed an explicit pool use this one, so a
/// test suite creating hundreds of stores spawns one set of threads total.
pub fn shared() -> &'static Arc<ComputePool> {
    static SHARED: OnceLock<Arc<ComputePool>> = OnceLock::new();
    SHARED.get_or_init(|| Arc::new(ComputePool::new(default_threads())))
}

/// Resolve a `[runtime] threads` knob: `0` = the shared auto-sized pool,
/// `1` = a serial pool (no threads, inline execution), `n` = a dedicated
/// pool with `n` lanes.
pub fn pool_for_threads(threads: usize) -> Arc<ComputePool> {
    match threads {
        0 => Arc::clone(shared()),
        n => Arc::new(ComputePool::new(n)),
    }
}

/// A published job: the erased task body plus the index count. The
/// `'static` on the task is a lie told to the type system — see the safety
/// argument in [`ComputePool::run`].
#[derive(Clone, Copy)]
struct Job {
    task: &'static (dyn Fn(usize) + Sync),
    tasks: usize,
}

struct JobState {
    /// Bumped once per published job; workers key adoption on a change.
    epoch: u64,
    /// The current job, retired (set back to `None`) before `run` returns.
    job: Option<Job>,
    /// Pool workers currently inside a claim loop for the published job.
    claiming: usize,
    shutdown: bool,
}

struct Shared {
    state: Mutex<JobState>,
    /// Workers park here between jobs.
    work_cv: Condvar,
    /// The submitter parks here until every claimer has exited.
    done_cv: Condvar,
    /// Next unclaimed task index of the current job.
    next: AtomicUsize,
    /// First panic payload raised by a task body; `run` resumes it after
    /// the join so the original message/location survives.
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

struct PoolInner {
    shared: Arc<Shared>,
    /// One job at a time: concurrent `run` calls queue here.
    submit: Mutex<()>,
    handles: Vec<JoinHandle<()>>,
}

/// Fixed-size persistent thread pool; see the module docs.
pub struct ComputePool {
    /// `None` = serial pool (one lane, inline execution).
    inner: Option<PoolInner>,
    threads: usize,
}

impl ComputePool {
    /// Build a pool with `threads` total lanes (the submitting thread is a
    /// lane, so `threads - 1` workers are spawned; `threads <= 1` spawns
    /// nothing and `run` executes inline in index order).
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        if threads == 1 {
            return Self { inner: None, threads: 1 };
        }
        let shared = Arc::new(Shared {
            state: Mutex::new(JobState { epoch: 0, job: None, claiming: 0, shutdown: false }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            next: AtomicUsize::new(0),
            panic: Mutex::new(None),
        });
        let handles = (0..threads - 1)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("compute-pool-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawning compute-pool worker")
            })
            .collect();
        Self { inner: Some(PoolInner { shared, submit: Mutex::new(()), handles }), threads }
    }

    /// Total parallel lanes (including the submitting thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// True for the one-lane pool: `run` executes inline, in index order.
    pub fn is_serial(&self) -> bool {
        self.inner.is_none()
    }

    /// Execute `f(0), f(1), ..., f(tasks - 1)`, fanning the indices out
    /// over the pool lanes, and return once **all** of them finished. `f`
    /// may borrow the caller's stack. Indices are claimed dynamically in
    /// ascending order; bodies run concurrently, so per-index effects must
    /// be disjoint (each index owns its output). If any body panics, the
    /// remaining claimed indices still run and the panic is re-raised here
    /// after the join.
    pub fn run(&self, tasks: usize, f: &(dyn Fn(usize) + Sync)) {
        if tasks == 0 {
            return;
        }
        let inner = match &self.inner {
            Some(inner) if tasks > 1 => inner,
            _ => {
                for i in 0..tasks {
                    let _prof =
                        crate::trace::profile::span(crate::trace::profile::Subsystem::PoolJob);
                    f(i);
                }
                return;
            }
        };
        let _submit = lock_ignore_poison(&inner.submit);
        let shared = &*inner.shared;
        *lock_ignore_poison(&shared.panic) = None;
        shared.next.store(0, Ordering::Relaxed);
        // SAFETY: the erased reference is only dereferenced by claim loops
        // that this function joins before returning — the job is retired
        // under the state lock and the wait below blocks until `claiming`
        // drops to zero, so no lane can touch `task` after `run` returns;
        // the borrow therefore outlives every use despite the 'static
        // erasure (the same argument std::thread::scope makes).
        let task = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(f)
        };
        let job = Job { task, tasks };
        {
            let mut st = lock_ignore_poison(&shared.state);
            st.epoch = st.epoch.wrapping_add(1);
            st.job = Some(job);
            shared.work_cv.notify_all();
        }
        // the submitter is a lane too
        run_tasks(shared, job);
        {
            let mut st = lock_ignore_poison(&shared.state);
            st.job = None; // no late adoption: every index is claimed by now
            while st.claiming > 0 {
                st = shared.done_cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        }
        if let Some(payload) = lock_ignore_poison(&shared.panic).take() {
            resume_unwind(payload);
        }
    }
}

impl std::fmt::Debug for ComputePool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ComputePool")
            .field("threads", &self.threads)
            .field("serial", &self.is_serial())
            .finish()
    }
}

impl Drop for ComputePool {
    fn drop(&mut self) {
        if let Some(inner) = self.inner.take() {
            {
                let mut st = lock_ignore_poison(&inner.shared.state);
                st.shutdown = true;
                inner.shared.work_cv.notify_all();
            }
            for h in inner.handles {
                let _ = h.join();
            }
        }
    }
}

/// Claim-and-execute loop shared by pool workers and the submitter.
fn run_tasks(shared: &Shared, job: Job) {
    loop {
        let i = shared.next.fetch_add(1, Ordering::Relaxed);
        if i >= job.tasks {
            break;
        }
        let _prof = crate::trace::profile::span(crate::trace::profile::Subsystem::PoolJob);
        if let Err(payload) = catch_unwind(AssertUnwindSafe(|| (job.task)(i))) {
            let mut slot = lock_ignore_poison(&shared.panic);
            if slot.is_none() {
                *slot = Some(payload);
            }
        }
    }
}

fn worker_loop(shared: &Shared) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = lock_ignore_poison(&shared.state);
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    if let Some(job) = st.job {
                        st.claiming += 1;
                        break job;
                    }
                    // epoch moved but the job already retired: keep waiting
                }
                st = shared.work_cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        };
        run_tasks(shared, job);
        let mut st = lock_ignore_poison(&shared.state);
        st.claiming -= 1;
        if st.claiming == 0 {
            shared.done_cv.notify_all();
        }
    }
}

// ---------------------------------------------------------------------------
// pipelined per-worker compute stage
// ---------------------------------------------------------------------------

/// Deferred per-worker compute stage over a [`ComputePool`].
///
/// Workers are `enqueue`d as soon as their inputs exist; the first `take`
/// that misses flushes **every** queued worker concurrently in one pool
/// burst and stores the results per worker, so the caller's consumption
/// order (the event-driven commit order) is completely decoupled from the
/// evaluation order. With a serial pool the flush evaluates in enqueue
/// order on the calling thread — the bit-identical reference the chaos
/// pins compare multi-lane runs against (results are keyed by worker and
/// each compute is a pure function of per-worker inputs, so lane count
/// can't change any value).
///
/// Queue/slot state lives in reusable per-worker arenas: steady-state
/// operation performs no allocation in the pipeline layer itself.
pub struct GradPipeline<T> {
    pool: Arc<ComputePool>,
    /// Workers enqueued since the last flush, in enqueue order.
    queued: Vec<usize>,
    /// Computed-but-unconsumed results, one slot per worker. Mutexed so
    /// flush tasks can write their own worker's slot concurrently;
    /// steady-state uncontended (each task touches exactly one slot).
    slots: Vec<Mutex<Option<T>>>,
    /// Workers whose last compute was discarded: its inputs were never
    /// consumed in the commit order, so the next enqueue must re-use them
    /// (signalled through [`Self::enqueue`]'s return value).
    retained: Vec<bool>,
}

impl<T: Send> GradPipeline<T> {
    pub fn new(pool: Arc<ComputePool>, workers: usize) -> Self {
        Self {
            pool,
            queued: Vec::with_capacity(workers),
            slots: (0..workers).map(|_| Mutex::new(None)).collect(),
            retained: vec![false; workers],
        }
    }

    /// Number of workers with a compute in flight (queued or computed).
    pub fn in_flight(&self) -> usize {
        self.queued.len()
            + self.slots.iter().filter(|s| lock_ignore_poison(s).is_some()).count()
    }

    /// Is a compute in flight for `worker`?
    pub fn has(&self, worker: usize) -> bool {
        self.queued.contains(&worker) || lock_ignore_poison(&self.slots[worker]).is_some()
    }

    /// Queued-but-unevaluated computes (what the next flush will burst).
    pub fn queued_len(&self) -> usize {
        self.queued.len()
    }

    /// Is `worker`'s result already evaluated (a take would not flush)?
    pub fn is_ready(&self, worker: usize) -> bool {
        lock_ignore_poison(&self.slots[worker]).is_some()
    }

    /// Register `worker` for the next flush. At most one compute may be in
    /// flight per worker (the scheduler's pull → compute → push lifecycle
    /// guarantees the caller never violates this).
    ///
    /// Returns `true` when the caller must draw **fresh** inputs (batch)
    /// for this compute, `false` when a previously [`Self::discard`]ed
    /// compute's inputs are retained and must be re-used — in the serial
    /// draw-at-commit order those inputs were never consumed, so drawing
    /// again would shift the worker's whole input stream.
    pub fn enqueue(&mut self, worker: usize) -> bool {
        debug_assert!(!self.has(worker), "worker {worker} already has a compute in flight");
        self.queued.push(worker);
        !std::mem::replace(&mut self.retained[worker], false)
    }

    /// Drop `worker`'s in-flight compute (crashed epoch: it must never be
    /// consumed); its inputs are marked retained for the next enqueue.
    /// Returns whether a compute existed.
    pub fn discard(&mut self, worker: usize) -> bool {
        let existed = if lock_ignore_poison(&self.slots[worker]).take().is_some() {
            true
        } else if let Some(p) = self.queued.iter().position(|&v| v == worker) {
            self.queued.remove(p);
            true
        } else {
            false
        };
        if existed {
            self.retained[worker] = true;
        }
        existed
    }

    /// Evaluate every queued worker concurrently on the pool.
    pub fn flush<F>(&mut self, compute: &F)
    where
        F: Fn(usize) -> T + Sync,
    {
        if self.queued.is_empty() {
            return;
        }
        let (queued, slots) = (&self.queued, &self.slots);
        self.pool.run(queued.len(), &|i| {
            let w = queued[i];
            *lock_ignore_poison(&slots[w]) = Some(compute(w));
        });
        self.queued.clear();
    }

    /// Consume `worker`'s result, flushing the queue first if it has not
    /// been evaluated yet. Panics if no compute is in flight for `worker`.
    pub fn take<F>(&mut self, worker: usize, compute: &F) -> T
    where
        F: Fn(usize) -> T + Sync,
    {
        if lock_ignore_poison(&self.slots[worker]).is_none() {
            self.flush(compute);
        }
        lock_ignore_poison(&self.slots[worker])
            .take()
            .expect("no compute in flight for worker")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn executes_every_task_exactly_once() {
        let pool = ComputePool::new(4);
        assert_eq!(pool.threads(), 4);
        assert!(!pool.is_serial());
        let hits: Vec<AtomicUsize> = (0..257).map(|_| AtomicUsize::new(0)).collect();
        pool.run(hits.len(), &|i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn serial_pool_runs_inline_in_order() {
        let pool = ComputePool::new(1);
        assert!(pool.is_serial());
        let order = Mutex::new(Vec::new());
        pool.run(10, &|i| order.lock().unwrap().push(i));
        assert_eq!(*order.lock().unwrap(), (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn tasks_may_borrow_the_callers_stack() {
        // the whole point of the lifetime erasure: read a stack slice,
        // write disjoint stack outputs through per-index mutexes
        let pool = ComputePool::new(3);
        let input: Vec<u64> = (0..100).map(|i| i * 3).collect();
        let out: Vec<Mutex<u64>> = (0..100).map(|_| Mutex::new(0)).collect();
        pool.run(100, &|i| {
            *out[i].lock().unwrap() = input[i] + 1;
        });
        for (i, o) in out.iter().enumerate() {
            assert_eq!(*o.lock().unwrap(), input[i] + 1);
        }
    }

    #[test]
    fn many_reuses_do_not_respawn_or_wedge() {
        let pool = ComputePool::new(4);
        let total = AtomicUsize::new(0);
        for round in 0..300 {
            let tasks = 1 + round % 7;
            pool.run(tasks, &|_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        }
        let expect: usize = (0..300).map(|r| 1 + r % 7).sum();
        assert_eq!(total.load(Ordering::Relaxed), expect);
    }

    #[test]
    fn zero_tasks_is_a_noop() {
        let pool = ComputePool::new(2);
        pool.run(0, &|_| panic!("must not run"));
    }

    #[test]
    fn panic_propagates_and_the_pool_survives() {
        let pool = ComputePool::new(3);
        let done = AtomicUsize::new(0);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run(16, &|i| {
                if i == 5 {
                    panic!("boom");
                }
                done.fetch_add(1, Ordering::Relaxed);
            });
        }));
        let payload = r.expect_err("task panic must propagate out of run");
        assert_eq!(
            payload.downcast_ref::<&str>(),
            Some(&"boom"),
            "the original panic payload must survive the pool"
        );
        assert_eq!(done.load(Ordering::Relaxed), 15, "non-panicking tasks still ran");
        // the pool remains usable after a propagated panic
        let again = AtomicUsize::new(0);
        pool.run(8, &|_| {
            again.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(again.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn concurrent_submitters_serialize_without_cross_talk() {
        let pool = Arc::new(ComputePool::new(4));
        let mut handles = Vec::new();
        for t in 0..3 {
            let pool = Arc::clone(&pool);
            handles.push(std::thread::spawn(move || {
                let hits: Vec<AtomicUsize> = (0..64).map(|_| AtomicUsize::new(0)).collect();
                for _ in 0..50 {
                    pool.run(hits.len(), &|i| {
                        hits[i].fetch_add(1, Ordering::Relaxed);
                    });
                }
                assert!(
                    hits.iter().all(|h| h.load(Ordering::Relaxed) == 50),
                    "submitter {t} lost or double-ran tasks"
                );
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn pool_for_threads_resolves_the_knob() {
        assert!(pool_for_threads(1).is_serial());
        assert_eq!(pool_for_threads(3).threads(), 3);
        // 0 = the shared auto-sized pool (same instance every time)
        let a = pool_for_threads(0);
        let b = pool_for_threads(0);
        assert!(Arc::ptr_eq(&a, &b));
        assert!(a.threads() >= 1);
    }

    #[test]
    fn pipeline_flushes_queued_workers_and_keys_results_by_worker() {
        for threads in [1usize, 4] {
            let mut pipe: GradPipeline<u64> =
                GradPipeline::new(Arc::new(ComputePool::new(threads)), 5);
            let compute = |w: usize| (w as u64) * 10 + 1;
            assert!(pipe.enqueue(3), "first enqueue draws fresh inputs");
            assert!(pipe.enqueue(0));
            assert!(pipe.enqueue(4));
            assert_eq!(pipe.in_flight(), 3);
            assert!(pipe.has(3) && !pipe.has(1));
            // first take flushes everything; later takes hit the slots
            assert_eq!(pipe.take(0, &compute), 1);
            assert_eq!(pipe.in_flight(), 2);
            assert_eq!(pipe.take(4, &compute), 41);
            assert_eq!(pipe.take(3, &compute), 31);
            assert_eq!(pipe.in_flight(), 0);
        }
    }

    #[test]
    fn pipeline_steady_state_reuses_its_arenas() {
        // after the first full cycle the pipeline layer allocates nothing:
        // the queue and the per-worker slots are reusable arenas (pointer/
        // capacity pinned, the same invariant the compressor arenas carry)
        let workers = 6;
        let mut pipe: GradPipeline<u64> = GradPipeline::new(Arc::new(ComputePool::new(3)), workers);
        let compute = |w: usize| w as u64;
        // warm one cycle
        for w in 0..workers {
            pipe.enqueue(w);
        }
        for w in 0..workers {
            assert_eq!(pipe.take(w, &compute), w as u64);
        }
        let queued_ptr = pipe.queued.as_ptr();
        let queued_cap = pipe.queued.capacity();
        let slots_ptr = pipe.slots.as_ptr();
        for _ in 0..50 {
            for w in 0..workers {
                pipe.enqueue(w);
            }
            for w in (0..workers).rev() {
                assert_eq!(pipe.take(w, &compute), w as u64);
            }
        }
        assert_eq!(pipe.queued.as_ptr(), queued_ptr, "queue arena reallocated");
        assert_eq!(pipe.queued.capacity(), queued_cap, "queue arena regrew");
        assert_eq!(pipe.slots.as_ptr(), slots_ptr, "slot arena moved");
    }

    #[test]
    fn pipeline_discard_drops_queued_and_computed_entries() {
        let mut pipe: GradPipeline<u64> = GradPipeline::new(Arc::new(ComputePool::new(2)), 4);
        let compute = |w: usize| w as u64;
        assert!(pipe.enqueue(1));
        assert!(pipe.discard(1), "queued entry must be discardable");
        assert!(!pipe.discard(1), "discard is idempotent");
        assert_eq!(pipe.in_flight(), 0);
        // the discarded compute's inputs are retained: the next enqueue
        // must re-use them (returns false), the one after draws fresh
        assert!(!pipe.enqueue(1), "post-discard enqueue must re-use retained inputs");
        assert_eq!(pipe.take(1, &compute), 1);
        assert!(pipe.enqueue(1), "consumed compute: back to fresh draws");
        assert_eq!(pipe.take(1, &compute), 1);
        // computed entry: enqueue two, flush via take of one, discard other
        assert!(pipe.enqueue(2));
        assert!(pipe.enqueue(3));
        assert_eq!(pipe.take(2, &compute), 2);
        assert!(pipe.has(3));
        assert!(pipe.discard(3), "computed entry must be discardable");
        assert!(!pipe.has(3));
        assert!(!pipe.enqueue(3), "discarded-after-flush inputs are retained too");
        // a worker with no in-flight compute reports false
        assert!(!pipe.discard(0));
    }
}
