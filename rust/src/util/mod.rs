//! Support substrates built from scratch (the build environment is offline
//! with a minimal crate set — see DESIGN.md §2).

pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
