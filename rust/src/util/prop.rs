//! Miniature property-based testing harness (proptest is not in the offline
//! crate set). Used by the coordinator/PS invariant tests.
//!
//! A property is a closure over a [`Gen`] (seeded RNG wrapper with value
//! generators). `check` runs it over many seeds; on failure it retries the
//! same seed with smaller size parameters (a lightweight stand-in for
//! shrinking) and reports the seed so the case can be replayed.

use super::rng::Pcg64;

/// Value generators bound to a seeded RNG and a size budget.
pub struct Gen {
    pub rng: Pcg64,
    /// Size hint in [0,1]; properties should scale their structures by it.
    pub size: f64,
}

impl Gen {
    pub fn new(seed: u64, size: f64) -> Self {
        Self { rng: Pcg64::new(seed), size }
    }

    /// usize in [lo, hi], scaled so small `size` generates small cases.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let span = ((hi - lo) as f64 * self.size).round() as usize;
        lo + self.rng.below(span as u64 + 1) as usize
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    pub fn f32_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.rng.normal(0.0, scale as f64) as f32).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u64) as usize]
    }
}

/// Outcome of a property: Ok(()) or a failure description.
pub type PropResult = Result<(), String>;

/// Run `prop` over `cases` seeds. Panics (test-failure style) on the first
/// failing seed, after retrying it at smaller sizes to find a more minimal
/// reproduction.
pub fn check<F: Fn(&mut Gen) -> PropResult>(name: &str, cases: u64, prop: F) {
    check_seeded(name, 0xDC_A5_6D, cases, prop)
}

pub fn check_seeded<F: Fn(&mut Gen) -> PropResult>(name: &str, base_seed: u64, cases: u64, prop: F) {
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut g = Gen::new(seed, 1.0);
        if let Err(msg) = prop(&mut g) {
            // "shrink": retry the same seed with progressively smaller sizes
            // and report the smallest size that still fails.
            let mut smallest = (1.0, msg.clone());
            for &size in &[0.5, 0.25, 0.1, 0.05] {
                let mut g2 = Gen::new(seed, size);
                if let Err(m2) = prop(&mut g2) {
                    smallest = (size, m2);
                }
            }
            panic!(
                "property {name:?} failed (seed={seed:#x}, case={case}, size={}): {}",
                smallest.0, smallest.1,
            );
        }
    }
}

/// Assert-style helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let counter = std::cell::Cell::new(0u64);
        check("sum-commutes", 50, |g| {
            counter.set(counter.get() + 1);
            let a = g.f64_in(-10.0, 10.0);
            let b = g.f64_in(-10.0, 10.0);
            if (a + b - (b + a)).abs() < 1e-12 {
                Ok(())
            } else {
                Err("addition not commutative?!".into())
            }
        });
        assert_eq!(counter.get(), 50);
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 5, |g| {
            let n = g.usize_in(0, 100);
            if n < 1000 {
                Err(format!("n={n} is always < 1000"))
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn generators_respect_bounds() {
        let mut g = Gen::new(9, 1.0);
        for _ in 0..100 {
            let v = g.usize_in(3, 17);
            assert!((3..=17).contains(&v));
        }
        let xs = g.f32_vec(32, 2.0);
        assert_eq!(xs.len(), 32);
        let choices = [1, 2, 3];
        for _ in 0..10 {
            assert!(choices.contains(g.pick(&choices)));
        }
    }

    #[test]
    fn small_size_shrinks_ranges() {
        let mut g = Gen::new(10, 0.05);
        for _ in 0..50 {
            assert!(g.usize_in(0, 1000) <= 50);
        }
    }

    // -- harness self-tests: bugs here would mask subsystem bugs ----------

    #[test]
    fn shrinking_reports_the_smallest_failing_size() {
        // a property that fails at EVERY size: the shrink loop must walk
        // down to its smallest retry (0.05) and report that, so replays
        // start from the most minimal reproduction
        let result = std::panic::catch_unwind(|| {
            check("always-fails-all-sizes", 1, |_g| Err("nope".into()));
        });
        let msg = *result.unwrap_err().downcast::<String>().expect("panic payload");
        assert!(msg.contains("size=0.05"), "expected smallest size in {msg:?}");
        assert!(msg.contains("seed="), "seed missing from {msg:?}");
        assert!(msg.contains("nope"), "failure description missing from {msg:?}");
    }

    #[test]
    fn shrinking_keeps_the_original_size_when_small_cases_pass() {
        // fails only above 500: every retry at size <= 0.5 caps the range
        // at 500 and PASSES, so the report must pin the original size-1.0
        // failure instead of over-claiming a smaller reproduction
        let result = std::panic::catch_unwind(|| {
            check("fails-only-large", 20, |g| {
                let n = g.usize_in(0, 1000);
                if n > 500 {
                    Err(format!("n={n} too big"))
                } else {
                    Ok(())
                }
            });
        });
        match result {
            // the first seed might generate <= 100 at full size and pass
            // everywhere — that is a legitimate no-failure outcome
            Ok(()) => {}
            Err(payload) => {
                let msg = *payload.downcast::<String>().expect("panic payload");
                assert!(msg.contains("size=1"), "shrink must not over-claim: {msg:?}");
            }
        }
    }

    #[test]
    fn check_seeded_derives_distinct_seeds_per_case() {
        let seeds = std::cell::RefCell::new(Vec::new());
        check_seeded("seed-walk", 0x1234, 40, |g| {
            seeds.borrow_mut().push(g.rng.next_u64());
            Ok(())
        });
        let seen = seeds.borrow();
        assert_eq!(seen.len(), 40, "every case must run exactly once");
        let mut uniq = seen.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), 40, "case seeds collided");
    }

    #[test]
    fn usize_in_covers_both_endpoints_at_full_size() {
        let mut g = Gen::new(77, 1.0);
        let (mut lo_hit, mut hi_hit) = (false, false);
        for _ in 0..2000 {
            match g.usize_in(3, 9) {
                3 => lo_hit = true,
                9 => hi_hit = true,
                v => assert!((3..=9).contains(&v)),
            }
        }
        assert!(lo_hit && hi_hit, "endpoints unreachable: lo={lo_hit} hi={hi_hit}");
    }

    #[test]
    fn usize_in_degenerate_range_is_constant() {
        let mut g = Gen::new(5, 1.0);
        for _ in 0..20 {
            assert_eq!(g.usize_in(7, 7), 7);
        }
        // size 0 collapses every range to its lower bound
        let mut g = Gen::new(5, 0.0);
        for _ in 0..20 {
            assert_eq!(g.usize_in(4, 1000), 4);
        }
    }

    #[test]
    fn f32_vec_is_finite_and_scales() {
        let mut g = Gen::new(11, 1.0);
        let xs = g.f32_vec(512, 0.5);
        assert_eq!(xs.len(), 512);
        assert!(xs.iter().all(|x| x.is_finite()));
        let spread = xs.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        assert!(spread > 0.0, "all-zero normal draw");
        let empty = g.f32_vec(0, 1.0);
        assert!(empty.is_empty());
    }

    #[test]
    fn gen_streams_are_seed_deterministic() {
        let mut a = Gen::new(99, 1.0);
        let mut b = Gen::new(99, 1.0);
        for _ in 0..50 {
            assert_eq!(a.usize_in(0, 1000), b.usize_in(0, 1000));
            assert_eq!(a.f64_in(-1.0, 1.0).to_bits(), b.f64_in(-1.0, 1.0).to_bits());
            assert_eq!(a.bool(), b.bool());
        }
    }
}
