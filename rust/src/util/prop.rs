//! Miniature property-based testing harness (proptest is not in the offline
//! crate set). Used by the coordinator/PS invariant tests.
//!
//! A property is a closure over a [`Gen`] (seeded RNG wrapper with value
//! generators). `check` runs it over many seeds; on failure it retries the
//! same seed with smaller size parameters (a lightweight stand-in for
//! shrinking) and reports the seed so the case can be replayed.

use super::rng::Pcg64;

/// Value generators bound to a seeded RNG and a size budget.
pub struct Gen {
    pub rng: Pcg64,
    /// Size hint in [0,1]; properties should scale their structures by it.
    pub size: f64,
}

impl Gen {
    pub fn new(seed: u64, size: f64) -> Self {
        Self { rng: Pcg64::new(seed), size }
    }

    /// usize in [lo, hi], scaled so small `size` generates small cases.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let span = ((hi - lo) as f64 * self.size).round() as usize;
        lo + self.rng.below(span as u64 + 1) as usize
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    pub fn f32_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.rng.normal(0.0, scale as f64) as f32).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u64) as usize]
    }
}

/// Outcome of a property: Ok(()) or a failure description.
pub type PropResult = Result<(), String>;

/// Run `prop` over `cases` seeds. Panics (test-failure style) on the first
/// failing seed, after retrying it at smaller sizes to find a more minimal
/// reproduction.
pub fn check<F: Fn(&mut Gen) -> PropResult>(name: &str, cases: u64, prop: F) {
    check_seeded(name, 0xDC_A5_6D, cases, prop)
}

pub fn check_seeded<F: Fn(&mut Gen) -> PropResult>(name: &str, base_seed: u64, cases: u64, prop: F) {
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut g = Gen::new(seed, 1.0);
        if let Err(msg) = prop(&mut g) {
            // "shrink": retry the same seed with progressively smaller sizes
            // and report the smallest size that still fails.
            let mut smallest = (1.0, msg.clone());
            for &size in &[0.5, 0.25, 0.1, 0.05] {
                let mut g2 = Gen::new(seed, size);
                if let Err(m2) = prop(&mut g2) {
                    smallest = (size, m2);
                }
            }
            panic!(
                "property {name:?} failed (seed={seed:#x}, case={case}, size={}): {}",
                smallest.0, smallest.1,
            );
        }
    }
}

/// Assert-style helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let counter = std::cell::Cell::new(0u64);
        check("sum-commutes", 50, |g| {
            counter.set(counter.get() + 1);
            let a = g.f64_in(-10.0, 10.0);
            let b = g.f64_in(-10.0, 10.0);
            if (a + b - (b + a)).abs() < 1e-12 {
                Ok(())
            } else {
                Err("addition not commutative?!".into())
            }
        });
        assert_eq!(counter.get(), 50);
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 5, |g| {
            let n = g.usize_in(0, 100);
            if n < 1000 {
                Err(format!("n={n} is always < 1000"))
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn generators_respect_bounds() {
        let mut g = Gen::new(9, 1.0);
        for _ in 0..100 {
            let v = g.usize_in(3, 17);
            assert!((3..=17).contains(&v));
        }
        let xs = g.f32_vec(32, 2.0);
        assert_eq!(xs.len(), 32);
        let choices = [1, 2, 3];
        for _ in 0..10 {
            assert!(choices.contains(g.pick(&choices)));
        }
    }

    #[test]
    fn small_size_shrinks_ranges() {
        let mut g = Gen::new(10, 0.05);
        for _ in 0..50 {
            assert!(g.usize_in(0, 1000) <= 50);
        }
    }
}
