//! Minimal JSON parser + serializer (no serde in the offline crate set).
//!
//! Covers the full JSON grammar we produce/consume: the AOT manifest,
//! metrics dumps, and bench reports. Numbers are kept as `f64` with an
//! integer fast-path accessor.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// `obj["key"]`-style access; returns Null for missing keys/non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // -- builders ------------------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // no surrogate-pair handling: our producers never emit them
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance over one UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write(self, &mut s);
        f.write_str(&s)
    }
}

fn write(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => escape(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write(item, out);
            }
            out.push(']');
        }
        Json::Obj(o) => {
            out.push('{');
            for (i, (k, val)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                write(val, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str().unwrap(), "x\ny");
    }

    #[test]
    fn parse_unicode_escape() {
        let v = Json::parse(r#""éA""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "éA");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"models":[{"n":8192,"name":"mlp","ok":true,"x":null}],"v":1.5}"#;
        let v = Json::parse(src).unwrap();
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn string_escaping_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".into());
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(8192.0).to_string(), "8192");
        assert_eq!(Json::Num(0.25).to_string(), "0.25");
    }

    #[test]
    fn get_on_missing_is_null() {
        let v = Json::parse(r#"{"a":1}"#).unwrap();
        assert_eq!(v.get("zzz"), &Json::Null);
        assert_eq!(v.get("a").get("nested"), &Json::Null);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
            "version": 2, "pad_multiple": 8192,
            "models": [{"name": "mlp_tiny", "n_params": 3268, "n_padded": 8192,
                        "x": {"dtype": "f32", "shape": [16, 64]},
                        "files": {"train": "mlp_tiny.train.hlo.txt"}}]
        }"#;
        let v = Json::parse(src).unwrap();
        let m = &v.get("models").as_arr().unwrap()[0];
        assert_eq!(m.get("name").as_str().unwrap(), "mlp_tiny");
        assert_eq!(m.get("x").get("shape").as_arr().unwrap()[1].as_usize().unwrap(), 64);
    }
}
