//! Test-set evaluation: classification error + mean loss over the test set.

use crate::data::Dataset;
use crate::runtime::EngineHandle;
use anyhow::Result;

/// Evaluate `params` on (a prefix of) the test set.
///
/// Uses the artifact's fixed batch size; evaluates `max_batches` batches
/// (0 = as many full batches as the test set holds). Returns
/// `(mean_loss, error_rate)` where error is over label *rows* (tokens for
/// LMs, examples for classifiers).
pub fn evaluate(
    engine: &EngineHandle,
    params: &[f32],
    test: &dyn Dataset,
    max_batches: usize,
) -> Result<(f32, f32)> {
    let b = engine.entry().batch;
    let rows_per_batch = engine.entry().tokens_per_batch;
    let avail = test.len() / b;
    let n_batches = if max_batches == 0 { avail } else { max_batches.min(avail) };
    anyhow::ensure!(n_batches > 0, "test set smaller than one batch ({} < {})", test.len(), b);
    let mut total_loss = 0.0f64;
    let mut total_correct = 0.0f64;
    for bi in 0..n_batches {
        let indices: Vec<usize> = (bi * b..(bi + 1) * b).collect();
        let batch = test.make_batch(&indices);
        let (loss, correct) = engine.eval(params, &batch)?;
        total_loss += loss as f64;
        total_correct += correct as f64;
    }
    let mean_loss = (total_loss / n_batches as f64) as f32;
    let total_rows = (n_batches * rows_per_batch) as f64;
    let error = 1.0 - (total_correct / total_rows);
    Ok((mean_loss, error as f32))
}
