//! Gradient compression: sparsification + quantization codecs with
//! error-feedback residuals.
//!
//! Communication-efficient training is the standard companion to delay
//! tolerance at scale (DC-S3GD pairs the two; see PAPERS.md): once the
//! `[comm]` model charges per-byte transfer time, shipping the full dense
//! `f32` gradient is just one point on the comm axis. This module opens the
//! rest of it:
//!
//! * [`GradientCodec`] — a lossy encoder from a dense gradient to a
//!   [`WirePayload`]; three implementations ([`codecs::TopK`],
//!   [`codecs::RandK`], [`codecs::Qsgd`]) plus the exact
//!   [`codecs::IdentityCodec`].
//! * [`ErrorFeedback`] — the per-worker EF-SGD residual: whatever the codec
//!   dropped this step is remembered and re-injected into the next encode,
//!   so the *accumulated* applied update tracks the accumulated true
//!   gradient (`sum(decoded) + residual == sum(g)` exactly, per step).
//! * [`WorkerCompressor`] — one codec + EF state + a reusable payload
//!   arena per worker. After warmup no steady-state heap allocation
//!   happens on the encode path (PR 2's zero-allocation invariant).
//!
//! ## Wire format & byte accounting
//!
//! The in-process payload keeps `u32` indices / `f32` values so the
//! parameter server can apply sparse updates shard-locally without
//! densifying. The *bytes-on-wire* accounting ([`WirePayload::wire_bytes`])
//! models what a real PS would ship: values as `f32`, sparse indices
//! bit-packed at `ceil(log2 n)` bits, quantized levels bit-packed at the
//! configured width plus one `f32` norm. The same philosophy as the DES
//! itself: gradients are real, *costs* are modelled.
//!
//! Decoding is payload-self-describing ([`WirePayload::decode_into`]), so
//! the server needs no codec instance — exactly like a tagged wire format.
//!
//! Selection via [`CodecConfig`] (the `[compress]` TOML section /
//! `--compress` CLI flag). `CodecConfig::None` is the default and is pinned
//! bit-identical to the uncompressed path: the driver builds no compressor
//! at all and pushes dense gradients as before.

pub mod codecs;

pub use codecs::{
    decode_dc_apply, decode_dca_apply, decode_sgd_apply, IdentityCodec, Qsgd, RandK, TopK,
};

use crate::util::pool::ComputePool;
use crate::util::rng::Pcg64;
use anyhow::bail;
use std::sync::Arc;

/// Bits needed to address an index in `[0, n)` (wire model for sparse
/// index streams). At least 1 so the degenerate n = 1 still costs a bit.
pub fn index_bits(n: usize) -> u32 {
    (usize::BITS - n.max(2).saturating_sub(1).leading_zeros()).max(1)
}

/// One encoded gradient. Buffers are reused across encodes (the enum
/// variant is stable per codec, so steady state never reallocates).
#[derive(Clone, Debug, PartialEq)]
pub enum WirePayload {
    /// Uncompressed f32 vector (identity / 32-bit quantization).
    Dense(Vec<f32>),
    /// Sparse (index, value) pairs; `idx` is strictly ascending so the
    /// sharded store can partition it per shard with a linear walk.
    Sparse { n: u32, idx: Vec<u32>, val: Vec<f32> },
    /// QSGD-style levels: `level[i] ∈ [0, 2L]` offset-binary packed at
    /// `bits` bits per element; dequantizes to `(level - L) / L * norm`
    /// with `L = 2^(bits-1) - 1`.
    Quantized { n: u32, bits: u8, norm: f32, packed: Vec<u8> },
}

impl Default for WirePayload {
    fn default() -> Self {
        WirePayload::Dense(Vec::new())
    }
}

impl WirePayload {
    /// Dense length this payload decodes to.
    pub fn len(&self) -> usize {
        match self {
            WirePayload::Dense(v) => v.len(),
            WirePayload::Sparse { n, .. } | WirePayload::Quantized { n, .. } => *n as usize,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Modelled bytes this payload occupies on the wire (see module docs).
    pub fn wire_bytes(&self) -> usize {
        match self {
            WirePayload::Dense(v) => 4 * v.len(),
            WirePayload::Sparse { n, idx, .. } => {
                codecs::sparse_wire_bytes(*n as usize, idx.len())
            }
            WirePayload::Quantized { n, bits, .. } => {
                codecs::quantized_wire_bytes(*n as usize, *bits as u32)
            }
        }
    }

    /// Decode into a dense vector (overwrites `out` entirely).
    pub fn decode_into(&self, out: &mut [f32]) {
        let _p = crate::trace::profile::span(crate::trace::profile::Subsystem::CodecDecode);
        assert_eq!(out.len(), self.len(), "decode length mismatch");
        match self {
            WirePayload::Dense(v) => out.copy_from_slice(v),
            WirePayload::Sparse { idx, val, .. } => {
                out.fill(0.0);
                for (&i, &v) in idx.iter().zip(val) {
                    out[i as usize] = v;
                }
            }
            WirePayload::Quantized { n, bits, norm, packed } => {
                codecs::dequantize_into(out, *n as usize, *bits as u32, *norm, packed);
            }
        }
    }
}

/// A lossy (or exact) gradient encoder. Stateful (`&mut self`) because
/// RandK / QSGD carry per-worker random streams; encoding must be
/// deterministic given the codec's seed and call sequence.
pub trait GradientCodec: Send {
    fn name(&self) -> &'static str;
    /// Encode `g` into `out`, reusing `out`'s buffers (no steady-state
    /// allocation once the buffers have reached capacity).
    fn encode(&mut self, g: &[f32], out: &mut WirePayload);
    /// Modelled wire size of an encoded `n`-element gradient (all codecs
    /// here are fixed-rate, so this is exact, not an estimate).
    fn wire_bytes(&self, n: usize) -> usize;
    /// True if `decode(encode(g)) == g` exactly (ratio 1.0 / 32 bits /
    /// identity): the error-feedback residual then stays identically zero.
    /// Decoding needs no codec method at all — payloads are
    /// self-describing ([`WirePayload::decode_into`]).
    fn is_identity(&self) -> bool {
        false
    }
}

/// Error-feedback (EF-SGD) residual state for one worker: the part of the
/// injected gradient the codec dropped, carried into the next encode.
///
/// Per step: `e = g + r`; `wire = encode(e)`; `r' = e - decode(wire)`.
/// Summing over steps telescopes to
/// `sum(decoded) + r_T == sum(g) + r_0` — the accumulated applied update
/// equals the accumulated true gradient up to the (bounded) final residual.
#[derive(Debug)]
pub struct ErrorFeedback {
    residual: Vec<f32>,
    injected: Vec<f32>,
    decoded: Vec<f32>,
}

impl ErrorFeedback {
    pub fn new(n: usize) -> Self {
        Self { residual: vec![0.0; n], injected: vec![0.0; n], decoded: vec![0.0; n] }
    }

    pub fn residual(&self) -> &[f32] {
        &self.residual
    }

    /// Zero the residual in place (worker churn: a rejoining worker's
    /// accumulated mass belongs to its dead incarnation and must not leak
    /// into the new epoch). No allocation; arena pointers stay fixed.
    pub fn reset(&mut self) {
        self.residual.fill(0.0);
    }

    /// Overwrite the residual (checkpoint resume). Lengths must match —
    /// identity codecs carry an empty residual and accept only `&[]`.
    pub fn set_residual(&mut self, src: &[f32]) {
        assert_eq!(
            src.len(),
            self.residual.len(),
            "error-feedback residual length mismatch"
        );
        self.residual.copy_from_slice(src);
    }

    /// One EF step: inject the residual, encode, update the residual.
    /// Identity codecs skip the residual arithmetic entirely (it is
    /// identically zero, and the arenas may be empty), which keeps the
    /// ratio-1.0 / 32-bit paths bit-exact with the dense pipeline.
    pub fn step(&mut self, codec: &mut dyn GradientCodec, g: &[f32], out: &mut WirePayload) {
        if codec.is_identity() {
            codec.encode(g, out);
            return;
        }
        assert_eq!(g.len(), self.residual.len());
        for ((e, gi), r) in self.injected.iter_mut().zip(g).zip(&self.residual) {
            *e = gi + r;
        }
        codec.encode(&self.injected, out);
        out.decode_into(&mut self.decoded);
        for ((r, e), d) in self.residual.iter_mut().zip(&self.injected).zip(&self.decoded) {
            *r = e - d;
        }
    }
}

/// Per-worker compression state: codec + EF residual + the reusable
/// payload arena. This is what the driver holds, one per worker.
pub struct WorkerCompressor {
    codec: Box<dyn GradientCodec>,
    ef: ErrorFeedback,
    payload: WirePayload,
}

impl WorkerCompressor {
    /// Build from config; `None` config means no compression (callers
    /// should then skip the encode path entirely).
    pub fn new(cfg: &CodecConfig, n: usize, seed: u64, worker: usize) -> Option<Self> {
        Self::with_pool(cfg, n, seed, worker, None)
    }

    /// Like [`WorkerCompressor::new`], additionally handing pool-capable
    /// codecs (TopK selection) a [`ComputePool`] for shard-parallel
    /// encoding. The encoded payload is identical with or without a pool.
    pub fn with_pool(
        cfg: &CodecConfig,
        n: usize,
        seed: u64,
        worker: usize,
        pool: Option<Arc<ComputePool>>,
    ) -> Option<Self> {
        let codec = cfg.build_with_pool(seed, worker, pool)?;
        // identity codecs never touch the EF arenas (the residual is
        // identically zero): don't pay 3n floats per worker for them
        let ef = ErrorFeedback::new(if codec.is_identity() { 0 } else { n });
        Some(Self { codec, ef, payload: WirePayload::default() })
    }

    /// EF-inject + encode `g`; the returned payload borrows this worker's
    /// arena and is valid until the next `compress` call.
    pub fn compress(&mut self, g: &[f32]) -> &WirePayload {
        let _p = crate::trace::profile::span(crate::trace::profile::Subsystem::CodecEncode);
        self.ef.step(self.codec.as_mut(), g, &mut self.payload);
        &self.payload
    }

    pub fn residual(&self) -> &[f32] {
        self.ef.residual()
    }

    /// Zero this worker's error-feedback residual (crash/rejoin: the
    /// accumulated mass of the dead incarnation must not leak into the new
    /// epoch, exactly as `w_bak(m)` is re-seeded on the server side).
    pub fn reset(&mut self) {
        self.ef.reset();
    }

    /// Restore this worker's residual from a checkpoint. Identity codecs
    /// carry no residual state (it is identically zero) and accept `&[]`.
    pub fn set_residual(&mut self, src: &[f32]) {
        self.ef.set_residual(src);
    }

    pub fn codec(&self) -> &dyn GradientCodec {
        self.codec.as_ref()
    }
}

/// Codec selection + parameters (the `[compress]` config section). `None`
/// is the default: no compressor is built and the training path is
/// bit-identical to pre-compression builds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CodecConfig {
    None,
    /// Keep the `ceil(ratio * n)` largest-magnitude coordinates.
    TopK { ratio: f64 },
    /// Keep `ceil(ratio * n)` uniformly random coordinates (per-worker
    /// deterministic stream; unscaled — EF absorbs the bias).
    RandK { ratio: f64 },
    /// QSGD-style stochastic quantization at `bits` bits per element
    /// (sign + magnitude levels against the max-norm); 32 = exact f32.
    Qsgd { bits: u32 },
}

impl CodecConfig {
    /// Parse a codec name with its parameter knobs (TOML / CLI).
    pub fn parse(name: &str, ratio: f64, bits: u32) -> anyhow::Result<Self> {
        let cfg = match name.to_ascii_lowercase().as_str() {
            "none" | "off" | "dense" => CodecConfig::None,
            "topk" | "top-k" => CodecConfig::TopK { ratio },
            "randk" | "rand-k" => CodecConfig::RandK { ratio },
            "qsgd" | "quant" => CodecConfig::Qsgd { bits },
            other => bail!("unknown codec {other:?} (none|topk|randk|qsgd)"),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn name(&self) -> &'static str {
        match self {
            CodecConfig::None => "none",
            CodecConfig::TopK { .. } => "topk",
            CodecConfig::RandK { .. } => "randk",
            CodecConfig::Qsgd { .. } => "qsgd",
        }
    }

    pub fn is_none(&self) -> bool {
        matches!(self, CodecConfig::None)
    }

    /// True when the configured codec is exact (`none`, ratio-1.0
    /// sparsifiers, 32-bit quantization): the error-feedback residual is
    /// then identically zero, so there is no per-worker compressor state
    /// to carry through checkpoints or invalidate on worker churn.
    pub fn is_lossless(&self) -> bool {
        match *self {
            CodecConfig::None => true,
            CodecConfig::TopK { ratio } | CodecConfig::RandK { ratio } => ratio >= 1.0,
            CodecConfig::Qsgd { bits } => bits >= 32,
        }
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        match self {
            CodecConfig::None => {}
            CodecConfig::TopK { ratio } | CodecConfig::RandK { ratio } => {
                if !(*ratio > 0.0 && *ratio <= 1.0) {
                    bail!("{} ratio must be in (0, 1], got {ratio}", self.name());
                }
            }
            CodecConfig::Qsgd { bits } => {
                // bits = 2 gives L = 1: per-element error reaches the full
                // norm and the EF residual is no longer contractive
                if !((3..=16).contains(bits) || *bits == 32) {
                    bail!("qsgd bits must be in [3, 16] or exactly 32, got {bits}");
                }
            }
        }
        Ok(())
    }

    /// Instantiate the codec for one worker. Random codecs derive their
    /// stream from `(seed, worker)` so runs are bit-reproducible and
    /// workers are decorrelated.
    pub fn build(&self, seed: u64, worker: usize) -> Option<Box<dyn GradientCodec>> {
        self.build_with_pool(seed, worker, None)
    }

    /// [`CodecConfig::build`] with an optional [`ComputePool`] for codecs
    /// whose encode can run shard-parallel (TopK key building and
    /// pre-selection). Payloads are identical with or without the pool —
    /// it trades wallclock only.
    pub fn build_with_pool(
        &self,
        seed: u64,
        worker: usize,
        pool: Option<Arc<ComputePool>>,
    ) -> Option<Box<dyn GradientCodec>> {
        let rng = || Pcg64::new(seed ^ 0xC0DE_C0DE).fork(worker as u64);
        match *self {
            CodecConfig::None => None,
            CodecConfig::TopK { ratio } => {
                let t = TopK::new(ratio);
                let t = match pool {
                    Some(p) => t.with_pool(p),
                    None => t,
                };
                Some(Box::new(t))
            }
            CodecConfig::RandK { ratio } => Some(Box::new(RandK::new(ratio, rng()))),
            CodecConfig::Qsgd { bits } => Some(Box::new(Qsgd::new(bits, rng()))),
        }
    }

    /// Modelled per-push bytes on the wire for an `n`-element gradient
    /// (dense f32 for `None`). Mirrors the codecs' own `wire_bytes`
    /// without instantiating one (pinned equal by the property tests).
    /// Note the sparse container is *larger* than dense at ratio 1.0
    /// (indices ride along), so identity-point schedules match dense only
    /// while `[comm]` is disabled.
    pub fn wire_bytes(&self, n: usize) -> usize {
        match *self {
            CodecConfig::None => 4 * n,
            CodecConfig::TopK { ratio } | CodecConfig::RandK { ratio } => {
                codecs::sparse_wire_bytes(n, codecs::kept(ratio, n))
            }
            CodecConfig::Qsgd { bits } => {
                if bits >= 32 {
                    4 * n
                } else {
                    codecs::quantized_wire_bytes(n, bits)
                }
            }
        }
    }
}

impl std::fmt::Display for CodecConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CodecConfig::None => write!(f, "none"),
            CodecConfig::TopK { ratio } => write!(f, "topk({ratio})"),
            CodecConfig::RandK { ratio } => write!(f, "randk({ratio})"),
            CodecConfig::Qsgd { bits } => write!(f, "qsgd({bits}b)"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grad(seed: u64, n: usize) -> Vec<f32> {
        let mut rng = Pcg64::new(seed);
        (0..n).map(|_| rng.normal(0.0, 0.5) as f32).collect()
    }

    #[test]
    fn index_bits_covers_range() {
        assert_eq!(index_bits(1), 1);
        assert_eq!(index_bits(2), 1);
        assert_eq!(index_bits(3), 2);
        assert_eq!(index_bits(256), 8);
        assert_eq!(index_bits(257), 9);
        assert_eq!(index_bits(860_160), 20);
        // every valid index must fit
        for n in [1usize, 2, 7, 100, 4097] {
            let b = index_bits(n);
            assert!((n - 1) as u64 <= (1u64 << b) - 1, "n={n} b={b}");
        }
    }

    #[test]
    fn codec_config_parse_and_validate() {
        assert_eq!(CodecConfig::parse("none", 0.1, 8).unwrap(), CodecConfig::None);
        assert_eq!(
            CodecConfig::parse("topk", 0.25, 8).unwrap(),
            CodecConfig::TopK { ratio: 0.25 }
        );
        assert_eq!(
            CodecConfig::parse("randk", 0.5, 8).unwrap(),
            CodecConfig::RandK { ratio: 0.5 }
        );
        assert_eq!(CodecConfig::parse("qsgd", 0.1, 4).unwrap(), CodecConfig::Qsgd { bits: 4 });
        assert!(CodecConfig::parse("warp", 0.1, 8).is_err());
        assert!(CodecConfig::parse("topk", 0.0, 8).is_err());
        assert!(CodecConfig::parse("topk", 1.5, 8).is_err());
        assert!(CodecConfig::parse("qsgd", 0.1, 1).is_err());
        assert!(CodecConfig::parse("qsgd", 0.1, 2).is_err(), "L=1 is not EF-contractive");
        assert!(CodecConfig::parse("qsgd", 0.1, 3).is_ok());
        assert!(CodecConfig::parse("qsgd", 0.1, 17).is_err());
        assert!(CodecConfig::parse("qsgd", 0.1, 32).is_ok());
    }

    #[test]
    fn none_builds_no_codec_and_costs_dense() {
        assert!(CodecConfig::None.build(1, 0).is_none());
        assert_eq!(CodecConfig::None.wire_bytes(1000), 4000);
        assert!(WorkerCompressor::new(&CodecConfig::None, 64, 1, 0).is_none());
    }

    #[test]
    fn topk_wire_bytes_beat_dense_by_5x_at_ratio_0_1() {
        // the acceptance gate's arithmetic: ratio 0.1 with bit-packed
        // indices must model >= 5x below dense f32
        for n in [100_000usize, 272_384, 860_160] {
            let dense = CodecConfig::None.wire_bytes(n);
            let topk = CodecConfig::TopK { ratio: 0.1 }.wire_bytes(n);
            assert!(
                dense as f64 / topk as f64 >= 5.0,
                "n={n}: dense {dense} / topk {topk} < 5x"
            );
        }
    }

    #[test]
    fn ef_telescopes_sum_applied_plus_residual_equals_sum_true() {
        let n = 256;
        for cfg in [
            CodecConfig::TopK { ratio: 0.2 },
            CodecConfig::RandK { ratio: 0.3 },
            CodecConfig::Qsgd { bits: 6 },
        ] {
            let mut wc = WorkerCompressor::new(&cfg, n, 7, 0).unwrap();
            let mut sum_true = vec![0.0f64; n];
            let mut sum_applied = vec![0.0f64; n];
            let mut dec = vec![0.0f32; n];
            for t in 0..50 {
                let g = grad(100 + t, n);
                let p = wc.compress(&g);
                p.decode_into(&mut dec);
                for i in 0..n {
                    sum_true[i] += g[i] as f64;
                    sum_applied[i] += dec[i] as f64;
                }
            }
            let r = wc.residual();
            for i in 0..n {
                let gap = (sum_applied[i] + r[i] as f64 - sum_true[i]).abs();
                assert!(gap < 1e-3, "{cfg:?}: telescoping broke at {i}: {gap}");
            }
        }
    }

    #[test]
    fn identity_configs_keep_residual_zero_and_roundtrip_exactly() {
        let n = 333;
        let g = grad(5, n);
        for cfg in [
            CodecConfig::TopK { ratio: 1.0 },
            CodecConfig::RandK { ratio: 1.0 },
            CodecConfig::Qsgd { bits: 32 },
        ] {
            let mut wc = WorkerCompressor::new(&cfg, n, 3, 1).unwrap();
            assert!(wc.codec().is_identity(), "{cfg:?}");
            let mut dec = vec![0.0f32; n];
            for _ in 0..3 {
                let p = wc.compress(&g);
                p.decode_into(&mut dec);
            }
            assert_eq!(dec, g, "{cfg:?} roundtrip not exact");
            assert!(wc.residual().iter().all(|&r| r == 0.0), "{cfg:?} residual nonzero");
        }
    }

    #[test]
    fn encode_path_has_no_steady_state_allocation() {
        // After one warmup encode, every reusable buffer's pointer and
        // capacity must stay fixed across many more encodes — the
        // PR 2 zero-allocation invariant, extended to the codec arenas.
        let n = 2048;
        for cfg in [
            CodecConfig::TopK { ratio: 0.1 },
            CodecConfig::RandK { ratio: 0.1 },
            CodecConfig::Qsgd { bits: 4 },
        ] {
            let mut wc = WorkerCompressor::new(&cfg, n, 11, 0).unwrap();
            let _ = wc.compress(&grad(1, n)); // warmup: arenas reach capacity
            let fingerprint = |p: &WirePayload| -> Vec<(usize, usize)> {
                match p {
                    WirePayload::Dense(v) => vec![(v.as_ptr() as usize, v.capacity())],
                    WirePayload::Sparse { idx, val, .. } => vec![
                        (idx.as_ptr() as usize, idx.capacity()),
                        (val.as_ptr() as usize, val.capacity()),
                    ],
                    WirePayload::Quantized { packed, .. } => {
                        vec![(packed.as_ptr() as usize, packed.capacity())]
                    }
                }
            };
            let before = fingerprint(&wc.payload);
            for t in 0..100 {
                let _ = wc.compress(&grad(200 + t, n));
            }
            let after = fingerprint(&wc.payload);
            assert_eq!(before, after, "{cfg:?}: payload arena reallocated");
        }
    }

    #[test]
    fn residual_reset_and_restore_roundtrip() {
        let n = 128;
        let cfg = CodecConfig::TopK { ratio: 0.1 };
        let mut wc = WorkerCompressor::new(&cfg, n, 3, 0).unwrap();
        for t in 0..5 {
            let _ = wc.compress(&grad(60 + t, n));
        }
        assert!(wc.residual().iter().any(|&r| r != 0.0), "lossy codec left a zero residual");
        let saved: Vec<f32> = wc.residual().to_vec();
        // reset zeroes in place without reallocating the arena
        let ptr = wc.residual().as_ptr();
        wc.reset();
        assert!(wc.residual().iter().all(|&r| r == 0.0));
        assert_eq!(wc.residual().as_ptr(), ptr, "reset reallocated the residual arena");
        // restore brings the exact state back
        wc.set_residual(&saved);
        assert_eq!(wc.residual(), &saved[..]);
        // identity codecs have no state: only the empty restore is legal
        let mut ident = WorkerCompressor::new(&CodecConfig::Qsgd { bits: 32 }, n, 3, 0).unwrap();
        ident.set_residual(&[]);
        ident.reset();
    }

    #[test]
    fn lossless_classification_matches_identity_codecs() {
        assert!(CodecConfig::None.is_lossless());
        assert!(CodecConfig::TopK { ratio: 1.0 }.is_lossless());
        assert!(CodecConfig::RandK { ratio: 1.0 }.is_lossless());
        assert!(CodecConfig::Qsgd { bits: 32 }.is_lossless());
        assert!(!CodecConfig::TopK { ratio: 0.5 }.is_lossless());
        assert!(!CodecConfig::RandK { ratio: 0.99 }.is_lossless());
        assert!(!CodecConfig::Qsgd { bits: 8 }.is_lossless());
        for cfg in [
            CodecConfig::TopK { ratio: 1.0 },
            CodecConfig::RandK { ratio: 1.0 },
            CodecConfig::Qsgd { bits: 32 },
        ] {
            let wc = WorkerCompressor::new(&cfg, 64, 1, 0).unwrap();
            assert_eq!(
                cfg.is_lossless(),
                wc.codec().is_identity(),
                "{cfg:?}: static and built identity classification disagree"
            );
        }
    }

    #[test]
    fn per_worker_streams_are_deterministic_and_distinct() {
        let n = 128;
        let g = grad(2, n);
        let cfg = CodecConfig::RandK { ratio: 0.1 };
        let mut a = WorkerCompressor::new(&cfg, n, 9, 0).unwrap();
        let mut b = WorkerCompressor::new(&cfg, n, 9, 0).unwrap();
        let mut c = WorkerCompressor::new(&cfg, n, 9, 1).unwrap();
        let pa = a.compress(&g).clone();
        let pb = b.compress(&g).clone();
        let pc = c.compress(&g).clone();
        assert_eq!(pa, pb, "same (seed, worker) must encode identically");
        assert_ne!(pa, pc, "different workers must draw distinct coordinates");
    }
}
