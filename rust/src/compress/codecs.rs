//! The codec implementations: TopK / RandK sparsification and QSGD-style
//! stochastic quantization, plus the exact identity codec.
//!
//! All encoders write into reusable [`WirePayload`] buffers and keep their
//! own selection scratch, so after the first call (which sizes the arenas)
//! the encode path performs no heap allocation. Randomized codecs own a
//! per-worker [`Pcg64`] stream: encoding is bit-deterministic given the
//! codec's seed and call sequence.
//!
//! Two codecs have a fast path gated on [`crate::optim::simd_enabled`]
//! (the same `[runtime] simd` switch as the update kernels), each pinned
//! bit-identical to its scalar reference by tests below:
//!
//! * **QSGD** packs levels through a streaming word accumulator
//!   ([`pack_levels`]) instead of per-field [`write_bits`] offset math;
//! * **TopK** selects on packed `(|g| bits, index)` u64 keys — one integer
//!   compare instead of a float comparator + explicit tiebreak — and can
//!   build keys / pre-select shard-parallel on the [`ComputePool`].
//!
//! This module also hosts the fused decode→compensate→apply entry points
//! ([`decode_sgd_apply`] / [`decode_dc_apply`] / [`decode_dca_apply`])
//! used by the parameter server's quantized push path: levels are decoded
//! block-at-a-time into a stack buffer (L1-resident) and applied with the
//! chunked kernels, so the weight state streams through memory exactly
//! once instead of bouncing through a densified gradient arena.

use std::sync::Arc;

use super::{index_bits, GradientCodec, WirePayload};
use crate::util::pool::ComputePool;
use crate::util::rng::Pcg64;

/// `ceil(ratio * n)` clamped to `[1, n]` — the sparsifiers' kept count.
pub(crate) fn kept(ratio: f64, n: usize) -> usize {
    ((ratio * n as f64).ceil() as usize).clamp(1, n)
}

/// Reuse `out` as a Sparse payload for `n` elements, returning cleared
/// idx/val buffers (variant replaced only on the first call).
fn sparse_bufs(out: &mut WirePayload, n: usize) -> (&mut Vec<u32>, &mut Vec<f32>) {
    if !matches!(out, WirePayload::Sparse { .. }) {
        *out = WirePayload::Sparse { n: 0, idx: Vec::new(), val: Vec::new() };
    }
    match out {
        WirePayload::Sparse { n: pn, idx, val } => {
            *pn = n as u32;
            idx.clear();
            val.clear();
            (idx, val)
        }
        _ => unreachable!(),
    }
}

/// Single source of truth for the sparse wire size: header + f32 values +
/// bit-packed indices ([`WirePayload::wire_bytes`] and the codecs' static
/// accounting both call this).
pub(crate) fn sparse_wire_bytes(n: usize, k: usize) -> usize {
    8 + 4 * k + (k * index_bits(n) as usize + 7) / 8
}

/// Single source of truth for the quantized wire size: self-describing
/// header — n (4B) + bits (1B) + norm (4B) — plus bit-packed levels.
pub(crate) fn quantized_wire_bytes(n: usize, bits: u32) -> usize {
    9 + (n * bits as usize + 7) / 8
}

// ---------------------------------------------------------------------------
// bit packing (shared by QSGD levels; width <= 32)
//
// All three routines operate on u64 WORDS, not per-field byte loops: a
// field of width <= 32 at a bit offset < 8 within its first byte spans at
// most 5 bytes, so whenever a full 8-byte window fits inside the buffer
// one unaligned little-endian load/store covers the whole field. Only the
// last few fields of a stream (where the window would run past the end)
// fall back to the byte loop — bit-for-bit the same layout, pinned by
// `word_packing_is_byte_exact_vs_reference` below.

/// Write `v` as a `width`-bit little-endian field at bit offset `off`.
/// `buf` must be pre-zeroed over the written range.
pub(crate) fn write_bits(buf: &mut [u8], off: usize, width: u32, v: u64) {
    debug_assert!(width <= 32);
    let v = v & ((1u64 << width) - 1);
    let byte = off / 8;
    let bit = off % 8;
    if byte + 8 <= buf.len() {
        let mut word = u64::from_le_bytes(buf[byte..byte + 8].try_into().expect("8-byte window"));
        word |= v << bit;
        buf[byte..byte + 8].copy_from_slice(&word.to_le_bytes());
        return;
    }
    // tail fields: the 8-byte window would run past the buffer
    let mut v = v;
    let mut off = off;
    let mut rem = width as usize;
    while rem > 0 {
        let byte = off / 8;
        let bit = off % 8;
        let take = (8 - bit).min(rem);
        buf[byte] |= ((v & ((1u64 << take) - 1)) as u8) << bit;
        v >>= take;
        off += take;
        rem -= take;
    }
}

/// Read a `width`-bit little-endian field at bit offset `off`.
pub(crate) fn read_bits(buf: &[u8], off: usize, width: u32) -> u64 {
    debug_assert!(width <= 32);
    let mask = (1u64 << width) - 1;
    let byte = off / 8;
    let bit = off % 8;
    if byte + 8 <= buf.len() {
        let word = u64::from_le_bytes(buf[byte..byte + 8].try_into().expect("8-byte window"));
        return (word >> bit) & mask;
    }
    let mut v = 0u64;
    let mut got = 0usize;
    let mut off = off;
    let mut rem = width as usize;
    while rem > 0 {
        let byte = off / 8;
        let bit = off % 8;
        let take = (8 - bit).min(rem);
        let part = (buf[byte] >> bit) as u64 & ((1u64 << take) - 1);
        v |= part << got;
        got += take;
        off += take;
        rem -= take;
    }
    v
}

/// Streaming bit writer: appends fixed-width fields to a little-endian bit
/// stream through a u64 accumulator, flushing a 32-bit word whenever one
/// completes. Per field this is a shift + or + compare — [`write_bits`]
/// recomputes byte/bit offsets and does an unaligned 8-byte RMW per field.
/// The emitted bytes are identical to per-field [`write_bits`] at
/// ascending offsets (pinned by `streaming_pack_matches_per_field_reference`).
pub(crate) struct BitPacker {
    acc: u64,
    acc_bits: u32,
    pos: usize,
}

impl BitPacker {
    pub(crate) fn new() -> Self {
        Self { acc: 0, acc_bits: 0, pos: 0 }
    }

    /// Append a `width`-bit field (`width <= 16`, so the accumulator never
    /// holds more than 47 bits before the flush check).
    #[inline(always)]
    pub(crate) fn push(&mut self, buf: &mut [u8], width: u32, v: u64) {
        debug_assert!(width <= 16);
        self.acc |= (v & ((1u64 << width) - 1)) << self.acc_bits;
        self.acc_bits += width;
        if self.acc_bits >= 32 {
            buf[self.pos..self.pos + 4].copy_from_slice(&(self.acc as u32).to_le_bytes());
            self.pos += 4;
            self.acc >>= 32;
            self.acc_bits -= 32;
        }
    }

    /// Flush the remaining partial word byte-wise (high bits of a partial
    /// final byte stay zero, matching [`write_bits`]' zero padding).
    pub(crate) fn finish(mut self, buf: &mut [u8]) {
        while self.acc_bits > 0 {
            buf[self.pos] = self.acc as u8;
            self.pos += 1;
            self.acc >>= 8;
            self.acc_bits = self.acc_bits.saturating_sub(8);
        }
    }
}

/// Pack pre-computed offset-binary levels (`width <= 16`) into a pre-zeroed
/// buffer via the streaming accumulator. Exposed (with the scalar form)
/// for the hotpath bench and the kernel equivalence tests.
pub fn pack_levels(packed: &mut [u8], width: u32, levels: &[u64]) {
    let mut p = BitPacker::new();
    for &v in levels {
        p.push(packed, width, v);
    }
    p.finish(packed);
}

/// Per-field reference packer: one [`write_bits`] call per level.
pub fn pack_levels_scalar(packed: &mut [u8], width: u32, levels: &[u64]) {
    for (i, &v) in levels.iter().enumerate() {
        write_bits(packed, i * width as usize, width, v);
    }
}

/// Dequantize a packed level stream (see [`WirePayload::Quantized`]).
/// Streams the packed bytes through a u64 accumulator (refilled a word at
/// a time while one fits), so the per-element work is a shift and a mask
/// instead of per-field offset arithmetic.
pub(crate) fn dequantize_into(out: &mut [f32], n: usize, bits: u32, norm: f32, packed: &[u8]) {
    debug_assert_eq!(out.len(), n);
    let l = ((1u32 << (bits - 1)) - 1) as i64;
    let scale = if l > 0 { norm / l as f32 } else { 0.0 };
    let mask = (1u64 << bits) - 1;
    let mut cur = LevelCursor::at(packed, bits, 0);
    for o in out.iter_mut() {
        let level = cur.next(bits, mask) as i64 - l;
        *o = level as f32 * scale;
    }
}

/// Streaming cursor over a packed level stream, startable at an arbitrary
/// element offset — the fused shard-slice decoders position one cursor per
/// shard range. Same refill discipline as the original streaming decode
/// (32-bit little-endian words while a full window fits, byte-wise at the
/// stream tail), and therefore the same decoded levels at every position
/// (`level_cursor_starts_at_arbitrary_offsets` pins mid-byte starts).
pub(crate) struct LevelCursor<'a> {
    packed: &'a [u8],
    acc: u64,
    acc_bits: u32,
    pos: usize,
}

impl<'a> LevelCursor<'a> {
    /// Position a cursor at element `elem` of a `bits`-wide stream.
    pub(crate) fn at(packed: &'a [u8], bits: u32, elem: usize) -> Self {
        let bit_off = elem * bits as usize;
        let mut c = Self { packed, acc: 0, acc_bits: 0, pos: bit_off / 8 };
        let skip = (bit_off % 8) as u32;
        if skip > 0 {
            // discard the partial byte in front of the first element
            c.refill(skip);
            c.acc >>= skip;
            c.acc_bits -= skip;
        }
        c
    }

    #[inline(always)]
    fn refill(&mut self, need: u32) {
        while self.acc_bits < need {
            // acc_bits < 32 here, so a 32-bit refill always fits in the
            // accumulator; the stream tail refills byte-wise
            if self.pos + 4 <= self.packed.len() {
                let w = u32::from_le_bytes(
                    self.packed[self.pos..self.pos + 4].try_into().expect("4-byte window"),
                ) as u64;
                self.acc |= w << self.acc_bits;
                self.pos += 4;
                self.acc_bits += 32;
            } else {
                debug_assert!(self.pos < self.packed.len(), "packed stream exhausted early");
                self.acc |= (self.packed[self.pos] as u64) << self.acc_bits;
                self.pos += 1;
                self.acc_bits += 8;
            }
        }
    }

    /// Next raw level (callers pass `mask = (1 << bits) - 1`).
    #[inline(always)]
    pub(crate) fn next(&mut self, bits: u32, mask: u64) -> u64 {
        self.refill(bits);
        let v = self.acc & mask;
        self.acc >>= bits;
        self.acc_bits -= bits;
        v
    }
}

// ---------------------------------------------------------------------------
// fused decode → compensate → apply
//
// The quantized push path's fast lane: instead of densifying the whole
// payload into a scratch arena and then running an update kernel over it
// (two full passes over n-sized buffers), decode FUSE_BLOCK levels at a
// time into a stack buffer and apply them immediately with the chunked
// kernels. The weight / backup / MeanSquare slices stream through memory
// exactly once, the decode buffer stays in L1, and the compensation math
// still vectorizes. Bit-identical to decode-then-apply: the cursor decodes
// the same level values as `dequantize_into` and the apply kernels are the
// same elementwise ops, so the block partition is unobservable.

/// Block size for the fused decoders: 2 KiB of f32 — comfortably
/// L1-resident alongside the operand lines, large enough that the chunked
/// apply kernels run at full width.
const FUSE_BLOCK: usize = 512;

/// Fused dequantize + SGD apply on one shard slice: `w -= lr * dq(g)`.
/// `start` is the slice's global element offset into the packed stream.
pub fn decode_sgd_apply(
    w: &mut [f32],
    start: usize,
    bits: u32,
    norm: f32,
    packed: &[u8],
    lr: f32,
) {
    let l = ((1u32 << (bits - 1)) - 1) as i64;
    let scale = if l > 0 { norm / l as f32 } else { 0.0 };
    let mask = (1u64 << bits) - 1;
    let mut cur = LevelCursor::at(packed, bits, start);
    let mut buf = [0.0f32; FUSE_BLOCK];
    let mut off = 0usize;
    while off < w.len() {
        let m = FUSE_BLOCK.min(w.len() - off);
        for b in buf[..m].iter_mut() {
            *b = (cur.next(bits, mask) as i64 - l) as f32 * scale;
        }
        crate::optim::sgd_step(&mut w[off..off + m], &buf[..m], lr);
        off += m;
    }
}

/// Fused dequantize + DC-ASGD-c apply (Eqn. 10) on one shard slice.
#[allow(clippy::too_many_arguments)]
pub fn decode_dc_apply(
    w: &mut [f32],
    w_bak: &[f32],
    start: usize,
    bits: u32,
    norm: f32,
    packed: &[u8],
    lr: f32,
    lam: f32,
) {
    debug_assert_eq!(w.len(), w_bak.len());
    let l = ((1u32 << (bits - 1)) - 1) as i64;
    let scale = if l > 0 { norm / l as f32 } else { 0.0 };
    let mask = (1u64 << bits) - 1;
    let mut cur = LevelCursor::at(packed, bits, start);
    let mut buf = [0.0f32; FUSE_BLOCK];
    let mut off = 0usize;
    while off < w.len() {
        let m = FUSE_BLOCK.min(w.len() - off);
        for b in buf[..m].iter_mut() {
            *b = (cur.next(bits, mask) as i64 - l) as f32 * scale;
        }
        crate::optim::dc_step(&mut w[off..off + m], &buf[..m], &w_bak[off..off + m], lr, lam);
        off += m;
    }
}

/// Fused dequantize + DC-ASGD-a apply (Eqn. 10 + 14) on one shard slice
/// (advances the slice's MeanSquare state).
#[allow(clippy::too_many_arguments)]
pub fn decode_dca_apply(
    w: &mut [f32],
    w_bak: &[f32],
    ms: &mut [f32],
    start: usize,
    bits: u32,
    norm: f32,
    packed: &[u8],
    lr: f32,
    lam0: f32,
    m: f32,
    eps: f32,
) {
    debug_assert_eq!(w.len(), w_bak.len());
    debug_assert_eq!(w.len(), ms.len());
    let l = ((1u32 << (bits - 1)) - 1) as i64;
    let scale = if l > 0 { norm / l as f32 } else { 0.0 };
    let mask = (1u64 << bits) - 1;
    let mut cur = LevelCursor::at(packed, bits, start);
    let mut buf = [0.0f32; FUSE_BLOCK];
    let mut off = 0usize;
    while off < w.len() {
        let blk = FUSE_BLOCK.min(w.len() - off);
        for b in buf[..blk].iter_mut() {
            *b = (cur.next(bits, mask) as i64 - l) as f32 * scale;
        }
        crate::optim::dc_adaptive_step(
            &mut w[off..off + blk],
            &buf[..blk],
            &w_bak[off..off + blk],
            &mut ms[off..off + blk],
            lr,
            lam0,
            m,
            eps,
        );
        off += blk;
    }
}

// ---------------------------------------------------------------------------
// identity

/// Exact passthrough: dense f32 on the wire. Used for `qsgd` at 32 bits
/// and directly in tests; `CodecConfig::None` skips encoding entirely.
#[derive(Debug, Default)]
pub struct IdentityCodec;

impl GradientCodec for IdentityCodec {
    fn name(&self) -> &'static str {
        "identity"
    }
    fn encode(&mut self, g: &[f32], out: &mut WirePayload) {
        if !matches!(out, WirePayload::Dense(_)) {
            *out = WirePayload::Dense(Vec::new());
        }
        match out {
            WirePayload::Dense(v) => {
                v.clear();
                v.extend_from_slice(g);
            }
            _ => unreachable!(),
        }
    }
    fn wire_bytes(&self, n: usize) -> usize {
        4 * n
    }
    fn is_identity(&self) -> bool {
        true
    }
}

// ---------------------------------------------------------------------------
// TopK

/// Fixed chunk width for the pool-parallel key build / pre-selection:
/// independent of lane count, so the kept set never depends on `threads`
/// (it is exact regardless — see `encode` — but fixed chunks also keep the
/// work split deterministic).
const TOPK_CHUNK: usize = 1 << 16;

/// Shared-nothing writer handle for the pool tasks: each task writes a
/// disjoint `TOPK_CHUNK`-aligned range and [`ComputePool::run`] joins all
/// tasks before returning, so no two tasks alias and no reference escapes
/// (the same contract `ShardedStore::par_for_each_shard` relies on).
struct SyncSlicePtr(*mut u64);
unsafe impl Sync for SyncSlicePtr {}

/// Selection key: |g[i]|'s IEEE bits in the high word, bit-inverted index
/// in the low word. For non-NaN f32, the bit pattern of |x| orders exactly
/// like |x|, so comparing keys descending == ordering by (|g| desc, index
/// asc) — one integer compare replaces the float comparator + explicit
/// tiebreak, and keys are unique, so the selected set has no boundary
/// ambiguity by construction.
#[inline(always)]
fn topk_key(x: f32, i: u32) -> u64 {
    ((x.abs().to_bits() as u64) << 32) | (u32::MAX - i) as u64
}

/// Keep the `ceil(ratio * n)` largest-|value| coordinates; exact values,
/// ascending indices. Ratio 1.0 keeps everything (exact identity).
///
/// Two selection paths, both producing the identical kept set (ties break
/// by lowest index): the scalar reference (float comparator over an index
/// permutation) and, when [`crate::optim::simd_enabled`], packed u64 keys
/// with optional [`ComputePool`]-parallel key building + per-chunk
/// pre-selection ([`TopK::with_pool`]).
pub struct TopK {
    ratio: f64,
    /// Scalar-path selection scratch: index permutation partitioned by |g|.
    order: Vec<u32>,
    /// Key-path scratch: one packed key per element.
    keys: Vec<u64>,
    /// Two-phase selection scratch: the per-chunk winners.
    cand: Vec<u64>,
    /// Parallel key build / pre-selection when set (and non-serial).
    pool: Option<Arc<ComputePool>>,
}

impl std::fmt::Debug for TopK {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // manual: ComputePool carries worker handles and has no Debug
        f.debug_struct("TopK")
            .field("ratio", &self.ratio)
            .field("pooled", &self.pool.is_some())
            .finish()
    }
}

impl TopK {
    pub fn new(ratio: f64) -> Self {
        assert!(ratio > 0.0 && ratio <= 1.0);
        Self { ratio, order: Vec::new(), keys: Vec::new(), cand: Vec::new(), pool: None }
    }

    /// Run key building and chunk pre-selection on `pool`. The kept set is
    /// exact either way; the pool trades wallclock only.
    pub fn with_pool(mut self, pool: Arc<ComputePool>) -> Self {
        self.pool = Some(pool);
        self
    }
}

impl GradientCodec for TopK {
    fn name(&self) -> &'static str {
        "topk"
    }
    fn encode(&mut self, g: &[f32], out: &mut WirePayload) {
        let n = g.len();
        let k = kept(self.ratio, n);
        let (idx, val) = sparse_bufs(out, n);
        if k == n {
            idx.extend(0..n as u32);
            val.extend_from_slice(g);
            return;
        }
        if crate::optim::simd_enabled() {
            // key path: build packed keys, select the k largest by integer
            // compare, recover indices from the low words
            self.keys.resize(n, 0);
            let chunks = n.div_ceil(TOPK_CHUNK);
            let par = match &self.pool {
                Some(p) if !p.is_serial() && chunks > 1 => Some(Arc::clone(p)),
                _ => None,
            };
            if let Some(pool) = &par {
                let dst = SyncSlicePtr(self.keys.as_mut_ptr());
                pool.run(chunks, &|c| {
                    let lo = c * TOPK_CHUNK;
                    let hi = (lo + TOPK_CHUNK).min(n);
                    // SAFETY: task c writes only [lo, hi), ranges are
                    // disjoint, and run() joins before returning
                    let ks = unsafe { std::slice::from_raw_parts_mut(dst.0.add(lo), hi - lo) };
                    for (o, j) in ks.iter_mut().zip(lo..hi) {
                        *o = topk_key(g[j], j as u32);
                    }
                });
            } else {
                for (i, (o, &x)) in self.keys.iter_mut().zip(g).enumerate() {
                    *o = topk_key(x, i as u32);
                }
            }
            // two-phase selection when parallel and clearly profitable:
            // per-chunk top-k (every global winner is a winner of its own
            // chunk), then one final select over the chunks*k candidates.
            let keys = &mut self.keys;
            let two_phase = par.is_some() && k < TOPK_CHUNK && 2 * k * chunks <= n;
            if two_phase {
                let pool = par.as_ref().expect("two_phase implies a pool");
                let dst = SyncSlicePtr(keys.as_mut_ptr());
                pool.run(chunks, &|c| {
                    let lo = c * TOPK_CHUNK;
                    let hi = (lo + TOPK_CHUNK).min(n);
                    // SAFETY: disjoint chunk ranges, joined before return
                    let ks = unsafe { std::slice::from_raw_parts_mut(dst.0.add(lo), hi - lo) };
                    if k < ks.len() {
                        ks.select_nth_unstable_by(k - 1, |a, b| b.cmp(a));
                    }
                });
                self.cand.clear();
                for c in 0..chunks {
                    let lo = c * TOPK_CHUNK;
                    let hi = (lo + TOPK_CHUNK).min(n);
                    self.cand.extend_from_slice(&keys[lo..(lo + k).min(hi)]);
                }
                if k < self.cand.len() {
                    self.cand.select_nth_unstable_by(k - 1, |a, b| b.cmp(a));
                }
                idx.extend(self.cand[..k].iter().map(|&key| u32::MAX - key as u32));
            } else {
                keys.select_nth_unstable_by(k - 1, |a, b| b.cmp(a));
                idx.extend(keys[..k].iter().map(|&key| u32::MAX - key as u32));
            }
            idx.sort_unstable();
            val.extend(idx.iter().map(|&i| g[i as usize]));
            return;
        }
        // scalar reference path
        self.order.clear();
        self.order.extend(0..n as u32);
        // partition the k largest magnitudes to the front (O(n) expected),
        // then emit them in ascending index order for the sharded apply.
        // Ties break by index explicitly: select_nth_unstable_by partitions
        // equal keys arbitrarily, so without the index tiebreak the kept
        // set could differ across platforms / std versions whenever
        // magnitudes collide at the selection boundary.
        self.order.select_nth_unstable_by(k - 1, |&a, &b| {
            g[b as usize]
                .abs()
                .partial_cmp(&g[a as usize].abs())
                .unwrap_or(std::cmp::Ordering::Equal)
                .then_with(|| a.cmp(&b))
        });
        idx.extend_from_slice(&self.order[..k]);
        idx.sort_unstable();
        val.extend(idx.iter().map(|&i| g[i as usize]));
    }
    fn wire_bytes(&self, n: usize) -> usize {
        sparse_wire_bytes(n, kept(self.ratio, n))
    }
    fn is_identity(&self) -> bool {
        self.ratio >= 1.0
    }
}

// ---------------------------------------------------------------------------
// RandK

/// Keep `ceil(ratio * n)` uniformly random coordinates (exact values,
/// unscaled — the EF residual absorbs the sampling bias; the classic
/// `n/k` unbiasing rescale would break EF contractiveness). Ratio 1.0
/// keeps everything.
#[derive(Debug)]
pub struct RandK {
    ratio: f64,
    rng: Pcg64,
    /// Persistent permutation buffer for the partial Fisher–Yates draw.
    perm: Vec<u32>,
}

impl RandK {
    pub fn new(ratio: f64, rng: Pcg64) -> Self {
        assert!(ratio > 0.0 && ratio <= 1.0);
        Self { ratio, rng, perm: Vec::new() }
    }
}

impl GradientCodec for RandK {
    fn name(&self) -> &'static str {
        "randk"
    }
    fn encode(&mut self, g: &[f32], out: &mut WirePayload) {
        let n = g.len();
        let k = kept(self.ratio, n);
        let (idx, val) = sparse_bufs(out, n);
        if k == n {
            idx.extend(0..n as u32);
            val.extend_from_slice(g);
            return;
        }
        if self.perm.len() != n {
            self.perm.clear();
            self.perm.extend(0..n as u32);
        }
        // partial Fisher–Yates: the first k entries are a uniform sample
        // (the buffer stays permuted between calls, which is still uniform)
        for i in 0..k {
            let j = i + self.rng.below((n - i) as u64) as usize;
            self.perm.swap(i, j);
        }
        idx.extend_from_slice(&self.perm[..k]);
        idx.sort_unstable();
        val.extend(idx.iter().map(|&i| g[i as usize]));
    }
    fn wire_bytes(&self, n: usize) -> usize {
        sparse_wire_bytes(n, kept(self.ratio, n))
    }
    fn is_identity(&self) -> bool {
        self.ratio >= 1.0
    }
}

// ---------------------------------------------------------------------------
// QSGD

/// QSGD-style stochastic quantization at `bits` bits per element: levels
/// `q ∈ [-L, L]` with `L = 2^(bits-1) - 1` against the max-norm, rounded
/// stochastically (unbiased: `E[dequant] = value`). `bits = 32` is exact
/// f32 passthrough. Per-element error is at most `norm / L`, so with
/// error feedback the residual stays bounded for `bits >= 3`.
#[derive(Debug)]
pub struct Qsgd {
    bits: u32,
    rng: Pcg64,
}

impl Qsgd {
    pub fn new(bits: u32, rng: Pcg64) -> Self {
        assert!((3..=16).contains(&bits) || bits == 32, "qsgd bits {bits}");
        Self { bits, rng }
    }
}

/// SplitMix64 finalizer — the per-element mixing step of QSGD's
/// counter-based rounding hash.
#[inline(always)]
fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Uniform `[0, 1)` rounding draw for element `i` under encode key `key`:
/// a pure function of `(key, i)`, so the quantize loop carries no RNG
/// state from one element to the next (53-bit mantissa fill, the same
/// convention as [`Pcg64::next_f64`]).
#[inline(always)]
fn rounding_draw(key: u64, i: u64) -> f64 {
    (mix64(key ^ i.wrapping_mul(0x9E37_79B9_7F4A_7C15)) >> 11) as f64
        * (1.0 / (1u64 << 53) as f64)
}

impl GradientCodec for Qsgd {
    fn name(&self) -> &'static str {
        "qsgd"
    }
    fn encode(&mut self, g: &[f32], out: &mut WirePayload) {
        if self.bits >= 32 {
            // exact: dense f32 on the wire
            IdentityCodec.encode(g, out);
            return;
        }
        let n = g.len();
        if !matches!(out, WirePayload::Quantized { .. }) {
            *out = WirePayload::Quantized { n: 0, bits: 0, norm: 0.0, packed: Vec::new() };
        }
        let (pn, pbits, pnorm, packed) = match out {
            WirePayload::Quantized { n, bits, norm, packed } => (n, bits, norm, packed),
            _ => unreachable!(),
        };
        *pn = n as u32;
        *pbits = self.bits as u8;
        let norm = g.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        *pnorm = norm;
        let nbytes = (n * self.bits as usize + 7) / 8;
        packed.clear();
        packed.resize(nbytes, 0);
        if norm == 0.0 {
            return; // all-zero levels decode to zero
        }
        let l = ((1u32 << (self.bits - 1)) - 1) as f32;
        let li = l as i64;
        // Counter-based stochastic rounding: ONE key per encode from the
        // codec's stream, then [`rounding_draw`] hashes `(key, i)` for each
        // element. Iterations carry no RNG state between them — the old
        // `rng.next_f64()`-per-element chain serialized the whole quantize
        // loop and cost the streaming path its vectorization — and both
        // packing paths consume the identical draw sequence, so the payload
        // is bit-identical either way (`simd_toggle_paths_are_bit_identical`).
        let key = self.rng.next_u64();
        if crate::optim::simd_enabled() {
            let mut packer = BitPacker::new();
            for (i, &x) in g.iter().enumerate() {
                let scaled = x / norm * l; // in [-l, l]
                let lo = scaled.floor();
                let p = scaled - lo;
                let q = (lo as i64 + (rounding_draw(key, i as u64) < p as f64) as i64)
                    .clamp(-li, li);
                packer.push(packed, self.bits, (q + li) as u64);
            }
            packer.finish(packed);
        } else {
            for (i, &x) in g.iter().enumerate() {
                let scaled = x / norm * l; // in [-l, l]
                let lo = scaled.floor();
                let p = scaled - lo;
                let q = (lo as i64 + (rounding_draw(key, i as u64) < p as f64) as i64)
                    .clamp(-li, li);
                write_bits(packed, i * self.bits as usize, self.bits, (q + li) as u64);
            }
        }
    }
    fn wire_bytes(&self, n: usize) -> usize {
        if self.bits >= 32 {
            4 * n
        } else {
            quantized_wire_bytes(n, self.bits)
        }
    }
    fn is_identity(&self) -> bool {
        self.bits >= 32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grad(seed: u64, n: usize) -> Vec<f32> {
        let mut rng = Pcg64::new(seed);
        (0..n).map(|_| rng.normal(0.0, 1.0) as f32).collect()
    }

    /// The pre-word-packing byte-loop writer, kept as the layout oracle.
    fn write_bits_ref(buf: &mut [u8], off: usize, width: u32, v: u64) {
        let mut v = v & ((1u64 << width) - 1);
        let mut off = off;
        let mut rem = width as usize;
        while rem > 0 {
            let byte = off / 8;
            let bit = off % 8;
            let take = (8 - bit).min(rem);
            buf[byte] |= ((v & ((1u64 << take) - 1)) as u8) << bit;
            v >>= take;
            off += take;
            rem -= take;
        }
    }

    /// The pre-word-packing byte-loop reader, kept as the layout oracle.
    fn read_bits_ref(buf: &[u8], off: usize, width: u32) -> u64 {
        let mut v = 0u64;
        let mut got = 0usize;
        let mut off = off;
        let mut rem = width as usize;
        while rem > 0 {
            let byte = off / 8;
            let bit = off % 8;
            let take = (8 - bit).min(rem);
            let part = (buf[byte] >> bit) as u64 & ((1u64 << take) - 1);
            v |= part << got;
            got += take;
            off += take;
            rem -= take;
        }
        v
    }

    #[test]
    fn word_packing_is_byte_exact_vs_reference() {
        // every width, awkward field counts (word path + tail fallback):
        // the u64-word writer must produce byte-identical buffers to the
        // byte-loop reference, and both readers must agree on every field
        let mut rng = Pcg64::new(77);
        for width in 1u32..=32 {
            for count in [1usize, 7, 64, 129] {
                let vals: Vec<u64> =
                    (0..count).map(|_| rng.next_u64() & ((1u64 << width) - 1)).collect();
                let nbytes = (count * width as usize + 7) / 8;
                let mut fast = vec![0u8; nbytes];
                let mut slow = vec![0u8; nbytes];
                for (i, &v) in vals.iter().enumerate() {
                    write_bits(&mut fast, i * width as usize, width, v);
                    write_bits_ref(&mut slow, i * width as usize, width, v);
                }
                assert_eq!(fast, slow, "width {width} count {count}: payload bytes diverged");
                for (i, &v) in vals.iter().enumerate() {
                    assert_eq!(read_bits(&fast, i * width as usize, width), v);
                    assert_eq!(read_bits_ref(&fast, i * width as usize, width), v);
                }
            }
        }
    }

    #[test]
    fn streaming_pack_matches_per_field_reference() {
        // the BitPacker stream must be byte-identical to per-field
        // write_bits at every width it supports, including partial-word
        // tails (counts chosen to land mid-byte and mid-word)
        let mut rng = Pcg64::new(78);
        for width in [1u32, 3, 4, 5, 7, 8, 11, 12, 15, 16] {
            for count in [1usize, 2, 7, 31, 32, 33, 129, 1003] {
                let vals: Vec<u64> =
                    (0..count).map(|_| rng.next_u64() & ((1u64 << width) - 1)).collect();
                let nbytes = (count * width as usize + 7) / 8;
                let mut fast = vec![0u8; nbytes];
                let mut slow = vec![0u8; nbytes];
                pack_levels(&mut fast, width, &vals);
                pack_levels_scalar(&mut slow, width, &vals);
                assert_eq!(fast, slow, "width {width} count {count}: streamed pack diverged");
            }
        }
    }

    #[test]
    fn streaming_dequantize_matches_per_field_reference() {
        let n = 1003; // odd length: exercises the byte-wise refill tail
        let g = grad(21, n);
        for bits in [3u32, 4, 7, 8, 12, 16] {
            let mut codec = Qsgd::new(bits, Pcg64::new(9));
            let mut out = WirePayload::default();
            codec.encode(&g, &mut out);
            let (norm, packed) = match &out {
                WirePayload::Quantized { norm, packed, .. } => (*norm, packed.clone()),
                other => panic!("expected quantized, got {other:?}"),
            };
            let mut fast = vec![0.0f32; n];
            dequantize_into(&mut fast, n, bits, norm, &packed);
            // per-field reference decode
            let l = ((1u32 << (bits - 1)) - 1) as i64;
            let scale = if l > 0 { norm / l as f32 } else { 0.0 };
            let slow: Vec<f32> = (0..n)
                .map(|i| {
                    (read_bits_ref(&packed, i * bits as usize, bits) as i64 - l) as f32 * scale
                })
                .collect();
            assert_eq!(fast, slow, "bits {bits}: streaming decode diverged");
        }
    }

    #[test]
    fn level_cursor_starts_at_arbitrary_offsets() {
        // a cursor positioned at element e must decode the identical level
        // sequence a from-zero reader sees — including starts that land
        // mid-byte (every bits/offset combination below hits some)
        let n = 1003;
        let mut rng = Pcg64::new(79);
        for bits in [3u32, 4, 7, 8, 12, 16] {
            let mask = (1u64 << bits) - 1;
            let vals: Vec<u64> = (0..n).map(|_| rng.next_u64() & mask).collect();
            let mut packed = vec![0u8; (n * bits as usize + 7) / 8];
            pack_levels_scalar(&mut packed, bits, &vals);
            for start in [0usize, 1, 2, 3, 5, 8, 127, 300, 301, n - 1] {
                let mut cur = LevelCursor::at(&packed, bits, start);
                for (e, &v) in vals.iter().enumerate().skip(start) {
                    assert_eq!(
                        cur.next(bits, mask),
                        v,
                        "bits {bits} start {start}: wrong level at {e}"
                    );
                }
            }
        }
    }

    #[test]
    fn fused_decode_apply_matches_staged_bitwise() {
        // decode_*_apply over shard slices (mid-stream cursor starts) must
        // equal densify-then-kernel over the same slices, bit for bit
        let n = 1003;
        let g = grad(22, n);
        let w0 = grad(23, n);
        let bak = grad(24, n);
        let ms0: Vec<f32> = grad(25, n).iter().map(|x| x.abs()).collect();
        let ranges = [(0usize, 300usize), (300, 301), (301, n)];
        for bits in [4u32, 8] {
            let mut codec = Qsgd::new(bits, Pcg64::new(11));
            let mut out = WirePayload::default();
            codec.encode(&g, &mut out);
            let (norm, packed) = match &out {
                WirePayload::Quantized { norm, packed, .. } => (*norm, packed.clone()),
                other => panic!("expected quantized, got {other:?}"),
            };
            let mut dense = vec![0.0f32; n];
            out.decode_into(&mut dense);

            let (lr, lam, lam0, m, eps) = (0.1f32, 0.7f32, 2.0f32, 0.95f32, 1e-7f32);

            let mut ws = w0.clone();
            let mut wf = w0.clone();
            for &(lo, hi) in &ranges {
                crate::optim::sgd_step(&mut ws[lo..hi], &dense[lo..hi], lr);
                decode_sgd_apply(&mut wf[lo..hi], lo, bits, norm, &packed, lr);
            }
            assert_eq!(ws, wf, "bits {bits}: fused sgd diverged");

            let mut ws = w0.clone();
            let mut wf = w0.clone();
            for &(lo, hi) in &ranges {
                crate::optim::dc_step(&mut ws[lo..hi], &dense[lo..hi], &bak[lo..hi], lr, lam);
                decode_dc_apply(&mut wf[lo..hi], &bak[lo..hi], lo, bits, norm, &packed, lr, lam);
            }
            assert_eq!(ws, wf, "bits {bits}: fused dc diverged");

            let mut ws = w0.clone();
            let mut wf = w0.clone();
            let mut mss = ms0.clone();
            let mut msf = ms0.clone();
            for &(lo, hi) in &ranges {
                crate::optim::dc_adaptive_step(
                    &mut ws[lo..hi],
                    &dense[lo..hi],
                    &bak[lo..hi],
                    &mut mss[lo..hi],
                    lr,
                    lam0,
                    m,
                    eps,
                );
                decode_dca_apply(
                    &mut wf[lo..hi],
                    &bak[lo..hi],
                    &mut msf[lo..hi],
                    lo,
                    bits,
                    norm,
                    &packed,
                    lr,
                    lam0,
                    m,
                    eps,
                );
            }
            assert_eq!(ws, wf, "bits {bits}: fused dca diverged");
            assert_eq!(mss, msf, "bits {bits}: fused dca MeanSquare diverged");
        }
    }

    #[test]
    fn simd_toggle_paths_are_bit_identical() {
        // the ONLY test in this binary that flips the global dispatch: the
        // optimized and scalar codec paths must emit byte-identical
        // payloads (other concurrently-running tests are unaffected by the
        // flip because every dispatch target is bit-identical)
        let n = 70_000; // > TOPK_CHUNK so the pool path engages
        let g: Vec<f32> = (0..n)
            .map(|i| {
                // tie-heavy: few distinct magnitudes stress the selection
                let mag = ((i * 37) % 5 + 1) as f32;
                if i % 2 == 0 {
                    mag
                } else {
                    -mag
                }
            })
            .collect();

        let encode_with = |codec: &mut dyn GradientCodec, on: bool| {
            crate::optim::set_simd_enabled(on);
            let mut out = WirePayload::default();
            codec.encode(&g, &mut out);
            crate::optim::set_simd_enabled(true);
            out
        };

        // qsgd: fresh codecs with the same seed so the RNG streams align
        let mut q_on = Qsgd::new(4, Pcg64::new(31));
        let mut q_off = Qsgd::new(4, Pcg64::new(31));
        let a = encode_with(&mut q_on, true);
        let b = encode_with(&mut q_off, false);
        assert_eq!(a, b, "qsgd payload differs between simd and scalar paths");

        // topk: serial keys vs scalar comparator vs pool-parallel keys
        let mut t_scalar = TopK::new(0.01);
        let mut t_keys = TopK::new(0.01);
        let mut t_pool =
            TopK::new(0.01).with_pool(Arc::new(crate::util::pool::ComputePool::new(4)));
        let a = encode_with(&mut t_scalar, false);
        let b = encode_with(&mut t_keys, true);
        let c = encode_with(&mut t_pool, true);
        assert_eq!(a, b, "topk kept set differs between comparator and key paths");
        assert_eq!(b, c, "topk kept set differs between serial and pooled key paths");
    }

    #[test]
    fn bit_roundtrip_all_widths() {
        for width in 1u32..=32 {
            let vals: Vec<u64> = (0..50)
                .map(|i| (i as u64).wrapping_mul(0x9E37_79B9) & ((1u64 << width) - 1))
                .collect();
            let mut buf = vec![0u8; (50 * width as usize + 7) / 8];
            for (i, &v) in vals.iter().enumerate() {
                write_bits(&mut buf, i * width as usize, width, v);
            }
            for (i, &v) in vals.iter().enumerate() {
                assert_eq!(read_bits(&buf, i * width as usize, width), v, "width {width}");
            }
        }
    }

    #[test]
    fn topk_keeps_largest_magnitudes_sorted() {
        let g = vec![0.1f32, -5.0, 0.0, 3.0, -0.2, 4.0];
        let mut codec = TopK::new(0.5); // k = 3
        let mut out = WirePayload::default();
        codec.encode(&g, &mut out);
        match &out {
            WirePayload::Sparse { n, idx, val } => {
                assert_eq!(*n, 6);
                assert_eq!(idx, &[1, 3, 5], "largest |g| at ascending indices");
                assert_eq!(val, &[-5.0, 3.0, 4.0]);
            }
            other => panic!("expected sparse, got {other:?}"),
        }
        let mut dec = vec![9.0f32; 6];
        out.decode_into(&mut dec);
        assert_eq!(dec, vec![0.0, -5.0, 0.0, 3.0, 0.0, 4.0]);
    }

    #[test]
    fn topk_breaks_ties_by_index_deterministically() {
        // tie-heavy gradient: every coordinate has one of two magnitudes,
        // so the selection boundary falls inside a huge tie class. The
        // kept set must match a full-sort reference ordered by
        // (|g| desc, index asc) — i.e. lowest indices win inside a tie —
        // regardless of how select_nth partitions internally. Exercises
        // the key path (simd default on); the toggle test covers scalar.
        let n = 256;
        let g: Vec<f32> = (0..n)
            .map(|i| {
                let mag = if i % 5 == 0 { 2.0 } else { 1.0 };
                if i % 2 == 0 {
                    mag
                } else {
                    -mag
                }
            })
            .collect();
        for ratio in [0.1f64, 0.3, 0.5, 0.9] {
            let k = kept(ratio, n);
            let mut reference: Vec<u32> = (0..n as u32).collect();
            reference.sort_by(|&a, &b| {
                g[b as usize]
                    .abs()
                    .partial_cmp(&g[a as usize].abs())
                    .unwrap()
                    .then_with(|| a.cmp(&b))
            });
            let mut expect: Vec<u32> = reference[..k].to_vec();
            expect.sort_unstable();
            let mut codec = TopK::new(ratio);
            let mut out = WirePayload::default();
            codec.encode(&g, &mut out);
            match &out {
                WirePayload::Sparse { idx, val, .. } => {
                    assert_eq!(idx, &expect, "ratio {ratio}: tie-break not by index");
                    for (&i, &v) in idx.iter().zip(val) {
                        assert_eq!(v, g[i as usize]);
                    }
                }
                other => panic!("expected sparse, got {other:?}"),
            }
            // and the selection is stable across repeated encodes
            let first = out.clone();
            codec.encode(&g, &mut out);
            assert_eq!(first, out, "ratio {ratio}: repeated encode diverged");
        }
    }

    #[test]
    fn topk_ratio_one_is_exact_identity() {
        let g = grad(3, 257);
        let mut codec = TopK::new(1.0);
        assert!(codec.is_identity());
        let mut out = WirePayload::default();
        codec.encode(&g, &mut out);
        let mut dec = vec![0.0f32; 257];
        out.decode_into(&mut dec);
        assert_eq!(dec, g);
    }

    #[test]
    fn randk_samples_k_distinct_ascending() {
        let g = grad(4, 500);
        let mut codec = RandK::new(0.1, Pcg64::new(9));
        let mut out = WirePayload::default();
        codec.encode(&g, &mut out);
        match &out {
            WirePayload::Sparse { idx, val, .. } => {
                assert_eq!(idx.len(), 50);
                assert!(idx.windows(2).all(|w| w[0] < w[1]), "indices not strictly ascending");
                for (&i, &v) in idx.iter().zip(val) {
                    assert_eq!(v, g[i as usize], "values must be exact");
                }
            }
            other => panic!("expected sparse, got {other:?}"),
        }
        // successive encodes draw different coordinate sets
        let first = out.clone();
        codec.encode(&g, &mut out);
        assert_ne!(first, out);
    }

    #[test]
    fn qsgd_error_bounded_by_norm_over_l() {
        let n = 1000;
        let g = grad(5, n);
        for bits in [4u32, 6, 8] {
            let mut codec = Qsgd::new(bits, Pcg64::new(1));
            let mut out = WirePayload::default();
            codec.encode(&g, &mut out);
            let mut dec = vec![0.0f32; n];
            out.decode_into(&mut dec);
            let norm = g.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            let l = ((1u32 << (bits - 1)) - 1) as f32;
            let bound = norm / l * 1.0001;
            for (a, b) in g.iter().zip(&dec) {
                assert!((a - b).abs() <= bound, "bits={bits}: |{a} - {b}| > {bound}");
            }
        }
    }

    #[test]
    fn qsgd_rounding_is_unbiased_on_average() {
        let n = 512;
        let g = grad(6, n);
        let mut codec = Qsgd::new(4, Pcg64::new(2));
        let mut out = WirePayload::default();
        let mut mean = vec![0.0f64; n];
        let trials = 400;
        let mut dec = vec![0.0f32; n];
        for _ in 0..trials {
            codec.encode(&g, &mut out);
            out.decode_into(&mut dec);
            for (m, &d) in mean.iter_mut().zip(&dec) {
                *m += d as f64 / trials as f64;
            }
        }
        let norm = g.iter().fold(0.0f32, |m, &x| m.max(x.abs())) as f64;
        let l = 7.0; // bits=4
        // stderr of the mean ~ (norm/l) / sqrt(trials); allow 5 sigma
        let tol = norm / l / (trials as f64).sqrt() * 5.0;
        for (i, (&m, &x)) in mean.iter().zip(&g).enumerate() {
            assert!((m - x as f64).abs() < tol, "elem {i}: mean {m} vs {x} (tol {tol})");
        }
    }

    #[test]
    fn qsgd_counter_rng_is_deterministic_per_seed_and_fresh_per_encode() {
        // one rounding key per encode: same seed + call sequence must
        // reproduce the payload exactly, while successive encodes of the
        // same gradient draw fresh keys and move the stochastic levels
        let g = grad(30, 777);
        let mut a = Qsgd::new(4, Pcg64::new(55));
        let mut b = Qsgd::new(4, Pcg64::new(55));
        let (mut oa, mut ob) = (WirePayload::default(), WirePayload::default());
        a.encode(&g, &mut oa);
        b.encode(&g, &mut ob);
        assert_eq!(oa, ob, "same seed + call sequence must give identical payloads");
        b.encode(&g, &mut ob);
        assert_ne!(oa, ob, "successive encodes reused the rounding key");
    }

    #[test]
    fn qsgd_zero_gradient_encodes_to_zero() {
        let mut codec = Qsgd::new(4, Pcg64::new(3));
        let mut out = WirePayload::default();
        codec.encode(&vec![0.0f32; 64], &mut out);
        let mut dec = vec![1.0f32; 64];
        out.decode_into(&mut dec);
        assert!(dec.iter().all(|&x| x == 0.0));
        assert_eq!(out.wire_bytes(), 9 + 32);
    }

    #[test]
    fn qsgd_32_bits_is_dense_exact() {
        let g = grad(7, 100);
        let mut codec = Qsgd::new(32, Pcg64::new(4));
        assert!(codec.is_identity());
        let mut out = WirePayload::default();
        codec.encode(&g, &mut out);
        assert!(matches!(out, WirePayload::Dense(_)));
        let mut dec = vec![0.0f32; 100];
        out.decode_into(&mut dec);
        assert_eq!(dec, g);
        assert_eq!(codec.wire_bytes(100), 400);
    }

    #[test]
    fn wire_bytes_match_payload_accounting() {
        let n = 4096;
        let g = grad(8, n);
        let mut topk = TopK::new(0.1);
        let mut randk = RandK::new(0.1, Pcg64::new(5));
        let mut qsgd = Qsgd::new(4, Pcg64::new(6));
        let codecs: [&mut dyn GradientCodec; 3] = [&mut topk, &mut randk, &mut qsgd];
        for codec in codecs {
            let mut out = WirePayload::default();
            codec.encode(&g, &mut out);
            assert_eq!(
                codec.wire_bytes(n),
                out.wire_bytes(),
                "{}: static and payload wire sizes disagree",
                codec.name()
            );
            assert!(out.wire_bytes() < 4 * n, "{} did not compress", codec.name());
        }
    }
}
