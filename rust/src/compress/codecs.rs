//! The codec implementations: TopK / RandK sparsification and QSGD-style
//! stochastic quantization, plus the exact identity codec.
//!
//! All encoders write into reusable [`WirePayload`] buffers and keep their
//! own selection scratch, so after the first call (which sizes the arenas)
//! the encode path performs no heap allocation. Randomized codecs own a
//! per-worker [`Pcg64`] stream: encoding is bit-deterministic given the
//! codec's seed and call sequence.

use super::{index_bits, GradientCodec, WirePayload};
use crate::util::rng::Pcg64;

/// `ceil(ratio * n)` clamped to `[1, n]` — the sparsifiers' kept count.
pub(crate) fn kept(ratio: f64, n: usize) -> usize {
    ((ratio * n as f64).ceil() as usize).clamp(1, n)
}

/// Reuse `out` as a Sparse payload for `n` elements, returning cleared
/// idx/val buffers (variant replaced only on the first call).
fn sparse_bufs(out: &mut WirePayload, n: usize) -> (&mut Vec<u32>, &mut Vec<f32>) {
    if !matches!(out, WirePayload::Sparse { .. }) {
        *out = WirePayload::Sparse { n: 0, idx: Vec::new(), val: Vec::new() };
    }
    match out {
        WirePayload::Sparse { n: pn, idx, val } => {
            *pn = n as u32;
            idx.clear();
            val.clear();
            (idx, val)
        }
        _ => unreachable!(),
    }
}

/// Single source of truth for the sparse wire size: header + f32 values +
/// bit-packed indices ([`WirePayload::wire_bytes`] and the codecs' static
/// accounting both call this).
pub(crate) fn sparse_wire_bytes(n: usize, k: usize) -> usize {
    8 + 4 * k + (k * index_bits(n) as usize + 7) / 8
}

/// Single source of truth for the quantized wire size: self-describing
/// header — n (4B) + bits (1B) + norm (4B) — plus bit-packed levels.
pub(crate) fn quantized_wire_bytes(n: usize, bits: u32) -> usize {
    9 + (n * bits as usize + 7) / 8
}

// ---------------------------------------------------------------------------
// bit packing (shared by QSGD levels; width <= 32)
//
// All three routines operate on u64 WORDS, not per-field byte loops: a
// field of width <= 32 at a bit offset < 8 within its first byte spans at
// most 5 bytes, so whenever a full 8-byte window fits inside the buffer
// one unaligned little-endian load/store covers the whole field. Only the
// last few fields of a stream (where the window would run past the end)
// fall back to the byte loop — bit-for-bit the same layout, pinned by
// `word_packing_is_byte_exact_vs_reference` below.

/// Write `v` as a `width`-bit little-endian field at bit offset `off`.
/// `buf` must be pre-zeroed over the written range.
pub(crate) fn write_bits(buf: &mut [u8], off: usize, width: u32, v: u64) {
    debug_assert!(width <= 32);
    let v = v & ((1u64 << width) - 1);
    let byte = off / 8;
    let bit = off % 8;
    if byte + 8 <= buf.len() {
        let mut word = u64::from_le_bytes(buf[byte..byte + 8].try_into().expect("8-byte window"));
        word |= v << bit;
        buf[byte..byte + 8].copy_from_slice(&word.to_le_bytes());
        return;
    }
    // tail fields: the 8-byte window would run past the buffer
    let mut v = v;
    let mut off = off;
    let mut rem = width as usize;
    while rem > 0 {
        let byte = off / 8;
        let bit = off % 8;
        let take = (8 - bit).min(rem);
        buf[byte] |= ((v & ((1u64 << take) - 1)) as u8) << bit;
        v >>= take;
        off += take;
        rem -= take;
    }
}

/// Read a `width`-bit little-endian field at bit offset `off`.
pub(crate) fn read_bits(buf: &[u8], off: usize, width: u32) -> u64 {
    debug_assert!(width <= 32);
    let mask = (1u64 << width) - 1;
    let byte = off / 8;
    let bit = off % 8;
    if byte + 8 <= buf.len() {
        let word = u64::from_le_bytes(buf[byte..byte + 8].try_into().expect("8-byte window"));
        return (word >> bit) & mask;
    }
    let mut v = 0u64;
    let mut got = 0usize;
    let mut off = off;
    let mut rem = width as usize;
    while rem > 0 {
        let byte = off / 8;
        let bit = off % 8;
        let take = (8 - bit).min(rem);
        let part = (buf[byte] >> bit) as u64 & ((1u64 << take) - 1);
        v |= part << got;
        got += take;
        off += take;
        rem -= take;
    }
    v
}

/// Dequantize a packed level stream (see [`WirePayload::Quantized`]).
/// Streams the packed bytes through a u64 accumulator (refilled a word at
/// a time while one fits), so the per-element work is a shift and a mask
/// instead of per-field offset arithmetic.
pub(crate) fn dequantize_into(out: &mut [f32], n: usize, bits: u32, norm: f32, packed: &[u8]) {
    debug_assert_eq!(out.len(), n);
    let l = ((1u32 << (bits - 1)) - 1) as i64;
    let scale = if l > 0 { norm / l as f32 } else { 0.0 };
    let mask = (1u64 << bits) - 1;
    let mut acc = 0u64;
    let mut acc_bits = 0u32;
    let mut pos = 0usize;
    for o in out.iter_mut() {
        while acc_bits < bits {
            // acc_bits < 32 here, so a 32-bit refill always fits in the
            // accumulator; the stream tail refills byte-wise
            if pos + 4 <= packed.len() {
                let w = u32::from_le_bytes(
                    packed[pos..pos + 4].try_into().expect("4-byte window"),
                ) as u64;
                acc |= w << acc_bits;
                pos += 4;
                acc_bits += 32;
            } else {
                debug_assert!(pos < packed.len(), "packed stream exhausted early");
                acc |= (packed[pos] as u64) << acc_bits;
                pos += 1;
                acc_bits += 8;
            }
        }
        let level = (acc & mask) as i64 - l;
        acc >>= bits;
        acc_bits -= bits;
        *o = level as f32 * scale;
    }
}

// ---------------------------------------------------------------------------
// identity

/// Exact passthrough: dense f32 on the wire. Used for `qsgd` at 32 bits
/// and directly in tests; `CodecConfig::None` skips encoding entirely.
#[derive(Debug, Default)]
pub struct IdentityCodec;

impl GradientCodec for IdentityCodec {
    fn name(&self) -> &'static str {
        "identity"
    }
    fn encode(&mut self, g: &[f32], out: &mut WirePayload) {
        if !matches!(out, WirePayload::Dense(_)) {
            *out = WirePayload::Dense(Vec::new());
        }
        match out {
            WirePayload::Dense(v) => {
                v.clear();
                v.extend_from_slice(g);
            }
            _ => unreachable!(),
        }
    }
    fn wire_bytes(&self, n: usize) -> usize {
        4 * n
    }
    fn is_identity(&self) -> bool {
        true
    }
}

// ---------------------------------------------------------------------------
// TopK

/// Keep the `ceil(ratio * n)` largest-|value| coordinates; exact values,
/// ascending indices. Ratio 1.0 keeps everything (exact identity).
#[derive(Debug)]
pub struct TopK {
    ratio: f64,
    /// Selection scratch: index permutation partitioned by |g|.
    order: Vec<u32>,
}

impl TopK {
    pub fn new(ratio: f64) -> Self {
        assert!(ratio > 0.0 && ratio <= 1.0);
        Self { ratio, order: Vec::new() }
    }
}

impl GradientCodec for TopK {
    fn name(&self) -> &'static str {
        "topk"
    }
    fn encode(&mut self, g: &[f32], out: &mut WirePayload) {
        let n = g.len();
        let k = kept(self.ratio, n);
        let (idx, val) = sparse_bufs(out, n);
        if k == n {
            idx.extend(0..n as u32);
            val.extend_from_slice(g);
            return;
        }
        self.order.clear();
        self.order.extend(0..n as u32);
        // partition the k largest magnitudes to the front (O(n) expected),
        // then emit them in ascending index order for the sharded apply.
        // Ties break by index explicitly: select_nth_unstable_by partitions
        // equal keys arbitrarily, so without the index tiebreak the kept
        // set could differ across platforms / std versions whenever
        // magnitudes collide at the selection boundary.
        self.order.select_nth_unstable_by(k - 1, |&a, &b| {
            g[b as usize]
                .abs()
                .partial_cmp(&g[a as usize].abs())
                .unwrap_or(std::cmp::Ordering::Equal)
                .then_with(|| a.cmp(&b))
        });
        idx.extend_from_slice(&self.order[..k]);
        idx.sort_unstable();
        val.extend(idx.iter().map(|&i| g[i as usize]));
    }
    fn wire_bytes(&self, n: usize) -> usize {
        sparse_wire_bytes(n, kept(self.ratio, n))
    }
    fn is_identity(&self) -> bool {
        self.ratio >= 1.0
    }
}

// ---------------------------------------------------------------------------
// RandK

/// Keep `ceil(ratio * n)` uniformly random coordinates (exact values,
/// unscaled — the EF residual absorbs the sampling bias; the classic
/// `n/k` unbiasing rescale would break EF contractiveness). Ratio 1.0
/// keeps everything.
#[derive(Debug)]
pub struct RandK {
    ratio: f64,
    rng: Pcg64,
    /// Persistent permutation buffer for the partial Fisher–Yates draw.
    perm: Vec<u32>,
}

impl RandK {
    pub fn new(ratio: f64, rng: Pcg64) -> Self {
        assert!(ratio > 0.0 && ratio <= 1.0);
        Self { ratio, rng, perm: Vec::new() }
    }
}

impl GradientCodec for RandK {
    fn name(&self) -> &'static str {
        "randk"
    }
    fn encode(&mut self, g: &[f32], out: &mut WirePayload) {
        let n = g.len();
        let k = kept(self.ratio, n);
        let (idx, val) = sparse_bufs(out, n);
        if k == n {
            idx.extend(0..n as u32);
            val.extend_from_slice(g);
            return;
        }
        if self.perm.len() != n {
            self.perm.clear();
            self.perm.extend(0..n as u32);
        }
        // partial Fisher–Yates: the first k entries are a uniform sample
        // (the buffer stays permuted between calls, which is still uniform)
        for i in 0..k {
            let j = i + self.rng.below((n - i) as u64) as usize;
            self.perm.swap(i, j);
        }
        idx.extend_from_slice(&self.perm[..k]);
        idx.sort_unstable();
        val.extend(idx.iter().map(|&i| g[i as usize]));
    }
    fn wire_bytes(&self, n: usize) -> usize {
        sparse_wire_bytes(n, kept(self.ratio, n))
    }
    fn is_identity(&self) -> bool {
        self.ratio >= 1.0
    }
}

// ---------------------------------------------------------------------------
// QSGD

/// QSGD-style stochastic quantization at `bits` bits per element: levels
/// `q ∈ [-L, L]` with `L = 2^(bits-1) - 1` against the max-norm, rounded
/// stochastically (unbiased: `E[dequant] = value`). `bits = 32` is exact
/// f32 passthrough. Per-element error is at most `norm / L`, so with
/// error feedback the residual stays bounded for `bits >= 3`.
#[derive(Debug)]
pub struct Qsgd {
    bits: u32,
    rng: Pcg64,
}

impl Qsgd {
    pub fn new(bits: u32, rng: Pcg64) -> Self {
        assert!((3..=16).contains(&bits) || bits == 32, "qsgd bits {bits}");
        Self { bits, rng }
    }
}

impl GradientCodec for Qsgd {
    fn name(&self) -> &'static str {
        "qsgd"
    }
    fn encode(&mut self, g: &[f32], out: &mut WirePayload) {
        if self.bits >= 32 {
            // exact: dense f32 on the wire
            IdentityCodec.encode(g, out);
            return;
        }
        let n = g.len();
        if !matches!(out, WirePayload::Quantized { .. }) {
            *out = WirePayload::Quantized { n: 0, bits: 0, norm: 0.0, packed: Vec::new() };
        }
        let (pn, pbits, pnorm, packed) = match out {
            WirePayload::Quantized { n, bits, norm, packed } => (n, bits, norm, packed),
            _ => unreachable!(),
        };
        *pn = n as u32;
        *pbits = self.bits as u8;
        let norm = g.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        *pnorm = norm;
        let nbytes = (n * self.bits as usize + 7) / 8;
        packed.clear();
        packed.resize(nbytes, 0);
        if norm == 0.0 {
            return; // all-zero levels decode to zero
        }
        let l = ((1u32 << (self.bits - 1)) - 1) as f32;
        for (i, &x) in g.iter().enumerate() {
            let scaled = x / norm * l; // in [-l, l]
            let lo = scaled.floor();
            let p = scaled - lo;
            let q = (lo as i64 + (self.rng.next_f64() < p as f64) as i64)
                .clamp(-(l as i64), l as i64);
            write_bits(packed, i * self.bits as usize, self.bits, (q + l as i64) as u64);
        }
    }
    fn wire_bytes(&self, n: usize) -> usize {
        if self.bits >= 32 {
            4 * n
        } else {
            quantized_wire_bytes(n, self.bits)
        }
    }
    fn is_identity(&self) -> bool {
        self.bits >= 32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grad(seed: u64, n: usize) -> Vec<f32> {
        let mut rng = Pcg64::new(seed);
        (0..n).map(|_| rng.normal(0.0, 1.0) as f32).collect()
    }

    /// The pre-word-packing byte-loop writer, kept as the layout oracle.
    fn write_bits_ref(buf: &mut [u8], off: usize, width: u32, v: u64) {
        let mut v = v & ((1u64 << width) - 1);
        let mut off = off;
        let mut rem = width as usize;
        while rem > 0 {
            let byte = off / 8;
            let bit = off % 8;
            let take = (8 - bit).min(rem);
            buf[byte] |= ((v & ((1u64 << take) - 1)) as u8) << bit;
            v >>= take;
            off += take;
            rem -= take;
        }
    }

    /// The pre-word-packing byte-loop reader, kept as the layout oracle.
    fn read_bits_ref(buf: &[u8], off: usize, width: u32) -> u64 {
        let mut v = 0u64;
        let mut got = 0usize;
        let mut off = off;
        let mut rem = width as usize;
        while rem > 0 {
            let byte = off / 8;
            let bit = off % 8;
            let take = (8 - bit).min(rem);
            let part = (buf[byte] >> bit) as u64 & ((1u64 << take) - 1);
            v |= part << got;
            got += take;
            off += take;
            rem -= take;
        }
        v
    }

    #[test]
    fn word_packing_is_byte_exact_vs_reference() {
        // every width, awkward field counts (word path + tail fallback):
        // the u64-word writer must produce byte-identical buffers to the
        // byte-loop reference, and both readers must agree on every field
        let mut rng = Pcg64::new(77);
        for width in 1u32..=32 {
            for count in [1usize, 7, 64, 129] {
                let vals: Vec<u64> =
                    (0..count).map(|_| rng.next_u64() & ((1u64 << width) - 1)).collect();
                let nbytes = (count * width as usize + 7) / 8;
                let mut fast = vec![0u8; nbytes];
                let mut slow = vec![0u8; nbytes];
                for (i, &v) in vals.iter().enumerate() {
                    write_bits(&mut fast, i * width as usize, width, v);
                    write_bits_ref(&mut slow, i * width as usize, width, v);
                }
                assert_eq!(fast, slow, "width {width} count {count}: payload bytes diverged");
                for (i, &v) in vals.iter().enumerate() {
                    assert_eq!(read_bits(&fast, i * width as usize, width), v);
                    assert_eq!(read_bits_ref(&fast, i * width as usize, width), v);
                }
            }
        }
    }

    #[test]
    fn streaming_dequantize_matches_per_field_reference() {
        let n = 1003; // odd length: exercises the byte-wise refill tail
        let g = grad(21, n);
        for bits in [3u32, 4, 7, 8, 12, 16] {
            let mut codec = Qsgd::new(bits, Pcg64::new(9));
            let mut out = WirePayload::default();
            codec.encode(&g, &mut out);
            let (norm, packed) = match &out {
                WirePayload::Quantized { norm, packed, .. } => (*norm, packed.clone()),
                other => panic!("expected quantized, got {other:?}"),
            };
            let mut fast = vec![0.0f32; n];
            dequantize_into(&mut fast, n, bits, norm, &packed);
            // per-field reference decode
            let l = ((1u32 << (bits - 1)) - 1) as i64;
            let scale = if l > 0 { norm / l as f32 } else { 0.0 };
            let slow: Vec<f32> = (0..n)
                .map(|i| {
                    (read_bits_ref(&packed, i * bits as usize, bits) as i64 - l) as f32 * scale
                })
                .collect();
            assert_eq!(fast, slow, "bits {bits}: streaming decode diverged");
        }
    }

    #[test]
    fn bit_roundtrip_all_widths() {
        for width in 1u32..=32 {
            let vals: Vec<u64> = (0..50)
                .map(|i| (i as u64).wrapping_mul(0x9E37_79B9) & ((1u64 << width) - 1))
                .collect();
            let mut buf = vec![0u8; (50 * width as usize + 7) / 8];
            for (i, &v) in vals.iter().enumerate() {
                write_bits(&mut buf, i * width as usize, width, v);
            }
            for (i, &v) in vals.iter().enumerate() {
                assert_eq!(read_bits(&buf, i * width as usize, width), v, "width {width}");
            }
        }
    }

    #[test]
    fn topk_keeps_largest_magnitudes_sorted() {
        let g = vec![0.1f32, -5.0, 0.0, 3.0, -0.2, 4.0];
        let mut codec = TopK::new(0.5); // k = 3
        let mut out = WirePayload::default();
        codec.encode(&g, &mut out);
        match &out {
            WirePayload::Sparse { n, idx, val } => {
                assert_eq!(*n, 6);
                assert_eq!(idx, &[1, 3, 5], "largest |g| at ascending indices");
                assert_eq!(val, &[-5.0, 3.0, 4.0]);
            }
            other => panic!("expected sparse, got {other:?}"),
        }
        let mut dec = vec![9.0f32; 6];
        out.decode_into(&mut dec);
        assert_eq!(dec, vec![0.0, -5.0, 0.0, 3.0, 0.0, 4.0]);
    }

    #[test]
    fn topk_breaks_ties_by_index_deterministically() {
        // tie-heavy gradient: every coordinate has one of two magnitudes,
        // so the selection boundary falls inside a huge tie class. The
        // kept set must match a full-sort reference ordered by
        // (|g| desc, index asc) — i.e. lowest indices win inside a tie —
        // regardless of how select_nth partitions internally.
        let n = 256;
        let g: Vec<f32> = (0..n)
            .map(|i| {
                let mag = if i % 5 == 0 { 2.0 } else { 1.0 };
                if i % 2 == 0 {
                    mag
                } else {
                    -mag
                }
            })
            .collect();
        for ratio in [0.1f64, 0.3, 0.5, 0.9] {
            let k = kept(ratio, n);
            let mut reference: Vec<u32> = (0..n as u32).collect();
            reference.sort_by(|&a, &b| {
                g[b as usize]
                    .abs()
                    .partial_cmp(&g[a as usize].abs())
                    .unwrap()
                    .then_with(|| a.cmp(&b))
            });
            let mut expect: Vec<u32> = reference[..k].to_vec();
            expect.sort_unstable();
            let mut codec = TopK::new(ratio);
            let mut out = WirePayload::default();
            codec.encode(&g, &mut out);
            match &out {
                WirePayload::Sparse { idx, val, .. } => {
                    assert_eq!(idx, &expect, "ratio {ratio}: tie-break not by index");
                    for (&i, &v) in idx.iter().zip(val) {
                        assert_eq!(v, g[i as usize]);
                    }
                }
                other => panic!("expected sparse, got {other:?}"),
            }
            // and the selection is stable across repeated encodes
            let first = out.clone();
            codec.encode(&g, &mut out);
            assert_eq!(first, out, "ratio {ratio}: repeated encode diverged");
        }
    }

    #[test]
    fn topk_ratio_one_is_exact_identity() {
        let g = grad(3, 257);
        let mut codec = TopK::new(1.0);
        assert!(codec.is_identity());
        let mut out = WirePayload::default();
        codec.encode(&g, &mut out);
        let mut dec = vec![0.0f32; 257];
        out.decode_into(&mut dec);
        assert_eq!(dec, g);
    }

    #[test]
    fn randk_samples_k_distinct_ascending() {
        let g = grad(4, 500);
        let mut codec = RandK::new(0.1, Pcg64::new(9));
        let mut out = WirePayload::default();
        codec.encode(&g, &mut out);
        match &out {
            WirePayload::Sparse { idx, val, .. } => {
                assert_eq!(idx.len(), 50);
                assert!(idx.windows(2).all(|w| w[0] < w[1]), "indices not strictly ascending");
                for (&i, &v) in idx.iter().zip(val) {
                    assert_eq!(v, g[i as usize], "values must be exact");
                }
            }
            other => panic!("expected sparse, got {other:?}"),
        }
        // successive encodes draw different coordinate sets
        let first = out.clone();
        codec.encode(&g, &mut out);
        assert_ne!(first, out);
    }

    #[test]
    fn qsgd_error_bounded_by_norm_over_l() {
        let n = 1000;
        let g = grad(5, n);
        for bits in [4u32, 6, 8] {
            let mut codec = Qsgd::new(bits, Pcg64::new(1));
            let mut out = WirePayload::default();
            codec.encode(&g, &mut out);
            let mut dec = vec![0.0f32; n];
            out.decode_into(&mut dec);
            let norm = g.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            let l = ((1u32 << (bits - 1)) - 1) as f32;
            let bound = norm / l * 1.0001;
            for (a, b) in g.iter().zip(&dec) {
                assert!((a - b).abs() <= bound, "bits={bits}: |{a} - {b}| > {bound}");
            }
        }
    }

    #[test]
    fn qsgd_rounding_is_unbiased_on_average() {
        let n = 512;
        let g = grad(6, n);
        let mut codec = Qsgd::new(4, Pcg64::new(2));
        let mut out = WirePayload::default();
        let mut mean = vec![0.0f64; n];
        let trials = 400;
        let mut dec = vec![0.0f32; n];
        for _ in 0..trials {
            codec.encode(&g, &mut out);
            out.decode_into(&mut dec);
            for (m, &d) in mean.iter_mut().zip(&dec) {
                *m += d as f64 / trials as f64;
            }
        }
        let norm = g.iter().fold(0.0f32, |m, &x| m.max(x.abs())) as f64;
        let l = 7.0; // bits=4
        // stderr of the mean ~ (norm/l) / sqrt(trials); allow 5 sigma
        let tol = norm / l / (trials as f64).sqrt() * 5.0;
        for (i, (&m, &x)) in mean.iter().zip(&g).enumerate() {
            assert!((m - x as f64).abs() < tol, "elem {i}: mean {m} vs {x} (tol {tol})");
        }
    }

    #[test]
    fn qsgd_zero_gradient_encodes_to_zero() {
        let mut codec = Qsgd::new(4, Pcg64::new(3));
        let mut out = WirePayload::default();
        codec.encode(&vec![0.0f32; 64], &mut out);
        let mut dec = vec![1.0f32; 64];
        out.decode_into(&mut dec);
        assert!(dec.iter().all(|&x| x == 0.0));
        assert_eq!(out.wire_bytes(), 9 + 32);
    }

    #[test]
    fn qsgd_32_bits_is_dense_exact() {
        let g = grad(7, 100);
        let mut codec = Qsgd::new(32, Pcg64::new(4));
        assert!(codec.is_identity());
        let mut out = WirePayload::default();
        codec.encode(&g, &mut out);
        assert!(matches!(out, WirePayload::Dense(_)));
        let mut dec = vec![0.0f32; 100];
        out.decode_into(&mut dec);
        assert_eq!(dec, g);
        assert_eq!(codec.wire_bytes(100), 400);
    }

    #[test]
    fn wire_bytes_match_payload_accounting() {
        let n = 4096;
        let g = grad(8, n);
        let mut topk = TopK::new(0.1);
        let mut randk = RandK::new(0.1, Pcg64::new(5));
        let mut qsgd = Qsgd::new(4, Pcg64::new(6));
        let codecs: [&mut dyn GradientCodec; 3] = [&mut topk, &mut randk, &mut qsgd];
        for codec in codecs {
            let mut out = WirePayload::default();
            codec.encode(&g, &mut out);
            assert_eq!(
                codec.wire_bytes(n),
                out.wire_bytes(),
                "{}: static and payload wire sizes disagree",
                codec.name()
            );
            assert!(out.wire_bytes() < 4 * n, "{} did not compress", codec.name());
        }
    }
}
