//! # dc-asgd
//!
//! A rust + JAX + Pallas reproduction of **"Asynchronous Stochastic Gradient
//! Descent with Delay Compensation"** (Zheng et al., ICML 2017).
//!
//! The crate is a parameter-server training framework:
//!
//! * [`runtime`] loads AOT-compiled JAX/Pallas artifacts (HLO text) and
//!   executes them through the PJRT C API (the `xla` crate). Python never
//!   runs on the training path.
//! * [`ps`] implements the paper's parameter server (Algorithm 2): the
//!   global model `w`, per-worker backup models `w_bak(m)`, and the
//!   delay-compensated update rule.
//! * [`optim`] implements the update rules: sequential SGD, momentum,
//!   ASGD, DC-ASGD-c, DC-ASGD-a, and the appendix-H DC-SSGD.
//! * [`sim`] is the discrete-event substrate: a virtual clock, worker
//!   compute-time models, and the event-driven [`sim::Scheduler`] that
//!   runs the per-worker pull → compute → push lifecycle under a
//!   pluggable synchronization [`sim::Protocol`] — including first-class
//!   worker faults and elastic membership ([`sim::faults`]).
//! * [`coordinator`] drives every protocol through one unified loop
//!   ([`coordinator::driver`]); `exec_mode = threads` additionally offers a
//!   real-OS-threads path for the ASGD family.
//! * [`data`] synthesizes the workloads (CIFAR-like, ImageNet-like,
//!   LM corpus) — see DESIGN.md §5 for the substitution rationale.
//!
//! ## Protocol matrix
//!
//! The paper's comparison is a spectrum of synchronization protocols; each
//! maps to a [`sim::Protocol`] plus an update rule on the server:
//!
//! | algorithm        | protocol                        | update rule on push      | trace gate events (`[trace]`)   |
//! |------------------|---------------------------------|--------------------------|---------------------------------|
//! | `sgd` (M=1)      | [`sim::FullyAsync`], one worker | plain SGD                | commits only (ungated)          |
//! | `ssgd`           | [`sim::BarrierSync`]            | sum of M gradients/round | gate-wait spans + barrier folds |
//! | `dc-ssgd`        | [`sim::BarrierSync`]            | appendix-H DC fold/round | gate-wait spans + barrier folds |
//! | `hier-ssgd`      | [`sim::BarrierSync`]            | two-level rack fold (SSGD rule, `[topology]`) | gate-wait spans + barrier folds |
//! | `ssp` (bound s)  | [`sim::StalenessBounded`]       | plain SGD                | gate-wait spans, commits w/ τ   |
//! | `dc-s3gd` (s)    | [`sim::StalenessBounded`]       | DC vs `w_bak` (Eqn. 10)  | gate-wait spans, commits w/ τ   |
//! | `asgd`           | [`sim::FullyAsync`]             | plain SGD                | commits w/ τ (no gate waits)    |
//! | `dc-asgd-c`      | [`sim::FullyAsync`]             | DC, constant lambda      | commits w/ τ (no gate waits)    |
//! | `dc-asgd-a`      | [`sim::FullyAsync`]             | DC, adaptive lambda      | commits w/ τ (no gate waits)    |
//!
//! SSP's `staleness_bound` sweeps the whole axis: `s = 0` reproduces the
//! SSGD round structure, `s -> inf` reproduces ASGD bit-for-bit (bench
//! `ssp_spectrum` sweeps it). The clock gate admits a worker only while it
//! is at most `s` steps ahead of the slowest (observed drift <= s + 1 with
//! the in-flight step), capping observable version staleness at
//! `(M-1)(2s+1)`.
//!
//! ## PS store architecture & comm model
//!
//! The parameter store ([`ps::ShardedStore`]) is read-optimized: the flat
//! vector is split into `S` contiguous shards, each behind its own
//! `RwLock` with a per-shard version counter. Snapshots and pulls take
//! read locks (readers never serialize against each other), pushes to
//! different shards proceed in parallel, and the per-worker backups
//! `w_bak(m)` live *outside* the shard locks — a pull records the copy it
//! actually handed out, so backup and snapshot are per-shard-consistent by
//! construction. Pulls are shard-atomic, exactly the consistency a
//! distributed PS provides. All push-path scratch (the momentum-DC
//! compensation buffers, the whole-vector XLA operands, the barrier-round
//! gradient slots and DC-SSGD fold buffers) lives in reusable arenas, so
//! the steady-state hot path performs zero heap allocations; multi-shard
//! aggregated applies fan out over scoped threads for large models with
//! bit-identical results. Bench `ps_throughput` ablates this store against
//! the previous mutex-per-shard design (JSONL rows per store × shards ×
//! workers).
//!
//! Communication cost is modelled explicitly: the `[comm]` config section
//! (off by default) makes the [`sim::Scheduler`] charge
//! `per_push + per_mb * MB` simulated seconds for every gradient upload
//! and model download ([`sim::CommModel`] / [`sim::CommCosts`]), so the
//! sync-vs-async wallclock comparison pays for transfers instead of
//! assuming a free network. With `[comm]` disabled the schedule is
//! bit-identical to earlier builds (adding 0.0 to a duration is exact).
//!
//! ## Fleet topology & scalable scheduler
//!
//! The scheduler's release machinery is built for fleets of thousands of
//! workers. Every protocol declares its gate in incremental form
//! ([`sim::GateSpec`]): the scheduler maintains a [`sim::FleetIndex`] —
//! a live-clock multiset (`BTreeMap` counts) plus live/blocked bitsets —
//! so a membership query is O(1), the live minimum clock is O(log M),
//! "all live clocks equal" is O(1) (`distinct_clocks`), and a release
//! cascade touches O(M/64 + released) state instead of re-running an
//! O(M) `may_start` scan per blocked worker (O(M²) per event). The scan
//! engine is retained verbatim as the semantic reference
//! ([`sim::Scheduler::force_scan_gates`]) and the chaos harness pins the
//! two engines bitwise-identical — same event streams, push traces, and
//! final model bits — under seeded fault churn; a 10_000-worker churn
//! smoke holds the whole plan to seconds of host time.
//!
//! The `[topology]` config section (off by default; any knob auto-enables
//! it) places the fleet on a physical layout: shards are striped across
//! `topology.ps_nodes` logical PS nodes ([`ps::ShardedStore::node_shards`]),
//! workers and PS nodes stripe over `topology.racks` racks, and each
//! transfer is charged per **link** — a rack-local model for same-rack
//! worker↔PS traffic and a cross-rack model for the rest, with the
//! cross-rack uplink fair-shared among a rack's residents
//! ([`sim::Topology`]). The per-worker costs install into the scheduler
//! via [`sim::Scheduler::set_worker_comm`], so rack placement shows up in
//! the schedule (same-rack workers turn around faster). `[topology]` and
//! `[comm]` are mutually exclusive (the flat comm model is the 1-node,
//! 1-rack degenerate case, which is pinned bit-identical), and a
//! disabled `[topology]` section leaves every schedule untouched.
//!
//! `hierarchical = true` additionally switches the barrier protocols to
//! **two-level aggregation**: rack reducers sum their residents'
//! gradients, the root folds one partial per rack, and each push pays the
//! rack link plus a 1/residents share of the cross-rack link — the
//! classic hierarchical all-reduce cost shape. As a protocol column this
//! is `algorithm = "hier-ssgd"`: the SSGD update rule under the rack-major
//! fold, which degenerates bit-for-bit to plain `ssgd` with one rack (and
//! the rack-major fold order itself is bitwise-inert for the flat
//! protocols, pinned by `tests/integration.rs`).
//!
//! ## Compute runtime & deterministic pipeline
//!
//! Host-side execution runs on a **persistent compute pool**
//! ([`util::pool::ComputePool`], the `[runtime] threads` knob /
//! `--threads`; `0` auto-sizes to available parallelism, `1` is fully
//! serial): a fixed set of worker threads created once per run, with jobs
//! fanned out as index ranges that idle lanes claim from a shared atomic
//! counter — no per-call `thread::scope` spawn/join anywhere on the hot
//! path. The pool serves the store's multi-shard applies
//! ([`ps::ShardedStore::par_for_each_shard`], and therefore `store_w` and
//! the barrier folds) and the driver's **pipelined gradient stage**
//! ([`util::pool::GradPipeline`]).
//!
//! The pipeline exploits the observation (Mishchenko et al. 2022) that
//! between a worker's pull and its finish event its gradient depends only
//! on inputs it already holds — the snapshot it pulled and its own batch
//! cursor — so the in-flight computations are mutually independent. The
//! driver draws each worker's batch at pull time, queues the compute, and
//! evaluates **all** queued gradients concurrently in one pool burst the
//! first time a finish event demands a result. Bitwise determinism is
//! preserved by construction:
//!
//! * commits happen strictly in the scheduler's event order — the pool
//!   only changes *when* a gradient value is materialized, never which
//!   value or when it is applied;
//! * every gradient is a pure function of per-worker inputs frozen at
//!   pull time, and results are keyed by worker, so lane count and claim
//!   order are unobservable;
//! * shard tasks own disjoint slices under their own write locks, so
//!   multi-shard applies are order-independent f32 arithmetic;
//! * a drop-policy crash voids an in-flight compute whose batch the
//!   serial loop would never have drawn — the stage retains that batch
//!   and re-uses it for the worker's first post-rejoin compute, keeping
//!   cursor streams identical to the draw-at-commit order.
//!
//! `runtime.threads = 1` is the pinned serial reference: the chaos
//! harness drives seeded fault plans through the pipelined bookkeeping at
//! several lane counts and asserts bit-identical push traces and final
//! model bits against the at-finish serial loop; the store's
//! lane-invariance tests pin the apply path the same way. Bench `hotpath`
//! measures the pool against the old scoped-spawn fan-out and writes the
//! machine-readable perf baseline `BENCH_PR6.json` that the CI perf-smoke
//! lane gates against — the baseline is **calibrated** (measured, not a
//! placeholder), so `DCASGD_PERF_GATE=1` *fails* on a >2x regression of
//! any cell. (Caveat: the PJRT backend executes all Train requests on its
//! single engine thread, so there the flush pipelines request *issue*
//! rather than parallelizing XLA execution — see the
//! [`coordinator::driver`] docs.)
//!
//! ## Kernel architecture & SIMD determinism
//!
//! The per-element update rules run through chunked-SIMD kernels
//! ([`optim::kernels`]): fixed 8-wide chunks via `chunks_exact` with a
//! scalar tail, a shape the autovectorizer reliably turns into packed
//! f32 arithmetic on stable Rust. The crucial property is that this is a
//! pure *traversal* rewrite — every lane computes the same correctly
//! rounded IEEE-754 expression on the same element as the scalar
//! reference loop, and no kernel on the hot path reorders a
//! floating-point reduction (the one hot-path reduction, QSGD's max-|g|
//! norm, is order-independent for non-NaN input). Chunked and scalar
//! paths are therefore **bit-identical**, which is what lets them share
//! one dispatch flag without perturbing the crate's determinism story:
//! `[runtime] simd` (`--simd`, on by default; the `simd` cargo feature
//! compiles the dispatch out entirely) selects chunked kernels, fused
//! codec paths, and pool-parallel TopK, and flipping it trades wallclock
//! only — pinned by kernel-equivalence property tests (`tests/kernels.rs`)
//! across tail lengths, unaligned sub-slices, and an end-to-end PS run.
//!
//! The shared elementwise cores (`optim::kernels::dc_comp` /
//! `dca_comp`) are the single source of truth for Eqn. 10 and the
//! adaptive Eqn. 14 recurrence — the staged compensate paths, the fused
//! kernels, and the sparse kernels all inline the same expression, so the
//! DC math cannot drift between code paths. On the server, quantized
//! pushes take a **fused decode→compensate→apply** pass
//! ([`compress::decode_dc_apply`] and friends): each shard seeks a
//! bit-cursor into its slice of the packed level stream and applies in
//! 512-element blocks, one DRAM pass over `w`/`w_bak`/`ms` instead of
//! materializing the dense gradient (guarded by
//! `UpdateKernel::is_native_elementwise`, so custom whole-vector kernels
//! keep the densified path). QSGD encode/pack stream through a u64
//! bit-accumulator flushing 32-bit words, and TopK selection goes through
//! u64 `(|g| bits, !idx)` keys — totally ordered, so chunk-local
//! selection on the [`util::pool::ComputePool`] merges deterministically
//! regardless of lane count.
//!
//! ## Gradient compression & wire format
//!
//! The `[compress]` config section (`--compress` CLI flag; `none` by
//! default) selects a [`compress::GradientCodec`] that every worker runs
//! on its gradient before the push: `topk` / `randk` sparsification (keep
//! `ceil(ratio * n)` coordinates) or `qsgd` stochastic quantization at a
//! configurable bit width. Each worker carries an **error-feedback
//! residual** ([`compress::ErrorFeedback`], living alongside `w_bak(m)`
//! outside the shard locks): whatever the codec dropped is re-injected
//! into the next encode, so the accumulated applied update telescopes to
//! the accumulated true gradient. Encode/decode scratch lives in reusable
//! per-worker arenas — the push path stays zero-allocation.
//!
//! On the server, sparse payloads apply **shard-locally without
//! densifying** for the elementwise rules (bit-identical to pushing the
//! densified gradient); DC-ASGD-a decodes densely first because its
//! MeanSquare state decays every coordinate per push. Delay compensation
//! composes unchanged: the *decoded* gradient is compensated against
//! `w_bak` (Eqn. 10). Codec composition: `asgd` / `ssp` / `dc-asgd-c` /
//! `dc-s3gd` take the sparse fast path, `dc-asgd-a` the dense-decode path,
//! and the barrier protocols (`ssgd` / `dc-ssgd`), momentum variants, and
//! the XLA backend reject compression at config validation.
//!
//! The [`sim::Scheduler`] charges gradient uploads at the **encoded wire
//! size** (bit-packed sparse indices / quantization levels; model
//! downloads stay dense) and accounts total bytes-on-wire either way.
//! With `compress = "none"` (the default) no codec is built and schedules
//! and trajectories are bit-identical to pre-compression builds (pinned by
//! regression tests). Bench `compression_sweep` sweeps codec × ratio/bits
//! × protocol × delay model into JSONL.
//!
//! ## Fault injection & elastic membership
//!
//! The `[faults]` config section (`--faults` / `--fault-*` CLI; off by
//! default) installs a seeded [`sim::FaultPlan`] into the scheduler:
//! Poisson worker crashes with exponential restart delays (or permanent
//! departures), late-joining workers, and transient straggler windows that
//! stretch compute times. The scheduler owns the whole lifecycle:
//!
//! * a crash under [`sim::CrashPolicy::Drop`] invalidates the in-flight
//!   compute (finish events are epoch-tagged, so a push from a crashed
//!   epoch can never commit); [`sim::CrashPolicy::Salvage`] drains it —
//!   the compute finishes and commits, then the worker goes down;
//! * every protocol gate evaluates over the **live** membership: a dead
//!   worker never wedges a `BarrierSync` round (the round folds whatever
//!   the live fleet contributed, k gradients at `k * lr`) and never pins
//!   the `StalenessBounded` minimum;
//! * on rejoin a lagging worker adopts the slowest live peer's clock and
//!   starts immediately, while one that died *ahead* of the fleet (its
//!   completed work is still buffered at an open barrier) re-enters
//!   through the protocol gate — clocks never regress, so completed work
//!   is never redone; either way its server-side backup `w_bak(m)` is
//!   re-seeded to the current model (DC-ASGD compensates against a live
//!   snapshot, never a dead incarnation's) and its error-feedback
//!   residual is zeroed;
//! * per-run counters (crashes / restarts / departures / late joins /
//!   dropped / salvaged pushes / straggle windows) surface in
//!   [`metrics::TrainReport`] and the summary JSON.
//!
//! Per-protocol churn behaviour: the immediate-commit protocols (`asgd` /
//! `dc-asgd-*`) lose at most the in-flight gradient per crash; `ssp` /
//! `dc-s3gd` additionally recompute the staleness gate over survivors
//! (live drift stays ≤ s + 1 through arbitrary churn); the barrier
//! protocols (`ssgd` / `dc-ssgd`) shrink the round to the live fleet.
//!
//! With `[faults]` off, no fault code path executes and schedules and
//! trajectories are **bit-identical** to pre-fault builds — pinned by the
//! scheduler tests and the chaos harness (`tests/chaos.rs`), which drives
//! 100+ seeded random fault plans per run (`CHAOS_SEEDS` scales it in CI)
//! and asserts the structural invariants above on every one. Bench
//! `fault_churn` sweeps crash-rate × {asgd, dc-asgd-a, ssp} and shows
//! DC-ASGD-a holding its loss advantage as churn amplifies staleness.
//!
//! ## Scenario files & pre-flight validation
//!
//! Every experiment knob — id, type, bounds, default, CLI flag, and the
//! cross-knob rejection rules — is declared exactly once in the
//! [`config::manifest`]. The TOML loader, the CLI overlay, and
//! [`config::ExperimentConfig::validate`] are all derived from it, so a
//! knob admits the same values and rejects with the same pinned message no
//! matter which layer set it. `dcasgd knobs` prints the manifest.
//!
//! Precedence is **CLI > scenario override > TOML/preset base > default**:
//! the base config comes from a preset or TOML file, a scenario's
//! `[overrides]`/`[sweep]` sections rewrite it knob-by-knob, and CLI flags
//! are overlaid last. Each layer goes through the same manifest setters,
//! which is why a run launched via `--scenario` is bitwise identical to
//! the equivalent CLI/TOML run (pinned by `tests/integration.rs`).
//!
//! A *scenario* file (`scenarios/*.toml`, see [`scenario`]) declares a
//! base config plus JSON-pointer-style overrides and sweep axes, and
//! expands into a validated run grid: `dcasgd train --scenario f.toml
//! --case N` runs one cell, [`scenario::run_grid`] drives whole grids for
//! benches/examples with one shared JSONL emitter, and `dcasgd validate
//! scenarios/ --strict` pre-flights the committed corpus in CI — every
//! case is checked against the manifest bounds and the rejection matrix
//! before anything runs.
//!
//! ## Observability
//!
//! The `[trace]` config section (`--trace` CLI; off by default) turns on
//! the run-trace layer ([`trace`]), three data planes written next to the
//! metrics bundle under `out_dir`:
//!
//! * **Structured events** (`<tag>.trace.jsonl`): typed records from the
//!   scheduler (gate waits, crashes, restarts, joins, departures,
//!   straggles) and the driver (pulls, push commits with τ, barrier
//!   folds, pipeline enqueue/flush, checkpoints), each carrying virtual
//!   time, wall time, worker id, epoch, and τ. The same stream renders as
//!   Chrome trace-event format (`<tag>.trace.json`): open it at
//!   <https://ui.perfetto.dev> (or `chrome://tracing`) for one track per
//!   worker, a counter track per PS shard, and a driver track —
//!   timestamps are the **virtual** clock in µs, i.e. the simulated
//!   schedule itself.
//! * **Subsystem profiles**: RAII span timers around PS shard-lock
//!   acquisition, pool job execution, codec encode/decode, and the fused
//!   apply ([`trace::profile`]); per-subsystem count/total/mean/max and a
//!   log2 histogram land in a `profile` block of `<tag>.summary.json`
//!   (`schema_version` 2).
//! * **Time series** (`<tag>.timeseries.csv`): every
//!   `trace.sample_every` steps the driver snapshots loss EMA, live
//!   workers, windowed staleness (n/mean/max), comm-bytes delta, and
//!   event-queue depth. Optional subsystems append *extension columns*
//!   after the fixed header ([`trace::rows_to_csv_with`]): per-rack
//!   cross-rack uplink utilization under `[topology]` (derived by
//!   [`sim::UplinkMeter`] from the same per-event byte accounting as
//!   `comm_bytes`), and windowed pull count / mean latency / epoch lag
//!   under `[serving]`. With no extras the CSV is byte-identical to the
//!   fixed-header format.
//!
//! `dcasgd report <run-dir>` digests the written artifacts (phase
//! breakdown, slowest spans, staleness/loss sparklines) with no model or
//! replay needed. Knobs: `trace.enabled`, `trace.sample_every`
//! (`--trace-sample-every`), `trace.events` (`--trace-events`),
//! `trace.profile` (`--trace-profile`), `trace.chrome_trace`
//! (`--trace-chrome`); setting any parameter knob auto-enables the
//! section, an explicit `enabled = false` wins, and `exec_mode = threads`
//! rejects tracing (virtual-time records need the event-driven
//! scheduler).
//!
//! The layer is **bitwise-inert**: every emission site observes a
//! decision already made, so trace-on and trace-off runs produce
//! identical `TrainReport`s and checkpoint bytes — pinned by
//! `tests/trace.rs` at both the scheduler level and the full-run level,
//! and the disabled-span cost is pinned unmeasurable by bench `hotpath`.
//!
//! ## Serving plane & snapshot publication
//!
//! The `[serving]` config section (off by default; any parameter knob
//! auto-enables it, an explicit `enabled = false` wins) layers an
//! inference read workload over a live training run. The data plane is
//! [`ps::SnapshotPlane`]: a double-buffered, epoch-published snapshot of
//! the whole model inside the sharded store. Every
//! `serving.publish_every` global steps the driver copies the live
//! shards into the spare buffer — under the same read locks as a
//! training pull, so publication never blocks training — and flips an
//! atomic epoch pointer. Batched serving reads
//! ([`ps::ShardedStore::serving_pull_batch`]) resolve every query range
//! in one epoch acquisition, **wait-free**: no locks, no waiting on
//! pushes, and torn reads are impossible by protocol (a publisher only
//! overwrites the buffer no live reader holds; pinned by a threaded race
//! test in `tests/serving.rs`). `serving.read_mode = "locked"` routes
//! the same queries through the per-shard read locks instead
//! ([`ps::ShardedStore::locked_pull_batch`]) — the contention baseline
//! the snapshot plane exists to beat, gated by bench `serving_latency`.
//!
//! The workload ([`sim::serving`]) is a pure *observer* of the training
//! schedule: a seeded arrival process (Poisson / bursty / diurnal via
//! thinning) is drained between scheduler events on the virtual clock
//! and never enters the event queue, so serving-on runs are bitwise
//! identical to serving-off (reports and checkpoint bytes; pinned in
//! `tests/serving.rs`). Pull latency is modeled deterministically
//! ([`sim::ServingClock`]): snapshot reads cost pure service time,
//! locked reads also wait out the push-apply window they arrive into.
//! Per-pull p50/p99/p999 and snapshot staleness (epoch lag in steps and
//! virtual seconds, bounded by the publish cadence) summarize into a
//! `serving` block of `summary.json` ([`sim::ServingSummary`]). Serving
//! rides the event-driven cluster loop, so async *and* barrier
//! protocols serve (snapshots publish on pushes or round folds
//! respectively); sequential SGD runs outside that loop and
//! `exec_mode = threads` has no virtual clock — both are rejected at
//! validation.
//!
//! ## Quickstart
//!
//! ```no_run
//! use dc_asgd::config::ExperimentConfig;
//! use dc_asgd::coordinator::Trainer;
//!
//! let mut cfg = ExperimentConfig::preset_quickstart();
//! cfg.algorithm = dc_asgd::config::Algorithm::DcAsgdAdaptive;
//! let report = Trainer::new(cfg).unwrap().run().unwrap();
//! println!("final test error {:.2}%", report.final_test_error * 100.0);
//! ```

pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod metrics;
pub mod optim;
pub mod ps;
pub mod runtime;
pub mod scenario;
pub mod sim;
pub mod theory;
pub mod trace;
pub mod util;

pub mod bench;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Default location of the AOT artifact directory (relative to repo root).
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";

/// Locate the artifact directory: `$DCASGD_ARTIFACTS`, else walk up from the
/// current directory looking for `artifacts/manifest.json`.
pub fn find_artifacts_dir() -> Option<std::path::PathBuf> {
    if let Ok(p) = std::env::var("DCASGD_ARTIFACTS") {
        let p = std::path::PathBuf::from(p);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    let mut dir = std::env::current_dir().ok()?;
    loop {
        let cand = dir.join(DEFAULT_ARTIFACTS_DIR);
        if cand.join("manifest.json").exists() {
            return Some(cand);
        }
        if !dir.pop() {
            return None;
        }
    }
}
