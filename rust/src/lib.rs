//! # dc-asgd
//!
//! A rust + JAX + Pallas reproduction of **"Asynchronous Stochastic Gradient
//! Descent with Delay Compensation"** (Zheng et al., ICML 2017).
//!
//! The crate is a parameter-server training framework:
//!
//! * [`runtime`] loads AOT-compiled JAX/Pallas artifacts (HLO text) and
//!   executes them through the PJRT C API (the `xla` crate). Python never
//!   runs on the training path.
//! * [`ps`] implements the paper's parameter server (Algorithm 2): the
//!   global model `w`, per-worker backup models `w_bak(m)`, and the
//!   delay-compensated update rule.
//! * [`optim`] implements the update rules: sequential SGD, momentum,
//!   ASGD, DC-ASGD-c, DC-ASGD-a, and the appendix-H DC-SSGD.
//! * [`coordinator`] wires workers and server together in three modes:
//!   sequential, synchronous (barrier), and asynchronous (threads), plus a
//!   discrete-event simulated-time mode in [`sim`] that reproduces the
//!   paper's wallclock figures deterministically.
//! * [`data`] synthesizes the workloads (CIFAR-like, ImageNet-like,
//!   LM corpus) — see DESIGN.md §5 for the substitution rationale.
//!
//! ## Quickstart
//!
//! ```no_run
//! use dc_asgd::config::ExperimentConfig;
//! use dc_asgd::coordinator::Trainer;
//!
//! let mut cfg = ExperimentConfig::preset_quickstart();
//! cfg.algorithm = dc_asgd::config::Algorithm::DcAsgdAdaptive;
//! let report = Trainer::new(cfg).unwrap().run().unwrap();
//! println!("final test error {:.2}%", report.final_test_error * 100.0);
//! ```

pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod metrics;
pub mod optim;
pub mod ps;
pub mod runtime;
pub mod sim;
pub mod theory;
pub mod util;

pub mod bench;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Default location of the AOT artifact directory (relative to repo root).
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";

/// Locate the artifact directory: `$DCASGD_ARTIFACTS`, else walk up from the
/// current directory looking for `artifacts/manifest.json`.
pub fn find_artifacts_dir() -> Option<std::path::PathBuf> {
    if let Ok(p) = std::env::var("DCASGD_ARTIFACTS") {
        let p = std::path::PathBuf::from(p);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    let mut dir = std::env::current_dir().ok()?;
    loop {
        let cand = dir.join(DEFAULT_ARTIFACTS_DIR);
        if cand.join("manifest.json").exists() {
            return Some(cand);
        }
        if !dir.pop() {
            return None;
        }
    }
}
