//! CIFAR-10 stand-in: a procedural 10-class dense-feature distribution.
//!
//! Example `i` is a pure function of `(seed, i)`:
//!
//! * label: uniform over classes (hashed from the index),
//! * features: `margin * anchor[label] + blend * anchor[label2] + noise*z`,
//!   where the per-class anchors are fixed unit-ish vectors drawn at
//!   construction, `label2` is a confuser class, and `z` is i.i.d. normal.
//! * a small fraction of examples carry a *flipped* label, creating an
//!   irreducible error floor so test-error curves have CIFAR-like shape
//!   (the paper's resnet floor is ~8%).
//!
//! The blend+noise structure makes the Bayes classifier non-trivial (a
//! linear probe does measurably worse than the MLP), which is what the
//! optimization-behaviour experiments need: a non-convex model trained past
//! the underfitting regime.

use super::{Dataset, FeatureKind};
use crate::util::rng::{Pcg64, SplitMix64};

#[derive(Clone, Debug)]
pub struct CifarLike {
    len: usize,
    dim: usize,
    classes: usize,
    seed: u64,
    /// classes × dim anchor matrix.
    anchors: Vec<f32>,
    pub margin: f32,
    pub blend: f32,
    pub noise: f32,
    /// Probability an example's observed label is resampled uniformly.
    pub label_noise: f32,
}

impl CifarLike {
    pub fn new(len: usize, dim: usize, classes: usize, seed: u64) -> Self {
        // Anchors are shared between train/test splits: derive them from the
        // split-invariant distribution seed.
        let dist_seed = super::dist_seed(seed) | 1;
        let mut rng = Pcg64::new(dist_seed ^ 0xC1FA_0000);
        let scale = 1.0 / (dim as f64).sqrt();
        let anchors =
            (0..classes * dim).map(|_| (rng.normal(0.0, scale)) as f32).collect();
        let envf = |k: &str, d: f32| -> f32 {
            std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
        };
        Self {
            len,
            dim,
            classes,
            seed,
            anchors,
            // Hardness calibrated so a small MLP lands in a CIFAR-like error
            // band (~10-20%) after ~10 epochs, leaving room for asynchrony
            // effects; override via env for ablations.
            margin: envf("DCASGD_TASK_MARGIN", 1.0),
            blend: envf("DCASGD_TASK_BLEND", 0.45),
            noise: envf("DCASGD_TASK_NOISE", 0.28),
            label_noise: envf("DCASGD_TASK_LABEL_NOISE", 0.02),
        }
    }

    fn anchor(&self, class: usize) -> &[f32] {
        &self.anchors[class * self.dim..(class + 1) * self.dim]
    }
}

impl Dataset for CifarLike {
    fn len(&self) -> usize {
        self.len
    }

    fn feature_kind(&self) -> FeatureKind {
        FeatureKind::Dense { dim: self.dim }
    }

    fn label_width(&self) -> usize {
        1
    }

    fn classes(&self) -> usize {
        self.classes
    }

    fn write_example(&self, idx: usize, x_f32: &mut [f32], _x_i32: &mut [i32], y: &mut [i32]) {
        debug_assert_eq!(x_f32.len(), self.dim);
        let mut sm = SplitMix64::new(self.seed ^ (idx as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Pcg64::new(sm.next_u64());
        let label = rng.below(self.classes as u64) as usize;
        let confuser = (label + 1 + rng.below(self.classes as u64 - 1) as usize) % self.classes;
        let a = self.anchor(label);
        let c = self.anchor(confuser);
        // Per-feature noise std is `noise` directly (NOT noise/sqrt(dim)):
        // projecting onto a unit anchor then gives projection-level noise
        // std = noise while the anchor's self-projection is `margin`, so
        // task hardness is margin/noise, independent of dimension. (With
        // /sqrt(dim) scaling, high-dim models saw a trivially separable
        // task — noise vanished under projection.)
        for (j, x) in x_f32.iter_mut().enumerate() {
            let z = rng.normal(0.0, 1.0) as f32;
            *x = self.margin * a[j] + self.blend * c[j] + self.noise * z;
        }
        // label noise: irreducible error floor
        let observed = if (rng.next_f64() as f32) < self.label_noise {
            rng.below(self.classes as u64) as usize
        } else {
            label
        };
        y[0] = observed as i32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ds() -> CifarLike {
        CifarLike::new(512, 48, 10, 7)
    }

    #[test]
    fn deterministic_examples() {
        let d = ds();
        let (mut x1, mut x2) = (vec![0.0; 48], vec![0.0; 48]);
        let (mut y1, mut y2) = ([0i32], [0i32]);
        d.write_example(13, &mut x1, &mut [], &mut y1);
        d.write_example(13, &mut x2, &mut [], &mut y2);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
        d.write_example(14, &mut x2, &mut [], &mut y2);
        assert_ne!(x1, x2);
    }

    #[test]
    fn labels_in_range_and_roughly_uniform() {
        let d = ds();
        let mut counts = vec![0usize; 10];
        let mut x = vec![0.0; 48];
        let mut y = [0i32];
        for i in 0..512 {
            d.write_example(i, &mut x, &mut [], &mut y);
            assert!((0..10).contains(&(y[0] as usize)));
            counts[y[0] as usize] += 1;
        }
        // each class should get a decent share of 512
        assert!(counts.iter().all(|&c| c > 20), "{counts:?}");
    }

    #[test]
    fn anchors_shared_across_splits() {
        // train (seed) and test (seed ^ mask) must sample the same class
        // anchors or the task would be unlearnable across splits.
        let train = CifarLike::new(64, 48, 10, 7);
        let test = CifarLike::new(64, 48, 10, 7 ^ 0x7E57_7E57_7E57_7E57);
        assert_eq!(train.anchors, test.anchors);
    }

    #[test]
    fn nearest_anchor_classifier_beats_chance() {
        // the synthetic task must be learnable: the Bayes-ish nearest-anchor
        // rule should classify well above 10% chance but below 100%.
        let d = ds();
        let mut x = vec![0.0; 48];
        let mut y = [0i32];
        let mut correct = 0;
        for i in 0..400 {
            d.write_example(i, &mut x, &mut [], &mut y);
            let mut best = (f32::NEG_INFINITY, 0usize);
            for k in 0..10 {
                let a = d.anchor(k);
                let dot: f32 = a.iter().zip(&x).map(|(ai, xi)| ai * xi).sum();
                if dot > best.0 {
                    best = (dot, k);
                }
            }
            if best.1 == y[0] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / 400.0;
        assert!(acc > 0.4, "nearest-anchor acc too low: {acc}");
        assert!(acc < 0.999, "task trivially separable: {acc}");
    }

    #[test]
    fn make_batch_layout() {
        let d = ds();
        let b = d.make_batch(&[1, 2, 3]);
        assert_eq!(b.rows, 3);
        assert_eq!(b.x_f32.len(), 3 * 48);
        assert_eq!(b.y_i32.len(), 3);
        assert!(b.x_i32.is_empty());
    }
}
