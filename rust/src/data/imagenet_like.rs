//! ImageNet stand-in: the CIFAR-like generator scaled up — many more
//! classes, hierarchical anchor structure (coarse super-classes with
//! fine-grained offsets), and slightly lower noise. See DESIGN.md §5.
//!
//! The hierarchy matters: with 100 flat random anchors the task is nearly
//! linearly separable; grouping fine classes around shared super-class
//! anchors produces the confusable-neighbour structure that makes top-1
//! error behave ImageNet-ishly (errors concentrated within super-classes).

use super::{Dataset, FeatureKind};
use crate::util::rng::{Pcg64, SplitMix64};

const SUPER_CLASSES: usize = 10;

#[derive(Clone, Debug)]
pub struct ImagenetLike {
    len: usize,
    dim: usize,
    classes: usize,
    seed: u64,
    /// super-class anchors: SUPER_CLASSES × dim
    coarse: Vec<f32>,
    /// fine offsets: classes × dim
    fine: Vec<f32>,
    pub coarse_w: f32,
    pub fine_w: f32,
    pub noise: f32,
    pub label_noise: f32,
}

impl ImagenetLike {
    pub fn new(len: usize, dim: usize, classes: usize, seed: u64) -> Self {
        let dist_seed = super::dist_seed(seed) | 1;
        let mut rng = Pcg64::new(dist_seed ^ 0x1AA6_E000);
        let scale = 1.0 / (dim as f64).sqrt();
        let coarse = (0..SUPER_CLASSES * dim).map(|_| rng.normal(0.0, scale) as f32).collect();
        let fine = (0..classes * dim).map(|_| rng.normal(0.0, scale) as f32).collect();
        let envf = |k: &str, d: f32| -> f32 {
            std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
        };
        Self {
            len,
            dim,
            classes,
            seed,
            coarse,
            fine,
            coarse_w: envf("DCASGD_TASK_COARSE", 1.0),
            fine_w: envf("DCASGD_TASK_FINE", 0.7),
            noise: envf("DCASGD_TASK_NOISE", 0.33),
            label_noise: envf("DCASGD_TASK_LABEL_NOISE", 0.02),
        }
    }

    #[inline]
    fn super_of(&self, class: usize) -> usize {
        class % SUPER_CLASSES
    }
}

impl Dataset for ImagenetLike {
    fn len(&self) -> usize {
        self.len
    }

    fn feature_kind(&self) -> FeatureKind {
        FeatureKind::Dense { dim: self.dim }
    }

    fn label_width(&self) -> usize {
        1
    }

    fn classes(&self) -> usize {
        self.classes
    }

    fn write_example(&self, idx: usize, x_f32: &mut [f32], _x_i32: &mut [i32], y: &mut [i32]) {
        debug_assert_eq!(x_f32.len(), self.dim);
        let mut sm = SplitMix64::new(self.seed ^ (idx as u64).wrapping_mul(0xD1B5_4A32_D192_ED03));
        let mut rng = Pcg64::new(sm.next_u64());
        let label = rng.below(self.classes as u64) as usize;
        let sup = self.super_of(label);
        let coarse = &self.coarse[sup * self.dim..(sup + 1) * self.dim];
        let fine = &self.fine[label * self.dim..(label + 1) * self.dim];
        // per-feature noise std = noise (see cifar_like.rs: projection-level
        // hardness must be dimension-independent)
        for (j, x) in x_f32.iter_mut().enumerate() {
            let z = rng.normal(0.0, 1.0) as f32;
            *x = self.coarse_w * coarse[j] + self.fine_w * fine[j] + self.noise * z;
        }
        let observed = if (rng.next_f64() as f32) < self.label_noise {
            // confusion is concentrated inside the super-class, like real
            // ImageNet top-1 mistakes
            let off = rng.below((self.classes / SUPER_CLASSES) as u64) as usize;
            (sup + off * SUPER_CLASSES) % self.classes
        } else {
            label
        };
        y[0] = observed as i32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_in_range() {
        let d = ImagenetLike::new(256, 64, 100, 3);
        let mut x = vec![0.0; 64];
        let mut y = [0i32];
        let mut seen = std::collections::HashSet::new();
        for i in 0..256 {
            d.write_example(i, &mut x, &mut [], &mut y);
            assert!((0..100).contains(&(y[0] as usize)));
            seen.insert(y[0]);
        }
        assert!(seen.len() > 60, "label diversity {}", seen.len());
        let mut x2 = vec![0.0; 64];
        let mut y2 = [0i32];
        d.write_example(200, &mut x2, &mut [], &mut y2);
        d.write_example(200, &mut x, &mut [], &mut y);
        assert_eq!(x, x2);
        assert_eq!(y, y2);
    }

    #[test]
    fn super_class_structure_is_learnable() {
        // nearest coarse-anchor should predict the super-class well above
        // the 1/SUPER_CLASSES chance level.
        let d = ImagenetLike::new(512, 64, 100, 5);
        let mut x = vec![0.0; 64];
        let mut y = [0i32];
        let mut correct = 0;
        for i in 0..500 {
            d.write_example(i, &mut x, &mut [], &mut y);
            let mut best = (f32::NEG_INFINITY, 0usize);
            for s in 0..SUPER_CLASSES {
                let a = &d.coarse[s * 64..(s + 1) * 64];
                let dot: f32 = a.iter().zip(&x).map(|(ai, xi)| ai * xi).sum();
                if dot > best.0 {
                    best = (dot, s);
                }
            }
            if best.1 == d.super_of(y[0] as usize) {
                correct += 1;
            }
        }
        let acc = correct as f64 / 500.0;
        assert!(acc > 0.35, "super-class structure not learnable: {acc}");
    }

    #[test]
    fn splits_share_distribution() {
        let train = ImagenetLike::new(64, 32, 100, 5);
        let test = ImagenetLike::new(64, 32, 100, 5 ^ 0x7E57_7E57_7E57_7E57);
        assert_eq!(train.coarse, test.coarse);
        assert_eq!(train.fine, test.fine);
    }
}
