//! Synthetic workloads (DESIGN.md §5 substitutions).
//!
//! Every dataset is *procedural*: example `i` is a pure function of
//! `(dataset seed, i)`, so datasets need no storage, shard trivially, and
//! training runs are bit-reproducible. The paper repartitions the data
//! randomly onto workers every epoch; [`EpochPartition`] reproduces that
//! protocol deterministically from `(seed, epoch)` so workers never need to
//! coordinate.

pub mod cifar_like;
pub mod imagenet_like;
pub mod lm_corpus;

use crate::util::rng::Pcg64;

/// XOR mask distinguishing the test split's example stream from the train
/// split's. Datasets recover the shared *distribution* seed (anchors,
/// grammar, ...) via `seed.min(seed ^ SPLIT_MASK)` — identical for both
/// splits because XOR is an involution.
pub const SPLIT_MASK: u64 = 0x7E57_7E57_7E57_7E57;

/// The split-invariant distribution seed for a given split seed.
pub fn dist_seed(seed: u64) -> u64 {
    seed.min(seed ^ SPLIT_MASK)
}

pub use cifar_like::CifarLike;
pub use imagenet_like::ImagenetLike;
pub use lm_corpus::LmCorpus;

/// Feature layout of a dataset, matched against the model artifact.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FeatureKind {
    /// Dense f32 features of the given dimension (classification models).
    Dense { dim: usize },
    /// Token sequences of the given length (LM models); labels are the
    /// next-token sequence of the same length.
    Tokens { seq_len: usize },
}

/// A materialized mini-batch in the layout the runtime feeds to PJRT.
#[derive(Clone, Debug, Default)]
pub struct Batch {
    pub x_f32: Vec<f32>,
    pub x_i32: Vec<i32>,
    pub y_i32: Vec<i32>,
    pub rows: usize,
}

/// A synthetic dataset: pure function from index to example.
pub trait Dataset: Send + Sync {
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    fn feature_kind(&self) -> FeatureKind;
    /// Number of label values per example (1 for classification, seq_len
    /// for LM next-token targets).
    fn label_width(&self) -> usize;
    /// Number of distinct classes / vocabulary size.
    fn classes(&self) -> usize;
    /// Write example `idx` into the destination slices. Exactly one of
    /// `x_f32` / `x_i32` is non-empty depending on [`FeatureKind`].
    fn write_example(&self, idx: usize, x_f32: &mut [f32], x_i32: &mut [i32], y: &mut [i32]);

    /// Materialize a batch for the given example indices.
    fn make_batch(&self, indices: &[usize]) -> Batch {
        let mut batch = Batch { rows: indices.len(), ..Batch::default() };
        let lw = self.label_width();
        batch.y_i32.resize(indices.len() * lw, 0);
        match self.feature_kind() {
            FeatureKind::Dense { dim } => {
                batch.x_f32.resize(indices.len() * dim, 0.0);
                for (r, &idx) in indices.iter().enumerate() {
                    let (xs, ys) = (
                        &mut batch.x_f32[r * dim..(r + 1) * dim],
                        &mut batch.y_i32[r * lw..(r + 1) * lw],
                    );
                    self.write_example(idx, xs, &mut [], ys);
                }
            }
            FeatureKind::Tokens { seq_len } => {
                batch.x_i32.resize(indices.len() * seq_len, 0);
                for (r, &idx) in indices.iter().enumerate() {
                    let (xs, ys) = (
                        &mut batch.x_i32[r * seq_len..(r + 1) * seq_len],
                        &mut batch.y_i32[r * lw..(r + 1) * lw],
                    );
                    self.write_example(idx, &mut [], xs, ys);
                }
            }
        }
        batch
    }
}

/// Per-epoch random repartition of example indices onto `workers` shards
/// (paper §6: "the data were repartitioned randomly onto the local workers
/// every epoch"). Deterministic in `(seed, epoch)`.
#[derive(Clone, Debug)]
pub struct EpochPartition {
    seed: u64,
    len: usize,
    workers: usize,
}

impl EpochPartition {
    pub fn new(seed: u64, len: usize, workers: usize) -> Self {
        assert!(workers >= 1 && len >= workers, "need at least one example per worker");
        Self { seed, len, workers }
    }

    /// The permuted index order for an epoch.
    fn epoch_order(&self, epoch: usize) -> Vec<u32> {
        let mut order: Vec<u32> = (0..self.len as u32).collect();
        let mut rng = Pcg64::new(self.seed ^ (epoch as u64).wrapping_mul(0xA24B_AED4_963E_E407));
        rng.shuffle(&mut order);
        order
    }

    /// Worker `m`'s shard of indices for `epoch` (contiguous slice of the
    /// epoch permutation; equal sizes up to remainder).
    pub fn shard(&self, epoch: usize, worker: usize) -> Vec<usize> {
        assert!(worker < self.workers);
        let order = self.epoch_order(epoch);
        let base = self.len / self.workers;
        let rem = self.len % self.workers;
        let start = worker * base + worker.min(rem);
        let size = base + usize::from(worker < rem);
        order[start..start + size].iter().map(|&i| i as usize).collect()
    }

    pub fn workers(&self) -> usize {
        self.workers
    }
    pub fn len(&self) -> usize {
        self.len
    }
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

/// Infinite per-worker batch cursor over epoch shards. Tracks the worker's
/// local epoch; `next_batch` never returns an empty batch (it rolls into
/// the next epoch's shard, dropping a final ragged remainder < batch_size).
#[derive(Clone, Debug)]
pub struct ShardCursor {
    partition: EpochPartition,
    worker: usize,
    batch_size: usize,
    epoch: usize,
    shard: Vec<usize>,
    pos: usize,
}

impl ShardCursor {
    pub fn new(partition: EpochPartition, worker: usize, batch_size: usize) -> Self {
        assert!(batch_size >= 1);
        let shard = partition.shard(0, worker);
        Self { partition, worker, batch_size, epoch: 0, shard, pos: 0 }
    }

    /// Epochs this worker has started (0-based current epoch).
    pub fn epoch(&self) -> usize {
        self.epoch
    }

    /// Next `batch_size` example indices.
    pub fn next_indices(&mut self) -> Vec<usize> {
        if self.pos + self.batch_size > self.shard.len() {
            self.epoch += 1;
            self.shard = self.partition.shard(self.epoch, self.worker);
            self.pos = 0;
        }
        let out = self.shard[self.pos..self.pos + self.batch_size].to_vec();
        self.pos += self.batch_size;
        out
    }
}

/// Build the dataset selected by an experiment config, sized to match a
/// model artifact's input shape.
pub fn build_dataset(
    kind: &crate::config::DatasetKind,
    feature: FeatureKind,
    classes: usize,
    train: bool,
    size: usize,
    seed: u64,
) -> Box<dyn Dataset> {
    use crate::config::DatasetKind;
    // train/test draw from the same distribution but disjoint index spaces
    let split_seed = if train { seed } else { seed ^ SPLIT_MASK };
    match kind {
        DatasetKind::CifarLike => {
            let dim = match feature {
                FeatureKind::Dense { dim } => dim,
                _ => panic!("cifar-like needs a dense-feature model"),
            };
            Box::new(CifarLike::new(size, dim, classes, split_seed))
        }
        DatasetKind::ImagenetLike => {
            let dim = match feature {
                FeatureKind::Dense { dim } => dim,
                _ => panic!("imagenet-like needs a dense-feature model"),
            };
            Box::new(ImagenetLike::new(size, dim, classes, split_seed))
        }
        DatasetKind::LmCorpus => {
            let seq = match feature {
                FeatureKind::Tokens { seq_len } => seq_len,
                _ => panic!("lm-corpus needs a token model"),
            };
            Box::new(LmCorpus::new(size, seq, classes, split_seed))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_covers_all_indices_once() {
        let p = EpochPartition::new(3, 103, 4);
        for epoch in [0, 1, 7] {
            let mut all: Vec<usize> = (0..4).flat_map(|m| p.shard(epoch, m)).collect();
            all.sort_unstable();
            assert_eq!(all, (0..103).collect::<Vec<_>>(), "epoch {epoch}");
        }
    }

    #[test]
    fn partition_changes_between_epochs_not_between_calls() {
        let p = EpochPartition::new(3, 64, 2);
        assert_eq!(p.shard(0, 0), p.shard(0, 0));
        assert_ne!(p.shard(0, 0), p.shard(1, 0));
    }

    #[test]
    fn partition_sizes_balanced() {
        let p = EpochPartition::new(9, 10, 3);
        let sizes: Vec<usize> = (0..3).map(|m| p.shard(0, m).len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 10);
        assert!(sizes.iter().all(|&s| s == 3 || s == 4));
    }

    #[test]
    fn cursor_rolls_epochs_and_keeps_batch_size() {
        let p = EpochPartition::new(5, 100, 4); // shard size 25
        let mut c = ShardCursor::new(p, 1, 8);
        let mut seen = 0;
        for _ in 0..10 {
            let idx = c.next_indices();
            assert_eq!(idx.len(), 8);
            seen += idx.len();
        }
        // 25/8 = 3 batches per epoch (24 examples), so 10 batches span 4 epochs
        assert_eq!(seen, 80);
        assert!(c.epoch() >= 3);
    }

    #[test]
    fn cursor_batches_use_only_own_shard() {
        let p = EpochPartition::new(5, 96, 3);
        let mut c = ShardCursor::new(p.clone(), 2, 4);
        let shard0: std::collections::HashSet<usize> = p.shard(0, 2).into_iter().collect();
        for _ in 0..(32 / 4) {
            for i in c.next_indices() {
                assert!(shard0.contains(&i));
            }
        }
    }
}
