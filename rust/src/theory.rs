//! The paper's theory, made executable.
//!
//! Theorem 5.1 / Corollary 5.2 bound the delay DC-ASGD tolerates and the
//! feasible range of lambda in terms of smoothness constants of the loss:
//!
//! * `L1` — gradient bound (Lipschitz constant of f),
//! * `L2` — smoothness (Lipschitz constant of the gradient),
//! * `L3` — Hessian Lipschitz constant,
//! * `pi` — search-diameter bound `||w - w'|| <= pi`,
//! * `eps_D` — Hessian diagonalization error (Lemma C.1),
//!
//! This module (a) estimates `L1..L3` empirically from gradient probes
//! along the training trajectory (finite differences of the gradient
//! oracle), and (b) evaluates the paper's feasibility formulas:
//!
//! * discussion (2) of Thm 5.1: DC-ASGD beats ASGD when `C_lambda < L2`,
//!   where `C_lambda^2 = L3^2 pi^2/2 + 2((1-lambda)L1^2 + eps_D)^2 + 2 eps_nc^2`;
//! * the simplified feasible lambda range
//!   `lambda in [1 - (L2 - L3 pi)/(2 L1^2), 1]` (paper discussion (2)),
//! * Corollary 5.2's speedup factor `T / C0`.
//!
//! Estimated constants are *local* (along the visited trajectory), which is
//! the regime the theorem actually speaks about; see the `theory_bounds`
//! integration test for the measured values on the CIFAR-like task.

use crate::util::stats::Running;

/// Empirical smoothness constants measured from gradient probes.
#[derive(Clone, Copy, Debug, Default)]
pub struct SmoothnessEstimate {
    /// max ||g|| observed (estimates L1)
    pub l1: f64,
    /// max ||g(w+d) - g(w)|| / ||d||  (estimates L2)
    pub l2: f64,
    /// max ||g(w+d) - 2 g(w) + g(w-d)|| / ||d||^2  (estimates L3)
    pub l3: f64,
    /// max ||w - w'|| over probed snapshots (estimates pi)
    pub pi: f64,
    pub probes: usize,
}

/// Accumulates gradient probes. The caller supplies a gradient oracle
/// (usually a closure over the PJRT engine with a fixed batch).
pub struct SmoothnessProbe {
    l1: Running,
    l2: Running,
    l3: Running,
    l1_max: f64,
    l2_max: f64,
    l3_max: f64,
    pi_max: f64,
    probes: usize,
}

impl Default for SmoothnessProbe {
    fn default() -> Self {
        Self::new()
    }
}

fn norm(v: &[f32]) -> f64 {
    v.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt()
}

impl SmoothnessProbe {
    pub fn new() -> Self {
        Self {
            l1: Running::new(),
            l2: Running::new(),
            l3: Running::new(),
            l1_max: 0.0,
            l2_max: 0.0,
            l3_max: 0.0,
            pi_max: 0.0,
            probes: 0,
        }
    }

    /// Probe at `w` along direction `d` (same batch for all three gradient
    /// evaluations). `g_at` is the gradient oracle.
    pub fn probe<F>(&mut self, w: &[f32], d: &[f32], mut g_at: F) -> anyhow::Result<()>
    where
        F: FnMut(&[f32]) -> anyhow::Result<Vec<f32>>,
    {
        let dn = norm(d);
        anyhow::ensure!(dn > 0.0, "zero probe direction");
        let wp: Vec<f32> = w.iter().zip(d).map(|(a, b)| a + b).collect();
        let wm: Vec<f32> = w.iter().zip(d).map(|(a, b)| a - b).collect();
        let g0 = g_at(w)?;
        let gp = g_at(&wp)?;
        let gm = g_at(&wm)?;

        let l1 = norm(&g0);
        let diff: Vec<f32> = gp.iter().zip(&g0).map(|(a, b)| a - b).collect();
        let l2 = norm(&diff) / dn;
        let second: Vec<f32> =
            gp.iter().zip(&g0).zip(&gm).map(|((p, z), m)| p - 2.0 * z + m).collect();
        let l3 = norm(&second) / (dn * dn);

        self.l1.push(l1);
        self.l2.push(l2);
        self.l3.push(l3);
        self.l1_max = self.l1_max.max(l1);
        self.l2_max = self.l2_max.max(l2);
        self.l3_max = self.l3_max.max(l3);
        self.probes += 1;
        Ok(())
    }

    /// Record a trajectory displacement (updates the pi estimate).
    pub fn observe_displacement(&mut self, w_a: &[f32], w_b: &[f32]) {
        let d: Vec<f32> = w_a.iter().zip(w_b).map(|(a, b)| a - b).collect();
        self.pi_max = self.pi_max.max(norm(&d));
    }

    pub fn estimate(&self) -> SmoothnessEstimate {
        SmoothnessEstimate {
            l1: self.l1_max,
            l2: self.l2_max,
            l3: self.l3_max,
            pi: self.pi_max,
            probes: self.probes,
        }
    }
}

/// The paper's feasibility quantities for a given lambda.
#[derive(Clone, Copy, Debug)]
pub struct DelayToleranceReport {
    pub lambda: f64,
    /// C_lambda (discussion (2), with eps_nc treated as negligible).
    pub c_lambda: f64,
    /// DC-ASGD strictly dominates ASGD's tolerance when C_lambda < L2.
    pub dc_beats_asgd: bool,
    /// Simplified feasible lambda interval [lo, 1] (empty if lo > 1).
    pub lambda_lo: f64,
    pub lambda_feasible: bool,
}

/// Evaluate the Theorem 5.1 discussion-(2) conditions.
pub fn delay_tolerance(est: &SmoothnessEstimate, lambda: f64, eps_d: f64) -> DelayToleranceReport {
    let c2 = est.l3.powi(2) * est.pi.powi(2) / 2.0
        + 2.0 * ((1.0 - lambda) * est.l1.powi(2) + eps_d).powi(2);
    let c_lambda = c2.sqrt();
    // lambda in [1 - (L2 - L3*pi)/(2 L1^2), 1], requiring L2 > L3*pi
    let headroom = est.l2 - est.l3 * est.pi;
    let lo = if est.l1 > 0.0 { 1.0 - headroom / (2.0 * est.l1.powi(2)) } else { 0.0 };
    DelayToleranceReport {
        lambda,
        c_lambda,
        dc_beats_asgd: c_lambda < est.l2,
        lambda_lo: lo,
        lambda_feasible: headroom > 0.0 && lambda >= lo.max(0.0) && lambda <= 1.0,
    }
}

/// Corollary 5.2: with T total iterations and constant C0, DC-ASGD
/// outperforms ASGD by a factor T / C0 (when the lambda interval above is
/// non-empty and T >= C0).
pub fn speedup_factor(total_iters: u64, c0: f64) -> f64 {
    if c0 <= 0.0 {
        return f64::INFINITY;
    }
    total_iters as f64 / c0
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Quadratic loss f(w) = 0.5 w' A w with known constants: L1 on a ball
    /// of radius r is ||A|| r, L2 = ||A||, L3 = 0.
    fn quad_grad(a_diag: &[f64], w: &[f32]) -> Vec<f32> {
        w.iter().zip(a_diag).map(|(wi, ai)| (*wi as f64 * ai) as f32).collect()
    }

    #[test]
    fn recovers_quadratic_constants() {
        let a = vec![2.0f64, 0.5, 1.0, 3.0];
        let mut probe = SmoothnessProbe::new();
        let w = vec![1.0f32, -1.0, 0.5, 0.25];
        let d = vec![0.01f32, 0.02, -0.01, 0.005];
        probe
            .probe(&w, &d, |wq| Ok(quad_grad(&a, wq)))
            .unwrap();
        let est = probe.estimate();
        // L2 estimate = ||A d||/||d|| <= ||A||_2 = 3, >= lambda_min = 0.5
        assert!(est.l2 > 0.5 && est.l2 <= 3.0 + 1e-6, "L2={}", est.l2);
        // quadratic: Hessian constant => L3 ~ 0 (up to f32 noise amplified by 1/||d||^2)
        assert!(est.l3 < 1.0, "L3={}", est.l3);
        assert_eq!(est.probes, 1);
    }

    #[test]
    fn pi_tracks_max_displacement() {
        let mut probe = SmoothnessProbe::new();
        probe.observe_displacement(&[0.0, 0.0], &[3.0, 4.0]);
        probe.observe_displacement(&[0.0, 0.0], &[1.0, 1.0]);
        assert!((probe.estimate().pi - 5.0).abs() < 1e-9);
    }

    #[test]
    fn lambda_one_minimizes_c_lambda_without_curvature() {
        // with L3=0 and eps_D=0: C_lambda = sqrt(2) (1-lambda) L1^2,
        // minimized (=0) at lambda = 1 — the paper's "lambda=1 extreme"
        let est = SmoothnessEstimate { l1: 2.0, l2: 1.0, l3: 0.0, pi: 0.5, probes: 1 };
        let r0 = delay_tolerance(&est, 0.0, 0.0);
        let r1 = delay_tolerance(&est, 1.0, 0.0);
        assert!(r1.c_lambda < r0.c_lambda);
        assert!((r1.c_lambda - 0.0).abs() < 1e-12);
        assert!(r1.dc_beats_asgd);
        assert!(!r0.dc_beats_asgd); // C_0 = sqrt(2)*4 > L2=1
    }

    #[test]
    fn feasible_interval_requires_smoothness_headroom() {
        // L2 < L3*pi: the simplified interval is empty
        let est = SmoothnessEstimate { l1: 1.0, l2: 0.1, l3: 10.0, pi: 1.0, probes: 1 };
        let r = delay_tolerance(&est, 1.0, 0.0);
        assert!(!r.lambda_feasible);
        // generous headroom: lo < 1 and lambda=1 is feasible
        let est2 = SmoothnessEstimate { l1: 1.0, l2: 5.0, l3: 0.1, pi: 1.0, probes: 1 };
        let r2 = delay_tolerance(&est2, 1.0, 0.0);
        assert!(r2.lambda_feasible);
        assert!(r2.lambda_lo < 1.0);
    }

    #[test]
    fn speedup_factor_matches_corollary() {
        assert!((speedup_factor(1000, 100.0) - 10.0).abs() < 1e-12);
        assert!(speedup_factor(1000, 0.0).is_infinite());
    }
}
