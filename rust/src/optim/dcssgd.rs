//! DC-SSGD (paper appendix H): delay-compensated *synchronous* SGD.
//!
//! Large-mini-batch SSGD assumes `g(w_{t+j}) ≈ g(w_t)` when it folds M
//! workers' gradients into one step (Goyal et al. 2017). Appendix H removes
//! that assumption: fold the gradients in sequentially, compensating each
//! with the DC term against the *virtually advanced* model `w~_{t+1}^j`,
//! ordered by increasing `||w~ - w_t||²` (smaller distance → more accurate
//! Taylor approximation first).
//!
//! ```text
//! w~^{j+1} = w~^j - (eta_hat / M) * [ g_j + lam * g_j (.) g_j (.) (w~^j - w_t) ]
//! ```
//!
//! with `eta_hat = M * eta` (the linear scaling rule).

use super::compensate_into;

/// Accumulates the M per-worker gradients of one synchronous step and
/// applies them sequentially with delay compensation (Eqn. 110/111).
///
/// All buffers — the gradient slots, the sync-point snapshot, the sort
/// scratch, the compensation scratch — are arenas: they grow to the round
/// size once and are reused forever after, so the steady-state barrier
/// fold performs no heap allocation.
pub struct DcSsgdAccumulator {
    n: usize,
    lam: f32,
    /// Gradient arena; `count` slots are live, the rest are reusable.
    grads: Vec<Vec<f32>>,
    count: usize,
    norms: Vec<f32>,
    order: Vec<usize>,
    w_t: Vec<f32>,
    comp_buf: Vec<f32>,
}

impl DcSsgdAccumulator {
    pub fn new(n: usize, lam: f32) -> Self {
        Self {
            n,
            lam,
            grads: Vec::new(),
            count: 0,
            norms: Vec::new(),
            order: Vec::new(),
            w_t: vec![0.0; n],
            comp_buf: vec![0.0; n],
        }
    }

    /// Copy `grad` into the next arena slot (allocation-free once the arena
    /// has grown to the round size).
    pub fn push_from(&mut self, grad: &[f32]) {
        assert_eq!(grad.len(), self.n);
        if self.count == self.grads.len() {
            self.grads.push(vec![0.0f32; self.n]);
        }
        self.grads[self.count].copy_from_slice(grad);
        self.count += 1;
    }

    /// Owned-buffer convenience wrapper over [`Self::push_from`].
    pub fn push(&mut self, grad: Vec<f32>) {
        self.push_from(&grad);
    }

    pub fn pending(&self) -> usize {
        self.count
    }

    /// Apply all pending gradients to `w` (the model at the sync point) and
    /// clear. `lr` is the *per-worker* learning rate eta; the effective
    /// large-batch rate is `M * lr` split over M sequential sub-steps, i.e.
    /// each sub-step uses `lr`.
    ///
    /// Sub-step order: appendix H prescribes increasing `||w~ - w_t||²`;
    /// since every sub-step moves `w~` further from `w_t`, that is exactly
    /// arrival order re-sorted by each gradient's prospective step size —
    /// we order by ascending `||g||²` (smallest displacement first).
    pub fn apply(&mut self, w: &mut [f32], lr: f32) {
        assert_eq!(w.len(), self.n);
        if self.count == 0 {
            return;
        }
        self.w_t.copy_from_slice(w); // snapshot of the sync point
        self.norms.clear();
        self.norms.extend(
            self.grads[..self.count].iter().map(|g| g.iter().map(|x| x * x).sum::<f32>()),
        );
        self.order.clear();
        self.order.extend(0..self.count);
        // total_cmp: gradients can be non-finite when the surrounding run
        // has already diverged; the fold must stay panic-free so the
        // experiment records the divergence instead of crashing.
        let norms = &self.norms;
        self.order.sort_by(|&a, &b| norms[a].total_cmp(&norms[b]));
        for &j in &self.order {
            // compensate g_j against the virtually-advanced model w (== w~^j)
            compensate_into(&mut self.comp_buf, &self.grads[j], w, &self.w_t, self.lam);
            for (wi, ci) in w.iter_mut().zip(&self.comp_buf) {
                *wi -= lr * ci;
            }
        }
        self.count = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{average_into, sgd_step};
    use crate::util::rng::Pcg64;

    fn grads(seed: u64, n: usize, k: usize) -> Vec<Vec<f32>> {
        let mut rng = Pcg64::new(seed);
        (0..k).map(|_| (0..n).map(|_| rng.normal(0.0, 0.1) as f32).collect()).collect()
    }

    #[test]
    fn single_gradient_is_plain_step() {
        let g = grads(1, 64, 1);
        let mut acc = DcSsgdAccumulator::new(64, 2.0);
        acc.push(g[0].clone());
        let mut w = vec![1.0f32; 64];
        acc.apply(&mut w, 0.1);
        // first sub-step has w~ == w_t, so compensation vanishes
        let mut expect = vec![1.0f32; 64];
        sgd_step(&mut expect, &g[0], 0.1);
        assert_eq!(w, expect);
        assert_eq!(acc.pending(), 0);
    }

    #[test]
    fn lambda_zero_equals_summed_sgd() {
        // with lam=0 the sequential fold is just sum of per-worker steps,
        // which equals SSGD with the M-scaled learning rate
        let gs = grads(2, 128, 4);
        let mut acc = DcSsgdAccumulator::new(128, 0.0);
        for g in &gs {
            acc.push(g.clone());
        }
        let mut w = vec![0.5f32; 128];
        acc.apply(&mut w, 0.1);

        let mut avg = vec![0.0f32; 128];
        let refs: Vec<&[f32]> = gs.iter().map(|g| g.as_slice()).collect();
        average_into(&mut avg, &refs);
        let mut expect = vec![0.5f32; 128];
        sgd_step(&mut expect, &avg, 0.4); // eta_hat = M*eta = 4*0.1
        for (a, b) in w.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn compensation_changes_multi_gradient_fold() {
        let gs = grads(3, 64, 4);
        let mut acc0 = DcSsgdAccumulator::new(64, 0.0);
        let mut acc2 = DcSsgdAccumulator::new(64, 2.0);
        for g in &gs {
            acc0.push(g.clone());
            acc2.push(g.clone());
        }
        let mut w0 = vec![0.3f32; 64];
        let mut w2 = vec![0.3f32; 64];
        acc0.apply(&mut w0, 0.1);
        acc2.apply(&mut w2, 0.1);
        assert_ne!(w0, w2);
    }

    #[test]
    fn apply_clears_and_is_reusable() {
        let gs = grads(4, 32, 2);
        let mut acc = DcSsgdAccumulator::new(32, 1.0);
        acc.push(gs[0].clone());
        let mut w = vec![0.0f32; 32];
        acc.apply(&mut w, 0.1);
        let w_after_first = w.clone();
        acc.push(gs[1].clone());
        acc.apply(&mut w, 0.1);
        assert_ne!(w, w_after_first);
        acc.apply(&mut w, 0.1); // empty apply is a no-op
        let w2 = w.clone();
        assert_eq!(w, w2);
    }

    #[test]
    fn push_from_equals_owned_push() {
        let gs = grads(7, 96, 3);
        let mut a = DcSsgdAccumulator::new(96, 1.5);
        let mut b = DcSsgdAccumulator::new(96, 1.5);
        for g in &gs {
            a.push(g.clone());
            b.push_from(g);
        }
        assert_eq!(a.pending(), b.pending());
        let mut wa = vec![0.2f32; 96];
        let mut wb = vec![0.2f32; 96];
        a.apply(&mut wa, 0.05);
        b.apply(&mut wb, 0.05);
        assert_eq!(wa, wb);
        // the arena survives a second round without growing demands
        b.push_from(&gs[0]);
        b.apply(&mut wb, 0.05);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn order_is_by_ascending_gradient_norm() {
        // construct two gradients with very different norms; verify the
        // small one is folded first by checking the asymmetric result
        let n = 8;
        let small = vec![0.01f32; n];
        let large = vec![1.0f32; n];
        let mut acc = DcSsgdAccumulator::new(n, 10.0);
        acc.push(large.clone());
        acc.push(small.clone());
        let mut w_a = vec![1.0f32; n];
        acc.apply(&mut w_a, 0.1);

        // manual fold small-first
        let w_t = vec![1.0f32; n];
        let mut w_b = vec![1.0f32; n];
        let mut buf = vec![0.0f32; n];
        for g in [&small, &large] {
            compensate_into(&mut buf, g, &w_b, &w_t, 10.0);
            for (wi, ci) in w_b.iter_mut().zip(&buf) {
                *wi -= 0.1 * ci;
            }
        }
        for (a, b) in w_a.iter().zip(&w_b) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}
