//! Update rules (paper §4 + appendix H), implemented three ways:
//!
//! * **scalar**: the `*_scalar` reference loops in this module — one plain
//!   per-element pass, kept as the ground truth every other implementation
//!   is pinned against,
//! * **simd**: the chunked-SIMD kernels in [`kernels`] — bit-identical to
//!   the scalar loops (see the module docs there for the f32 op-order
//!   contract) and selected by default via [`simd_enabled`],
//! * **xla**: the AOT-compiled Pallas kernels, dispatched via
//!   [`crate::runtime`] when `UpdateBackend::Xla` is selected.
//!
//! All functions operate on sub-slices so the sharded store can apply them
//! per-shard in parallel. They are written as single fused passes: each
//! element of every operand is touched exactly once (bytes moved =
//! theoretical minimum), mirroring the Pallas kernels' structure. The
//! delay-compensation math itself lives in exactly one place — the
//! [`kernels::dc_comp`] / [`kernels::dca_comp`] elementwise cores — shared
//! by the fused steps, the staged `compensate_*` buffers, and the sparse
//! kernels, so the variants cannot drift apart.

pub mod dcssgd;
pub mod kernels;

pub use dcssgd::DcSsgdAccumulator;
pub use kernels::{set_simd_enabled, simd_enabled, LANES};

use kernels::{dc_comp, dca_comp};

/// Plain SGD: `w -= lr * g`. Dispatches on [`simd_enabled`].
pub fn sgd_step(w: &mut [f32], g: &[f32], lr: f32) {
    if simd_enabled() {
        kernels::sgd_step_simd(w, g, lr);
    } else {
        sgd_step_scalar(w, g, lr);
    }
}

/// Scalar reference for [`sgd_step`].
pub fn sgd_step_scalar(w: &mut [f32], g: &[f32], lr: f32) {
    debug_assert_eq!(w.len(), g.len());
    for (wi, gi) in w.iter_mut().zip(g) {
        *wi -= lr * gi;
    }
}

/// Heavy-ball momentum: `v = mu*v + g; w -= lr*v`.
pub fn momentum_step(w: &mut [f32], v: &mut [f32], g: &[f32], lr: f32, mu: f32) {
    if simd_enabled() {
        kernels::momentum_step_simd(w, v, g, lr, mu);
    } else {
        momentum_step_scalar(w, v, g, lr, mu);
    }
}

/// Scalar reference for [`momentum_step`].
pub fn momentum_step_scalar(w: &mut [f32], v: &mut [f32], g: &[f32], lr: f32, mu: f32) {
    debug_assert_eq!(w.len(), g.len());
    debug_assert_eq!(w.len(), v.len());
    for ((wi, vi), gi) in w.iter_mut().zip(v.iter_mut()).zip(g) {
        *vi = mu * *vi + gi;
        *wi -= lr * *vi;
    }
}

/// DC-ASGD-c (Eqn. 10): `w -= lr * (g + lam * g⊙g⊙(w - w_bak))`.
///
/// `w` is the *current* global model; `w_bak` is the snapshot the worker
/// pulled. Single fused pass.
pub fn dc_step(w: &mut [f32], g: &[f32], w_bak: &[f32], lr: f32, lam: f32) {
    if simd_enabled() {
        kernels::dc_step_simd(w, g, w_bak, lr, lam);
    } else {
        dc_step_scalar(w, g, w_bak, lr, lam);
    }
}

/// Scalar reference for [`dc_step`].
pub fn dc_step_scalar(w: &mut [f32], g: &[f32], w_bak: &[f32], lr: f32, lam: f32) {
    debug_assert_eq!(w.len(), g.len());
    debug_assert_eq!(w.len(), w_bak.len());
    for ((wi, gi), bi) in w.iter_mut().zip(g).zip(w_bak) {
        *wi -= lr * dc_comp(*gi, *wi, *bi, lam);
    }
}

/// DC-ASGD-a (Eqn. 10 + Eqn. 14): MeanSquare-normalized lambda.
///
/// `ms = m*ms + (1-m)*g⊙g; lam_t = lam0/sqrt(ms + eps)` elementwise.
#[allow(clippy::too_many_arguments)]
pub fn dc_adaptive_step(
    w: &mut [f32],
    g: &[f32],
    w_bak: &[f32],
    ms: &mut [f32],
    lr: f32,
    lam0: f32,
    m: f32,
    eps: f32,
) {
    if simd_enabled() {
        kernels::dc_adaptive_step_simd(w, g, w_bak, ms, lr, lam0, m, eps);
    } else {
        dc_adaptive_step_scalar(w, g, w_bak, ms, lr, lam0, m, eps);
    }
}

/// Scalar reference for [`dc_adaptive_step`].
#[allow(clippy::too_many_arguments)]
pub fn dc_adaptive_step_scalar(
    w: &mut [f32],
    g: &[f32],
    w_bak: &[f32],
    ms: &mut [f32],
    lr: f32,
    lam0: f32,
    m: f32,
    eps: f32,
) {
    debug_assert_eq!(w.len(), g.len());
    debug_assert_eq!(w.len(), w_bak.len());
    debug_assert_eq!(w.len(), ms.len());
    let one_minus_m = 1.0 - m;
    for (((wi, gi), bi), msi) in w.iter_mut().zip(g).zip(w_bak).zip(ms.iter_mut()) {
        let comp = dca_comp(*gi, *wi, *bi, msi, lam0, m, one_minus_m, eps);
        *wi -= lr * comp;
    }
}

/// Delay-compensated gradient *without* applying it (used by DC-SSGD and by
/// momentum composition): `out = g + lam * g⊙g⊙(w - w_bak)`.
pub fn compensate_into(out: &mut [f32], g: &[f32], w: &[f32], w_bak: &[f32], lam: f32) {
    if simd_enabled() {
        kernels::compensate_into_simd(out, g, w, w_bak, lam);
    } else {
        compensate_into_scalar(out, g, w, w_bak, lam);
    }
}

/// Scalar reference for [`compensate_into`].
pub fn compensate_into_scalar(out: &mut [f32], g: &[f32], w: &[f32], w_bak: &[f32], lam: f32) {
    debug_assert_eq!(out.len(), g.len());
    for (((oi, gi), wi), bi) in out.iter_mut().zip(g).zip(w).zip(w_bak) {
        *oi = dc_comp(*gi, *wi, *bi, lam);
    }
}

/// Adaptive-lambda compensation into a buffer (updates `ms`). Shares the
/// [`kernels::dca_comp`] core with [`dc_adaptive_step`], so staged
/// compensation == fused step holds *bitwise* (previously the recurrence
/// was duplicated in both functions and only agreed to rounding noise by
/// inspection).
#[allow(clippy::too_many_arguments)]
pub fn compensate_adaptive_into(
    out: &mut [f32],
    g: &[f32],
    w: &[f32],
    w_bak: &[f32],
    ms: &mut [f32],
    lam0: f32,
    m: f32,
    eps: f32,
) {
    if simd_enabled() {
        kernels::compensate_adaptive_into_simd(out, g, w, w_bak, ms, lam0, m, eps);
    } else {
        compensate_adaptive_into_scalar(out, g, w, w_bak, ms, lam0, m, eps);
    }
}

/// Scalar reference for [`compensate_adaptive_into`].
#[allow(clippy::too_many_arguments)]
pub fn compensate_adaptive_into_scalar(
    out: &mut [f32],
    g: &[f32],
    w: &[f32],
    w_bak: &[f32],
    ms: &mut [f32],
    lam0: f32,
    m: f32,
    eps: f32,
) {
    let one_minus_m = 1.0 - m;
    for ((((oi, gi), wi), bi), msi) in
        out.iter_mut().zip(g).zip(w).zip(w_bak).zip(ms.iter_mut())
    {
        *oi = dca_comp(*gi, *wi, *bi, msi, lam0, m, one_minus_m, eps);
    }
}

/// Sparse SGD on one shard slice: for each pair `(i, v)` with global index
/// `i` inside the shard that starts at `base`, `w[i - base] -= lr * v`.
/// Identical f32 ops (in ascending-index order) to [`sgd_step`] on the
/// densified gradient — untouched coordinates are exactly unchanged there
/// too (`x - lr * 0.0 == x`), so sparse and dense applies are bit-equal.
/// The index walk is an irregular gather, so there is no SIMD variant; the
/// per-element math is the same expression the dense kernels evaluate.
pub fn sgd_step_sparse(w: &mut [f32], base: usize, idx: &[u32], val: &[f32], lr: f32) {
    debug_assert_eq!(idx.len(), val.len());
    for (&i, &v) in idx.iter().zip(val) {
        w[i as usize - base] -= lr * v;
    }
}

/// Sparse DC-ASGD-c (Eqn. 10) on one shard slice: compensation against the
/// worker's backup only at the transmitted coordinates. Bit-equal to
/// [`dc_step`] on the densified gradient (a zero gradient element
/// contributes `0 + lam * 0 * delta = 0` there). Uses the shared
/// [`kernels::dc_comp`] core.
pub fn dc_step_sparse(
    w: &mut [f32],
    w_bak: &[f32],
    base: usize,
    idx: &[u32],
    val: &[f32],
    lr: f32,
    lam: f32,
) {
    debug_assert_eq!(w.len(), w_bak.len());
    debug_assert_eq!(idx.len(), val.len());
    for (&i, &v) in idx.iter().zip(val) {
        let j = i as usize - base;
        w[j] -= lr * dc_comp(v, w[j], w_bak[j], lam);
    }
}

/// Average equal-length gradient rows into `out` (SSGD). Generic over the
/// row type (`&[f32]`, `Vec<f32>`, ...) so callers with owned arenas don't
/// build a vector of slice refs; the f32 accumulation order (copy row 0,
/// add the rest, scale) is part of the repo's determinism contract — which
/// is also why this stays a plain loop: vectorizing across *rows* would be
/// fine (elementwise), but the simple form is not on the PS hot path.
pub fn average_into<G: AsRef<[f32]>>(out: &mut [f32], grads: &[G]) {
    assert!(!grads.is_empty());
    let inv = 1.0 / grads.len() as f32;
    out.copy_from_slice(grads[0].as_ref());
    for g in &grads[1..] {
        let g = g.as_ref();
        debug_assert_eq!(g.len(), out.len());
        for (oi, gi) in out.iter_mut().zip(g.iter()) {
            *oi += gi;
        }
    }
    for oi in out.iter_mut() {
        *oi *= inv;
    }
}

/// Default epsilon inside the MeanSquare sqrt (paper: 1e-7).
pub const MS_EPS: f32 = 1e-7;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn vecs(seed: u64, n: usize, k: usize) -> Vec<Vec<f32>> {
        let mut rng = Pcg64::new(seed);
        (0..k).map(|_| (0..n).map(|_| rng.normal(0.0, 1.0) as f32).collect()).collect()
    }

    #[test]
    fn sgd_matches_scalar_math() {
        let mut w = vec![1.0, -2.0, 0.5];
        sgd_step(&mut w, &[0.5, 0.5, -1.0], 0.1);
        assert_eq!(w, vec![0.95, -2.05, 0.6]);
    }

    #[test]
    fn dc_step_matches_formula_elementwise() {
        let v = vecs(1, 257, 3);
        let (g, wb) = (&v[1], &v[2]);
        let mut w = v[0].clone();
        let (lr, lam) = (0.1f32, 0.7f32);
        let expect: Vec<f32> = v[0]
            .iter()
            .zip(g)
            .zip(wb)
            .map(|((wi, gi), bi)| wi - lr * (gi + lam * gi * gi * (wi - bi)))
            .collect();
        dc_step(&mut w, g, wb, lr, lam);
        for (a, b) in w.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn dc_with_lambda_zero_is_sgd() {
        let v = vecs(2, 128, 3);
        let mut w1 = v[0].clone();
        let mut w2 = v[0].clone();
        dc_step(&mut w1, &v[1], &v[2], 0.3, 0.0);
        sgd_step(&mut w2, &v[1], 0.3);
        assert_eq!(w1, w2);
    }

    #[test]
    fn dc_with_zero_delay_is_sgd() {
        let v = vecs(3, 64, 2);
        let mut w1 = v[0].clone();
        let mut w2 = v[0].clone();
        let bak = v[0].clone();
        dc_step(&mut w1, &v[1], &bak, 0.2, 5.0);
        sgd_step(&mut w2, &v[1], 0.2);
        assert_eq!(w1, w2);
    }

    #[test]
    fn adaptive_meansquare_recursion() {
        let v = vecs(4, 96, 4);
        let mut w = v[0].clone();
        let mut ms = vec![0.0; 96];
        let m = 0.9f32;
        for step in 0..3 {
            let g = &vecs(100 + step, 96, 1)[0];
            dc_adaptive_step(&mut w, g, &v[2], &mut ms, 0.05, 1.0, m, MS_EPS);
        }
        let mut expect = vec![0.0f32; 96];
        for step in 0..3 {
            let g = &vecs(100 + step, 96, 1)[0];
            for (e, gi) in expect.iter_mut().zip(g) {
                *e = m * *e + (1.0 - m) * gi * gi;
            }
        }
        for (a, b) in ms.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn adaptive_matches_staged_compensation_bitwise() {
        // fused dc_adaptive_step == compensate_adaptive_into + sgd_step.
        // BITWISE: both evaluate the shared kernels::dca_comp core, so the
        // staged path cannot drift from the fused one (this was previously
        // a 1e-6-tolerance test over two hand-duplicated recurrences).
        let v = vecs(5, 200, 4);
        let (g, wb) = (&v[1], &v[2]);
        let ms0: Vec<f32> = v[3].iter().map(|x| x.abs()).collect();

        let mut w_fused = v[0].clone();
        let mut ms_fused = ms0.clone();
        dc_adaptive_step(&mut w_fused, g, wb, &mut ms_fused, 0.1, 2.0, 0.95, MS_EPS);

        let mut w_staged = v[0].clone();
        let mut ms_staged = ms0;
        let mut comp = vec![0.0; 200];
        compensate_adaptive_into(&mut comp, g, &w_staged, wb, &mut ms_staged, 2.0, 0.95, MS_EPS);
        sgd_step(&mut w_staged, &comp, 0.1);

        assert_eq!(w_fused, w_staged);
        assert_eq!(ms_fused, ms_staged);
    }

    #[test]
    fn momentum_accumulates_velocity() {
        let mut w = vec![0.0f32; 4];
        let mut v = vec![0.0f32; 4];
        let g = vec![1.0f32; 4];
        momentum_step(&mut w, &mut v, &g, 1.0, 0.5);
        assert_eq!(v, vec![1.0; 4]);
        assert_eq!(w, vec![-1.0; 4]);
        momentum_step(&mut w, &mut v, &g, 1.0, 0.5);
        assert_eq!(v, vec![1.5; 4]);
        assert_eq!(w, vec![-2.5; 4]);
    }

    #[test]
    fn average_into_means() {
        let g1 = vec![1.0f32, 2.0, 3.0];
        let g2 = vec![3.0f32, 2.0, 1.0];
        let g3 = vec![2.0f32, 2.0, 2.0];
        let mut out = vec![0.0; 3];
        average_into(&mut out, &[&g1, &g2, &g3]);
        assert_eq!(out, vec![2.0, 2.0, 2.0]);
    }

    #[test]
    fn compensate_into_matches_dc_step_bitwise() {
        let v = vecs(6, 150, 3);
        let (g, wb) = (&v[1], &v[2]);
        let mut w1 = v[0].clone();
        dc_step(&mut w1, g, wb, 0.1, 0.7);
        let mut comp = vec![0.0; 150];
        compensate_into(&mut comp, g, &v[0], wb, 0.7);
        let mut w2 = v[0].clone();
        sgd_step(&mut w2, &comp, 0.1);
        assert_eq!(w1, w2);
    }

    #[test]
    fn simd_kernels_match_scalar_reference_bitwise() {
        // the exhaustive tail/offset sweep lives in tests/kernels.rs; this
        // is the in-crate smoke version over one awkward odd length
        let n = 1003;
        let v = vecs(9, n, 4);
        let (g, wb) = (&v[1], &v[2]);
        let ms0: Vec<f32> = v[3].iter().map(|x| x.abs()).collect();

        let mut ws = v[0].clone();
        let mut wk = v[0].clone();
        sgd_step_scalar(&mut ws, g, 0.17);
        kernels::sgd_step_simd(&mut wk, g, 0.17);
        assert_eq!(ws, wk);

        let mut ws = v[0].clone();
        let mut wk = v[0].clone();
        dc_step_scalar(&mut ws, g, wb, 0.17, 1.3);
        kernels::dc_step_simd(&mut wk, g, wb, 0.17, 1.3);
        assert_eq!(ws, wk);

        let mut ws = v[0].clone();
        let mut wk = v[0].clone();
        let mut mss = ms0.clone();
        let mut msk = ms0.clone();
        dc_adaptive_step_scalar(&mut ws, g, wb, &mut mss, 0.1, 2.0, 0.95, MS_EPS);
        kernels::dc_adaptive_step_simd(&mut wk, g, wb, &mut msk, 0.1, 2.0, 0.95, MS_EPS);
        assert_eq!(ws, wk);
        assert_eq!(mss, msk);
    }

    #[test]
    fn sparse_steps_match_densified_dense_steps_bitwise() {
        // sparse kernels must be BIT-equal to the dense kernels on the
        // densified gradient (zeros at untransmitted coordinates)
        let v = vecs(8, 300, 3);
        let (w0, wb) = (&v[0], &v[2]);
        let idx: Vec<u32> = (0..300).filter(|i| i % 7 == 0).map(|i| i as u32).collect();
        let val: Vec<f32> = idx.iter().map(|&i| v[1][i as usize]).collect();
        let mut dense_g = vec![0.0f32; 300];
        for (&i, &x) in idx.iter().zip(&val) {
            dense_g[i as usize] = x;
        }

        let mut a = w0.clone();
        let mut b = w0.clone();
        sgd_step(&mut a, &dense_g, 0.3);
        sgd_step_sparse(&mut b, 0, &idx, &val, 0.3);
        assert_eq!(a, b);

        let mut a = w0.clone();
        let mut b = w0.clone();
        dc_step(&mut a, &dense_g, wb, 0.3, 1.7);
        dc_step_sparse(&mut b, wb, 0, &idx, &val, 0.3, 1.7);
        assert_eq!(a, b);
    }

    #[test]
    fn sparse_steps_respect_shard_base_offset() {
        // global indices [100, 105) applied to a shard starting at 100
        let mut w = vec![1.0f32; 5];
        let bak = vec![0.5f32; 5];
        let idx = [101u32, 103];
        let val = [2.0f32, -1.0];
        sgd_step_sparse(&mut w, 100, &idx, &val, 0.1);
        assert_eq!(w, vec![1.0, 0.8, 1.0, 1.1, 1.0]);
        dc_step_sparse(&mut w, &bak, 100, &idx, &val, 0.1, 0.0);
        assert_eq!(w, vec![1.0, 0.6, 1.0, 1.2, 1.0]);
    }

    #[test]
    fn sharded_application_equals_whole() {
        // applying dc_step shard-by-shard must equal one whole-vector pass
        let v = vecs(7, 1000, 3);
        let (g, wb) = (&v[1], &v[2]);
        let mut whole = v[0].clone();
        dc_step(&mut whole, g, wb, 0.05, 1.3);
        let mut sharded = v[0].clone();
        for (lo, hi) in [(0, 300), (300, 301), (301, 1000)] {
            dc_step(&mut sharded[lo..hi], &g[lo..hi], &wb[lo..hi], 0.05, 1.3);
        }
        assert_eq!(whole, sharded);
    }
}
