//! Update rules (paper §4 + appendix H), implemented twice:
//!
//! * **native**: fused slice loops in this module — the parameter server's
//!   hot path (bench `ps_throughput` ablates against the XLA path),
//! * **xla**: the AOT-compiled Pallas kernels, dispatched via
//!   [`crate::runtime`] when `UpdateBackend::Xla` is selected.
//!
//! All functions operate on sub-slices so the sharded store can apply them
//! per-shard in parallel. They are written as single fused passes: each
//! element of every operand is touched exactly once (bytes moved =
//! theoretical minimum), mirroring the Pallas kernels' structure.

pub mod dcssgd;

pub use dcssgd::DcSsgdAccumulator;

/// Plain SGD: `w -= lr * g`.
pub fn sgd_step(w: &mut [f32], g: &[f32], lr: f32) {
    debug_assert_eq!(w.len(), g.len());
    for (wi, gi) in w.iter_mut().zip(g) {
        *wi -= lr * gi;
    }
}

/// Heavy-ball momentum: `v = mu*v + g; w -= lr*v`.
pub fn momentum_step(w: &mut [f32], v: &mut [f32], g: &[f32], lr: f32, mu: f32) {
    debug_assert_eq!(w.len(), g.len());
    debug_assert_eq!(w.len(), v.len());
    for ((wi, vi), gi) in w.iter_mut().zip(v.iter_mut()).zip(g) {
        *vi = mu * *vi + gi;
        *wi -= lr * *vi;
    }
}

/// DC-ASGD-c (Eqn. 10): `w -= lr * (g + lam * g⊙g⊙(w - w_bak))`.
///
/// `w` is the *current* global model; `w_bak` is the snapshot the worker
/// pulled. Single fused pass.
pub fn dc_step(w: &mut [f32], g: &[f32], w_bak: &[f32], lr: f32, lam: f32) {
    debug_assert_eq!(w.len(), g.len());
    debug_assert_eq!(w.len(), w_bak.len());
    for ((wi, gi), bi) in w.iter_mut().zip(g).zip(w_bak) {
        let delta = *wi - bi;
        *wi -= lr * (gi + lam * gi * gi * delta);
    }
}

/// DC-ASGD-a (Eqn. 10 + Eqn. 14): MeanSquare-normalized lambda.
///
/// `ms = m*ms + (1-m)*g⊙g; lam_t = lam0/sqrt(ms + eps)` elementwise.
pub fn dc_adaptive_step(
    w: &mut [f32],
    g: &[f32],
    w_bak: &[f32],
    ms: &mut [f32],
    lr: f32,
    lam0: f32,
    m: f32,
    eps: f32,
) {
    debug_assert_eq!(w.len(), g.len());
    debug_assert_eq!(w.len(), w_bak.len());
    debug_assert_eq!(w.len(), ms.len());
    let one_minus_m = 1.0 - m;
    for (((wi, gi), bi), msi) in w.iter_mut().zip(g).zip(w_bak).zip(ms.iter_mut()) {
        let g2 = gi * gi;
        let ms_new = m * *msi + one_minus_m * g2;
        *msi = ms_new;
        let lam_t = lam0 / (ms_new + eps).sqrt();
        let delta = *wi - bi;
        *wi -= lr * (gi + lam_t * g2 * delta);
    }
}

/// Delay-compensated gradient *without* applying it (used by DC-SSGD and by
/// momentum composition): `out = g + lam * g⊙g⊙(w - w_bak)`.
pub fn compensate_into(out: &mut [f32], g: &[f32], w: &[f32], w_bak: &[f32], lam: f32) {
    debug_assert_eq!(out.len(), g.len());
    for (((oi, gi), wi), bi) in out.iter_mut().zip(g).zip(w).zip(w_bak) {
        *oi = gi + lam * gi * gi * (wi - bi);
    }
}

/// Adaptive-lambda compensation into a buffer (updates `ms`).
pub fn compensate_adaptive_into(
    out: &mut [f32],
    g: &[f32],
    w: &[f32],
    w_bak: &[f32],
    ms: &mut [f32],
    lam0: f32,
    m: f32,
    eps: f32,
) {
    let one_minus_m = 1.0 - m;
    for ((((oi, gi), wi), bi), msi) in
        out.iter_mut().zip(g).zip(w).zip(w_bak).zip(ms.iter_mut())
    {
        let g2 = gi * gi;
        let ms_new = m * *msi + one_minus_m * g2;
        *msi = ms_new;
        let lam_t = lam0 / (ms_new + eps).sqrt();
        *oi = gi + lam_t * g2 * (wi - bi);
    }
}

/// Sparse SGD on one shard slice: for each pair `(i, v)` with global index
/// `i` inside the shard that starts at `base`, `w[i - base] -= lr * v`.
/// Identical f32 ops (in ascending-index order) to [`sgd_step`] on the
/// densified gradient — untouched coordinates are exactly unchanged there
/// too (`x - lr * 0.0 == x`), so sparse and dense applies are bit-equal.
pub fn sgd_step_sparse(w: &mut [f32], base: usize, idx: &[u32], val: &[f32], lr: f32) {
    debug_assert_eq!(idx.len(), val.len());
    for (&i, &v) in idx.iter().zip(val) {
        w[i as usize - base] -= lr * v;
    }
}

/// Sparse DC-ASGD-c (Eqn. 10) on one shard slice: compensation against the
/// worker's backup only at the transmitted coordinates. Bit-equal to
/// [`dc_step`] on the densified gradient (a zero gradient element
/// contributes `0 + lam * 0 * delta = 0` there).
pub fn dc_step_sparse(
    w: &mut [f32],
    w_bak: &[f32],
    base: usize,
    idx: &[u32],
    val: &[f32],
    lr: f32,
    lam: f32,
) {
    debug_assert_eq!(w.len(), w_bak.len());
    debug_assert_eq!(idx.len(), val.len());
    for (&i, &v) in idx.iter().zip(val) {
        let j = i as usize - base;
        let delta = w[j] - w_bak[j];
        w[j] -= lr * (v + lam * v * v * delta);
    }
}

/// Average equal-length gradient rows into `out` (SSGD). Generic over the
/// row type (`&[f32]`, `Vec<f32>`, ...) so callers with owned arenas don't
/// build a vector of slice refs; the f32 accumulation order (copy row 0,
/// add the rest, scale) is part of the repo's determinism contract.
pub fn average_into<G: AsRef<[f32]>>(out: &mut [f32], grads: &[G]) {
    assert!(!grads.is_empty());
    let inv = 1.0 / grads.len() as f32;
    out.copy_from_slice(grads[0].as_ref());
    for g in &grads[1..] {
        let g = g.as_ref();
        debug_assert_eq!(g.len(), out.len());
        for (oi, gi) in out.iter_mut().zip(g.iter()) {
            *oi += gi;
        }
    }
    for oi in out.iter_mut() {
        *oi *= inv;
    }
}

/// Default epsilon inside the MeanSquare sqrt (paper: 1e-7).
pub const MS_EPS: f32 = 1e-7;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn vecs(seed: u64, n: usize, k: usize) -> Vec<Vec<f32>> {
        let mut rng = Pcg64::new(seed);
        (0..k).map(|_| (0..n).map(|_| rng.normal(0.0, 1.0) as f32).collect()).collect()
    }

    #[test]
    fn sgd_matches_scalar_math() {
        let mut w = vec![1.0, -2.0, 0.5];
        sgd_step(&mut w, &[0.5, 0.5, -1.0], 0.1);
        assert_eq!(w, vec![0.95, -2.05, 0.6]);
    }

    #[test]
    fn dc_step_matches_formula_elementwise() {
        let v = vecs(1, 257, 3);
        let (g, wb) = (&v[1], &v[2]);
        let mut w = v[0].clone();
        let (lr, lam) = (0.1f32, 0.7f32);
        let expect: Vec<f32> = v[0]
            .iter()
            .zip(g)
            .zip(wb)
            .map(|((wi, gi), bi)| wi - lr * (gi + lam * gi * gi * (wi - bi)))
            .collect();
        dc_step(&mut w, g, wb, lr, lam);
        for (a, b) in w.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn dc_with_lambda_zero_is_sgd() {
        let v = vecs(2, 128, 3);
        let mut w1 = v[0].clone();
        let mut w2 = v[0].clone();
        dc_step(&mut w1, &v[1], &v[2], 0.3, 0.0);
        sgd_step(&mut w2, &v[1], 0.3);
        assert_eq!(w1, w2);
    }

    #[test]
    fn dc_with_zero_delay_is_sgd() {
        let v = vecs(3, 64, 2);
        let mut w1 = v[0].clone();
        let mut w2 = v[0].clone();
        let bak = v[0].clone();
        dc_step(&mut w1, &v[1], &bak, 0.2, 5.0);
        sgd_step(&mut w2, &v[1], 0.2);
        assert_eq!(w1, w2);
    }

    #[test]
    fn adaptive_meansquare_recursion() {
        let v = vecs(4, 96, 4);
        let mut w = v[0].clone();
        let mut ms = vec![0.0; 96];
        let m = 0.9f32;
        for step in 0..3 {
            let g = &vecs(100 + step, 96, 1)[0];
            dc_adaptive_step(&mut w, g, &v[2], &mut ms, 0.05, 1.0, m, MS_EPS);
        }
        let mut expect = vec![0.0f32; 96];
        for step in 0..3 {
            let g = &vecs(100 + step, 96, 1)[0];
            for (e, gi) in expect.iter_mut().zip(g) {
                *e = m * *e + (1.0 - m) * gi * gi;
            }
        }
        for (a, b) in ms.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn adaptive_matches_staged_compensation() {
        // fused dc_adaptive_step == compensate_adaptive_into + sgd_step
        let v = vecs(5, 200, 4);
        let (g, wb) = (&v[1], &v[2]);
        let ms0: Vec<f32> = v[3].iter().map(|x| x.abs()).collect();

        let mut w_fused = v[0].clone();
        let mut ms_fused = ms0.clone();
        dc_adaptive_step(&mut w_fused, g, wb, &mut ms_fused, 0.1, 2.0, 0.95, MS_EPS);

        let mut w_staged = v[0].clone();
        let mut ms_staged = ms0;
        let mut comp = vec![0.0; 200];
        compensate_adaptive_into(&mut comp, g, &w_staged, wb, &mut ms_staged, 2.0, 0.95, MS_EPS);
        sgd_step(&mut w_staged, &comp, 0.1);

        for (a, b) in w_fused.iter().zip(&w_staged) {
            assert!((a - b).abs() < 1e-6);
        }
        assert_eq!(ms_fused, ms_staged);
    }

    #[test]
    fn momentum_accumulates_velocity() {
        let mut w = vec![0.0f32; 4];
        let mut v = vec![0.0f32; 4];
        let g = vec![1.0f32; 4];
        momentum_step(&mut w, &mut v, &g, 1.0, 0.5);
        assert_eq!(v, vec![1.0; 4]);
        assert_eq!(w, vec![-1.0; 4]);
        momentum_step(&mut w, &mut v, &g, 1.0, 0.5);
        assert_eq!(v, vec![1.5; 4]);
        assert_eq!(w, vec![-2.5; 4]);
    }

    #[test]
    fn average_into_means() {
        let g1 = vec![1.0f32, 2.0, 3.0];
        let g2 = vec![3.0f32, 2.0, 1.0];
        let g3 = vec![2.0f32, 2.0, 2.0];
        let mut out = vec![0.0; 3];
        average_into(&mut out, &[&g1, &g2, &g3]);
        assert_eq!(out, vec![2.0, 2.0, 2.0]);
    }

    #[test]
    fn compensate_into_matches_dc_step() {
        let v = vecs(6, 150, 3);
        let (g, wb) = (&v[1], &v[2]);
        let mut w1 = v[0].clone();
        dc_step(&mut w1, g, wb, 0.1, 0.7);
        let mut comp = vec![0.0; 150];
        compensate_into(&mut comp, g, &v[0], wb, 0.7);
        let mut w2 = v[0].clone();
        sgd_step(&mut w2, &comp, 0.1);
        for (a, b) in w1.iter().zip(&w2) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn sparse_steps_match_densified_dense_steps_bitwise() {
        // sparse kernels must be BIT-equal to the dense kernels on the
        // densified gradient (zeros at untransmitted coordinates)
        let v = vecs(8, 300, 3);
        let (w0, wb) = (&v[0], &v[2]);
        let idx: Vec<u32> = (0..300).filter(|i| i % 7 == 0).map(|i| i as u32).collect();
        let val: Vec<f32> = idx.iter().map(|&i| v[1][i as usize]).collect();
        let mut dense_g = vec![0.0f32; 300];
        for (&i, &x) in idx.iter().zip(&val) {
            dense_g[i as usize] = x;
        }

        let mut a = w0.clone();
        let mut b = w0.clone();
        sgd_step(&mut a, &dense_g, 0.3);
        sgd_step_sparse(&mut b, 0, &idx, &val, 0.3);
        assert_eq!(a, b);

        let mut a = w0.clone();
        let mut b = w0.clone();
        dc_step(&mut a, &dense_g, wb, 0.3, 1.7);
        dc_step_sparse(&mut b, wb, 0, &idx, &val, 0.3, 1.7);
        assert_eq!(a, b);
    }

    #[test]
    fn sparse_steps_respect_shard_base_offset() {
        // global indices [100, 105) applied to a shard starting at 100
        let mut w = vec![1.0f32; 5];
        let bak = vec![0.5f32; 5];
        let idx = [101u32, 103];
        let val = [2.0f32, -1.0];
        sgd_step_sparse(&mut w, 100, &idx, &val, 0.1);
        assert_eq!(w, vec![1.0, 0.8, 1.0, 1.1, 1.0]);
        dc_step_sparse(&mut w, &bak, 100, &idx, &val, 0.1, 0.0);
        assert_eq!(w, vec![1.0, 0.6, 1.0, 1.2, 1.0]);
    }

    #[test]
    fn sharded_application_equals_whole() {
        // applying dc_step shard-by-shard must equal one whole-vector pass
        let v = vecs(7, 1000, 3);
        let (g, wb) = (&v[1], &v[2]);
        let mut whole = v[0].clone();
        dc_step(&mut whole, g, wb, 0.05, 1.3);
        let mut sharded = v[0].clone();
        for (lo, hi) in [(0, 300), (300, 301), (301, 1000)] {
            dc_step(&mut sharded[lo..hi], &g[lo..hi], &wb[lo..hi], 0.05, 1.3);
        }
        assert_eq!(whole, sharded);
    }
}
