//! Chunked-SIMD update kernels + the shared elementwise cores.
//!
//! ## The f32 op-order contract
//!
//! Every update rule in this crate is **per-element independent**: element
//! `i` of the output depends only on element `i` of each operand, and the
//! expression tree evaluated per element is fixed (one source of truth:
//! [`dc_comp`] / [`dca_comp`] below, shared by the fused steps, the staged
//! `compensate_*` paths, and the sparse kernels). The vectorized kernels in
//! this module therefore produce **bit-identical** results to the scalar
//! reference loops:
//!
//! * chunking changes only the traversal *grouping*, never the per-element
//!   operation order — elements never interact, so there is no
//!   reassociation of f32 arithmetic anywhere;
//! * every primitive involved (`+`, `-`, `*`, `/`, `sqrt`) is required by
//!   IEEE 754 to be correctly rounded in both scalar and packed forms, so
//!   a lane of a vector op returns the same bits as the scalar op.
//!
//! This is *not* true of reductions (a vectorized sum reassociates), which
//! is why the only reduction on the hot path — QSGD's max-abs norm — uses
//! `max`, whose fold is order-independent for non-NaN inputs.
//!
//! The kernels are written as chunked loops over fixed-size windows with
//! scalar remainder tails ("autovectorization-friendly" rather than
//! `std::simd`, which is not on stable). `chunks_exact` gives LLVM a
//! compile-time trip count, so the inner loops compile to packed
//! `mulps`/`sqrtps`/`divps` on every x86-64 target.
//!
//! ## Dispatch
//!
//! The public wrappers in [`crate::optim`] pick between these kernels and
//! the `*_scalar` reference loops via [`simd_enabled`]: a process-global
//! switch set from the `[runtime] simd` config knob (`--simd false` on the
//! CLI) and compiled out entirely when the crate's `simd` cargo feature is
//! disabled. Because both sides are bit-identical (pinned by the
//! `tests/kernels.rs` property suite), the switch trades wallclock only —
//! it exists for A/B measurement and as the serial reference lane in CI.

use std::sync::atomic::{AtomicBool, Ordering};

/// Elements per vectorized chunk: one AVX register of f32 (and exactly two
/// SSE registers), matching the widest unit stable rustc targets by default.
pub const LANES: usize = 8;

/// Process-global kernel dispatch: `true` = chunked-SIMD kernels, `false` =
/// scalar reference loops. Compiled to `false` permanently when the `simd`
/// cargo feature is off.
static SIMD_ENABLED: AtomicBool = AtomicBool::new(cfg!(feature = "simd"));

/// Flip the kernel dispatch (the `[runtime] simd` knob). A no-op toward
/// `true` when the `simd` cargo feature is compiled out. Safe to call from
/// anywhere at any time: both dispatch targets are bit-identical, so a
/// concurrent flip is unobservable in results.
pub fn set_simd_enabled(on: bool) {
    SIMD_ENABLED.store(on && cfg!(feature = "simd"), Ordering::Relaxed);
}

/// Current kernel dispatch (also gates the fused decode→apply and the
/// streaming codec paths in [`crate::compress`]).
pub fn simd_enabled() -> bool {
    SIMD_ENABLED.load(Ordering::Relaxed)
}

// ---------------------------------------------------------------------------
// shared elementwise cores
//
// The single source of truth for the delay-compensation math: the fused
// steps, the staged compensate_* buffers, and the sparse kernels all
// evaluate exactly these expression trees (so they cannot drift apart, and
// fused == staged holds bitwise).

/// One element of the constant-lambda compensated gradient (Eqn. 10):
/// `g + lam * g^2 * (w - w_bak)`.
#[inline(always)]
pub fn dc_comp(gi: f32, wi: f32, bi: f32, lam: f32) -> f32 {
    gi + lam * gi * gi * (wi - bi)
}

/// One element of the adaptive-lambda recurrence (Eqn. 10 + Eqn. 14):
/// advances the MeanSquare state in place and returns the compensated
/// gradient. `one_minus_m` is hoisted by the callers (`1.0 - m`) so every
/// call site rounds it identically.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
pub fn dca_comp(
    gi: f32,
    wi: f32,
    bi: f32,
    msi: &mut f32,
    lam0: f32,
    m: f32,
    one_minus_m: f32,
    eps: f32,
) -> f32 {
    let g2 = gi * gi;
    let ms_new = m * *msi + one_minus_m * g2;
    *msi = ms_new;
    let lam_t = lam0 / (ms_new + eps).sqrt();
    gi + lam_t * g2 * (wi - bi)
}

// ---------------------------------------------------------------------------
// chunked-SIMD kernels (scalar tails)

/// Chunked [`crate::optim::sgd_step`]: `w -= lr * g`.
pub fn sgd_step_simd(w: &mut [f32], g: &[f32], lr: f32) {
    debug_assert_eq!(w.len(), g.len());
    let head = w.len() - w.len() % LANES;
    let (wv, wt) = w.split_at_mut(head);
    let (gv, gt) = g.split_at(head);
    for (wc, gc) in wv.chunks_exact_mut(LANES).zip(gv.chunks_exact(LANES)) {
        for j in 0..LANES {
            wc[j] -= lr * gc[j];
        }
    }
    for (wi, gi) in wt.iter_mut().zip(gt) {
        *wi -= lr * gi;
    }
}

/// Chunked [`crate::optim::momentum_step`]: `v = mu*v + g; w -= lr*v`.
pub fn momentum_step_simd(w: &mut [f32], v: &mut [f32], g: &[f32], lr: f32, mu: f32) {
    debug_assert_eq!(w.len(), g.len());
    debug_assert_eq!(w.len(), v.len());
    let head = w.len() - w.len() % LANES;
    let (wv, wt) = w.split_at_mut(head);
    let (vv, vt) = v.split_at_mut(head);
    let (gv, gt) = g.split_at(head);
    for ((wc, vc), gc) in
        wv.chunks_exact_mut(LANES).zip(vv.chunks_exact_mut(LANES)).zip(gv.chunks_exact(LANES))
    {
        for j in 0..LANES {
            vc[j] = mu * vc[j] + gc[j];
            wc[j] -= lr * vc[j];
        }
    }
    for ((wi, vi), gi) in wt.iter_mut().zip(vt.iter_mut()).zip(gt) {
        *vi = mu * *vi + gi;
        *wi -= lr * *vi;
    }
}

/// Chunked [`crate::optim::dc_step`] (Eqn. 10).
pub fn dc_step_simd(w: &mut [f32], g: &[f32], w_bak: &[f32], lr: f32, lam: f32) {
    debug_assert_eq!(w.len(), g.len());
    debug_assert_eq!(w.len(), w_bak.len());
    let head = w.len() - w.len() % LANES;
    let (wv, wt) = w.split_at_mut(head);
    let (gv, gt) = g.split_at(head);
    let (bv, bt) = w_bak.split_at(head);
    for ((wc, gc), bc) in
        wv.chunks_exact_mut(LANES).zip(gv.chunks_exact(LANES)).zip(bv.chunks_exact(LANES))
    {
        for j in 0..LANES {
            wc[j] -= lr * dc_comp(gc[j], wc[j], bc[j], lam);
        }
    }
    for ((wi, gi), bi) in wt.iter_mut().zip(gt).zip(bt) {
        *wi -= lr * dc_comp(*gi, *wi, *bi, lam);
    }
}

/// Chunked [`crate::optim::dc_adaptive_step`] (Eqn. 10 + 14). The packed
/// `sqrtps`/`divps` this compiles to are the kernel family's biggest win:
/// the scalar loop is latency-bound on the per-element sqrt.
#[allow(clippy::too_many_arguments)]
pub fn dc_adaptive_step_simd(
    w: &mut [f32],
    g: &[f32],
    w_bak: &[f32],
    ms: &mut [f32],
    lr: f32,
    lam0: f32,
    m: f32,
    eps: f32,
) {
    debug_assert_eq!(w.len(), g.len());
    debug_assert_eq!(w.len(), w_bak.len());
    debug_assert_eq!(w.len(), ms.len());
    let one_minus_m = 1.0 - m;
    let head = w.len() - w.len() % LANES;
    let (wv, wt) = w.split_at_mut(head);
    let (gv, gt) = g.split_at(head);
    let (bv, bt) = w_bak.split_at(head);
    let (mv, mt) = ms.split_at_mut(head);
    for (((wc, gc), bc), mc) in wv
        .chunks_exact_mut(LANES)
        .zip(gv.chunks_exact(LANES))
        .zip(bv.chunks_exact(LANES))
        .zip(mv.chunks_exact_mut(LANES))
    {
        for j in 0..LANES {
            let comp = dca_comp(gc[j], wc[j], bc[j], &mut mc[j], lam0, m, one_minus_m, eps);
            wc[j] -= lr * comp;
        }
    }
    for (((wi, gi), bi), msi) in wt.iter_mut().zip(gt).zip(bt).zip(mt.iter_mut()) {
        let comp = dca_comp(*gi, *wi, *bi, msi, lam0, m, one_minus_m, eps);
        *wi -= lr * comp;
    }
}

/// Chunked [`crate::optim::compensate_into`].
pub fn compensate_into_simd(out: &mut [f32], g: &[f32], w: &[f32], w_bak: &[f32], lam: f32) {
    debug_assert_eq!(out.len(), g.len());
    debug_assert_eq!(out.len(), w.len());
    debug_assert_eq!(out.len(), w_bak.len());
    let head = out.len() - out.len() % LANES;
    let (ov, ot) = out.split_at_mut(head);
    let (gv, gt) = g.split_at(head);
    let (wv, wt) = w.split_at(head);
    let (bv, bt) = w_bak.split_at(head);
    for (((oc, gc), wc), bc) in ov
        .chunks_exact_mut(LANES)
        .zip(gv.chunks_exact(LANES))
        .zip(wv.chunks_exact(LANES))
        .zip(bv.chunks_exact(LANES))
    {
        for j in 0..LANES {
            oc[j] = dc_comp(gc[j], wc[j], bc[j], lam);
        }
    }
    for (((oi, gi), wi), bi) in ot.iter_mut().zip(gt).zip(wt).zip(bt) {
        *oi = dc_comp(*gi, *wi, *bi, lam);
    }
}

/// Chunked [`crate::optim::compensate_adaptive_into`] (updates `ms`).
#[allow(clippy::too_many_arguments)]
pub fn compensate_adaptive_into_simd(
    out: &mut [f32],
    g: &[f32],
    w: &[f32],
    w_bak: &[f32],
    ms: &mut [f32],
    lam0: f32,
    m: f32,
    eps: f32,
) {
    debug_assert_eq!(out.len(), g.len());
    debug_assert_eq!(out.len(), ms.len());
    let one_minus_m = 1.0 - m;
    let head = out.len() - out.len() % LANES;
    let (ov, ot) = out.split_at_mut(head);
    let (gv, gt) = g.split_at(head);
    let (wv, wt) = w.split_at(head);
    let (bv, bt) = w_bak.split_at(head);
    let (mv, mt) = ms.split_at_mut(head);
    for ((((oc, gc), wc), bc), mc) in ov
        .chunks_exact_mut(LANES)
        .zip(gv.chunks_exact(LANES))
        .zip(wv.chunks_exact(LANES))
        .zip(bv.chunks_exact(LANES))
        .zip(mv.chunks_exact_mut(LANES))
    {
        for j in 0..LANES {
            oc[j] = dca_comp(gc[j], wc[j], bc[j], &mut mc[j], lam0, m, one_minus_m, eps);
        }
    }
    for ((((oi, gi), wi), bi), msi) in
        ot.iter_mut().zip(gt).zip(wt).zip(bt).zip(mt.iter_mut())
    {
        *oi = dca_comp(*gi, *wi, *bi, msi, lam0, m, one_minus_m, eps);
    }
}
