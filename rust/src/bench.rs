//! Micro/meso benchmark harness (criterion is not in the offline crate
//! set): warmup + timed iterations + robust summary stats, plus an aligned
//! table printer the paper-reproduction benches share.

use crate::util::stats::{percentile, Running};
use std::time::Instant;

/// Timing summary of one benchmark case.
#[derive(Clone, Debug)]
pub struct Summary {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub min_s: f64,
}

impl Summary {
    pub fn per_sec(&self) -> f64 {
        if self.mean_s > 0.0 {
            1.0 / self.mean_s
        } else {
            f64::INFINITY
        }
    }

    pub fn print(&self) {
        println!(
            "{:<40} {:>10} {:>12} {:>12} {:>12} {:>14}",
            self.name,
            self.iters,
            fmt_time(self.mean_s),
            fmt_time(self.p50_s),
            fmt_time(self.p99_s),
            format!("{:.1}/s", self.per_sec()),
        );
    }
}

pub fn header() {
    println!(
        "{:<40} {:>10} {:>12} {:>12} {:>12} {:>14}",
        "benchmark", "iters", "mean", "p50", "p99", "throughput"
    );
    println!("{}", "-".repeat(104));
}

fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// Time `f` for `iters` iterations after `warmup` warmup calls.
pub fn time_fn<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Summary {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    let mut run = Running::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed().as_secs_f64();
        samples.push(dt);
        run.push(dt);
    }
    Summary {
        name: name.to_string(),
        iters,
        mean_s: run.mean(),
        std_s: run.std(),
        p50_s: percentile(&samples, 50.0),
        p99_s: percentile(&samples, 99.0),
        min_s: run.min(),
    }
}

/// Time `f` adaptively: run batches until `target_secs` of samples exist
/// (good for sub-microsecond bodies where per-call Instant overhead bites).
pub fn time_batched<F: FnMut()>(name: &str, target_secs: f64, mut f: F) -> Summary {
    // calibrate batch size to ~1ms per batch
    let mut batch = 1usize;
    loop {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        let dt = t0.elapsed().as_secs_f64();
        if dt > 1e-3 || batch >= 1 << 20 {
            break;
        }
        batch *= 4;
    }
    let mut samples = Vec::new();
    let mut run = Running::new();
    let t_total = Instant::now();
    while t_total.elapsed().as_secs_f64() < target_secs {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        let per = t0.elapsed().as_secs_f64() / batch as f64;
        samples.push(per);
        run.push(per);
    }
    Summary {
        name: name.to_string(),
        iters: samples.len() * batch,
        mean_s: run.mean(),
        std_s: run.std(),
        p50_s: percentile(&samples, 50.0),
        p99_s: percentile(&samples, 99.0),
        min_s: run.min(),
    }
}

/// Aligned table printer for paper-style result tables.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let parts: Vec<String> =
                cells.iter().enumerate().map(|(i, c)| format!("{:>w$}", c, w = widths[i])).collect();
            println!("| {} |", parts.join(" | "));
        };
        line(&self.headers);
        println!(
            "|{}|",
            widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|")
        );
        for row in &self.rows {
            line(row);
        }
    }

    /// Also emit CSV (benches drop these next to the binary for plotting).
    pub fn write_csv(&self, path: &std::path::Path) -> std::io::Result<()> {
        use std::io::Write;
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(f, "{}", self.headers.join(","))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join(","))?;
        }
        Ok(())
    }
}

/// Standard output directory for bench CSVs.
pub fn bench_out_dir() -> std::path::PathBuf {
    let dir = std::path::PathBuf::from("runs/bench");
    std::fs::create_dir_all(&dir).ok();
    dir
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_measures_something() {
        let s = time_fn("spin", 2, 20, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(s.iters, 20);
        assert!(s.mean_s > 0.0);
        assert!(s.p50_s <= s.p99_s);
        assert!(s.min_s <= s.mean_s * 2.0);
    }

    #[test]
    fn batched_timer_runs() {
        let s = time_batched("tiny", 0.05, || {
            std::hint::black_box(1 + 1);
        });
        assert!(s.iters > 100);
        assert!(s.mean_s < 1e-3);
    }

    #[test]
    fn table_prints_and_saves() {
        let mut t = Table::new(&["# workers", "algorithm", "error(%)"]);
        t.row(&["4".into(), "asgd".into(), "9.27".into()]);
        t.row(&["4".into(), "dc-asgd-a".into(), "8.19".into()]);
        t.print();
        let path = std::env::temp_dir().join(format!("dcasgd_tbl_{}.csv", std::process::id()));
        t.write_csv(&path).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.starts_with("# workers,algorithm,error(%)"));
        assert_eq!(body.lines().count(), 3);
        std::fs::remove_file(&path).ok();
    }
}
