//! The parameter server (paper Algorithm 2).
//!
//! Owns the global model `w`, per-worker backup models `w_bak(m)`, the
//! MeanSquare state (DC-ASGD-a), version/staleness accounting, and the
//! update-rule dispatch. Thread-safe: the async coordinator calls `pull` /
//! `push` from M worker threads concurrently.

pub mod checkpoint;
pub mod shard;

pub use checkpoint::{check_ef_compat, Checkpoint};
pub use shard::{ShardData, ShardedStore, SnapshotMeta, SnapshotPlane};

use crate::config::{Algorithm, UpdateBackend};
use crate::optim;
use std::sync::atomic::{AtomicU64, Ordering};

/// Pluggable update executor: native slice loops (default) or the
/// AOT-compiled XLA/Pallas artifacts (`runtime::XlaUpdateKernel`).
pub trait UpdateKernel: Send + Sync {
    fn sgd(&self, w: &mut [f32], g: &[f32], lr: f32);
    fn dc(&self, w: &mut [f32], g: &[f32], w_bak: &[f32], lr: f32, lam: f32);
    #[allow(clippy::too_many_arguments)]
    fn dca(
        &self,
        w: &mut [f32],
        g: &[f32],
        w_bak: &[f32],
        ms: &mut [f32],
        lr: f32,
        lam0: f32,
        m: f32,
        eps: f32,
    );
    /// True if the kernel must see the whole vector at once (XLA artifacts
    /// are compiled for the full padded length → shards must be 1).
    fn requires_whole_vector(&self) -> bool {
        false
    }
    /// True if this kernel *is* the native elementwise math bit-for-bit
    /// (i.e. delegates to [`crate::optim`] unchanged). Gates the fused
    /// quantized decode→compensate→apply fast path: fusing decodes levels
    /// in blocks and applies the native rule per block, so it is only valid
    /// when the kernel would have computed exactly the native expressions
    /// anyway. Custom and whole-vector kernels keep the densified path.
    fn is_native_elementwise(&self) -> bool {
        false
    }
    /// Sparse variants for compressed pushes ([`Self::sgd`]/[`Self::dc`]
    /// restricted to the transmitted coordinates). Defaults delegate to
    /// the fused native loops so any elementwise kernel stays consistent
    /// between dense and compressed pushes; whole-vector kernels never see
    /// them (`push_encoded` rejects `requires_whole_vector`).
    fn sgd_sparse(&self, w: &mut [f32], base: usize, idx: &[u32], val: &[f32], lr: f32) {
        optim::sgd_step_sparse(w, base, idx, val, lr);
    }
    #[allow(clippy::too_many_arguments)]
    fn dc_sparse(
        &self,
        w: &mut [f32],
        w_bak: &[f32],
        base: usize,
        idx: &[u32],
        val: &[f32],
        lr: f32,
        lam: f32,
    ) {
        optim::dc_step_sparse(w, w_bak, base, idx, val, lr, lam);
    }
    fn name(&self) -> &'static str;
}

/// Fused native loops from [`crate::optim`].
pub struct NativeKernel;

impl UpdateKernel for NativeKernel {
    fn sgd(&self, w: &mut [f32], g: &[f32], lr: f32) {
        optim::sgd_step(w, g, lr);
    }
    fn dc(&self, w: &mut [f32], g: &[f32], w_bak: &[f32], lr: f32, lam: f32) {
        optim::dc_step(w, g, w_bak, lr, lam);
    }
    fn dca(
        &self,
        w: &mut [f32],
        g: &[f32],
        w_bak: &[f32],
        ms: &mut [f32],
        lr: f32,
        lam0: f32,
        m: f32,
        eps: f32,
    ) {
        optim::dc_adaptive_step(w, g, w_bak, ms, lr, lam0, m, eps);
    }
    fn is_native_elementwise(&self) -> bool {
        true
    }
    fn name(&self) -> &'static str {
        "native"
    }
}

/// Hyper-parameters of the update rule (fixed per run; lr varies per push).
#[derive(Clone, Copy, Debug)]
pub struct Hyper {
    pub lambda0: f32,
    pub ms_momentum: f32,
    pub momentum: f32,
    pub eps: f32,
}

impl Hyper {
    pub fn from_config(cfg: &crate::config::ExperimentConfig) -> Self {
        Self {
            lambda0: cfg.lambda0 as f32,
            ms_momentum: cfg.ms_momentum as f32,
            momentum: cfg.momentum as f32,
            eps: optim::MS_EPS,
        }
    }
}

/// Result of one push: the global step it became and the delay it suffered.
#[derive(Clone, Copy, Debug)]
pub struct PushOutcome {
    /// Global model version after this update (t+1 in paper notation).
    pub version: u64,
    /// tau: global updates applied between this worker's pull and its push.
    pub staleness: u64,
}

/// The parameter server.
pub struct ParamServer {
    store: ShardedStore,
    algo: Algorithm,
    hyper: Hyper,
    kernel: Box<dyn UpdateKernel>,
    /// Global update counter t.
    version: AtomicU64,
    /// Version at each worker's last pull.
    pull_version: Vec<AtomicU64>,
    /// Pulls served per worker (diagnostic gate/churn accounting).
    pull_count: Vec<AtomicU64>,
    /// Scratch buffers for the whole-vector (XLA) path.
    whole_scratch: std::sync::Mutex<WholeScratch>,
    /// Reusable per-worker dense buffers for decoding quantized /
    /// densified payloads on the encoded push path (sized lazily, then
    /// steady-state). Per-worker like `w_bak(m)`: concurrent compressed
    /// pushes never serialize on a shared decode arena.
    decode_scratch: Vec<std::sync::Mutex<Vec<f32>>>,
}

#[derive(Default)]
struct WholeScratch {
    w: Vec<f32>,
    bak: Vec<f32>,
    ms: Vec<f32>,
}

impl ParamServer {
    /// Build against the process-shared compute pool (auto lane count).
    pub fn new(
        init: &[f32],
        workers: usize,
        shards: usize,
        algo: Algorithm,
        hyper: Hyper,
        kernel: Box<dyn UpdateKernel>,
    ) -> anyhow::Result<Self> {
        Self::with_pool(
            init,
            workers,
            shards,
            algo,
            hyper,
            kernel,
            std::sync::Arc::clone(crate::util::pool::shared()),
        )
    }

    /// Build against an explicit compute pool (the `[runtime] threads`
    /// knob); the pool serves multi-shard applies and `store_w`.
    #[allow(clippy::too_many_arguments)]
    pub fn with_pool(
        init: &[f32],
        workers: usize,
        shards: usize,
        algo: Algorithm,
        hyper: Hyper,
        kernel: Box<dyn UpdateKernel>,
        pool: std::sync::Arc<crate::util::pool::ComputePool>,
    ) -> anyhow::Result<Self> {
        if kernel.requires_whole_vector() && shards != 1 {
            anyhow::bail!(
                "update backend {:?} operates on the whole vector: set shards = 1",
                kernel.name()
            );
        }
        if hyper.momentum > 0.0 && kernel.requires_whole_vector() {
            anyhow::bail!("momentum variants are only supported by the native backend");
        }
        Ok(Self {
            store: ShardedStore::with_pool(init, workers, shards, pool),
            algo,
            hyper,
            kernel,
            version: AtomicU64::new(0),
            pull_version: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            pull_count: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            whole_scratch: std::sync::Mutex::new(WholeScratch::default()),
            decode_scratch: (0..workers).map(|_| std::sync::Mutex::new(Vec::new())).collect(),
        })
    }

    pub fn from_config(
        cfg: &crate::config::ExperimentConfig,
        init: &[f32],
        kernel: Box<dyn UpdateKernel>,
    ) -> anyhow::Result<Self> {
        let pool = crate::util::pool::pool_for_threads(cfg.runtime.threads);
        Self::from_config_with_pool(cfg, init, kernel, pool)
    }

    /// Like [`Self::from_config`], but sharing an already-built pool (the
    /// trainer hands the same pool to the store and the driver's pipelined
    /// gradient stage, so one set of threads serves the whole run).
    pub fn from_config_with_pool(
        cfg: &crate::config::ExperimentConfig,
        init: &[f32],
        kernel: Box<dyn UpdateKernel>,
        pool: std::sync::Arc<crate::util::pool::ComputePool>,
    ) -> anyhow::Result<Self> {
        if cfg.update_backend == UpdateBackend::Xla && !kernel.requires_whole_vector() {
            log::warn!("config requests xla backend but a native kernel was supplied");
        }
        Self::with_pool(
            init,
            cfg.workers,
            cfg.shards,
            cfg.algorithm,
            Hyper::from_config(cfg),
            kernel,
            pool,
        )
    }

    pub fn n(&self) -> usize {
        self.store.n()
    }
    pub fn workers(&self) -> usize {
        self.store.workers()
    }
    pub fn algorithm(&self) -> Algorithm {
        self.algo
    }
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::SeqCst)
    }
    pub fn store(&self) -> &ShardedStore {
        &self.store
    }

    /// Place the shard blocks on a logical PS-node fleet (`[topology]`
    /// `ps_nodes`). Placement metadata only — see
    /// [`ShardedStore::set_ps_nodes`]; no parameter state moves.
    pub fn set_ps_nodes(&self, nodes: usize) {
        self.store.set_ps_nodes(nodes);
    }

    /// Worker pull (Algorithm 2): copy `w_t` out, back it up as w_bak(m),
    /// remember t for staleness accounting.
    pub fn pull(&self, worker: usize, out: &mut [f32]) {
        self.store.pull_into(worker, out);
        // Read the version *after* copying: the copy is shard-atomic, so any
        // concurrent update lands either in the copy or in a version bump we
        // observe here; staleness stays an upper-bound-accurate counter.
        let v = self.version.load(Ordering::SeqCst);
        self.pull_version[worker].store(v, Ordering::SeqCst);
        self.pull_count[worker].fetch_add(1, Ordering::SeqCst);
    }

    /// Staleness worker `m` would observe if it pushed right now: global
    /// updates applied since its last pull. Diagnostic accessor (the SSP
    /// gate itself runs on the scheduler's logical clocks, not PS state):
    /// lets tests and external monitors inspect in-flight delay without
    /// perturbing anything.
    pub fn pending_staleness(&self, worker: usize) -> u64 {
        let v = self.version.load(Ordering::SeqCst);
        v.saturating_sub(self.pull_version[worker].load(Ordering::SeqCst))
    }

    /// Pulls served to worker `m` so far (diagnostic counter for gate/churn
    /// monitoring alongside [`Self::pending_staleness`]).
    pub fn pull_count(&self, worker: usize) -> u64 {
        self.pull_count[worker].load(Ordering::SeqCst)
    }

    /// Model snapshot without backup side-effects (evaluation).
    pub fn snapshot(&self, out: &mut [f32]) {
        self.store.snapshot_into(out);
    }

    /// Build the serving snapshot plane (idempotent; `[serving]` enabled).
    /// See [`ShardedStore::enable_serving`].
    pub fn enable_serving(&self) {
        self.store.enable_serving();
    }

    /// Publish the current model to the serving plane as the next epoch,
    /// stamped with training step / virtual time
    /// ([`ShardedStore::publish_snapshot`]).
    pub fn publish_snapshot(&self, step: u64, time: f64) -> u64 {
        self.store.publish_snapshot(step, time)
    }

    /// Wait-free batched serving read against the latest published epoch
    /// ([`ShardedStore::serving_pull_batch`]); `None` when serving is
    /// disabled or nothing is published yet.
    pub fn serving_pull_batch(
        &self,
        queries: &[std::ops::Range<usize>],
        out: &mut [f32],
    ) -> Option<crate::ps::shard::SnapshotMeta> {
        self.store.serving_pull_batch(queries, out)
    }

    /// Locked-read serving baseline ([`ShardedStore::locked_pull_batch`]):
    /// copies from the live shards under their read locks, contending with
    /// pushes the way a training pull does.
    pub fn locked_pull_batch(&self, queries: &[std::ops::Range<usize>], out: &mut [f32]) {
        self.store.locked_pull_batch(queries, out);
    }

    /// Worker push (Algorithm 2): apply gradient `g` with the configured
    /// update rule at learning rate `lr`.
    pub fn push(&self, worker: usize, g: &[f32], lr: f32) -> PushOutcome {
        assert_eq!(g.len(), self.n());
        let h = self.hyper;
        match self.algo {
            Algorithm::Asgd
            | Algorithm::SequentialSgd
            | Algorithm::SyncSgd
            | Algorithm::HierSsgd
            | Algorithm::Ssp => {
                if h.momentum > 0.0 {
                    self.store.for_each_shard(|s, range| {
                        optim::momentum_step(&mut s.w, &mut s.vel, &g[range], lr, h.momentum);
                    });
                } else if self.kernel.requires_whole_vector() {
                    self.push_whole_sgd(g, lr);
                } else {
                    self.store.for_each_shard(|s, range| {
                        self.kernel.sgd(&mut s.w, &g[range], lr);
                    });
                }
            }
            Algorithm::DcAsgdConst | Algorithm::DcS3gd => {
                if h.momentum > 0.0 {
                    let bak = self.store.bak_lock(worker);
                    self.store.for_each_shard(|s, range| {
                        let ShardData { w, vel, comp, .. } = &mut *s;
                        // compensate into the shard's reusable scratch, then
                        // momentum-apply — zero allocations on this path
                        optim::compensate_into(comp, &g[range.clone()], w, &bak[range], h.lambda0);
                        optim::momentum_step(w, vel, comp, lr, h.momentum);
                    });
                } else if self.kernel.requires_whole_vector() {
                    self.push_whole_dc(worker, g, lr);
                } else {
                    let bak = self.store.bak_lock(worker);
                    self.store.for_each_shard(|s, range| {
                        self.kernel.dc(&mut s.w, &g[range.clone()], &bak[range], lr, h.lambda0);
                    });
                }
            }
            Algorithm::DcAsgdAdaptive => {
                if h.momentum > 0.0 {
                    let bak = self.store.bak_lock(worker);
                    self.store.for_each_shard(|s, range| {
                        let ShardData { w, ms, vel, comp } = &mut *s;
                        optim::compensate_adaptive_into(
                            comp,
                            &g[range.clone()],
                            w,
                            &bak[range],
                            ms,
                            h.lambda0,
                            h.ms_momentum,
                            h.eps,
                        );
                        optim::momentum_step(w, vel, comp, lr, h.momentum);
                    });
                } else if self.kernel.requires_whole_vector() {
                    self.push_whole_dca(worker, g, lr);
                } else {
                    let bak = self.store.bak_lock(worker);
                    self.store.for_each_shard(|s, range| {
                        let ShardData { w, ms, .. } = &mut *s;
                        self.kernel.dca(
                            w,
                            &g[range.clone()],
                            &bak[range],
                            ms,
                            lr,
                            h.lambda0,
                            h.ms_momentum,
                            h.eps,
                        );
                    });
                }
            }
            Algorithm::DcSyncSgd => {
                // handled by the sync coordinator via DcSsgdAccumulator;
                // a direct push falls back to the constant-lambda DC rule.
                let bak = self.store.bak_lock(worker);
                self.store.for_each_shard(|s, range| {
                    self.kernel.dc(&mut s.w, &g[range.clone()], &bak[range], lr, h.lambda0);
                });
            }
        }
        self.commit(worker)
    }

    /// Shared push tail: bump the global version and report the delay tau
    /// this update suffered.
    fn commit(&self, worker: usize) -> PushOutcome {
        let version = self.version.fetch_add(1, Ordering::SeqCst) + 1;
        let pulled = self.pull_version[worker].load(Ordering::SeqCst);
        PushOutcome { version, staleness: (version - 1).saturating_sub(pulled) }
    }

    /// Worker push of a compressed gradient ([`crate::compress`]): the
    /// decoded gradient goes through exactly the same update rules as a
    /// dense push — delay compensation composes unchanged (the *decoded*
    /// gradient is compensated against `w_bak(m)`, Eqn. 10).
    ///
    /// Sparse payloads apply shard-locally without densifying for the
    /// elementwise rules (SGD family, constant-lambda DC family) — only
    /// the shards owning transmitted coordinates take write locks, and the
    /// result is bit-identical to pushing the densified gradient. The
    /// adaptive rule (DC-ASGD-a) decodes densely first: its MeanSquare
    /// state decays at *every* coordinate per push, transmitted or not, so
    /// a truly sparse apply would change the math. Quantized payloads take
    /// a fused decode→compensate→apply pass per shard slice when the
    /// kernel is the native elementwise math and SIMD dispatch is on
    /// ([`crate::compress::decode_dc_apply`] and friends — each element of
    /// `w`/`w_bak`/`ms` is loaded exactly once, levels decode in
    /// L1-resident blocks); otherwise they decode densely into a reusable
    /// arena and run the normal dense push. Both routes are bit-identical.
    /// Momentum and whole-vector (XLA) backends don't compose with
    /// compression; config validation rejects them upstream.
    pub fn push_encoded(
        &self,
        worker: usize,
        p: &crate::compress::WirePayload,
        lr: f32,
    ) -> PushOutcome {
        use crate::compress::WirePayload as P;
        assert_eq!(p.len(), self.n(), "payload length mismatch");
        assert!(
            self.hyper.momentum == 0.0 && !self.kernel.requires_whole_vector(),
            "compression requires the native momentum-free backend"
        );
        let h = self.hyper;
        match p {
            P::Dense(g) => self.push(worker, g, lr),
            P::Quantized { bits, norm, packed, .. } => {
                if self.kernel.is_native_elementwise() && crate::optim::simd_enabled() {
                    self.push_quantized_fused(worker, *bits as u32, *norm, packed, lr)
                } else {
                    self.push_densified(worker, p, lr)
                }
            }
            P::Sparse { idx, val, .. } => match self.algo {
                Algorithm::DcAsgdAdaptive => self.push_densified(worker, p, lr),
                Algorithm::Asgd
                | Algorithm::SequentialSgd
                | Algorithm::SyncSgd
                | Algorithm::HierSsgd
                | Algorithm::Ssp => {
                    self.store.for_each_shard_sparse(idx, val, |s, range, si, sv| {
                        self.kernel.sgd_sparse(&mut s.w, range.start, si, sv, lr);
                    });
                    self.commit(worker)
                }
                Algorithm::DcAsgdConst | Algorithm::DcS3gd | Algorithm::DcSyncSgd => {
                    let bak = self.store.bak_lock(worker);
                    self.store.for_each_shard_sparse(idx, val, |s, range, si, sv| {
                        self.kernel.dc_sparse(
                            &mut s.w,
                            &bak[range.clone()],
                            range.start,
                            si,
                            sv,
                            lr,
                            h.lambda0,
                        );
                    });
                    self.commit(worker)
                }
            },
        }
    }

    /// Fused quantized push: stream the packed levels straight into the
    /// update rule, one pass over each shard slice ([`crate::compress`]'s
    /// `decode_*_apply` entry points). Bit-identical to densify-then-push:
    /// the decoded values and the per-element update expressions are the
    /// same, only the arena round-trip through DRAM is gone. Caller
    /// guarantees the kernel is native-elementwise (checked in
    /// [`Self::push_encoded`]); lock order matches the dense path
    /// (`bak` → shards).
    fn push_quantized_fused(
        &self,
        worker: usize,
        bits: u32,
        norm: f32,
        packed: &[u8],
        lr: f32,
    ) -> PushOutcome {
        let _p = crate::trace::profile::span(crate::trace::profile::Subsystem::FusedApply);
        let h = self.hyper;
        match self.algo {
            Algorithm::Asgd
            | Algorithm::SequentialSgd
            | Algorithm::SyncSgd
            | Algorithm::HierSsgd
            | Algorithm::Ssp => {
                self.store.for_each_shard(|s, range| {
                    crate::compress::decode_sgd_apply(
                        &mut s.w, range.start, bits, norm, packed, lr,
                    );
                });
            }
            Algorithm::DcAsgdConst | Algorithm::DcS3gd | Algorithm::DcSyncSgd => {
                let bak = self.store.bak_lock(worker);
                self.store.for_each_shard(|s, range| {
                    crate::compress::decode_dc_apply(
                        &mut s.w,
                        &bak[range.clone()],
                        range.start,
                        bits,
                        norm,
                        packed,
                        lr,
                        h.lambda0,
                    );
                });
            }
            Algorithm::DcAsgdAdaptive => {
                let bak = self.store.bak_lock(worker);
                self.store.for_each_shard(|s, range| {
                    let ShardData { w, ms, .. } = &mut *s;
                    crate::compress::decode_dca_apply(
                        w,
                        &bak[range.clone()],
                        ms,
                        range.start,
                        bits,
                        norm,
                        packed,
                        lr,
                        h.lambda0,
                        h.ms_momentum,
                        h.eps,
                    );
                });
            }
        }
        self.commit(worker)
    }

    /// Decode a payload into the reusable dense arena and run the normal
    /// dense push path.
    fn push_densified(
        &self,
        worker: usize,
        p: &crate::compress::WirePayload,
        lr: f32,
    ) -> PushOutcome {
        let mut buf = self.decode_scratch[worker].lock().unwrap();
        buf.resize(self.n(), 0.0);
        p.decode_into(&mut buf);
        self.push(worker, &buf, lr)
    }

    // ---- whole-vector (XLA artifact) paths --------------------------------

    fn with_whole<F: FnOnce(&mut WholeScratch)>(&self, f: F) {
        let mut s = self.whole_scratch.lock().unwrap();
        let n = self.n();
        s.w.resize(n, 0.0);
        s.bak.resize(n, 0.0);
        s.ms.resize(n, 0.0);
        f(&mut s);
    }

    fn push_whole_sgd(&self, g: &[f32], lr: f32) {
        self.with_whole(|s| {
            self.store.snapshot_into(&mut s.w);
            self.kernel.sgd(&mut s.w, g, lr);
            self.store.store_w(&s.w);
        });
    }

    fn push_whole_dc(&self, worker: usize, g: &[f32], lr: f32) {
        self.with_whole(|s| {
            self.store.snapshot_into(&mut s.w);
            self.store.read_bak(worker, &mut s.bak);
            self.kernel.dc(&mut s.w, g, &s.bak, lr, self.hyper.lambda0);
            self.store.store_w(&s.w);
        });
    }

    fn push_whole_dca(&self, worker: usize, g: &[f32], lr: f32) {
        self.with_whole(|s| {
            self.store.snapshot_into(&mut s.w);
            let WholeScratch { w, bak, ms } = &mut *s;
            self.store.read_bak_ms(worker, bak, ms);
            self.kernel.dca(
                w,
                g,
                bak,
                ms,
                lr,
                self.hyper.lambda0,
                self.hyper.ms_momentum,
                self.hyper.eps,
            );
            self.store.store_w(w);
            self.store.store_ms(ms);
        });
    }

    /// Synchronous-mode update: apply an already-aggregated gradient as one
    /// global step (used by the SSGD barrier loop). Shard math is
    /// independent, so the multi-shard apply fans out across threads for
    /// large models — bit-identical to the sequential order.
    pub fn apply_aggregated(&self, g: &[f32], lr: f32) -> u64 {
        if self.hyper.momentum > 0.0 {
            let mu = self.hyper.momentum;
            self.store.par_for_each_shard(|s, range| {
                optim::momentum_step(&mut s.w, &mut s.vel, &g[range], lr, mu);
            });
        } else {
            self.store.par_for_each_shard(|s, range| {
                self.kernel.sgd(&mut s.w, &g[range], lr);
            });
        }
        self.version.fetch_add(1, Ordering::SeqCst) + 1
    }

    /// Restore the global update counter (checkpoint resume). Pull versions
    /// resync to `v` (next pushes see zero staleness) and the per-worker
    /// pull counters restart from zero, so post-resume diagnostics count
    /// only post-resume activity instead of drifting across restores.
    pub fn set_version(&self, v: u64) {
        self.version.store(v, Ordering::SeqCst);
        for pv in &self.pull_version {
            pv.store(v, Ordering::SeqCst);
        }
        for pc in &self.pull_count {
            pc.store(0, Ordering::SeqCst);
        }
    }

    /// Worker churn: when worker `m` (re)joins — crash recovery, elastic
    /// scale-up — its stale backup model must not poison the compensation
    /// term. Refresh w_bak(m) to the current model and reset its pull
    /// version, exactly as if it had just pulled.
    pub fn reset_worker(&self, m: usize) {
        self.store.refresh_bak(m);
        self.pull_version[m].store(self.version.load(Ordering::SeqCst), Ordering::SeqCst);
    }

    /// Mutate the raw model (DC-SSGD fold); bumps the version by one.
    pub fn apply_with<F: FnOnce(&mut [f32])>(&self, f: F) -> u64 {
        // materialize into the reusable whole-vector arena, transform,
        // store back (parallel across shards for large models): the fold
        // itself is sequential, but the copies never allocate
        self.with_whole(|s| {
            self.store.snapshot_into(&mut s.w);
            f(&mut s.w);
            self.store.store_w(&s.w);
        });
        self.version.fetch_add(1, Ordering::SeqCst) + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Algorithm;

    fn hyper() -> Hyper {
        Hyper { lambda0: 0.5, ms_momentum: 0.9, momentum: 0.0, eps: optim::MS_EPS }
    }

    fn server(algo: Algorithm, n: usize, workers: usize, shards: usize) -> ParamServer {
        let init: Vec<f32> = (0..n).map(|i| (i as f32 * 0.1).sin()).collect();
        ParamServer::new(&init, workers, shards, algo, hyper(), Box::new(NativeKernel)).unwrap()
    }

    fn grad(seed: u64, n: usize) -> Vec<f32> {
        let mut rng = crate::util::rng::Pcg64::new(seed);
        (0..n).map(|_| rng.normal(0.0, 0.1) as f32).collect()
    }

    #[test]
    fn staleness_counts_intervening_updates() {
        let ps = server(Algorithm::Asgd, 64, 2, 1);
        let mut w0 = vec![0.0; 64];
        let mut w1 = vec![0.0; 64];
        ps.pull(0, &mut w0);
        ps.pull(1, &mut w1);
        let g = grad(1, 64);
        // worker 1 pushes twice, then worker 0's push sees staleness 2
        assert_eq!(ps.push(1, &g, 0.1).staleness, 0);
        ps.pull(1, &mut w1);
        assert_eq!(ps.push(1, &g, 0.1).staleness, 0);
        let out = ps.push(0, &g, 0.1);
        assert_eq!(out.staleness, 2);
        assert_eq!(out.version, 3);
    }

    #[test]
    fn pending_staleness_and_pull_counts_track_activity() {
        let ps = server(Algorithm::Asgd, 32, 2, 1);
        let mut w = vec![0.0; 32];
        ps.pull(0, &mut w);
        ps.pull(1, &mut w);
        assert_eq!(ps.pull_count(0), 1);
        assert_eq!(ps.pending_staleness(0), 0);
        let g = grad(8, 32);
        ps.push(1, &g, 0.1);
        ps.pull(1, &mut w);
        ps.push(1, &g, 0.1);
        assert_eq!(ps.pending_staleness(0), 2, "two pushes since worker 0's pull");
        assert_eq!(ps.pull_count(1), 2);
    }

    #[test]
    fn ssp_push_is_plain_sgd_and_dcs3gd_is_dc() {
        let n = 64;
        let g = grad(9, n);
        // SSP applies the plain SGD rule
        let ps = server(Algorithm::Ssp, n, 2, 2);
        let mut w = vec![0.0; n];
        ps.pull(0, &mut w);
        ps.push(0, &g, 0.2);
        let mut expect = w.clone();
        optim::sgd_step(&mut expect, &g, 0.2);
        let mut got = vec![0.0; n];
        ps.snapshot(&mut got);
        assert_eq!(got, expect);

        // DC-S3GD compensates against the worker's own backup
        let ps = server(Algorithm::DcS3gd, n, 2, 2);
        let mut w0 = vec![0.0; n];
        ps.pull(0, &mut w0);
        ps.pull(1, &mut w);
        ps.push(1, &grad(10, n), 0.2); // move the model under worker 0
        let mut now = vec![0.0; n];
        ps.snapshot(&mut now);
        ps.push(0, &g, 0.2);
        let mut expect = now.clone();
        optim::dc_step(&mut expect, &g, &w0, 0.2, 0.5);
        let mut got = vec![0.0; n];
        ps.snapshot(&mut got);
        for (a, b) in got.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn sequential_pull_push_has_zero_staleness() {
        let ps = server(Algorithm::SequentialSgd, 32, 1, 1);
        let mut w = vec![0.0; 32];
        for s in 0..5 {
            ps.pull(0, &mut w);
            let out = ps.push(0, &grad(s, 32), 0.1);
            assert_eq!(out.staleness, 0);
        }
        assert_eq!(ps.version(), 5);
    }

    #[test]
    fn dc_push_uses_workers_own_backup() {
        // two workers pull at different model versions; their DC updates
        // must compensate against *their own* snapshots
        let n = 128;
        let ps = server(Algorithm::DcAsgdConst, n, 2, 4);
        let mut w0 = vec![0.0; n];
        ps.pull(0, &mut w0);
        let g1 = grad(2, n);
        ps.push(1, &g1, 0.2); // worker 1's push moves the model
        let mut w_now = vec![0.0; n];
        ps.snapshot(&mut w_now);
        let g0 = grad(3, n);
        ps.push(0, &g0, 0.2);

        // manual expectation: dc_step on w_now against backup w0
        let mut expect = w_now.clone();
        optim::dc_step(&mut expect, &g0, &w0, 0.2, 0.5);
        let mut got = vec![0.0; n];
        ps.snapshot(&mut got);
        for (a, b) in got.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn asgd_equals_sgd_math() {
        let n = 64;
        let ps = server(Algorithm::Asgd, n, 1, 2);
        let mut w = vec![0.0; n];
        ps.pull(0, &mut w);
        let g = grad(4, n);
        ps.push(0, &g, 0.3);
        let mut expect = w.clone();
        optim::sgd_step(&mut expect, &g, 0.3);
        let mut got = vec![0.0; n];
        ps.snapshot(&mut got);
        assert_eq!(got, expect);
    }

    #[test]
    fn adaptive_updates_meansquare_state() {
        let n = 32;
        let ps = server(Algorithm::DcAsgdAdaptive, n, 1, 1);
        let mut w = vec![0.0; n];
        ps.pull(0, &mut w);
        let g = grad(5, n);
        ps.push(0, &g, 0.1);
        // second push with same gradient: ms should now be nonzero,
        // producing a different (smaller-lambda) effective step
        let mut bak = vec![0.0; n];
        let mut ms = vec![0.0; n];
        ps.store().read_bak_ms(0, &mut bak, &mut ms);
        let expect_ms: Vec<f32> = g.iter().map(|gi| 0.1 * gi * gi).collect();
        for (a, b) in ms.iter().zip(&expect_ms) {
            assert!((a - b).abs() < 1e-7, "{a} {b}");
        }
    }

    #[test]
    fn sharding_does_not_change_results() {
        for algo in [Algorithm::Asgd, Algorithm::DcAsgdConst, Algorithm::DcAsgdAdaptive] {
            let n = 517;
            let ps1 = server(algo, n, 2, 1);
            let ps8 = server(algo, n, 2, 8);
            let mut buf = vec![0.0; n];
            for step in 0..6 {
                let worker = step % 2;
                ps1.pull(worker, &mut buf);
                ps8.pull(worker, &mut buf);
                let g = grad(10 + step as u64, n);
                ps1.push(worker, &g, 0.1);
                ps8.push(worker, &g, 0.1);
            }
            let mut a = vec![0.0; n];
            let mut b = vec![0.0; n];
            ps1.snapshot(&mut a);
            ps8.snapshot(&mut b);
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-6, "{algo:?}");
            }
        }
    }

    #[test]
    fn momentum_velocity_accumulates_across_pushes() {
        let n = 16;
        let init = vec![0.0f32; n];
        let h = Hyper { momentum: 0.5, ..hyper() };
        let ps = ParamServer::new(&init, 1, 1, Algorithm::Asgd, h, Box::new(NativeKernel)).unwrap();
        let g = vec![1.0f32; n];
        let mut w = vec![0.0; n];
        ps.pull(0, &mut w);
        ps.push(0, &g, 1.0);
        ps.pull(0, &mut w);
        ps.push(0, &g, 1.0);
        let mut got = vec![0.0; n];
        ps.snapshot(&mut got);
        // v1=1, w1=-1; v2=1.5, w2=-2.5
        assert!(got.iter().all(|&x| (x + 2.5).abs() < 1e-6));
    }

    #[test]
    fn reset_worker_refreshes_backup_and_staleness() {
        let n = 64;
        let ps = server(Algorithm::DcAsgdConst, n, 2, 2);
        let mut w = vec![0.0; n];
        ps.pull(0, &mut w);
        // worker 1 advances the model 3 times while worker 0 is "crashed"
        for s in 0..3 {
            ps.pull(1, &mut w);
            ps.push(1, &grad(20 + s, n), 0.1);
        }
        // worker 0 rejoins: reset must refresh its backup to the current w
        ps.reset_worker(0);
        let mut now = vec![0.0; n];
        ps.snapshot(&mut now);
        let mut bak = vec![0.0; n];
        let mut ms = vec![0.0; n];
        ps.store().read_bak_ms(0, &mut bak, &mut ms);
        assert_eq!(bak, now);
        // and its next push sees zero staleness (as if it just pulled)
        let out = ps.push(0, &grad(30, n), 0.1);
        assert_eq!(out.staleness, 0);
    }

    #[test]
    fn set_version_restores_counters() {
        let ps = server(Algorithm::Asgd, 16, 2, 1);
        let mut w = vec![0.0; 16];
        ps.pull(0, &mut w);
        ps.pull(0, &mut w);
        assert_eq!(ps.pull_count(0), 2);
        ps.set_version(41);
        assert_eq!(ps.version(), 41);
        // diagnostics restart clean on restore: counters zeroed, no
        // phantom staleness
        assert_eq!(ps.pull_count(0), 0);
        assert_eq!(ps.pending_staleness(0), 0);
        let out = ps.push(0, &grad(1, 16), 0.1);
        assert_eq!(out.version, 42);
        assert_eq!(out.staleness, 0); // pull versions were synced to 41
    }

    #[test]
    fn encoded_push_matches_dense_push_bitwise() {
        use crate::compress::WirePayload;
        // sparse payloads must produce BIT-identical models to pushing the
        // densified gradient through the dense rule, for every update rule
        // (the adaptive rule routes through the dense decode internally)
        let n = 517;
        for algo in [Algorithm::Asgd, Algorithm::DcAsgdConst, Algorithm::DcAsgdAdaptive] {
            let enc = server(algo, n, 2, 4);
            let den = server(algo, n, 2, 4);
            let mut buf = vec![0.0; n];
            for step in 0..6u64 {
                let worker = (step % 2) as usize;
                enc.pull(worker, &mut buf);
                den.pull(worker, &mut buf);
                let g = grad(40 + step, n);
                let idx: Vec<u32> =
                    (0..n).filter(|i| (i + step as usize) % 3 == 0).map(|i| i as u32).collect();
                let val: Vec<f32> = idx.iter().map(|&i| g[i as usize]).collect();
                let mut densified = vec![0.0f32; n];
                for (&i, &v) in idx.iter().zip(&val) {
                    densified[i as usize] = v;
                }
                let p = WirePayload::Sparse { n: n as u32, idx, val };
                let a = enc.push_encoded(worker, &p, 0.1);
                let b = den.push(worker, &densified, 0.1);
                assert_eq!(a.version, b.version);
                assert_eq!(a.staleness, b.staleness);
            }
            let mut we = vec![0.0; n];
            let mut wd = vec![0.0; n];
            enc.snapshot(&mut we);
            den.snapshot(&mut wd);
            assert_eq!(we, wd, "{algo:?}: encoded push diverged from dense");
        }
    }

    #[test]
    fn quantized_push_decodes_through_dense_path() {
        use crate::compress::{GradientCodec, Qsgd, WirePayload};
        let n = 256;
        let ps = server(Algorithm::DcAsgdConst, n, 1, 2);
        let dense = server(Algorithm::DcAsgdConst, n, 1, 2);
        let mut buf = vec![0.0; n];
        ps.pull(0, &mut buf);
        dense.pull(0, &mut buf);
        let g = grad(50, n);
        let mut codec = Qsgd::new(8, crate::util::rng::Pcg64::new(1));
        let mut p = WirePayload::default();
        codec.encode(&g, &mut p);
        let mut decoded = vec![0.0f32; n];
        p.decode_into(&mut decoded);
        ps.push_encoded(0, &p, 0.2);
        dense.push(0, &decoded, 0.2);
        let mut a = vec![0.0; n];
        let mut b = vec![0.0; n];
        ps.snapshot(&mut a);
        dense.snapshot(&mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn fused_quantized_push_matches_densified_bitwise() {
        use crate::compress::{GradientCodec, Qsgd, WirePayload};
        // A kernel with identical math that opts out of the fused route
        // (is_native_elementwise = false → quantized payloads densify into
        // the arena). NativeKernel takes the fused decode→compensate→apply
        // pass; the two must produce bit-identical models for every rule.
        struct Densify;
        impl UpdateKernel for Densify {
            fn sgd(&self, w: &mut [f32], g: &[f32], lr: f32) {
                optim::sgd_step(w, g, lr)
            }
            fn dc(&self, w: &mut [f32], g: &[f32], b: &[f32], lr: f32, lam: f32) {
                optim::dc_step(w, g, b, lr, lam)
            }
            fn dca(
                &self,
                w: &mut [f32],
                g: &[f32],
                b: &[f32],
                ms: &mut [f32],
                lr: f32,
                l0: f32,
                m: f32,
                e: f32,
            ) {
                optim::dc_adaptive_step(w, g, b, ms, lr, l0, m, e)
            }
            fn name(&self) -> &'static str {
                "densify"
            }
        }
        let n = 517;
        for algo in [Algorithm::Asgd, Algorithm::DcAsgdConst, Algorithm::DcAsgdAdaptive] {
            let init: Vec<f32> = (0..n).map(|i| (i as f32 * 0.1).sin()).collect();
            let fused =
                ParamServer::new(&init, 2, 4, algo, hyper(), Box::new(NativeKernel)).unwrap();
            let dense = ParamServer::new(&init, 2, 4, algo, hyper(), Box::new(Densify)).unwrap();
            let mut buf = vec![0.0; n];
            for step in 0..6u64 {
                let worker = (step % 2) as usize;
                fused.pull(worker, &mut buf);
                dense.pull(worker, &mut buf);
                let g = grad(60 + step, n);
                let mut codec = Qsgd::new(4, crate::util::rng::Pcg64::new(step + 1));
                let mut p = WirePayload::default();
                codec.encode(&g, &mut p);
                fused.push_encoded(worker, &p, 0.1);
                dense.push_encoded(worker, &p, 0.1);
            }
            let mut a = vec![0.0; n];
            let mut b = vec![0.0; n];
            fused.snapshot(&mut a);
            dense.snapshot(&mut b);
            assert_eq!(a, b, "{algo:?}: fused quantized push diverged from densified");
        }
    }

    #[test]
    fn aggregated_apply_bumps_version_once() {
        let ps = server(Algorithm::SyncSgd, 32, 4, 2);
        let g = grad(6, 32);
        let v = ps.apply_aggregated(&g, 0.1);
        assert_eq!(v, 1);
        assert_eq!(ps.version(), 1);
    }

    #[test]
    fn whole_vector_kernel_requires_single_shard() {
        struct Whole;
        impl UpdateKernel for Whole {
            fn sgd(&self, w: &mut [f32], g: &[f32], lr: f32) {
                optim::sgd_step(w, g, lr)
            }
            fn dc(&self, w: &mut [f32], g: &[f32], b: &[f32], lr: f32, lam: f32) {
                optim::dc_step(w, g, b, lr, lam)
            }
            fn dca(
                &self,
                w: &mut [f32],
                g: &[f32],
                b: &[f32],
                ms: &mut [f32],
                lr: f32,
                l0: f32,
                m: f32,
                e: f32,
            ) {
                optim::dc_adaptive_step(w, g, b, ms, lr, l0, m, e)
            }
            fn requires_whole_vector(&self) -> bool {
                true
            }
            fn name(&self) -> &'static str {
                "whole"
            }
        }
        let init = vec![0.0f32; 16];
        assert!(ParamServer::new(&init, 1, 4, Algorithm::Asgd, hyper(), Box::new(Whole)).is_err());
        // shards=1 works and matches native math
        let ps = ParamServer::new(&init, 1, 1, Algorithm::DcAsgdConst, hyper(), Box::new(Whole))
            .unwrap();
        let mut w = vec![0.0; 16];
        ps.pull(0, &mut w);
        let g = vec![0.5f32; 16];
        ps.push(0, &g, 0.1);
        let mut got = vec![0.0; 16];
        ps.snapshot(&mut got);
        let mut expect = vec![0.0f32; 16];
        optim::dc_step(&mut expect, &g, &vec![0.0; 16], 0.1, 0.5);
        assert_eq!(got, expect);
    }
}
